"""Serve a (reduced) assigned LM architecture with batched prefill+decode —
exercises the production serving path (KV cache, slots, greedy decode) on
CPU for any --arch in the registry.

Run:  PYTHONPATH=src python examples/serve_llm.py --arch gemma2-9b
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.serve import main as serve_main

if __name__ == "__main__":
    sys.argv = [sys.argv[0], "--smoke"] + sys.argv[1:]
    serve_main()
