"""Cross-step activation cache: the FLOPs/quality dial in one script
(CPU, ~1 minute).

Samples a single request repeatedly under the same plan while sweeping
the cache refresh interval k (plus the analytic error-proxy policy) and
prints the trade-off table: analytic FLOPs vs the uncached run, realized
refresh rate, and x0 drift. interval=1 is bit-identical to no cache;
larger k trades drift for deep-block FLOPs. Every cached run after the
first replays ONE compiled runner — the refresh mask is data, not
structure.

Run:  PYTHONPATH=src python examples/cached_sampling.py [--T 20]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import CacheSpec, cache_savings
from repro.configs.base import AttnConfig, DiTConfig, ModelConfig
from repro.core import flexify
from repro.core.scheduler import FlexiSchedule
from repro.diffusion import schedule as sch
from repro.models import dit as dit_mod
from repro.pipeline import FlexiPipeline, SamplingPlan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--T", type=int, default=20)
    ap.add_argument("--train-T", type=int, default=1000)
    args = ap.parse_args()

    cfg = ModelConfig(
        name="cached-dit", family="dit", num_layers=4, d_model=96,
        d_ff=384, vocab_size=0, attn=AttnConfig(6, 6, 16, use_rope=False),
        dit=DiTConfig(latent_shape=(1, 16, 16, 4), patch_size=(1, 2, 2),
                      flex_patch_sizes=(), underlying_patch_size=(1, 2, 2),
                      conditioning="class", num_classes=10),
        mlp_activation="gelu", norm_type="layernorm",
        param_dtype="float32", compute_dtype="float32", remat="none",
        max_seq_len=256)
    key = jax.random.PRNGKey(0)
    params = dit_mod.init_dit(cfg, key)
    # break the zero-init de-embed / adaLN gates (as training would):
    # fresh DiT weights output exact zeros, which would make every
    # policy look drift-free
    for path, scale in ((("deembed", "w_flex"), 0.1),
                        (("final", "ada", "w"), 0.05),
                        (("blocks", "ada", "w"), 0.05)):
        node = params
        for p in path[:-1]:
            node = node[p]
        key = jax.random.fold_in(key, 1)
        node[path[-1]] = jax.random.normal(key, node[path[-1]].shape) * scale
    # flexify so the plan composes weak-mode token reduction WITH the
    # cache: the weak phase gets cheaper still, the powerful phase gains
    # the deep-block knob
    params, cfg = flexify(params, cfg, [(1, 4, 4)])
    pipe = FlexiPipeline(params, cfg, sch.linear_schedule(args.train_T))

    budget = FlexiSchedule.weak_first(args.T, args.T // 2)
    key = jax.random.PRNGKey(42)
    cond = jnp.asarray([7], jnp.int32)
    ts = sch.respaced_timesteps(args.train_T, args.T)

    base = SamplingPlan(T=args.T, budget=budget, guidance_scale=1.5)
    ref = pipe.sample(base, 1, key, cond=cond)
    ref_pow = float(jnp.mean(ref.x0 ** 2))
    split = CacheSpec().resolve_split(cfg.num_layers)
    print(f"model: {cfg.num_layers} blocks, split={split} shallow | "
          f"T={args.T} steps, uncached {ref.flops / 1e9:.2f} GFLOPs")
    print(f"{'policy':>14} {'rel FLOPs':>10} {'refresh':>8} "
          f"{'x0 rel-MSE':>12}")

    specs = [("no cache", None)]
    specs += [(f"interval k={k}",
               CacheSpec(policy="interval", interval=k))
              for k in (1, 2, 3, 4)]
    specs.append(("proxy (default)", CacheSpec(policy="proxy")))
    for name, spec in specs:
        plan = SamplingPlan(T=args.T, budget=budget, guidance_scale=1.5,
                            cache=spec)
        res = pipe.sample(plan, 1, key, cond=cond)
        drift = float(jnp.mean((res.x0 - ref.x0) ** 2)) / ref_pow
        if spec is None:
            rel, rate = 1.0, 1.0
        else:
            led = cache_savings(cfg, budget, ts, spec)
            rel, rate = 1.0 - led["flops_saved_frac"], led["refresh_rate"]
        tag = "  (bit-identical)" if drift == 0.0 and spec is not None \
            else ""
        print(f"{name:>14} {rel:>10.3f} {rate:>8.2f} {drift:>12.2e}{tag}")

    stats = pipe.cache_stats()
    print(f"compiled runners: {stats['compiled']} (1 uncached + 1 cached — "
          f"policy sweeps reuse the cached one)")


if __name__ == "__main__":
    main()
