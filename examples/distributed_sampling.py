"""Sequence-parallel FlexiDiT sampling on a device mesh (DESIGN.md
§distributed).

Runs on any machine: with fewer than 8 real devices it forces 8 fake CPU
host devices (the same trick CI uses), builds a (data=2, seq=4) mesh,
and samples the same plan single-device and sequence-parallel:

  PYTHONPATH=src python examples/distributed_sampling.py

The weak phase (patch 4×4, 16 tokens) and powerful phase (patch 2×2,
64 tokens) shard differently — the engine re-shards at the phase
boundary — and budget switches on the fixed mesh never recompile.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

from repro.launch.mesh import ensure_host_devices

ensure_host_devices(8)      # before the jax backend initializes

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.diffusion import schedule as sch
from repro.distributed import plan_partition
from repro.launch.mesh import make_inference_mesh
from repro.models import dit as dit_mod
from repro.pipeline import FlexiPipeline, ParallelSpec, SamplingPlan


def main():
    cfg = get_config("dit-xl-2").reduced()
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
    sched = sch.linear_schedule(100)
    mesh = make_inference_mesh(data=2, seq=4)
    print(f"mesh: {dict(zip(mesh.axis_names, mesh.devices.shape))}")

    single = FlexiPipeline(params, cfg, sched)
    multi = FlexiPipeline(params, cfg, sched, mesh=mesh)
    key = jax.random.PRNGKey(42)

    for budget in (0.6, 1.0):
        plan_sp = SamplingPlan(T=8, budget=budget, guidance_scale=1.5,
                               parallel=ParallelSpec())   # auto: ulysses
        plan_sp.validate(cfg)
        fs = plan_sp.resolve_schedule(cfg)
        part = plan_partition(cfg, fs, 4, plan_sp.parallel)
        r_sp = multi.sample(plan_sp, 4, key)
        r_1d = single.sample(SamplingPlan(T=8, budget=budget,
                                          guidance_scale=1.5), 4, key)
        diff = float(jnp.max(jnp.abs(r_sp.x0 - r_1d.x0)))
        shards = " ".join(f"mode{p.mode}:{p.tokens}tok/"
                          f"{p.sp}shards(+{p.pad}pad)"
                          for p, n in part.phases if n)
        print(f"budget={budget}: rel_compute={r_sp.relative_compute:.3f} "
              f"max|sp - single|={diff:.2e}")
        print(f"  shards: {shards} impl={part.phases[0][0].impl} "
              f"collectives={part.collective_bytes(cfg) / 1e6:.1f} MB/sample")
        assert diff < 1e-4

    stats = multi.cache_stats()
    print(f"cache: runners={stats['runners']} compiled={stats['compiled']} "
          f"(one per budget — switches never recompile)")


if __name__ == "__main__":
    main()
