"""Fault-tolerant training demo: checkpoint/restart with injected worker
failures + elastic rescale planning + straggler rebalancing — the control
plane that runs unchanged on a real multi-pod cluster.

Run:  PYTHONPATH=src python examples/fault_tolerant_training.py
"""
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config
from repro.configs.base import TrainConfig
from repro.data import pipeline as dp
from repro.launch import steps as st
from repro.models import lm
from repro.optim import adamw
from repro.runtime.elastic import plan_mesh_shape
from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                           TrainingSupervisor,
                                           run_with_recovery)
from repro.runtime.straggler import StragglerDetector, rebalance_shards


def main():
    cfg = get_config("deepseek-7b").reduced()
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=5, total_steps=60)
    params = lm.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params)
    step_fn = jax.jit(st.make_train_step(cfg, tc))
    make_batch = dp.make_lm_batch_fn(cfg.vocab_size, 64, 8)

    ckdir = Path(tempfile.mkdtemp(prefix="repro_ft_"))
    ck = Checkpointer(ckdir, keep=3, async_save=True)
    hb = HeartbeatMonitor(n_workers=8, timeout_s=1e9)
    sup = TrainingSupervisor(ck, hb, checkpoint_every=10,
                             rescale_plan=lambda n: plan_mesh_shape(n, 2))
    sd = StragglerDetector(n_workers=8)

    def train_fn(step, state):
        b = make_batch(step, 0, 1, np.random.default_rng(step))
        batch = {"tokens": jnp.asarray(b["tokens"]),
                 "targets": jnp.asarray(b["targets"])}
        p, o, m = step_fn(state["params"], state["opt"], batch)
        # simulated per-worker data-fetch timings (worker 5 is slow)
        for w in range(8):
            sd.record(w, 100.0 if w != 5 else 420.0)
        if step % 10 == 0:
            print(f"  step {step:3d} loss {float(m['loss']):.4f}")
        return {"params": p, "opt": o}

    def fault_hook(step):
        # kill two workers at step 23 (once)
        if step == 23 and not getattr(fault_hook, "fired", False):
            fault_hook.fired = True
            print("  !! injecting failure of workers [2, 6]")
            return [2, 6]
        return None

    state = {"params": params, "opt": opt}
    state, events = run_with_recovery(train_fn, state, 40, sup, fault_hook)

    print("\nrecovery events:")
    for e in events:
        print(f"  step {e.step:3d}: {e.kind:8s} {e.detail}")
    rep = sd.report(40)
    print(f"\nstraggler report: {rep}")
    print("rebalanced shards:",
          rebalance_shards(32, np.asarray([100] * 5 + [420] + [100] * 2)))
    print(f"checkpoints kept: {ck.all_steps()} (dir {ckdir})")


if __name__ == "__main__":
    main()
