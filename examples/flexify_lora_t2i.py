"""LoRA-recipe flexification of a text-conditioned DiT (§3.2) — the
workflow for models whose pre-training data is unavailable:

1. "pre-trained" T2I DiT (cross-attention conditioning);
2. flexify with per-patch-size LoRAs — the pre-trained forward pass stays
   bit-exact at patch 2;
3. distill the powerful model's predictions into the weak mode (frozen base,
   frozen cross-attention — App. C.2);
4. compare merged vs unmerged LoRA inference (Fig. 5).

Run:  PYTHONPATH=src python examples/flexify_lora_t2i.py
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttnConfig, DiTConfig, ModelConfig, TrainConfig
from repro.core import FlexiSchedule, flexify, merge_lora, trainable_mask
from repro.core.distill import make_distill_step
from repro.core.scheduler import dit_nfe_flops, lora_nfe_overhead
from repro.data import pipeline as dp
from repro.diffusion import schedule as sch
from repro.launch import steps as st
from repro.models import dit as dit_mod
from repro.optim import adamw
from repro.pipeline import FlexiPipeline, SamplingPlan


def main():
    latent = (1, 16, 16, 4)
    cfg = ModelConfig(
        name="t2i-example", family="dit", num_layers=3, d_model=96, d_ff=384,
        vocab_size=0, attn=AttnConfig(6, 6, 16, use_rope=False),
        dit=DiTConfig(latent_shape=latent, patch_size=(1, 2, 2),
                      conditioning="text", text_len=8, text_dim=96,
                      learn_sigma=False, underlying_patch_size=(1, 2, 2)),
        mlp_activation="gelu", norm_type="layernorm",
        param_dtype="float32", compute_dtype="float32", remat="none")
    sched = sch.linear_schedule(100)
    make_batch = dp.make_text_cond_batch_fn(latent, 8, 96, 32)

    # 1) "pre-trained" model (trained briefly here; in practice: loaded)
    print("== pre-training T2I DiT ==")
    tc = TrainConfig(learning_rate=2e-3, warmup_steps=10, total_steps=200)
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params)
    pre = jax.jit(st.make_dit_train_step(cfg, tc, sched))
    key = jax.random.PRNGKey(1)
    for i in range(200):
        b = make_batch(i, 0, 1, np.random.default_rng(i))
        batch = {"x0": jnp.asarray(b["x0"]), "cond": jnp.asarray(b["cond"])}
        params, opt, m = pre(params, opt, batch, jax.random.fold_in(key, i))

    # 2) flexify with LoRAs
    print("== flexify (LoRA rank 8) ==")
    fparams, fcfg = flexify(params, cfg, [(1, 4, 4)], lora_rank=8)
    x = jnp.asarray(make_batch(0, 0, 1, np.random.default_rng(0))["x0"][:2])
    t = jnp.asarray([10.0, 50.0])
    cond = jnp.asarray(make_batch(0, 0, 1,
                                  np.random.default_rng(0))["cond"][:2])
    base = dit_mod.dit_forward(params, x, t, cond, cfg)
    out0 = dit_mod.dit_forward(fparams, x, t, cond, fcfg, mode=0)
    print(f"  mode-0 bit-exactness: max|Δ| = "
          f"{float(jnp.abs(out0 - base).max()):.2e}")

    # 3) distillation (teacher = powerful, student = weak + LoRA)
    print("== distilling powerful → weak ==")
    mask = trainable_mask(fparams, "lora")
    tc2 = TrainConfig(learning_rate=2e-3, warmup_steps=5, total_steps=150)
    dstep = jax.jit(make_distill_step(fcfg, tc2, sched, mode_weak=1,
                                      trainable=mask))
    opt = adamw.init_opt_state(fparams)
    for i in range(150):
        b = make_batch(i, 0, 1, np.random.default_rng(5000 + i))
        batch = {"x0": jnp.asarray(b["x0"]), "cond": jnp.asarray(b["cond"])}
        fparams, opt, m = dstep(fparams, opt, batch,
                                jax.random.fold_in(key, i))
        if i % 30 == 0:
            print(f"  step {i:4d} distill loss {float(m['distill_loss']):.5f}")

    # 4) merged vs unmerged inference (Fig. 5 trade-off)
    merged = merge_lora(fparams, fcfg, 1)
    w_un = dit_mod.dit_forward(fparams, x, t, cond, fcfg, mode=1)
    w_me = dit_mod.dit_forward(merged, x, t, cond, fcfg, mode=1)
    print(f"  merged vs unmerged max|Δ| = "
          f"{float(jnp.abs(w_un - w_me).max()):.2e}")
    f_base = dit_nfe_flops(fcfg, 1)
    f_lora = lora_nfe_overhead(fcfg, 1)
    print(f"  unmerged LoRA FLOPs overhead per NFE: "
          f"{100 * f_lora / f_base:.2f}% (paper: 'minimal')")

    # 5) end-to-end sampling through the pipeline: the plan's `lora` field
    #    picks the variant; merging is handled (and cached) internally
    print("== sampling merged vs unmerged (pipeline API) ==")
    pipe = FlexiPipeline(fparams, fcfg, sched)
    T = 12
    b = make_batch(0, 0, 1, np.random.default_rng(9))
    y = jnp.asarray(b["cond"][:8])
    plan_un = SamplingPlan(T=T, budget=FlexiSchedule.weak_first(T, 8),
                           guidance_scale=1.5, lora="unmerged")
    plan_me = SamplingPlan(T=T, budget=FlexiSchedule.weak_first(T, 8),
                           guidance_scale=1.5, lora="merged")
    key = jax.random.PRNGKey(17)
    r_un = pipe.sample(plan_un, 8, key, cond=y)
    r_me = pipe.sample(plan_me, 8, key, cond=y)
    print(f"  sampled merged vs unmerged max|Δ| = "
          f"{float(jnp.abs(r_un.x0 - r_me.x0).max()):.2e}")
    print(f"  FLOPs: unmerged {r_un.flops:.3e} vs merged {r_me.flops:.3e} "
          f"(+{100 * (r_un.flops / r_me.flops - 1):.2f}%)")
    print("done.")


if __name__ == "__main__":
    main()
