"""Quickstart: the full FlexiDiT story in one script (CPU, ~2 minutes).

1. pre-train a small class-conditional DiT on synthetic latents;
2. flexify it to also understand patch size 4 (§3.1, shared params);
3. fine-tune alternating patch sizes;
4. sample with the weak→powerful inference scheduler and compare quality
   and FLOPs against the all-powerful baseline.

Run:  PYTHONPATH=src python examples/quickstart.py [--steps 400]
"""
import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import AttnConfig, DiTConfig, ModelConfig, TrainConfig
from repro.core import FlexiSchedule, flexify
from repro.data import pipeline as dp
from repro.diffusion import schedule as sch
from repro.launch import steps as st
from repro.models import dit as dit_mod
from repro.optim import adamw
from repro.pipeline import FlexiPipeline, SamplingPlan


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    ap.add_argument("--sample-T", type=int, default=20)
    args = ap.parse_args()

    latent = (1, 16, 16, 4)
    cfg = ModelConfig(
        name="quickstart-dit", family="dit", num_layers=3, d_model=96,
        d_ff=384, vocab_size=0, attn=AttnConfig(6, 6, 16, use_rope=False),
        dit=DiTConfig(latent_shape=latent, patch_size=(1, 2, 2),
                      conditioning="class", num_classes=8, learn_sigma=False,
                      underlying_patch_size=(1, 2, 2)),
        mlp_activation="gelu", norm_type="layernorm",
        param_dtype="float32", compute_dtype="float32", remat="none")
    sched = sch.linear_schedule(100)
    tc = TrainConfig(learning_rate=2e-3, warmup_steps=20,
                     total_steps=args.steps)
    make_batch = dp.make_dit_batch_fn(latent, 8, 32, 0.15)

    # 1) pre-train (powerful patch size only)
    print("== pre-training DiT (patch 2) ==")
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params)
    pre = jax.jit(st.make_dit_train_step(cfg, tc, sched))
    key = jax.random.PRNGKey(1)
    half = args.steps // 2
    for i in range(half):
        b = make_batch(i, 0, 1, np.random.default_rng(i))
        batch = {"x0": jnp.asarray(b["x0"]), "cond": jnp.asarray(b["cond"])}
        params, opt, m = pre(params, opt, batch, jax.random.fold_in(key, i))
        if i % 50 == 0:
            print(f"  step {i:4d} loss {float(m['loss']):.4f}")

    # 2) flexify (adds patch size 4 with PI-resize init — §3.1)
    print("== flexifying to patch sizes {2, 4} ==")
    fparams, fcfg = flexify(params, cfg, [(1, 4, 4)])

    # 3) fine-tune, alternating patch sizes (<< pre-training compute)
    opt = adamw.init_opt_state(fparams)
    mode_steps = [jax.jit(st.make_dit_train_step(fcfg, tc, sched, mode=m))
                  for m in (0, 1)]
    for i in range(half, args.steps):
        b = make_batch(i, 0, 1, np.random.default_rng(i))
        batch = {"x0": jnp.asarray(b["x0"]), "cond": jnp.asarray(b["cond"])}
        fparams, opt, m = mode_steps[i % 2](fparams, opt, batch,
                                            jax.random.fold_in(key, i))
        if i % 50 == 0:
            print(f"  step {i:4d} loss {float(m['loss']):.4f} "
                  f"(mode {i % 2})")

    # 4) sample: all-powerful vs weak→powerful scheduler, through the
    #    unified pipeline API (compile-once across the budget sweep)
    from benchmarks import common as C
    ref, _ = C.reference_set(128, latent=latent)
    pipe = FlexiPipeline(fparams, fcfg, sched)
    T = args.sample_T
    print("== sampling ==")
    for T_weak in (0, T // 2, 3 * T // 4):
        plan = SamplingPlan(T=T, budget=FlexiSchedule.weak_first(T, T_weak),
                            guidance_scale=1.5)
        res = pipe.sample(plan, 48, jax.random.PRNGKey(42))
        fid = C.fid_proxy(np.asarray(res.x0), ref)
        print(f"  T_weak={T_weak:2d}/{T}  "
              f"compute={res.relative_compute*100:5.1f}%  "
              f"FID-proxy={fid:.3f}")
    # fraction budgets solve to the cheapest weak-first schedule themselves
    plan = SamplingPlan(T=T, budget=0.6, guidance_scale=1.5)
    res = pipe.sample(plan, 48, jax.random.PRNGKey(42))
    fs = res.trace["schedule"]
    print(f"  budget=0.60 → T_weak={fs.phases[0][1]}/{T}  "
          f"compute={res.relative_compute*100:5.1f}%  "
          f"FID-proxy={C.fid_proxy(np.asarray(res.x0), ref):.3f}")
    print("done — weak early steps save >40% FLOPs at comparable quality.")


if __name__ == "__main__":
    main()
