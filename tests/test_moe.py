"""MoE: sort-based dispatch vs dense oracle; capacity behavior; aux losses."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig
from repro.models.common import init_tree
from repro.models.moe import (capacity, moe_apply_dense, moe_apply_sorted,
                              moe_schema)


def _setup(E=4, k=2, shared=1, cf=4.0, d=32, e_ff=16, seed=0):
    cfg = MoEConfig(num_experts=E, num_experts_per_tok=k,
                    num_shared_experts=shared, expert_d_ff=e_ff,
                    capacity_factor=cf)
    params = init_tree(moe_schema(d, cfg, 0), jax.random.PRNGKey(seed),
                       jnp.float32)
    return cfg, params


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("E,k", [(4, 2), (8, 2), (4, 1)])
def test_sorted_matches_dense_oracle(E, k, seed):
    cfg, params = _setup(E=E, k=k, cf=8.0, seed=seed)   # cf high → no drops
    x = jax.random.normal(jax.random.PRNGKey(seed + 10), (2, 8, 32))
    y_sorted, aux_s = moe_apply_sorted(params, x, cfg)
    y_dense, _ = moe_apply_dense(params, x, cfg)
    assert float(aux_s["dropped_fraction"]) == 0.0
    np.testing.assert_allclose(np.asarray(y_sorted), np.asarray(y_dense),
                               atol=1e-4, rtol=1e-4)


def test_capacity_drops_tokens():
    cfg, params = _setup(cf=0.25)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 32, 32))
    _, aux = moe_apply_sorted(params, x, cfg)
    assert float(aux["dropped_fraction"]) > 0.0


def test_capacity_value():
    cfg = MoEConfig(num_experts=8, num_experts_per_tok=2,
                    capacity_factor=1.25)
    c = capacity(1024, cfg)
    assert c >= 1024 * 2 * 1.25 / 8 and c % 8 == 0


def test_aux_losses_present_and_positive():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 32))
    _, aux = moe_apply_sorted(params, x, cfg)
    assert float(aux["load_balance"]) > 0
    assert float(aux["router_z"]) >= 0


def test_gradients_flow_through_dispatch():
    cfg, params = _setup(cf=8.0)
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, 32))

    def loss(p):
        y, _ = moe_apply_sorted(p, x, cfg)
        return jnp.sum(y ** 2)

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["w_in"]).max()) > 0
    assert float(jnp.abs(g["router"]).max()) > 0
