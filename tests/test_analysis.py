"""Trace-safety analysis layer: per-rule fixtures (one flagged, one
passing), suppression + baseline round-trips, the pinned cache-key field
sets, jaxpr fingerprint invariance across data-only switches, and the
strict CLI (DESIGN.md §analysis)."""
import dataclasses
import json
import sys
import textwrap
from pathlib import Path

import pytest

from repro.analysis import engine
from repro.analysis import rules_cachekey as rc

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

pytestmark = pytest.mark.tier1


def _lint_src(tmp_path, name, src, **kw):
    p = tmp_path / name
    p.write_text(textwrap.dedent(src))
    return engine.lint_paths([p], **kw)


def _rules(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# Trace-safety rule: flagged / passing fixtures


BAD_TRACED = """
    import jax
    import jax.numpy as jnp
    import numpy as np

    @jax.jit
    def step(x, t):
        if t > 0:
            x = x + 1
        n = int(jnp.sum(x))
        k = len(x)
        msg = f"value={x}"
        y = np.abs(x)
        return x * n + y
"""

GOOD_TRACED = """
    import jax
    import jax.numpy as jnp

    @jax.jit
    def step(x, t, flag=None):
        if flag is None:
            x = x * 2
        if x.ndim == 3:
            x = x[None]
        n = x.shape[0]
        return jnp.where(t > 0, x + 1.0, x) * n
"""


def test_trace_rules_flag_bad_fixture(tmp_path):
    found = _rules(_lint_src(tmp_path, "bad.py", BAD_TRACED))
    assert {"trace-python-branch", "trace-host-cast", "trace-len",
            "trace-fstring", "trace-host-np"} <= found


def test_trace_rules_pass_good_fixture(tmp_path):
    assert _lint_src(tmp_path, "good.py", GOOD_TRACED) == []


def test_traced_marker_extends_coverage(tmp_path):
    src = """
        import jax.numpy as jnp

        def helper(x):  # repro: traced
            return int(jnp.sum(x))
    """
    assert "trace-host-cast" in _rules(_lint_src(tmp_path, "m.py", src))
    # without the marker the function is host code: int() on a device
    # value is only flagged inside loops (hot-host-sync)
    assert _lint_src(tmp_path, "n.py", src.replace("# repro: traced", "")) \
        == []


def test_hot_host_sync_rule(tmp_path):
    bad = """
        import jax.numpy as jnp

        def drive(xs):
            out = []
            for x in xs:
                out.append(float(jnp.mean(x)))
            return out
    """
    good = """
        import jax.numpy as jnp

        def drive(xs):
            total = jnp.mean(jnp.stack([jnp.mean(x) for x in xs]))
            return float(total)
    """
    assert "hot-host-sync" in _rules(_lint_src(tmp_path, "bad.py", bad))
    assert _lint_src(tmp_path, "good.py", good) == []


# ---------------------------------------------------------------------------
# Mask-parity rule


def test_mask_parity_flags_reimplementation(tmp_path):
    bad = """
        def segment_allowed(q_seg, k_seg):
            return q_seg == k_seg
    """
    rules = _rules(_lint_src(tmp_path, "bad.py", bad))
    assert "mask-parity" in rules


def test_mask_parity_flags_inline_comparison(tmp_path):
    bad = """
        import jax.numpy as jnp

        def my_mask(q_seg, k_seg):
            return jnp.where(q_seg[:, None] == k_seg[None, :], 0.0, -1e9)
    """
    assert "mask-parity" in _rules(_lint_src(tmp_path, "bad.py", bad))


def test_mask_parity_passes_importer(tmp_path):
    good = """
        from repro.kernels.attention import mask

        def my_mask(q_seg, k_seg):
            return mask.segment_allowed(q_seg, k_seg)
    """
    assert _lint_src(tmp_path, "good.py", good) == []


def test_backends_import_shared_mask():
    """The real backends must keep importing the canonical mask module."""
    findings = engine.lint_paths(
        [engine.REPO_ROOT / "src" / "repro" / "models",
         engine.REPO_ROOT / "src" / "repro" / "kernels",
         engine.REPO_ROOT / "src" / "repro" / "distributed"])
    assert not [f for f in findings if f.rule.startswith("mask-parity")], \
        [f.render() for f in findings]


# ---------------------------------------------------------------------------
# Suppressions + baseline round-trip


def test_inline_suppression_roundtrip(tmp_path):
    src = """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x):
            return int(jnp.sum(x))  # repro: ignore[trace-host-cast]
    """
    assert _lint_src(tmp_path, "s.py", src) == []
    kept = _lint_src(tmp_path, "s.py", src, collect_suppressed=True)
    assert "trace-host-cast" in _rules(kept)


def test_bare_suppression_covers_all_rules(tmp_path):
    src = """
        import jax
        import jax.numpy as jnp

        @jax.jit
        def step(x):
            return int(jnp.sum(x))  # repro: ignore
    """
    assert _lint_src(tmp_path, "s.py", src) == []


def test_baseline_roundtrip(tmp_path):
    f = engine.Finding("trace-host-cast", "error", "pkg/mod.py", 12,
                      "msg", "fn")
    entries = engine.baseline_entries([f], justification="known")
    new, old = engine.split_baselined([f], entries)
    assert new == [] and old == [f]
    # the key is line-free: the same finding at a drifted line still
    # matches its baseline entry
    f2 = dataclasses.replace(f, line=99)
    new2, old2 = engine.split_baselined([f2], entries)
    assert new2 == [] and old2 == [f2]


def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "baseline.json"
    p.write_text(json.dumps({"findings": [
        {"rule": "r", "path": "p.py", "symbol": "f"}]}))
    with pytest.raises(ValueError, match="justification"):
        engine.load_baseline(p)


# ---------------------------------------------------------------------------
# Cache-key completeness (satellite: the pinned field sets)


def test_check_witnesses_core():
    ok = rc.check_witnesses(["a", "b"], {"a": ("wa",)}, ("b",),
                            "key = (wa, other)", "X")
    assert ok == []
    missing = rc.check_witnesses(["a"], {"a": ("zzz",)}, (), "key = (wa,)",
                                 "X")
    assert missing and missing[0][0] == "a"
    unclass = rc.check_witnesses(["c"], {}, (), "", "X")
    assert unclass == [("c", "unclassified")]


def test_sampling_plan_field_set_pinned():
    """Adding a SamplingPlan field must update the witness tables (and
    the cache key) — this pin makes the omission a test failure."""
    from repro.pipeline.plan import SamplingPlan
    fields = {f.name for f in dataclasses.fields(SamplingPlan)}
    assert fields == {"T", "budget", "solver", "guidance_scale",
                      "guidance_kind", "weak_mode", "lora", "weak_last",
                      "clip_x0", "parallel", "cache", "attn_backend"}
    assert fields == set(rc.PLAN_WITNESSES) | set(rc.PLAN_DATA_ONLY)


def test_spec_field_sets_pinned():
    from repro.cache.policy import CacheSpec
    from repro.distributed.partition import ParallelSpec
    from repro.pipeline.packed import PackLayout
    assert {f.name for f in dataclasses.fields(CacheSpec)} == \
        {"policy", "interval", "bands", "threshold", "split"}
    assert {f.name for f in dataclasses.fields(CacheSpec)} == \
        set(rc.CACHESPEC_STRUCTURAL) | set(rc.CACHESPEC_DATA_ONLY)
    assert {f.name for f in dataclasses.fields(ParallelSpec)} == \
        {"axis", "attn"}
    assert {f.name for f in dataclasses.fields(PackLayout)} == \
        {"groups", "guided", "row_capacity"}


def test_cachekey_rule_clean_on_repo():
    """Every structural field's witness is present in the live runner /
    packed keys (the rule would flag a key gap)."""
    findings = engine.lint_paths(
        [engine.REPO_ROOT / "src" / "repro" / "pipeline"])
    cachekey = [f for f in findings if f.rule.startswith("cachekey")]
    assert cachekey == [], [f.render() for f in cachekey]


def test_cachekey_rule_flags_a_gap():
    """Drop a witness from the extracted key text and the rule fires."""
    problems = rc.check_witnesses(
        ["attn_backend"], rc.PLAN_WITNESSES, rc.PLAN_DATA_ONLY,
        "sig = (plan.solver, plan.clip_x0)", "SamplingPlan")
    assert problems and problems[0][0] == "attn_backend"


# ---------------------------------------------------------------------------
# Level 2: jaxpr fingerprints


def test_fingerprint_sees_baked_constants():
    """Two closures identical in structure but with different baked
    constant VALUES must fingerprint differently — baked data is a
    per-trace recompile hazard."""
    import jax
    import jax.numpy as jnp
    from repro.analysis.jaxpr_audit import fingerprint
    c1 = jnp.arange(4.0)
    c2 = jnp.arange(4.0) * 2
    x = jnp.zeros((4,))
    f1 = fingerprint(jax.make_jaxpr(lambda v: v + c1)(x))
    f2 = fingerprint(jax.make_jaxpr(lambda v: v + c2)(x))
    f1b = fingerprint(jax.make_jaxpr(lambda v: v + c1)(x))
    assert f1 == f1b
    assert f1 != f2


def test_fingerprint_invariant_across_budget_ladder():
    from repro.analysis import jaxpr_audit
    rep = jaxpr_audit.audit_packed_step()
    bad = [f for f in rep.findings
           if f.rule in ("jaxpr-fingerprint-drift", "jaxpr-trace-failure")]
    assert bad == [], [f.render() for f in bad]


def test_fingerprint_invariant_across_cache_policy():
    from repro.analysis import jaxpr_audit
    for unit in (jaxpr_audit.audit_packed_cached_step,
                 jaxpr_audit.audit_cached_runner):
        rep = unit()
        bad = [f for f in rep.findings
               if f.rule in ("jaxpr-fingerprint-drift",
                             "jaxpr-trace-failure")]
        assert bad == [], [f.render() for f in bad]


def test_fingerprint_invariant_across_pack_segments():
    from repro.analysis import jaxpr_audit
    rep = jaxpr_audit.audit_attention_segments()
    bad = [f for f in rep.findings
           if f.rule in ("jaxpr-fingerprint-drift", "jaxpr-trace-failure")]
    assert bad == [], [f.render() for f in bad]


# ---------------------------------------------------------------------------
# The strict gate itself


def test_strict_cli_clean_against_baseline():
    """`python -m repro.analysis --strict src/repro` (Level 1) must be
    clean against the committed baseline — the tier-1 form of the CI
    gate (the full jaxpr pass is covered unit-wise above and by
    `benchmarks.run --suite analysis`)."""
    from repro.analysis.__main__ import main
    rc_ = main(["--no-jaxpr", "--strict",
                str(engine.REPO_ROOT / "src" / "repro")])
    assert rc_ == 0


def test_bench_baseline_dotted_paths(tmp_path):
    from benchmarks.baseline import BaselineRegression, check_baseline
    p = tmp_path / "baselines.json"
    p.write_text(json.dumps({"b": {
        "engine.recompiles": {"max": 0},
        "results.1.eff": {"min": 0.9},
    }}))
    metrics = {"engine": {"recompiles": 0},
               "results": [{"eff": 0.5}, {"eff": 0.95}]}
    check_baseline("b", metrics, path=p)
    metrics["engine"]["recompiles"] = 2
    with pytest.raises(BaselineRegression, match="engine.recompiles"):
        check_baseline("b", metrics, path=p)
    with pytest.raises(BaselineRegression, match="missing"):
        check_baseline("b", {"engine": {}}, path=p)
