"""Prefill + decode must reproduce the full forward, for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnConfig, ModelConfig, MoEConfig, SSMConfig
from repro.models import lm


def mk(family, **kw):
    attn = AttnConfig(num_heads=4, num_kv_heads=2, head_dim=16)
    base = dict(name="t", family=family, num_layers=2, d_model=64, d_ff=128,
                vocab_size=97, attn=attn, param_dtype="float32",
                compute_dtype="float32", remat="none", max_seq_len=64)
    base.update(kw)
    return ModelConfig(**base)


CASES = {
    "dense": mk("dense"),
    "dense_local_softcap": mk("dense", attn=AttnConfig(
        4, 2, 16, sliding_window=8, local_global_pattern="LG",
        logit_softcap=30.0), use_post_norm=True),
    "qwen_bias": mk("dense", attn=AttnConfig(4, 2, 16, qkv_bias=True)),
    "moe": mk("moe", moe=MoEConfig(4, 2, 1, expert_d_ff=32,
                                   capacity_factor=4.0)),
    "ssm": mk("ssm", attn=None, d_ff=0,
              ssm=SSMConfig(state_dim=8, head_dim=16, chunk_size=8)),
    "hybrid": mk("hybrid", ssm=SSMConfig(state_dim=8, head_dim=16,
                                         chunk_size=8)),
    "vlm": mk("vlm", num_layers=4, cross_attn_every=2, vision_tokens=8),
    "audio": mk("audio", encoder_layers=2, audio_frames=12),
}


def _extras(cfg, key, B):
    extra = {}
    if cfg.family == "vlm":
        extra["vision"] = jax.random.normal(key, (B, 8, 64))
    if cfg.family == "audio":
        extra["frames"] = jax.random.normal(key, (B, 12, 64))
    return extra


@pytest.mark.parametrize("name", sorted(CASES))
def test_prefill_decode_match_forward(name):
    cfg = CASES[name]
    key = jax.random.PRNGKey(0)
    B, S = 2, 16
    params = lm.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extra = _extras(cfg, key, B)
    full, _ = lm.forward_train(params, tokens, cfg, extra=extra)
    pre, cache = lm.prefill(params, tokens[:, :S - 1], cfg, extra=extra)

    from conftest import pad_cache_seq
    cache = pad_cache_seq(cache, 1)
    dec, _ = lm.decode_step(params, cache, tokens[:, S - 1:S],
                            jnp.full((B,), S - 1, jnp.int32), cfg)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, S - 2]),
                               atol=3e-4, rtol=3e-4)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, S - 1]),
                               atol=3e-4, rtol=3e-4)


@pytest.mark.parametrize("name", sorted(CASES))
def test_train_loss_and_grads_finite(name):
    cfg = CASES[name]
    key = jax.random.PRNGKey(1)
    B, S = 2, 16
    params = lm.init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    batch.update(_extras(cfg, key, B))
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: lm.lm_loss(p, batch, cfg), has_aux=True)(params)
    assert np.isfinite(float(loss))
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


def test_sliding_window_restricts_attention():
    """Token far outside the window must not influence the output."""
    cfg = mk("dense", attn=AttnConfig(4, 2, 16, sliding_window=4,
                                      local_global_pattern="L"))
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B, S = 1, 24
    tokens = jax.random.randint(key, (B, S), 0, 97)
    logits1, _ = lm.forward_train(params, tokens, cfg)
    tokens2 = tokens.at[0, 0].set((tokens[0, 0] + 1) % 97)
    logits2, _ = lm.forward_train(params, tokens2, cfg)
    # position 0 changed → last position (>window away) unaffected
    np.testing.assert_allclose(np.asarray(logits1[0, -1]),
                               np.asarray(logits2[0, -1]), atol=1e-5)
    # but a nearby position IS affected
    assert np.abs(np.asarray(logits1[0, 1]) -
                  np.asarray(logits2[0, 1])).max() > 1e-4
