"""End-to-end inference telemetry (DESIGN.md §telemetry).

The load-bearing asserts: tapped steps produce BIT-IDENTICAL latents to
untapped ones (taps are data, not structure), the on-device drift tap
matches an eager host recomputation, turning telemetry on adds zero
recompiles to a warm engine, and the exported trace is valid Chrome
trace-event JSON.
"""
import ast
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import apply as cache_apply
from repro.core import flexify
from repro.core.guidance import GuidanceConfig
from repro.diffusion import schedule as sch
from repro.pipeline import FlexiPipeline, PackLayout, SamplingPlan
from repro.pipeline.packed import make_packed_step_fn
from repro.pipeline.plan import CacheSpec
from repro.serving import ServingEngine
from repro.telemetry import TapAggregator, TapSample, Telemetry
from repro.telemetry import export as tel_export
from repro.telemetry.trace import ENGINE_PID, REQUEST_PID, SpanRecorder

pytestmark = pytest.mark.tier1

T = 6


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        self.t += 0.001          # every read advances: spans get nonzero dur
        return self.t


@pytest.fixture(scope="module")
def flexi(tiny_dit_cfg, trained_like_dit):
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    return fparams, fcfg, sch.linear_schedule(100)


@pytest.fixture(scope="module")
def pipe(flexi):
    fparams, fcfg, sched = flexi
    return FlexiPipeline(fparams, fcfg, sched)


# ---------------------------------------------------------------------------
# SpanRecorder / trace export


def test_span_recorder_ring_buffer_counts_drops():
    rec = SpanRecorder(clock=FakeClock(), max_events=4)
    for i in range(7):
        rec.instant(f"e{i}")
    assert len(rec.events) == 4
    assert rec.events_recorded == 7
    assert rec.events_dropped == 3
    assert [e.name for e in rec.events] == ["e3", "e4", "e5", "e6"]


def test_span_recorder_event_kinds():
    rec = SpanRecorder(clock=FakeClock())
    with rec.span("work", args={"k": 2}):
        pass
    rec.complete("req0", 1.0, 3.5, pid=REQUEST_PID, tid=7,
                 args={"budget": 0.6})
    rec.counter("engine", {"inflight": 3.0})
    spans = rec.by_name("work")
    assert len(spans) == 1 and spans[0].ph == "X" and spans[0].dur > 0
    req = rec.by_name("req0")[0]
    assert (req.pid, req.tid, req.dur) == (REQUEST_PID, 7, 2.5)
    assert rec.by_name("engine")[0].ph == "C"


def test_chrome_trace_export_roundtrip(tmp_path):
    rec = SpanRecorder(clock=FakeClock())
    with rec.span("dispatch"):
        pass
    rec.instant("mark")
    path = tmp_path / "trace.json"
    rec.dump(str(path))
    t = json.loads(path.read_text())           # must be plain-JSON loadable
    evs = t["traceEvents"]
    # process metadata names both tracks; ts/dur are exported in µs
    meta = [e for e in evs if e["ph"] == "M"]
    assert {e["pid"] for e in meta} == {ENGINE_PID, REQUEST_PID}
    x = next(e for e in evs if e["ph"] == "X")
    src = rec.by_name("dispatch")[0]
    assert x["ts"] == pytest.approx(src.ts * 1e6)
    assert x["dur"] == pytest.approx(src.dur * 1e6)
    inst = next(e for e in evs if e["ph"] == "i")
    assert inst["s"] == "t"


# ---------------------------------------------------------------------------
# TapAggregator


def _sample(k=2, n_real=(1, 2), caps=(2, 3), drift=True, t=0.0):
    groups = tuple((m, c) for m, c in zip((0, 1), caps))
    eps = tuple(np.full((k, c), 1.0 + g) for g, c in enumerate(caps))
    dr = tuple(np.full((k, c), 0.5 * (g + 1)) for g, c in enumerate(caps)) \
        if drift else None
    return TapSample(time=t, k=k, groups=groups, n_real=n_real,
                     eps_norm=eps, drift=dr,
                     attn_blocks=np.asarray([3, 4], np.int32))


def test_tap_aggregator_masks_dummy_slots():
    agg = TapAggregator()
    agg.add(_sample(n_real=(1, 2)))
    out = agg.aggregate()
    # 2 steps x (1 + 2) live requests = 6 request-steps, dummies excluded
    assert out["request_steps"] == 6
    assert out["eps_norm"]["mean"] == pytest.approx((1.0 * 2 + 2.0 * 4) / 6)
    assert out["drift"]["max"] == pytest.approx(1.0)
    assert out["drift_per_mode"] == {"0": pytest.approx(0.5),
                                     "1": pytest.approx(1.0)}
    assert out["attn_blocks"] == {"active": 6, "total": 8,
                                  "skip_rate": pytest.approx(0.25)}


def test_tap_counter_series_backdated_into_trace():
    agg = TapAggregator()
    agg.add(_sample(t=1.5))
    agg.add(_sample(n_real=(0, 0), t=2.5))     # all-dummy: no point
    series = agg.counter_series()
    assert len(series) == 1
    when, vals = series[0]
    assert when == 1.5
    assert vals["drift_max"] == pytest.approx(1.0)
    assert set(vals) == {"eps_norm_mean", "drift_mean", "drift_max"}
    rec = SpanRecorder(clock=FakeClock(10.0))
    rec.counter("taps", vals, ts=when)
    assert rec.by_name("taps")[0].ts == 1.5    # dispatch time, not now


def test_tap_aggregator_empty_groups_and_window():
    agg = TapAggregator(max_samples=2)
    for i in range(5):
        agg.add(_sample(n_real=(0, 0), t=float(i)))
    out = agg.aggregate()
    assert len(agg) == 2
    assert out["samples_recorded"] == 5
    assert out["request_steps"] == 0
    assert "eps_norm" not in out and "drift" not in out


# ---------------------------------------------------------------------------
# Exporters


def test_flatten_drops_nan_and_sanitizes():
    flat = tel_export.flatten_metrics(
        {"a": {"p50": 1.5, "bad": float("nan")}, "ok": True, "s": "str"})
    assert flat == {"repro_a_p50": 1.5, "repro_ok": 1.0}


def test_prometheus_text_format():
    text = tel_export.prometheus_text(summary={"served": 3.0},
                                      taps={"drift": {"mean": 0.25}})
    lines = text.strip().splitlines()
    assert "# TYPE repro_serving_served gauge" in lines
    assert "repro_serving_served 3" in lines
    assert "repro_taps_drift_mean 0.25" in lines


def test_metrics_line_order_and_content():
    line = tel_export.metrics_line(
        {"served": 5, "p99": 2.0, "p50": 1.0, "zzz": 9.0},
        taps={"drift": {"mean": 0.5, "max": 1.5}},
        compile_stats={"compiled": 4})
    assert line.startswith("[metrics] served=5 p50=1 p99=2")
    assert "drift_mean=0.5" in line and "compiled=4" in line
    assert line.rstrip().endswith("zzz=9")      # unknown keys trail


# ---------------------------------------------------------------------------
# Taps are data, not structure: bit-identity + drift ≡ eager


@pytest.mark.parametrize("cache_split", [None, 1])
def test_tapped_step_bit_identical(flexi, cache_split):
    fparams, fcfg, sched = flexi
    layout = PackLayout(groups=((0, 1), (1, 2)), guided=True)
    kw = dict(k_steps=2, cache_split=cache_split)
    off = make_packed_step_fn(fcfg, sched, layout, **kw)
    on = make_packed_step_fn(fcfg, sched, layout, taps=True, **kw)
    xs, metas, keys, deltas, refreshes = [], [], [], [], []
    key = jax.random.PRNGKey(0)
    for gi, (mode, n) in enumerate(layout.groups):
        xs.append(jax.random.normal(jax.random.fold_in(key, gi),
                                    (n,) + fcfg.dit.latent_shape))
        meta = np.zeros((2, 3, n), np.int32)
        meta[0, 0], meta[1, 0] = 90, 80
        meta[0, 1], meta[1, 1] = 80, 70
        metas.append(jnp.asarray(meta))
        keys.append(jnp.zeros((2, n, 2), jnp.uint32))
        if cache_split is not None:
            _eb, N, d = cache_apply.delta_shape(fcfg, mode, n, True)
            deltas.append(jnp.zeros((n, 2, N, d)))
            refreshes.append(jnp.asarray([[True] * n, [False] * n]))
    args = [fparams, tuple(xs), tuple(metas), tuple(keys)]
    if cache_split is not None:
        args += [tuple(deltas), tuple(refreshes)]
    out_off = off(*args)
    out_on = on(*args)
    if cache_split is None:
        xs_off, (xs_on, tap) = out_off, out_on
    else:
        (xs_off, nd_off), (xs_on, nd_on, tap) = out_off, out_on
        for a, b in zip(nd_off, nd_on):
            assert np.array_equal(np.asarray(a), np.asarray(b))
        assert len(tap["drift"]) == len(layout.groups)
    for a, b in zip(xs_off, xs_on):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    # tap contract: [k, n_g] per group + the layout's block ledger
    for g, (_m, n) in enumerate(layout.groups):
        assert tap["eps_norm"][g].shape == (2, n)
    active, total = (int(v) for v in np.asarray(tap["attn_blocks"]))
    assert 0 < active <= total


def test_drift_tap_matches_eager_recomputation(flexi):
    fparams, fcfg, sched = flexi
    B = 2
    g = GuidanceConfig(scale=1.5, mode_cond=0, mode_uncond=0)
    cond = jnp.asarray([1, 2], jnp.int32)
    null = jnp.full((B,), fcfg.dit.num_classes, jnp.int32)
    eps_fn_c = cache_apply.make_cached_eps_fn(
        fparams, fcfg, cond, null, g, None, None, 1, attn_backend="dense")
    ts = sch.respaced_timesteps(100, T)
    refresh = jnp.asarray([i % 2 == 0 for i in range(len(ts))])
    x0 = jax.random.normal(jax.random.PRNGKey(3),
                           (B,) + fcfg.dit.latent_shape)
    delta0 = jnp.zeros(cache_apply.delta_shape(fcfg, 0, B, True))
    key = jax.random.PRNGKey(4)
    _x, tap = cache_apply.cached_ddim_phase(
        eps_fn_c, sched, x0, ts, refresh, key, delta0, taps=True)
    tap_drift = np.asarray(tap["drift"])                     # [T, 2B]

    ts_prev = np.concatenate([ts[1:], [-1]])
    x, delta, eager = x0, delta0, []
    for i, (t, tp) in enumerate(zip(ts, ts_prev)):
        tb = jnp.full((B,), int(t), jnp.int32)
        tpb = jnp.full((B,), int(tp), jnp.int32)
        eps, _lv, nd = eps_fn_c(x, tb, delta, refresh[i])
        d = np.asarray(nd - delta)
        eager.append(np.sqrt(np.mean(np.square(d),
                                     axis=tuple(range(1, d.ndim)))))
        x = sch.ddim_step(sched, x, eps, tb, tpb, 0.0, key)
        delta = nd
    eager = np.stack(eager)
    mask = np.asarray(refresh)
    assert float(eager[mask].mean()) > 0        # drift is a real signal
    np.testing.assert_allclose(tap_drift, eager, atol=1e-5)
    # skip steps replay exactly: the tap is exactly zero there
    assert np.max(np.abs(tap_drift[~mask])) == 0.0


def test_pipeline_sample_taps(pipe, flexi):
    _f, fcfg, _s = flexi
    plan = SamplingPlan(T=T, guidance_scale=1.5,
                        cache=CacheSpec(policy="interval", interval=2,
                                        split=1))
    key = jax.random.PRNGKey(5)
    res_off = pipe.sample(plan, 2, key)
    res_on = pipe.sample(plan, 2, key, taps=True)
    assert np.array_equal(np.asarray(res_off.x0), np.asarray(res_on.x0))
    phases = res_on.trace["taps"]
    assert len(phases) >= 1
    total = sum(p["drift"].shape[0] for p in phases)
    assert total == T
    with pytest.raises(ValueError, match="no cache"):
        pipe.sample(SamplingPlan(T=T, guidance_scale=1.5), 2, key,
                    taps=True)


# ---------------------------------------------------------------------------
# Engine integration


def _make_engine(pipe, telemetry=None, clock=None):
    plans = {0.6: SamplingPlan(T=T, budget=0.5, guidance_scale=1.5),
             1.0: SamplingPlan(T=T, budget=1.0, guidance_scale=1.5)}
    return ServingEngine(pipe, plans, policy="fifo", steps_per_dispatch=2,
                         cache=CacheSpec(policy="interval", interval=2,
                                         split=1),
                         clock=clock, telemetry=telemetry)


def _serve(engine, n=4):
    for i in range(n):
        engine.submit(cond=i % 10, budget=0.6 if i % 2 else 1.0)
    return {r.request.id: np.asarray(r.x0) for r in engine.run()}


def test_engine_telemetry_zero_recompiles_and_bit_identity(pipe):
    tel = Telemetry(taps=True)
    eng_on = _make_engine(pipe, telemetry=tel, clock=FakeClock())
    served_on = _serve(eng_on)
    warm = eng_on.cache_stats()["compiled"]
    # replay the same budget mix: everything warm, taps included
    again = _serve(eng_on)
    assert eng_on.cache_stats()["compiled"] == warm
    assert set(again) != set(served_on)          # fresh request ids

    eng_off = _make_engine(pipe, clock=FakeClock())
    served_off = _serve(eng_off)
    for rid, x_on in served_on.items():
        assert np.array_equal(x_on, served_off[rid])

    agg = tel.taps.aggregate()
    assert agg["request_steps"] > 0
    assert agg["drift"]["mean"] >= 0 and "eps_norm" in agg
    assert agg["attn_blocks"]["total"] > 0


def test_engine_spans_cover_lifecycle(pipe, tmp_path):
    tel = Telemetry(taps=True)
    eng = _make_engine(pipe, telemetry=tel, clock=FakeClock())
    _serve(eng, n=3)
    names = {e.name for e in tel.recorder.events}
    for expected in ("admit", "plan", "pack", "dispatch", "materialize"):
        assert expected in names, f"missing span {expected!r}"
    # one lifecycle row per request on the requests track
    rows = [e for e in tel.recorder.events if e.pid == REQUEST_PID]
    assert len(rows) == 3
    assert {e.tid for e in rows} == {0, 1, 2}
    assert all(e.args["budget_served"] >= 0.6 for e in rows)
    # cold dispatches surfaced as compile events (fresh pipe had to build)
    assert any(e.name == "compile" for e in tel.recorder.events)
    path = tmp_path / "engine_trace.json"
    tel.recorder.dump(str(path))
    assert json.loads(path.read_text())["traceEvents"]


def test_engine_without_telemetry_records_nothing(pipe):
    eng = _make_engine(pipe, clock=FakeClock())
    _serve(eng, n=2)
    assert eng.telemetry is None


# ---------------------------------------------------------------------------
# Analysis: lint rules + jaxpr audit unit


def _lint(src: str, path="src/repro/telemetry/taps.py"):
    from repro.analysis.rules_telemetry import TelemetryRule
    return TelemetryRule().check(path, ast.parse(src), src)


def test_rules_telemetry_flags_host_callback():
    bad = "import jax\ndef tap(x):\n    jax.debug.print('{}', x)\n"
    fs = _lint(bad)
    assert [f.rule for f in fs] == ["telemetry-host-callback"]
    fs = _lint("from jax import pure_callback\n"
               "def t(x):\n    return pure_callback(f, s, x)\n")
    assert [f.rule for f in fs] == ["telemetry-host-callback"]


def test_rules_telemetry_flags_host_sync_outside_sink():
    bad = ("import numpy as np\n"
           "class TapAggregator:\n"
           "    def add(self, s):\n"
           "        self.v = np.asarray(s.eps)\n")
    fs = _lint(bad)
    assert [f.rule for f in fs] == ["telemetry-tap-host-sync"]


def test_rules_telemetry_allows_sink_and_other_files():
    ok = ("import numpy as np\n"
          "class TapAggregator:\n"
          "    def aggregate(self):\n"
          "        return float(np.asarray(self.v).mean())\n")
    assert _lint(ok) == []
    # outside telemetry/ the rule is silent
    assert _lint("import jax\njax.debug.print('x')\n",
                 path="src/repro/pipeline/packed.py") == []


def test_repo_telemetry_source_is_clean():
    from pathlib import Path

    from repro import telemetry
    from repro.analysis.rules_telemetry import TelemetryRule
    rule = TelemetryRule()
    pkg = Path(telemetry.__file__).parent
    for py in sorted(pkg.glob("*.py")):
        rel = f"src/repro/telemetry/{py.name}"
        text = py.read_text()
        assert rule.check(rel, ast.parse(text), text) == [], rel


def test_jaxpr_audit_tapped_step_passes():
    from repro.analysis.jaxpr_audit import audit_tapped_step
    rep = audit_tapped_step()
    assert rep.findings == []
    assert set(rep.fingerprints) == {"packed_step_tapped",
                                     "packed_cached_step_tapped"}
