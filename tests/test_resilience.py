"""Resilience tests (DESIGN.md §resilience): deterministic fault
injection, NaN/Inf quarantine with weak→powerful escalation, cache-slot
integrity, the write-ahead request journal, deadline expiry, watchdog
flight-recorder behaviour under pressure, and the chaos harness at
tier-1 scale.

The non-negotiables proven here:

* a **disarmed** engine (no fault plan) is byte-identical to the
  pre-resilience engine — the harness must be free when off;
* a quarantined (poisoned) request recovers to the exact clean
  powerful-path sample — the fault leaves no numerical trace;
* corruption of a resident cache slot is detected by checksum and
  repaired by forced refresh;
* the journal replays a crashed fleet's unfinished set exactly-once;
* stale/duplicate heartbeats never move liveness backwards.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scheduler import FlexiSchedule
from repro.diffusion import schedule as sch
from repro.pipeline import FlexiPipeline, SamplingPlan
from repro.resilience.faults import (ALLOC_FAIL, CORRUPT_SLOT, CRASH,
                                     HANG, HEARTBEAT_DELAY, PARTITION,
                                     POISON, SLOWDOWN, UNHANG, FaultEvent,
                                     FaultInjector, FaultPlan)
from repro.resilience.journal import RequestJournal

pytestmark = pytest.mark.tier1

T = 6


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


@pytest.fixture(scope="module")
def flexi(tiny_dit_cfg, trained_like_dit):
    from repro.core import flexify
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    sched = sch.linear_schedule(100)
    return fparams, fcfg, sched


@pytest.fixture(scope="module")
def pipe(flexi):
    fparams, fcfg, sched = flexi
    return FlexiPipeline(fparams, fcfg, sched)


def make_plans():
    return {0.6: SamplingPlan(T=T, budget=FlexiSchedule.weak_first(T, 3),
                              solver="ddim", guidance_scale=1.5),
            1.0: SamplingPlan(T=T, budget=1.0, solver="ddim",
                              guidance_scale=1.5)}


def make_engine(pipe, **kw):
    from repro.serving.scheduler import ServingEngine
    kw.setdefault("max_tokens_per_step", 256)
    kw.setdefault("steps_per_dispatch", 2)
    return ServingEngine(pipe, make_plans(), **kw)


# ---------------------------------------------------------------------------
# FaultInjector (host-pure units)


def test_fault_plan_validates_kind():
    with pytest.raises(ValueError):
        FaultEvent(at=0.0, kind="meteor")


def test_injector_due_order_and_exhaustion():
    p = FaultPlan()
    p.add(0.3, CRASH, replica=1)
    p.add(0.1, HANG, replica=0)
    p.add(0.2, UNHANG, replica=0)
    inj = FaultInjector(p)
    assert not inj.exhausted()
    assert [e.kind for e in inj.due(0.05)] == []
    assert [e.kind for e in inj.due(0.25)] == [HANG, UNHANG]
    assert not inj.exhausted()
    assert [e.kind for e in inj.due(1.0)] == [CRASH]
    assert inj.exhausted()
    assert inj.due(2.0) == []


def test_injector_defer_retries_event():
    p = FaultPlan()
    p.add(0.1, POISON, rid=5)
    inj = FaultInjector(p)
    (ev,) = inj.due(0.2)
    inj.defer(ev)                      # target not actionable yet
    assert not inj.exhausted()
    assert [e.rid for e in inj.due(0.2)] == [5]
    assert inj.exhausted()


def test_injector_slowdown_window_expires():
    inj = FaultInjector(FaultPlan())
    inj.slow(0, until=1.0, factor=3.0)
    assert inj.slowdown_factor(0, 0.5) == 3.0
    assert inj.slowdown_factor(0, 1.0) == 1.0    # window closed
    assert inj.slowdown_factor(1, 0.5) == 1.0    # other replica untouched


def test_injector_beat_delay_keeps_original_stamp():
    inj = FaultInjector(FaultPlan())
    inj.delay_beats(0, until=1.0, delay=0.5)
    assert inj.route_beat(0, 0.2) is None        # held, not dropped
    due = inj.due_beats(0.7)
    assert due == [(0, 0.2)]                     # original send stamp
    assert inj.route_beat(0, 2.0) == 2.0         # window over: direct


def test_injector_partition_drops_beats():
    inj = FaultInjector(FaultPlan())
    inj.partition(0, until=1.0)
    assert inj.route_beat(0, 0.5) is None
    assert inj.due_beats(5.0) == []              # dropped, never delivered
    assert inj.counters["beats_dropped"] == 1
    assert inj.route_beat(0, 1.5) == 1.5


def test_injector_poison_take_once_and_target_memory():
    inj = FaultInjector(FaultPlan())
    inj.add_poison(0, 7)
    assert inj.is_poison_target(0, 7)
    assert inj.take_poison(0, 7)
    assert not inj.take_poison(0, 7)             # consumed
    assert inj.is_poison_target(0, 7)            # but remembered
    assert not inj.is_poison_target(1, 7)


def test_injector_alloc_failures_count_down():
    inj = FaultInjector(FaultPlan())
    inj.add_alloc_failures(2, 2)
    rf = inj.for_replica(2)
    assert rf.take_alloc_failure()
    assert rf.take_alloc_failure()
    assert not rf.take_alloc_failure()
    assert inj.counters["alloc_failed"] == 2


# ---------------------------------------------------------------------------
# Write-ahead journal


def test_journal_roundtrip_and_unfinished(tmp_path):
    path = tmp_path / "journal.jsonl"
    j = RequestJournal(str(path))
    j.admit(0, cond=3, budget=1.0, deadline=math.inf, time=0.0)
    j.admit(1, cond=4, budget=0.6, deadline=math.inf, time=0.1)
    j.admit(2, cond=5, budget=0.6, deadline=1.0, time=0.2)
    j.dispatch(0, replica=0, time=0.3)
    j.finish(0, replica=0, time=0.5)
    j.expire(2, time=1.2)
    j.close()

    loaded = RequestJournal.load(str(path))
    un = loaded.unfinished()
    assert [int(r["rid"]) for r in un] == [1]    # finished/expired gone
    assert un[0]["cond"] == 4 and un[0]["budget"] == 0.6
    s = loaded.summary()
    assert s["admit"] == 3 and s["finish"] == 1 and s["unfinished"] == 1


def test_journal_unfinished_dedupes_readmissions(tmp_path):
    path = tmp_path / "j.jsonl"
    j = RequestJournal(str(path))
    j.admit(0, cond=1, budget=1.0, deadline=math.inf, time=0.0)
    j.dispatch(0, replica=0, time=0.1)
    j.escalate(0, time=0.2, retries=1)           # re-admitted, same rid
    j.dispatch(0, replica=1, time=0.3)
    j.close()
    un = RequestJournal.load(str(path)).unfinished()
    assert [int(r["rid"]) for r in un] == [0]    # once, despite re-dispatch


# ---------------------------------------------------------------------------
# Heartbeat monotonicity (stale / duplicate / out-of-order beats)


def test_heartbeat_monitor_stale_beat_never_moves_backwards():
    from repro.runtime.fault_tolerance import HeartbeatMonitor
    clk = FakeClock()
    mon = HeartbeatMonitor(1, timeout_s=1.0, clock=clk)
    mon.heartbeat(0, at=5.0)
    mon.heartbeat(0, at=2.0)                     # stale, out of order
    mon.heartbeat(0, at=5.0)                     # duplicate
    assert mon.workers[0].last_heartbeat == 5.0
    clk.t = 5.9
    assert mon.check() == []                     # still fresh
    clk.t = 6.1
    assert mon.check() == [0]


def test_membership_beat_ignores_dead_replica():
    from repro.fleet.membership import FleetMembership
    clk = FakeClock()
    m = FleetMembership(2, [0, 1], timeout_s=1.0, clock=clk)
    m.mark_dead(1)
    m.beat(1, at=10.0)                           # late beat from a corpse
    clk.t = 10.5
    assert m.state(1) == "dead"
    assert m.monitor.workers[1].alive is False


# ---------------------------------------------------------------------------
# Engine seams: disarmed transparency, quarantine, expiry, integrity


def test_disarmed_engine_is_byte_identical(pipe):
    """quarantine+integrity machinery enabled but NO fault plan: output
    arrays must be byte-identical to the stock engine's."""
    from repro.cache.policy import CacheSpec
    key = jax.random.PRNGKey(11)
    outs = []
    for kw in ({}, {"quarantine": True, "cache_integrity": True}):
        eng = make_engine(pipe, cache=CacheSpec(policy="interval",
                                                interval=1, split=1), **kw)
        eng.submit(3, 1.0, key=key)
        (res,) = eng.run()
        outs.append(np.asarray(res.x0))
    assert np.array_equal(outs[0], outs[1])


def test_engine_quarantine_self_heal_matches_powerful_path(pipe):
    """A poisoned request self-heals: re-enqueued at the most powerful
    level with the same key, its recovered sample is exactly the clean
    powerful-path sample."""
    inj = FaultInjector(FaultPlan())
    inj.add_poison(0, 0)                         # first engine rid
    eng = make_engine(pipe, faults=inj.for_replica(0))
    key = jax.random.PRNGKey(3)
    rid = eng.submit(4, 0.6, key=key)
    results = eng.run()
    assert eng.metrics.total_poisoned == 1
    assert eng.metrics.total_quarantined == 1
    (res,) = [r for r in results if r.request.id == rid]
    assert res.budget_served == 1.0              # escalated weak→powerful
    assert np.isfinite(np.asarray(res.x0)).all()

    clean = make_engine(pipe)
    clean.submit(4, 1.0, key=key)
    (ref,) = clean.run()
    assert np.array_equal(np.asarray(res.x0), np.asarray(ref.x0))
    assert "quarantined" in eng.metrics.summary()


def test_engine_quarantine_parks_after_retry_budget(pipe):
    """Unbounded self-heal loops are forbidden: past max_retries the
    request parks in ``quarantined`` for the caller."""
    inj = FaultInjector(FaultPlan())
    eng = make_engine(pipe, faults=inj.for_replica(0), max_retries=0)
    rid = eng.submit(2, 0.6)
    inj.add_poison(0, rid)
    results = eng.run()
    assert results == []
    assert [r.id for r in eng.take_quarantined()] == [rid]
    assert eng.take_quarantined() == []          # drained


def test_engine_finite_tap_detects_midflight(pipe):
    """With taps armed, the in-graph finite tap flags the poisoned
    request (as data, at the existing sync) before it retires."""
    from repro.telemetry import Telemetry
    inj = FaultInjector(FaultPlan())
    eng = make_engine(pipe, faults=inj.for_replica(0),
                      telemetry=Telemetry(taps=True))
    key = jax.random.PRNGKey(9)
    rid = eng.submit(1, 0.6, key=key)
    inj.add_poison(0, rid)
    results = eng.run()
    assert eng.metrics.total_quarantined == 1
    (res,) = [r for r in results if r.request.id == rid]
    assert np.isfinite(np.asarray(res.x0)).all()


def test_engine_deadline_expiry_is_terminal(pipe):
    clk = FakeClock(1.0)
    eng = make_engine(pipe, expire_queued=True, clock=clk)
    rid_late = eng.submit(3, 0.6, deadline=0.5)  # already past
    rid_ok = eng.submit(4, 0.6, deadline=math.inf)
    results = eng.run()
    assert [r.request.id for r in results] == [rid_ok]
    assert [r.id for r in eng.take_expired()] == [rid_late]
    assert eng.metrics.total_expired == 1
    assert eng.metrics.summary()["expired"] == 1.0


def test_engine_default_keeps_serving_late_requests(pipe):
    """expire_queued is opt-in: by default a late request still gets
    served (best-effort queues)."""
    clk = FakeClock(1.0)
    eng = make_engine(pipe, clock=clk)
    rid = eng.submit(3, 0.6, deadline=0.5)
    results = eng.run()
    assert [r.request.id for r in results] == [rid]
    assert eng.metrics.total_expired == 0


def test_store_integrity_detects_corruption(pipe):
    """CRC catches out-of-band slot corruption; the engine forces a
    refresh and, under interval=1 (never reads the cache), the final
    sample is still bit-identical to the uncached reference."""
    from repro.cache.policy import CacheSpec
    eng = make_engine(pipe, cache=CacheSpec(policy="interval",
                                            interval=1, split=1),
                      cache_integrity=True)
    key = jax.random.PRNGKey(5)
    eng.submit(3, 0.6, key=key)
    eng.step()                                   # first dispatch: scatter
    (mode, slot) = eng.store.active_slots()[0]
    eng.store.corrupt_slot(mode, slot)
    results = eng.run()
    assert eng.store.corruptions == 1
    assert eng.store.integrity_failures >= 1
    assert eng.metrics.total_integrity_refreshes >= 1
    clean = make_engine(pipe, cache=CacheSpec(policy="interval",
                                              interval=1, split=1))
    clean.submit(3, 0.6, key=key)
    (ref,) = clean.run()
    assert np.array_equal(np.asarray(results[0].x0), np.asarray(ref.x0))


def test_store_verify_passes_clean_slots(pipe):
    from repro.cache.policy import CacheSpec
    eng = make_engine(pipe, cache=CacheSpec(policy="interval",
                                            interval=1, split=1),
                      cache_integrity=True)
    eng.submit(3, 0.6)
    eng.run()
    assert eng.store.integrity_failures == 0
    assert eng.metrics.total_integrity_refreshes == 0


def test_engine_transient_alloc_failure_recovers(pipe):
    """An injected allocation failure runs the request slotless for one
    dispatch (exact recompute) and re-allocates next time; the sample is
    unchanged."""
    from repro.cache.policy import CacheSpec
    inj = FaultInjector(FaultPlan())
    inj.add_alloc_failures(0, 1)
    eng = make_engine(pipe, faults=inj.for_replica(0),
                      cache=CacheSpec(policy="interval", interval=1,
                                      split=1))
    key = jax.random.PRNGKey(7)
    eng.submit(2, 0.6, key=key)
    (res,) = eng.run()
    assert eng.metrics.total_alloc_failures == 1
    clean = make_engine(pipe, cache=CacheSpec(policy="interval", interval=1,
                                              split=1))
    clean.submit(2, 0.6, key=key)
    (ref,) = clean.run()
    assert np.array_equal(np.asarray(res.x0), np.asarray(ref.x0))


# ---------------------------------------------------------------------------
# Watchdog under pressure (flight recorder)


def _wd(tmp_path, **cfg_kw):
    from repro.telemetry.trace import SpanRecorder
    from repro.telemetry.watchdog import Watchdog, WatchdogConfig
    rec = SpanRecorder(max_events=8)             # tiny ring: forces wrap
    wd = Watchdog(WatchdogConfig(**cfg_kw), recorder=rec,
                  postmortem_dir=str(tmp_path))
    return wd, rec


def test_watchdog_nonfinite_cooldown_refires(tmp_path):
    """Quarantine growth suppressed by the cooldown re-fires once the
    cooldown expires (the seen-mark only advances on an actual fire)."""
    wd, _ = _wd(tmp_path, cooldown_steps=5)
    obs = dict(queued=0, inflight=1, compiled=1)
    assert [a.kind for a in wd.observe_step(now=0.0, nonfinite=1, **obs)] \
        == ["nonfinite"]
    # growth during cooldown: suppressed, seen-mark must NOT advance
    assert wd.observe_step(now=0.1, nonfinite=2, **obs) == []
    for i in range(3):
        wd.observe_step(now=0.2 + i * 0.1, nonfinite=2, **obs)
    # cooldown over: the suppressed growth fires now
    fired = wd.observe_step(now=0.6, nonfinite=2, **obs)
    assert [a.kind for a in fired] == ["nonfinite"]
    assert wd._nonfinite_seen == 2
    # no further growth: quiet
    assert wd.observe_step(now=0.7, nonfinite=2, **obs) == []


def test_watchdog_dump_under_full_span_ring(tmp_path):
    """dump() with a saturated SpanRecorder ring stays bounded, keeps
    only the ring's tail, and never raises."""
    wd, rec = _wd(tmp_path)
    for i in range(100):                         # 12x the ring size
        rec.instant(f"ev{i}")
    wd.observe_step(now=0.0, queued=0, inflight=0, compiled=1,
                    nonfinite=1)
    path = wd.dump(reason="test", engine_snapshot={"queued": 0})
    assert path is not None
    bundle = json.loads(open(path).read())
    assert bundle["reason"] == "test"
    assert len(bundle["spans"]) <= 8             # ring cap, not 100
    assert [a["kind"] for a in bundle["alerts"]] == ["nonfinite"]


def test_watchdog_dump_cap(tmp_path):
    wd, _ = _wd(tmp_path, max_dumps=2, cooldown_steps=0)
    obs = dict(queued=0, inflight=0, compiled=1)
    for i in range(4):
        wd.observe_step(now=float(i), nonfinite=i + 1, **obs)
        wd.dump(reason=f"r{i}")
    assert len(wd.dumps_written) == 2
    assert not wd.should_dump()


# ---------------------------------------------------------------------------
# Router escalation (host-pure units)


def _register(router, deadline=math.inf):
    return router.register(3, 0.6, deadline, key=object(), now=0.0)


def test_router_escalate_backoff_doubles_and_holds_pending():
    from repro.fleet.router import Router, ReplicaView
    r = Router()
    req = _register(r)
    views = [ReplicaView(rid=0, admitting=True, backlog_seconds=0.0,
                         prices={1.0: 1.0})]
    r.place(req, views, 0.6)
    assert r.escalate(req, now=1.0, level=1.0, max_retries=2,
                      backoff_base=0.1)
    assert req.budget == 1.0 and req.escalated and req.retries == 1
    assert req.not_before == pytest.approx(1.1)
    assert r.pending(now=1.05) == []             # held back
    assert [x.rid for x in r.pending(now=1.2)] == [req.rid]
    r.place(req, views, 1.0)
    assert r.escalate(req, now=2.0, level=1.0, max_retries=2,
                      backoff_base=0.1)
    assert req.not_before == pytest.approx(2.2)  # doubled


def test_router_escalate_caps_backoff_at_deadline_slack():
    from repro.fleet.router import Router, ReplicaView
    r = Router()
    req = _register(r, deadline=2.0)
    views = [ReplicaView(rid=0, admitting=True, backlog_seconds=0.0,
                         prices={1.0: 1.0})]
    r.place(req, views, 0.6)
    r.escalate(req, now=1.0, level=1.0, backoff_base=10.0)
    assert req.not_before == pytest.approx(1.25)  # 25% of 1s slack


def test_router_escalate_overflow_counts_but_never_drops():
    from repro.fleet.router import Router, ReplicaView
    r = Router()
    req = _register(r)
    views = [ReplicaView(rid=0, admitting=True, backlog_seconds=0.0,
                         prices={1.0: 1.0})]
    for i in range(3):
        r.place(req, views, 1.0)
        ok = r.escalate(req, now=float(i), level=1.0, max_retries=2,
                        backoff_base=0.0)
        assert ok == (i < 2)
    assert r.escalation_overflows == 1
    assert req.rid in [x.rid for x in r.pending(now=10.0)]  # never lost


def test_router_mark_done_removes_readmitted_from_pending():
    """A hedged twin can win while the original sits re-admitted in
    backoff; mark_done must pull it from the pending pool."""
    from repro.fleet.router import Router, ReplicaView
    r = Router()
    req = _register(r)
    views = [ReplicaView(rid=0, admitting=True, backlog_seconds=0.0,
                         prices={1.0: 1.0})]
    r.place(req, views, 0.6)
    r.escalate(req, now=0.0, level=1.0, backoff_base=100.0)
    assert r.n_pending == 1
    assert r.mark_done(req, 1.0, served_by=1)
    assert r.n_pending == 0
    assert r.unfinished() == []


# ---------------------------------------------------------------------------
# Chaos harness + journal replay (tier-1 scale)


def chaos_engine_kwargs():
    from repro.cache.policy import CacheSpec
    return {"max_tokens_per_step": 256, "steps_per_dispatch": 2,
            "cache": CacheSpec(policy="interval", interval=1, split=1)}


def test_chaos_small_fleet_loses_nothing(pipe):
    from repro.resilience import chaos as chaos_mod
    plan = FaultPlan()
    # poison early; crash only after the quarantine has had time to
    # retire + escalate (a crash first would hand the poisoned request
    # back with fresh state and no escalation would ever be needed)
    plan.add(0.001, POISON, rid=1)
    plan.add(0.006, CRASH, replica=1)
    plan.add(0.004, SLOWDOWN, replica=0, duration=0.01, factor=2.0)
    res = chaos_mod.run_chaos(pipe, make_plans(), n_replicas=2,
                              n_requests=8, fault_plan=plan,
                              engine_kwargs=chaos_engine_kwargs(), seed=0)
    assert res["requests_lost"] == 0
    assert res["nonfinite_outputs"] == 0
    assert res["faults_exhausted"]
    assert res["deaths"] == 1
    assert len(res["escalated_rids"]) >= 1
    v = chaos_mod.verify_escalations(pipe, make_plans(), res,
                                     engine_kwargs=chaos_engine_kwargs())
    assert v["escalated_bitwise"] == 1
    assert v["moved_max_err"] <= 1e-4


def test_journal_replay_exactly_once(pipe, tmp_path):
    from repro.resilience import chaos as chaos_mod
    rep = chaos_mod.run_replay(pipe, make_plans(),
                               str(tmp_path / "j.jsonl"),
                               n_replicas=2, n_requests=6,
                               crash_after_finished=1,
                               engine_kwargs=chaos_engine_kwargs())
    assert rep["missing"] == 0
    assert rep["duplicates"] == 0
    assert rep["replayed"] >= 1
    assert rep["max_readmit_err"] <= 1e-4


# ---------------------------------------------------------------------------
# Lint rules


def test_resilience_host_pure_rule_flags_device_imports(tmp_path):
    from repro.analysis.engine import lint_paths
    bad = tmp_path / "resilience" / "faults.py"
    bad.parent.mkdir()
    bad.write_text(
        "import numpy as np\n"
        "def due(now):\n"
        "    return float(np.min(now).item())\n")
    findings = lint_paths([bad])
    rules = {f.rule for f in findings}
    assert rules == {"resilience-host-pure"}
    assert len(findings) >= 2
    assert all(f.severity == "error" for f in findings)


def test_resilience_armed_guard_rule(tmp_path):
    from repro.analysis.engine import lint_paths
    f = tmp_path / "serving" / "scheduler.py"
    f.parent.mkdir()
    f.write_text(
        "class E:\n"
        "    def bad(self):\n"
        "        return self._faults.take_poison(1)\n"
        "    def guarded(self):\n"
        "        if self._faults is not None:\n"
        "            return self._faults.take_poison(1)\n"
        "    def short_circuit(self):\n"
        "        if self._faults is not None and "
        "self._faults.take_poison(1):\n"
        "            return 1\n"
        "    def early_return(self):\n"
        "        if self._faults is None:\n"
        "            return None\n"
        "        return self._faults.take_poison(1)\n")
    findings = [x for x in lint_paths([f])
                if x.rule == "resilience-armed-guard"]
    assert [x.symbol for x in findings] == ["bad"]


def test_resilience_modules_pass_their_lints():
    from pathlib import Path
    from repro.analysis.engine import lint_paths
    src = Path(__file__).resolve().parents[1] / "src/repro"
    findings = [f for f in lint_paths([src / "resilience",
                                       src / "serving" / "scheduler.py",
                                       src / "fleet"])
                if f.rule.startswith("resilience-")]
    assert findings == []
