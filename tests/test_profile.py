"""Compiled-cost profiling, attribution, watchdog (DESIGN.md §profiling).

The load-bearing asserts: per-request attributed wall/FLOPs/bytes sum
EXACTLY (integer equality) to every dispatch's totals across mixed
budgets, cache refresh patterns, and join/leave mid-flight; the packed
cache-key mirror in telemetry/profile.py matches FlexiPipeline's real
runner cache; harvesting XLA cost analysis adds zero jit compiles and
profiling leaves latents and jaxpr fingerprints bit-identical; the
BudgetController reprices from measured calibration; the watchdog's
detectors fire (and cool down) on the right signals and the flight
recorder writes a complete bundle.
"""
import ast
import json
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flexify
from repro.diffusion import schedule as sch
from repro.pipeline import FlexiPipeline, PackLayout, SamplingPlan
from repro.pipeline.plan import CacheSpec
from repro.serving import ServingEngine
from repro.serving.controller import (BudgetController, plan_mode_flops,
                                      request_cost_flops)
from repro.telemetry import Telemetry
from repro.telemetry import export as tel_export
from repro.telemetry.attribution import (AttributionLedger, ServedCost,
                                         exact_shares)
from repro.telemetry.profile import (CompiledCostRegistry, packed_arg_specs,
                                     packed_key)
from repro.telemetry.trace import SpanRecorder
from repro.telemetry.watchdog import (ALERT_DRIFT, ALERT_P99, ALERT_QUEUE,
                                      ALERT_RECOMPILE, Watchdog,
                                      WatchdogConfig)

pytestmark = pytest.mark.tier1

T = 6


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        self.t += 0.001
        return self.t


@pytest.fixture(scope="module")
def flexi(tiny_dit_cfg, trained_like_dit):
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    return fparams, fcfg, sch.linear_schedule(100)


@pytest.fixture(scope="module")
def pipe(flexi):
    fparams, fcfg, sched = flexi
    return FlexiPipeline(fparams, fcfg, sched)


def _plans():
    return {0.6: SamplingPlan(T=T, budget=0.5, guidance_scale=1.5),
            1.0: SamplingPlan(T=T, budget=1.0, guidance_scale=1.5)}


def _make_engine(pipe, telemetry=None, controller=None, policy="fifo"):
    return ServingEngine(pipe, _plans(), policy=policy,
                         steps_per_dispatch=2,
                         cache=CacheSpec(policy="interval", interval=2,
                                         split=1),
                         clock=FakeClock(), telemetry=telemetry,
                         controller=controller)


def _serve(engine, n=4):
    for i in range(n):
        engine.submit(cond=i % 10, budget=0.6 if i % 2 else 1.0)
    return {r.request.id: r for r in engine.run()}


# ---------------------------------------------------------------------------
# exact_shares: the conservation primitive


def test_exact_shares_sum_is_exact():
    rng = np.random.default_rng(0)
    for _ in range(50):
        n = int(rng.integers(1, 9))
        total = int(rng.integers(0, 10**12))
        weights = rng.random(n) * rng.choice([1e-6, 1.0, 1e9])
        shares = exact_shares(total, list(weights))
        assert sum(shares) == total
        assert all(s >= 0 for s in shares)


def test_exact_shares_degenerate_weights_split_equally():
    assert exact_shares(10, [0.0, 0.0]) == [5, 5]
    assert exact_shares(7, [0.0, 0.0, 0.0]) == [3, 2, 2]
    # negative weights clamp to zero, never to negative shares
    assert exact_shares(9, [-5.0, 3.0]) == [0, 9]
    assert exact_shares(0, [1.0, 2.0]) == [0, 0]
    assert exact_shares(5, []) == []


def test_exact_shares_proportional_when_divisible():
    assert exact_shares(4, [1.0, 3.0]) == [1, 3]
    assert exact_shares(100, [1.0, 1.0, 2.0]) == [25, 25, 50]


# ---------------------------------------------------------------------------
# AttributionLedger


def test_ledger_conservation_and_finalize():
    led = AttributionLedger()
    led.attribute_dispatch(time=0.0, label="d0", request_ids=[1, 2],
                           weights=[1.0, 2.0], wall_ns=1_000_001,
                           flops=999_999_999_999, bytes_=7)
    led.attribute_dispatch(time=1.0, label="d1", request_ids=[2, 3],
                           weights=[5.0, 1e-9], wall_ns=13, flops=17)
    assert all(v == 0 for v in led.conservation().values())
    assert all(d.conserved for d in led.dispatches)
    c2 = led.finalize(2, queue_wait_s=0.5, budget="0.6")
    assert c2.dispatches == 2 and c2.budget == "0.6"
    assert c2.queue_wait_s == 0.5
    # conservation holds across the open/finalized split
    assert all(v == 0 for v in led.conservation().values())
    led.finalize(1)
    led.finalize(3)
    total = sum(c.wall_ns for c in led.finalized.values())
    assert total == led.total_wall_ns == 1_000_001 + 13


def test_ledger_finalize_without_dispatch_is_zeros():
    led = AttributionLedger()
    c = led.finalize(42, queue_wait_s=1.0, budget="1.0")
    assert isinstance(c, ServedCost)
    assert (c.flops, c.bytes, c.wall_ns, c.dispatches) == (0, 0, 0, 0)
    # idempotent: a second finalize returns the same record
    assert led.finalize(42) is led.finalized[42]


# ---------------------------------------------------------------------------
# packed-key mirror + spec derivation + harvest


def test_packed_key_mirrors_runner_cache(pipe):
    layout = PackLayout(groups=((0, 1), (1, 1)), guided=True)
    kw = dict(solver="ddim", guidance_scale=1.5, clip_x0=0.0, k_steps=2,
              cache_split=1, attn_backend="auto", taps=False)
    pipe.packed_step(layout, **kw)
    mirror = packed_key(layout, **kw)
    assert mirror in pipe.runners(), \
        "telemetry/profile.py's packed_key drifted from " \
        "FlexiPipeline.packed_step's cache key"


def test_packed_arg_specs_lower_without_jit_compiles(pipe):
    engine = _make_engine(pipe)
    _serve(engine, n=2)
    before = pipe.cache_stats()["compiled"]
    n_packed = 0
    for key, fn in pipe.runners().items():
        if key[0] != "packed":
            continue
        n_packed += 1
        specs = packed_arg_specs(pipe.cfg, key, pipe.params)
        fn.lower(*specs)         # spec tree must match the real signature
    assert n_packed > 0
    assert pipe.cache_stats()["compiled"] == before


def test_registry_harvest_is_invisible_and_idempotent(pipe):
    tel = Telemetry(profile=True)
    engine = _make_engine(pipe, telemetry=tel)
    _serve(engine, n=3)
    before = pipe.cache_stats()["compiled"]
    hv = tel.profile.harvest(pipe)
    assert pipe.cache_stats()["compiled"] == before, \
        "AOT cost harvest touched the jit dispatch cache"
    assert hv["errors"] == 0 and hv["harvested"] > 0
    hv2 = tel.profile.harvest(pipe)          # already harvested: all noops
    assert hv2["harvested"] == 0 and hv2["errors"] == 0
    rep = tel.profile.reconcile()
    assert rep["n_errors"] == 0
    assert rep["n_records"] == hv["total"]
    assert 0 < rep["min_xla_over_analytic"]
    # engine fed per-dispatch walls under the same keys the harvest used
    packed_walls = [k for k in tel.profile.walls if k[0] == "packed"]
    assert packed_walls and all(k in tel.profile.records
                                for k in packed_walls)
    wall_rows = [r for r in rep["rows"] if "wall_ms_ewma" in r]
    assert wall_rows and all(r["wall_ms_ewma"] > 0 for r in wall_rows)


# ---------------------------------------------------------------------------
# Engine attribution: exact conservation across join/leave


def test_engine_attribution_conserves_with_join_leave(pipe):
    tel = Telemetry(profile=True)
    engine = _make_engine(pipe, telemetry=tel)
    for i in range(3):                       # first cohort, mixed budgets
        engine.submit(cond=i, budget=0.6 if i % 2 else 1.0)
    for _ in range(2):                       # advance partway...
        engine.step()
    engine.submit(cond=7, budget=1.0)        # ...then join mid-flight
    engine.submit(cond=8, budget=0.6)
    results = {r.request.id: r for r in engine.run()}
    assert len(results) == 5
    led = tel.attribution
    assert all(v == 0 for v in led.conservation().values()), \
        "attribution broke conservation"
    assert all(d.conserved for d in led.dispatches)
    assert len(led.finalized) == 5 and not led._open
    agg_wall = sum(c.wall_ns for c in led.finalized.values())
    agg_flops = sum(c.flops for c in led.finalized.values())
    assert agg_wall == led.total_wall_ns
    assert agg_flops == led.total_flops
    for rid, res in results.items():
        assert res.cost is not None
        assert res.cost.request_id == rid
        assert res.cost.dispatches > 0 and res.cost.flops > 0
        assert res.cost.budget == str(res.budget_served)
        assert res.cost.queue_wait_s >= 0
    # a full-budget request rides more denoise steps than a weak one at
    # the same ladder, so its attributed FLOPs must dominate
    full = [r.cost.flops for r in results.values() if r.budget_served == 1.0]
    weak = [r.cost.flops for r in results.values() if r.budget_served == 0.6]
    assert min(full) > max(weak)


def test_profiling_bit_identity_and_fingerprint(pipe):
    served_off = {i: np.asarray(r.x0)
                  for i, r in _serve(_make_engine(pipe)).items()}
    warm = pipe.cache_stats()["compiled"]
    tel = Telemetry(profile=True)
    tel.profile.harvest(pipe)                # harvest-then-serve ordering
    served_on = {i: np.asarray(r.x0)
                 for i, r in _serve(_make_engine(pipe, telemetry=tel)).items()}
    assert pipe.cache_stats()["compiled"] == warm, \
        "profiling replay recompiled a warm engine"
    for rid, x in served_off.items():
        assert np.array_equal(x, served_on[rid]), \
            "profiling changed the served latents"
    # jaxpr fingerprints: tracing a packed runner from its derived specs
    # yields the same jaxpr before and after a harvest
    from repro.analysis.jaxpr_audit import fingerprint
    key = next(k for k in pipe.runners() if k[0] == "packed")
    fn = pipe.runners()[key]
    specs = packed_arg_specs(pipe.cfg, key, pipe.params)
    fp1 = fingerprint(jax.make_jaxpr(fn)(*specs))
    tel2 = Telemetry(profile=True)
    tel2.profile.harvest(pipe)
    fp2 = fingerprint(jax.make_jaxpr(fn)(*specs))
    assert fp1 == fp2


# ---------------------------------------------------------------------------
# Controller: mode split + measured repricing


def test_plan_mode_flops_sums_to_request_cost(flexi):
    _p, fcfg, _s = flexi
    cache = CacheSpec(policy="interval", interval=2, split=1)
    for budget in (0.5, 1.0):
        for cs in (None, cache):
            plan = SamplingPlan(T=T, budget=budget, guidance_scale=1.5)
            split = plan_mode_flops(fcfg, plan, cache=cs,
                                    num_train_steps=100)
            total = request_cost_flops(fcfg, plan, cache=cs,
                                       num_train_steps=100)
            assert sum(split.values()) == pytest.approx(total)
    # the weak plan spends most steps in the cheap mode
    weak = plan_mode_flops(fcfg, SamplingPlan(T=T, budget=0.5,
                                              guidance_scale=1.5))
    assert len(weak) == 2 and min(weak) == 0


def test_controller_reprices_from_measured_calibration(flexi):
    _p, fcfg, _s = flexi
    ctrl = BudgetController(fcfg, _plans(), num_train_steps=100)
    assert ctrl.calibration is None
    assert ctrl.solve() == ctrl.solve_analytic()    # uncalibrated: legacy
    wpf = 1e-10                                     # measured wall/FLOP
    ctrl.observe_calibration(None, 1.0, wpf)
    cs = {b: ctrl.cost_seconds(b) for b in ctrl.levels}
    assert cs[1.0] > cs[0.6] > 0
    # seconds budget between the two measured costs; analytic capacity
    # believes a 4x faster device than measured
    mid = 0.5 * (cs[0.6] + cs[1.0])
    ctrl.observe_arrival(0.0)
    ctrl.observe_arrival(mid / ctrl.target_util)
    ctrl.observe_service(4.0 / wpf, 1.0)
    assert ctrl.solve_analytic() == 1.0             # analytic: sustain full
    assert ctrl.solve() == 0.6                      # measured: demote
    assert ctrl.assign(1.0) == 0.6


def test_controller_per_family_calibration_ewma(flexi):
    _p, fcfg, _s = flexi
    ctrl = BudgetController(fcfg, _plans(), alpha=0.5, num_train_steps=100)
    ctrl.observe_calibration(0, 1e9, 1.0)           # family 0: 1e-9 s/FLOP
    ctrl.observe_calibration(0, 1e9, 3.0)           # EWMA -> 2e-9
    ctrl.observe_calibration(None, 1e9, 10.0)       # mixed: global only
    cal = ctrl.calibration
    assert cal["per_family"] == {0: pytest.approx(2e-9)}
    assert cal["global"] == pytest.approx(0.5 * 2e-9 + 0.5 * 10e-9)
    # families never seen alone price at the global factor
    seen = {m for b in ctrl.levels for m in ctrl.mode_costs[b]}
    assert 1 in seen
    expect = sum(fl * (cal["per_family"][0] if m == 0 else cal["global"])
                 for m, fl in ctrl.mode_costs[1.0].items())
    assert ctrl.cost_seconds(1.0) == pytest.approx(expect)
    # bad observations are ignored, not poisonous
    ctrl.observe_calibration(0, 0.0, 1.0)
    ctrl.observe_calibration(0, 1e9, -1.0)
    assert ctrl.calibration == cal


# ---------------------------------------------------------------------------
# Watchdog detectors + flight recorder


def test_watchdog_recompile_detector_and_cooldown():
    wd = Watchdog(WatchdogConfig(warmup_steps=2, cooldown_steps=3))
    base = dict(queued=0, inflight=1, compiled=5)
    assert wd.observe_step(now=0.0, **base) == []
    assert wd.observe_step(now=1.0, **base) == []
    # a compile during warmup re-baselines silently
    fired = wd.observe_step(now=2.0, queued=0, inflight=1, compiled=6)
    assert [a.kind for a in fired] == [ALERT_RECOMPILE]
    # cooldown suppresses an immediate re-fire, baseline still advances
    assert wd.observe_step(now=3.0, queued=0, inflight=1, compiled=7) == []
    wd.observe_step(now=4.0, queued=0, inflight=1, compiled=7)
    wd.observe_step(now=5.0, queued=0, inflight=1, compiled=7)
    fired = wd.observe_step(now=6.0, queued=0, inflight=1, compiled=8)
    assert [a.kind for a in fired] == [ALERT_RECOMPILE]
    assert len(wd.alerts) == 2


def test_watchdog_queue_p99_drift_detectors():
    wd = Watchdog(WatchdogConfig(queue_limit=4, p99_slo_s=1.0,
                                 min_latencies=3, drift_limit=0.1,
                                 warmup_steps=1))
    fired = wd.observe_step(now=0.0, queued=9, inflight=2, compiled=1,
                            latencies=[2.0, 2.5, 3.0], drift_max=0.5)
    kinds = sorted(a.kind for a in fired)
    assert kinds == sorted([ALERT_QUEUE, ALERT_P99, ALERT_DRIFT])
    p99 = next(a for a in fired if a.kind == ALERT_P99)
    assert p99.value == pytest.approx(3.0) and p99.limit == 1.0
    # below every limit: silence
    wd2 = Watchdog(WatchdogConfig(queue_limit=4, p99_slo_s=10.0,
                                  min_latencies=3, drift_limit=0.1))
    assert wd2.observe_step(now=0.0, queued=1, inflight=1, compiled=1,
                            latencies=[0.1, 0.2, 0.3],
                            drift_max=0.01) == []
    # too few latencies: the p99 detector stays quiet
    wd3 = Watchdog(WatchdogConfig(p99_slo_s=0.01, min_latencies=8))
    assert wd3.observe_step(now=0.0, queued=0, inflight=0, compiled=0,
                            latencies=[5.0] * 3) == []


def test_watchdog_alerts_land_in_span_recorder():
    rec = SpanRecorder(clock=FakeClock())
    wd = Watchdog(WatchdogConfig(queue_limit=1), recorder=rec)
    wd.observe_step(now=0.5, queued=5, inflight=0, compiled=0)
    evs = rec.by_name(f"alert.{ALERT_QUEUE}")
    assert len(evs) == 1 and evs[0].ph == "i"
    assert evs[0].args["value"] == 5.0 and evs[0].args["limit"] == 1.0


def test_watchdog_dump_bundle_and_cap(tmp_path):
    rec = SpanRecorder(clock=FakeClock())
    rec.instant("mark")
    led = AttributionLedger()
    led.attribute_dispatch(time=0.0, label="d", request_ids=[0],
                           weights=[1.0], wall_ns=10, flops=20)
    reg = CompiledCostRegistry()
    wd = Watchdog(WatchdogConfig(queue_limit=1, max_dumps=2),
                  recorder=rec, postmortem_dir=str(tmp_path))
    assert not wd.should_dump()              # nothing fired yet
    wd.observe_step(now=0.0, queued=9, inflight=1, compiled=3)
    assert wd.should_dump()
    path = wd.dump(reason="alert", engine_snapshot={"queued": []},
                   attribution=led, registry=reg)
    assert path and Path(path).exists()
    assert not wd.should_dump()              # pending flag consumed
    bundle = json.loads(Path(path).read_text())
    assert bundle["reason"] == "alert"
    assert bundle["alerts"][0]["kind"] == ALERT_QUEUE
    assert bundle["engine"] == {"queued": []}
    assert any(e["name"] == "mark" for e in bundle["spans"])
    assert bundle["span_counters"]["events_recorded"] >= 1
    assert bundle["attribution"]["totals"]["wall_ns"] == 10
    assert "compiled_costs" in bundle
    # the cap: max_dumps bundles, then the recorder goes quiet
    assert wd.dump(reason="crash") is not None
    assert wd.dump(reason="crash") is None
    assert len(wd.dumps_written) == 2


def test_watchdog_dump_never_raises(tmp_path):
    class Broken:
        def snapshot(self):
            raise RuntimeError("boom")
    wd = Watchdog(postmortem_dir=str(tmp_path))
    assert wd.dump(reason="crash", attribution=Broken()) is None
    wd2 = Watchdog()                          # no dir configured: no-op
    assert wd2.dump(reason="crash") is None


def test_engine_watchdog_fires_and_dumps_on_queue_breach(pipe, tmp_path):
    wd = Watchdog(WatchdogConfig(queue_limit=0, warmup_steps=0))
    tel = Telemetry(profile=True, watchdog=wd,
                    postmortem_dir=str(tmp_path))
    engine = _make_engine(pipe, telemetry=tel)
    engine.max_inflight = 1                  # force a standing queue
    _serve(engine, n=3)
    kinds = {a.kind for a in wd.alerts}
    assert ALERT_QUEUE in kinds
    dumps = sorted(tmp_path.glob("postmortem_*.json"))
    assert dumps
    bundle = json.loads(dumps[0].read_text())
    assert bundle["reason"] == "alert"
    assert "inflight" in bundle["engine"]
    assert bundle["attribution"]["conservation"]["flops_delta"] == 0
    assert tel.snapshot()["alerts"]


# ---------------------------------------------------------------------------
# Telemetry bundle + exporters


def test_telemetry_bundle_wires_profile_and_watchdog(tmp_path):
    tel = Telemetry(profile=True, postmortem_dir=str(tmp_path))
    assert tel.profiling
    assert isinstance(tel.profile, CompiledCostRegistry)
    assert isinstance(tel.attribution, AttributionLedger)
    assert tel.watchdog is not None          # default-built from the dir
    assert tel.watchdog.recorder is tel.recorder
    assert tel.watchdog.postmortem_dir == str(tmp_path)
    snap = tel.snapshot()
    assert snap["attribution"]["conservation"]["wall_ns_delta"] == 0
    assert snap["alerts"] == []
    plain = Telemetry()
    assert not plain.profiling and plain.watchdog is None
    assert "attribution" not in plain.snapshot()


def test_export_surfaces_span_counters():
    rec = SpanRecorder(clock=FakeClock(), max_events=4)
    for i in range(6):
        rec.instant(f"e{i}")
    spans = rec.counters()
    assert spans == {"events_recorded": 6, "events_dropped": 2,
                     "occupancy": 1.0, "capacity": 4}
    line = tel_export.metrics_line({"served": 2}, spans=spans)
    assert "span_dropped=2" in line and "span_occupancy=1" in line
    text = tel_export.prometheus_text(summary={"served": 2.0}, spans=spans)
    assert "repro_spans_events_dropped 2" in text
    assert "repro_spans_occupancy 1" in text
    snap = json.loads(tel_export.json_snapshot(summary={"served": 2.0},
                                               spans=spans))
    assert snap["spans"]["events_dropped"] == 2


# ---------------------------------------------------------------------------
# Bench harness: the committed perf trajectory


def test_update_trajectory_replaces_one_suite_and_is_stable(tmp_path):
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
    from benchmarks.run import _headline, update_trajectory
    path = tmp_path / "BENCH.json"
    update_trajectory("serving", {"serving_engine": {"speedup": 1.5,
                                                     "note": "str-dropped"}},
                      "sha1", path=path)
    update_trajectory("profile", {"profile": {"bit_identical": True,
                                              "reconcile": {"n_errors": 0}}},
                      "sha1", path=path)
    doc = json.loads(path.read_text())
    assert set(doc["suites"]) == {"serving", "profile"}
    prof = doc["suites"]["profile"]["benches"]["profile"]
    assert prof == {"bit_identical": 1, "reconcile.n_errors": 0}
    assert "note" not in doc["suites"]["serving"]["benches"]["serving_engine"]
    # re-running the same suite at the same sha is byte-stable and
    # preserves the other suite's entry
    before = path.read_bytes()
    update_trajectory("profile", {"profile": {"bit_identical": True,
                                              "reconcile": {"n_errors": 0}}},
                      "sha1", path=path)
    assert path.read_bytes() == before
    assert json.loads(path.read_text())["suites"]["serving"]["git_sha"] \
        == "sha1"
    assert _headline({"a": {"b": 2.5}, "c": [1, 2], "d": "x"}) \
        == {"a.b": 2.5}


# ---------------------------------------------------------------------------
# Lint: attribution must stay host-pure


def _lint_attr(src: str):
    from repro.analysis.rules_telemetry import TelemetryRule
    return TelemetryRule().check("src/repro/telemetry/attribution.py",
                                 ast.parse(src), src)


def test_rules_attribution_bans_device_imports():
    assert [f.rule for f in _lint_attr("import numpy as np\n")] \
        == ["telemetry-attribution-device"]
    assert [f.rule for f in _lint_attr("from jax import numpy as jnp\n")] \
        == ["telemetry-attribution-device"]
    assert [f.rule for f in _lint_attr("import jaxlib\n")] \
        == ["telemetry-attribution-device"]


def test_rules_attribution_bans_device_calls_and_syncs():
    bad = ("def f(x):\n"
           "    return np.sum(x)\n")
    assert [f.rule for f in _lint_attr(bad)] \
        == ["telemetry-attribution-device"]
    bad = ("def f(x):\n"
           "    return x.block_until_ready()\n")
    assert [f.rule for f in _lint_attr(bad)] \
        == ["telemetry-attribution-device"]
    bad = ("def f(x):\n"
           "    return x.item()\n")
    assert [f.rule for f in _lint_attr(bad)] \
        == ["telemetry-attribution-device"]


def test_rules_attribution_allows_host_arithmetic():
    ok = ("import dataclasses\n"
          "def exact_shares(total, weights):\n"
          "    s = float(sum(weights))\n"
          "    return [int(total * w / s) for w in weights]\n")
    assert _lint_attr(ok) == []
    # the shipped module is clean under its own rule
    src = Path(__file__).resolve().parents[1] \
        / "src/repro/telemetry/attribution.py"
    text = src.read_text()
    assert _lint_attr(text) == []
