"""Pallas kernels vs pure-jnp oracles — shape/dtype sweeps (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.attention import ops as attn_ops
from repro.kernels.attention import ref as attn_ref
from repro.kernels.patch_embed import ops as pe_ops
from repro.kernels.patch_embed import ref as pe_ref
from repro.kernels.patch_embed.patch_embed import (patch_deembed_pallas,
                                                   patch_embed_pallas)
from repro.kernels.ssd import ops as ssd_ops
from repro.kernels.ssd import ref as ssd_ref

ATTN_CASES = [
    # B, S, H, K, hd, causal, softcap, window, dtype
    (2, 128, 4, 2, 64, True, 0.0, 0, jnp.float32),
    (1, 256, 4, 4, 64, True, 50.0, 0, jnp.float32),
    (2, 256, 8, 2, 32, True, 0.0, 128, jnp.float32),
    (1, 128, 2, 1, 128, False, 0.0, 0, jnp.float32),
    (1, 256, 4, 2, 64, True, 0.0, 0, jnp.bfloat16),
    (2, 384, 6, 2, 64, True, 30.0, 256, jnp.float32),
]


@pytest.mark.parametrize("case", ATTN_CASES,
                         ids=[f"a{i}" for i in range(len(ATTN_CASES))])
def test_flash_attention_allclose(case):
    B, S, H, K, hd, causal, cap, win, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(B * S + H), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = attn_ops.flash_attention(q, k, v, causal=causal, softcap=cap,
                                   window=win)
    want = attn_ref.attention_ref(q, k, v, causal=causal, softcap=cap,
                                  window=win)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


SSD_CASES = [(2, 64, 4, 16, 8, 16), (1, 96, 2, 32, 16, 32),
             (2, 48, 3, 8, 8, 16), (1, 128, 4, 16, 32, 64)]


@pytest.mark.parametrize("case", SSD_CASES,
                         ids=[f"s{i}" for i in range(len(SSD_CASES))])
def test_ssd_kernel_allclose(case):
    B, S, H, P, N, chunk = case
    ks = jax.random.split(jax.random.PRNGKey(S + N), 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_true, h_true = ssd_ref.ssd_recurrence_ref(x, dt, A, Bm, Cm)
    y, h = ssd_ops.ssd(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_true),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_true),
                               atol=2e-3, rtol=2e-3)


PE_CASES = [(512, 64, 256, jnp.float32), (256, 48, 128, jnp.float32),
            (1024, 128, 512, jnp.bfloat16), (256, 16, 64, jnp.float32)]


@pytest.mark.parametrize("case", PE_CASES,
                         ids=[f"p{i}" for i in range(len(PE_CASES))])
def test_patch_embed_allclose(case):
    N, K, d, dtype = case
    ks = jax.random.split(jax.random.PRNGKey(N + d), 3)
    x = jax.random.normal(ks[0], (N, K), dtype)
    w = jax.random.normal(ks[1], (K, d), dtype)
    b = jax.random.normal(ks[2], (d,), dtype)
    got = patch_embed_pallas(x, w, b, block_n=min(256, N),
                             block_d=min(256, d))
    want = pe_ref.patch_embed_ref(x, w, b)
    tol = 2e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)
    got2 = patch_deembed_pallas(x, w, b, block_n=min(256, N))
    want2 = pe_ref.patch_deembed_ref(x, w, b)
    np.testing.assert_allclose(np.asarray(got2, np.float32),
                               np.asarray(want2, np.float32),
                               atol=tol, rtol=tol)


def test_flexi_embed_kernel_matches_core_path():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (2, 1, 16, 16, 4))
    w_flex = jax.random.normal(ks[1], (16, 4, 64))
    b = jax.random.normal(ks[2], (64,))
    from repro.core import patch as pm
    for p in [(1, 2, 2), (1, 4, 4)]:
        got = pe_ops.embed_tokens_flex(w_flex, b, x, p, (1, 4, 4))
        want = pm.embed_tokens_flex(w_flex, b, x, p, (1, 4, 4))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   atol=2e-4, rtol=2e-4)
