"""Direct unit tests for the seed runtime control modules the fleet
wires in (DESIGN.md §fleet): elastic mesh replanning, heartbeat fault
detection with an injectable clock, and straggler detection/hedging.

These are host-only (no device work) — the control logic is the part
that transfers to a real cluster, so it gets first-class coverage
instead of riding along inside integration tests.
"""
import numpy as np
import pytest

from repro.runtime.elastic import plan_mesh_shape
from repro.runtime.fault_tolerance import HeartbeatMonitor
from repro.runtime.straggler import (StragglerDetector,
                                     backup_request_schedule,
                                     rebalance_shards)

pytestmark = pytest.mark.tier1


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


# ---------------------------------------------------------------------------
# elastic.plan_mesh_shape

class TestPlanMeshShape:
    def test_exact_divisor_kept(self):
        assert plan_mesh_shape(8, 4) == (2, 4)
        assert plan_mesh_shape(8, 8) == (1, 8)
        assert plan_mesh_shape(12, 4) == (3, 4)

    def test_nondivisor_halves_to_power_of_two(self):
        # 8 does not divide 12; largest halving that does is 4
        assert plan_mesh_shape(12, 8) == (3, 4)
        # 4 does not divide 6; halves to 2
        assert plan_mesh_shape(6, 4) == (3, 2)

    def test_coprime_collapses_to_data_parallel(self):
        assert plan_mesh_shape(7, 4) == (7, 1)
        assert plan_mesh_shape(5, 8) == (5, 1)

    def test_zero_or_negative_model_parallel_means_one(self):
        assert plan_mesh_shape(5, 0) == (5, 1)
        assert plan_mesh_shape(5, -3) == (5, 1)

    def test_single_device(self):
        assert plan_mesh_shape(1, 4) == (1, 1)

    def test_product_always_covers_devices(self):
        for n in range(1, 33):
            for mp in range(0, 9):
                data, model = plan_mesh_shape(n, mp)
                assert data * model == n
                assert data >= 1 and model >= 1


# ---------------------------------------------------------------------------
# fault_tolerance.HeartbeatMonitor

class TestHeartbeatMonitor:
    def test_alive_until_timeout(self):
        clk = FakeClock()
        mon = HeartbeatMonitor(3, timeout_s=10.0, clock=clk)
        clk.advance(10.0)          # exactly the timeout: not yet dead
        assert mon.check() == []
        assert mon.alive_count == 3
        clk.advance(0.5)
        assert sorted(mon.check()) == [0, 1, 2]
        assert mon.alive_count == 0

    def test_heartbeat_defers_death(self):
        clk = FakeClock()
        mon = HeartbeatMonitor(2, timeout_s=10.0, clock=clk)
        clk.advance(8.0)
        mon.heartbeat(0)
        clk.advance(4.0)           # worker 0 at 4s, worker 1 at 12s
        assert mon.check() == [1]
        assert mon.alive_count == 1

    def test_check_reports_each_death_once(self):
        clk = FakeClock()
        mon = HeartbeatMonitor(1, timeout_s=1.0, clock=clk)
        clk.advance(2.0)
        assert mon.check() == [0]
        clk.advance(2.0)
        assert mon.check() == []   # already dead, not "newly" dead

    def test_restart_bumps_incarnation(self):
        clk = FakeClock()
        mon = HeartbeatMonitor(2, timeout_s=1.0, clock=clk)
        assert mon.workers[0].incarnation == 0
        clk.advance(2.0)
        assert mon.check() == [0, 1]
        mon.heartbeat(0)           # restarted worker comes back
        assert mon.workers[0].alive
        assert mon.workers[0].incarnation == 1
        assert mon.alive_count == 1
        # a live worker's heartbeat never bumps the incarnation
        mon.heartbeat(0)
        assert mon.workers[0].incarnation == 1
        # die and come back again: monotone incarnations
        clk.advance(2.0)
        assert mon.check() == [0]
        mon.heartbeat(0)
        assert mon.workers[0].incarnation == 2


# ---------------------------------------------------------------------------
# straggler.StragglerDetector + schedules

class TestStragglerDetector:
    def test_first_sample_sets_ewma_seed(self):
        det = StragglerDetector(2, ewma=0.7)
        det.record(0, 100.0)
        assert det.times[0] == pytest.approx(100.0)
        det.record(0, 200.0)       # 0.7*100 + 0.3*200
        assert det.times[0] == pytest.approx(130.0)
        assert not det.seen[1]

    def test_report_flags_beyond_threshold_times_median(self):
        det = StragglerDetector(4, threshold=2.0)
        for i, ms in enumerate([10.0, 10.0, 11.0, 25.0]):
            det.record(i, ms)
        rep = det.report(step=7)
        assert rep.step == 7
        assert rep.stragglers == [3]
        assert rep.median_ms == pytest.approx(10.5)
        assert rep.worst_ms == pytest.approx(25.0)

    def test_report_ignores_unseen_workers(self):
        det = StragglerDetector(3)
        rep = det.report(0)
        assert rep.stragglers == [] and rep.median_ms == 0.0
        det.record(0, 10.0)        # a single worker is never a straggler
        assert det.report(1).stragglers == []


class TestRebalanceShards:
    def test_conserves_shards_and_floors_at_one(self):
        out = rebalance_shards(8, np.array([10.0, 10.0, 1000.0]))
        assert sum(out) == 8
        assert min(out) >= 1
        assert out[2] == min(out)  # slowest worker gets the fewest

    def test_uniform_times_split_evenly(self):
        assert rebalance_shards(8, np.array([5.0, 5.0, 5.0, 5.0])) \
            == [2, 2, 2, 2]


class TestBackupRequestSchedule:
    def test_flags_predicted_late_workers(self):
        assert backup_request_schedule([5.0, 15.0, 9.0, 30.0], 10.0) \
            == [1, 3]

    def test_accepts_plain_host_lists(self):
        # the fleet health layer is host-pure and passes python lists
        assert backup_request_schedule([], 10.0) == []
        assert backup_request_schedule([1.0, 2.0], 0.0) == [0, 1]
