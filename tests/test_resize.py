"""PI-resize properties (paper §3.1 / FlexiViT math)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from prop import given, patch_pairs
from repro.core import resize


@given(patch_pairs, n=6)
def test_embed_functional_preservation(pair):
    """W(p_pre) = Q(p_pre)·B·w_pre == w_pre exactly (full column rank)."""
    p_pre, pp = pair
    key = jax.random.PRNGKey(0)
    w = jax.random.normal(key, (int(np.prod(p_pre)), 3, 16))
    w_flex = resize.lift_embed(w, p_pre, pp)
    back = resize.project_embed(w_flex, p_pre, pp)
    np.testing.assert_allclose(np.asarray(back), np.asarray(w),
                               atol=2e-5, rtol=2e-5)


@given(patch_pairs, n=6)
def test_deembed_functional_preservation(pair):
    p_pre, pp = pair
    key = jax.random.PRNGKey(1)
    w = jax.random.normal(key, (16, 4, int(np.prod(p_pre))))
    b = jax.random.normal(key, (4, int(np.prod(p_pre))))
    back_w = resize.project_deembed(resize.lift_deembed(w, p_pre, pp), p_pre, pp)
    back_b = resize.project_deembed_bias(resize.lift_deembed_bias(b, p_pre, pp),
                                         p_pre, pp)
    np.testing.assert_allclose(np.asarray(back_w), np.asarray(w), atol=2e-5)
    np.testing.assert_allclose(np.asarray(back_b), np.asarray(b), atol=2e-5)


def test_identity_projection():
    """p_current == p' → Q is the identity."""
    Q = resize.q_embed((1, 4, 4), (1, 4, 4))
    np.testing.assert_allclose(Q, np.eye(16), atol=1e-10)


@given(patch_pairs, n=6)
def test_token_semantics_preserved_for_upsampled_inputs(pair):
    """⟨upsample(x), w_flex⟩ == ⟨x, w_pre⟩: the PI-resize contract — tokens of
    a bilinearly-upsampled patch match the original embedding exactly."""
    p_pre, pp = pair
    key = jax.random.PRNGKey(2)
    n_pre = int(np.prod(p_pre))
    w = jax.random.normal(key, (n_pre, 1, 8))
    x = jax.random.normal(jax.random.fold_in(key, 1), (n_pre,))
    B = resize.b_up(p_pre, pp)
    x_up = B @ np.asarray(x)
    w_flex = resize.lift_embed(w, p_pre, pp)
    # w_flex = B·w ⇒ need ⟨x_up, pinv-projected back⟩... the operational
    # check: token at p_pre via projected weights == token via original.
    tok_pre = np.asarray(x) @ np.asarray(w[:, 0])
    tok_flex = np.asarray(resize.project_embed(w_flex, p_pre, pp))[:, 0]
    np.testing.assert_allclose(np.asarray(x) @ tok_flex, tok_pre, atol=1e-4)


def test_bilinear_matrix_full_column_rank():
    for pair in [((1, 2, 2), (1, 4, 4)), ((2, 2, 2), (2, 4, 4)),
                 ((1, 4, 4), (1, 8, 8))]:
        B = resize.b_up(*pair)
        rank = np.linalg.matrix_rank(B)
        assert rank == B.shape[1], (pair, rank, B.shape)
