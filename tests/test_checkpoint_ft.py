"""Checkpointing, fault tolerance, elastic restore, stragglers."""
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.elastic import plan_mesh_shape
from repro.runtime.fault_tolerance import (HeartbeatMonitor,
                                           TrainingSupervisor,
                                           run_with_recovery)
from repro.runtime.straggler import (StragglerDetector, rebalance_shards)


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"params": {"w": jax.random.normal(k, (4, 8)),
                       "blocks": {"b": jnp.arange(6.0)}},
            "opt": {"m": jnp.zeros((4, 8))}}


def test_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    t = _tree()
    ck.save(10, t, extra={"note": "hi"})
    restored, extra = ck.restore()
    assert extra["note"] == "hi"
    np.testing.assert_allclose(restored["params"]["w"],
                               np.asarray(t["params"]["w"]))
    np.testing.assert_allclose(restored["params"]["blocks"]["b"],
                               np.arange(6.0))


def test_async_save_and_retention(tmp_path):
    ck = Checkpointer(tmp_path, keep=2, async_save=True)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s))
    ck.wait()
    assert ck.all_steps() == [3, 4]


def test_restore_ignores_uncommitted(tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    ck.save(5, _tree())
    # fake a crashed save
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    (bad / "manifest.json").write_text("{}")
    assert ck.latest_step() == 5


def test_elastic_restore_reshards(tmp_path):
    """Restore onto a different (1-device) 'mesh' with explicit shardings."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ck = Checkpointer(tmp_path, async_save=False)
    t = _tree()
    ck.save(7, t)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    restored, _ = ck.restore(shardings=sh)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())


def test_heartbeat_detection():
    clock = {"t": 0.0}
    hb = HeartbeatMonitor(4, timeout_s=10, clock=lambda: clock["t"])
    clock["t"] = 5.0
    hb.heartbeat(0)
    hb.heartbeat(1)
    clock["t"] = 12.0
    dead = hb.check()
    assert set(dead) == {2, 3}
    assert hb.alive_count == 2
    hb.heartbeat(2)
    assert hb.workers[2].alive and hb.workers[2].incarnation == 1


def test_run_with_recovery_restores_and_completes(tmp_path):
    ck = Checkpointer(tmp_path, async_save=False)
    hb = HeartbeatMonitor(4, timeout_s=1e9)
    sup = TrainingSupervisor(ck, hb, checkpoint_every=5,
                             rescale_plan=lambda n: plan_mesh_shape(n, 2))
    killed = {"done": False}

    def fault_hook(step):
        if step == 7 and not killed["done"]:
            killed["done"] = True
            return [3]
        return None

    def train_fn(step, state):
        return {"x": state["x"] + 1.0}

    state, events = run_with_recovery(train_fn, {"x": jnp.zeros(())}, 12,
                                      sup, fault_hook)
    kinds = [e.kind for e in events]
    assert "failure" in kinds and "restart" in kinds and "rescale" in kinds
    # final state reflects 12 *effective* steps (replay from step 5)
    assert float(state["x"]) == 12.0


def test_plan_mesh_shape():
    assert plan_mesh_shape(256, 16) == (16, 16)
    assert plan_mesh_shape(255, 16) == (255, 1)     # degraded but valid
    assert plan_mesh_shape(240, 16) == (15, 16)
    assert plan_mesh_shape(252, 16) == (63, 4)


def test_straggler_detection_and_rebalance():
    sd = StragglerDetector(4, threshold=2.0)
    for step in range(5):
        for w, ms in enumerate([100, 110, 95, 400]):
            sd.record(w, ms)
    rep = sd.report(5)
    assert rep.stragglers == [3]
    shards = rebalance_shards(16, np.asarray([100, 110, 95, 400.0]))
    assert sum(shards) == 16
    assert shards[3] == min(shards)     # slowest gets fewest
    assert shards[2] == max(shards)     # fastest gets most
