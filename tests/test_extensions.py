"""Beyond-paper extensions: flow matching (paper App. A: 'applies out of
the box'), the adaptive per-sample scheduler (paper future work), and the
int8 KV cache."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnConfig, ModelConfig
from repro.core.adaptive import adaptive_sample, make_mode_eps_fns
from repro.core import flexify
from repro.diffusion import flow, schedule as sch
from repro.models import lm

pytestmark = pytest.mark.tier1


# ---------------------------------------------------------------------------
# Flow matching


def test_flow_interpolation_endpoints():
    x0 = jnp.ones((2, 4, 4, 1))
    eps = -jnp.ones((2, 4, 4, 1))
    np.testing.assert_allclose(
        np.asarray(flow.interpolate(x0, eps, jnp.zeros(2))), np.asarray(x0))
    np.testing.assert_allclose(
        np.asarray(flow.interpolate(x0, eps, jnp.ones(2))), np.asarray(eps))


def test_flow_euler_exact_for_linear_field():
    """With the TRUE velocity v = ε − x0 (constant along the path), Euler
    integration from τ=1 recovers x0 exactly in one step or many."""
    key = jax.random.PRNGKey(0)
    x0 = jax.random.normal(key, (2, 4, 4, 1))
    eps = jax.random.normal(jax.random.fold_in(key, 1), x0.shape)
    v_true = flow.velocity_target(x0, eps)

    def v_fn(x, tau):
        return v_true

    for steps in (1, 4, 16):
        taus = flow.tau_ladder(steps)
        out = flow.euler_phase(v_fn, eps, taus)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x0),
                                   atol=1e-5, rtol=1e-5)


def test_flow_phased_split_invariant():
    key = jax.random.PRNGKey(1)
    x_T = jax.random.normal(key, (2, 4, 4, 1))

    def v_fn(x, tau):
        return jnp.tanh(x) * (1.0 + tau.reshape(-1, 1, 1, 1))

    taus = flow.tau_ladder(8)
    whole = flow.euler_phase(v_fn, x_T, taus)
    parts = flow.sample_flow_phased(
        [(v_fn, taus[:5]), (v_fn, taus[4:])], x_T)
    np.testing.assert_allclose(np.asarray(parts), np.asarray(whole),
                               atol=1e-5)


def test_flow_heun_more_accurate_than_euler():
    """Heun (2nd order) beats Euler on a curved field at equal step count."""
    key = jax.random.PRNGKey(2)
    x_T = jax.random.normal(key, (2, 8))

    def v_fn(x, tau):                     # τ-dependent → curved trajectories
        return -x * (2.0 * tau.reshape(-1, 1))

    # dense-Euler reference ≈ ground truth
    ref = flow.euler_phase(v_fn, x_T, flow.tau_ladder(512))
    e = flow.euler_phase(v_fn, x_T, flow.tau_ladder(8))
    h = flow.heun_phase(v_fn, x_T, flow.tau_ladder(8))
    err_e = float(jnp.abs(e - ref).max())
    err_h = float(jnp.abs(h - ref).max())
    assert err_h < err_e, (err_h, err_e)


def test_flexidit_flow_sampling(tiny_dit_cfg, trained_like_dit):
    """FlexiDiT weak→powerful schedule under flow matching end-to-end."""
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    cond = jnp.asarray([1, 2])
    taus = flow.tau_ladder(8)
    phases = flow.split_tau_ladder(taus, [(1, 5), (0, 3)])
    v_fns = {m: flow.make_flow_v_fn(fparams, fcfg, cond, mode=m)
             for m in (0, 1)}
    x_T = jax.random.normal(jax.random.PRNGKey(3), (2, 1, 16, 16, 4))
    out = flow.sample_flow_phased([(v_fns[m], t) for m, t in phases], x_T)
    assert out.shape == x_T.shape
    assert np.isfinite(np.asarray(out)).all()


# ---------------------------------------------------------------------------
# Adaptive scheduler


def test_adaptive_sampler_switches_and_saves_flops(tiny_dit_cfg,
                                                   trained_like_dit):
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    sched = sch.linear_schedule(100)
    ts = sch.respaced_timesteps(100, 10)
    cond = jnp.asarray([1, 2])
    null = jnp.asarray([10, 10])
    fns = make_mode_eps_fns(fparams, fcfg, cond, null, cfg_scale=1.5)
    x_T = jax.random.normal(jax.random.PRNGKey(4), (2, 1, 16, 16, 4))
    res = adaptive_sample(fns, sched, x_T, ts, jax.random.PRNGKey(5), fcfg,
                          threshold=0.5, probe_every=2)
    assert np.isfinite(np.asarray(res.x0)).all()
    assert 0 <= res.switch_step <= len(ts)
    assert len(res.gaps) >= 1
    # a zero threshold must switch immediately (all-powerful + probes)
    res0 = adaptive_sample(fns, sched, x_T, ts, jax.random.PRNGKey(5), fcfg,
                           threshold=0.0)
    assert res0.switch_step == 0
    # an infinite threshold never switches → cheapest
    res_inf = adaptive_sample(fns, sched, x_T, ts, jax.random.PRNGKey(5),
                              fcfg, threshold=1e9)
    assert res_inf.switch_step == len(ts)
    assert res_inf.flops < res0.flops


# ---------------------------------------------------------------------------
# int8 KV cache


@pytest.mark.parametrize("family", ["dense", "gqa"])
def test_int8_kv_cache_close_to_bf16(family):
    kv = 4 if family == "dense" else 2
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      d_ff=128, vocab_size=97,
                      attn=AttnConfig(4, kv, 16), param_dtype="float32",
                      compute_dtype="float32", remat="none", max_seq_len=32)
    qcfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    B, S = 2, 10
    tokens = jax.random.randint(key, (B, S), 0, 97)
    cache_q = lm.init_cache(qcfg, B, S)
    cache_f = lm.init_cache(cfg, B, S)
    assert cache_q["k"].dtype == jnp.int8 and "k_scale" in cache_q
    max_rel = 0.0
    for i in range(S):
        tok = tokens[:, i:i + 1]
        pos = jnp.full((B,), i, jnp.int32)
        lq, cache_q = lm.decode_step(params, cache_q, tok, pos, qcfg)
        lf, cache_f = lm.decode_step(params, cache_f, tok, pos, cfg)
        rel = float(jnp.abs(lq - lf).max() / jnp.maximum(jnp.abs(lf).max(),
                                                         1e-9))
        max_rel = max(max_rel, rel)
    assert max_rel < 0.05, max_rel


def test_int8_cache_halves_storage():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      d_ff=128, vocab_size=97, attn=AttnConfig(4, 2, 16),
                      param_dtype="bfloat16", compute_dtype="bfloat16",
                      remat="none", max_seq_len=64)
    qcfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    full = sum(x.size * x.dtype.itemsize
               for x in jax.tree.leaves(lm.init_cache(cfg, 2, 64)))
    quant = sum(x.size * x.dtype.itemsize
                for x in jax.tree.leaves(lm.init_cache(qcfg, 2, 64)))
    assert quant < 0.6 * full, (quant, full)
