"""Direct unit tests for serving/metrics.py (DESIGN.md §serving).

The engine tests exercise metrics end-to-end; these pin the ledger's own
contract — rolling-window bounds, cache/attention ledgers, and summary
key stability across the edge cases (no requests, wall=0, a
single-request window) that exporters and log lines must survive.
"""
import pytest

from repro.serving.metrics import RequestRecord, ServingMetrics, StepRecord


def _req(i: int, arrival=0.0, admit=0.5, finish=2.0, deadline=10.0,
         requested=1.0, served=1.0, tokens=100, flops=1e9) -> RequestRecord:
    return RequestRecord(id=i, arrival=arrival, admit=admit, finish=finish,
                         deadline=deadline, budget_requested=requested,
                         budget_served=served, tokens=tokens, flops=flops)


class TestRequestRecord:
    def test_derived_properties(self):
        r = _req(0, arrival=1.0, finish=3.5, deadline=3.0,
                 requested=1.0, served=0.6)
        assert r.latency == 2.5
        assert not r.met_deadline
        assert r.degraded

    def test_deadline_boundary_is_met(self):
        assert _req(0, finish=10.0, deadline=10.0).met_deadline


class TestRollingWindow:
    def test_window_bounds_memory_but_not_totals(self):
        m = ServingMetrics(window=4)
        for i in range(10):
            m.record_request(_req(i, finish=float(i + 1)))
            m.record_step(float(i), real_tokens=50, packed_tokens=100,
                          n_requests=1)
        assert len(m.requests) == 4
        assert len(m.steps) == 4
        assert m.total_served == 10
        assert m.total_steps == 10
        assert m.total_tokens == 10 * 100

    def test_percentiles_reflect_window_not_lifetime(self):
        m = ServingMetrics(window=2)
        m.record_request(_req(0, finish=100.0))       # evicted
        m.record_request(_req(1, finish=1.0))
        m.record_request(_req(2, finish=1.0))
        p = m.latency_percentiles()
        assert p["p99"] <= 1.0

    def test_unbounded_window(self):
        m = ServingMetrics(window=None)
        for i in range(100):
            m.record_request(_req(i))
        assert len(m.requests) == 100


class TestLedgers:
    def test_cache_ledger(self):
        m = ServingMetrics()
        m.record_cache(refreshes=3, skips=7)
        m.record_cache(refreshes=2, skips=8)
        assert m.cache_hit_rate == pytest.approx(15 / 20)
        m.set_cache_bytes(4096)
        m.record_refresh_intervals([2, 2, 3])
        cs = m.cache_summary()
        assert cs["enabled"]
        assert cs["refreshes"] == 5 and cs["skips"] == 15
        assert cs["bytes_resident"] == 4096
        assert cs["refresh_interval_hist"] == {"2": 2, "3": 1}

    def test_cache_ledger_empty(self):
        m = ServingMetrics()
        assert m.cache_hit_rate == 0.0
        assert not m.cache_summary()["enabled"]

    def test_attention_ledger(self):
        m = ServingMetrics()
        m.record_attention_blocks(30, 100)
        m.record_attention_blocks(20, 100)
        assert m.attn_block_skip_rate == pytest.approx(0.75)

    def test_attention_ledger_empty(self):
        assert ServingMetrics().attn_block_skip_rate == 0.0

    def test_packing_efficiency(self):
        m = ServingMetrics()
        m.record_step(0.0, real_tokens=60, packed_tokens=100, n_requests=2)
        m.record_step(1.0, real_tokens=40, packed_tokens=100, n_requests=1)
        assert m.packing_efficiency == pytest.approx(0.5)
        assert ServingMetrics().packing_efficiency == 1.0


class TestSummaryEdgeCases:
    BASE_KEYS = {"served", "steps", "tokens", "packing_efficiency",
                 "degraded"}

    def test_empty_summary_has_no_nan(self):
        out = ServingMetrics().summary()
        assert set(out) == self.BASE_KEYS
        assert all(v == v for v in out.values())      # no NaN anywhere

    def test_empty_percentiles_omitted_not_nan(self):
        assert ServingMetrics().latency_percentiles() == {}

    def test_wall_zero_reports_wall_but_no_rates(self):
        out = ServingMetrics().summary(wall=0.0)
        assert out["wall_s"] == 0.0
        assert "tokens_per_s" not in out
        assert "requests_per_s" not in out

    def test_wall_none_omits_wall_keys(self):
        out = ServingMetrics().summary(wall=None)
        assert "wall_s" not in out

    def test_single_request_window(self):
        m = ServingMetrics()
        m.record_request(_req(0, arrival=0.0, finish=2.0))
        out = m.summary(wall=4.0)
        assert out["p50"] == pytest.approx(2.0)
        assert out["p99"] == pytest.approx(2.0)
        assert out["deadline_hit_rate"] == 1.0
        assert out["tokens_per_s"] == pytest.approx(25.0)

    def test_key_stability_full(self):
        m = ServingMetrics()
        m.record_request(_req(0))
        m.record_step(0.0, 50, 100, 1)
        m.record_cache(1, 1)
        m.record_attention_blocks(1, 2)
        out = m.summary(wall=1.0)
        assert set(out) == self.BASE_KEYS | {
            "p50", "p99", "deadline_hit_rate", "flops", "cache_hit_rate",
            "cache_bytes_resident", "attn_block_skip_rate", "wall_s",
            "tokens_per_s", "requests_per_s"}
