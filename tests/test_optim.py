"""Optimizer, EMA, compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.optim import adamw, compression, ema


def test_adamw_converges_on_quadratic():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=200,
                     schedule="constant", grad_clip=0.0, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    opt = adamw.init_opt_state(params)
    target = jnp.asarray([1.0, 2.0])

    @jax.jit
    def step(p, o):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(p)
        return adamw.adamw_update(p, g, o, tc)

    for _ in range(200):
        params, opt, m = step(params, opt)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=1e-2)


def test_grad_clip():
    g = {"a": jnp.asarray([30.0, 40.0])}    # norm 50
    clipped, norm = adamw.clip_by_global_norm(g, 5.0)
    assert float(norm) == pytest.approx(50.0)
    np.testing.assert_allclose(np.asarray(clipped["a"]),
                               np.asarray([3.0, 4.0]), atol=1e-5)


def test_lr_schedule_shapes():
    tc = TrainConfig(learning_rate=1.0, warmup_steps=10, total_steps=100,
                     schedule="cosine")
    lrs = [float(adamw.lr_at(tc, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9]                  # warmup
    assert lrs[20] > lrs[90]                # decay
    assert all(l >= 0 for l in lrs)


def test_trainable_mask_freezes():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, grad_clip=0.0)
    params = {"a": jnp.ones(3), "b": jnp.ones(3)}
    grads = {"a": jnp.ones(3), "b": jnp.ones(3)}
    opt = adamw.init_opt_state(params)
    trainable = {"a": True, "b": False}
    p2, _, _ = adamw.adamw_update(params, grads, opt, tc, trainable)
    assert float(jnp.abs(p2["a"] - 1.0).max()) > 0
    np.testing.assert_array_equal(np.asarray(p2["b"]), np.ones(3))


def test_ema_tracks_params():
    p = {"w": jnp.zeros(4)}
    e = ema.init_ema(p)
    for _ in range(100):
        p = {"w": p["w"] + 0.1}
        e = ema.ema_update(e, p, 0.9)
    assert 0 < float(e["w"][0]) < float(p["w"][0])


def test_int8_compression_error_feedback_unbiased():
    """With error feedback, the accumulated compressed sum tracks the true
    sum (residuals don't accumulate unboundedly)."""
    rng = np.random.default_rng(0)
    g_true = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
    ef = {"g": jnp.zeros(64)}
    total_c = jnp.zeros(64)
    total_t = jnp.zeros(64)
    for i in range(50):
        g = g_true * (1.0 + 0.1 * i)
        deq, ef = compression.compress_decompress({"g": g}, ef)
        total_c = total_c + deq["g"]
        total_t = total_t + g
    # relative error of the running sum stays tiny thanks to EF
    rel = float(jnp.linalg.norm(total_c - total_t)
                / jnp.linalg.norm(total_t))
    assert rel < 1e-2, rel


def test_int8_single_shot_bounded_error():
    rng = np.random.default_rng(1)
    g = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
    deq, _ = compression.compress_decompress(
        {"g": g}, {"g": jnp.zeros(256)})
    err = float(jnp.abs(deq["g"] - g).max())
    scale = float(jnp.abs(g).max()) / 127.0
    assert err <= scale * 0.5 + 1e-6
