"""Per-assigned-architecture smoke tests: instantiate a REDUCED same-family
config and run one forward/train step on CPU, asserting output shapes and
no NaNs (the FULL configs are exercised only via the dry-run)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, DIT_ARCHS, get_config
from repro.configs.base import TrainConfig
from repro.launch import steps as st
from repro.models import dit as dit_mod
from repro.models import lm
from repro.optim import adamw


def _batch_for(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "targets": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(key, (B, cfg.vision_tokens,
                                                  cfg.d_model))
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(key, (B, cfg.audio_frames,
                                                  cfg.d_model))
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(0)
    params = lm.init_params(cfg, key)
    batch = _batch_for(cfg, key)

    logits, aux = lm.forward_train(params, batch["tokens"], cfg, extra=batch)
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    tc = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=2)
    step = st.make_train_step(cfg, tc)
    opt = adamw.init_opt_state(params)
    p2, o2, metrics = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(metrics["loss"])), arch
    # at least one parameter moved
    moved = any(float(jnp.abs(a - b).max()) > 0
                for a, b in zip(jax.tree.leaves(p2), jax.tree.leaves(params)))
    assert moved, arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_arch_smoke_prefill_decode(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(1)
    params = lm.init_params(cfg, key)
    B, S = 2, 8
    batch = _batch_for(cfg, key, B, S)
    logits, cache = lm.prefill(params, batch["tokens"], cfg, extra=batch)
    assert logits.shape == (B, cfg.vocab_size)

    from conftest import pad_cache_seq
    cache = pad_cache_seq(cache, 4)
    tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache2 = lm.decode_step(params, cache, tok,
                                     jnp.full((B,), S, jnp.int32), cfg)
    assert logits2.shape == (B, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


@pytest.mark.parametrize("arch", DIT_ARCHS)
def test_dit_arch_smoke(arch):
    cfg = get_config(arch).reduced()
    key = jax.random.PRNGKey(2)
    params = dit_mod.init_dit(cfg, key)
    B = 2
    F, H, W, C = cfg.dit.latent_shape
    x = jax.random.normal(key, (B, F, H, W, C))
    t = jnp.asarray([3.0, 47.0])
    if cfg.dit.conditioning == "class":
        cond = jnp.asarray([1, 2])
    else:
        dc = cfg.dit.text_dim or cfg.d_model
        cond = jax.random.normal(key, (B, cfg.dit.text_len, dc))
    for mode in range(1 + len(cfg.dit.flex_patch_sizes)):
        out = dit_mod.dit_forward(params, x, t, cond, cfg, mode=mode)
        assert out.shape == (B, F, H, W, dit_mod.c_out_dim(cfg)), (arch, mode)
        assert np.isfinite(np.asarray(out, np.float32)).all(), (arch, mode)

    tc = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=2)
    step = st.make_dit_train_step(cfg, tc)
    opt = adamw.init_opt_state(params)
    batch = {"x0": x, "cond": cond}
    p2, o2, metrics = jax.jit(step)(params, opt, batch, key)
    assert np.isfinite(float(metrics["loss"])), arch


def test_full_config_param_counts_plausible():
    """Analytic param counts are in the right ballpark for known models."""
    expected = {"grok-1-314b": (2.0e11, 3.6e11),
                "deepseek-moe-16b": (1.2e10, 2.2e10),
                "deepseek-7b": (5e9, 8e9),
                "gemma3-4b": (3e9, 6e9),
                "qwen2.5-14b": (1.1e10, 1.8e10),
                "gemma2-9b": (7e9, 1.2e10),
                "llama-3.2-vision-90b": (7e10, 1.1e11),
                "whisper-small": (1.3e8, 3.5e8),
                "hymba-1.5b": (1.0e9, 2.2e9),
                "mamba2-130m": (1.0e8, 1.8e8)}
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).num_params()
        assert lo <= n <= hi, (arch, n)
