"""Continuous-batching serving engine (DESIGN.md §serving).

Deterministic simulated-clock tests: no wall time anywhere — the engine,
queue, controller, and metrics all read the injected clock. The heavy
asserts: a packed mixed-budget engine step is bit-compatible (≤1e-4;
observed exactly 0) with per-request ``FlexiPipeline.sample``, join/leave
happen mid-flight without draining, EDF reorders under contention, and
the SLA controller degrades budgets under load.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flexify
from repro.core.packing import (assign_rows, mixed_pack_cost, pack_ratio,
                                packed_row_flops)
from repro.core.scheduler import FlexiSchedule, dit_nfe_flops
from repro.diffusion import schedule as sch
from repro.models import dit as dit_mod
from repro.pipeline import FlexiPipeline, PackLayout, SamplingPlan
from repro.serving import (BucketMenu, BudgetController, Request,
                           RequestQueue, ServingEngine, count_chain,
                           request_cost_flops)

pytestmark = pytest.mark.tier1

T = 6


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


@pytest.fixture(scope="module")
def flexi(tiny_dit_cfg, trained_like_dit):
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    return fparams, fcfg, sch.linear_schedule(100)


@pytest.fixture(scope="module")
def pipe(flexi):
    fparams, fcfg, sched = flexi
    return FlexiPipeline(fparams, fcfg, sched)


def make_plans(solver="ddim"):
    return {0.6: SamplingPlan(T=T, budget=FlexiSchedule.weak_first(T, 3),
                              solver=solver, guidance_scale=1.5),
            1.0: SamplingPlan(T=T, budget=1.0, solver=solver,
                              guidance_scale=1.5)}


# ---------------------------------------------------------------------------
# Host-only: row assembly, bucket menu, queue, controller


def test_assign_rows_first_fit():
    # full segments own a row; weak ones pack r-per-row; no row overflows
    rows = assign_rows([64, 16, 16, 16, 16, 64], capacity=64)
    assert sorted(len(r) for r in rows) == [1, 1, 4]
    for row in rows:
        assert sum([64, 16, 16, 16, 16, 64][i] for i in row) <= 64
    # a leftover weak segment opens a fresh (padded) row
    assert len(assign_rows([16] * 5, capacity=64)) == 2
    with pytest.raises(ValueError, match="capacity"):
        assign_rows([65], capacity=64)


def test_count_chain():
    assert count_chain(0) == ()
    assert count_chain(1) == (1,)
    assert count_chain(6) == (1, 2, 3, 4, 6)
    assert count_chain(16) == (1, 2, 3, 4, 6, 9, 13, 16)


def test_bucket_menu_choose(flexi):
    _, fcfg, _ = flexi
    menu = BucketMenu(fcfg, (0, 1), max_tokens_per_step=256, guided=True)
    # every layout respects the token budget
    for layout in menu.layouts:
        assert layout.cost(fcfg).packed_tokens <= 256
    # pure-full demand → the biggest full bucket (2 requests = 4 CFG rows)
    l = menu.choose({0: 5})
    assert l.capacity_for(0) == 2 and l.capacity_for(1) == 0
    # mixed demand is served mixed
    l = menu.choose({0: 1, 1: 2})
    assert l.capacity_for(0) >= 1 and l.capacity_for(1) >= 2
    # tiny demand picks a tight bucket, not the biggest one
    l = menu.choose({1: 1})
    assert l.capacity_for(1) == 1 and l.n_requests == 1
    assert menu.choose({}) is None
    with pytest.raises(ValueError, match="not in the bucket menu"):
        menu.choose({3: 1})
    with pytest.raises(ValueError, match="below one row"):
        BucketMenu(fcfg, (0, 1), max_tokens_per_step=32, guided=True)


def test_request_queue_policies():
    q = RequestQueue()
    q.submit(Request(id=0, cond=0, budget=1.0, deadline=5.0), now=0.0)
    q.submit(Request(id=1, cond=0, budget=1.0, deadline=1.0), now=0.1)
    q.submit(Request(id=2, cond=0, budget=1.0, deadline=3.0), now=0.2)
    assert q.pop("fifo").id == 0
    assert q.pop("edf").id == 1          # earliest deadline, not arrival
    assert q.pop("edf").id == 2
    with pytest.raises(IndexError):
        q.pop("fifo")
    q.submit(Request(id=3, cond=0, budget=1.0), now=0.3)
    with pytest.raises(ValueError, match="policy"):
        q.pop("sjf")


def test_controller_solves_highest_sustainable_budget(flexi):
    _, fcfg, _ = flexi
    plans = make_plans()
    ctl = BudgetController(fcfg, plans, target_util=1.0, alpha=1.0)
    f_hi = request_cost_flops(fcfg, plans[1.0])
    f_lo = request_cost_flops(fcfg, plans[0.6])
    assert f_lo < f_hi
    # no estimates yet → no evidence of pressure → highest level
    assert ctl.solve() == 1.0
    # capacity for exactly 2 full-budget requests/s, arrivals at 1/s
    ctl.observe_service(flops=2 * f_hi, dt=1.0)
    ctl.observe_arrival(0.0)
    ctl.observe_arrival(1.0)
    assert ctl.solve() == 1.0
    # arrivals speed up to 4/s: only the weak level fits 2*f_hi/4 per req
    for t in (1.25, 1.5, 1.75):
        ctl.observe_arrival(t)
    assert ctl.arrival_rate == pytest.approx(4.0)
    assert ctl.solve() == 0.6
    assert ctl.assign(1.0) == 0.6        # demoted
    assert ctl.assign(0.6) == 0.6        # never promoted
    # load drops again → back to full quality
    ctl.observe_arrival(101.75)
    assert ctl.solve() == 1.0
    assert ctl.assign(1.0) == 1.0


def test_request_cost_flops_counts_parallel_padding(flexi):
    """The ledger charges sequence-parallel pad-to-divisible waste
    (distributed.partition) on top of the plan's analytic FLOPs."""
    _, fcfg, _ = flexi
    plan = SamplingPlan(T=T, budget=FlexiSchedule.weak_first(T, 3),
                        guidance_scale=1.5)
    base = request_cost_flops(fcfg, plan, sp=1)
    assert base == pytest.approx(plan.flops(fcfg))
    padded = request_cost_flops(fcfg, plan, sp=3)   # 64 % 3 != 0 → padding
    assert padded > base


# ---------------------------------------------------------------------------
# Packed-cost accounting (satellite: conditioning-token overhead)


def test_packed_row_flops_conditioning_overhead(flexi):
    _, fcfg, _ = flexi
    N0 = dit_mod.tokens_for_mode(fcfg, 0)
    d, L = fcfg.d_model, fcfg.num_layers
    r = pack_ratio(fcfg, 1)
    row = packed_row_flops(fcfg, [1] * r, capacity=N0)
    # every packed segment carries its own adaLN conditioning where the
    # plain NFE pays for one sample: that exact delta is in the ledger
    ada_overhead = (r - 1) * (L * 2 * d * 6 * d + 2 * d * 2 * d)
    seg_embed = sum(2 * dit_mod.tokens_for_mode(fcfg, 1) * 16
                    * (4 * d + d * dit_mod.c_out_dim(fcfg))
                    for _ in range(r))       # npix=16 for the (1,4,4) mode
    plain_embed = (2 * N0 * 4 * 4 * d
                   + 2 * N0 * d * 4 * dit_mod.c_out_dim(fcfg))
    assert row == pytest.approx(dit_nfe_flops(fcfg, 0) + ada_overhead
                                + seg_embed - plain_embed)
    with pytest.raises(ValueError, match="exceed"):
        packed_row_flops(fcfg, [1] * (r + 1), capacity=N0)


def test_mixed_pack_cost(flexi):
    _, fcfg, _ = flexi
    # one full + four weak segments fill exactly two rows, zero waste
    c = mixed_pack_cost(fcfg, [0, 1, 1, 1, 1])
    assert c.rows == 2 and c.efficiency == 1.0
    # one full + one weak: the weak row is 3/4 padding
    c2 = mixed_pack_cost(fcfg, [0, 1])
    assert c2.rows == 2
    assert c2.efficiency == pytest.approx((64 + 16) / 128)
    assert c2.flops < c.flops


# ---------------------------------------------------------------------------
# The engine: bit-exactness, join/leave, EDF, degradation


def _reference(pipe, plans, level, label, key):
    return np.asarray(pipe.sample(plans[level], 1, key,
                                  cond=jnp.asarray([label], jnp.int32)).x0[0])


@pytest.mark.parametrize("solver", ["ddim", "ddpm"])
def test_engine_matches_per_request_sampling(pipe, flexi, solver):
    """A packed mixed-budget engine step — requests at different denoise
    steps, budgets, and modes in ONE forward — reproduces each request's
    standalone FlexiPipeline.sample output (acceptance: ≤1e-4), with
    requests joining and leaving mid-flight and zero recompiles when the
    same workload shape replays."""
    plans = make_plans(solver)
    clk = FakeClock()
    eng = ServingEngine(pipe, plans, max_tokens_per_step=256,
                        policy="fifo", clock=clk)
    spec = [(0, 0.6, 3), (1, 1.0, 7), (2, 0.6, 5)]
    keys = {rid: jax.random.PRNGKey(40 + rid) for rid, _, _ in spec}
    for rid, lvl, label in spec:
        eng.submit(cond=label, budget=lvl, key=keys[rid])
        clk.advance(0.01)
    # two steps in, a late request JOINS while the others are mid-flight
    results = []
    for _ in range(2):
        results += eng.step()
        clk.advance(0.01)
    late = eng.submit(cond=9, budget=1.0, key=jax.random.PRNGKey(99))
    spec.append((late, 1.0, 9))
    keys[late] = jax.random.PRNGKey(99)
    results += eng.run()
    assert len(results) == 4
    # the early requests LEFT before the late one finished (no drain)
    order = [r.request.id for r in results]
    assert order.index(late) == len(order) - 1
    assert set(order) == {0, 1, 2, late}
    for r in results:
        _, lvl, label = next(s for s in spec if s[0] == r.request.id)
        ref = _reference(pipe, plans, lvl, label, keys[r.request.id])
        np.testing.assert_allclose(np.asarray(r.x0), ref, atol=1e-4,
                                   rtol=1e-4)
    # replaying the same workload shape is compile-free (bucket warmup)
    warm = eng.cache_stats()
    for rid, lvl, label in spec[:3]:
        eng.submit(cond=label, budget=lvl, key=keys[rid])
        clk.advance(0.01)
    for _ in range(2):
        eng.step()
        clk.advance(0.01)
    eng.submit(cond=9, budget=1.0, key=keys[late])
    eng.run()
    after = eng.cache_stats()
    assert after["compiled"] == warm["compiled"]
    assert after["misses"] == warm["misses"]
    # simulated clock → deterministic latency metrics
    assert eng.metrics.summary()["served"] == 8.0
    assert math.isfinite(eng.metrics.latency_percentiles()["p99"])


def test_edf_orders_by_deadline_under_contention(pipe):
    """With capacity for one full request per step, EDF serves the later
    arrival with the earlier deadline first; FIFO does not."""
    plans = {1.0: SamplingPlan(T=T, budget=1.0, guidance_scale=1.5)}
    finish_order = {}
    for policy in ("fifo", "edf"):
        clk = FakeClock()
        eng = ServingEngine(pipe, plans, max_tokens_per_step=128,
                            policy=policy, clock=clk)
        eng.submit(cond=1, budget=1.0, deadline=100.0)   # early arrival
        clk.advance(0.01)
        eng.submit(cond=2, budget=1.0, deadline=1.0)     # urgent latecomer
        results = []
        while not eng.idle:
            results += eng.step()
            clk.advance(0.01)
        finish_order[policy] = [r.request.id for r in results]
    assert finish_order["fifo"] == [0, 1]
    assert finish_order["edf"] == [1, 0]


def test_degrade_demotes_under_load_and_recovers(pipe, flexi):
    _, fcfg, _ = flexi
    plans = make_plans()
    ctl = BudgetController(fcfg, plans, target_util=1.0, alpha=1.0)
    clk = FakeClock()
    eng = ServingEngine(pipe, plans, max_tokens_per_step=256,
                        policy="degrade", clock=clk, controller=ctl)
    # teach the controller: capacity = 2 full requests/s, arrivals 8/s
    ctl.observe_service(flops=2 * request_cost_flops(fcfg, plans[1.0]),
                        dt=1.0)
    for i in range(8):
        eng.submit(cond=i % 10, budget=1.0)
        clk.advance(0.125)
    overloaded = eng.run()
    assert all(r.budget_served == 0.6 for r in overloaded)
    assert all(r.record.degraded for r in overloaded)
    assert eng.metrics.summary()["degraded"] == 8.0
    # load drops: next request arrives after a long gap → full quality
    clk.advance(50.0)
    eng.submit(cond=3, budget=1.0)
    relaxed = eng.run()
    assert [r.budget_served for r in relaxed] == [1.0]
    # degraded requests still sample correctly — at the weaker plan
    plans_ref = make_plans()
    r0 = overloaded[0]
    ref = _reference(pipe, plans_ref, 0.6, r0.request.cond, r0.request.key)
    np.testing.assert_allclose(np.asarray(r0.x0), ref, atol=1e-4, rtol=1e-4)


def test_engine_menu_validation(pipe, flexi):
    _, fcfg, _ = flexi
    with pytest.raises(ValueError, match="non-empty"):
        ServingEngine(pipe, {})
    with pytest.raises(ValueError, match="adaptive"):
        from repro.pipeline import AdaptiveBudget
        ServingEngine(pipe, {1.0: SamplingPlan(T=T, budget=AdaptiveBudget())})
    with pytest.raises(ValueError, match="share solver"):
        ServingEngine(pipe, {0.6: SamplingPlan(T=T, budget=0.6,
                                               solver="ddim"),
                             1.0: SamplingPlan(T=T, budget=1.0,
                                               solver="ddpm")})
    with pytest.raises(ValueError, match="weak_cond"):
        ServingEngine(pipe, {0.6: SamplingPlan(
            T=T, budget=0.6, guidance_kind="weak_cond")})
    # requested budgets quantize UP to the menu (at least as powerful)
    eng = ServingEngine(pipe, make_plans(), max_tokens_per_step=256)
    assert eng.quantize(0.3) == 0.6
    assert eng.quantize(0.6) == 0.6
    assert eng.quantize(0.7) == 1.0
    assert eng.quantize(1.0) == 1.0


def test_packlayout_validation():
    with pytest.raises(ValueError, match="at least one"):
        PackLayout(groups=())
    with pytest.raises(ValueError, match="mode-sorted"):
        PackLayout(groups=((1, 2), (0, 1)))
    with pytest.raises(ValueError, match="counts"):
        PackLayout(groups=((0, 0),))
    layout = PackLayout.for_counts({1: 2, 0: 1})
    assert layout.groups == ((0, 1), (1, 2))
    assert layout.n_requests == 3
    assert layout.segment_modes() == (0, 0, 1, 1, 1, 1)
