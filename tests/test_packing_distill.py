"""Packing (App. B.2) + distillation (§3.2) + MMD (App. B.1)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.core import flexify, trainable_mask
from repro.core.distill import make_distill_step
from repro.core.mmd import bootstrap_mmd_loss, make_mmd_finetune_step, rbf_mmd2
from repro.core.packing import (packed_mixed_forward, packed_row_flops,
                                packed_weak_forward, packing_cost, pack_ratio)
from repro.core.scheduler import dit_nfe_flops
from repro.diffusion import schedule as sch
from repro.models import dit as dit_mod


def test_pack_ratio(tiny_dit_cfg, trained_like_dit):
    _, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    assert pack_ratio(fcfg, 1) == 4


def test_packed_equals_unpacked(tiny_dit_cfg, trained_like_dit):
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    B, r = 2, 4
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (r, B, 1, 16, 16, 4))
    t = jnp.asarray([5.0, 50.0])
    conds = jax.random.randint(key, (r, B), 0, 10)
    packed = packed_weak_forward(fparams, x, t, conds, fcfg, mode=1)
    for i in range(r):
        single = dit_mod.dit_forward(fparams, x[i], t, conds[i], fcfg, mode=1)
        np.testing.assert_allclose(np.asarray(packed[i]), np.asarray(single),
                                   atol=2e-3, rtol=2e-3)


def test_packed_long_sequence_blocked_path(tiny_dit_cfg, trained_like_dit,
                                           monkeypatch):
    """Long packed sequences (N above the blocked-attention threshold WITH
    segment ids) must route through the flash-style blocked path instead of
    materializing [B,H,N,N] dense scores — and match the dense result.
    Regression for the packed-video CFG OOM (ISSUE 2 satellite)."""
    from repro.models import attention as attn_mod
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    B, r = 2, 4
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (r, B, 1, 16, 16, 4))
    t = jnp.asarray([5.0, 50.0])
    conds = jax.random.randint(key, (r, B), 0, 10)
    dense = packed_weak_forward(fparams, x, t, conds, fcfg, mode=1)
    # packed row = 4×16 = 64 tokens; force it over the threshold so the
    # segment-aware blocked path runs (q_block smaller than the row)
    monkeypatch.setattr(attn_mod, "BLOCKED_ATTN_THRESHOLD", 16)
    blocked = packed_weak_forward(fparams, x, t, conds, fcfg, mode=1)
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(dense),
                               atol=1e-5, rtol=1e-5)


def test_packing_cost_table(tiny_dit_cfg, trained_like_dit):
    _, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    costs = packing_cost(fcfg, 1, n_images=8)
    assert [c.approach for c in costs] == [1, 2, 3, 4]
    # approach 2 (separate batched) has the lowest FLOPs (paper Fig. 12)
    assert costs[1].flops <= costs[2].flops
    assert costs[1].flops <= costs[3].flops
    # approach 3/4 use fewer sequential calls (latency)
    assert costs[3].nfe_calls < costs[0].nfe_calls


def test_packing_cost_counts_conditioning_overhead(tiny_dit_cfg,
                                                   trained_like_dit):
    """Approach 4's ledger includes the per-token adaLN conditioning the
    packed path actually pays (regression: it used to price a packed row
    as a plain powerful NFE)."""
    _, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    n, r = 8, pack_ratio(fcfg, 1)
    f_p = dit_nfe_flops(fcfg, 0)
    N_p = dit_mod.tokens_for_mode(fcfg, 0)
    costs = packing_cost(fcfg, 1, n_images=n)
    rows = -(-n // r)
    row_fl = packed_row_flops(fcfg, [1] * r, capacity=N_p)
    assert costs[3].flops == pytest.approx(n * f_p + rows * row_fl)
    assert row_fl > f_p            # the overhead is real, not free


def test_packed_mixed_forward_equals_unpacked(tiny_dit_cfg,
                                              trained_like_dit):
    """Weak AND powerful segments in one packed forward match their
    unpacked per-mode NFEs (the serving engine's step primitive)."""
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    key = jax.random.PRNGKey(5)
    x_full = jax.random.normal(key, (1, 1, 16, 16, 4))
    x_weak = jax.random.normal(jax.random.fold_in(key, 1), (3, 1, 16, 16, 4))
    t_full = jnp.asarray([7], jnp.int32)
    t_weak = jnp.asarray([3, 50, 93], jnp.int32)     # different steps!
    c_full = jnp.asarray([2], jnp.int32)
    c_weak = jnp.asarray([0, 5, 9], jnp.int32)
    packed = packed_mixed_forward(
        fparams, fcfg, ((0, 1), (1, 3)), [x_full, x_weak],
        [t_full, t_weak], [c_full, c_weak])
    ref_full = dit_mod.dit_forward(fparams, x_full, t_full, c_full, fcfg,
                                   mode=0)
    np.testing.assert_allclose(np.asarray(packed[0]), np.asarray(ref_full),
                               atol=1e-4, rtol=1e-4)
    for i in range(3):
        ref = dit_mod.dit_forward(fparams, x_weak[i:i + 1], t_weak[i:i + 1],
                                  c_weak[i:i + 1], fcfg, mode=1)
        np.testing.assert_allclose(np.asarray(packed[1][i:i + 1]),
                                   np.asarray(ref), atol=1e-4, rtol=1e-4)


def test_distill_trains_only_adapters(tiny_dit_cfg, trained_like_dit):
    lparams, lcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)],
                            lora_rank=4)
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=1, total_steps=20)
    mask = trainable_mask(lparams, "lora")
    from repro.optim import adamw
    opt = adamw.init_opt_state(lparams)
    step = jax.jit(make_distill_step(lcfg, tc, mode_weak=1, trainable=mask))
    key = jax.random.PRNGKey(0)
    batch = {"x0": jax.random.normal(key, (4, 1, 16, 16, 4)),
             "cond": jax.random.randint(key, (4,), 0, 10)}
    p, o, m0 = step(lparams, opt, batch, key)
    for i in range(25):
        p, o, m = step(p, o, batch, jax.random.fold_in(key, i))
    assert float(m["distill_loss"]) < float(m0["distill_loss"])
    np.testing.assert_array_equal(np.asarray(p["blocks"]["attn"]["wq"]),
                                  np.asarray(lparams["blocks"]["attn"]["wq"]))
    assert float(jnp.abs(p["blocks"]["lora"]["attn"]["wq"]["b"]).max()) > 0


def test_rbf_mmd_separates_distributions():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    x = jax.random.normal(k1, (64, 8))
    y_same = jax.random.normal(k2, (64, 8))
    y_diff = jax.random.normal(k3, (64, 8)) * 3.0 + 2.0
    same = float(rbf_mmd2(x, y_same))
    diff = float(rbf_mmd2(x, y_diff))
    assert diff > same + 0.05


def test_bootstrap_mmd_runs_and_is_finite(tiny_dit_cfg, trained_like_dit):
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    key = jax.random.PRNGKey(0)
    batch = {"x0": jax.random.normal(key, (4, 1, 16, 16, 4)),
             "cond": jax.random.randint(key, (4,), 0, 10)}
    loss, aux = bootstrap_mmd_loss(fparams, batch, key, fcfg,
                                   sch.linear_schedule(100))
    assert np.isfinite(float(loss))


def test_mmd_finetune_step(tiny_dit_cfg, trained_like_dit):
    sparams, scfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    tc = TrainConfig(learning_rate=1e-4, warmup_steps=1, total_steps=5)
    from repro.optim import adamw
    step = jax.jit(make_mmd_finetune_step(scfg, tc,
                                          sched=sch.linear_schedule(100)))
    key = jax.random.PRNGKey(1)
    batch = {"x0": jax.random.normal(key, (4, 1, 16, 16, 4)),
             "cond": jax.random.randint(key, (4,), 0, 10)}
    opt = adamw.init_opt_state(sparams)
    p, o, m = step(sparams, opt, batch, key)
    assert np.isfinite(float(m["denoise_loss"]))
    assert np.isfinite(float(m["mmd_loss"]))
