"""Mamba2 SSD: chunked == naive recurrence; streaming state equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from prop import given, ssd_shapes
from repro.configs.base import SSMConfig
from repro.kernels.ssd import ref as ssd_ref
from repro.models import ssm
from repro.models.common import init_tree


@given(ssd_shapes, n=8)
def test_chunked_matches_recurrence(shape):
    B, S, H, P, N, chunk = shape
    key = jax.random.PRNGKey(sum(shape))
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_ref, h_ref = ssd_ref.ssd_recurrence_ref(x, dt, A, Bm, Cm)
    y, h = ssm.ssd_chunked(x, dt, A, Bm, Cm, chunk)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=2e-3, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(h), np.asarray(h_ref),
                               atol=2e-3, rtol=2e-3)


def test_streaming_state_equivalence():
    """Full-sequence layer == prefill on first half + step-by-step decode."""
    cfg = SSMConfig(state_dim=8, head_dim=16, chunk_size=8)
    d = 32
    params = init_tree(ssm.ssm_schema(d, cfg), jax.random.PRNGKey(0),
                       jnp.float32)
    B, S = 2, 20
    u = jax.random.normal(jax.random.PRNGKey(1), (B, S, d))
    full, _ = ssm.ssm_apply(params, u, cfg, d)

    state = ssm.init_ssm_state(B, d, cfg, jnp.float32)
    half, state = ssm.ssm_apply(params, u[:, :12], cfg, d, state)
    outs = [half]
    for i in range(12, S):
        y, state = ssm.ssm_apply(params, u[:, i:i + 1], cfg, d, state)
        outs.append(y)
    stream = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(stream), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


def test_decay_bounds():
    """a_t = exp(A·dt) ∈ (0,1] for A<0, dt≥0 — state can't explode."""
    dt = jnp.asarray([[0.0, 0.5, 5.0]])
    A = jnp.asarray([-1.0])
    a = jnp.exp(dt * A)
    assert float(a.max()) <= 1.0 and float(a.min()) > 0.0
