"""Diffusion schedule identities + samplers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.diffusion import sampler, schedule as sch

pytestmark = pytest.mark.tier1


def test_q_sample_interpolates():
    s = sch.linear_schedule(100)
    x0 = jnp.ones((2, 4, 4, 1))
    noise = jnp.zeros_like(x0)
    t = jnp.asarray([0, 99])
    x_t = sch.q_sample(s, x0, t, noise)
    d = s._derived
    np.testing.assert_allclose(np.asarray(x_t[0]).mean(),
                               d["sqrt_acp"][0], atol=1e-5)
    np.testing.assert_allclose(np.asarray(x_t[1]).mean(),
                               d["sqrt_acp"][99], atol=1e-5)


def test_eps_x0_roundtrip():
    s = sch.linear_schedule(100)
    key = jax.random.PRNGKey(0)
    x0 = jax.random.normal(key, (2, 8, 8, 3))
    eps = jax.random.normal(jax.random.fold_in(key, 1), x0.shape)
    t = jnp.asarray([10, 70])
    x_t = sch.q_sample(s, x0, t, eps)
    x0_hat = sch.predict_x0_from_eps(s, x_t, t, eps)
    np.testing.assert_allclose(np.asarray(x0_hat), np.asarray(x0), atol=1e-4)


def test_posterior_at_t1_recovers_x0_direction():
    s = sch.linear_schedule(100)
    x0 = jnp.ones((1, 4, 4, 1)) * 2.0
    x_t = x0 * 0.5
    mean = sch.posterior_mean(s, x0, x_t, jnp.asarray([1]))
    assert np.isfinite(np.asarray(mean)).all()


def test_respaced_descending_unique():
    ts = sch.respaced_timesteps(1000, 50)
    assert len(ts) == 50 and ts[0] == 999 and ts[-1] == 0
    assert (np.diff(ts) < 0).all()


def _const_eps_fn(x, t):
    return jnp.zeros_like(x), None


def test_ddim_deterministic():
    s = sch.linear_schedule(100)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 4, 1))
    ts = sch.respaced_timesteps(100, 10)
    a = sampler.ddim_phase(_const_eps_fn, s, x, ts, jax.random.PRNGKey(1))
    b = sampler.ddim_phase(_const_eps_fn, s, x, ts, jax.random.PRNGKey(2))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_ddpm_zero_eps_contracts_toward_x0_scale():
    s = sch.linear_schedule(100)
    x = jax.random.normal(jax.random.PRNGKey(0), (2, 4, 4, 1)) * 3
    ts = sch.respaced_timesteps(100, 100)
    out = sampler.ddpm_phase(_const_eps_fn, s, x, ts, jax.random.PRNGKey(1))
    assert np.isfinite(np.asarray(out)).all()


def test_phased_equals_single_phase_when_same_fn():
    """Chaining phases with the same eps_fn == one phase over all steps."""
    s = sch.linear_schedule(50)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 4, 1))
    ts = sch.respaced_timesteps(50, 10)
    whole = sampler.ddim_phase(_const_eps_fn, s, x, ts, jax.random.PRNGKey(9))
    parts = sampler.sample_phased(
        [(_const_eps_fn, ts[:6]), (_const_eps_fn, ts[6:])], s, x,
        jax.random.PRNGKey(9), solver="ddim")
    np.testing.assert_allclose(np.asarray(parts), np.asarray(whole), atol=1e-5)


def test_dpm2_runs():
    s = sch.linear_schedule(100)
    x = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 4, 1))
    ts = sch.respaced_timesteps(100, 8)
    out = sampler.dpm2_phase(_const_eps_fn, s, x, ts, jax.random.PRNGKey(1))
    assert np.isfinite(np.asarray(out)).all()
