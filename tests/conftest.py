import os
import sys
from pathlib import Path

# NOTE: do NOT set xla_force_host_platform_device_count unconditionally —
# smoke tests and benches must see 1 device (the 512-device flag is
# dryrun.py-only). The distributed suite re-launches itself in a subprocess
# with REPRO_FAKE_DEVICES=8 (tests/test_distributed.py); honoring it here,
# BEFORE jax initializes, is the env-guard half of that handshake.
if os.environ.get("REPRO_FAKE_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        f"{os.environ.get('XLA_FLAGS', '')} "
        f"--xla_force_host_platform_device_count="
        f"{os.environ['REPRO_FAKE_DEVICES']}").strip()

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parent))

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import AttnConfig, DiTConfig, ModelConfig


def pad_cache_seq(cache, extra: int):
    """Pad only the KV caches ('k'/'v' keys) along the sequence dim."""
    def rec(node):
        if isinstance(node, dict):
            return {k: (jnp.pad(v, [(0, 0)] * (v.ndim - 3)
                                + [(0, extra), (0, 0), (0, 0)])
                        if k in ("k", "v") else rec(v))
                    for k, v in node.items()}
        return node
    return rec(cache)


@pytest.fixture(scope="session")
def tiny_dit_cfg() -> ModelConfig:
    return ModelConfig(
        name="tiny-dit", family="dit", num_layers=2, d_model=64, d_ff=256,
        vocab_size=0, attn=AttnConfig(4, 4, 16, use_rope=False),
        dit=DiTConfig(latent_shape=(1, 16, 16, 4), patch_size=(1, 2, 2),
                      flex_patch_sizes=(), underlying_patch_size=(1, 2, 2),
                      conditioning="class", num_classes=10),
        mlp_activation="gelu", norm_type="layernorm",
        param_dtype="float32", compute_dtype="float32", remat="none",
        max_seq_len=256)


@pytest.fixture(scope="session")
def eight_fake_devices():
    """The fake-device mesh pool for distributed tests. Skips unless the
    process was launched with REPRO_FAKE_DEVICES=8 (see the env guard at
    the top of this file); tests/test_distributed.py owns the subprocess
    that does so."""
    if jax.device_count() < 8:
        pytest.skip("needs REPRO_FAKE_DEVICES=8 (8 fake host devices)")
    return jax.devices()[:8]


@pytest.fixture(scope="session")
def trained_like_dit(tiny_dit_cfg):
    """A tiny DiT with non-degenerate de-embed / adaLN gates (as if trained)."""
    from repro.models import dit as dit_mod
    key = jax.random.PRNGKey(0)
    params = dit_mod.init_dit(tiny_dit_cfg, key)
    params["deembed"]["w_flex"] = jax.random.normal(
        jax.random.fold_in(key, 1), params["deembed"]["w_flex"].shape) * 0.1
    params["final"]["ada"]["w"] = jax.random.normal(
        jax.random.fold_in(key, 2), params["final"]["ada"]["w"].shape) * 0.05
    params["blocks"]["ada"]["w"] = jax.random.normal(
        jax.random.fold_in(key, 3), params["blocks"]["ada"]["w"].shape) * 0.05
    return params
