"""Inference scheduler FLOPs accounting (§3.3) + guidance math (§3.4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FlexiSchedule, GuidanceConfig, dit_nfe_flops,
                        flexify, make_eps_fn, relative_compute)
from repro.core.guidance import SCALE_RULE
from repro.models import dit as dit_mod

pytestmark = pytest.mark.tier1


def test_weak_nfe_much_cheaper(tiny_dit_cfg):
    _, fcfg = flexify(dit_mod.init_dit(tiny_dit_cfg, jax.random.PRNGKey(0)),
                      tiny_dit_cfg, [(1, 4, 4)])
    f0 = dit_nfe_flops(fcfg, 0)
    f1 = dit_nfe_flops(fcfg, 1)
    # 4× fewer tokens ⇒ > 4× fewer FLOPs (paper §3.3: "compute required for
    # the powerful model is > 4× compared to the weak model")
    assert f0 / f1 > 4.0, f0 / f1


def test_relative_compute_monotone(tiny_dit_cfg):
    _, fcfg = flexify(dit_mod.init_dit(tiny_dit_cfg, jax.random.PRNGKey(0)),
                      tiny_dit_cfg, [(1, 4, 4)])
    T = 20
    fracs = [relative_compute(fcfg, FlexiSchedule.weak_first(T, w))
             for w in range(0, T + 1, 5)]
    assert fracs[0] == pytest.approx(1.0)
    assert all(a > b for a, b in zip(fracs, fracs[1:]))
    # >40% savings at 60% weak steps (paper Fig. 6 regime)
    assert relative_compute(fcfg, FlexiSchedule.weak_first(T, 12)) < 0.6


def test_schedule_split():
    ts = np.arange(19, -1, -1)
    fs = FlexiSchedule.weak_first(20, 12)
    phases = fs.split_timesteps(ts)
    assert phases[0][0] == 1 and len(phases[0][1]) == 12
    assert phases[1][0] == 0 and len(phases[1][1]) == 8
    assert np.concatenate([p[1] for p in phases]).tolist() == ts.tolist()


def test_scale_rule():
    g = GuidanceConfig(scale=4.5, mode_cond=0, mode_uncond=1, kind="weak_cond")
    s2 = g.effective_scale()
    assert (1 - 4.5) / (1 - s2) == pytest.approx(SCALE_RULE)


def test_vanilla_cfg_identity(tiny_dit_cfg, trained_like_dit):
    """eps_cfg == e_u + s·(e_c − e_u) computed by hand."""
    cfg = tiny_dit_cfg
    params = trained_like_dit
    B = 2
    x = jax.random.normal(jax.random.PRNGKey(3), (B, 1, 16, 16, 4))
    t = jnp.asarray([5.0, 50.0])
    y = jnp.asarray([1, 2])
    null = jnp.asarray([10, 10])
    g = GuidanceConfig(scale=3.0, mode_cond=0, mode_uncond=0, kind="uncond")
    eps_fn = make_eps_fn(params, cfg, y, null, g)
    got, _ = eps_fn(x, t)
    e_c = dit_mod.eps_prediction(dit_mod.dit_forward(params, x, t, y, cfg), cfg)
    e_u = dit_mod.eps_prediction(dit_mod.dit_forward(params, x, t, null, cfg), cfg)
    want = e_u + 3.0 * (e_c - e_u)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)


def test_weak_guidance_uses_conditional(tiny_dit_cfg, trained_like_dit):
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    B = 2
    x = jax.random.normal(jax.random.PRNGKey(4), (B, 1, 16, 16, 4))
    t = jnp.asarray([5.0, 50.0])
    y = jnp.asarray([1, 2])
    null = jnp.asarray([10, 10])
    g = GuidanceConfig(scale=3.0, mode_cond=0, mode_uncond=1, kind="weak_cond")
    got, _ = eps = make_eps_fn(fparams, fcfg, y, null, g)(x, t)
    e_c = dit_mod.eps_prediction(
        dit_mod.dit_forward(fparams, x, t, y, fcfg, mode=0), fcfg)
    e_w = dit_mod.eps_prediction(
        dit_mod.dit_forward(fparams, x, t, y, fcfg, mode=1), fcfg)
    s2 = g.effective_scale()
    want = e_w + s2 * (e_c - e_w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-4, rtol=1e-4)
