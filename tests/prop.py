"""Tiny property-based testing shim (hypothesis is unavailable offline).

``@given(strategy_fn, n=20)`` runs the test across n seeded random draws and
reports the failing draw's seed + value for reproduction.
"""
from __future__ import annotations

import functools
import inspect
from typing import Any, Callable

import numpy as np


def given(strategy: Callable[[np.random.Generator], Any], n: int = 20,
          seed: int = 0):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            for i in range(n):
                rng = np.random.default_rng(seed * 7919 + i)
                value = strategy(rng)
                try:
                    fn(*args, value, **kwargs)
                except AssertionError as e:
                    raise AssertionError(
                        f"property failed on draw #{i} (seed={seed * 7919 + i}): "
                        f"value={value!r}\n{e}") from e
        # hide the injected (last) parameter from pytest's fixture resolver
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())[:-1]
        wrapper.__signature__ = sig.replace(parameters=params)
        return wrapper
    return deco


# -- shared strategies -------------------------------------------------------

def patch_pairs(rng: np.random.Generator):
    opts = [((1, 2, 2), (1, 4, 4)), ((1, 2, 2), (2, 4, 4)),
            ((1, 4, 4), (1, 8, 8)), ((2, 2, 2), (2, 4, 4)),
            ((1, 2, 2), (1, 8, 8)), ((1, 1, 1), (1, 4, 4))]
    return opts[rng.integers(len(opts))]


def attn_shapes(rng: np.random.Generator):
    hd = int(rng.choice([32, 64, 128]))
    K = int(rng.choice([1, 2, 4]))
    G = int(rng.choice([1, 2]))
    S = int(rng.choice([128, 256]))
    B = int(rng.integers(1, 3))
    return B, S, K * G, K, hd


def ssd_shapes(rng: np.random.Generator):
    return (int(rng.integers(1, 3)), int(rng.choice([32, 64, 96])),
            int(rng.choice([2, 4])), int(rng.choice([8, 16, 32])),
            int(rng.choice([8, 16])), int(rng.choice([16, 32])))
