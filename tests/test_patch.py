"""Tokenization: patchify/unpatchify and shared-coordinate pos embeds."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import patch


def test_patchify_roundtrip():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 4, 8, 8, 3))
    for p in [(1, 2, 2), (2, 4, 4), (1, 4, 4), (4, 8, 8), (1, 1, 1)]:
        t = patch.patchify(x, p)
        assert t.shape[1] == patch.num_tokens(x.shape[1:], p)
        x2 = patch.unpatchify(t, x.shape[1:], p)
        np.testing.assert_allclose(np.asarray(x), np.asarray(x2), atol=1e-6)


def test_patch_centers_shared_coordinate_frame():
    """Weak-mode patch centers are the mean of the powerful-mode centers they
    cover (paper: positions identified in original-image coordinates)."""
    ls = (1, 8, 8, 4)
    c2 = patch.patch_centers(ls, (1, 2, 2)).reshape(4, 4, 3)
    c4 = patch.patch_centers(ls, (1, 4, 4)).reshape(2, 2, 3)
    block = c2[:2, :2].reshape(-1, 3).mean(0)
    np.testing.assert_allclose(c4[0, 0], block, atol=1e-6)


def test_sincos_posembed_scales_with_coords():
    ls = (1, 16, 16, 4)
    e2 = patch.sincos_pos_embed(64, patch.patch_centers(ls, (1, 2, 2)))
    e4 = patch.sincos_pos_embed(64, patch.patch_centers(ls, (1, 4, 4)))
    assert e2.shape == (64, 64) and e4.shape == (16, 64)
    assert np.isfinite(e2).all() and np.isfinite(e4).all()
    # distinct positions get distinct embeddings
    assert np.unique(np.round(e2, 5), axis=0).shape[0] == e2.shape[0]


def test_embed_deembed_shapes():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (2, 1, 16, 16, 4))
    w = jax.random.normal(key, (16, 4, 32))
    tok = patch.embed_tokens_flex(w, jnp.zeros(32), x, (1, 2, 2), (1, 4, 4))
    assert tok.shape == (2, 64, 32)
    wd = jax.random.normal(key, (32, 8, 16))
    bd = jnp.zeros((8, 16))
    out = patch.deembed_tokens_flex(wd, bd, tok, (1, 16, 16, 4), (1, 2, 2),
                                    (1, 4, 4), 8)
    assert out.shape == (2, 1, 16, 16, 8)
