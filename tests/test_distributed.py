"""Distributed inference engine (DESIGN.md §distributed).

Two-process layout: the normal test run sees 1 device, so the inner tests
skip and ``test_distributed_suite_on_fake_devices`` re-launches this file
in a subprocess with ``REPRO_FAKE_DEVICES=8`` (honored by the conftest
env guard before jax initializes). Inside that subprocess the launcher
skips and the real suite runs on 8 fake CPU devices:

* sharded vs single-device equivalence per solver (static + flow);
* re-shard at the weak→powerful phase boundary, incl. pad-to-divisible;
* zero recompiles across budget switches on a fixed mesh;
* ring vs Ulysses agreement;
* the serving driver end-to-end on a mesh.

Partition/cost arithmetic tests are pure host python and run everywhere.
"""
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flexify
from repro.core.scheduler import FlexiSchedule, dit_nfe_flops
from repro.diffusion import schedule as sch
from repro.distributed import (ParallelSpec, mesh_fingerprint,
                               mode_partition, padded_tokens, plan_partition,
                               resolve_impl)
from repro.pipeline import FlexiPipeline, SamplingPlan

pytestmark = pytest.mark.tier1

MULTI = jax.device_count() >= 8
T = 6
N = 4
TOL = 1e-4


# ---------------------------------------------------------------------------
# Outer launcher (runs in the normal 1-device session)


@pytest.mark.skipif(MULTI, reason="already inside the fake-device subprocess")
def test_distributed_suite_on_fake_devices():
    """Spawn the 8-fake-device subprocess that runs the real suite below."""
    env = dict(os.environ, REPRO_FAKE_DEVICES="8")
    env.pop("XLA_FLAGS", None)
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", "-p", "no:cacheprovider",
         str(Path(__file__).resolve())],
        env=env, capture_output=True, text=True, timeout=1800,
        cwd=str(Path(__file__).resolve().parents[1]))
    tail = (r.stdout or "")[-4000:] + "\n" + (r.stderr or "")[-2000:]
    assert r.returncode == 0, f"inner distributed suite failed:\n{tail}"
    assert "passed" in r.stdout, tail


# ---------------------------------------------------------------------------
# Inner suite (8 fake devices)

needs_devices = pytest.mark.skipif(
    not MULTI, reason="runs inside the REPRO_FAKE_DEVICES=8 subprocess")


@pytest.fixture(scope="module")
def flexi(tiny_dit_cfg, trained_like_dit):
    # two weak modes: (1,4,4) → 16 tokens, (1,8,8) → 4 tokens (pads on an
    # 8-way sequence axis) over the 64-token powerful sequence
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg,
                            [(1, 4, 4), (1, 8, 8)])
    return fparams, fcfg, sch.linear_schedule(100)


@pytest.fixture(scope="module")
def mesh24(eight_fake_devices):
    return jax.make_mesh((2, 4), ("data", "seq"))


@pytest.fixture(scope="module")
def mesh18(eight_fake_devices):
    return jax.make_mesh((1, 8), ("data", "seq"))


@pytest.fixture(scope="module")
def single(flexi):
    fparams, fcfg, sched = flexi
    return FlexiPipeline(fparams, fcfg, sched)


@needs_devices
@pytest.mark.parametrize("solver,scale",
                         [("ddim", 1.5), ("ddpm", 1.5), ("dpm2", 1.5),
                          ("flow_euler", 0.0)])
def test_sharded_matches_single_device(flexi, single, mesh24, solver, scale):
    """Ulysses on a (2 data × 4 seq) mesh reproduces the single-device
    sample for every solver (acceptance: ≤1e-4 max abs diff)."""
    fparams, fcfg, sched = flexi
    pipe = FlexiPipeline(fparams, fcfg, sched, mesh=mesh24)
    key = jax.random.PRNGKey(42)
    kw = dict(T=T, budget=0.6, solver=solver, guidance_scale=scale)
    r0 = single.sample(SamplingPlan(**kw), N, key)
    r1 = pipe.sample(SamplingPlan(parallel=ParallelSpec(attn="ulysses"),
                                  **kw), N, key)
    np.testing.assert_allclose(np.asarray(r1.x0), np.asarray(r0.x0),
                               atol=TOL, rtol=0)
    # the analytic ledger is sharding-agnostic
    assert r1.flops == pytest.approx(r0.flops)
    assert r1.relative_compute == pytest.approx(r0.relative_compute)


@needs_devices
def test_phase_boundary_reshard_with_padding(flexi, single, mesh18):
    """Weak mode (1,8,8) has 4 tokens on an 8-way axis → padded to 8, then
    re-sharded to the 64-token powerful phase at the boundary."""
    fparams, fcfg, sched = flexi
    pipe = FlexiPipeline(fparams, fcfg, sched, mesh=mesh18)
    fs = FlexiSchedule(((2, 3), (0, T - 3)))
    key = jax.random.PRNGKey(5)
    plan = SamplingPlan(T=T, budget=fs, guidance_scale=1.5,
                        parallel=ParallelSpec())       # auto → ring (4 heads)
    r0 = single.sample(SamplingPlan(T=T, budget=fs, guidance_scale=1.5),
                       N, key)
    r1 = pipe.sample(plan, N, key)
    np.testing.assert_allclose(np.asarray(r1.x0), np.asarray(r0.x0),
                               atol=TOL, rtol=0)
    part = plan_partition(fcfg, fs, 8, plan.parallel)
    assert [p.pad for p, _ in part.phases] == [4, 0]
    assert part.reshard_boundaries == (3,)


@needs_devices
def test_ring_matches_ulysses(flexi, mesh24):
    fparams, fcfg, sched = flexi
    pipe = FlexiPipeline(fparams, fcfg, sched, mesh=mesh24)
    key = jax.random.PRNGKey(6)
    kw = dict(T=T, budget=0.6, guidance_scale=1.5)
    r_u = pipe.sample(SamplingPlan(parallel=ParallelSpec(attn="ulysses"),
                                   **kw), N, key)
    r_r = pipe.sample(SamplingPlan(parallel=ParallelSpec(attn="ring"),
                                   **kw), N, key)
    np.testing.assert_allclose(np.asarray(r_r.x0), np.asarray(r_u.x0),
                               atol=TOL, rtol=0)


@needs_devices
def test_budget_switch_fixed_mesh_never_recompiles(flexi, mesh24):
    fparams, fcfg, sched = flexi
    pipe = FlexiPipeline(fparams, fcfg, sched, mesh=mesh24)
    key = jax.random.PRNGKey(7)
    plans = [SamplingPlan(T=T, budget=b, guidance_scale=1.5,
                          parallel=ParallelSpec(attn="ulysses"))
             for b in (0.6, 1.0)]
    for p in plans:
        pipe.sample(p, N, key)
    base = pipe.cache_stats()
    for i in range(4):
        pipe.sample(plans[i % 2], N, jax.random.fold_in(key, i))
    stats = pipe.cache_stats()
    assert stats["compiled"] == base["compiled"]
    assert stats["misses"] == base["misses"]
    assert stats["hits"] == base["hits"] + 4


@needs_devices
def test_mesh_switch_compiles_separate_runners(flexi, mesh24, mesh18):
    """Same plan on two meshes → two runners (fingerprint in the key);
    going back to the first mesh is a cache hit."""
    fparams, fcfg, sched = flexi
    pipe = FlexiPipeline(fparams, fcfg, sched, mesh=mesh24)
    key = jax.random.PRNGKey(8)
    plan = SamplingPlan(T=T, budget=0.6, guidance_scale=1.5,
                        parallel=ParallelSpec(attn="ring"))
    pipe.sample(plan, N, key)
    one = pipe.cache_stats()["runners"]
    pipe.set_mesh(mesh18)
    pipe.sample(plan, N, key)
    assert pipe.cache_stats()["runners"] == one + 1
    pipe.set_mesh(mesh24)
    hits = pipe.cache_stats()["hits"]
    pipe.sample(plan, N, key)
    assert pipe.cache_stats()["runners"] == one + 1
    assert pipe.cache_stats()["hits"] == hits + 1


@needs_devices
def test_ulysses_requires_dividing_heads(flexi, mesh18):
    """4 heads on an 8-way axis: explicit ulysses errors eagerly, auto
    falls back to ring."""
    fparams, fcfg, sched = flexi
    pipe = FlexiPipeline(fparams, fcfg, sched, mesh=mesh18)
    plan = SamplingPlan(T=T, budget=0.6, guidance_scale=1.5,
                        parallel=ParallelSpec(attn="ulysses"))
    with pytest.raises(ValueError, match="divisible"):
        pipe.sample(plan, N, jax.random.PRNGKey(0))
    assert resolve_impl(fcfg, ParallelSpec(), 8) == "ring"
    assert resolve_impl(fcfg, ParallelSpec(), 4) == "ulysses"


@needs_devices
def test_missing_mesh_and_missing_axis_error(flexi, single, mesh24):
    fparams, fcfg, sched = flexi
    plan = SamplingPlan(T=T, budget=0.6, guidance_scale=1.5,
                        parallel=ParallelSpec())
    with pytest.raises(ValueError, match="mesh"):
        single.sample(plan, N, jax.random.PRNGKey(0))
    pipe = FlexiPipeline(fparams, fcfg, sched, mesh=mesh24)
    bad = SamplingPlan(T=T, budget=0.6, guidance_scale=1.5,
                       parallel=ParallelSpec(axis="ctx"))
    with pytest.raises(ValueError, match="no 'ctx' axis"):
        pipe.sample(bad, N, jax.random.PRNGKey(0))


@needs_devices
def test_serve_dit_on_mesh_smoke(capsys):
    import argparse
    from repro.configs import get_config
    from repro.launch.serve import serve_dit
    args = argparse.Namespace(budget=0.6, T=4, train_T=100, solver="ddim",
                              cfg_scale=1.5, requests=4, batch_slots=2,
                              mesh="2x4", budget_levels="0.6,1.0")
    serve_dit(get_config("dit-xl-2").reduced(), args)
    out = capsys.readouterr().out
    assert "served 4 requests" in out
    assert "[mesh] data=2 seq=4" in out
    assert "[shard]" in out


# ---------------------------------------------------------------------------
# Partition / cost arithmetic (host-only, runs in every session)


def test_parallel_spec_validation():
    with pytest.raises(ValueError, match="attn"):
        ParallelSpec(attn="pipefusion")
    with pytest.raises(ValueError, match="axis"):
        ParallelSpec(axis="")
    with pytest.raises(ValueError, match="adaptive"):
        from repro.pipeline import AdaptiveBudget
        SamplingPlan(T=T, budget=AdaptiveBudget(), parallel=ParallelSpec())
    with pytest.raises(ValueError, match="ParallelSpec"):
        SamplingPlan(T=T, parallel="seq")          # type: ignore[arg-type]


def test_padded_tokens_and_mode_partition(tiny_dit_cfg, trained_like_dit):
    assert padded_tokens(4, 8) == 8
    assert padded_tokens(16, 8) == 16
    assert padded_tokens(17, 8) == 24
    _, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4), (1, 8, 8)])
    p = mode_partition(fcfg, 2, 8)                 # 4 tokens on 8 shards
    assert (p.tokens, p.tokens_padded, p.pad, p.shard_tokens) == (4, 8, 4, 1)
    assert p.impl == "ring"                        # 4 heads % 8 != 0
    assert p.pad_flops_per_nfe(fcfg) > 0
    p0 = mode_partition(fcfg, 0, 4)                # 64 tokens, 4 shards
    assert p0.pad == 0 and p0.impl == "ulysses"
    assert p0.pad_flops_per_nfe(fcfg) == 0.0


def test_partition_plan_costs(tiny_dit_cfg, trained_like_dit):
    _, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4), (1, 8, 8)])
    fs = FlexiSchedule(((2, 3), (0, 3)))
    part = plan_partition(fcfg, fs, 8)
    # ulysses is impossible at sp=8 here (ring): every shard sends its K+V
    # chunk (sp-1) times per layer → L·2·(sp-1)·chunk·d·4·sp bytes total
    L, d = fcfg.num_layers, fcfg.d_model
    weak, pow_ = part.phases[0][0], part.phases[1][0]
    assert weak.collective_bytes_per_nfe(fcfg) == \
        L * 2 * 7 * (8 // 8) * d * 4 * 8
    assert pow_.collective_bytes_per_nfe(fcfg) == \
        L * 2 * 7 * (64 // 8) * d * 4 * 8
    # CFG doubles the bytes; 3 steps per phase
    assert part.collective_bytes(fcfg) == pytest.approx(
        2 * 3 * (weak.collective_bytes_per_nfe(fcfg)
                 + pow_.collective_bytes_per_nfe(fcfg)))
    # padding waste shows up in efficiency < 1 and in pad_flops
    assert part.parallel_efficiency(fcfg) < 1.0
    assert part.pad_flops(fcfg) > 0
    # ulysses bytes formula on the 4-way mesh
    part4 = plan_partition(fcfg, fs, 4)
    w4 = part4.phases[1][0]
    assert w4.impl == "ulysses"
    assert w4.collective_bytes_per_nfe(fcfg) == L * 4 * (64 * d * 4 * 3 / 4)
    # no parallelism → no collectives, no padding
    part1 = plan_partition(fcfg, fs, 1)
    assert part1.collective_bytes(fcfg) == 0.0
    assert part1.parallel_efficiency(fcfg) == 1.0


def test_mesh_fingerprint_host_only():
    assert mesh_fingerprint(None) is None
    mesh = jax.make_mesh((1, 1), ("data", "seq"))
    fp1 = mesh_fingerprint(mesh)
    fp2 = mesh_fingerprint(jax.make_mesh((1, 1), ("data", "seq")))
    assert fp1 == fp2                      # same layout → same runners
    assert fp1 != mesh_fingerprint(jax.make_mesh((1, 1), ("data", "model")))
