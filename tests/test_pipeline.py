"""Unified pipeline API: plan validation + FLOPs golden tests, budget
solving, baseline equivalence, compile-once cache behaviour, the adaptive
path's FLOPs ledger, and the DiT serving driver (DESIGN.md §pipeline)."""
import argparse

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FlexiSchedule, GuidanceConfig, flexify, make_eps_fn,
                        relative_compute, schedule_flops)
from repro.core.scheduler import dit_nfe_flops, lora_nfe_overhead
from repro.diffusion import sampler, schedule as sch
from repro.pipeline import (AdaptiveBudget, FlexiPipeline, SamplingPlan,
                            solve_t_weak)

pytestmark = pytest.mark.tier1

T = 10
N = 4


@pytest.fixture(scope="module")
def flexi(tiny_dit_cfg, trained_like_dit):
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    return fparams, fcfg, sch.linear_schedule(100)


@pytest.fixture(scope="module")
def pipe(flexi):
    fparams, fcfg, sched = flexi
    return FlexiPipeline(fparams, fcfg, sched)


# ---------------------------------------------------------------------------
# SamplingPlan: FLOPs golden tests + budget solving


def test_plan_flops_matches_schedule_flops(flexi):
    _, fcfg, _ = flexi
    fs = FlexiSchedule.weak_first(T, 6)
    plan = SamplingPlan(T=T, budget=fs, guidance_scale=1.5)
    assert plan.flops(fcfg) == pytest.approx(
        schedule_flops(fcfg, fs, cfg_scale_active=True))
    assert plan.relative_compute(fcfg) == pytest.approx(
        relative_compute(fcfg, fs))
    # unguided: one NFE per step
    plain = SamplingPlan(T=T, budget=fs, guidance_scale=0.0)
    assert plain.flops(fcfg) == pytest.approx(
        schedule_flops(fcfg, fs, cfg_scale_active=False))
    # batch scaling
    assert plan.flops(fcfg, batch=7) == pytest.approx(7 * plan.flops(fcfg))
    # 2nd-order solvers evaluate the model twice per step
    dpm2 = SamplingPlan(T=T, budget=fs, guidance_scale=1.5, solver="dpm2")
    assert dpm2.flops(fcfg) == pytest.approx(2 * plan.flops(fcfg))


def test_plan_flops_unmerged_lora(tiny_dit_cfg, trained_like_dit):
    _, lcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)],
                      lora_rank=4)
    fs = FlexiSchedule.weak_first(T, 6)
    merged = SamplingPlan(T=T, budget=fs, guidance_scale=1.5, lora="merged")
    unmerged = SamplingPlan(T=T, budget=fs, guidance_scale=1.5,
                            lora="unmerged")
    assert unmerged.flops(lcfg) == pytest.approx(
        schedule_flops(lcfg, fs, cfg_scale_active=True, lora_unmerged=True))
    overhead = unmerged.flops(lcfg) - merged.flops(lcfg)
    # 6 weak guided steps → 12 weak NFEs paying the adapter overhead
    assert overhead == pytest.approx(12 * lora_nfe_overhead(lcfg, 1))


def test_fraction_budget_solves_cheapest_t_weak(flexi):
    _, fcfg, _ = flexi
    target = 0.6
    plan = SamplingPlan(T=T, budget=target, guidance_scale=1.5)
    fs = plan.resolve_schedule(fcfg)
    t_weak = fs.phases[0][1]
    assert relative_compute(fcfg, fs) <= target
    # fewest weak steps: one step fewer must miss the target
    assert t_weak >= 1
    assert relative_compute(
        fcfg, FlexiSchedule.weak_first(T, t_weak - 1)) > target
    assert solve_t_weak(fcfg, T, target) == t_weak
    # trivial budgets
    assert SamplingPlan(T=T, budget=1.0).resolve_schedule(fcfg).phases[0][1] == 0
    # impossible budgets are rejected up front
    with pytest.raises(ValueError, match="floor"):
        SamplingPlan(T=T, budget=0.05).validate(fcfg)


def test_plan_validation_errors(flexi):
    _, fcfg, _ = flexi
    with pytest.raises(ValueError, match="solver"):
        SamplingPlan(T=T, solver="euler")
    with pytest.raises(ValueError, match="fraction"):
        SamplingPlan(T=T, budget=1.5)
    with pytest.raises(ValueError, match="covers"):
        SamplingPlan(T=T, budget=FlexiSchedule.weak_first(T + 2, 1))
    with pytest.raises(ValueError, match="adaptive"):
        SamplingPlan(T=T, budget=AdaptiveBudget(), solver="dpm2")
    with pytest.raises(ValueError, match="unguided"):
        SamplingPlan(T=T, solver="flow_euler", guidance_scale=1.5)
    with pytest.raises(ValueError, match="modes"):
        SamplingPlan(T=T, weak_mode=3).validate(fcfg)
    with pytest.raises(ValueError, match="LoRA"):
        SamplingPlan(T=T, lora="unmerged").validate(fcfg)


# ---------------------------------------------------------------------------
# FlexiPipeline: baseline equivalence + compile-once cache


def test_t_weak_zero_matches_all_powerful_baseline(flexi, pipe):
    """budget=1.0 (→ T_weak=0) must reproduce the hand-wired all-powerful
    CFG run bit-for-bit (same key derivation)."""
    fparams, fcfg, sched = flexi
    key = jax.random.PRNGKey(42)
    res = pipe.sample(SamplingPlan(T=T, budget=1.0, guidance_scale=1.5,
                                   solver="ddim"), N, key)
    # manual wiring (the pre-pipeline call-site pattern)
    ts = sch.respaced_timesteps(sched.num_steps, T)
    y = jnp.arange(N) % fcfg.dit.num_classes
    null = jnp.full((N,), fcfg.dit.num_classes)
    g = GuidanceConfig(scale=1.5, mode_cond=0, mode_uncond=0)
    eps_fn = make_eps_fn(fparams, fcfg, y, null, g)
    x_T = jax.random.normal(key, (N,) + fcfg.dit.latent_shape)
    want = sampler.sample_phased([(eps_fn, ts)], sched, x_T,
                                 jax.random.fold_in(key, 1), solver="ddim")
    np.testing.assert_allclose(np.asarray(res.x0), np.asarray(want),
                               atol=1e-5, rtol=1e-5)
    assert res.relative_compute == pytest.approx(1.0)


def test_repeat_and_mode_switch_never_recompile(pipe):
    key = jax.random.PRNGKey(0)
    plan_a = SamplingPlan(T=T, budget=1.0, guidance_scale=1.5)
    plan_b = SamplingPlan(T=T, budget=0.6, guidance_scale=1.5)
    pipe.sample(plan_a, N, key)
    base = pipe.cache_stats()
    # same plan, same batch shape → pure cache hit, zero new compilations
    pipe.sample(plan_a, N, jax.random.PRNGKey(1))
    s = pipe.cache_stats()
    assert s["compiled"] == base["compiled"]
    assert s["misses"] == base["misses"]
    assert s["hits"] == base["hits"] + 1
    # budget switch compiles its own runner ONCE...
    pipe.sample(plan_b, N, key)
    s2 = pipe.cache_stats()
    assert s2["compiled"] == base["compiled"] + 1
    # ...and switching back and forth stays compile-free
    pipe.sample(plan_a, N, key)
    pipe.sample(plan_b, N, key)
    assert pipe.cache_stats()["compiled"] == s2["compiled"]


def test_update_params_keeps_compiled_runners(flexi, pipe):
    fparams, _, _ = flexi
    key = jax.random.PRNGKey(3)
    plan = SamplingPlan(T=T, budget=1.0, guidance_scale=1.5)
    pipe.sample(plan, N, key)
    before = pipe.cache_stats()["compiled"]
    bumped = jax.tree.map(lambda x: x * 1.001, fparams)
    pipe.update_params(bumped)
    out = pipe.sample(plan, N, key)
    assert np.isfinite(np.asarray(out.x0)).all()
    assert pipe.cache_stats()["compiled"] == before
    pipe.update_params(fparams)


def test_weak_guidance_plan(flexi, pipe):
    """§3.4 weak-model guidance routes through the pipeline."""
    _, fcfg, _ = flexi
    fs = FlexiSchedule.weak_first(T, 6)
    plan = SamplingPlan(T=T, budget=fs, guidance_scale=1.5,
                        guidance_kind="weak_cond")
    res = pipe.sample(plan, N, jax.random.PRNGKey(5))
    assert np.isfinite(np.asarray(res.x0)).all()
    # the powerful phase's guidance NFE runs at the weak mode → cheaper
    # than vanilla CFG on the same schedule
    vanilla = SamplingPlan(T=T, budget=fs, guidance_scale=1.5)
    assert plan.flops(fcfg) < vanilla.flops(fcfg)


def test_flow_solver_plan(pipe):
    fs = FlexiSchedule.weak_first(T, 5)
    plan = SamplingPlan(T=T, budget=fs, solver="flow_euler",
                        guidance_scale=0.0)
    res = pipe.sample(plan, N, jax.random.PRNGKey(6))
    assert res.x0.shape == (N,) + pipe.cfg.dit.latent_shape
    assert np.isfinite(np.asarray(res.x0)).all()


# ---------------------------------------------------------------------------
# Adaptive plans


def test_adaptive_flops_ledger(flexi, pipe):
    """Guided NFEs cost 2 NFEs each; probes are reused, not recomputed."""
    _, fcfg, _ = flexi
    B = 2
    key = jax.random.PRNGKey(7)
    f_w = 2.0 * dit_nfe_flops(fcfg, 1)      # CFG multiplier
    f_p = 2.0 * dit_nfe_flops(fcfg, 0)
    # threshold 0 → first probe switches → 1 weak + 1 powerful probe NFE,
    # then T powerful steps
    plan0 = SamplingPlan(T=T, budget=AdaptiveBudget(threshold=0.0),
                         guidance_scale=1.5)
    r0 = pipe.sample(plan0, B, key)
    assert r0.trace["switch_step"] == 0
    assert r0.flops == pytest.approx(B * (f_w + f_p + T * f_p))
    assert r0.trace["flops_static_powerful"] == pytest.approx(B * T * f_p)
    # threshold ∞ → never switches: T weak steps + ceil(T/2) probes, and
    # every probe's weak ε is REUSED for its step (no extra weak NFEs)
    plan_inf = SamplingPlan(T=T, budget=AdaptiveBudget(threshold=1e9,
                                                       probe_every=2),
                            guidance_scale=1.5)
    r_inf = pipe.sample(plan_inf, B, key)
    assert r_inf.trace["switch_step"] == T
    n_probes = len(range(0, T, 2))
    assert r_inf.flops == pytest.approx(B * (T * f_w + n_probes * f_p))
    assert r_inf.relative_compute < 1.0
    assert np.isfinite(np.asarray(r_inf.x0)).all()
    assert len(r_inf.trace["gaps"]) == n_probes


def test_adaptive_worst_case_bound(flexi):
    _, fcfg, _ = flexi
    plan = SamplingPlan(T=T, budget=AdaptiveBudget(threshold=1e9),
                        guidance_scale=1.5)
    # plan.flops is the never-switch worst case = the actual spend above
    f_w = 2.0 * dit_nfe_flops(fcfg, 1)
    f_p = 2.0 * dit_nfe_flops(fcfg, 0)
    assert plan.flops(fcfg) == pytest.approx(T * f_w + 5 * f_p)


# ---------------------------------------------------------------------------
# LoRA variants through the pipeline


def test_lora_merged_matches_unmerged_sampling(tiny_dit_cfg,
                                               trained_like_dit):
    lparams, lcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)],
                            lora_rank=4)
    # give the adapters non-zero effect so the equivalence is non-trivial
    lora = lparams["blocks"]["lora"]
    lora["attn"]["wq"]["b"] = 0.02 * jax.random.normal(
        jax.random.PRNGKey(8), lora["attn"]["wq"]["b"].shape)
    p = FlexiPipeline(lparams, lcfg, sch.linear_schedule(100))
    fs = FlexiSchedule.weak_first(T, 6)
    key = jax.random.PRNGKey(9)
    r_un = p.sample(SamplingPlan(T=T, budget=fs, guidance_scale=1.5,
                                 lora="unmerged"), N, key)
    r_me = p.sample(SamplingPlan(T=T, budget=fs, guidance_scale=1.5,
                                 lora="merged"), N, key)
    np.testing.assert_allclose(np.asarray(r_un.x0), np.asarray(r_me.x0),
                               atol=1e-4, rtol=1e-4)
    assert r_un.flops > r_me.flops        # unmerged pays the adapter FLOPs


def test_lora_merged_weak_guidance_nfe(tiny_dit_cfg, trained_like_dit):
    """§3.4 weak-model guidance under merged LoRA: the guidance NFE must
    see the merged weak-mode weights (same result as unmerged, and the
    analytic ledger's merged-⇒-no-overhead promise holds at runtime)."""
    lparams, lcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)],
                            lora_rank=4)
    lora = lparams["blocks"]["lora"]
    lora["mlp"]["w_in"]["b"] = 0.02 * jax.random.normal(
        jax.random.PRNGKey(12), lora["mlp"]["w_in"]["b"].shape)
    p = FlexiPipeline(lparams, lcfg, sch.linear_schedule(100))
    fs = FlexiSchedule.weak_first(T, 4)
    key = jax.random.PRNGKey(13)
    r_un = p.sample(SamplingPlan(T=T, budget=fs, guidance_scale=1.5,
                                 guidance_kind="weak_cond",
                                 lora="unmerged"), N, key)
    r_me = p.sample(SamplingPlan(T=T, budget=fs, guidance_scale=1.5,
                                 guidance_kind="weak_cond",
                                 lora="merged"), N, key)
    np.testing.assert_allclose(np.asarray(r_un.x0), np.asarray(r_me.x0),
                               atol=1e-4, rtol=1e-4)


def test_adaptive_unmerged_lora_ledger(tiny_dit_cfg, trained_like_dit):
    """Adaptive plans on unmerged LoRA count the adapter FLOPs per weak NFE."""
    lparams, lcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)],
                            lora_rank=4)
    p = FlexiPipeline(lparams, lcfg, sch.linear_schedule(100))
    plan = SamplingPlan(T=T, budget=AdaptiveBudget(threshold=1e9,
                                                   probe_every=2),
                        guidance_scale=1.5, lora="unmerged")
    r = p.sample(plan, 2, jax.random.PRNGKey(14))
    f_w = 2.0 * (dit_nfe_flops(lcfg, 1) + lora_nfe_overhead(lcfg, 1))
    f_p = 2.0 * dit_nfe_flops(lcfg, 0)
    assert r.flops == pytest.approx(2 * (T * f_w + 5 * f_p))
    assert r.flops == pytest.approx(plan.flops(lcfg, batch=2))


def test_lora_merged_per_phase_mode(tiny_dit_cfg, trained_like_dit):
    """A schedule using a weak mode other than plan.weak_mode must merge
    THAT mode's adapters (regression: all weak phases used to get the
    plan.weak_mode merge)."""
    lparams, lcfg = flexify(trained_like_dit, tiny_dit_cfg,
                            [(1, 4, 4), (1, 8, 8)], lora_rank=4)
    lora = lparams["blocks"]["lora"]
    lora["attn"]["wq"]["b"] = 0.02 * jax.random.normal(
        jax.random.PRNGKey(10), lora["attn"]["wq"]["b"].shape)
    p = FlexiPipeline(lparams, lcfg, sch.linear_schedule(100))
    fs = FlexiSchedule(((2, 4), (0, T - 4)))     # weak phase at mode 2
    key = jax.random.PRNGKey(11)
    r_un = p.sample(SamplingPlan(T=T, budget=fs, guidance_scale=1.5,
                                 lora="unmerged"), N, key)
    r_me = p.sample(SamplingPlan(T=T, budget=fs, guidance_scale=1.5,
                                 lora="merged"), N, key)
    np.testing.assert_allclose(np.asarray(r_un.x0), np.asarray(r_me.x0),
                               atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# Serving driver


def test_serve_dit_smoke(capsys):
    """The DiT serving driver now runs the continuous-batching engine:
    two identical waves (warmup + steady state) of --requests each."""
    from repro.configs import get_config
    from repro.launch.serve import serve_dit
    args = argparse.Namespace(budget=0.6, T=6, train_T=100, solver="ddim",
                              cfg_scale=1.5, requests=3, batch_slots=2,
                              budget_levels="0.6,1.0")
    serve_dit(get_config("dit-xl-2").reduced(), args)
    out = capsys.readouterr().out
    assert "served 6 requests" in out
    assert "[metrics]" in out
    assert "[cache]" in out
