"""End-to-end system behaviour: the full FlexiDiT pipeline — pre-train a
tiny DiT on synthetic data, flexify it, fine-tune, and sample with the
weak→powerful inference scheduler; plus the paper's Fig. 4 claim (weak vs
powerful prediction gap shrinks at early/noisy timesteps).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import AttnConfig, DiTConfig, ModelConfig, TrainConfig
from repro.core import (FlexiSchedule, GuidanceConfig, flexify, make_eps_fn,
                        relative_compute)
from repro.data import pipeline as dp
from repro.diffusion import sampler, schedule as sch
from repro.launch import steps as st
from repro.models import dit as dit_mod
from repro.optim import adamw


@pytest.fixture(scope="module")
def pretrained():
    """Train a tiny class-conditional DiT for a few hundred steps."""
    cfg = ModelConfig(
        name="sys-dit", family="dit", num_layers=2, d_model=64, d_ff=128,
        vocab_size=0, attn=AttnConfig(4, 4, 16, use_rope=False),
        dit=DiTConfig(latent_shape=(1, 8, 8, 2), patch_size=(1, 2, 2),
                      flex_patch_sizes=(), underlying_patch_size=(1, 2, 2),
                      conditioning="class", num_classes=4, learn_sigma=False),
        mlp_activation="gelu", norm_type="layernorm",
        param_dtype="float32", compute_dtype="float32", remat="none")
    tc = TrainConfig(learning_rate=2e-3, warmup_steps=10, total_steps=300,
                     schedule="cosine", grad_clip=1.0)
    sched = sch.linear_schedule(100)
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_opt_state(params)
    step = jax.jit(st.make_dit_train_step(cfg, tc, sched))
    make_batch = dp.make_dit_batch_fn(cfg.dit.latent_shape, 4, 16,
                                      noise_scale=0.1)
    key = jax.random.PRNGKey(1)
    losses = []
    for i in range(300):
        b = make_batch(i, 0, 1, np.random.default_rng(i))
        batch = {"x0": jnp.asarray(b["x0"]), "cond": jnp.asarray(b["cond"])}
        params, opt, m = step(params, opt, batch, jax.random.fold_in(key, i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-30:]) < np.mean(losses[:30]) * 0.8, \
        "pre-training did not learn"
    return cfg, params, sched


def test_pretraining_then_flexify_then_sample(pretrained):
    cfg, params, sched = pretrained
    fparams, fcfg = flexify(params, cfg, [(1, 4, 4)])

    # brief flexi fine-tune alternating modes (paper §4.1)
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=5, total_steps=100)
    steps = [jax.jit(st.make_dit_train_step(fcfg, tc, sched, mode=m))
             for m in (0, 1)]
    opt = adamw.init_opt_state(fparams)
    make_batch = dp.make_dit_batch_fn(cfg.dit.latent_shape, 4, 16,
                                      noise_scale=0.1)
    key = jax.random.PRNGKey(2)
    for i in range(100):
        b = make_batch(i, 0, 1, np.random.default_rng(1000 + i))
        batch = {"x0": jnp.asarray(b["x0"]), "cond": jnp.asarray(b["cond"])}
        fparams, opt, m = steps[i % 2](fparams, opt, batch,
                                       jax.random.fold_in(key, i))

    # sample with the weak→powerful scheduler
    T = 20
    ts = sch.respaced_timesteps(100, T)
    fs = FlexiSchedule.weak_first(T, 12)
    B = 8
    y = jnp.arange(B) % 4
    null = jnp.full((B,), 4)
    phases = []
    for mode, tsub in fs.split_timesteps(ts):
        g = GuidanceConfig(scale=1.5, mode_cond=mode, mode_uncond=mode)
        phases.append((make_eps_fn(fparams, fcfg, y, null, g), tsub))
    x_T = jax.random.normal(jax.random.PRNGKey(3), (B, 1, 8, 8, 2))
    x0 = sampler.sample_phased(phases, sched, x_T, jax.random.PRNGKey(4),
                               solver="ddim")
    assert np.isfinite(np.asarray(x0)).all()

    # samples should correlate with their class patterns more than others'
    pats = np.stack([dp.class_pattern(c, cfg.dit.latent_shape)
                     for c in range(4)])
    x0n = np.asarray(x0)
    own, other = [], []
    for i in range(B):
        for c in range(4):
            corr = np.corrcoef(x0n[i].ravel(), pats[c].ravel())[0, 1]
            (own if c == int(y[i]) else other).append(corr)
    assert np.mean(own) > np.mean(other), (np.mean(own), np.mean(other))
    # and the schedule actually saved >40% compute
    assert relative_compute(fcfg, fs) < 0.6


def test_weak_powerful_gap_smaller_at_high_noise(pretrained):
    """Fig. 4 (right): ‖ε_weak − ε_powerful‖ grows as t → 0."""
    cfg, params, sched = pretrained
    fparams, fcfg = flexify(params, cfg, [(1, 4, 4)])
    # fine-tune both modes in alternation (paper recipe) long enough for the
    # weak mode to be meaningful
    tc = TrainConfig(learning_rate=2e-3, warmup_steps=5, total_steps=200)
    steps2 = [jax.jit(st.make_dit_train_step(fcfg, tc, sched, mode=m))
              for m in (0, 1)]
    opt = adamw.init_opt_state(fparams)
    make_batch = dp.make_dit_batch_fn(cfg.dit.latent_shape, 4, 16, 0.1)
    for i in range(200):
        b = make_batch(i, 0, 1, np.random.default_rng(2000 + i))
        batch = {"x0": jnp.asarray(b["x0"]), "cond": jnp.asarray(b["cond"])}
        fparams, opt, _ = steps2[i % 2](
            fparams, opt, batch,
            jax.random.fold_in(jax.random.PRNGKey(5), i))

    key = jax.random.PRNGKey(6)
    b = make_batch(0, 0, 1, np.random.default_rng(7))
    x0 = jnp.asarray(b["x0"])
    cond = jnp.asarray(b["cond"])
    gaps = {}
    for t_val in (10, 90):
        t = jnp.full((x0.shape[0],), t_val)
        noise = jax.random.normal(key, x0.shape)
        x_t = sch.q_sample(sched, x0, t, noise)
        e0 = dit_mod.eps_prediction(
            dit_mod.dit_forward(fparams, x_t, t.astype(jnp.float32), cond,
                                fcfg, mode=0), fcfg)
        e1 = dit_mod.eps_prediction(
            dit_mod.dit_forward(fparams, x_t, t.astype(jnp.float32), cond,
                                fcfg, mode=1), fcfg)
        # relative gap (normalized by prediction energy — magnitudes differ
        # strongly across t at toy scale)
        gaps[t_val] = float(jnp.mean(jnp.square(e0 - e1))
                            / jnp.mean(jnp.square(e0)))
    # early denoising steps (large t) → smaller weak/powerful gap (Fig. 4)
    assert gaps[90] < gaps[10], gaps
