"""Fleet serving tests (DESIGN.md §fleet): router placement + affinity,
membership drain/join/death over heartbeats, straggler down-weighting
and hedged re-dispatch, background warm-set compilation, and the
end-to-end guarantees — a drain loses zero accepted requests, a
kill-mid-flight re-admission reproduces the uninterrupted single-engine
sample (≤1e-4), and warm traffic replays with zero recompiles.

Everything runs on a simulated clock (virtual time: each replica's
clock advances by modeled dispatch cost), so all counters and latencies
are deterministic.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scheduler import FlexiSchedule
from repro.diffusion import schedule as sch
from repro.fleet import (BackgroundCompiler, Fleet, FixedSlotEngine,
                         FleetHealth, FleetMembership, ReplicaView, Router,
                         init_process_group, partition_devices)
from repro.pipeline import FlexiPipeline, SamplingPlan

pytestmark = pytest.mark.tier1

T = 6


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


@pytest.fixture(scope="module")
def flexi(tiny_dit_cfg, trained_like_dit):
    from repro.core import flexify
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    sched = sch.linear_schedule(100)
    return fparams, fcfg, sched


@pytest.fixture(scope="module")
def pipe(flexi):
    fparams, fcfg, sched = flexi
    return FlexiPipeline(fparams, fcfg, sched)


def make_plans():
    return {0.6: SamplingPlan(T=T, budget=FlexiSchedule.weak_first(T, 3),
                              solver="ddim", guidance_scale=1.5),
            1.0: SamplingPlan(T=T, budget=1.0, solver="ddim",
                              guidance_scale=1.5)}


def _reference(pipe, plans, level, label, key):
    return np.asarray(pipe.sample(plans[level], 1, key,
                                  cond=jnp.asarray([label], jnp.int32)).x0[0])


def _check_all_results(fleet, pipe, plans):
    """Every fleet result reproduces its standalone single-request
    sample — the re-admission/affinity machinery must never change
    what a request's key samples."""
    assert fleet.results, "nothing served"
    for rid, r in fleet.results.items():
        req = fleet.router.requests[rid]
        ref = _reference(pipe, plans, r.budget_served, req.cond, req.key)
        np.testing.assert_allclose(np.asarray(r.x0), ref,
                                   atol=1e-4, rtol=1e-4)


def _mixed_submit(fleet, n, deadline=math.inf):
    return [fleet.submit(cond=i % 10, budget=[0.6, 1.0][i % 2],
                         deadline=deadline) for i in range(n)]


# ---------------------------------------------------------------------------
# Router (host-pure unit tests)


def _views(*specs):
    """specs: (rid, backlog, price) with a flat one-level price menu."""
    return [ReplicaView(rid=rid, admitting=True, backlog_seconds=b,
                       prices={1.0: p}) for rid, b, p in specs]


def test_router_rejects_unknown_policy():
    with pytest.raises(ValueError, match="policy"):
        Router("sjf")


def test_cheapest_scores_priced_backlog_and_charges_placement():
    r = Router("cheapest")
    req1 = r.register(cond=0, budget=1.0, deadline=math.inf, key=None,
                      now=0.0)
    req2 = r.register(cond=0, budget=1.0, deadline=math.inf, key=None,
                      now=0.0)
    views = _views((0, 5.0, 1.0), (1, 0.0, 1.0))
    assert r.place(req1, views, 1.0) == 1
    # the placement charged replica 1's backlog: 0.0 + 1.0 price
    assert views[1].backlog_seconds == pytest.approx(1.0)
    # straggler weight prices replica 1 out for the second placement
    views[1].weight = 8.0
    assert r.place(req2, views, 1.0) == 0
    assert r.n_pending == 0


def test_rr_rotates_over_admitting_replicas():
    r = Router("rr")
    views = _views((0, 0.0, 1.0), (1, 99.0, 1.0), (2, 0.0, 1.0))
    views[1].admitting = False
    got = []
    for _ in range(4):
        req = r.register(0, 1.0, math.inf, None, 0.0)
        got.append(r.place(req, views, 1.0))
    assert got == [0, 2, 0, 2]


def test_affinity_sticks_to_home_and_shards_fresh_requests():
    r = Router("affinity")
    # fresh request shards by class label across the live set
    req = r.register(cond=1, budget=1.0, deadline=math.inf, key=None,
                     now=0.0)
    views = _views((0, 0.0, 1.0), (1, 0.2, 1.0))
    assert r.place(req, views, 1.0) == 1       # cond 1 % 2 replicas
    assert req.home == 1
    # dispatched on its home, then handed back in a drain: the cache
    # slots pin it to replica 1 even though replica 0 is now cheaper
    req.dispatched = True
    r.handback(req, lost_state=False)
    views = _views((0, 0.0, 1.0), (1, 50.0, 1.0))
    assert r.place(req, views, 1.0) == 1
    assert r.state_readmits == 0
    # a badly-behind shard loses a FRESH request to the cheapest replica
    req2 = r.register(cond=1, budget=1.0, deadline=math.inf, key=None,
                      now=0.0)
    views = _views((0, 0.0, 1.0), (1, 50.0, 1.0))
    assert r.place(req2, views, 1.0) == 0


def test_state_losing_move_counts_against_affinity():
    r = Router("cheapest")
    req = r.register(0, 1.0, math.inf, None, 0.0)
    views = _views((0, 0.0, 1.0), (1, 5.0, 1.0))
    assert r.place(req, views, 1.0) == 0
    req.dispatched = True                      # slots allocated on 0
    r.handback(req, lost_state=True)           # replica 0 died
    assert req.readmits == 1
    views = _views((0, 0.0, 1.0), (1, 0.0, 1.0))
    views[0].admitting = False
    assert r.place(req, views, 1.0) == 1
    assert r.state_readmits == 1
    # 1 forced refresh out of 10 dispatches
    assert r.affinity_hit_rate(10) == pytest.approx(0.9)
    assert r.affinity_hit_rate(0) == 1.0


def test_mark_done_first_completion_wins():
    r = Router("cheapest")
    req = r.register(0, 1.0, math.inf, None, 0.0)
    r.place(req, _views((0, 0.0, 1.0)), 1.0)
    assert r.mark_done(req, 3.0, served_by=0)
    assert not r.mark_done(req, 4.0, served_by=1)   # hedged twin loses
    assert req.served_by == 0 and req.done_at == 3.0
    assert r.unfinished() == []


# ---------------------------------------------------------------------------
# Membership (host-pure unit tests)


def test_partition_devices_plans_through_elastic():
    assert partition_devices(range(8), 4, 2) == \
        [(0, 1), (2, 3), (4, 5), (6, 7)]
    with pytest.raises(ValueError, match="does not divide"):
        partition_devices(range(7), 2, 2)
    with pytest.raises(ValueError, match="replicas"):
        partition_devices(range(4), 3, 2)


def test_membership_drain_state_machine():
    clk = FakeClock()
    m = FleetMembership(2, range(2), timeout_s=5.0, clock=clk)
    assert m.admitting(0) and m.pumpable(0)
    m.start_drain(0)
    assert not m.admitting(0) and m.pumpable(0)    # finishes in-flight
    with pytest.raises(RuntimeError, match="draining"):
        m.start_drain(0)
    m.finish_drain(0)
    assert m.state(0) == "drained"
    assert not m.pumpable(0)
    with pytest.raises(RuntimeError, match="drained"):
        m.finish_drain(0)
    assert m.alive_count == 1


def test_membership_death_by_missed_beats_and_rejoin_incarnation():
    clk = FakeClock()
    m = FleetMembership(2, range(2), timeout_s=5.0, clock=clk)
    clk.advance(4.0)
    m.beat(1)
    clk.advance(2.0)                   # replica 0 at 6s > timeout
    assert m.check() == [0]
    assert m.state(0) == "dead" and not m.admitting(0)
    assert m.incarnation(0) == 0
    assert m.rejoin(0) == 1            # comeback bumps the incarnation
    assert m.admitting(0)
    # beats on a dead replica are ignored (stale incarnation must not
    # resurrect silently)
    m.mark_dead(1)
    m.beat(1)
    assert m.state(1) == "dead"
    assert m.check() == []             # explicit kill already marked it


def test_membership_join_grows_monitor():
    clk = FakeClock()
    m = FleetMembership(1, range(2), seq_parallel=2, timeout_s=5.0,
                        clock=clk)
    rid = m.join((2, 3))
    assert rid == 1
    assert m.admitting(rid) and m.incarnation(rid) == 0
    assert m.summary()["alive"] == 2
    with pytest.raises(ValueError):
        m.join((4,) * 3)               # 2 does not divide 3


def test_process_group_seam():
    calls = []
    g = init_process_group("grpc://head:1234", 4, 2,
                           initialize_fn=lambda **kw: calls.append(kw))
    assert not g.simulated and g.num_processes == 4
    assert calls == [{"coordinator_address": "grpc://head:1234",
                      "num_processes": 4, "process_id": 2}]
    assert init_process_group().simulated


# ---------------------------------------------------------------------------
# Health (host-pure unit tests)


def test_health_weights_clamp_and_grow():
    # 3 workers so the median tracks the fast pair (with 2 workers the
    # median is the mean and the ratio saturates at 2.0 by construction)
    h = FleetHealth(3, max_weight=4.0)
    assert h.weights() == {0: 1.0, 1: 1.0, 2: 1.0}   # unseen → neutral
    for _ in range(8):
        h.record_dispatch(0, 16.0)
        h.record_dispatch(1, 10.0)
        h.record_dispatch(2, 10.0)
    w = h.weights()
    assert w[0] > 1.4                          # slow: routed away from
    assert w[1] == 1.0 and w[2] == 1.0         # fast is never boosted
    h.record_dispatch(0, 1e6)
    assert h.weights()[0] == 4.0               # clamped at max_weight
    h.grow(4)
    assert h.weights()[3] == 1.0
    assert h.ewma_ms(3) == 0.0 and h.ewma_ms(1) > 0.0


def test_health_hedge_candidates_maps_seed_policy():
    h = FleetHealth(2)
    # positive lateness = predicted to miss its deadline
    assert h.hedge_candidates([7, 9, 11], [-5.0, 3.0, 0.0]) == [9]
    assert h.hedge_candidates([], []) == []


# ---------------------------------------------------------------------------
# The fleet, end to end (virtual time)


def test_fleet_throughput_and_reference_match(pipe):
    """Mixed-budget traffic over 3 replicas: every sample matches its
    standalone reference, placements spread, and virtual makespan beats
    a single replica's serial time."""
    plans = make_plans()
    clk = FakeClock()
    fleet = Fleet(pipe, plans, 3, router="cheapest", clock=clk,
                  seconds_per_token=1e-4)
    rids = _mixed_submit(fleet, 9)
    results = fleet.run()
    assert sorted(r.rid for r in results) == rids
    _check_all_results(fleet, pipe, plans)
    s = fleet.summary()
    assert s["served"] == 9
    assert s["affinity_hit_rate"] == 1.0
    assert s["tokens_per_s"] > 0
    served_by = [fleet.results[r].replica for r in rids]
    assert len(set(served_by)) == 3            # all replicas took work
    # serial lower bound: one replica doing all the work needs the sum
    # of every dispatch's modeled time; 3 replicas finish sooner
    clk1 = FakeClock()
    solo = Fleet(pipe, plans, 1, clock=clk1, seconds_per_token=1e-4)
    _mixed_submit(solo, 9)
    solo.run()
    assert fleet.makespan() < solo.makespan()


def test_drain_loses_zero_accepted_requests(pipe):
    plans = make_plans()
    clk = FakeClock()
    fleet = Fleet(pipe, plans, 2, router="cheapest", clock=clk,
                  seconds_per_token=1e-4,
                  engine_kwargs={"max_tokens_per_step": 128,
                                 "max_inflight": 2})
    rids = _mixed_submit(fleet, 8)
    fleet.tick()                       # some in-flight, some queued
    handed = fleet.drain_replica(0)
    assert handed > 0                  # its queue went back to the router
    assert fleet.membership.state(0) == "draining"
    results = fleet.run()
    assert sorted(fleet.results) == rids               # zero lost
    assert fleet.membership.state(0) == "drained"
    _check_all_results(fleet, pipe, plans)
    # the drained replica finished its in-flight cohort, took nothing new
    assert fleet.replicas[0].engine.metrics.total_served > 0
    assert fleet.router.handbacks >= handed
    # drain handbacks of never-dispatched requests are not affinity misses
    for r in results:
        if fleet.results[r.rid].replica == 1:
            continue
    assert fleet.summary()["served"] == 8


def test_kill_midflight_readmits_and_matches_reference(pipe):
    """The acceptance gate: a replica killed mid-drain loses zero
    accepted requests; every re-admitted request restarts from step 0
    on the survivor (forced cache refresh, same key) and reproduces the
    uninterrupted single-engine sample ≤1e-4."""
    plans = make_plans()
    clk = FakeClock()
    fleet = Fleet(pipe, plans, 2, router="affinity", clock=clk,
                  seconds_per_token=1e-4)
    rids = _mixed_submit(fleet, 8)
    fleet.tick()                       # dispatch once: state on devices
    killed_inflight = fleet.replicas[0].engine.n_inflight
    n_re = fleet.kill_replica(0)
    assert n_re > 0
    assert fleet.membership.state(0) == "dead"
    fleet.run()
    assert sorted(fleet.results) == rids               # zero lost
    assert all(r.replica == 1 for r in fleet.results.values())
    _check_all_results(fleet, pipe, plans)
    s = fleet.summary()
    assert s["readmit"]["count"] == n_re
    # only the dispatched orphans were state-losing moves
    assert fleet.router.state_readmits == killed_inflight
    assert s["affinity_hit_rate"] == pytest.approx(
        1.0 - killed_inflight / s["request_dispatches"])


def test_affinity_keeps_requests_home_across_migrations(pipe):
    """With the affinity policy and no faults every request's dispatches
    all run on its home replica even as cohorts migrate between packed
    buckets — hit rate exactly 1.0."""
    plans = make_plans()
    clk = FakeClock()
    fleet = Fleet(pipe, plans, 2, router="affinity", clock=clk,
                  seconds_per_token=1e-4,
                  engine_kwargs={"max_tokens_per_step": 256})
    _mixed_submit(fleet, 8)
    fleet.run()
    assert fleet.router.state_readmits == 0
    s = fleet.summary()
    assert s["affinity_hit_rate"] == 1.0
    # sticky homes: each request was placed exactly once
    assert all(r.placements == 1
               for r in fleet.router.requests.values())
    # class sharding: equal cond classes landed on the same replica
    by_cond = {}
    for rid, res in fleet.results.items():
        req = fleet.router.requests[rid]
        by_cond.setdefault(req.cond, set()).add(res.replica)
    assert all(len(v) == 1 for v in by_cond.values())


def test_warm_traffic_replays_with_zero_recompiles(pipe):
    """Compile-once across fleet restarts: the pipeline's runner cache
    is the durable artifact, so a fresh fleet over the same (shared)
    pipe replays an identical workload with zero recompiles."""
    plans = make_plans()
    fleet = Fleet(pipe, plans, 2, router="cheapest", clock=FakeClock(),
                  seconds_per_token=1e-4)
    _mixed_submit(fleet, 6)
    fleet.run()
    warm = fleet.compile_stats()
    assert warm["pipes"] == 1          # shared pipeline: one XLA process
    replay = Fleet(pipe, plans, 2, router="cheapest", clock=FakeClock(),
                   seconds_per_token=1e-4)
    _mixed_submit(replay, 6)           # same workload, fresh fleet state
    replay.run()
    after = replay.compile_stats()
    assert after["compiled"] == warm["compiled"]
    assert after["misses"] == warm["misses"]


def test_background_compiler_warms_while_serving(pipe):
    plans = make_plans()
    clk = FakeClock()
    fleet = Fleet(pipe, plans, 1, clock=clk, seconds_per_token=1e-4)
    eng = fleet.replicas[0].engine
    warm = BackgroundCompiler(eng, max_per_mode=1, k_depths=(1, 2)).start()
    _mixed_submit(fleet, 4)            # serve WHILE the ladder compiles
    fleet.run()
    assert warm.wait(timeout=600.0)
    n = warm.assert_warm()             # every rung provably captured
    assert n > 0
    assert fleet.summary()["served"] == 4
    # the ladder is idempotent: a second walk has nothing left to do
    again = BackgroundCompiler(eng, max_per_mode=1, k_depths=(1, 2))
    c0 = eng.cache_stats()["compiled"]
    again.start()
    assert again.wait(timeout=60.0)
    assert again.captured == 0
    assert eng.cache_stats()["compiled"] == c0


def test_hung_replica_dies_by_heartbeat_timeout(pipe):
    plans = make_plans()
    clk = FakeClock()
    fleet = Fleet(pipe, plans, 2, router="rr", clock=clk,
                  seconds_per_token=1e-4, heartbeat_timeout_s=5.0)
    rids = _mixed_submit(fleet, 6)
    fleet.tick()                       # both replicas beat at t=0
    fleet.inject_hang(0)
    clk.advance(6.0)                   # past the timeout without a beat
    fleet.tick()                       # survivor beats; monitor fires
    assert fleet.membership.state(0) == "dead"
    fleet.run()
    assert sorted(fleet.results) == rids
    assert all(r.replica == 1 for r in fleet.results.values())
    _check_all_results(fleet, pipe, plans)
    # rejoin: fresh engine, bumped incarnation, takes traffic again
    assert fleet.rejoin_replica(0) == 1
    more = _mixed_submit(fleet, 2)
    fleet.run()
    assert set(more) <= set(fleet.results)


def test_straggler_downweights_slow_replica(pipe):
    plans = make_plans()
    clk = FakeClock()
    fleet = Fleet(pipe, plans, 2, router="cheapest", clock=clk,
                  seconds_per_token=1e-4, speed_factors={0: 4.0})
    _mixed_submit(fleet, 10)
    fleet.run()
    w = fleet.health.weights()
    # with 2 replicas the median is the mean, and cheapest routing packs
    # the slow replica's dispatches lighter — the ratio lands well below
    # the raw 4x speed factor, but the down-weight direction must hold
    assert w[0] > 1.15 and w[1] == 1.0
    served = {rid: sum(1 for r in fleet.results.values()
                       if r.replica == rid) for rid in (0, 1)}
    assert served[1] > served[0]       # fast replica took most work
    assert fleet.summary()["straggler"]["stragglers"] in ([0], [])


def test_hedged_request_served_once_and_matches_reference(pipe):
    plans = make_plans()
    clk = FakeClock()
    fleet = Fleet(pipe, plans, 2, router="rr", clock=clk,
                  seconds_per_token=1e-4, speed_factors={0: 4.0},
                  engine_kwargs={"steps_per_dispatch": 1})
    # prime the detector: one request on each replica via rr
    _mixed_submit(fleet, 2)
    fleet.run()
    assert fleet.health.weights()[0] > 1.5
    # rr puts the next request on the slow replica; its tight deadline
    # makes it hedge-eligible once predicted late
    rid = fleet.submit(cond=3, budget=1.0, deadline=fleet.now + 1e-3)
    fleet.tick()
    req = fleet.router.requests[rid]
    assert req.owner == 0
    fleet.run()
    assert req.hedged
    assert fleet.router.hedges == 1
    # first completion won; the twin was cancelled or dropped — exactly
    # one result, and it is the reference sample regardless of winner
    assert sorted(fleet.results) == [0, 1, rid]
    _check_all_results(fleet, pipe, plans)
    assert (fleet.router.hedge_wins + fleet._hedge_losses <= 1)


def test_join_replica_takes_new_traffic(pipe):
    plans = make_plans()
    clk = FakeClock()
    fleet = Fleet(pipe, plans, 1, router="cheapest", clock=clk,
                  seconds_per_token=1e-4)
    _mixed_submit(fleet, 4)
    fleet.tick()
    rid = fleet.join_replica()
    assert rid == 1
    assert fleet.membership.admitting(rid)
    _mixed_submit(fleet, 4)
    fleet.run()
    assert len(fleet.results) == 8
    assert any(r.replica == rid for r in fleet.results.values())
    _check_all_results(fleet, pipe, plans)


def test_fixed_slot_engine_matches_reference(pipe):
    """The seq-parallel-compatible engine kind: per-request x_T stacking
    makes a fixed-slot ddim batch reproduce standalone samples."""
    plans = make_plans()
    clk = FakeClock()
    eng = FixedSlotEngine(pipe, plans, batch_size=4, clock=clk)
    keys = {i: jax.random.PRNGKey(70 + i) for i in range(3)}
    for i in range(3):
        eng.submit(cond=i, budget=1.0, key=keys[i])
    out = eng.run()
    assert len(out) == 3 and eng.idle
    for r in out:
        ref = _reference(pipe, plans, 1.0, r.request.cond,
                         keys[r.request.id])
        np.testing.assert_allclose(np.asarray(r.x0), ref,
                                   atol=1e-4, rtol=1e-4)
    # the fleet surface: drain extracts the queue in arrival order
    eng.submit(cond=5, budget=0.6)
    eng.submit(cond=6, budget=1.0)
    eng.stop_admissions()
    assert [r.cond for r in eng.extract_queued()] == [5, 6]
    assert eng.idle


def test_fleet_with_fixed_slot_replicas(pipe):
    plans = make_plans()
    clk = FakeClock()
    fleet = Fleet(pipe, plans, 2, router="rr", clock=clk,
                  engine_kind="fixed", seconds_per_token=1e-4)
    rids = _mixed_submit(fleet, 4)
    fleet.run()
    assert sorted(fleet.results) == rids
    _check_all_results(fleet, pipe, plans)


def test_fleet_constructor_validation(pipe):
    with pytest.raises(ValueError, match="at least one"):
        Fleet(pipe, make_plans(), 0)
    with pytest.raises(ValueError, match="policy"):
        Fleet(pipe, make_plans(), 1, router="fastest")


# ---------------------------------------------------------------------------
# The fleet-host-pure lint rule


def test_fleet_host_pure_rule_flags_device_imports(tmp_path):
    from repro.analysis.engine import lint_paths
    bad = tmp_path / "fleet" / "router.py"
    bad.parent.mkdir()
    bad.write_text(
        "import numpy as np\n"
        "def score(xs):\n"
        "    return float(np.mean(xs).item())\n")
    findings = lint_paths([bad])
    rules = {f.rule for f in findings}
    assert rules == {"fleet-host-pure"}
    assert len(findings) >= 2          # the import and the np call
    assert all(f.severity == "error" for f in findings)


def test_fleet_control_modules_pass_host_pure_lint():
    from pathlib import Path
    from repro.analysis.engine import lint_paths
    fleet_dir = Path(__file__).resolve().parents[1] / "src/repro/fleet"
    findings = [f for f in lint_paths([fleet_dir])
                if f.rule == "fleet-host-pure"]
    assert findings == []
