"""Cross-step activation cache (DESIGN.md §cache).

The load-bearing asserts: interval=1 (refresh every step) is
BIT-IDENTICAL to uncached sampling for ddim AND ddpm on both the plain
pipeline and the packed engine path; interval k>1 drifts boundedly;
policy switches on a warm runner never recompile; and engine cache
slots are released on retire and reused across join/leave.
"""
import collections
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cache import (CacheSpec, CacheStore, cache_savings,
                         cached_nfe_flops, conditioning_drift, delta_bytes,
                         ladder_refresh_mask, refresh_intervals, refresh_mask)
from repro.cache.ledger import deep_block_flops
from repro.core import flexify
from repro.core.scheduler import (FlexiSchedule, dit_block_flops,
                                  dit_nfe_flops)
from repro.diffusion import schedule as sch
from repro.models import dit as dit_mod
from repro.pipeline import FlexiPipeline, SamplingPlan
from repro.serving import BudgetController, ServingEngine, request_cost_flops

pytestmark = pytest.mark.tier1

T = 6


@pytest.fixture(scope="module")
def flexi(tiny_dit_cfg, trained_like_dit):
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    return fparams, fcfg, sch.linear_schedule(100)


@pytest.fixture(scope="module")
def pipe(flexi):
    fparams, fcfg, sched = flexi
    return FlexiPipeline(fparams, fcfg, sched)


def make_plans(solver="ddim", cache=None):
    return {0.6: SamplingPlan(T=T, budget=FlexiSchedule.weak_first(T, 3),
                              solver=solver, guidance_scale=1.5, cache=cache),
            1.0: SamplingPlan(T=T, budget=1.0, solver=solver,
                              guidance_scale=1.5, cache=cache)}


# ---------------------------------------------------------------------------
# Policies (host-only)


def test_cache_spec_validation():
    with pytest.raises(ValueError, match="policy"):
        CacheSpec(policy="lru")
    with pytest.raises(ValueError, match="interval"):
        CacheSpec(interval=0)
    with pytest.raises(ValueError, match="threshold"):
        CacheSpec(threshold=0.0)
    with pytest.raises(ValueError, match="bands"):
        CacheSpec(policy="banded", bands=((5, 0),))
    assert CacheSpec(policy="interval", interval=1).exact
    assert not CacheSpec(policy="interval", interval=2).exact
    assert not CacheSpec(policy="proxy").exact
    # split=0 resolves to L//4 (min 1) and must leave a deep block
    assert CacheSpec().resolve_split(8) == 2
    assert CacheSpec().resolve_split(2) == 1
    with pytest.raises(ValueError, match="deep block"):
        CacheSpec(split=4).resolve_split(4)


def test_refresh_mask_interval_and_banded():
    ts = np.linspace(99, 0, 8).round().astype(np.int64)
    m1 = refresh_mask(CacheSpec(policy="interval", interval=1), ts)
    assert m1.all()
    m3 = refresh_mask(CacheSpec(policy="interval", interval=3), ts)
    np.testing.assert_array_equal(
        m3, [True, False, False, True, False, False, True, False])
    # banded: refresh every step while t >= 50, every 4 below
    mb = refresh_mask(CacheSpec(policy="banded", bands=((50, 1),),
                                interval=4), ts)
    assert mb[:4].all()                      # ts 99..57 band at interval 1
    assert list(mb[4:]) == [False, False, False, True]
    assert refresh_intervals(m3) == [3, 3]
    assert refresh_mask(CacheSpec(), np.zeros(0, np.int64)).shape == (0,)


def test_refresh_mask_proxy_monotone_in_threshold():
    ts = np.linspace(999, 0, 20).round().astype(np.int64)
    loose = refresh_mask(CacheSpec(policy="proxy", threshold=0.5), ts)
    tight = refresh_mask(CacheSpec(policy="proxy", threshold=0.01), ts)
    assert loose[0] and tight[0]
    assert tight.sum() >= loose.sum()        # tighter drift → more refreshes
    assert 0 < loose.sum() < len(ts)         # neither degenerate
    # drift is 0 at zero gap and grows with the gap
    assert conditioning_drift([50], [50])[0] == pytest.approx(0.0, abs=1e-12)
    assert conditioning_drift([80], [50])[0] > \
        conditioning_drift([55], [50])[0] > 0


def test_ladder_mask_resets_per_phase():
    fs = FlexiSchedule.weak_first(T, 3)
    ts = sch.respaced_timesteps(100, T)
    mask = ladder_refresh_mask(CacheSpec(policy="interval", interval=4),
                               fs.split_timesteps(ts))
    # phase boundaries force a refresh: step 0 AND step 3 (mode switch)
    np.testing.assert_array_equal(mask, [True, False, False,
                                         True, False, False])


# ---------------------------------------------------------------------------
# Ledger


def test_cached_flops_ledger(flexi):
    _, fcfg, _ = flexi
    L = fcfg.num_layers
    full = dit_nfe_flops(fcfg, 0)
    skip = cached_nfe_flops(fcfg, 0, split=1, refresh=False)
    assert cached_nfe_flops(fcfg, 0, split=1, refresh=True) == full
    # the skipped deep share is exactly (L - split)/L of the block FLOPs
    N0 = dit_mod.tokens_for_mode(fcfg, 0)
    assert full - skip == pytest.approx(
        dit_block_flops(fcfg, N0) * (L - 1) / L)
    assert deep_block_flops(fcfg, 0, 1) == pytest.approx(full - skip)
    # a full-T exact run saves nothing; interval 2 saves something
    fs = FlexiSchedule(((0, T),))
    ts = sch.respaced_timesteps(100, T)
    exact = cache_savings(fcfg, fs, ts, CacheSpec(policy="interval",
                                                  interval=1, split=1))
    assert exact["flops_saved_frac"] == 0.0
    k2 = cache_savings(fcfg, fs, ts, CacheSpec(policy="interval",
                                               interval=2, split=1))
    assert 0.0 < k2["flops_saved_frac"] < 1.0
    assert k2["refresh_rate"] == pytest.approx(0.5)
    assert delta_bytes(fcfg, 0, guided=True) == \
        2 * dit_mod.tokens_for_mode(fcfg, 0) * fcfg.d_model * 4


def test_plan_cached_flops_and_controller_pricing(flexi):
    _, fcfg, _ = flexi
    spec = CacheSpec(policy="interval", interval=2, split=1)
    plans = make_plans()
    plan = plans[1.0]
    assert plan.cached_flops(fcfg) == plan.flops(fcfg)     # no cache: same
    cost_plain = request_cost_flops(fcfg, plan)
    cost_cached = request_cost_flops(fcfg, plan, cache=spec)
    assert cost_cached < cost_plain
    # the controller prices cache-adjusted costs into the budget solve:
    # capacity that only sustains 0.6 uncached sustains 1.0 with caching
    lam, cap = 4.0, 4.0 * request_cost_flops(fcfg, plans[0.6])
    for cache in (None, spec):
        ctl = BudgetController(fcfg, plans, target_util=1.0, alpha=1.0,
                               cache=cache)
        ctl.observe_service(flops=cap, dt=1.0)
        for i in range(5):
            ctl.observe_arrival(i / lam)
        if cache is None:
            assert ctl.solve() == 0.6
        else:
            assert ctl.costs[1.0] < ctl.costs[0.6] * 1.7   # savings priced
    plan_c = SamplingPlan(T=T, budget=1.0, cache=spec)
    assert plan_c.cached_flops(fcfg) < plan_c.flops(fcfg)


def test_cache_plan_validation():
    with pytest.raises(ValueError, match="solvers"):
        SamplingPlan(T=T, budget=1.0, solver="dpm2", cache=CacheSpec())
    with pytest.raises(ValueError, match="weak_cond|vanilla"):
        SamplingPlan(T=T, budget=1.0, guidance_kind="weak_cond",
                     cache=CacheSpec())
    from repro.pipeline import AdaptiveBudget
    with pytest.raises(ValueError, match="static"):
        SamplingPlan(T=T, budget=AdaptiveBudget(), cache=CacheSpec())


# ---------------------------------------------------------------------------
# Store


def test_cache_store_slots_and_eviction(flexi):
    _, fcfg, _ = flexi
    store = CacheStore(fcfg, (0, 1), n_slots=2, guided=True)
    s0 = store.alloc(0, owner=10)
    s1 = store.alloc(0, owner=11)
    assert {s0, s1} == {0, 1} and store.n_active == 2
    assert store.bytes_resident == 2 * delta_bytes(fcfg, 0)
    # pool exhausted → LRU eviction: oldest owner loses its slot
    store.touch(0, s1)
    s2 = store.alloc(0, owner=12)
    assert s2 == s0 and store.owner_of(0, s0) == 12
    assert store.evictions == 1
    # release → freed slot is reused (join/leave recycling)
    store.release(0, s1)
    assert store.owner_of(0, s1) is None
    assert store.alloc(1, owner=13) in (0, 1)   # per-mode pools independent
    assert store.n_active == 2                  # mode-0 s2 + the mode-1 slot
    # gather/scatter round-trip
    vals = jnp.ones((1, 2, dit_mod.tokens_for_mode(fcfg, 0),
                     fcfg.d_model))
    store.scatter(0, [s2], vals)
    np.testing.assert_array_equal(np.asarray(store.gather(0, [s2])),
                                  np.asarray(vals))
    assert store.bytes_total == 2 * (delta_bytes(fcfg, 0)
                                     + delta_bytes(fcfg, 1))


# ---------------------------------------------------------------------------
# Plain pipeline path: exactness, drift, zero-recompile policy switches


@pytest.mark.parametrize("solver", ["ddim", "ddpm"])
def test_interval1_bit_identical_plain(pipe, solver):
    key = jax.random.PRNGKey(7)
    cond = jnp.asarray([3, 8], jnp.int32)
    plan = SamplingPlan(T=T, budget=FlexiSchedule.weak_first(T, 3),
                        solver=solver, guidance_scale=1.5)
    exact = CacheSpec(policy="interval", interval=1, split=1)
    ref = pipe.sample(plan, 2, key, cond=cond).x0
    got = pipe.sample(SamplingPlan(T=T,
                                   budget=FlexiSchedule.weak_first(T, 3),
                                   solver=solver, guidance_scale=1.5,
                                   cache=exact), 2, key, cond=cond).x0
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


@pytest.mark.parametrize("solver", ["ddim", "ddpm"])
def test_interval_k_bounded_drift(pipe, flexi, solver):
    _, fcfg, _ = flexi
    key = jax.random.PRNGKey(11)
    cond = jnp.asarray([1, 4], jnp.int32)
    plan = SamplingPlan(T=T, budget=1.0, solver=solver, guidance_scale=1.5)
    ref = pipe.sample(plan, 2, key, cond=cond).x0
    spec = CacheSpec(policy="interval", interval=2, split=1)
    res = pipe.sample(SamplingPlan(T=T, budget=1.0, solver=solver,
                                   guidance_scale=1.5, cache=spec),
                      2, key, cond=cond)
    rel = float(jnp.mean((res.x0 - ref) ** 2) / jnp.mean(ref ** 2))
    assert 0.0 < rel < 0.25, rel            # stale but bounded
    # the ledger prices the skipped deep blocks into the result
    assert res.flops < plan.flops(fcfg, batch=2)
    assert res.trace["cache_refreshes"] < res.trace["cache_steps"]


def test_policy_switch_never_recompiles(pipe):
    key = jax.random.PRNGKey(3)
    cond = jnp.asarray([2], jnp.int32)

    def run(spec):
        return pipe.sample(SamplingPlan(T=T, budget=1.0, solver="ddim",
                                        guidance_scale=1.5, cache=spec),
                           1, key, cond=cond).x0
    run(CacheSpec(policy="interval", interval=2, split=1))
    warm = pipe.cache_stats()
    # interval change, banded, proxy threshold sweep: all the same runner
    for spec in (CacheSpec(policy="interval", interval=3, split=1),
                 CacheSpec(policy="banded", bands=((50, 1),), interval=4,
                           split=1),
                 CacheSpec(policy="proxy", threshold=0.02, split=1),
                 CacheSpec(policy="proxy", threshold=0.3, split=1)):
        run(spec)
    after = pipe.cache_stats()
    assert after["compiled"] == warm["compiled"]
    assert after["misses"] == warm["misses"]


# ---------------------------------------------------------------------------
# Packed engine path: parity, slot lifecycle, metrics


def _reference(pipe, plans, level, label, key):
    # the engine's packed steps run the segment-aware Pallas kernel
    # ('auto' resolves to it on packed token streams); bit-exactness is a
    # within-backend guarantee, so the per-request reference samples at
    # the same backend (cross-backend ≤1e-4 parity lives in test_serving
    # / test_attention_backend)
    plan = dataclasses.replace(plans[level], attn_backend="pallas")
    return np.asarray(pipe.sample(plan, 1, key,
                                  cond=jnp.asarray([label], jnp.int32)).x0[0])


@pytest.mark.parametrize("solver", ["ddim", "ddpm"])
def test_engine_interval1_bit_identical_packed(pipe, solver):
    """Packed cached dispatches at interval=1 reproduce the UNCACHED
    per-request pipeline bit-for-bit — requests join and leave
    mid-flight, so slots churn while exactness holds."""
    plans = make_plans(solver)
    eng = ServingEngine(pipe, plans, max_tokens_per_step=256,
                        cache=CacheSpec(policy="interval", interval=1,
                                        split=1))
    spec = [(0, 0.6, 3), (1, 1.0, 7), (2, 0.6, 5)]
    keys = {rid: jax.random.PRNGKey(60 + rid) for rid, _, _ in spec}
    for rid, lvl, label in spec:
        eng.submit(cond=label, budget=lvl, key=keys[rid])
    results = []
    for _ in range(2):
        results += eng.step()
    late = eng.submit(cond=9, budget=1.0, key=jax.random.PRNGKey(99))
    spec.append((late, 1.0, 9))
    keys[late] = jax.random.PRNGKey(99)
    results += eng.run()
    assert len(results) == 4
    for r in results:
        _, lvl, label = next(s for s in spec if s[0] == r.request.id)
        ref = _reference(pipe, plans, lvl, label, keys[r.request.id])
        np.testing.assert_array_equal(np.asarray(r.x0), ref)
    assert eng.store.n_active == 0          # every slot released on retire


def test_engine_cache_drift_and_slot_reuse(pipe, flexi):
    _, fcfg, _ = flexi
    plans = make_plans("ddim")
    eng = ServingEngine(pipe, plans, max_tokens_per_step=256,
                        cache=CacheSpec(policy="interval", interval=2,
                                        split=1))
    key = jax.random.PRNGKey(5)
    eng.submit(cond=4, budget=1.0, key=key)
    (r1,) = eng.run()
    used = [(m, s) for m in eng.store.modes
            for s in range(eng.store.n_slots)
            if eng.store.owner_of(m, s) is not None]
    assert not used                          # released at retire
    ref = _reference(pipe, plans, 1.0, 4, key)
    rel = float(np.mean((np.asarray(r1.x0) - ref) ** 2) / np.mean(ref ** 2))
    assert 0.0 < rel < 0.25
    # join/leave slot recycling: the next request claims the same slot id
    eng.submit(cond=2, budget=1.0, key=jax.random.PRNGKey(6))
    eng.step()
    active = [(m, s) for m in eng.store.modes
              for s in range(eng.store.n_slots)
              if eng.store.owner_of(m, s) is not None]
    assert len(active) == 1 and active[0][1] == 0   # slot 0 reused
    eng.run()
    assert eng.store.n_active == 0
    # ledger: hits recorded, histogram populated, bytes gauge settled at 0
    cs = eng.metrics.cache_summary()
    assert cs["enabled"] and 0.0 < cs["hit_rate"] < 1.0
    assert cs["refresh_interval_hist"]
    assert eng.metrics.cache_bytes_resident == 0
    assert eng.metrics.summary()["cache_hit_rate"] == cs["hit_rate"]
    assert eng.store.bytes_total > 0


def test_precapture_warm_set(pipe):
    plans = make_plans("ddim")
    eng = ServingEngine(pipe, plans, max_tokens_per_step=256,
                        steps_per_dispatch=4)
    n = eng.precapture_warm_set(max_per_mode=1)
    # every small layout is now warm at every power-of-two depth
    for layout in eng.menu.layouts:
        if all(c <= 1 for _m, c in layout.groups):
            for k in (1, 2, 4):
                assert eng._is_warm(layout, k)
    assert eng.precapture_warm_set(max_per_mode=1) == 0   # idempotent
    assert n >= 0
