"""Data pipeline determinism/sharding + sharding-rule machinery."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.data import pipeline as dp
from repro.models.common import ParamSpec, spec_tree
from repro.runtime import sharding as shd


def test_lm_loader_deterministic_and_structured():
    fn = dp.make_lm_batch_fn(vocab=97, seq_len=32, global_batch=8)
    rng = np.random.default_rng(0)
    b1 = fn(0, 0, 1, np.random.default_rng(123))
    b2 = fn(0, 0, 1, np.random.default_rng(123))
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # learnable structure: targets at even positions are a function of tokens
    nxt = (b1["tokens"][:, ::2] * 31 + 7) % (97 // 16)
    assert (b1["targets"][:, ::2] == nxt).mean() > 0.9


def test_host_sharded_loader_prefetch():
    fn = dp.make_lm_batch_fn(vocab=17, seq_len=8, global_batch=4)
    loader = dp.HostShardedLoader(fn, shard_id=0, n_shards=2, prefetch=2)
    b = next(loader)
    assert b["tokens"].shape == (2, 8)      # global 4 over 2 shards
    loader.close()


def test_shards_differ_across_hosts():
    fn = dp.make_lm_batch_fn(vocab=97, seq_len=16, global_batch=8)
    b0 = fn(3, 0, 2, np.random.default_rng((0 * 1_000_003 + 3) * 65_537 + 0))
    b1 = fn(3, 1, 2, np.random.default_rng((0 * 1_000_003 + 3) * 65_537 + 1))
    assert not np.array_equal(b0["tokens"], b1["tokens"])


def test_dit_batch_class_signal():
    ls = (1, 16, 16, 4)
    fn = dp.make_dit_batch_fn(ls, num_classes=4, global_batch=8,
                              noise_scale=0.0)
    b = fn(0, 0, 1, np.random.default_rng(0))
    # same class → same pattern when noise-free
    c = b["cond"]
    for i in range(len(c)):
        for j in range(i + 1, len(c)):
            same = np.allclose(b["x0"][i], b["x0"][j])
            assert same == (c[i] == c[j])


def test_spec_tree_divisibility_guard():
    schema = {"w": ParamSpec((48, 100), ("embed", "mlp"))}
    specs = spec_tree(schema, {"embed": "data", "mlp": "model"},
                      axis_sizes={"data": 16, "model": 16})
    # 48 % 16 == 0 → sharded; 100 % 16 != 0 → dropped
    assert specs["w"] == P("data", None)


def test_spec_tree_duplicate_axis_dropped():
    schema = {"w": ParamSpec((64, 64), ("mlp", "heads"))}
    specs = spec_tree(schema, {"mlp": "model", "heads": "model"},
                      axis_sizes={"model": 16})
    assert specs["w"] == P("model", None)


def test_profile_resolution():
    assert shd.resolve_profile(get_config("mamba2-130m"), "auto") == "dp"
    assert shd.resolve_profile(get_config("grok-1-314b"), "auto") == "fsdp2d"
    assert shd.resolve_profile(get_config("mamba2-130m"), "tp_only") == "tp_only"


def test_batch_and_cache_spec_helpers():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    assert shd.batch_spec(4, mesh) == P(("data",))
    b_ax, s_ax = shd.seq_axes_for_cache(1, mesh)
    assert b_ax == ("data",) or b_ax is None or "model" in (s_ax if
        isinstance(s_ax, tuple) else (s_ax,))
