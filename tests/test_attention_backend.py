"""Segment-aware Pallas flash attention as the unified backend
(DESIGN.md §attention-backend).

Property tests (``interpret=True``): the kernel matches the dense XLA
reference to ≤1e-4 on randomized pack layouts (ragged segments, padding,
window/softcap combos, GQA ratios), the block map is always a superset
of the elementwise mask, pack-layout switches under a fixed bucket shape
never recompile, and the packed step family (ddim AND ddpm) is
backend-consistent end to end.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from prop import given
from repro.configs.base import AttnConfig
from repro.core import packing
from repro.core.flexify import flexify
from repro.core.scheduler import dit_block_flops, dit_nfe_flops
from repro.diffusion import schedule as sch
from repro.kernels.attention import costing
from repro.kernels.attention import mask as mask_mod
from repro.kernels.attention import ops as attn_ops
from repro.models import attention as attn_mod
from repro.models import dit as dit_mod
from repro.pipeline.packed import PackLayout, make_packed_step_fn
from repro.pipeline.plan import SamplingPlan

pytestmark = pytest.mark.tier1

TOL = 1e-4


# ---------------------------------------------------------------------------
# Strategies


def pack_case(rng: np.random.Generator):
    """Randomized pack layout: bucket shape, ragged segments + padding,
    feature combo, GQA ratio."""
    S = int(rng.choice([128, 192, 256]))
    bq = int(rng.choice([32, 64]))
    K = int(rng.choice([1, 2, 4]))
    H = K * int(rng.choice([1, 2]))
    hd = int(rng.choice([16, 32]))
    B = int(rng.integers(1, 3))
    softcap = float(rng.choice([0.0, 30.0]))
    causal = bool(rng.integers(0, 2))
    window = int(rng.choice([0, 0, bq]))     # windows only make sense causal
    segs = []
    for _ in range(B):
        n_seg = int(rng.integers(1, 9))
        lengths, left = [], S
        for i in range(n_seg):
            if left <= 1:
                break
            hi = max(2, left // max(1, n_seg - i))
            lengths.append(int(rng.integers(1, hi + 1)))
            left -= lengths[-1]
        segs.append(lengths)                  # rest of the row is padding
    return dict(S=S, bq=bq, B=B, H=H, K=K, hd=hd, softcap=softcap,
                causal=causal, window=window, segs=segs)


def _seg_array(segs, B, S):
    ids = np.full((B, S), -1, np.int32)
    for b, lengths in enumerate(segs):
        off = 0
        for i, n in enumerate(lengths):
            ids[b, off:off + n] = i
            off += n
    return ids


def _dense_ref(q, k, v, seg, cfg, *, causal, window, softcap):
    """XLA reference via the shared-bias dense path (the oracle the
    Pallas kernel must match on real tokens)."""
    B, S = q.shape[:2]
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    bias = attn_mod.make_attention_bias(
        pos, pos, causal=causal, window=window,
        q_segment=None if seg is None else jnp.asarray(seg),
        k_segment=None if seg is None else jnp.asarray(seg))
    return attn_mod.gqa_attend(q, k, v, bias,
                               dataclasses.replace(cfg,
                                                   logit_softcap=softcap))


# ---------------------------------------------------------------------------
# Kernel vs dense reference


@given(pack_case, n=12)
def test_flash_matches_dense_on_random_packs(case):
    S, B, H, K, hd = case["S"], case["B"], case["H"], case["K"], case["hd"]
    ks = jax.random.split(jax.random.PRNGKey(S + H + case["bq"]), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, K, hd), jnp.float32)
    seg = _seg_array(case["segs"], B, S)
    window = case["window"] if case["causal"] else 0
    cfg = AttnConfig(num_heads=H, num_kv_heads=K, head_dim=hd,
                     use_rope=False, logit_softcap=case["softcap"])
    got = attn_ops.flash_attention(
        q, k, v, causal=case["causal"], softcap=case["softcap"],
        window=window, segment_ids=jnp.asarray(seg),
        block_q=case["bq"], block_k=case["bq"])
    want = _dense_ref(q, k, v, seg, cfg, causal=case["causal"],
                      window=window, softcap=case["softcap"])
    real = seg >= 0
    err = np.abs(np.asarray(got) - np.asarray(want))[real]
    assert err.size and float(err.max()) <= TOL
    # padding rows: no visible key → the kernel returns exact zeros
    if (~real).any():
        np.testing.assert_array_equal(np.asarray(got)[~real], 0.0)


@given(pack_case, n=12)
def test_block_map_is_superset_of_elementwise_mask(case):
    S, B, bq = case["S"], case["B"], case["bq"]
    seg = _seg_array(case["segs"], B, S)
    window = case["window"] if case["causal"] else 0
    bm = np.asarray(mask_mod.attention_block_map(
        seg, seg, block_q=bq, block_k=bq, causal=case["causal"],
        window=window))
    pos = np.broadcast_to(np.arange(S, dtype=np.int32), (B, S))
    allowed = np.asarray(mask_mod.position_allowed(
        pos, pos, causal=case["causal"], window=window)
        & mask_mod.segment_allowed(seg, seg))
    nq = S // bq
    tiles = allowed.reshape(B, nq, bq, nq, bq).any(axis=(2, 4))
    # every elementwise-visible pair lives in an active block
    assert not (tiles & ~bm.astype(bool)).any()


def test_flash_matches_blocked_xla_path():
    """Drift guard: the kernel and ``blocked_gqa_attend`` share one mask
    helper — packed outputs must agree on real tokens."""
    B, S, H, hd = 2, 256, 4, 32
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    seg = _seg_array([[100, 60, 30], [128, 128]], B, S)
    cfg = AttnConfig(num_heads=H, num_kv_heads=H, head_dim=hd,
                     use_rope=False)
    pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    blocked = attn_mod.blocked_gqa_attend(
        q, k, v, positions=pos, causal=False, window=0, cfg=cfg,
        q_block=64, segment_ids=jnp.asarray(seg))
    flash = attn_ops.flash_attention(q, k, v, causal=False,
                                     segment_ids=jnp.asarray(seg),
                                     block_q=64, block_k=64)
    real = seg >= 0
    err = np.abs(np.asarray(blocked) - np.asarray(flash))[real]
    assert float(err.max()) <= TOL


def test_zero_recompile_across_pack_layouts():
    """Fixed bucket shape, different pack layouts → ONE executable (the
    block map and segment ids are traced data)."""
    B, S, H, hd = 1, 128, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    layouts = [[[128]], [[64, 64]], [[32, 32, 32, 32]], [[100, 20]], [[50]]]
    sizes = []
    for lay in layouts:
        seg = _seg_array(lay, B, S)
        attn_ops.flash_attention(q, k, v, causal=False,
                                 segment_ids=jnp.asarray(seg),
                                 block_q=32, block_k=32)
        sizes.append(attn_ops.compile_cache_size())
    assert sizes[-1] == sizes[0], f"recompiled across layouts: {sizes}"


# ---------------------------------------------------------------------------
# Backend resolution / plan surface


def test_resolve_backend_rules():
    r = attn_mod.resolve_backend
    assert r("auto", n_tokens=64, segmented=True) == "pallas"
    assert r("auto", n_tokens=64, segmented=False) == "dense"
    assert r("auto", n_tokens=10_000, segmented=False) == "pallas"
    assert r("auto", n_tokens=10_000, segmented=True,
             window_traced=True) == "xla-blocked"
    assert r("xla", n_tokens=64, segmented=True) == "dense"  # legacy alias
    assert r("dense", n_tokens=10_000, segmented=True) == "dense"
    with pytest.raises(ValueError, match="attn_backend"):
        r("cuda", n_tokens=64, segmented=False)
    with pytest.raises(ValueError, match="static window"):
        r("pallas", n_tokens=64, segmented=False, window_traced=True)


def test_plan_validates_attn_backend():
    with pytest.raises(ValueError, match="attn_backend"):
        SamplingPlan(T=4, attn_backend="triton")
    p = SamplingPlan(T=4, attn_backend="pallas")
    assert dataclasses.replace(p, attn_backend="dense").attn_backend == "dense"


# ---------------------------------------------------------------------------
# Packed forward + step family (e2e, ddim AND ddpm)


@pytest.fixture(scope="module")
def flexi(tiny_dit_cfg, trained_like_dit):
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    return fparams, fcfg, sch.linear_schedule(100)


def test_packed_mixed_forward_backend_consistent(flexi):
    fparams, fcfg, _ = flexi
    key = jax.random.PRNGKey(11)
    groups = ((0, 1), (1, 3))
    xs = [jax.random.normal(jax.random.fold_in(key, g),
                            (n,) + fcfg.dit.latent_shape)
          for g, (m, n) in enumerate(groups)]
    ts = [jnp.full((n,), 50, jnp.int32) for m, n in groups]
    conds = [jnp.arange(n, dtype=jnp.int32) for m, n in groups]
    out = {}
    for be in ("pallas", "dense", "auto"):
        out[be] = packing.packed_mixed_forward(fparams, fcfg, groups, xs, ts,
                                               conds, attn_backend=be)
    for g in range(len(groups)):
        err = np.abs(np.asarray(out["pallas"][g])
                     - np.asarray(out["dense"][g])).max()
        assert float(err) <= TOL
        # packed token streams default to the Pallas kernel
        np.testing.assert_array_equal(np.asarray(out["auto"][g]),
                                      np.asarray(out["pallas"][g]))


@pytest.mark.parametrize("solver", ["ddim", "ddpm"])
def test_packed_step_backend_consistent(flexi, solver):
    fparams, fcfg, sched = flexi
    layout = PackLayout(groups=((0, 1), (1, 2)), guided=True)
    key = jax.random.PRNGKey(13)
    xs = [jax.random.normal(jax.random.fold_in(key, 1),
                            (1,) + fcfg.dit.latent_shape),
          jax.random.normal(jax.random.fold_in(key, 2),
                            (2,) + fcfg.dit.latent_shape)]
    metas = [jnp.asarray([[[60], [40], [3]]], jnp.int32),
             jnp.asarray([[[60, 55], [40, 35], [1, 2]]], jnp.int32)]
    rng = np.random.default_rng(7)
    keys = [jnp.asarray(rng.integers(0, 2**31, (1, 1, 2)).astype(np.uint32)),
            jnp.asarray(rng.integers(0, 2**31, (1, 2, 2)).astype(np.uint32))]
    outs = {}
    for be in ("pallas", "dense"):
        fn = jax.jit(make_packed_step_fn(fcfg, sched, layout, solver=solver,
                                         attn_backend=be))
        outs[be] = fn(fparams, tuple(xs), tuple(metas), tuple(keys))
    for a, b in zip(outs["pallas"], outs["dense"]):
        assert float(np.abs(np.asarray(a) - np.asarray(b)).max()) <= TOL


# ---------------------------------------------------------------------------
# Analytic ledger


def _serving_scale_cfg(tiny_dit_cfg):
    """Analytic-only config at real serving shapes (1024-token rows, so
    the 128-token default block tiles show cross-segment sparsity);
    never instantiated as weights."""
    return dataclasses.replace(
        tiny_dit_cfg,
        dit=dataclasses.replace(tiny_dit_cfg.dit,
                                latent_shape=(1, 64, 64, 4),
                                flex_patch_sizes=((1, 4, 4),)))


def test_block_sparse_pack_pricing(tiny_dit_cfg):
    fcfg = _serving_scale_cfg(tiny_dit_cfg)
    N0 = dit_mod.tokens_for_mode(fcfg, 0)
    r = packing.pack_ratio(fcfg, 1)
    dense_row = packing.packed_row_flops(fcfg, [1] * r, capacity=N0)
    sparse_row = packing.packed_row_flops(fcfg, [1] * r, capacity=N0,
                                          attn_backend="pallas")
    # cross-segment blocks are skipped → strictly cheaper than dense
    assert sparse_row < dense_row
    # a single full-row segment has nothing to skip (block-aligned)
    assert packing.packed_row_flops(fcfg, [0], capacity=N0,
                                    attn_backend="pallas") \
        == pytest.approx(packing.packed_row_flops(fcfg, [0], capacity=N0))
    # the saving is exactly the masked-out score tiles, per layer
    active, total = packing.pack_attention_block_stats(fcfg, [1] * r, N0)
    assert active < total
    d, L = fcfg.d_model, fcfg.num_layers
    bq, bk = costing.effective_blocks(N0)
    expect = L * (total - active) * costing.dense_attention_flops(bq, bk, d)
    assert dense_row - sparse_row == pytest.approx(expect)


def test_request_cost_prices_backend(tiny_dit_cfg):
    from repro.serving import request_cost_flops
    fcfg = _serving_scale_cfg(tiny_dit_cfg)
    plan = SamplingPlan(T=4, budget=1.0, guidance_scale=1.5)
    dense = request_cost_flops(fcfg, plan, attn_backend="dense")
    pallas = request_cost_flops(fcfg, plan, attn_backend="pallas")
    # single requests only round up to block granularity — never cheaper
    assert pallas >= dense
    # the default follows the plan's backend ('auto' → pallas pricing)
    assert request_cost_flops(fcfg, plan) == pallas
    assert dit_nfe_flops(fcfg, 0, attn_backend="auto") \
        == dit_nfe_flops(fcfg, 0, attn_backend="pallas")
    assert dit_block_flops(fcfg, 64, attn_backend="dense") \
        == dit_block_flops(fcfg, 64)


def test_metrics_skip_rate():
    from repro.serving.metrics import ServingMetrics
    m = ServingMetrics()
    assert m.attn_block_skip_rate == 0.0
    m.record_attention_blocks(6, 16)
    m.record_attention_blocks(2, 4)
    assert m.attn_block_skip_rate == pytest.approx(1.0 - 8 / 20)
    assert m.summary()["attn_block_skip_rate"] == m.attn_block_skip_rate
