"""Flexification invariants (§3.1 / §3.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flexify, merge_lora, trainable_mask
from repro.models import dit as dit_mod

pytestmark = pytest.mark.tier1


def _fwd(params, cfg, mode=0, key=jax.random.PRNGKey(7)):
    B = 2
    F, H, W, C = cfg.dit.latent_shape
    x = jax.random.normal(key, (B, F, H, W, C))
    t = jnp.asarray([10.0, 500.0])
    y = jnp.asarray([1, 3])
    return dit_mod.dit_forward(params, x, t, y, cfg, mode=mode)


def test_shared_recipe_mode0_preservation(tiny_dit_cfg, trained_like_dit):
    base = _fwd(trained_like_dit, tiny_dit_cfg)
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    out0 = _fwd(fparams, fcfg, mode=0)
    # shared recipe: exact up to float roundoff of the PI-resize lift
    np.testing.assert_allclose(np.asarray(out0), np.asarray(base),
                               atol=1e-4, rtol=1e-4)


def test_lora_recipe_mode0_bit_exact(tiny_dit_cfg, trained_like_dit):
    base = _fwd(trained_like_dit, tiny_dit_cfg)
    lparams, lcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)],
                            lora_rank=4)
    out0 = _fwd(lparams, lcfg, mode=0)
    np.testing.assert_array_equal(np.asarray(out0), np.asarray(base))


def test_weak_mode_runs_and_differs(tiny_dit_cfg, trained_like_dit):
    fparams, fcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)])
    out0 = _fwd(fparams, fcfg, mode=0)
    out1 = _fwd(fparams, fcfg, mode=1)
    assert out1.shape == out0.shape
    assert np.isfinite(np.asarray(out1)).all()
    assert np.abs(np.asarray(out1) - np.asarray(out0)).max() > 1e-6


def test_merged_lora_equals_unmerged(tiny_dit_cfg, trained_like_dit):
    lparams, lcfg = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)],
                            lora_rank=4)
    # give LoRA b some mass so the merge actually changes weights
    lparams["blocks"]["lora"]["attn"]["wq"]["b"] = jax.random.normal(
        jax.random.PRNGKey(5),
        lparams["blocks"]["lora"]["attn"]["wq"]["b"].shape) * 0.1
    unmerged = _fwd(lparams, lcfg, mode=1)
    merged = merge_lora(lparams, lcfg, 1)
    out = _fwd(merged, lcfg, mode=1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(unmerged),
                               atol=2e-4, rtol=2e-4)


def test_trainable_mask_recipes(tiny_dit_cfg, trained_like_dit):
    lparams, _ = flexify(trained_like_dit, tiny_dit_cfg, [(1, 4, 4)],
                         lora_rank=4)
    m = trainable_mask(lparams, "lora")
    assert m["blocks"]["lora"]["attn"]["wq"]["a"] is True
    assert m["blocks"]["attn"]["wq"] is False
    assert m["embed"]["w_flex"] is False
    assert m["embed_new"]["m1"]["w"] is True
    m2 = trainable_mask(lparams, "shared")
    assert all(jax.tree.leaves(m2))


def test_video_temporal_flexify(tiny_dit_cfg, trained_like_dit):
    """3D patches incl. temporal weak mode (paper §4.3)."""
    import dataclasses
    cfg = dataclasses.replace(
        tiny_dit_cfg, dit=dataclasses.replace(
            tiny_dit_cfg.dit, latent_shape=(4, 16, 16, 4)))
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
    params["deembed"]["w_flex"] = jax.random.normal(
        jax.random.PRNGKey(1), params["deembed"]["w_flex"].shape) * 0.1
    base = _fwd(params, cfg)
    fparams, fcfg = flexify(params, cfg, [(2, 2, 2), (1, 4, 4)])
    out0 = _fwd(fparams, fcfg, mode=0)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(base), atol=1e-5)
    for mode in (1, 2):
        out = _fwd(fparams, fcfg, mode=mode)
        assert out.shape == base.shape and np.isfinite(np.asarray(out)).all()
