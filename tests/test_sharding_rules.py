"""Direct unit tests for runtime/sharding.py: dp_axes / batch_spec /
token_spec / rules_for (incl. the sequence-parallel 'tokens' rule) and the
jax-version-portable get_abstract_mesh shim behind ``constrain`` —
previously only exercised indirectly through train/serve paths."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import get_config
from repro.runtime import sharding as sh

pytestmark = pytest.mark.tier1


def _mesh(shape, axes):
    devs = np.asarray(jax.devices()[:1]).reshape(shape)
    return Mesh(devs, axes)


def test_dp_axes_and_axis_sizes():
    m = _mesh((1, 1), ("data", "model"))
    assert sh.dp_axes(m) == ("data",)
    assert sh.axis_sizes(m) == {"data": 1, "model": 1}
    m3 = _mesh((1, 1, 1), ("pod", "data", "model"))
    assert sh.dp_axes(m3) == ("pod", "data")
    ms = _mesh((1, 1), ("data", "seq"))
    assert sh.dp_axes(ms) == ("data",)     # 'seq' is never a DP axis


def test_batch_spec_divisibility():
    """batch_spec greedily takes data axes whose cumulative product divides
    the batch; on 1-sized axes everything divides."""
    m = _mesh((1, 1), ("data", "model"))
    assert sh.batch_spec(4, m) == P(("data",))
    assert sh.batch_spec(3, m) == P(("data",))
    # a real multi-device shape check needs fake devices; the pure
    # arithmetic is covered via the distributed suite's meshes


def test_token_spec():
    ms = _mesh((1, 1), ("data", "seq"))
    assert sh.token_spec(4, ms) == P(("data",), "seq")
    mm = _mesh((1, 1), ("data", "model"))
    assert sh.token_spec(4, mm) == P(("data",), None)


def test_rules_for_profiles_and_seq_axis():
    cfg = get_config("dit-xl-2").reduced()     # tiny → resolves to 'dp'
    ms = _mesh((1, 1), ("data", "seq"))
    rules = sh.rules_for(cfg, ms, "auto")
    assert rules["embed"] is None              # dp: replicated weights
    assert rules["tokens"] == sh.SEQ_AXIS      # activations scatter on seq
    mm = _mesh((1, 1), ("data", "model"))
    assert sh.rules_for(cfg, mm, "auto")["tokens"] is None
    big = get_config("dit-xl-2")               # 675M... still under 3e9 → dp
    assert sh.resolve_profile(big, "auto") in ("dp", "fsdp2d")
    r2 = sh.rules_for(cfg, ms, "fsdp2d")
    assert r2["embed"] == ("data",) and r2["mlp"] == "model"
    assert r2["tokens"] == sh.SEQ_AXIS
    r3 = sh.rules_for(cfg, ms, "tp_only")
    assert r3["embed"] is None and r3["heads"] == "model"
    assert r3["tokens"] == sh.SEQ_AXIS


def test_base_profile_strips_suffixes():
    assert sh.base_profile("fsdp2d_sp") == "fsdp2d"
    assert sh.base_profile("tp_only_kvq") == "tp_only"
    assert sh.base_profile("dp") == "dp"


def test_ambient_mesh_shim_and_constrain_noop():
    """Outside any mesh context the shim reports no axes and ``constrain``
    is the identity (keeps single-device tests mesh-free)."""
    assert sh._ambient_axis_names() == ()
    x = jnp.ones((2, 2))
    assert sh.constrain(x, P("data", None)) is x
    # inside a `with mesh:` context the shim surfaces the axis names and
    # constrain filters specs down to the axes that exist
    m = _mesh((1, 1), ("data", "model"))
    with m:
        assert set(sh._ambient_axis_names()) == {"data", "model"}
        y = sh.constrain(x, P("data", "nope"))          # unknown axis dropped
        assert y.shape == x.shape
        z = sh.constrain(x, P(("pod", "data"), None))   # tuple filtering
        assert z.shape == x.shape
