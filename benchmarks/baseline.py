"""BENCH-json baseline gate (no network, pure threshold checks).

``benchmarks/baselines.json`` records, per bench name, bounds on the
analytic metrics a suite must hold, e.g.::

    {"attention": {"attn_flops_reduction_frac": {"min": 0.30},
                   "attn_flops_sparse": {"max": 2.1e9, "rtol": 0.05}}}

``check_baseline(name, metrics)`` compares the freshly computed BENCH
dict against those bounds and raises :class:`BaselineRegression` on any
violation; ``run.py`` turns that into a non-zero exit so CI fails loudly
when a change regresses the analytic attention-FLOPs ledger (silent cost
regressions are how block-sparse savings rot).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict

BASELINES_PATH = Path(__file__).resolve().parent / "baselines.json"


class BaselineRegression(RuntimeError):
    """A BENCH metric violated its recorded baseline bound."""


_MISSING = object()


def _resolve(metrics: object, key: str) -> object:
    """Dotted-path lookup into nested BENCH dicts and lists:
    ``engine.recompiles_after_warmup``, ``results.3.parallel_efficiency``.
    Flat keys containing dots still win if present verbatim."""
    if isinstance(metrics, dict) and key in metrics:
        return metrics[key]
    node = metrics
    for part in key.split("."):
        if isinstance(node, dict) and part in node:
            node = node[part]
        elif isinstance(node, (list, tuple)) and part.lstrip("-").isdigit():
            try:
                node = node[int(part)]
            except IndexError:
                return _MISSING
        else:
            return _MISSING
    return node


def check_baseline(name: str, metrics: Dict[str, object],
                   path: Path = BASELINES_PATH) -> None:
    """Validate ``metrics`` against the recorded bounds for ``name``.

    Bound spec per metric key: ``min`` (value must be >=), ``max``
    (value must be <=); ``rtol`` loosens either bound by a relative
    slack (default 0 — analytic numbers are deterministic). Keys may be
    dotted paths into nested dicts / list indices. A bench name with no
    recorded baselines passes vacuously.
    """
    if not path.exists():
        return
    bounds = json.loads(path.read_text()).get(name, {})
    failures = []
    for key, spec in bounds.items():
        raw = _resolve(metrics, key)
        if raw is _MISSING:
            failures.append(f"{key}: missing from BENCH output")
            continue
        val = float(raw)
        rtol = float(spec.get("rtol", 0.0))
        if "min" in spec and val < float(spec["min"]) * (1.0 - rtol):
            failures.append(f"{key}: {val:.6g} below baseline min "
                            f"{float(spec['min']):.6g}")
        if "max" in spec and val > float(spec["max"]) * (1.0 + rtol):
            failures.append(f"{key}: {val:.6g} above baseline max "
                            f"{float(spec['max']):.6g}")
    if failures:
        raise BaselineRegression(
            f"bench {name!r} regressed vs {path.name}: " + "; ".join(failures))
