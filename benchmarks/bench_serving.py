"""Continuous-batching engine vs the fixed-slot baseline (DESIGN.md
§serving).

The workload is what FlexiDiT makes possible: a FINE budget menu (one
level per distinct T_weak — the full quality dial) over a bursty Poisson
arrival trace. The fixed-slot baseline must batch per level (a level is
a compiled plan) and pad every batch to ``SLOT_B``; the engine packs
whatever mix is in flight token-wise, because per step only the patch
MODE matters, not the budget level.

Phases:

* **drain** (deterministic) — the full request set is available up
  front; both systems drain it. Used to calibrate capacity and to assert
  ZERO recompiles after bucket warmup (identical replay → identical
  layout/k trajectory → every executable hot).
* **poisson** (measured) — the same requests arrive at ~85% of the
  engine's drain rate, replayed against the wall clock for both
  systems. Reports useful tokens/s (token-steps of real requests only —
  padding and dummy slots count for neither side), p50/p99 latency, and
  packing efficiency; asserts the engine's tokens/s is >= 1.3x the
  baseline's.

The smoke model is sized (4 layers, d=128) so per-step compute dominates
dispatch overhead — the regime real serving runs in.
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

T = 12
TRAIN_T = 100
N_REQ = 24
SLOT_B = 4                     # fixed-slot baseline batch size
MAX_TOKENS = 4096              # engine step budget (8 full CFG requests)
LOAD = 0.85                    # poisson rate as a fraction of engine rate
REPEATS = 4                    # best-of-N timing (CPU wall noise)


def _bench_cfg():
    from repro.configs import get_config
    base = get_config("dit-xl-2").reduced()
    return dataclasses.replace(
        base, num_layers=4, d_model=128, d_ff=512,
        attn=dataclasses.replace(base.attn, num_heads=8, num_kv_heads=8,
                                 head_dim=16))


def bench_serving() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks import common as C
    from benchmarks.baseline import check_baseline
    from repro.core.scheduler import FlexiSchedule
    from repro.diffusion import schedule as sch
    from repro.models import dit as dit_mod
    from repro.pipeline import FlexiPipeline, SamplingPlan
    from repro.serving import BucketMenu, ServingEngine

    cfg = _bench_cfg()
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
    pipe = FlexiPipeline(params, cfg, sch.linear_schedule(TRAIN_T))
    # one level per distinct T_weak: the full quality dial
    plans = {}
    for tw in range(T):
        # this bench measures SCHEDULING (continuous batching vs fixed
        # slots), so both sides hold the attention backend equal: the
        # engine's default interpret-mode Pallas kernel is a CPU stand-in
        # for the TPU kernel and would skew a same-host wall-clock race
        # against the baseline's compiled XLA path (bench_attention owns
        # the backend comparison)
        plan = SamplingPlan(T=T, budget=FlexiSchedule.weak_first(T, tw),
                            guidance_scale=1.5, attn_backend="dense")
        plan.validate(cfg)
        plans[round(plan.relative_compute(cfg), 3)] = plan
    levels = sorted(plans)
    level_tokens = {}
    for b, plan in plans.items():
        fs = plan.resolve_schedule(cfg)
        level_tokens[b] = 2 * sum(
            n * dit_mod.tokens_for_mode(cfg, m) for m, n in fs.phases)
    rng = np.random.default_rng(0)
    reqs = [(int(rng.integers(0, cfg.dit.num_classes)),
             levels[int(rng.integers(0, len(levels)))])
            for _ in range(N_REQ)]
    useful_tokens = sum(level_tokens[lvl] for _, lvl in reqs)
    menu = BucketMenu(cfg, (0, 1), MAX_TOKENS, guided=True)

    # ------------------------------------------------------------------
    # Drain phase: capacity + compile-once

    def drain_engine():
        engine = ServingEngine(pipe, plans, max_tokens_per_step=MAX_TOKENS,
                               menu=menu)
        for i, (label, lvl) in enumerate(reqs):
            engine.submit(cond=label, budget=lvl,
                          key=jax.random.fold_in(jax.random.PRNGKey(7), i))
        results = engine.run()
        jax.block_until_ready(results[-1].x0)
        return engine, results

    def drain_baseline():
        queues = {b: [] for b in levels}
        for label, lvl in reqs:
            queues[lvl].append(label)
        batches = slots = 0
        while any(queues.values()):
            b = max(queues, key=lambda k: len(queues[k]))
            labels = [queues[b].pop(0)
                      for _ in range(min(SLOT_B, len(queues[b])))]
            labels += [labels[-1]] * (SLOT_B - len(labels))
            res = pipe.sample(plans[b], SLOT_B,
                              jax.random.fold_in(jax.random.PRNGKey(8),
                                                 batches),
                              cond=jnp.asarray(labels, jnp.int32))
            jax.block_until_ready(res.x0)
            batches += 1
            slots += SLOT_B
        return batches, slots

    drain_engine()                                 # bucket warmup (compiles)
    drain_baseline()                               # compile phase runners
    warm = pipe.cache_stats()
    dt_eng_drain = dt_base_drain = float("inf")
    for _ in range(REPEATS):                       # interleave: fair under
        t0 = time.perf_counter()                   # machine-load drift
        engine, results = drain_engine()
        dt_eng_drain = min(dt_eng_drain, time.perf_counter() - t0)
        t0 = time.perf_counter()
        batches, slots = drain_baseline()
        dt_base_drain = min(dt_base_drain, time.perf_counter() - t0)
    recompiles = pipe.cache_stats()["compiled"] - warm["compiled"]
    assert recompiles == 0, \
        f"{recompiles} recompiles after bucket warmup (layouts must be hot)"
    assert len(results) == N_REQ
    drain_eff = engine.metrics.packing_efficiency
    drain_speedup = dt_base_drain / dt_eng_drain
    C.csv_row("serving_drain", dt_eng_drain * 1e6,
              f"engine_tps={useful_tokens / dt_eng_drain:.0f};"
              f"baseline_tps={useful_tokens / dt_base_drain:.0f};"
              f"speedup={drain_speedup:.2f};"
              f"slot_fill={N_REQ / slots:.2f};packing_eff={drain_eff:.3f};"
              f"recompiles_after_warmup={recompiles}")

    # ------------------------------------------------------------------
    # Poisson phase: the measured comparison

    lam = LOAD * N_REQ / dt_eng_drain              # requests per second
    arrivals = np.cumsum(rng.exponential(1.0 / lam, size=N_REQ))

    def replay_engine(allow_cold=True):
        engine = ServingEngine(pipe, plans, max_tokens_per_step=MAX_TOKENS,
                               menu=menu, allow_cold=allow_cold)
        t0 = time.perf_counter()
        nxt = 0
        while len(engine.metrics.requests) < N_REQ:
            now = time.perf_counter() - t0
            while nxt < N_REQ and arrivals[nxt] <= now:
                label, lvl = reqs[nxt]
                engine.submit(cond=label, budget=lvl,
                              key=jax.random.fold_in(jax.random.PRNGKey(9),
                                                     nxt))
                nxt += 1
            if engine.idle:
                time.sleep(1e-3)
                continue
            engine.step()
        return engine, time.perf_counter() - t0

    # online-shape warmup: mid-trace cohort mixes hit (layout, k) combos
    # the drain never forms; capture them off the clock, as a serving
    # deployment would at startup. ``precapture_warm_set`` compiles AND
    # executes the whole small-cohort bucket ladder (every menu layout
    # with per-mode counts <= 2, at every power-of-two micro-step depth)
    # so mid-trace Poisson cohorts land on exact fine layouts instead of
    # coarse fallbacks, and the frozen planner always has a warm bucket.
    # The measured replay then runs FROZEN (allow_cold=False): only warm
    # executables, zero compile stalls — asserted via cache_stats.
    pre = ServingEngine(pipe, plans, max_tokens_per_step=MAX_TOKENS,
                        menu=menu).precapture_warm_set(max_per_mode=2)
    replay_engine()
    replay_engine()
    warm_online = pipe.cache_stats()["compiled"]
    engine, dt_eng = replay_engine(allow_cold=False)
    online_recompiles = pipe.cache_stats()["compiled"] - warm_online
    assert online_recompiles == 0, \
        f"{online_recompiles} compiles during the frozen online replay"
    eng_tps = useful_tokens / dt_eng
    eng_lat = engine.metrics.latency_percentiles()
    eng_eff = engine.metrics.packing_efficiency

    base_lat = []
    t0 = time.perf_counter()
    nxt = 0
    queues = {b: [] for b in levels}
    n_batches = 0
    while nxt < N_REQ or any(queues.values()):
        now = time.perf_counter() - t0
        while nxt < N_REQ and arrivals[nxt] <= now:
            label, lvl = reqs[nxt]
            queues[lvl].append((label, arrivals[nxt]))
            nxt += 1
        if not any(queues.values()):
            time.sleep(1e-3)
            continue
        b = max(queues, key=lambda k: len(queues[k]))
        batch = [queues[b].pop(0)
                 for _ in range(min(SLOT_B, len(queues[b])))]
        labels = [l for l, _ in batch]
        labels += [labels[-1]] * (SLOT_B - len(labels))
        res = pipe.sample(plans[b], SLOT_B,
                          jax.random.fold_in(jax.random.PRNGKey(10),
                                             n_batches),
                          cond=jnp.asarray(labels, jnp.int32))
        jax.block_until_ready(res.x0)
        done = time.perf_counter() - t0
        base_lat.extend(done - arr for _, arr in batch)
        n_batches += 1
    dt_base = time.perf_counter() - t0
    base_tps = useful_tokens / dt_base
    base_p = {f"p{q}": float(np.percentile(base_lat, q)) for q in (50, 99)}
    speedup = eng_tps / base_tps

    C.csv_row("serving_poisson", dt_eng * 1e6,
              f"tokens_per_s={eng_tps:.0f};baseline_tps={base_tps:.0f};"
              f"speedup={speedup:.2f};packing_eff={eng_eff:.3f};"
              f"p50={eng_lat['p50']:.3f}s;p99={eng_lat['p99']:.3f}s;"
              f"baseline_p50={base_p['p50']:.3f}s;"
              f"baseline_p99={base_p['p99']:.3f}s")
    bench = {
        "name": "serving_engine", "arch": "dit-xl-2:reduced+4L128d",
        "T": T, "requests": N_REQ, "levels": levels,
        "max_tokens_per_step": MAX_TOKENS, "slot_batch": SLOT_B,
        "poisson_rate_per_s": lam,
        "engine": {"tokens_per_s": eng_tps, "wall_s": dt_eng,
                   "packing_efficiency": eng_eff,
                   "attn_backend": engine.attn_backend,
                   "attn_block_skip_rate":
                       engine.metrics.attn_block_skip_rate,
                   "p50_s": eng_lat["p50"], "p99_s": eng_lat["p99"],
                   "drain_tokens_per_s": useful_tokens / dt_eng_drain,
                   "recompiles_after_warmup": recompiles,
                   "frozen_online_compiles": online_recompiles,
                   "precaptured_small_cohort_executables": pre,
                   # activation cache off in this bench (bench_cache
                   # covers on/off); the ledger fields ride along so the
                   # BENCH schema is stable either way
                   "cache": engine.metrics.cache_summary()},
        "baseline": {"tokens_per_s": base_tps, "wall_s": dt_base,
                     "slot_fill_drain": N_REQ / slots,
                     "p50_s": base_p["p50"], "p99_s": base_p["p99"],
                     "drain_tokens_per_s": useful_tokens / dt_base_drain},
        "speedup_tokens_per_s_drain": drain_speedup,
        "speedup_tokens_per_s_poisson": speedup,
    }
    print("BENCH " + json.dumps(bench))
    check_baseline("serving_engine", bench)
    assert drain_speedup >= 1.3, \
        f"engine only {drain_speedup:.2f}x the fixed-slot baseline at " \
        f"saturation (need >=1.3x)"


if __name__ == "__main__":
    bench_serving()
