"""Shared benchmark infrastructure: tiny trained FlexiDiT fixtures (cached
on disk), FID/CLIP proxy metrics, and timing helpers.

Proxy metrics (offline container — no Inception/CLIP weights): Fréchet
distance over a fixed random-projection feature space for FID; cosine
alignment between the conditioning concept embedding and a fixed projection
of the generated image for CLIP score. Same mathematical form; trends (not
absolute values) are the reproduction target (DESIGN.md §6).
"""
from __future__ import annotations

import sys
import time
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import AttnConfig, DiTConfig, ModelConfig, TrainConfig
from repro.core import flexify
from repro.data import pipeline as dp
from repro.diffusion import schedule as sch
from repro.launch import steps as st
from repro.models import dit as dit_mod
from repro.optim import adamw

CACHE = Path("/tmp/repro_bench_cache")
T_TRAIN = 100          # diffusion timesteps for bench models
LATENT = (1, 16, 16, 4)
N_CLASSES = 8


def tiny_cfg(conditioning: str = "class", latent=LATENT,
             flex=((1, 4, 4),), name: str = "bench-dit") -> ModelConfig:
    return ModelConfig(
        name=name, family="dit", num_layers=3, d_model=96, d_ff=384,
        vocab_size=0, attn=AttnConfig(6, 6, 16, use_rope=False),
        dit=DiTConfig(latent_shape=latent, patch_size=(1, 2, 2),
                      flex_patch_sizes=tuple(flex),
                      underlying_patch_size=tuple(
                          max(p[i] for p in ((1, 2, 2),) + tuple(flex))
                          for i in range(3)),
                      conditioning=conditioning, num_classes=N_CLASSES,
                      text_len=8, text_dim=96, learn_sigma=False),
        mlp_activation="gelu", norm_type="layernorm",
        param_dtype="float32", compute_dtype="float32", remat="none")


def get_flexidit(conditioning: str = "class", latent=LATENT,
                 flex=((1, 4, 4),), steps: int = 500, name="bench-dit",
                 seed: int = 0) -> Tuple[Any, ModelConfig, sch.DiffusionSchedule]:
    """Train (or load cached) a tiny FlexiDiT: pre-train at p=2, then
    alternate modes (paper §4.1 recipe)."""
    cfg = tiny_cfg(conditioning, latent, flex, name)
    sched = sch.linear_schedule(T_TRAIN)
    tag = f"{name}_{conditioning}_{'-'.join(map(str, np.ravel(flex)))}_{steps}"
    ck = Checkpointer(CACHE / tag, async_save=False)
    fcfg = flexify(dit_mod.init_dit(cfg, jax.random.PRNGKey(seed)), cfg,
                   list(flex))[1]
    if ck.latest_step() is not None:
        state, _ = ck.restore()
        params = jax.tree.map(jnp.asarray, state["params"])
        return params, fcfg, sched

    tc = TrainConfig(learning_rate=2e-3, warmup_steps=20, total_steps=steps)
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(seed))
    if conditioning == "class":
        make_batch = dp.make_dit_batch_fn(latent, N_CLASSES, 32, 0.15)
    else:
        make_batch = dp.make_text_cond_batch_fn(latent, 8, 96, 32)
    opt = adamw.init_opt_state(params)
    pre = jax.jit(st.make_dit_train_step(cfg, tc, sched))
    key = jax.random.PRNGKey(seed + 1)
    half = steps // 2
    for i in range(half):
        b = make_batch(i, 0, 1, np.random.default_rng(i))
        batch = {"x0": jnp.asarray(b["x0"]), "cond": jnp.asarray(b["cond"])}
        params, opt, m = pre(params, opt, batch, jax.random.fold_in(key, i))
    fparams, fcfg = flexify(params, cfg, list(flex))
    opt = adamw.init_opt_state(fparams)
    mode_steps = [jax.jit(st.make_dit_train_step(fcfg, tc, sched, mode=m))
                  for m in range(1 + len(flex))]
    for i in range(half, steps):
        b = make_batch(i, 0, 1, np.random.default_rng(i))
        batch = {"x0": jnp.asarray(b["x0"]), "cond": jnp.asarray(b["cond"])}
        fn = mode_steps[i % len(mode_steps)]
        fparams, opt, m = fn(fparams, opt, batch, jax.random.fold_in(key, i))
    ck.save(steps, {"params": fparams})
    return fparams, fcfg, sched


# ---------------------------------------------------------------------------
# Proxy metrics


_FEAT_KEY = jax.random.PRNGKey(1234)


def features(x: np.ndarray, dim: int = 64) -> np.ndarray:
    """Fixed random-projection + nonlinearity feature map for FID-proxy."""
    flat = np.asarray(x, np.float32).reshape(x.shape[0], -1)
    rng = np.random.default_rng(42)
    W = rng.normal(size=(flat.shape[1], dim)).astype(np.float32) \
        / np.sqrt(flat.shape[1])
    h = flat @ W
    return np.concatenate([np.tanh(h), h], axis=1)


def frechet(a: np.ndarray, b: np.ndarray) -> float:
    """Fréchet distance between feature Gaussians (FID form, real sqrtm via
    eigendecomposition of the product)."""
    mu1, mu2 = a.mean(0), b.mean(0)
    c1 = np.cov(a, rowvar=False) + 1e-6 * np.eye(a.shape[1])
    c2 = np.cov(b, rowvar=False) + 1e-6 * np.eye(b.shape[1])
    diff = ((mu1 - mu2) ** 2).sum()
    # sqrtm(c1 @ c2) trace via eigenvalues of the PSD-similar product
    s1_vals, s1_vecs = np.linalg.eigh(c1)
    s1_sqrt = (s1_vecs * np.sqrt(np.maximum(s1_vals, 0))) @ s1_vecs.T
    inner = s1_sqrt @ c2 @ s1_sqrt
    vals = np.linalg.eigvalsh(inner)
    tr_sqrt = np.sqrt(np.maximum(vals, 0)).sum()
    return float(diff + np.trace(c1) + np.trace(c2) - 2 * tr_sqrt)


def fid_proxy(samples: np.ndarray, reference: np.ndarray) -> float:
    return frechet(features(samples), features(reference))


def clip_proxy(samples: np.ndarray, concepts: np.ndarray) -> float:
    """Cosine alignment between image features and concept pattern features."""
    f_img = features(samples)
    f_ref = features(concepts)
    num = (f_img * f_ref).sum(1)
    den = np.linalg.norm(f_img, axis=1) * np.linalg.norm(f_ref, axis=1)
    return float((num / np.maximum(den, 1e-9)).mean())


def ssim(a: np.ndarray, b: np.ndarray) -> float:
    """Global SSIM (single window) per sample, averaged."""
    a = a.reshape(a.shape[0], -1).astype(np.float64)
    b = b.reshape(b.shape[0], -1).astype(np.float64)
    mu_a, mu_b = a.mean(1), b.mean(1)
    va, vb = a.var(1), b.var(1)
    cov = ((a - mu_a[:, None]) * (b - mu_b[:, None])).mean(1)
    c1, c2 = 0.01 ** 2, 0.03 ** 2
    s = ((2 * mu_a * mu_b + c1) * (2 * cov + c2) /
         ((mu_a ** 2 + mu_b ** 2 + c1) * (va + vb + c2)))
    return float(s.mean())


def reference_set(n: int = 128, conditioning="class", latent=LATENT
                  ) -> Tuple[np.ndarray, np.ndarray]:
    if conditioning == "class":
        mk = dp.make_dit_batch_fn(latent, N_CLASSES, n, 0.15)
    else:
        mk = dp.make_text_cond_batch_fn(latent, 8, 96, n)
    b = mk(0, 0, 1, np.random.default_rng(555))
    return b["x0"], b["cond"]


def timeit(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    """Median wall μs per call (block_until_ready)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(times))


def csv_row(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}", flush=True)


# ---------------------------------------------------------------------------
# Generation via the unified pipeline API (DESIGN.md §pipeline)

_PIPELINES: Dict[Tuple[int, str, int], Any] = {}


def get_pipeline(params, cfg, sched):
    """One FlexiPipeline per (params, cfg, schedule) for the process, so
    benches sweeping budgets reuse the same compiled executables. Keyed by
    object identity (the cached pipeline keeps both alive, so ids are
    stable) — two same-length schedules with different betas don't alias."""
    from repro.pipeline import FlexiPipeline
    key = (id(params), cfg.name, id(sched))
    pipe = _PIPELINES.get(key)
    if pipe is None:
        pipe = _PIPELINES[key] = FlexiPipeline(params, cfg, sched)
    return pipe


def generate(params, cfg, sched, *, T: int, T_weak: int, n: int,
             key, cfg_scale: float = 1.5, weak_guidance: bool = False,
             solver: str = "ddim", weak_mode: int = 1,
             weak_last: bool = False, conditioning="class",
             cond=None) -> np.ndarray:
    """Sample n images with the weak→powerful scheduler (or reversed)."""
    from repro.core import FlexiSchedule
    from repro.pipeline import SamplingPlan

    fs = (FlexiSchedule.powerful_first(T, T_weak, weak_mode) if weak_last
          else FlexiSchedule.weak_first(T, T_weak, weak_mode))
    plan = SamplingPlan(
        T=T, budget=fs, solver=solver, guidance_scale=cfg_scale,
        guidance_kind="weak_cond" if weak_guidance else "uncond",
        weak_mode=weak_mode)
    if conditioning == "class" and cond is not None:
        cond = jnp.asarray(cond)
    res = get_pipeline(params, cfg, sched).sample(plan, n, key, cond=cond)
    return np.asarray(res.x0)
