"""Fig. 7 (T2I), Fig. 8 (video spatial/temporal weak), Fig. 11 (MMD)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import FlexiSchedule, relative_compute
from repro.diffusion import schedule as sch


def bench_fig7_t2i(T: int = 20, n: int = 48):
    """CLIP-proxy + FID-proxy across compute levels (text-conditional)."""
    params, cfg, sched = C.get_flexidit(conditioning="text", name="bench-t2i")
    from repro.data import pipeline as dp
    mk = dp.make_text_cond_batch_fn(C.LATENT, 8, 96, n)
    b = mk(0, 0, 1, np.random.default_rng(0))
    cond = jnp.asarray(b["cond"])
    concepts = np.stack([dp.class_pattern(int(c), C.LATENT, seed=777)
                         for c in b["concept"]])
    ref, _ = C.reference_set(128, conditioning="text")
    key = jax.random.PRNGKey(21)
    rows = []
    for T_weak in (0, T // 2, 3 * T // 4):
        s = C.generate(params, cfg, sched, T=T, T_weak=T_weak, n=n, key=key,
                       conditioning="text", cond=cond)
        fid = C.fid_proxy(s, ref)
        clip = C.clip_proxy(s, concepts)
        comp = relative_compute(cfg, FlexiSchedule.weak_first(T, T_weak))
        rows.append((comp, fid, clip))
        C.csv_row(f"fig7_t2i_Tweak{T_weak}", 0.0,
                  f"compute={comp:.3f};fid={fid:.3f};clip={clip:.4f}")
    # weak-conditional guidance variant (§3.4)
    s = C.generate(params, cfg, sched, T=T, T_weak=T // 2, n=n, key=key,
                   conditioning="text", cond=cond, weak_guidance=True)
    C.csv_row("fig7_weak_guidance", 0.0,
              f"fid={C.fid_proxy(s, ref):.3f};"
              f"clip={C.clip_proxy(s, concepts):.4f}")
    return rows


def bench_fig8_video(T: int = 16, n: int = 16):
    """Video: spatial (1,4,4) and temporal (2,2,2) weak modes (§4.3)."""
    latent = (4, 16, 16, 4)
    params, cfg, sched = C.get_flexidit(
        conditioning="class", latent=latent,
        flex=((2, 2, 2), (1, 4, 4)), name="bench-video", steps=400)
    ref, _ = C.reference_set(96, latent=latent)
    key = jax.random.PRNGKey(31)
    base = C.generate(params, cfg, sched, T=T, T_weak=0, n=n, key=key)
    fid0 = C.fid_proxy(base, ref)
    C.csv_row("fig8_video_powerful", 0.0, f"compute=1.0;fid={fid0:.3f}")
    out = {"powerful": fid0}
    for name, mode in (("temporal", 1), ("spatial", 2)):
        for frac in (0.5, 0.75):
            T_weak = int(T * frac)
            s = C.generate(params, cfg, sched, T=T, T_weak=T_weak, n=n,
                           key=key, weak_mode=mode)
            fid = C.fid_proxy(s, ref)
            comp = relative_compute(
                cfg, FlexiSchedule.weak_first(T, T_weak, weak_mode=mode))
            out[f"{name}_{frac}"] = fid
            C.csv_row(f"fig8_video_{name}_w{T_weak}", 0.0,
                      f"compute={comp:.3f};fid={fid:.3f}")
    return out


def bench_fig11_mmd_gap():
    """MMD(p_chain, q) as a function of t_end: grows toward x0 (Fig. 11 left),
    and the weak chain has a larger gap than the powerful chain."""
    params, cfg, sched = C.get_flexidit()
    from repro.core.mmd import bootstrap_mmd_loss
    key = jax.random.PRNGKey(41)
    ref, cond = C.reference_set(64)
    batch = {"x0": jnp.asarray(ref[:32]), "cond": jnp.asarray(cond[:32])}
    vals = {}
    for name, (nw, np_) in (("weak_chain", (3, 0)), ("powerful_chain", (0, 3))):
        loss, _ = bootstrap_mmd_loss(params, batch, key, cfg, sched,
                                     n_weak=nw, n_powerful=np_)
        vals[name] = float(loss)
    C.csv_row("fig11_mmd", 0.0,
              f"mmd_weak={vals['weak_chain']:.4f};"
              f"mmd_powerful={vals['powerful_chain']:.4f}")
    return vals
