"""Compiled-cost profiling gates (DESIGN.md §profiling).

Five claims, all gated via ``baselines.json``:

* **free** — turning profiling on compiles nothing: the same runner
  cache keys serve both drains, so ``cache_stats()['compiled']`` is
  flat from the profiling-off warm drain through the profiling-on one.
* **AOT harvest is invisible** — harvesting ``cost_analysis`` /
  ``memory_analysis`` from the whole warm set (``registry.harvest``)
  leaves the jit compile counter untouched, and a full replay drain
  after the harvest adds zero recompiles.
* **bit-identity** — latents served with profiling on equal the
  profiling-off drain bit-for-bit (profiling only measures).
* **reconciliation** — every executable's XLA flop count lands within
  a loose band of the analytic *body* cost (the scan body is counted
  once, trip-count-blind; see profile.py), with zero harvest errors.
* **measured repricing** — the BudgetController, calibrated with the
  engine-measured wall-per-analytic-FLOP, demotes below what the
  analytic solve sustains when the analytic capacity estimate is
  optimistic (here: a nominal 4x-faster-than-measured device). The
  conservation deltas of the attribution ledger are exactly zero.
"""
from __future__ import annotations

import dataclasses
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

T = 12
TRAIN_T = 100
N_REQ = 12
MAX_TOKENS = 4096


def _bench_cfg():
    from repro.configs import get_config
    base = get_config("dit-xl-2").reduced()
    return dataclasses.replace(
        base, num_layers=4, d_model=128, d_ff=512,
        attn=dataclasses.replace(base.attn, num_heads=4, num_kv_heads=4,
                                 head_dim=32))


def bench_profile() -> None:
    import jax
    import numpy as np

    from benchmarks import common as C
    from benchmarks.baseline import check_baseline
    from repro.diffusion import schedule as sch
    from repro.models import dit as dit_mod
    from repro.pipeline import FlexiPipeline, SamplingPlan
    from repro.serving import BucketMenu, CacheSpec, ServingEngine
    from repro.serving.controller import BudgetController
    from repro.telemetry import Telemetry

    cfg = _bench_cfg()
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
    pipe = FlexiPipeline(params, cfg, sch.linear_schedule(TRAIN_T))
    cache = CacheSpec(policy="interval", interval=2)

    plans = {}
    for b in (0.4, 0.7, 1.0):
        plan = SamplingPlan(T=T, budget=b, guidance_scale=1.5,
                            attn_backend="dense")
        plan.validate(cfg)
        plans[b] = plan
    levels = sorted(plans)
    rng = np.random.default_rng(0)
    reqs = [(int(rng.integers(0, cfg.dit.num_classes)),
             levels[int(rng.integers(0, len(levels)))])
            for _ in range(N_REQ)]
    menu = BucketMenu(cfg, (0, 1), MAX_TOKENS, guided=True)

    def drain(telemetry=None, controller=None):
        # fifo even when a controller rides along: fifo never calls
        # controller.assign, so calibration observation cannot change
        # which budget a request is served at (bit-identity holds)
        engine = ServingEngine(pipe, plans, max_tokens_per_step=MAX_TOKENS,
                               menu=menu, cache=cache, telemetry=telemetry,
                               controller=controller)
        for i, (label, lvl) in enumerate(reqs):
            engine.submit(cond=label, budget=lvl,
                          key=jax.random.fold_in(jax.random.PRNGKey(7), i))
        results = engine.run()
        jax.block_until_ready(results[-1].x0)
        return engine, results

    # ------------------------------------------------------------------
    # Gate 1+2+3: compile-flat profiling, invisible harvest, bit-identity

    _eng, res_off = drain()                        # warm, profiling off
    c_warm = pipe.cache_stats()["compiled"]
    tel1 = Telemetry(profile=True)
    drain(tel1)
    c_prof = pipe.cache_stats()["compiled"]
    profile_added = c_prof - c_warm

    hv = tel1.profile.harvest(pipe)
    c_harv = pipe.cache_stats()["compiled"]
    harvest_added = c_harv - c_prof
    rec = tel1.profile.reconcile()

    # replay AFTER the harvest, with a pre-harvested registry, so the
    # attributed per-request bytes come from real compiled-cost records
    ctrl_fed = BudgetController(cfg, plans, cache=cache,
                                num_train_steps=TRAIN_T,
                                attn_backend="dense")
    tel2 = Telemetry(profile=True)
    tel2.profile.harvest(pipe)
    eng2, res_on = drain(tel2, controller=ctrl_fed)
    c_replay = pipe.cache_stats()["compiled"]
    replay_added = c_replay - c_harv

    a = {r.request.id: np.asarray(r.x0) for r in res_off}
    b = {r.request.id: np.asarray(r.x0) for r in res_on}
    bit_identical = int(all(np.array_equal(a[i], b[i]) for i in a))
    assert bit_identical, "profiling changed the served latents"

    cons = tel2.attribution.conservation()
    conserved = int(all(v == 0 for v in cons.values()))
    bytes_attributed = sum(c.bytes for c in tel2.attribution
                           .finalized.values())
    wall_attr_ns = sum(c.wall_ns for c in tel2.attribution
                       .finalized.values())
    flops_attr = sum(c.flops for c in tel2.attribution.finalized.values())

    # ------------------------------------------------------------------
    # Gate 5: measured calibration reprices the budget solve

    cal = ctrl_fed.calibration
    assert cal is not None, "fifo drain with a controller must calibrate"
    wpf = cal["global"]                 # measured wall per analytic FLOP

    demo = BudgetController(cfg, plans, cache=cache,
                            num_train_steps=TRAIN_T, attn_backend="dense")
    demo.observe_calibration(None, 1.0, wpf)     # r = wpf exactly
    cs = {b_: demo.cost_seconds(b_) for b_ in levels}
    # arrival rate tuned so the seconds budget lands between the menu's
    # cheapest and priciest measured costs ...
    mid = 0.5 * (cs[levels[0]] + cs[levels[-1]])
    gap = mid / demo.target_util
    demo.observe_arrival(0.0)
    demo.observe_arrival(gap)
    # ... while the analytic capacity estimate believes a device 4x
    # faster than measured — the analytic/wall divergence scenario
    demo.observe_service(4.0 / wpf, 1.0)
    b_cal = demo.solve()
    b_ana = demo.solve_analytic()
    repriced = int(b_cal < b_ana)

    C.csv_row("profile_compiles", 0.0,
              f"warm={c_warm};profile_added={profile_added};"
              f"harvest_added={harvest_added};replay_added={replay_added};"
              f"bit_identical={bit_identical}")
    C.csv_row("profile_reconcile", 0.0,
              f"records={rec['n_records']};errors={rec['n_errors']};"
              f"flagged={rec['n_flagged']};"
              f"ratio=[{rec.get('min_xla_over_analytic', 0.0):.2f},"
              f"{rec.get('max_xla_over_analytic', 0.0):.2f}]")
    C.csv_row("profile_attribution", 0.0,
              f"conserved={conserved};wall_ms={wall_attr_ns/1e6:.1f};"
              f"gflops={flops_attr/1e9:.2f};mbytes={bytes_attributed/1e6:.1f}")
    C.csv_row("profile_repricing", 0.0,
              f"wall_per_flop={wpf:.3e};solve_analytic={b_ana};"
              f"solve_calibrated={b_cal};repriced={repriced}")

    bench = {
        "name": "profile", "arch": "dit-xl-2:reduced+4L128d",
        "T": T, "requests": N_REQ, "levels": levels,
        "compiles": {"warm": c_warm, "profile_added": profile_added,
                     "harvest_added": harvest_added,
                     "replay_added": replay_added},
        "recompiles_after_harvest": harvest_added + replay_added,
        "bit_identical": bit_identical,
        "harvest": hv,
        "reconcile": {
            "n_records": rec["n_records"], "n_errors": rec["n_errors"],
            "n_flagged": rec["n_flagged"],
            "max_xla_over_analytic": rec.get("max_xla_over_analytic", 0.0),
            "min_xla_over_analytic": rec.get("min_xla_over_analytic", 0.0)},
        "attribution": {"conserved": conserved,
                        "wall_ns": wall_attr_ns, "flops": flops_attr,
                        "bytes_attributed": bytes_attributed,
                        "n_requests": len(tel2.attribution.finalized),
                        "n_dispatches": len(tel2.attribution.dispatches)},
        "calibration": {"wall_per_flop": wpf,
                        "families": len(cal["per_family"]),
                        "solve_analytic": b_ana,
                        "solve_calibrated": b_cal,
                        "repriced": repriced},
    }
    print("BENCH " + json.dumps(bench))
    check_baseline("profile", bench)


if __name__ == "__main__":
    bench_profile()
