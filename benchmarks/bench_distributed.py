"""Distributed engine scaling: tokens/s and collective bytes per denoise
step vs mesh size on fake CPU devices (DESIGN.md §distributed).

The outer entry (``bench_distributed``, run via ``benchmarks.run --suite
distributed``) re-launches this module in a subprocess with 8 fake host
devices — the flag must be set before jax initializes, and the main bench
process keeps its 1-device view. The inner run sweeps sequence-axis sizes
(1, 2, 4 → Ulysses; 8 → ring on the 4-head smoke model), times warm
sampling, prices the collectives analytically, and emits one ``BENCH``
JSON line plus the usual CSV rows.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

SEQ_SIZES = (1, 2, 4, 8)
T = 4
BATCH = 4


def bench_distributed() -> None:
    """Outer harness entry: run the sweep on 8 fake host devices."""
    from repro.launch.mesh import ensure_host_devices
    env = ensure_host_devices(8, dict(os.environ))
    r = subprocess.run([sys.executable, "-m", "benchmarks.bench_distributed"],
                       env=env, capture_output=True, text=True, timeout=1200,
                       cwd=str(Path(__file__).resolve().parents[1]))
    sys.stdout.write(r.stdout)
    if r.returncode != 0:
        raise RuntimeError(f"inner distributed bench failed:\n{r.stderr[-2000:]}")
    # the inner subprocess prints the BENCH line; the gate runs out here
    from benchmarks.baseline import check_baseline
    for line in r.stdout.splitlines():
        if line.startswith("BENCH "):
            check_baseline("distributed_seqpar", json.loads(line[len("BENCH "):]))


def _inner() -> None:
    import jax
    import numpy as np

    from benchmarks import common as C
    from repro.configs import get_config
    from repro.diffusion import schedule as sch
    from repro.distributed import ParallelSpec, plan_partition
    from repro.launch.mesh import make_inference_mesh
    from repro.models import dit as dit_mod
    from repro.pipeline import FlexiPipeline, SamplingPlan

    cfg = get_config("dit-xl-2").reduced()
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
    sched = sch.linear_schedule(100)
    key = jax.random.PRNGKey(1)
    results = []
    for sp in SEQ_SIZES:
        mesh = make_inference_mesh(1, sp) if sp > 1 else None
        parallel = ParallelSpec() if sp > 1 else None
        pipe = FlexiPipeline(params, cfg, sched, mesh=mesh)
        plan = SamplingPlan(T=T, budget=0.6, guidance_scale=1.5,
                            parallel=parallel)
        plan.validate(cfg)
        fs = plan.resolve_schedule(cfg)
        part = plan_partition(cfg, fs, sp, parallel or ParallelSpec())
        jax.block_until_ready(pipe.sample(plan, BATCH, key).x0)   # compile
        times = []
        for i in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(
                pipe.sample(plan, BATCH, jax.random.fold_in(key, i)).x0)
            times.append(time.perf_counter() - t0)
        dt = float(np.median(times))
        # token-steps actually computed (padded, CFG-doubled) per sample
        tok_steps = 2 * sum(n * p.tokens_padded for p, n in part.phases)
        tokens_per_s = BATCH * tok_steps / dt
        bytes_per_step = part.collective_bytes(cfg) / T
        impl = part.phases[0][0].impl if sp > 1 else "none"
        C.csv_row(f"distributed_seq{sp}", dt * 1e6,
                  f"impl={impl};tokens_per_s={tokens_per_s:.0f};"
                  f"collective_bytes_per_step={bytes_per_step:.0f};"
                  f"pad_eff={part.parallel_efficiency(cfg):.3f}")
        results.append({
            "seq": sp, "impl": impl, "wall_s": dt,
            "tokens_per_s": tokens_per_s,
            "collective_bytes_per_step": bytes_per_step,
            "parallel_efficiency": part.parallel_efficiency(cfg),
        })
    print("BENCH " + json.dumps({"name": "distributed_seqpar", "T": T,
                                 "batch": BATCH, "arch": "dit-xl-2:reduced",
                                 "results": results}))


if __name__ == "__main__":
    _inner()
