"""Cross-step activation cache: FLOPs saved vs eps drift, engine
throughput with caching on/off, and zero-recompile policy switches
(DESIGN.md §cache).

Two phases on the reduced smoke model (same 4-layer/128d sizing as
bench_serving, so per-step compute dominates dispatch overhead):

* **pipeline sweep** — one uncached reference run, then each refresh
  policy (interval k, timestep-banded, the analytic error proxy);
  reports analytic FLOPs saved (``repro.cache.ledger``) and the x0 MSE
  drift vs the reference (eps errors integrate into x0, so this is the
  end-to-end drift a user sees). Policy switches replay ONE compiled
  runner — asserted via ``cache_stats`` (the zero-recompile guarantee:
  masks are data, the split is structure).
* **engine drain** — the same request set through the serving engine
  with caching off vs on (default error-proxy policy); reports useful
  tokens/s both ways plus the cache ledger (hit rate, refresh-interval
  histogram, bytes resident).

Acceptance (asserted): the default error-proxy policy saves >= 25%
analytic FLOPs while its drift stays within 10x of interval-2's (the
matched-drift band), and no policy switch compiles anything new.
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

T = 20
TRAIN_T = 1000
N_REQ = 16
MAX_TOKENS = 4096
REPEATS = 3


def _bench_cfg():
    from repro.configs import get_config
    base = get_config("dit-xl-2").reduced()
    return dataclasses.replace(
        base, num_layers=4, d_model=128, d_ff=512,
        attn=dataclasses.replace(base.attn, num_heads=8, num_kv_heads=8,
                                 head_dim=16))


def bench_cache() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks import common as C
    from benchmarks.baseline import check_baseline
    from repro.cache import CacheSpec, cache_savings
    from repro.core.scheduler import FlexiSchedule
    from repro.diffusion import schedule as sch
    from repro.models import dit as dit_mod
    from repro.pipeline import FlexiPipeline, SamplingPlan
    from repro.serving import ServingEngine

    cfg = _bench_cfg()
    key = jax.random.PRNGKey(0)
    params = dit_mod.init_dit(cfg, key)
    # break the zero-init de-embed / final-adaLN gates (as training
    # would): a zero-output model would make every policy drift-free
    params["deembed"]["w_flex"] = jax.random.normal(
        jax.random.fold_in(key, 1),
        params["deembed"]["w_flex"].shape) * 0.1
    params["final"]["ada"]["w"] = jax.random.normal(
        jax.random.fold_in(key, 2),
        params["final"]["ada"]["w"].shape) * 0.05
    params["blocks"]["ada"]["w"] = jax.random.normal(
        jax.random.fold_in(key, 3),
        params["blocks"]["ada"]["w"].shape) * 0.05
    pipe = FlexiPipeline(params, cfg, sch.linear_schedule(TRAIN_T))
    sched_budget = FlexiSchedule.weak_first(T, T // 2)
    ts = sch.respaced_timesteps(TRAIN_T, T)
    key = jax.random.PRNGKey(1)
    cond = jnp.asarray(np.arange(8) % cfg.dit.num_classes, jnp.int32)

    def plan_for(cache):
        return SamplingPlan(T=T, budget=sched_budget, guidance_scale=1.5,
                            cache=cache)

    # ------------------------------------------------------------------
    # Pipeline sweep: drift + analytic savings per policy

    ref = pipe.sample(plan_for(None), 8, key, cond=cond).x0
    ref_pow = float(jnp.mean(ref ** 2))
    policies = {
        "interval_1": CacheSpec(policy="interval", interval=1),
        "interval_2": CacheSpec(policy="interval", interval=2),
        "interval_4": CacheSpec(policy="interval", interval=4),
        "banded": CacheSpec(policy="banded", bands=((TRAIN_T // 2, 1),),
                            interval=4),
        "proxy_default": CacheSpec(policy="proxy"),
    }
    sweep = {}
    warm = None
    for name, spec in policies.items():
        res = pipe.sample(plan_for(spec), 8, key, cond=cond)
        drift = float(jnp.mean((res.x0 - ref) ** 2)) / ref_pow
        led = cache_savings(cfg, sched_budget, ts, spec)
        sweep[name] = {
            "x0_rel_mse": drift,
            "flops_saved_frac": led["flops_saved_frac"],
            "refresh_rate": led["refresh_rate"],
        }
        C.csv_row(f"cache_policy_{name}", 0.0,
                  f"saved={led['flops_saved_frac']:.3f};"
                  f"refresh_rate={led['refresh_rate']:.2f};"
                  f"x0_rel_mse={drift:.2e}")
        if warm is None:
            warm = pipe.cache_stats()      # first cached runner compiled
    after = pipe.cache_stats()
    policy_recompiles = after["compiled"] - warm["compiled"]
    assert policy_recompiles == 0, \
        f"{policy_recompiles} recompiles across policy switches (masks " \
        f"must be data, not structure)"
    assert sweep["interval_1"]["x0_rel_mse"] == 0.0, \
        "interval=1 must be bit-identical to the uncached pipeline"
    proxy = sweep["proxy_default"]
    assert proxy["flops_saved_frac"] >= 0.25, \
        f"default proxy policy saves only {proxy['flops_saved_frac']:.2f} " \
        f"FLOPs (need >= 0.25)"
    assert proxy["x0_rel_mse"] <= 10 * max(sweep["interval_2"]["x0_rel_mse"],
                                           1e-12), \
        "proxy drift far off the interval-2 matched-drift band"

    # ------------------------------------------------------------------
    # Engine drain: tokens/s with caching off vs on

    plans = {0.6: SamplingPlan(T=T, budget=sched_budget,
                               guidance_scale=1.5),
             1.0: SamplingPlan(T=T, budget=1.0, guidance_scale=1.5)}
    rng = np.random.default_rng(0)
    reqs = [(int(rng.integers(0, cfg.dit.num_classes)),
             0.6 if rng.random() < 0.5 else 1.0) for _ in range(N_REQ)]
    level_tokens = {}
    for b, plan in plans.items():
        fs = plan.resolve_schedule(cfg)
        level_tokens[b] = 2 * sum(
            n * dit_mod.tokens_for_mode(cfg, m) for m, n in fs.phases)
    useful_tokens = sum(level_tokens[lvl] for _, lvl in reqs)

    def drain(cache):
        engine = ServingEngine(pipe, plans,
                               max_tokens_per_step=MAX_TOKENS, cache=cache)
        for i, (label, lvl) in enumerate(reqs):
            engine.submit(cond=label, budget=lvl,
                          key=jax.random.fold_in(jax.random.PRNGKey(7), i))
        results = engine.run()
        jax.block_until_ready(results[-1].x0)
        return engine

    spec_on = CacheSpec(policy="proxy")
    drain(None)
    drain(spec_on)                          # bucket warmup both families
    warm_eng = pipe.cache_stats()
    dt_off = dt_on = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        drain(None)
        dt_off = min(dt_off, time.perf_counter() - t0)
        t0 = time.perf_counter()
        eng_on = drain(spec_on)
        dt_on = min(dt_on, time.perf_counter() - t0)
    eng_recompiles = pipe.cache_stats()["compiled"] - warm_eng["compiled"]
    assert eng_recompiles == 0, \
        f"{eng_recompiles} engine recompiles after warmup"
    cache_m = eng_on.metrics.cache_summary()
    tps_off = useful_tokens / dt_off
    tps_on = useful_tokens / dt_on
    C.csv_row("cache_engine_drain", dt_on * 1e6,
              f"tokens_per_s_on={tps_on:.0f};tokens_per_s_off={tps_off:.0f};"
              f"speedup={tps_on / tps_off:.2f};"
              f"hit_rate={cache_m['hit_rate']:.3f}")

    bench = {
        "name": "activation_cache", "arch": "dit-xl-2:reduced+4L128d",
        "T": T, "train_T": TRAIN_T,
        "split": CacheSpec().resolve_split(cfg.num_layers),
        "policies": sweep,
        "policy_switch_recompiles": policy_recompiles,
        "engine": {
            "requests": N_REQ,
            "tokens_per_s_cache_off": tps_off,
            "tokens_per_s_cache_on": tps_on,
            "speedup": tps_on / tps_off,
            "recompiles_after_warmup": eng_recompiles,
            "cache": cache_m,
        },
    }
    print("BENCH " + json.dumps(bench))
    check_baseline("activation_cache", bench)


if __name__ == "__main__":
    bench_cache()
