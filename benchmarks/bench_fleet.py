"""Fleet router vs a single engine (DESIGN.md §fleet).

A saturated mixed-budget Poisson drain runs through N=4 in-process
replica engines behind the router, in *virtual time*: every replica owns
a clock advanced by its modeled dispatch cost (packed tokens x
seconds-per-token), so a one-accelerator container reports the
aggregate-throughput arithmetic honestly (fleet makespan = max replica
clock; see DESIGN.md §fleet for what transfers to real multi-host).

Phases:

* **scale** — the identical workload drains through 1 replica and
  through 4; aggregate useful tokens/s must be >= 3.0x the single
  engine (the loss to 4.0x is placement imbalance + tail cohorts).
* **kill** (affinity router) — the same Poisson drain, but replica 0 is
  killed mid-drain after its first dispatch. Zero accepted requests may
  be lost; every re-admitted request restarts from step 0 elsewhere with
  a forced cache refresh and must reproduce the uninterrupted
  single-engine sample (<=1e-4); the dispatch-level cache-affinity hit
  rate must stay >= 0.95; re-admission latency is reported.
* **compile-once** — the kill drain replays after a rehearsal pass;
  zero recompiles across every replica (shared pipeline = one XLA
  process; the bucket warmup covers mid-drain re-admission cohorts).

All gates are asserted against ``baselines.json`` (``fleet_router``).
"""
from __future__ import annotations

import dataclasses
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

T = 12
TRAIN_T = 100
N_REQ = 48
N_REPLICAS = 4
SPT = 1e-4                     # modeled seconds per packed token
MAX_TOKENS = 1024              # per-replica step budget (4 full CFG reqs)
STEPS_PER_DISPATCH = 2         # finer dispatches -> honest affinity stats
LOAD_RATE = 40.0               # virtual arrivals/s (saturates 4 replicas)


class _Clock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += dt
        return self.t


def _bench_cfg():
    from repro.configs import get_config
    base = get_config("dit-xl-2").reduced()
    return dataclasses.replace(
        base, num_layers=2, d_model=64, d_ff=256,
        attn=dataclasses.replace(base.attn, num_heads=4, num_kv_heads=4,
                                 head_dim=16))


def bench_fleet() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks import common as C
    from benchmarks.baseline import check_baseline
    from repro.core.scheduler import FlexiSchedule
    from repro.diffusion import schedule as sch
    from repro.fleet import Fleet
    from repro.models import dit as dit_mod
    from repro.pipeline import FlexiPipeline, SamplingPlan

    cfg = _bench_cfg()
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
    pipe = FlexiPipeline(params, cfg, sch.linear_schedule(TRAIN_T))
    plans = {}
    for level, budget in ((0.5, FlexiSchedule.weak_first(T, 8)),
                          (0.75, FlexiSchedule.weak_first(T, 4)),
                          (1.0, 1.0)):
        plan = SamplingPlan(T=T, budget=budget, guidance_scale=1.5,
                            attn_backend="dense")
        plan.validate(cfg)
        plans[level] = plan
    levels = sorted(plans)
    rng = np.random.default_rng(0)
    reqs = [(int(rng.integers(0, cfg.dit.num_classes)),
             levels[int(rng.integers(0, len(levels)))])
            for _ in range(N_REQ)]
    arrivals = np.cumsum(rng.exponential(1.0 / LOAD_RATE, size=N_REQ))
    engine_kwargs = {"max_tokens_per_step": MAX_TOKENS,
                     "steps_per_dispatch": STEPS_PER_DISPATCH}

    def poisson_drain(n_replicas, router, kill_after_submit=False):
        """One full drain in virtual time; arrivals land mid-serving.
        ``kill_after_submit``: one extra tick after the last arrival,
        then replica 0 dies mid-drain."""
        clk = _Clock()
        fleet = Fleet(pipe, plans, n_replicas, router=router, clock=clk,
                      seconds_per_token=SPT, engine_kwargs=engine_kwargs)
        rids = []
        for (label, lvl), at in zip(reqs, arrivals):
            if at > clk():
                clk.advance(at - clk())
            rids.append(fleet.submit(cond=label, budget=lvl))
            fleet.tick()
        orphans = 0
        if kill_after_submit:
            fleet.tick()
            orphans = fleet.kill_replica(0)
        fleet.run()
        return fleet, rids, orphans

    # ------------------------------------------------------------------
    # Warmup: compile every bucket the three drain shapes visit (the
    # rehearsal kill run covers mid-drain re-admission cohorts too)
    poisson_drain(1, "cheapest")
    poisson_drain(N_REPLICAS, "cheapest")
    poisson_drain(N_REPLICAS, "affinity", kill_after_submit=True)
    warm = pipe.cache_stats()

    # ------------------------------------------------------------------
    # Scale phase: aggregate useful tokens/s, 4 replicas vs 1
    solo, rids, _ = poisson_drain(1, "cheapest")
    assert sorted(solo.results) == rids
    s1 = solo.summary()
    fleet, rids, _ = poisson_drain(N_REPLICAS, "cheapest")
    assert sorted(fleet.results) == rids
    s4 = fleet.summary()
    assert s4["tokens"] == s1["tokens"], "same workload, same useful tokens"
    speedup = s4["tokens_per_s"] / s1["tokens_per_s"]
    C.csv_row("fleet_scale", s4["makespan_s"] * 1e6,
              f"tokens_per_s={s4['tokens_per_s']:.0f};"
              f"single_tps={s1['tokens_per_s']:.0f};"
              f"speedup={speedup:.2f};replicas={N_REPLICAS};"
              f"affinity={s4['affinity_hit_rate']:.3f}")

    # ------------------------------------------------------------------
    # Kill phase: replica 0 dies mid-drain (measured replay of the
    # rehearsed trajectory — so this phase also proves compile-once)
    kfleet, rids, orphans = poisson_drain(N_REPLICAS, "affinity",
                                          kill_after_submit=True)
    recompiles = pipe.cache_stats()["compiled"] - warm["compiled"]
    lost = len(set(rids) - set(kfleet.results))
    sk = kfleet.summary()
    assert orphans > 0, "the kill must orphan accepted requests"
    assert kfleet.membership.state(0) == "dead"

    # every re-admitted/handed-back request reproduces the sample an
    # uninterrupted single engine would have served (same PRNG key,
    # restart from step 0, forced cache refresh on the new owner)
    moved = [r for r in kfleet.router.requests.values()
             if r.readmits or r.handbacks]
    max_err = 0.0
    for req in moved:
        res = kfleet.results[req.rid]
        ref = pipe.sample(plans[res.budget_served], 1, req.key,
                          cond=jnp.asarray([req.cond], jnp.int32)).x0[0]
        max_err = max(max_err, float(jnp.abs(res.x0 - ref).max()))
    C.csv_row("fleet_kill", sk["makespan_s"] * 1e6,
              f"orphans={orphans};moved={len(moved)};lost={lost};"
              f"max_readmit_err={max_err:.2e};"
              f"affinity={sk['affinity_hit_rate']:.3f};"
              f"readmit_mean_s={sk['readmit']['mean_s']:.4f};"
              f"recompiles={recompiles}")

    bench = {
        "name": "fleet_router", "arch": "dit-xl-2:reduced+2L64d",
        "T": T, "requests": N_REQ, "replicas": N_REPLICAS,
        "levels": levels, "seconds_per_token": SPT,
        "poisson_rate_per_s": LOAD_RATE,
        "virtual_time": True,
        "fleet": {"tokens_per_s": s4["tokens_per_s"],
                  "makespan_s": s4["makespan_s"],
                  "affinity_hit_rate": s4["affinity_hit_rate"],
                  "request_dispatches": s4["request_dispatches"]},
        "single": {"tokens_per_s": s1["tokens_per_s"],
                   "makespan_s": s1["makespan_s"]},
        "speedup_vs_single": speedup,
        "kill": {"orphans": orphans, "moved": len(moved), "lost": lost,
                 "max_readmit_err": max_err,
                 "affinity_hit_rate": sk["affinity_hit_rate"],
                 "readmit_count": sk["readmit"]["count"],
                 "readmit_mean_s": sk["readmit"]["mean_s"],
                 "readmit_max_s": sk["readmit"]["max_s"],
                 "makespan_s": sk["makespan_s"],
                 "makespan_penalty":
                     sk["makespan_s"] / s4["makespan_s"]},
        "recompiles_after_warmup": recompiles,
        "compile": kfleet.compile_stats(),
    }
    print("BENCH " + json.dumps(bench))
    check_baseline("fleet_router", bench)
    assert speedup >= 3.0, \
        f"4-replica fleet only {speedup:.2f}x a single engine at " \
        f"saturation (need >=3.0x)"
    assert lost == 0, f"{lost} accepted request(s) lost across the kill"
    assert max_err <= 1e-4, \
        f"re-admitted output diverged from the uninterrupted reference " \
        f"({max_err:.2e} > 1e-4)"
    assert sk["affinity_hit_rate"] >= 0.95, \
        f"cache-affinity hit rate {sk['affinity_hit_rate']:.3f} < 0.95"
    assert recompiles == 0, \
        f"{recompiles} recompile(s) after warmup across the fleet"


if __name__ == "__main__":
    bench_fleet()
