"""Aggregate dry-run JSONs into the §Roofline table (EXPERIMENTS.md)."""
from __future__ import annotations

import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"

COLS = ("arch", "shape", "profile", "dominant", "compute_s", "memory_s",
        "collective_s", "roofline_fraction", "useful_flops_ratio")


def load(mesh: str = "pod16x16"):
    rows = []
    for f in sorted((RESULTS / mesh).glob("*.json")):
        r = json.loads(f.read_text())
        if r.get("status") == "ok":
            t = r["roofline"]
            rows.append({
                "arch": r["arch"], "shape": r["shape"],
                "profile": r.get("profile", "?"),
                "dominant": t["dominant"],
                "compute_s": t["compute_s"], "memory_s": t["memory_s"],
                "collective_s": t["collective_s"],
                "roofline_fraction": t["roofline_fraction"],
                "useful_flops_ratio": t.get("useful_flops_ratio", 0.0),
                "mem_temp_gb": (r["memory_analysis"].get("temp_size_in_bytes")
                                or 0) / r.get("n_devices", 1) / 2 ** 30,
                "args_gb": r.get("sharded_args_bytes_per_device", 0) / 2 ** 30,
            })
        elif r.get("status") == "skipped":
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "profile": "-", "dominant": "SKIPPED",
                         "compute_s": 0, "memory_s": 0, "collective_s": 0,
                         "roofline_fraction": 0, "useful_flops_ratio": 0,
                         "mem_temp_gb": 0, "args_gb": 0,
                         "skip": r.get("skip_reason", "")})
        else:
            rows.append({"arch": r["arch"], "shape": r["shape"],
                         "profile": "-", "dominant": "ERROR",
                         "compute_s": 0, "memory_s": 0, "collective_s": 0,
                         "roofline_fraction": 0, "useful_flops_ratio": 0,
                         "mem_temp_gb": 0, "args_gb": 0})
    return rows


def markdown_table(mesh: str = "pod16x16") -> str:
    rows = load(mesh)
    out = ["| arch | shape | prof | dominant | compute s | memory s | "
           "collective s | roofline frac | useful/HLO | mem GiB/dev |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r["dominant"] in ("SKIPPED", "ERROR"):
            out.append(f"| {r['arch']} | {r['shape']} | - | {r['dominant']} "
                       f"| – | – | – | – | – | – |")
        else:
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['profile']} "
                f"| **{r['dominant']}** | {r['compute_s']:.3g} "
                f"| {r['memory_s']:.3g} | {r['collective_s']:.3g} "
                f"| {r['roofline_fraction']:.3f} "
                f"| {r['useful_flops_ratio']:.2f} "
                f"| {r['mem_temp_gb'] + r['args_gb']:.2f} |")
    return "\n".join(out)


def bench_roofline():
    from benchmarks.common import csv_row
    for mesh in ("pod16x16", "pod2x16x16"):
        if not (RESULTS / mesh).exists():
            continue
        for r in load(mesh):
            if r["dominant"] in ("SKIPPED", "ERROR"):
                csv_row(f"roofline_{mesh}_{r['arch']}_{r['shape']}", 0.0,
                        r["dominant"])
            else:
                csv_row(
                    f"roofline_{mesh}_{r['arch']}_{r['shape']}", 0.0,
                    f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f};"
                    f"c={r['compute_s']:.3g};m={r['memory_s']:.3g};"
                    f"x={r['collective_s']:.3g}")


if __name__ == "__main__":
    print(markdown_table(sys.argv[1] if len(sys.argv) > 1 else "pod16x16"))
