"""Roofline table off the compiled-cost registry (DESIGN.md §profiling).

Replaces the stale seed script that aggregated a nonexistent
``results/dryrun/`` tree. This version measures, not loads: it builds a
profiling-enabled :class:`FlexiPipeline`, samples each requested budget
(static + activation-cached plans), harvests XLA ``cost_analysis`` /
``memory_analysis`` through the compiled-cost registry's AOT path, and
emits one row per arch×budget reconciling

    analytic GFLOPs | XLA GFLOPs | bytes | wall ms | achieved GFLOP/s
    | arithmetic intensity (flops/byte)

This exercises the registry's *sample-path* harvest (static/cached
runner specs recorded by ``enable_cost_profiling``), complementing
``bench_profile``'s packed-engine path. Note the xla/analytic column
here compares XLA's trip-count-blind count (each ``lax.scan`` body
tallied ONCE — see profile.py) against the full-request analytic total,
so sub-1 ratios on multi-phase sample runners are expected, not drift;
the gated packed-body reconciliation lives in ``bench_profile``.

  PYTHONPATH=src python -m benchmarks.roofline_table          # table
"""
from __future__ import annotations

import sys
import time
from pathlib import Path
from typing import Dict, List, Optional, Sequence

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

DEFAULT_BUDGETS = (0.4, 0.7, 1.0)
T = 10
TRAIN_T = 100
N = 2

COLS = ("arch", "budget", "cached", "analytic_gflops", "xla_gflops",
        "ratio", "bytes_mb", "wall_ms", "achieved_gflops_s", "intensity")


def registry_rows(arch: str = "dit-xl-2",
                  budgets: Sequence[float] = DEFAULT_BUDGETS,
                  cache_interval: Optional[int] = 2,
                  attn_backend: str = "dense") -> List[Dict]:
    """Sample each budget (plain + cached when ``cache_interval``),
    harvest compiled costs, and reconcile against the analytic ledger."""
    import jax

    from repro.cache.policy import CacheSpec
    from repro.configs import get_config
    from repro.diffusion import schedule as sch
    from repro.models import dit as dit_mod
    from repro.pipeline import FlexiPipeline, SamplingPlan
    from repro.telemetry.profile import CompiledCostRegistry

    cfg = get_config(arch).reduced()
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
    pipe = FlexiPipeline(params, cfg, sch.linear_schedule(TRAIN_T))
    pipe.enable_cost_profiling()
    registry = CompiledCostRegistry()

    variants = [(b, None) for b in budgets]
    if cache_interval is not None:
        variants += [(b, CacheSpec(policy="interval",
                                   interval=cache_interval))
                     for b in budgets]
    keys_of: Dict[tuple, tuple] = {}
    for b, cache in variants:
        plan = SamplingPlan(T=T, budget=b, guidance_scale=1.5,
                            attn_backend=attn_backend, cache=cache)
        plan.validate(cfg)
        before = set(pipe.runners())
        res = pipe.sample(plan, N, jax.random.PRNGKey(17))
        jax.block_until_ready(res.x0)
        # time a warm replay so wall reflects execution, not tracing
        t0 = time.perf_counter()
        res = pipe.sample(plan, N, jax.random.PRNGKey(17))
        jax.block_until_ready(res.x0)
        wall = time.perf_counter() - t0
        new = set(pipe.runners()) - before
        assert len(new) == 1, f"expected one runner per variant, got {new}"
        rkey = next(iter(new))
        keys_of[(b, cache is not None)] = rkey
        registry.observe_wall(rkey, wall)
    registry.harvest(pipe)

    rows: List[Dict] = []
    for (b, cached), rkey in sorted(keys_of.items()):
        rec = registry.records[rkey]
        w = registry.walls[rkey]
        row: Dict = {
            "arch": arch, "budget": b, "cached": cached,
            "analytic_gflops": rec.analytic_body / 1e9,
            "xla_gflops": (rec.xla_flops or 0.0) / 1e9,
            "ratio": rec.xla_over_analytic or 0.0,
            "bytes_mb": (rec.xla_bytes or 0.0) / 1e6,
            "wall_ms": w.ewma_s * 1e3,
            "achieved_gflops_s": (rec.analytic_body / w.ewma_s / 1e9
                                  if w.ewma_s > 0 else 0.0),
            "intensity": ((rec.xla_flops or 0.0)
                          / max(rec.xla_bytes or 0.0, 1.0)),
            "error": rec.error,
        }
        rows.append(row)
    return rows


def markdown_table(rows: Sequence[Dict]) -> str:
    out = ["| arch | budget | cached | analytic G | xla G | xla/analytic "
           "| bytes MB | wall ms | achieved G/s | flops/byte |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.get("error"):
            out.append(f"| {r['arch']} | {r['budget']:.2f} | "
                       f"{'y' if r['cached'] else 'n'} | ERROR: "
                       f"{r['error']} | | | | | | |")
            continue
        out.append(
            f"| {r['arch']} | {r['budget']:.2f} "
            f"| {'y' if r['cached'] else 'n'} "
            f"| {r['analytic_gflops']:.3f} | {r['xla_gflops']:.3f} "
            f"| {r['ratio']:.2f} | {r['bytes_mb']:.1f} "
            f"| {r['wall_ms']:.1f} | {r['achieved_gflops_s']:.2f} "
            f"| {r['intensity']:.2f} |")
    return "\n".join(out)


def bench_roofline():
    from benchmarks.common import csv_row
    rows = registry_rows()
    for r in rows:
        if r.get("error"):
            csv_row(f"roofline_{r['arch']}_b{r['budget']:.2f}"
                    f"{'_cached' if r['cached'] else ''}", 0.0,
                    f"ERROR:{r['error']}")
            continue
        csv_row(
            f"roofline_{r['arch']}_b{r['budget']:.2f}"
            f"{'_cached' if r['cached'] else ''}",
            r["wall_ms"] * 1e3,
            f"analytic={r['analytic_gflops']:.3f}G;"
            f"xla={r['xla_gflops']:.3f}G;ratio={r['ratio']:.2f};"
            f"achieved={r['achieved_gflops_s']:.2f}G/s;"
            f"intensity={r['intensity']:.2f}")


if __name__ == "__main__":
    print(markdown_table(registry_rows(
        sys.argv[1] if len(sys.argv) > 1 else "dit-xl-2")))
