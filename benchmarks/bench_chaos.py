"""Chaos resilience suite (DESIGN.md §resilience).

A 4-replica fleet drains a mixed-budget workload while the standard
scripted :func:`~repro.resilience.chaos.default_fault_plan` fires every
fault kind at least once — replica crash, transient hang, delayed and
partitioned heartbeats, dispatch slowdown, NaN poisoning, cache-slot
corruption, transient allocation failure. Three phases:

* **chaos** — the scripted drain. Gates: zero admitted requests lost,
  zero non-finite latents served, every scripted fault applied, the
  crash + partition produce real deaths, and at least one poisoned
  request escalated (weak→powerful quarantine recovery).
* **verify** — every escalated request's served latents are compared
  bitwise against a clean powerful-path run of the same key (a fresh
  fault-free fleet); every death-re-admitted request against the
  uninterrupted single-request pipeline sample (<=1e-4).
* **replay** — fleet A journals admits/dispatches/finishes and is
  abandoned mid-drain (router crash); fleet B replays the journal's
  unfinished set exactly-once (no misses, no duplicates) with replayed
  samples <=1e-4 of their uninterrupted references.

The whole scenario replays after a rehearsal pass with **zero new XLA
compiles**: faults change data and placement, never compiled structure.
Gates are asserted against ``baselines.json`` (``chaos_resilience``).
"""
from __future__ import annotations

import dataclasses
import json
import sys
import tempfile
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

T = 12
TRAIN_T = 100
N_REQ = 32
N_REPLICAS = 4
SPT = 1e-4                     # modeled seconds per packed token
MAX_TOKENS = 1024              # per-replica step budget (4 full CFG reqs)
STEPS_PER_DISPATCH = 2


def _bench_cfg():
    from repro.configs import get_config
    base = get_config("dit-xl-2").reduced()
    return dataclasses.replace(
        base, num_layers=2, d_model=64, d_ff=256,
        attn=dataclasses.replace(base.attn, num_heads=4, num_kv_heads=4,
                                 head_dim=16))


def bench_chaos() -> None:
    import jax

    from benchmarks import common as C
    from benchmarks.baseline import check_baseline
    from repro.cache.policy import CacheSpec
    from repro.core.scheduler import FlexiSchedule
    from repro.diffusion import schedule as sch
    from repro.models import dit as dit_mod
    from repro.pipeline import FlexiPipeline, SamplingPlan
    from repro.resilience import chaos as chaos_mod
    from repro.resilience.journal import RequestJournal

    cfg = _bench_cfg()
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
    pipe = FlexiPipeline(params, cfg, sch.linear_schedule(TRAIN_T))
    plans = {}
    for level, budget in ((0.5, FlexiSchedule.weak_first(T, 8)),
                          (0.75, FlexiSchedule.weak_first(T, 4)),
                          (1.0, 1.0)):
        plan = SamplingPlan(T=T, budget=budget, guidance_scale=1.5,
                            attn_backend="dense")
        plan.validate(cfg)
        plans[level] = plan
    engine_kwargs = {
        "max_tokens_per_step": MAX_TOKENS,
        "steps_per_dispatch": STEPS_PER_DISPATCH,
        # interval=1 keeps outputs bit-identical to the uncached path
        # (references stay exact) while the CacheStore slots, checksums,
        # and allocation seams are all fully exercised
        "cache": CacheSpec(policy="interval", interval=1, split=1),
    }
    tmp = Path(tempfile.mkdtemp(prefix="chaos_journal_"))

    def scenario(tag: str):
        journal = RequestJournal(str(tmp / f"chaos_{tag}.jsonl"))
        chaos = chaos_mod.run_chaos(
            pipe, plans, n_replicas=N_REPLICAS, n_requests=N_REQ,
            journal=journal, seconds_per_token=SPT,
            engine_kwargs=engine_kwargs, seed=0)
        journal.close()
        verify = chaos_mod.verify_escalations(
            pipe, plans, chaos, seconds_per_token=SPT,
            engine_kwargs=engine_kwargs)
        # enough requests that the router crash strands real work: with
        # cohorts of 4 per dispatch, 24 requests over 2 replicas finish
        # in waves, and the crash lands between waves
        replay = chaos_mod.run_replay(
            pipe, plans, str(tmp / f"replay_{tag}.jsonl"),
            n_requests=24, crash_after_finished=4,
            seconds_per_token=SPT, engine_kwargs=engine_kwargs)
        return chaos, verify, replay

    # ------------------------------------------------------------------
    # Rehearsal: compile every bucket the chaos scenario, the powerful
    # references, and the replay fleets visit
    scenario("rehearsal")
    warm = pipe.cache_stats()

    # ------------------------------------------------------------------
    # Measured replay of the rehearsed scenario (identical script)
    chaos, verify, replay = scenario("measured")
    recompiles = pipe.cache_stats()["compiled"] - warm["compiled"]

    C.csv_row("chaos_drain", chaos["ticks"] * 1e3,
              f"lost={chaos['requests_lost']};"
              f"nonfinite={chaos['nonfinite_outputs']};"
              f"deaths={chaos['deaths']};"
              f"escalated={len(chaos['escalated_rids'])};"
              f"moved={len(chaos['moved_rids'])};"
              f"faults_applied={chaos['faults'].get('applied', 0)};"
              f"recompiles={recompiles}")
    C.csv_row("chaos_verify", verify["escalated_max_err"] * 1e6,
              f"escalated_bitwise={verify['escalated_bitwise']};"
              f"moved_max_err={verify['moved_max_err']:.2e}")
    C.csv_row("chaos_replay", replay["max_readmit_err"] * 1e6,
              f"replayed={replay['replayed']};missing={replay['missing']};"
              f"duplicates={replay['duplicates']}")

    bench = {
        "name": "chaos_resilience", "arch": "dit-xl-2:reduced+2L64d",
        "T": T, "requests": N_REQ, "replicas": N_REPLICAS,
        "seconds_per_token": SPT, "virtual_time": True,
        "chaos": {
            "ticks": chaos["ticks"],
            "requests_lost": chaos["requests_lost"],
            "nonfinite_outputs": chaos["nonfinite_outputs"],
            "deaths": chaos["deaths"],
            "escalated": len(chaos["escalated_rids"]),
            "moved": len(chaos["moved_rids"]),
            "expirations": chaos["expirations"],
            "quarantined": chaos["quarantined"],
            "integrity_refreshes": chaos["integrity_refreshes"],
            "alloc_failures": chaos["alloc_failures"],
            "faults_applied": chaos["faults"].get("applied", 0),
            "faults_exhausted": int(chaos["faults_exhausted"]),
        },
        "recovery": chaos["recovery"],
        "verify": verify,
        "replay": {k: v for k, v in replay.items() if k != "journal"},
        "recompiles_after_warmup": recompiles,
    }
    print("BENCH " + json.dumps(bench))
    check_baseline("chaos_resilience", bench)
    assert chaos["requests_lost"] == 0, \
        f"{chaos['requests_lost']} admitted request(s) lost under chaos"
    assert chaos["nonfinite_outputs"] == 0, \
        f"{chaos['nonfinite_outputs']} non-finite latent(s) served"
    assert chaos["faults_exhausted"], \
        f"scripted faults never applied: {chaos['faults']}"
    assert chaos["deaths"] >= 2, \
        f"crash + partition should kill 2 replicas, got {chaos['deaths']}"
    assert verify["escalated"] >= 1 and verify["escalated_bitwise"] == 1, \
        f"escalated samples not bitwise-identical to the clean " \
        f"powerful path: {verify}"
    assert verify["moved_max_err"] <= 1e-4, \
        f"re-admitted output diverged ({verify['moved_max_err']:.2e})"
    assert replay["replayed"] >= 1, \
        f"router crash stranded no work — replay proved nothing: {replay}"
    assert replay["missing"] == 0 and replay["duplicates"] == 0, \
        f"journal replay not exactly-once: {replay}"
    assert replay["max_readmit_err"] <= 1e-4, \
        f"replayed output diverged ({replay['max_readmit_err']:.2e})"
    assert recompiles == 0, \
        f"{recompiles} recompile(s) after the chaos rehearsal"


if __name__ == "__main__":
    bench_chaos()
