"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Benches that emit a
``BENCH {...}`` json line get that summary persisted: after a run the
harness writes ``benchmarks/BENCH_<suite>.json`` (git sha, timestamp,
one summary dict per bench) so CI diffs and dashboards read artifacts,
not stdout scrollback.

  PYTHONPATH=src python -m benchmarks.run                  # all
  PYTHONPATH=src python -m benchmarks.run fig6 fig12       # substring filter
  PYTHONPATH=src python -m benchmarks.run --suite pipeline # named suite
  PYTHONPATH=src python -m benchmarks.run --suite profile --strict-analysis

Besides the per-run ``BENCH_<suite>.json`` (gitignored), each run also
folds its suite's headline numbers into the COMMITTED compact
``benchmarks/BENCH.json`` — one entry per suite with git sha — so the
perf trajectory is visible in plain git history. ``--strict-analysis``
pre-flights ``python -m repro.analysis --strict src/repro`` and refuses
to run any bench when the static-analysis gate fails.
"""
from __future__ import annotations

import json
import subprocess
import sys
import time
from datetime import datetime, timezone
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

ARTIFACT_DIR = Path(__file__).resolve().parent

# Named suites: exact bench names run together by `--suite <name>`.
SUITES = {
    "pipeline": ("pipeline_cache", "fig6_fid_vs_compute", "fig7_t2i",
                 "adaptive_scheduler", "flow_matching"),
    "distributed": ("distributed_seqpar",),
    "serving": ("serving_engine",),
    "fleet": ("fleet_router",),
    "chaos": ("chaos_resilience",),
    "cache": ("activation_cache",),
    "attention": ("attention_kernel",),
    "analysis": ("static_analysis",),
    "telemetry": ("telemetry",),
    "profile": ("compiled_profile",),
}

#: the committed perf-trajectory file (unlike BENCH_<suite>.json, this
#: one is NOT gitignored — regressions show up in plain `git log -p`)
TRAJECTORY_PATH = ARTIFACT_DIR / "BENCH.json"


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=Path(__file__).resolve().parents[1], capture_output=True,
            text=True, timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


class _BenchCapture:
    """stdout tee that collects ``BENCH {json}`` summary lines."""

    def __init__(self, wrapped):
        self._wrapped = wrapped
        self._buf = ""
        self.summaries = {}

    def write(self, s: str) -> int:
        self._buf += s
        while "\n" in self._buf:
            line, self._buf = self._buf.split("\n", 1)
            if line.startswith("BENCH "):
                try:
                    d = json.loads(line[len("BENCH "):])
                    self.summaries[d.get("name", f"bench{len(self.summaries)}")] = d
                except (json.JSONDecodeError, AttributeError):
                    pass
        return self._wrapped.write(s)

    def flush(self) -> None:
        self._wrapped.flush()

    def __getattr__(self, name):
        return getattr(self._wrapped, name)


def write_artifact(suite: str, summaries: dict, sha: str,
                   out_dir: Path = ARTIFACT_DIR) -> Path:
    """Persist one suite run's BENCH summaries as
    ``BENCH_<suite>.json`` (overwritten per run — the git sha inside is
    the provenance, the file name is the stable handle)."""
    out = out_dir / f"BENCH_{suite}.json"
    out.write_text(json.dumps({
        "suite": suite,
        "git_sha": sha,
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "benches": summaries,
    }, indent=1, sort_keys=True) + "\n")
    return out


def _headline(node, prefix: str = "", out: dict = None) -> dict:
    """Flatten one bench summary to dotted-key numeric headlines (the
    same paths ``baselines.json`` bounds use); strings/lists dropped."""
    if out is None:
        out = {}
    if isinstance(node, dict):
        for k, v in sorted(node.items()):
            _headline(v, f"{prefix}.{k}" if prefix else str(k), out)
    elif isinstance(node, bool):
        out[prefix] = int(node)
    elif isinstance(node, (int, float)):
        out[prefix] = node
    return out


def update_trajectory(suite: str, summaries: dict, sha: str,
                      path: Path = TRAJECTORY_PATH) -> Path:
    """Fold one suite run's headline numbers into the committed compact
    trajectory file: other suites' entries are preserved, this suite's
    entry is replaced. No timestamp — the file must be byte-stable for a
    given (sha, results) so re-runs don't dirty the tree."""
    doc = {"suites": {}}
    if path.exists():
        try:
            doc = json.loads(path.read_text())
        except json.JSONDecodeError:
            pass
    doc.setdefault("suites", {})[suite] = {
        "git_sha": sha,
        "benches": {name: _headline(s) for name, s in summaries.items()},
    }
    path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    return path


def main() -> None:
    from benchmarks import (bench_analysis, bench_attention, bench_cache,
                            bench_chaos, bench_core, bench_distributed,
                            bench_extensions,
                            bench_fleet, bench_modalities, bench_perf,
                            bench_pipeline, bench_profile, bench_serving,
                            bench_telemetry)
    from benchmarks.baseline import BaselineRegression
    from benchmarks.roofline_table import bench_roofline

    benches = [
        ("fig2_spectral", bench_core.bench_fig2_spectral),
        ("fig4_pred_gap", bench_core.bench_fig4_pred_gap),
        ("fig6_fid_vs_compute", bench_core.bench_fig6_fid_vs_compute),
        ("fig6_T_orthogonality", bench_core.bench_fig6_T_orthogonality),
        ("fig7_t2i", bench_modalities.bench_fig7_t2i),
        ("fig8_video", bench_modalities.bench_fig8_video),
        ("fig10_pruning", bench_core.bench_fig10_pruning_baselines),
        ("fig11_mmd", bench_modalities.bench_fig11_mmd_gap),
        ("fig9_utilization", bench_perf.bench_fig9_utilization),
        ("fig12_packing", bench_perf.bench_fig12_packing),
        ("adaptive_scheduler", bench_extensions.bench_adaptive_scheduler),
        ("flow_matching", bench_extensions.bench_flow_matching),
        ("pipeline_cache", bench_pipeline.bench_pipeline_cache),
        ("distributed_seqpar", bench_distributed.bench_distributed),
        ("serving_engine", bench_serving.bench_serving),
        ("fleet_router", bench_fleet.bench_fleet),
        ("chaos_resilience", bench_chaos.bench_chaos),
        ("activation_cache", bench_cache.bench_cache),
        ("attention_kernel", bench_attention.bench_attention),
        ("static_analysis", bench_analysis.bench_analysis),
        ("telemetry", bench_telemetry.bench_telemetry),
        ("compiled_profile", bench_profile.bench_profile),
        ("roofline", bench_roofline),
    ]
    argv = sys.argv[1:]
    if "--strict-analysis" in argv:
        argv.remove("--strict-analysis")
        root = Path(__file__).resolve().parents[1]
        import os
        env = dict(os.environ)
        env["PYTHONPATH"] = (str(root / "src")
                             + (os.pathsep + env["PYTHONPATH"]
                                if env.get("PYTHONPATH") else ""))
        rc = subprocess.run(
            [sys.executable, "-m", "repro.analysis", "--strict",
             "src/repro"], cwd=root, env=env).returncode
        if rc != 0:
            raise SystemExit("# strict-analysis pre-flight failed "
                             f"(exit {rc}); refusing to run benches")
        print("# strict-analysis pre-flight passed", flush=True)
    suite = None
    if "--suite" in argv:
        i = argv.index("--suite")
        if i + 1 >= len(argv):
            raise SystemExit(f"--suite needs a name; known: {sorted(SUITES)}")
        suite = argv[i + 1]
        if suite not in SUITES:
            raise SystemExit(f"unknown suite {suite!r}; known: {sorted(SUITES)}")
        del argv[i:i + 2]
    filters = [a for a in argv if not a.startswith("-")]
    cap = _BenchCapture(sys.stdout)
    sys.stdout = cap
    print("name,us_per_call,derived")
    regressions = []
    try:
        for name, fn in benches:
            if suite is not None and name not in SUITES[suite]:
                continue
            if filters and not any(f in name for f in filters):
                continue
            t0 = time.time()
            try:
                fn()
                print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
            except BaselineRegression as e:
                # a recorded analytic baseline was violated: keep running the
                # remaining benches, but fail the harness loudly at the end
                regressions.append((name, str(e)))
                print(f"{name},0.0,REGRESSION:{e}", flush=True)
            except Exception as e:  # keep the harness running
                print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
    finally:
        sys.stdout = cap._wrapped
    if cap.summaries:
        sha = _git_sha()
        out = write_artifact(suite or "all", cap.summaries, sha)
        print(f"# wrote {out} ({len(cap.summaries)} bench summaries)",
              flush=True)
        traj = update_trajectory(suite or "all", cap.summaries, sha)
        print(f"# updated trajectory {traj}", flush=True)
    if regressions:
        for name, msg in regressions:
            print(f"# BASELINE REGRESSION in {name}: {msg}",
                  file=sys.stderr, flush=True)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
