"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Benches that emit a
``BENCH {...}`` json line get that summary persisted: after a run the
harness writes ``benchmarks/BENCH_<suite>.json`` (git sha, timestamp,
one summary dict per bench) so CI diffs and dashboards read artifacts,
not stdout scrollback.

  PYTHONPATH=src python -m benchmarks.run                  # all
  PYTHONPATH=src python -m benchmarks.run fig6 fig12       # substring filter
  PYTHONPATH=src python -m benchmarks.run --suite pipeline # named suite
"""
from __future__ import annotations

import json
import subprocess
import sys
import time
from datetime import datetime, timezone
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

ARTIFACT_DIR = Path(__file__).resolve().parent

# Named suites: exact bench names run together by `--suite <name>`.
SUITES = {
    "pipeline": ("pipeline_cache", "fig6_fid_vs_compute", "fig7_t2i",
                 "adaptive_scheduler", "flow_matching"),
    "distributed": ("distributed_seqpar",),
    "serving": ("serving_engine",),
    "cache": ("activation_cache",),
    "attention": ("attention_kernel",),
    "analysis": ("static_analysis",),
    "telemetry": ("telemetry",),
}


def _git_sha() -> str:
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"],
            cwd=Path(__file__).resolve().parents[1], capture_output=True,
            text=True, timeout=10).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


class _BenchCapture:
    """stdout tee that collects ``BENCH {json}`` summary lines."""

    def __init__(self, wrapped):
        self._wrapped = wrapped
        self._buf = ""
        self.summaries = {}

    def write(self, s: str) -> int:
        self._buf += s
        while "\n" in self._buf:
            line, self._buf = self._buf.split("\n", 1)
            if line.startswith("BENCH "):
                try:
                    d = json.loads(line[len("BENCH "):])
                    self.summaries[d.get("name", f"bench{len(self.summaries)}")] = d
                except (json.JSONDecodeError, AttributeError):
                    pass
        return self._wrapped.write(s)

    def flush(self) -> None:
        self._wrapped.flush()

    def __getattr__(self, name):
        return getattr(self._wrapped, name)


def write_artifact(suite: str, summaries: dict, sha: str,
                   out_dir: Path = ARTIFACT_DIR) -> Path:
    """Persist one suite run's BENCH summaries as
    ``BENCH_<suite>.json`` (overwritten per run — the git sha inside is
    the provenance, the file name is the stable handle)."""
    out = out_dir / f"BENCH_{suite}.json"
    out.write_text(json.dumps({
        "suite": suite,
        "git_sha": sha,
        "timestamp": datetime.now(timezone.utc).isoformat(),
        "benches": summaries,
    }, indent=1, sort_keys=True) + "\n")
    return out


def main() -> None:
    from benchmarks import (bench_analysis, bench_attention, bench_cache,
                            bench_core, bench_distributed, bench_extensions,
                            bench_modalities, bench_perf, bench_pipeline,
                            bench_serving, bench_telemetry)
    from benchmarks.baseline import BaselineRegression
    from benchmarks.roofline_table import bench_roofline

    benches = [
        ("fig2_spectral", bench_core.bench_fig2_spectral),
        ("fig4_pred_gap", bench_core.bench_fig4_pred_gap),
        ("fig6_fid_vs_compute", bench_core.bench_fig6_fid_vs_compute),
        ("fig6_T_orthogonality", bench_core.bench_fig6_T_orthogonality),
        ("fig7_t2i", bench_modalities.bench_fig7_t2i),
        ("fig8_video", bench_modalities.bench_fig8_video),
        ("fig10_pruning", bench_core.bench_fig10_pruning_baselines),
        ("fig11_mmd", bench_modalities.bench_fig11_mmd_gap),
        ("fig9_utilization", bench_perf.bench_fig9_utilization),
        ("fig12_packing", bench_perf.bench_fig12_packing),
        ("adaptive_scheduler", bench_extensions.bench_adaptive_scheduler),
        ("flow_matching", bench_extensions.bench_flow_matching),
        ("pipeline_cache", bench_pipeline.bench_pipeline_cache),
        ("distributed_seqpar", bench_distributed.bench_distributed),
        ("serving_engine", bench_serving.bench_serving),
        ("activation_cache", bench_cache.bench_cache),
        ("attention_kernel", bench_attention.bench_attention),
        ("static_analysis", bench_analysis.bench_analysis),
        ("telemetry", bench_telemetry.bench_telemetry),
        ("roofline", bench_roofline),
    ]
    argv = sys.argv[1:]
    suite = None
    if "--suite" in argv:
        i = argv.index("--suite")
        if i + 1 >= len(argv):
            raise SystemExit(f"--suite needs a name; known: {sorted(SUITES)}")
        suite = argv[i + 1]
        if suite not in SUITES:
            raise SystemExit(f"unknown suite {suite!r}; known: {sorted(SUITES)}")
        del argv[i:i + 2]
    filters = [a for a in argv if not a.startswith("-")]
    cap = _BenchCapture(sys.stdout)
    sys.stdout = cap
    print("name,us_per_call,derived")
    regressions = []
    try:
        for name, fn in benches:
            if suite is not None and name not in SUITES[suite]:
                continue
            if filters and not any(f in name for f in filters):
                continue
            t0 = time.time()
            try:
                fn()
                print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
            except BaselineRegression as e:
                # a recorded analytic baseline was violated: keep running the
                # remaining benches, but fail the harness loudly at the end
                regressions.append((name, str(e)))
                print(f"{name},0.0,REGRESSION:{e}", flush=True)
            except Exception as e:  # keep the harness running
                print(f"{name},0.0,ERROR:{type(e).__name__}:{e}", flush=True)
    finally:
        sys.stdout = cap._wrapped
    if cap.summaries:
        out = write_artifact(suite or "all", cap.summaries, _git_sha())
        print(f"# wrote {out} ({len(cap.summaries)} bench summaries)",
              flush=True)
    if regressions:
        for name, msg in regressions:
            print(f"# BASELINE REGRESSION in {name}: {msg}",
                  file=sys.stderr, flush=True)
        raise SystemExit(1)


if __name__ == '__main__':
    main()
