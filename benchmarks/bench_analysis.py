"""Static-analysis suite as a bench: runs the full trace-safety pass
(Level-1 AST lint over ``src/repro`` + Level-2 jaxpr audit) and gates it
through ``baselines.json`` like every other suite — zero non-baselined
errors, every fingerprint invariance intact (DESIGN.md §analysis).

The BENCH line records the finding counts and the per-unit jaxpr
fingerprints, so CI diffs show WHICH step family's structure moved when
a fingerprint changes.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))


def bench_analysis() -> None:
    from benchmarks import common as C
    from benchmarks.baseline import check_baseline
    from repro.analysis import engine

    t0 = time.perf_counter()
    lint_only = engine.run_analysis(
        [engine.REPO_ROOT / "src" / "repro"], with_jaxpr=False)
    dt_lint = time.perf_counter() - t0

    t0 = time.perf_counter()
    report = engine.run_analysis([engine.REPO_ROOT / "src" / "repro"])
    dt_full = time.perf_counter() - t0

    new_err = len(report.new_errors)
    new_warn = len(report.new) - new_err
    drift = sum(1 for f in report.new + report.baselined
                if f.rule == "jaxpr-fingerprint-drift")
    C.csv_row("analysis_lint", dt_lint * 1e6,
              f"new_errors={new_err};warnings={new_warn};"
              f"baselined={len(report.baselined)}")
    C.csv_row("analysis_full", dt_full * 1e6,
              f"fingerprinted_units={len(report.fingerprints)};"
              f"drift={drift}")
    bench = {
        "name": "analysis",
        "lint_wall_s": dt_lint, "full_wall_s": dt_full,
        "new_errors": new_err, "new_warnings": new_warn,
        "baselined": len(report.baselined),
        "fingerprint_drift": drift,
        "fingerprints": report.fingerprints,
    }
    print("BENCH " + json.dumps(bench))
    check_baseline("analysis", bench)


if __name__ == "__main__":
    bench_analysis()
