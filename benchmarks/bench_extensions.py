"""Beyond-paper extensions: adaptive per-sample scheduler (paper App. A
future work) and flow-matching compatibility (paper: 'applied out of the
box for flow matching') — both driven through the unified pipeline API."""
from __future__ import annotations

import jax
import numpy as np

from benchmarks import common as C
from repro.core import FlexiSchedule
from repro.pipeline import AdaptiveBudget, SamplingPlan


def bench_adaptive_scheduler(T: int = 20, n: int = 32):
    """Adaptive switch-point vs static schedules: quality at matched FLOPs."""
    params, cfg, sched = C.get_flexidit()
    ref, _ = C.reference_set(128)
    pipe = C.get_pipeline(params, cfg, sched)
    key = jax.random.PRNGKey(77)
    for thr in (0.2, 0.4, 0.8):
        plan = SamplingPlan(T=T, budget=AdaptiveBudget(threshold=thr,
                                                       probe_every=2),
                            guidance_scale=1.5)
        res = pipe.sample(plan, n, key)
        fid = C.fid_proxy(np.asarray(res.x0), ref)
        C.csv_row(f"adaptive_thr{thr}", 0.0,
                  f"switch_at={res.trace['switch_step']}/{T};"
                  f"compute={res.relative_compute:.3f};fid={fid:.3f}")
    return True


def bench_flow_matching(T: int = 16, n: int = 32):
    """FlexiDiT weak→powerful schedule under rectified flow (Euler)."""
    params, cfg, sched = C.get_flexidit()
    ref, _ = C.reference_set(128)
    pipe = C.get_pipeline(params, cfg, sched)
    key = jax.random.PRNGKey(88)
    # NOTE: the bench DiT was trained with the DDPM ε-objective; under the
    # linear path ε-prediction ≈ velocity up to the x0 term, so this bench
    # reports *relative* weak-vs-powerful behaviour under the flow sampler.
    for T_weak in (0, T // 2):
        plan = SamplingPlan(T=T, budget=FlexiSchedule.weak_first(T, T_weak),
                            solver="flow_euler", guidance_scale=0.0)
        res = pipe.sample(plan, n, key)
        out = np.asarray(res.x0)
        fid = C.fid_proxy(out, ref)
        C.csv_row(f"flow_Tweak{T_weak}", 0.0, f"fid={fid:.3f};finite="
                  f"{bool(np.isfinite(out).all())}")
    return True
