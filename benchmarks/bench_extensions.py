"""Beyond-paper extensions: adaptive per-sample scheduler (paper App. A
future work) and flow-matching compatibility (paper: 'applied out of the
box for flow matching')."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core.adaptive import adaptive_sample, make_mode_eps_fns
from repro.diffusion import flow, schedule as sch


def bench_adaptive_scheduler(T: int = 20, n: int = 32):
    """Adaptive switch-point vs static schedules: quality at matched FLOPs."""
    params, cfg, sched = C.get_flexidit()
    ref, _ = C.reference_set(128)
    ts = sch.respaced_timesteps(sched.num_steps, T)
    cond = jnp.arange(n) % C.N_CLASSES
    null = jnp.full((n,), C.N_CLASSES)
    fns = make_mode_eps_fns(params, cfg, cond, null, cfg_scale=1.5)
    key = jax.random.PRNGKey(77)
    x_T = jax.random.normal(key, (n,) + cfg.dit.latent_shape)
    for thr in (0.2, 0.4, 0.8):
        res = adaptive_sample(fns, sched, x_T, ts, key, cfg, threshold=thr,
                              probe_every=2)
        fid = C.fid_proxy(np.asarray(res.x0), ref)
        frac = res.flops / res.flops_static_powerful
        C.csv_row(f"adaptive_thr{thr}", 0.0,
                  f"switch_at={res.switch_step}/{T};compute={frac:.3f};"
                  f"fid={fid:.3f}")
    return True


def bench_flow_matching(T: int = 16, n: int = 32):
    """FlexiDiT weak→powerful schedule under rectified flow (Euler)."""
    params, cfg, sched = C.get_flexidit()
    ref, _ = C.reference_set(128)
    cond = jnp.arange(n) % C.N_CLASSES
    key = jax.random.PRNGKey(88)
    x_T = jax.random.normal(key, (n,) + cfg.dit.latent_shape)
    # NOTE: the bench DiT was trained with the DDPM ε-objective; under the
    # linear path ε-prediction ≈ velocity up to the x0 term, so this bench
    # reports *relative* weak-vs-powerful behaviour under the flow sampler.
    v_fns = {m: flow.make_flow_v_fn(params, cfg, cond, mode=m)
             for m in (0, 1)}
    taus = flow.tau_ladder(T)
    for T_weak in (0, T // 2):
        phases = flow.split_tau_ladder(taus, [(1, T_weak), (0, T - T_weak)])
        out = flow.sample_flow_phased([(v_fns[m], t) for m, t in phases],
                                      x_T)
        fid = C.fid_proxy(np.asarray(out), ref)
        C.csv_row(f"flow_Tweak{T_weak}", 0.0, f"fid={fid:.3f};finite="
                  f"{bool(np.isfinite(np.asarray(out)).all())}")
    return True
