"""Segment-aware Pallas flash attention vs the XLA paths (DESIGN.md
§attention-backend).

Serving bucket shapes (dit-xl-2 geometry: 256-token rows, weak segments
of 64 tokens) drive three measurements:

* **analytic** — attention FLOPs of a saturated mixed-budget pack under
  dense N² pricing vs the block-sparse ledger (the tiles the kernel
  actually visits), plus the cross-segment block skip rate of REAL
  ``greedy_fit`` packs from the serving bucket menu. Deterministic;
  gated against ``baselines.json`` (``run.py`` fails loudly on
  regression).
* **wall-clock** — one packed-row attention call per backend
  (interpret-mode Pallas on this CPU container is expected to trail the
  fused XLA einsums — the compiled path targets TPU; the number is
  reported for trend-tracking, not gated).
* **zero-recompile** — swapping pack layouts under the fixed bucket
  shape must replay one executable.
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

REPEATS = 5


def _bench_cfg():
    """dit-xl-2 token geometry (256-token rows, 64-token weak segments —
    ``reduced()`` shrinks the latent, so pin the real 32x32 grid back)
    at smoke width: attention shapes are what matter here."""
    from repro.configs import get_config
    base = get_config("dit-xl-2")
    red = base.reduced()
    return dataclasses.replace(
        red, num_layers=4, d_model=128, d_ff=512,
        attn=dataclasses.replace(red.attn, num_heads=8, num_kv_heads=8,
                                 head_dim=16),
        dit=dataclasses.replace(red.dit,
                                latent_shape=base.dit.latent_shape))


def _time_best(fn, *args):
    import jax
    jax.block_until_ready(fn(*args))          # compile / warm
    best = float("inf")
    for _ in range(REPEATS):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def bench_attention() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.base import AttnConfig
    from repro.core import packing
    from repro.kernels.attention import costing
    from repro.kernels.attention import ops as attn_ops
    from repro.models import attention as attn_mod
    from repro.models import dit as dit_mod
    from repro.serving.batcher import BucketMenu
    from benchmarks.baseline import check_baseline

    cfg = _bench_cfg()
    d = cfg.d_model
    H = cfg.attn.num_heads
    hd = d // H
    N0 = dit_mod.tokens_for_mode(cfg, 0)            # row capacity (256)
    N1 = dit_mod.tokens_for_mode(cfg, 1)            # weak segment (64)
    r = packing.pack_ratio(cfg, 1)

    # --- a saturated mixed-budget pack: the steady-state weak-heavy mix
    # a budget<=0.6 menu keeps in flight (most steps are weak phases),
    # assembled by the SAME greedy_fit the engine's cold planner runs
    menu = BucketMenu(cfg, (0, 1), max_tokens_per_step=16 * N0, guided=True)
    req_modes = [0] + [1] * 10
    idx, counts = menu.greedy_fit(req_modes)
    assert len(idx) == len(req_modes), "pack not saturated"
    from repro.pipeline.packed import PackLayout
    layout = PackLayout.for_counts(counts, guided=True, row_capacity=N0)
    seg_modes = layout.segment_modes()

    dense_attn = 0.0
    sparse_attn = 0.0
    rows = packing.assign_rows(
        [dit_mod.tokens_for_mode(cfg, m) for m in seg_modes], N0)
    seg_tokens = [dit_mod.tokens_for_mode(cfg, m) for m in seg_modes]
    L = cfg.num_layers
    for row in rows:
        lengths = [seg_tokens[i] for i in row]
        dense_attn += L * costing.dense_attention_flops(N0, N0, d)
        sparse_attn += L * costing.block_sparse_attention_flops(
            lengths, N0, d)
    reduction = 1.0 - sparse_attn / dense_attn
    active, total = layout.attention_block_stats(cfg)
    skip_rate = 1.0 - active / total

    # pack-level cost through the public ledger (controller pricing path)
    cost_dense = layout.cost(cfg).flops
    cost_sparse = layout.cost(cfg, attn_backend="pallas").flops

    # --- wall-clock at the bucket shape: R packed rows of capacity N0
    R = len(rows)
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (R, N0, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (R, N0, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (R, N0, H, hd), jnp.float32)
    seg = np.full((R, N0), -1, np.int32)
    for ri, row in enumerate(rows):
        off = 0
        for si in row:
            seg[ri, off:off + seg_tokens[si]] = si
            off += seg_tokens[si]
    seg_j = jnp.asarray(seg)
    acfg = AttnConfig(num_heads=H, num_kv_heads=H, head_dim=hd,
                      use_rope=False)
    pos = jnp.broadcast_to(jnp.arange(N0, dtype=jnp.int32), (R, N0))

    pallas_fn = jax.jit(lambda q, k, v, s: attn_ops.flash_attention(
        q, k, v, causal=False, segment_ids=s))
    dense_fn = jax.jit(lambda q, k, v, s: attn_mod.gqa_attend(
        q, k, v, attn_mod.make_attention_bias(pos, pos, causal=False,
                                              window=0, q_segment=s,
                                              k_segment=s), acfg))
    blocked_fn = jax.jit(lambda q, k, v, s: attn_mod.blocked_gqa_attend(
        q, k, v, positions=pos, causal=False, window=0, cfg=acfg,
        q_block=128, segment_ids=s))
    us_pallas = _time_best(pallas_fn, q, k, v, seg_j)
    us_dense = _time_best(dense_fn, q, k, v, seg_j)
    us_blocked = _time_best(blocked_fn, q, k, v, seg_j)

    # --- zero recompiles across pack layouts at the fixed bucket shape
    n_before = attn_ops.compile_cache_size()
    alt = np.full((R, N0), -1, np.int32)
    alt[:, :200] = 0                              # a different layout
    jax.block_until_ready(pallas_fn(q, k, v, jnp.asarray(alt)))
    recompiles = attn_ops.compile_cache_size() - n_before

    bench = {
        "name": "attention",
        "row_capacity": N0,
        "weak_segment_tokens": N1,
        "pack_ratio": r,
        "rows": R,
        "pack_segments": len(seg_modes),
        "attn_flops_dense": dense_attn,
        "attn_flops_sparse": sparse_attn,
        "attn_flops_reduction_frac": reduction,
        "attn_block_skip_rate": skip_rate,
        "pack_cost_flops_dense": cost_dense,
        "pack_cost_flops_sparse": cost_sparse,
        "us_pallas_interpret": us_pallas,
        "us_dense": us_dense,
        "us_blocked": us_blocked,
        "recompiles_across_layouts": recompiles,
    }
    print("BENCH " + json.dumps(bench))
    print(f"attention,{us_pallas:.1f},"
          f"sparse_reduction={reduction:.3f};skip={skip_rate:.3f};"
          f"recompiles={recompiles}")
    assert recompiles == 0, "pack-layout switch recompiled the kernel"
    check_baseline("attention", bench)


if __name__ == "__main__":
    bench_attention()
