"""Benchmarks for the paper's core figures on the class-conditional model:
Fig. 2 (spectral), Fig. 4 (prediction gap), Fig. 6 (FID vs compute; T vs
T_weak), Fig. 10 (pruning baselines), Fig. 19 (opposite scheduler).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.core import FlexiSchedule, relative_compute
from repro.diffusion import schedule as sch
from repro.models import dit as dit_mod


def bench_fig4_pred_gap():
    """‖ε_weak − ε_powerful‖² vs t: should DECREASE with t (Fig. 4 right)."""
    params, cfg, sched = C.get_flexidit()
    ref, cond = C.reference_set(32)
    x0 = jnp.asarray(ref[:32])
    y = jnp.asarray(cond[:32])
    key = jax.random.PRNGKey(0)
    gaps = []
    for t_val in (5, 25, 50, 75, 95):
        t = jnp.full((32,), t_val)
        x_t = sch.q_sample(sched, x0, t, jax.random.normal(key, x0.shape))
        e0 = dit_mod.eps_prediction(dit_mod.dit_forward(
            params, x_t, t.astype(jnp.float32), y, cfg, mode=0), cfg)
        e1 = dit_mod.eps_prediction(dit_mod.dit_forward(
            params, x_t, t.astype(jnp.float32), y, cfg, mode=1), cfg)
        gaps.append(float(jnp.mean(jnp.square(e0 - e1))
                          / jnp.mean(jnp.square(e0))))
    trend = "decreasing" if gaps[-1] < gaps[0] else "NOT-decreasing"
    C.csv_row("fig4_pred_gap", 0.0,
              f"rel_gap(t=5..95)={['%.4f' % g for g in gaps]};{trend}")
    return {"t": [5, 25, 50, 75, 95], "gap": gaps}


def bench_fig6_fid_vs_compute(T: int = 20, n: int = 64):
    """FID-proxy across T_weak sweep + the opposite scheduler ablation."""
    params, cfg, sched = C.get_flexidit()
    ref, _ = C.reference_set(128)
    key = jax.random.PRNGKey(7)
    rows = []
    for T_weak in (0, T // 4, T // 2, 3 * T // 4, T - 2):
        s = C.generate(params, cfg, sched, T=T, T_weak=T_weak, n=n, key=key)
        fid = C.fid_proxy(s, ref)
        comp = relative_compute(cfg, FlexiSchedule.weak_first(T, T_weak))
        rows.append((T_weak, comp, fid))
        C.csv_row(f"fig6_fid_Tweak{T_weak}", 0.0,
                  f"compute={comp:.3f};fid={fid:.3f}")
    # opposite scheduler (Fig. 19): weak LAST should be worse
    T_weak = T // 2
    s_rev = C.generate(params, cfg, sched, T=T, T_weak=T_weak, n=n, key=key,
                       weak_last=True)
    fid_rev = C.fid_proxy(s_rev, ref)
    fid_fwd = rows[2][2]
    C.csv_row("fig19_weak_last", 0.0,
              f"fid_weak_first={fid_fwd:.3f};fid_weak_last={fid_rev:.3f};"
              f"weak_first_better={fid_rev > fid_fwd}")
    return rows


def bench_fig6_T_orthogonality(n: int = 48):
    """Gains from weak steps are orthogonal to lowering T (Fig. 6 right)."""
    params, cfg, sched = C.get_flexidit()
    ref, _ = C.reference_set(128)
    key = jax.random.PRNGKey(9)
    out = {}
    for T in (10, 20):
        for frac in (0.0, 0.5):
            T_weak = int(T * frac)
            s = C.generate(params, cfg, sched, T=T, T_weak=T_weak, n=n,
                           key=key)
            fid = C.fid_proxy(s, ref)
            comp = relative_compute(cfg, FlexiSchedule.weak_first(T, T_weak)) * T
            out[(T, T_weak)] = fid
            C.csv_row(f"fig6r_T{T}_w{T_weak}", 0.0,
                      f"nfe_equiv={comp:.1f};fid={fid:.3f}")
    return out


def bench_fig2_spectral(T: int = 20, n: int = 24):
    """Filter ONE step's update (low/high pass) early vs late; measure final
    sample change (L2 + SSIM): high-pass filtering matters more EARLY."""
    params, cfg, sched = C.get_flexidit()
    key = jax.random.PRNGKey(3)
    from repro.pipeline import SamplingPlan
    pipe = C.get_pipeline(params, cfg, sched)
    ts = sch.respaced_timesteps(sched.num_steps, T)
    plan = SamplingPlan(T=T, budget=1.0, solver="ddim", guidance_scale=1.5)

    def filtered(step_idx, kind):
        def transform(eps, x, t):
            hit = jnp.any(t[0] == ts[step_idx])
            F = jnp.fft.fft2(eps.astype(jnp.complex64), axes=(2, 3))
            H, W = eps.shape[2], eps.shape[3]
            fy = jnp.fft.fftfreq(H)[None, None, :, None, None]
            fx = jnp.fft.fftfreq(W)[None, None, None, :, None]
            rad = jnp.sqrt(fy ** 2 + fx ** 2)
            mask = (rad <= 0.25) if kind == "low" else (rad > 0.25)
            Ff = jnp.where(mask, F, 0.0)
            eps_f = jnp.real(jnp.fft.ifft2(Ff, axes=(2, 3))).astype(eps.dtype)
            return jnp.where(hit, eps_f, eps)
        return transform

    x_T = jax.random.normal(key, (n,) + cfg.dit.latent_shape)
    base = np.asarray(pipe.sample(plan, n, key, x_T=x_T).x0)
    results = {}
    for when, idx in (("early", 1), ("late", T - 2)):
        for kind in ("low", "high"):
            out = np.asarray(pipe.sample(plan, n, key, x_T=x_T,
                                         eps_transform=filtered(idx, kind)).x0)
            l2 = float(np.sqrt(((out - base) ** 2).mean()))
            s = C.ssim(out, base)
            results[(when, kind)] = (l2, s)
            C.csv_row(f"fig2_{when}_{kind}pass", 0.0,
                      f"l2={l2:.4f};ssim={s:.4f}")
    # paper: removing low frequencies (high-pass) hurts MORE early than late
    ok = results[("early", "high")][0] > results[("late", "high")][0]
    C.csv_row("fig2_claim", 0.0, f"highpass_hurts_more_early={ok}")
    return results


def bench_fig10_pruning_baselines(T: int = 20, n: int = 48):
    """FlexiDiT weak-schedule vs magnitude/random pruning at matched FLOPs."""
    params, cfg, sched = C.get_flexidit()
    ref, _ = C.reference_set(128)
    key = jax.random.PRNGKey(11)
    T_weak = T // 2
    comp = relative_compute(cfg, FlexiSchedule.weak_first(T, T_weak))
    s_flexi = C.generate(params, cfg, sched, T=T, T_weak=T_weak, n=n, key=key)
    fid_flexi = C.fid_proxy(s_flexi, ref)

    def prune(p, frac, kind):
        def prune_leaf(path_leaf):
            w = path_leaf
            if w.ndim < 2:
                return w
            if kind == "magnitude":
                thresh = jnp.quantile(jnp.abs(w), frac)
                return jnp.where(jnp.abs(w) < thresh, 0.0, w)
            k = jax.random.PRNGKey(int(w.size) % 7919)
            mask = jax.random.uniform(k, w.shape) > frac
            return w * mask
        out = dict(p)
        out["blocks"] = dict(p["blocks"])
        out["blocks"]["mlp"] = jax.tree.map(prune_leaf, p["blocks"]["mlp"])
        out["blocks"]["attn"] = jax.tree.map(prune_leaf, p["blocks"]["attn"])
        return out

    frac = 1.0 - comp          # match the FLOPs saved by the weak schedule
    rows = {"flexidit": fid_flexi}
    for kind in ("magnitude", "random"):
        pp = prune(params, frac, kind)
        s = C.generate(pp, cfg, sched, T=T, T_weak=0, n=n, key=key)
        rows[kind] = C.fid_proxy(s, ref)
    C.csv_row("fig10_pruning", 0.0,
              f"compute={comp:.2f};fid_flexi={rows['flexidit']:.3f};"
              f"fid_magnitude={rows['magnitude']:.3f};"
              f"fid_random={rows['random']:.3f};"
              f"flexi_best={rows['flexidit'] <= min(rows['magnitude'], rows['random'])}")
    return rows
