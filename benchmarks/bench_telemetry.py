"""Telemetry overhead + correctness gates (DESIGN.md §telemetry).

Three claims, all gated via ``baselines.json``:

* **overhead** — serving the same drain workload with full telemetry
  (spans + taps) costs <3% tokens/s vs telemetry off. Taps are extra
  data outputs of the same fused step; spans are a handful of host
  clock reads per dispatch. Timed best-of-N, interleaved, because CPU
  wall clocks drift.
* **zero added recompiles** — after one warm drain per family, replaying
  the workload (a budget-mix switch each wave) compiles nothing, taps on
  or off. The tapped family is cached under its own key; turning
  telemetry on costs exactly the one-time warmup of that family.
* **drift tap ≡ eager** — the on-device replay-drift tap
  (``‖new_delta − old_delta‖`` inside the scan) matches an eager
  step-by-step host recomputation of the same quantity to ≤1e-5, on
  trained-like weights where drift is nonzero.
"""
from __future__ import annotations

import dataclasses
import json
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))
sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

T = 12
TRAIN_T = 100
N_REQ = 16
MAX_TOKENS = 4096
REPEATS = 6                    # best-of-N timing (CPU wall noise)
DRIFT_ATOL = 1e-5


def _bench_cfg():
    # Big enough that model compute dominates per-dispatch fixed costs —
    # the overhead gate measures the marginal cost of taps, and on a toy
    # model host/jit-call constants swamp it.
    from repro.configs import get_config
    base = get_config("dit-xl-2").reduced()
    return dataclasses.replace(
        base, num_layers=6, d_model=256, d_ff=1024,
        attn=dataclasses.replace(base.attn, num_heads=8, num_kv_heads=8,
                                 head_dim=32))


def _trained_like(params, key):
    """Non-degenerate de-embed / adaLN gates so cached-replay drift is a
    real signal, not structurally zero (zero-init heads make every block
    an identity at init)."""
    import jax
    params["deembed"]["w_flex"] = jax.random.normal(
        jax.random.fold_in(key, 1),
        params["deembed"]["w_flex"].shape) * 0.1
    params["final"]["ada"]["w"] = jax.random.normal(
        jax.random.fold_in(key, 2), params["final"]["ada"]["w"].shape) * 0.05
    params["blocks"]["ada"]["w"] = jax.random.normal(
        jax.random.fold_in(key, 3), params["blocks"]["ada"]["w"].shape) * 0.05
    return params


def bench_telemetry() -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks import common as C
    from benchmarks.baseline import check_baseline
    from repro.cache import apply as cache_apply
    from repro.core.guidance import GuidanceConfig
    from repro.diffusion import schedule as sch
    from repro.models import dit as dit_mod
    from repro.pipeline import FlexiPipeline, SamplingPlan
    from repro.serving import BucketMenu, CacheSpec, ServingEngine
    from repro.telemetry import Telemetry

    cfg = _bench_cfg()
    params = _trained_like(dit_mod.init_dit(cfg, jax.random.PRNGKey(0)),
                           jax.random.PRNGKey(0))
    sched = sch.linear_schedule(TRAIN_T)
    pipe = FlexiPipeline(params, cfg, sched)
    cache = CacheSpec(policy="interval", interval=2)
    split = cache.resolve_split(cfg.num_layers)

    # ------------------------------------------------------------------
    # Gate 3 first (cheap, device-independent): drift tap ≡ eager replay

    B = 2
    g = GuidanceConfig(scale=1.5, mode_cond=0, mode_uncond=0)
    cond = jnp.asarray([1, 2], jnp.int32)
    null = jnp.full((B,), cfg.dit.num_classes, jnp.int32)
    eps_fn_c = cache_apply.make_cached_eps_fn(
        params, cfg, cond, null, g, None, None, split,
        attn_backend="dense")
    ts = sch.respaced_timesteps(TRAIN_T, 8)
    refresh = jnp.asarray([i % 2 == 0 for i in range(len(ts))])
    x0 = jax.random.normal(jax.random.PRNGKey(3),
                           (B,) + cfg.dit.latent_shape)
    delta0 = jnp.zeros(cache_apply.delta_shape(cfg, 0, B, True))
    key = jax.random.PRNGKey(4)
    _x, tap = cache_apply.cached_ddim_phase(
        eps_fn_c, sched, x0, ts, refresh, key, delta0, taps=True)
    tap_drift = np.asarray(tap["drift"])            # [T, 2B]

    # eager recomputation: same loop, step by step on the host
    ts_prev = np.concatenate([ts[1:], [-1]])
    x, delta = x0, delta0
    eager = []
    for i, (t, tp) in enumerate(zip(ts, ts_prev)):
        tb = jnp.full((B,), int(t), jnp.int32)
        tpb = jnp.full((B,), int(tp), jnp.int32)
        eps, _lv, nd = eps_fn_c(x, tb, delta, refresh[i])
        d = np.asarray(nd - delta)
        eager.append(np.sqrt(np.mean(np.square(d),
                                     axis=tuple(range(1, d.ndim)))))
        x = sch.ddim_step(sched, x, eps, tb, tpb, 0.0, key)
        delta = nd
    eager = np.stack(eager)
    drift_err = float(np.max(np.abs(tap_drift - eager)))
    drift_refresh_mean = float(eager[np.asarray(refresh)].mean())
    skip_max = float(np.max(np.abs(tap_drift[~np.asarray(refresh)])))
    assert drift_refresh_mean > 0, \
        "trained-like weights should produce nonzero refresh drift"
    C.csv_row("telemetry_drift", 0.0,
              f"tap_vs_eager_max_err={drift_err:.2e};"
              f"refresh_drift_mean={drift_refresh_mean:.4f};"
              f"skip_drift_max={skip_max:.2e}")

    # ------------------------------------------------------------------
    # Gates 1+2: serving overhead + zero added recompiles

    plans = {}
    for b in (0.4, 0.7, 1.0):
        plan = SamplingPlan(T=T, budget=b, guidance_scale=1.5,
                            attn_backend="dense")
        plan.validate(cfg)
        plans[b] = plan
    levels = sorted(plans)
    level_tokens = {}
    for b, plan in plans.items():
        fs = plan.resolve_schedule(cfg)
        level_tokens[b] = 2 * sum(
            n * dit_mod.tokens_for_mode(cfg, m) for m, n in fs.phases)
    rng = np.random.default_rng(0)
    reqs = [(int(rng.integers(0, cfg.dit.num_classes)),
             levels[int(rng.integers(0, len(levels)))])
            for _ in range(N_REQ)]
    useful_tokens = sum(level_tokens[lvl] for _, lvl in reqs)
    menu = BucketMenu(cfg, (0, 1), MAX_TOKENS, guided=True)

    def drain(telemetry=None):
        engine = ServingEngine(pipe, plans, max_tokens_per_step=MAX_TOKENS,
                               menu=menu, cache=cache, telemetry=telemetry)
        for i, (label, lvl) in enumerate(reqs):
            engine.submit(cond=label, budget=lvl,
                          key=jax.random.fold_in(jax.random.PRNGKey(7), i))
        results = engine.run()
        jax.block_until_ready(results[-1].x0)
        return engine, results

    drain()                                        # warm the untapped family
    warm_off = pipe.cache_stats()["compiled"]
    tel_warm = Telemetry(taps=True)
    drain(tel_warm)                                # warm the tapped family
    warm_on = pipe.cache_stats()["compiled"]
    tapped_family_compiles = warm_on - warm_off

    dt_off = dt_on = float("inf")
    for rep in range(REPEATS):                     # interleave AND alternate
        tel = Telemetry(taps=True)                 # order: per-drain wall
        legs = [("off", None), ("on", tel)]        # noise is ~10%, an order
        if rep % 2:                                # bias would swamp the
            legs.reverse()                         # few-% signal
        for which, t in legs:
            t0 = time.perf_counter()
            engine, res = drain(t)
            dt = time.perf_counter() - t0
            if which == "off":
                engine_off, res_off = engine, res
                dt_off = min(dt_off, dt)
            else:
                engine_on, res_on = engine, res
                dt_on = min(dt_on, dt)
    recompiles = pipe.cache_stats()["compiled"] - warm_on
    assert recompiles == 0, \
        f"{recompiles} recompiles during telemetry on/off replay"
    # latents must not depend on whether anyone was watching
    a = {r.request.id: np.asarray(r.x0) for r in res_off}
    b = {r.request.id: np.asarray(r.x0) for r in res_on}
    assert all(np.array_equal(a[i], b[i]) for i in a), \
        "telemetry changed the served latents"

    tps_off = useful_tokens / dt_off
    tps_on = useful_tokens / dt_on
    overhead = 1.0 - tps_on / tps_off
    agg = tel.taps.aggregate()
    n_spans = tel.recorder.events_recorded
    C.csv_row("telemetry_overhead", dt_on * 1e6,
              f"tps_off={tps_off:.0f};tps_on={tps_on:.0f};"
              f"overhead_frac={overhead:.4f};"
              f"recompiles_after_warmup={recompiles};"
              f"tapped_family_compiles={tapped_family_compiles};"
              f"span_events={n_spans};"
              f"tap_request_steps={agg['request_steps']}")

    bench = {
        "name": "telemetry", "arch": "dit-xl-2:reduced+4L128d",
        "T": T, "requests": N_REQ, "levels": levels,
        "drift": {"tap_vs_eager_max_err": drift_err,
                  "refresh_drift_mean": drift_refresh_mean,
                  "skip_drift_max": skip_max},
        "overhead": {"tokens_per_s_off": tps_off,
                     "tokens_per_s_on": tps_on,
                     "overhead_frac": overhead,
                     "wall_s_off": dt_off, "wall_s_on": dt_on},
        "recompiles_after_warmup": recompiles,
        "tapped_family_compiles": tapped_family_compiles,
        "spans": {"events_recorded": n_spans,
                  "events_dropped": tel.recorder.events_dropped},
        "taps": agg,
    }
    print("BENCH " + json.dumps(bench))
    check_baseline("telemetry", bench)


if __name__ == "__main__":
    bench_telemetry()
