"""Fig. 9 (FLOPs vs latency / utilization) and Fig. 12 (packing)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common as C
from repro.configs import get_config
from repro.core.packing import packing_cost, packed_weak_forward
from repro.core.scheduler import dit_nfe_flops
from repro.models import dit as dit_mod


def bench_fig9_utilization():
    """Wall-time vs FLOPs for each patch mode of the bench DiT (CPU), plus
    the analytic TPU-v5e projection for the paper's full-size models."""
    params, cfg, sched = C.get_flexidit()
    B = 2  # paper's fig-9 batch (CFG pair)
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (B,) + cfg.dit.latent_shape)
    t = jnp.full((B,), 10.0)
    y = jnp.arange(B) % C.N_CLASSES
    rows = []
    for mode in range(1 + len(cfg.dit.flex_patch_sizes)):
        fn = jax.jit(lambda p, x, t, y, m=mode: dit_mod.dit_forward(
            p, x, t, y, cfg, mode=m))
        us = C.timeit(fn, params, x, t, y)
        fl = B * dit_nfe_flops(cfg, mode)
        gflops = fl / (us * 1e-6) / 1e9
        tok = dit_mod.tokens_for_mode(cfg, mode)
        rows.append((mode, tok, us, gflops))
        C.csv_row(f"fig9_cpu_mode{mode}", us,
                  f"tokens={tok};gflops_per_s={gflops:.2f}")
    # analytic v5e projections for the paper-scale configs
    from repro.launch.roofline import PEAK_FLOPS
    for arch in ("t2i-transformer", "video-dit"):
        full = get_config(arch)
        for mode in range(1 + len(full.dit.flex_patch_sizes)):
            fl = dit_nfe_flops(full, mode)
            tok = dit_mod.tokens_for_mode(full, mode)
            us_ideal = fl / PEAK_FLOPS * 1e6
            C.csv_row(f"fig9_v5e_{arch}_mode{mode}", us_ideal,
                      f"tokens={tok};tflops_per_nfe={fl/1e12:.2f}")
    return rows


def bench_fig12_packing():
    """FLOPs/latency of the 4 CFG-packing approaches: analytic + measured."""
    params, cfg, sched = C.get_flexidit()
    for n_images in (1, 4, 8):
        costs = packing_cost(cfg, 1, n_images)
        best_flops = min(c.flops for c in costs)
        for c in costs:
            C.csv_row(f"fig12_n{n_images}_approach{c.approach}", 0.0,
                      f"flops={c.flops:.3e};calls={c.nfe_calls};"
                      f"norm_flops={c.flops/best_flops:.2f}")
    # measured: packed weak forward (approach 4) vs 4 separate weak calls
    B, r = 2, 4
    key = jax.random.PRNGKey(1)
    xs = jax.random.normal(key, (r, B) + cfg.dit.latent_shape)
    t = jnp.full((B,), 10.0)
    conds = jnp.tile(jnp.arange(B)[None] % C.N_CLASSES, (r, 1))
    packed = jax.jit(lambda p, xs, t, c: packed_weak_forward(
        p, xs, t, c, cfg, mode=1))
    us_packed = C.timeit(packed, params, xs, t, conds)

    single = jax.jit(lambda p, x, t, c: dit_mod.dit_forward(
        p, x, t, c, cfg, mode=1))

    def run_separate(p, xs, t, conds):
        return [single(p, xs[i], t, conds[i]) for i in range(r)]
    us_sep = C.timeit(run_separate, params, xs, t, conds)
    C.csv_row("fig12_measured_packed", us_packed,
              f"separate_us={us_sep:.0f};speedup={us_sep/us_packed:.2f}x")
    return us_packed, us_sep
