"""Pipeline API behaviour: compile-once steady state and budget switching.

Measures cold (first-call, includes XLA compile) vs warm wall time per
plan, and asserts via cache stats that sweeping budgets back and forth
compiles exactly one runner per plan (DESIGN.md §pipeline)."""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks import common as C
from repro.pipeline import FlexiPipeline, SamplingPlan


def bench_pipeline_cache(T: int = 20, n: int = 16):
    params, cfg, sched = C.get_flexidit()
    pipe = FlexiPipeline(params, cfg, sched)   # fresh: measure cold compiles
    key = jax.random.PRNGKey(123)
    plans = {b: SamplingPlan(T=T, budget=b, guidance_scale=1.5)
             for b in (1.0, 0.6, 0.4)}

    warm = {}
    for b, plan in plans.items():
        t0 = time.perf_counter()
        jax.block_until_ready(pipe.sample(plan, n, key).x0)
        cold = (time.perf_counter() - t0) * 1e6
        times = []
        for i in range(3):
            t0 = time.perf_counter()
            jax.block_until_ready(
                pipe.sample(plan, n, jax.random.fold_in(key, i)).x0)
            times.append((time.perf_counter() - t0) * 1e6)
        warm[b] = float(np.median(times))
        C.csv_row(f"pipeline_budget{b}", warm[b],
                  f"cold_us={cold:.0f};speedup={cold / warm[b]:.1f}x")

    # budget sweep: alternating plans must not trigger any new compiles
    before = pipe.cache_stats()["compiled"]
    for i in range(6):
        b = (1.0, 0.6, 0.4)[i % 3]
        jax.block_until_ready(
            pipe.sample(plans[b], n, jax.random.fold_in(key, 100 + i)).x0)
    stats = pipe.cache_stats()
    C.csv_row("pipeline_cache", 0.0,
              f"runners={stats['runners']};compiled={stats['compiled']};"
              f"hits={stats['hits']};"
              f"switch_recompiles={stats['compiled'] - before}")
    assert stats["compiled"] == before, "budget switches must not recompile"
    return stats
