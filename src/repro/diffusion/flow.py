"""Rectified flow / flow matching — the paper notes FlexiDiT "is largely
agnostic to the diffusion process and can be applied out of the box for
flow matching methods" (App. A). This module makes that concrete: linear
interpolation path x_t = (1−τ)·x0 + τ·ε, velocity target v = ε − x0,
Euler/Heun integrators with the same *phased* structure as the DDPM
samplers, so the weak→powerful FlexiSchedule drops straight in.

τ convention: τ ∈ [0,1], τ=1 is pure noise (matches the diffusion-t
direction so schedulers transfer unchanged; model conditioning uses
τ·1000 to reuse the timestep embedding range).
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# v_fn(x, tau[B]) -> velocity prediction (= eps - x0 target)
VFn = Callable[[jax.Array, jax.Array], jax.Array]


def interpolate(x0: jax.Array, eps: jax.Array, tau: jax.Array) -> jax.Array:
    tau = tau.reshape((-1,) + (1,) * (x0.ndim - 1))
    return (1.0 - tau) * x0 + tau * eps


def velocity_target(x0: jax.Array, eps: jax.Array) -> jax.Array:
    return eps - x0


def flow_matching_loss(v_pred: jax.Array, x0: jax.Array,
                       eps: jax.Array) -> jax.Array:
    v = velocity_target(x0, eps)
    return jnp.mean(jnp.square(v_pred.astype(jnp.float32)
                               - v.astype(jnp.float32)))


def tau_ladder(num_steps: int) -> np.ndarray:
    """Descending τ ladder 1 → 0 (sampling order), num_steps intervals."""
    return np.linspace(1.0, 0.0, num_steps + 1)


def euler_phase(v_fn: VFn, x: jax.Array, taus: np.ndarray) -> jax.Array:
    """Integrate dx/dτ = v from taus[0] down to taus[-1] (Euler)."""
    t_hi = jnp.asarray(taus[:-1], jnp.float32)
    t_lo = jnp.asarray(taus[1:], jnp.float32)

    def body(x, inp):
        ta, tb = inp
        tau_b = jnp.full((x.shape[0],), ta, jnp.float32)
        v = v_fn(x, tau_b)
        return x + (tb - ta) * v, None

    x, _ = jax.lax.scan(body, x, (t_hi, t_lo))
    return x


def heun_phase(v_fn: VFn, x: jax.Array, taus: np.ndarray) -> jax.Array:
    """2nd-order Heun integrator (2 NFEs per step)."""
    t_hi = jnp.asarray(taus[:-1], jnp.float32)
    t_lo = jnp.asarray(taus[1:], jnp.float32)

    def body(x, inp):
        ta, tb = inp
        dt = tb - ta
        tau_a = jnp.full((x.shape[0],), ta, jnp.float32)
        tau_b = jnp.full((x.shape[0],), tb, jnp.float32)
        v1 = v_fn(x, tau_a)
        x_pred = x + dt * v1
        v2 = v_fn(x_pred, tau_b)
        return x + dt * 0.5 * (v1 + v2), None

    x, _ = jax.lax.scan(body, x, (t_hi, t_lo))
    return x


def sample_flow_phased(phases: Sequence[Tuple[VFn, np.ndarray]],
                       x_T: jax.Array, solver: str = "euler") -> jax.Array:
    """Chain phases exactly like diffusion.sampler.sample_phased: each phase
    is (v_fn, its τ SUB-LADDER incl. its end point). The FlexiSchedule's
    weak→powerful split applies unchanged."""
    fn = euler_phase if solver == "euler" else heun_phase
    x = x_T
    for v_fn, taus in phases:
        if len(taus) >= 2:
            x = fn(v_fn, x, taus)
    return x


def split_tau_ladder(taus: np.ndarray, phases: Sequence[Tuple[int, int]]
                     ) -> List[Tuple[int, np.ndarray]]:
    """Split a τ ladder across (mode, n_steps) phases, duplicating boundary
    points so each phase integrates a contiguous interval."""
    out, i = [], 0
    for mode, n in phases:
        out.append((mode, taus[i:i + n + 1]))
        i += n
    return out


def make_flow_v_fn(params, cfg, cond, mode: int = 0, parallel=None,
                   attn_backend: str = "auto") -> VFn:
    """Wrap a (learn_sigma=False) DiT as a velocity model: the τ∈[0,1] time
    is mapped onto the timestep-embedding range. ``parallel`` threads the
    sequence-parallel engine into the NFE (repro.distributed)."""
    from repro.models import dit as dit_mod

    def v_fn(x, tau):
        out = dit_mod.dit_forward(params, x, tau * 1000.0, cond, cfg,
                                  mode=mode, parallel=parallel,
                                  attn_backend=attn_backend)
        return dit_mod.eps_prediction(out, cfg)

    return v_fn
