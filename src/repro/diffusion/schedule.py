"""DDPM noise schedule and per-step transition math.

Faithful to the DiT / ADM conventions (linear betas, ε-prediction, optional
learned variance as an interpolation between β and β̃).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DiffusionSchedule:
    betas: np.ndarray                    # [T]

    @property
    def num_steps(self) -> int:
        return len(self.betas)

    @functools.cached_property
    def _derived(self):
        betas = self.betas.astype(np.float64)
        alphas = 1.0 - betas
        acp = np.cumprod(alphas)
        acp_prev = np.concatenate([[1.0], acp[:-1]])
        post_var = betas * (1.0 - acp_prev) / (1.0 - acp)
        return dict(
            alphas=alphas, acp=acp, acp_prev=acp_prev,
            sqrt_acp=np.sqrt(acp), sqrt_1macp=np.sqrt(1.0 - acp),
            post_var=post_var,
            post_log_var=np.log(np.maximum(post_var, 1e-20)),
            post_c0=betas * np.sqrt(acp_prev) / (1.0 - acp),
            post_ct=(1.0 - acp_prev) * np.sqrt(alphas) / (1.0 - acp),
        )


def linear_schedule(T: int = 1000, beta_start: float = 1e-4,
                    beta_end: float = 0.02) -> DiffusionSchedule:
    return DiffusionSchedule(np.linspace(beta_start, beta_end, T,
                                         dtype=np.float64))


def cosine_schedule(T: int = 1000, s: float = 0.008) -> DiffusionSchedule:
    t = np.arange(T + 1) / T
    f = np.cos((t + s) / (1 + s) * np.pi / 2) ** 2
    acp = f / f[0]
    betas = np.clip(1 - acp[1:] / acp[:-1], 0, 0.999)
    return DiffusionSchedule(betas)


def respaced_timesteps(T: int, num_steps: int) -> np.ndarray:
    """Uniformly spaced subset of [0, T), descending (sampling order)."""
    ts = np.linspace(0, T - 1, num_steps).round().astype(np.int64)
    return ts[::-1].copy()


# ---------------------------------------------------------------------------
# Array-side helpers (gather schedule constants by traced t)


def _g(arr: np.ndarray, t: jax.Array, ndim: int) -> jax.Array:
    v = jnp.take(jnp.asarray(arr, jnp.float32), t)
    return v.reshape(v.shape + (1,) * (ndim - v.ndim))


def q_sample(sched: DiffusionSchedule, x0: jax.Array, t: jax.Array,
             noise: jax.Array) -> jax.Array:
    d = sched._derived
    return (_g(d["sqrt_acp"], t, x0.ndim) * x0
            + _g(d["sqrt_1macp"], t, x0.ndim) * noise)


def predict_x0_from_eps(sched: DiffusionSchedule, x_t: jax.Array, t: jax.Array,
                        eps: jax.Array) -> jax.Array:
    d = sched._derived
    return ((x_t - _g(d["sqrt_1macp"], t, x_t.ndim) * eps)
            / _g(d["sqrt_acp"], t, x_t.ndim))


def posterior_mean(sched: DiffusionSchedule, x0: jax.Array, x_t: jax.Array,
                   t: jax.Array) -> jax.Array:
    d = sched._derived
    return (_g(d["post_c0"], t, x_t.ndim) * x0
            + _g(d["post_ct"], t, x_t.ndim) * x_t)


def ddpm_step(sched: DiffusionSchedule, x_t: jax.Array, eps: jax.Array,
              t: jax.Array, key: jax.Array,
              logvar_frac: Optional[jax.Array] = None,
              clip_x0: float = 0.0) -> jax.Array:
    """One ancestral DDPM step x_t → x_{t-1}.

    ``logvar_frac`` ∈ [0,1] (model output) interpolates log σ² between β̃
    (posterior) and β, as in ADM/DiT learned-variance models.
    """
    d = sched._derived
    x0 = predict_x0_from_eps(sched, x_t, t, eps)
    if clip_x0 > 0:
        x0 = jnp.clip(x0, -clip_x0, clip_x0)
    mean = posterior_mean(sched, x0, x_t, t)
    if logvar_frac is not None:
        frac = (logvar_frac + 1.0) / 2.0          # model outputs in [-1,1]
        log_beta = jnp.log(jnp.maximum(_g(sched.betas, t, x_t.ndim), 1e-20))
        logvar = frac * log_beta + (1 - frac) * _g(d["post_log_var"], t, x_t.ndim)
    else:
        logvar = _g(d["post_log_var"], t, x_t.ndim)
    noise = jax.random.normal(key, x_t.shape, x_t.dtype)
    nonzero = (t > 0).astype(x_t.dtype).reshape((-1,) + (1,) * (x_t.ndim - 1))
    return mean + nonzero * jnp.exp(0.5 * logvar) * noise


def ddim_step(sched: DiffusionSchedule, x_t: jax.Array, eps: jax.Array,
              t: jax.Array, t_prev: jax.Array, eta: float = 0.0,
              key: Optional[jax.Array] = None) -> jax.Array:
    d = sched._derived
    acp_t = _g(d["acp"], t, x_t.ndim)
    acp_prev = jnp.where(t_prev.reshape(acp_t.shape) >= 0,
                         _g(d["acp"], jnp.maximum(t_prev, 0), x_t.ndim), 1.0)
    x0 = predict_x0_from_eps(sched, x_t, t, eps)
    sigma = eta * jnp.sqrt((1 - acp_prev) / (1 - acp_t)
                           * (1 - acp_t / acp_prev))
    dir_xt = jnp.sqrt(jnp.maximum(1 - acp_prev - sigma ** 2, 0.0)) * eps
    x_prev = jnp.sqrt(acp_prev) * x0 + dir_xt
    if eta > 0 and key is not None:
        x_prev = x_prev + sigma * jax.random.normal(key, x_t.shape, x_t.dtype)
    return x_prev


def dpm_solver2_step(sched: DiffusionSchedule, x_t: jax.Array,
                     eps_fn, t: jax.Array, t_prev: jax.Array) -> jax.Array:
    """DPM-Solver-2 (midpoint) step using λ = log(√acp/√(1−acp))."""
    d = sched._derived
    lam = np.log(d["sqrt_acp"] / np.maximum(d["sqrt_1macp"], 1e-20))

    def at(arr, tt):
        return _g(arr, jnp.maximum(tt, 0), x_t.ndim)

    lam_t, lam_s = at(lam, t), at(lam, t_prev)
    h = lam_s - lam_t
    # midpoint in λ-space → nearest integer timestep
    lam_np = lam
    t_mid = jnp.argmin(jnp.abs(jnp.asarray(lam_np, jnp.float32)[None, :]
                               - (lam_t + h / 2).reshape(-1, 1)), axis=-1)
    eps_t = eps_fn(x_t, t)
    x_mid = (at(d["sqrt_acp"], t_mid) / at(d["sqrt_acp"], t)) * x_t \
        - at(d["sqrt_1macp"], t_mid) * jnp.expm1(h / 2) * eps_t
    eps_mid = eps_fn(x_mid, t_mid)
    x_prev = (at(d["sqrt_acp"], t_prev) / at(d["sqrt_acp"], t)) * x_t \
        - at(d["sqrt_1macp"], t_prev) * jnp.expm1(h) * eps_mid
    return x_prev
