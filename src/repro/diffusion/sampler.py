"""Sampling loops. A *phase* is (eps_fn, timesteps): the FlexiDiT inference
scheduler (core.scheduler) chains a weak phase and a powerful phase — each
phase is one ``lax.scan`` over its timesteps with a single compiled NFE body,
so no recompilation ever happens inside the loop (DESIGN.md §3).

User-facing code should not assemble phases by hand: ``repro.pipeline``
(DESIGN.md §pipeline) is the single inference entry point and compiles/
caches these loops per plan.
"""
from __future__ import annotations

from typing import Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.diffusion import schedule as sch

# eps_fn(x_t, t[B]) -> (eps, logvar_frac | None)
EpsFn = Callable[[jax.Array, jax.Array], Tuple[jax.Array, Optional[jax.Array]]]


def ddpm_phase(eps_fn: EpsFn, sched: sch.DiffusionSchedule, x: jax.Array,
               timesteps: np.ndarray, key: jax.Array,
               clip_x0: float = 0.0) -> jax.Array:
    """Run DDPM ancestral steps for the given (descending) timesteps."""
    ts = jnp.asarray(timesteps, jnp.int32)
    keys = jax.random.split(key, len(timesteps))

    def body(x, inp):
        t, k = inp
        tb = jnp.full((x.shape[0],), t, jnp.int32)
        eps, logvar = eps_fn(x, tb)
        x = sch.ddpm_step(sched, x, eps, tb, k, logvar, clip_x0)
        return x, None

    x, _ = jax.lax.scan(body, x, (ts, keys))
    return x


def ddim_phase(eps_fn: EpsFn, sched: sch.DiffusionSchedule, x: jax.Array,
               timesteps: np.ndarray, key: jax.Array,
               eta: float = 0.0, t_final: int = -1) -> jax.Array:
    """``t_final``: the timestep the NEXT phase starts at (-1 = final x0
    step) — keeps phase chaining identical to a single un-split run."""
    ts = jnp.asarray(timesteps, jnp.int32)
    ts_prev = jnp.concatenate([ts[1:], jnp.asarray([t_final], jnp.int32)])
    keys = jax.random.split(key, len(timesteps))

    def body(x, inp):
        t, tp, k = inp
        tb = jnp.full((x.shape[0],), t, jnp.int32)
        tpb = jnp.full((x.shape[0],), tp, jnp.int32)
        eps, _ = eps_fn(x, tb)
        return sch.ddim_step(sched, x, eps, tb, tpb, eta, k), None

    x, _ = jax.lax.scan(body, x, (ts, ts_prev, keys))
    return x


def dpm2_phase(eps_fn: EpsFn, sched: sch.DiffusionSchedule, x: jax.Array,
               timesteps: np.ndarray, key: jax.Array,
               t_final: int = 0) -> jax.Array:
    ts = jnp.asarray(timesteps, jnp.int32)
    ts_prev = jnp.concatenate([ts[1:], jnp.asarray([max(t_final, 0)],
                                                   jnp.int32)])

    def eps_only(xx, tb):
        return eps_fn(xx, tb)[0]

    def body(x, inp):
        t, tp = inp
        tb = jnp.full((x.shape[0],), t, jnp.int32)
        tpb = jnp.full((x.shape[0],), tp, jnp.int32)
        return sch.dpm_solver2_step(sched, x, eps_only, tb, tpb), None

    x, _ = jax.lax.scan(body, x, (ts, ts_prev))
    return x


PHASE_FNS = {"ddpm": ddpm_phase, "ddim": ddim_phase, "dpm2": dpm2_phase}


def sample_phased(phases: Sequence[Tuple[EpsFn, np.ndarray]],  # repro: traced
                  sched: sch.DiffusionSchedule, x_T: jax.Array,
                  key: jax.Array, solver: str = "ddpm",
                  clip_x0: float = 0.0) -> jax.Array:
    """Chain phases: each (eps_fn, its slice of the timestep ladder)."""
    phase_fn = PHASE_FNS[solver]
    x = x_T
    active = [(f, ts) for f, ts in phases if len(ts)]
    for i, (eps_fn, ts) in enumerate(active):
        k = jax.random.fold_in(key, i)
        # boundary: hand the next phase's first timestep to the solver
        t_final = int(active[i + 1][1][0]) if i + 1 < len(active) else -1
        if solver == "ddpm":
            x = phase_fn(eps_fn, sched, x, ts, k, clip_x0)
        elif solver == "ddim":
            x = phase_fn(eps_fn, sched, x, ts, k, t_final=t_final)
        else:
            x = phase_fn(eps_fn, sched, x, ts, k, t_final=t_final)
    return x
