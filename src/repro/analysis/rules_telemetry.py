"""Telemetry data-only lint rules (DESIGN.md §telemetry, §analysis).

The telemetry layer's contract is **observability must be data, not
structure**: taps ride along as extra outputs of already-compiled
steps, and the host sees their values only at the aggregate/export
sink. Two rules keep that contract honest as the code grows:

* ``telemetry-host-callback`` — telemetry source must never inject a
  host callback (``jax.debug.print``/``debug.callback``,
  ``pure_callback``, ``io_callback``, ``host_callback``) anywhere. A
  callback inside a tap helper would ride into every tapped step's
  jaxpr and break the DCE-recovers-untapped proof
  (``jaxpr_audit.audit_tapped_step``).
* ``telemetry-tap-host-sync`` — in ``telemetry/taps.py``, host
  materialization of tap values (``np.*`` calls, ``float()``/``int()``
  casts, ``.item()``, ``jax.device_get``, ``block_until_ready``) is
  legal ONLY inside the declared export-time sinks
  (``TapAggregator.aggregate`` / ``counter_series``). Anywhere else —
  the tap helpers (traced), ``TapSample`` construction,
  ``TapAggregator.add`` — it would block the dispatch path on the
  device.
* ``telemetry-attribution-device`` — ``telemetry/attribution.py`` runs
  per dispatch on the serving hot path and is specified as pure host
  integer arithmetic (DESIGN.md §profiling): importing jax or numpy, or
  calling any device-sync primitive there, would let an innocent edit
  add a hidden per-dispatch host sync. The rule statically rejects the
  whole category.

All are scoped to ``src/repro/telemetry/``; the general trace-safety
rule covers the rest of the repo.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.engine import Finding

#: call names (last dotted component) that reach back into Python from
#: compiled code
CALLBACK_NAMES = {"pure_callback", "io_callback", "host_callback",
                  "debug_callback", "call_tpu", "id_tap", "id_print"}

#: host materialization of a (possibly device) value
HOST_SYNC_CALLS = {"asarray", "array", "concatenate", "percentile",
                   "device_get", "block_until_ready"}
HOST_CASTS = {"float", "int", "bool"}

#: the only functions allowed to pull tap values to the host
TAP_SINKS = ("aggregate", "counter_series")


def _dotted(func: ast.AST) -> List[str]:
    parts: List[str] = []
    while isinstance(func, ast.Attribute):
        parts.append(func.attr)
        func = func.value
    if isinstance(func, ast.Name):
        parts.append(func.id)
    return parts[::-1]


class TelemetryRule:
    """Per-file source rule over ``src/repro/telemetry/``."""

    def check(self, path: str, tree: ast.AST, text: str) -> List[Finding]:
        if "repro/telemetry/" not in path.replace("\\", "/"):
            return []
        findings: List[Finding] = []
        is_taps = path.endswith("taps.py")
        is_attr = path.endswith("attribution.py")
        if is_attr:
            for node in ast.walk(tree):
                mods = []
                if isinstance(node, ast.Import):
                    mods = [a.name for a in node.names]
                elif isinstance(node, ast.ImportFrom) and node.module:
                    mods = [node.module]
                for mod in mods:
                    root = mod.split(".")[0]
                    if root in ("jax", "jaxlib", "numpy", "np"):
                        findings.append(Finding(
                            "telemetry-attribution-device", "error", path,
                            node.lineno,
                            f"attribution.py imports `{mod}` — per-request "
                            f"attribution is pure host integer arithmetic "
                            f"on the dispatch hot path; device libraries "
                            f"are banned here", "<module>"))
        stack: List[str] = []

        class V(ast.NodeVisitor):
            def visit_FunctionDef(self, node):
                stack.append(node.name)
                self.generic_visit(node)
                stack.pop()

            visit_AsyncFunctionDef = visit_FunctionDef

            def visit_Call(self, node):
                parts = _dotted(node.func)
                name = parts[-1] if parts else ""
                sym = stack[-1] if stack else "<module>"
                if name in CALLBACK_NAMES or \
                        (len(parts) >= 2 and parts[-2] == "debug"
                         and name in ("print", "callback")):
                    findings.append(Finding(
                        "telemetry-host-callback", "error", path,
                        node.lineno,
                        f"telemetry code calls `{'.'.join(parts)}` — a "
                        f"host callback would ride into every tapped "
                        f"jaxpr (taps must be data, not structure)", sym))
                elif is_attr:
                    is_np = (len(parts) >= 2
                             and parts[0] in ("np", "numpy", "jnp", "jax"))
                    is_sync = name in ("device_get", "block_until_ready")
                    is_item = (isinstance(node.func, ast.Attribute)
                               and node.func.attr == "item")
                    if is_np or is_sync or is_item:
                        findings.append(Finding(
                            "telemetry-attribution-device", "error", path,
                            node.lineno,
                            f"`{'.'.join(parts) or 'item'}` in "
                            f"attribution.py — attribution must stay pure "
                            f"host integer arithmetic (no device values, "
                            f"no syncs) on the dispatch hot path", sym))
                elif is_taps and not any(f in TAP_SINKS for f in stack):
                    is_np = (len(parts) >= 2
                             and parts[0] in ("np", "numpy")
                             and name in HOST_SYNC_CALLS)
                    is_jax_sync = name in ("device_get",
                                           "block_until_ready")
                    is_item = (isinstance(node.func, ast.Attribute)
                               and node.func.attr == "item")
                    if is_np or is_jax_sync or is_item:
                        findings.append(Finding(
                            "telemetry-tap-host-sync", "error", path,
                            node.lineno,
                            f"`{'.'.join(parts) or 'item'}` materializes "
                            f"tap values outside the "
                            f"TapAggregator sinks {TAP_SINKS} — the "
                            f"dispatch path must never block on a tap",
                            sym))
                self.generic_visit(node)

        V().visit(tree)
        return findings
