"""Cache-key completeness lint rule (DESIGN.md §analysis).

The zero-recompile contract: every field of ``SamplingPlan`` /
``CacheSpec`` / ``ParallelSpec`` / ``PackLayout`` is either

* **structural** — it changes the traced graph, so it (or a resolved
  witness of it, e.g. ``budget`` -> ``schedule.phases``) MUST join the
  ``FlexiPipeline`` runner key or the packed-step key; or
* **data-only** — it only shapes traced *inputs* (refresh masks, block
  maps, timestep metas), so it must NOT need to join any key.

A structural field missing from the key is the recompile-hazard bug
class this rule exists for: the pipeline would silently replay a stale
executable for a plan that needs a different graph. The rule:

1. hashes a canonical instance of each keyed dataclass (an unhashable
   spec cannot be a cache key at all);
2. extracts the key tuples from ``pipeline/pipeline.py`` (the
   ``sig = (...)`` runner signature + every ``self._lookup(...)`` key,
   and ``packed_step``'s ``key = (...)``) and checks each structural
   field's witness expression appears in them;
3. cross-checks ``make_packed_step_fn``'s own signature against the
   packed key — a new step-family argument that does not join the key
   is flagged the day it is added;
4. flags any field that appears on a keyed dataclass but in neither the
   structural nor the data-only classification below — forcing every
   future field to take a position.

The classification tables ARE the reviewed contract; updating them is
part of adding a field (see tests/test_analysis.py, which pins the
field sets).
"""
from __future__ import annotations

import ast
import dataclasses
from typing import Dict, Iterable, List, Tuple

from repro.analysis.engine import Finding

PIPELINE_PATH = "src/repro/pipeline/pipeline.py"
PACKED_PATH = "src/repro/pipeline/packed.py"

#: SamplingPlan structural fields -> witness expressions that must appear
#: in the runner-key text. A witness is the *resolved* form the key
#: carries (`budget` joins as the resolved `schedule.phases` + the
#: timestep ladder `ts`, `lora` as the cfg-resolved `variant`, `cache`
#: as its structural split).
PLAN_WITNESSES: Dict[str, Tuple[str, ...]] = {
    "T": ("int(t) for t in ts",),     # the ladder joins as a tuple
    "budget": ("schedule.phases",),
    "solver": ("plan.solver",),
    "guidance_scale": ("plan.guidance_scale",),
    "guidance_kind": ("plan.guidance_kind",),
    "weak_mode": ("plan.weak_mode",),
    "lora": ("variant",),
    "weak_last": ("schedule.phases",),    # resolves into the phase split
    "clip_x0": ("plan.clip_x0",),
    "parallel": ("plan.parallel",),
    "cache": ("plan.cache.resolve_split",),
    "attn_backend": ("plan.attn_backend",),
}
#: SamplingPlan fields that are data-only (none today — plans are pure
#: structure; budgets resolve to phase splits before compilation).
PLAN_DATA_ONLY: Tuple[str, ...] = ()

#: pipeline state (not plan fields) that must also join the runner key
PIPELINE_STATE_WITNESSES: Tuple[str, ...] = ("mesh_fingerprint",)

#: CacheSpec: only the split changes the traced graph; policy knobs
#: resolve to refresh masks, which are traced scan inputs.
CACHESPEC_STRUCTURAL: Dict[str, Tuple[str, ...]] = {
    "split": ("plan.cache.resolve_split", "cache_split"),
}
CACHESPEC_DATA_ONLY: Tuple[str, ...] = ("policy", "interval", "bands",
                                        "threshold")

#: ParallelSpec / PackLayout join their keys whole — every field is
#: structural and witnessed by the object itself.
PARALLEL_WITNESSES: Tuple[str, ...] = ("plan.parallel",)
LAYOUT_WITNESSES: Tuple[str, ...] = ("layout",)

#: make_packed_step_fn args owned by the pipeline instance itself
#: (per-instance runner dict ⇒ they never need to join the key)
PACKED_INSTANCE_ARGS: Tuple[str, ...] = ("cfg", "sched")


def _canonical_instances():
    """One hashable exemplar per keyed dataclass (import deferred so the
    linter core stays jax-free until this rule runs)."""
    from repro.cache.policy import CacheSpec
    from repro.distributed.partition import ParallelSpec
    from repro.pipeline.packed import PackLayout
    from repro.pipeline.plan import SamplingPlan
    return {
        "SamplingPlan": SamplingPlan(T=4),
        "CacheSpec": CacheSpec(),
        "ParallelSpec": ParallelSpec(),
        "PackLayout": PackLayout(groups=((0, 1),)),
    }


# ---------------------------------------------------------------------------
# Key-text extraction from the pipeline AST


def _key_texts(tree: ast.AST) -> Dict[str, str]:
    """{'runner': <sig + every _lookup key>, 'packed': <packed_step key>}
    as concatenated unparsed source of the key tuple expressions."""
    runner_parts: List[str] = []
    packed_parts: List[str] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.FunctionDef):
            in_packed = node.name in ("packed_step", "packed_step_is_warm")
            for sub in ast.walk(node):
                if isinstance(sub, ast.Assign) \
                        and any(isinstance(t, ast.Name)
                                and t.id in ("sig", "key")
                                for t in sub.targets):
                    (packed_parts if in_packed else runner_parts).append(
                        ast.unparse(sub.value))
                elif isinstance(sub, ast.Call) \
                        and isinstance(sub.func, ast.Attribute) \
                        and sub.func.attr == "_lookup" and len(sub.args) >= 2:
                    runner_parts.append(ast.unparse(sub.args[1]))
                elif in_packed and isinstance(sub, ast.Return) \
                        and sub.value is not None:
                    packed_parts.append(ast.unparse(sub.value))
    return {"runner": "\n".join(runner_parts),
            "packed": "\n".join(packed_parts)}


def check_witnesses(fields: Iterable[str],
                    witnesses: Dict[str, Tuple[str, ...]],
                    data_only: Iterable[str], key_text: str,
                    owner: str) -> List[Tuple[str, str]]:
    """Pure core (unit-tested directly): returns (field, problem) pairs.
    Witness semantics: EVERY listed witness expression must appear in the
    key text for the field to count as covered."""
    problems: List[Tuple[str, str]] = []
    data_only = set(data_only)
    for f in fields:
        if f in data_only:
            continue
        if f not in witnesses:
            problems.append((f, "unclassified"))
            continue
        missing = [w for w in witnesses[f] if w not in key_text]
        if missing:
            problems.append((f, f"witness {missing} not in key"))
    return problems


# ---------------------------------------------------------------------------
# The rule object


class CacheKeyRule:
    """Repo rule: structural fields must join the executable cache keys."""

    name = "cache-key"

    def check_repo(self, files: Dict[str, Tuple[ast.AST, str]]
                   ) -> List[Finding]:
        if PIPELINE_PATH not in files:
            return []                    # partial lint run
        findings: List[Finding] = []

        # 1 — hashability of every keyed dataclass
        try:
            instances = _canonical_instances()
        except Exception as e:           # import/constructor breakage
            return [Finding("cachekey-hashable", "error", PIPELINE_PATH, 1,
                            f"cannot build canonical plan/spec instances: "
                            f"{type(e).__name__}: {e}")]
        for cls_name, inst in instances.items():
            try:
                hash(inst)
            except TypeError as e:
                findings.append(Finding(
                    "cachekey-hashable", "error", PIPELINE_PATH, 1,
                    f"{cls_name} is not hashable ({e}); it cannot join "
                    f"the runner/packed cache keys", cls_name))

        texts = _key_texts(files[PIPELINE_PATH][0])

        # 2 — field coverage per class
        def fields_of(inst) -> List[str]:
            return [f.name for f in dataclasses.fields(inst)]

        checks = [
            ("SamplingPlan", fields_of(instances["SamplingPlan"]),
             PLAN_WITNESSES, PLAN_DATA_ONLY, texts["runner"]),
            ("CacheSpec", fields_of(instances["CacheSpec"]),
             CACHESPEC_STRUCTURAL, CACHESPEC_DATA_ONLY,
             texts["runner"] + texts["packed"]),
            ("ParallelSpec", fields_of(instances["ParallelSpec"]),
             {f.name: PARALLEL_WITNESSES
              for f in dataclasses.fields(instances["ParallelSpec"])},
             (), texts["runner"]),
            ("PackLayout", fields_of(instances["PackLayout"]),
             {f.name: LAYOUT_WITNESSES
              for f in dataclasses.fields(instances["PackLayout"])},
             (), texts["packed"]),
        ]
        for cls_name, fields, witnesses, data_only, text in checks:
            for field, problem in check_witnesses(fields, witnesses,
                                                  data_only, text, cls_name):
                rule = ("cachekey-unclassified" if problem == "unclassified"
                        else "cachekey-missing")
                msg = (f"{cls_name}.{field} has no structural/data-only "
                       f"classification in rules_cachekey — decide "
                       f"whether it changes the traced graph and add it "
                       f"to the witness tables AND the cache key"
                       if problem == "unclassified" else
                       f"{cls_name}.{field} is structural but its "
                       f"{problem} text — a plan differing only in this "
                       f"field would replay the wrong executable")
                findings.append(Finding(rule, "error", PIPELINE_PATH, 1,
                                        msg, f"{cls_name}.{field}"))

        # pipeline-owned structure (the mesh) must key runners too
        for witness in PIPELINE_STATE_WITNESSES:
            if witness not in texts["runner"]:
                findings.append(Finding(
                    "cachekey-missing", "error", PIPELINE_PATH, 1,
                    f"pipeline state witness `{witness}` missing from the "
                    f"runner key", witness))

        # 3 — make_packed_step_fn signature ⊆ packed key
        if PACKED_PATH in files:
            packed_tree = files[PACKED_PATH][0]
            for node in ast.walk(packed_tree):
                if isinstance(node, ast.FunctionDef) \
                        and node.name == "make_packed_step_fn":
                    args = node.args
                    names = [a.arg for a in (args.posonlyargs + args.args
                                             + args.kwonlyargs)]
                    for name in names:
                        if name in PACKED_INSTANCE_ARGS:
                            continue
                        if name not in texts["packed"]:
                            findings.append(Finding(
                                "cachekey-missing", "error", PACKED_PATH,
                                node.lineno,
                                f"make_packed_step_fn arg `{name}` does "
                                f"not join FlexiPipeline.packed_step's "
                                f"key — two step families would share "
                                f"one executable", f"packed.{name}"))
        return findings
