"""Fleet control-plane host-purity lint (DESIGN.md §fleet, §analysis).

The fleet's routing decision runs once per scheduling round on the
serving hot path, and its three control modules — ``fleet/router.py``,
``fleet/membership.py``, ``fleet/health.py`` — are specified as pure
host bookkeeping: PRNG keys pass through as opaque objects, wall times
arrive as plain floats, and any numpy/EWMA arithmetic is delegated to
``runtime.straggler``. The ``fleet-host-pure`` rule statically rejects
the whole category of regressions (same shape as PR 8's
``telemetry-attribution-device`` rule):

* importing ``jax``/``jaxlib``/``numpy`` in a control module — the day
  someone "just inspects" a request key or batches scores through
  numpy, placement acquires a device dependency and, worse, a possible
  per-round host sync;
* calling ``jax.*``/``np.*``, ``device_get``/``block_until_ready``, or
  ``.item()`` there — the sync itself.

The data-plane modules (``replica.py``, ``fleet.py``, ``warmup.py``)
legitimately touch jax and are covered by the general trace-safety
rule instead.
"""
from __future__ import annotations

import ast
from typing import List

from repro.analysis.engine import Finding

#: the control-plane modules under the host-purity contract
HOST_PURE_FILES = ("fleet/router.py", "fleet/membership.py",
                   "fleet/health.py")

BANNED_IMPORT_ROOTS = ("jax", "jaxlib", "numpy", "np")


def _dotted(func: ast.AST) -> List[str]:
    parts: List[str] = []
    while isinstance(func, ast.Attribute):
        parts.append(func.attr)
        func = func.value
    if isinstance(func, ast.Name):
        parts.append(func.id)
    return parts[::-1]


class FleetHostPureRule:
    """Per-file source rule over the fleet control plane."""

    def check(self, path: str, tree: ast.AST, text: str) -> List[Finding]:
        posix = path.replace("\\", "/")
        if not any(posix.endswith(f) for f in HOST_PURE_FILES):
            return []
        findings: List[Finding] = []
        for node in ast.walk(tree):
            mods = []
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                mods = [node.module]
            for mod in mods:
                if mod.split(".")[0] in BANNED_IMPORT_ROOTS:
                    findings.append(Finding(
                        "fleet-host-pure", "error", path, node.lineno,
                        f"fleet control plane imports `{mod}` — "
                        f"routing/membership/health are pure host "
                        f"bookkeeping on the per-round hot path; device "
                        f"libraries are banned here", "<module>"))
        stack: List[str] = []

        class V(ast.NodeVisitor):
            def visit_FunctionDef(self, node):
                stack.append(node.name)
                self.generic_visit(node)
                stack.pop()

            visit_AsyncFunctionDef = visit_FunctionDef

            def visit_Call(self, node):
                parts = _dotted(node.func)
                name = parts[-1] if parts else ""
                sym = stack[-1] if stack else "<module>"
                is_dev = (len(parts) >= 2
                          and parts[0] in ("np", "numpy", "jnp", "jax"))
                is_sync = name in ("device_get", "block_until_ready")
                is_item = (isinstance(node.func, ast.Attribute)
                           and node.func.attr == "item")
                if is_dev or is_sync or is_item:
                    findings.append(Finding(
                        "fleet-host-pure", "error", path, node.lineno,
                        f"`{'.'.join(parts) or 'item'}` in a fleet "
                        f"control module — placement must stay pure "
                        f"host bookkeeping (no device values, no "
                        f"syncs); delegate array math to "
                        f"runtime.straggler", sym))
                self.generic_visit(node)

        V().visit(tree)
        return findings
