"""Resilience lint rules (DESIGN.md §resilience, §analysis).

Two statically-provable contracts keep the fault-injection layer from
regressing the serving invariants it exists to test:

* ``resilience-host-pure`` — ``resilience/faults.py`` (the scripted
  injector: event heap, windows, seeded RNG) and
  ``resilience/journal.py`` (the write-ahead request journal) are pure
  host bookkeeping. They run inside the fleet tick and the engine pack
  loop; the day one of them imports jax/numpy or syncs a device value,
  a *disarmed* run stops being free and the byte-identical-transparency
  guarantee silently erodes. Same shape as ``fleet-host-pure``.

* ``resilience-armed-guard`` — every call on an injection seam
  attribute (``self._faults`` / ``self.faults`` in the engine and
  replica, ``self._injector`` in the fleet) must be lexically guarded
  by an ``is not None`` test on that same attribute. The seams sit on
  the hot pack/dispatch/tick paths; an unguarded call is either an
  ``AttributeError`` on every disarmed run or — worse — a fault seam
  that quietly activates without a plan. Accepted guard shapes::

      if self._faults is not None:
          self._faults.take_poison(...)          # guarded body

      if self._faults is not None and self._faults.take_poison(...):
          ...                                    # short-circuit And

      inj = self._injector
      if inj is None:
          return                                 # early return: the
      inj.due(now)                               # local alias is armed

  (Calls through a local alias after an early-return guard are not
  self-prefixed and therefore never flagged; the rule polices the
  direct-attribute form only — the alias pattern is the documented
  alternative for long armed-only helpers.)
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.engine import Finding

#: host-pure resilience modules (suffix match, like ``fleet-host-pure``)
HOST_PURE_FILES = ("resilience/faults.py", "resilience/journal.py")

#: files whose injection seams must be armed-guarded
ARMED_FILES = ("serving/scheduler.py", "fleet/fleet.py",
               "fleet/replica.py")

#: the seam attributes (``self.<attr>.<method>(...)``)
SEAM_ATTRS = ("_faults", "_injector", "faults")

BANNED_IMPORT_ROOTS = ("jax", "jaxlib", "numpy", "np")


def _dotted(func: ast.AST) -> List[str]:
    parts: List[str] = []
    while isinstance(func, ast.Attribute):
        parts.append(func.attr)
        func = func.value
    if isinstance(func, ast.Name):
        parts.append(func.id)
    return parts[::-1]


class ResilienceHostPureRule:
    """faults.py / journal.py: no device libraries, no syncs."""

    def check(self, path: str, tree: ast.AST, text: str) -> List[Finding]:
        posix = path.replace("\\", "/")
        if not any(posix.endswith(f) for f in HOST_PURE_FILES):
            return []
        findings: List[Finding] = []
        for node in ast.walk(tree):
            mods = []
            if isinstance(node, ast.Import):
                mods = [a.name for a in node.names]
            elif isinstance(node, ast.ImportFrom) and node.module:
                mods = [node.module]
            for mod in mods:
                if mod.split(".")[0] in BANNED_IMPORT_ROOTS:
                    findings.append(Finding(
                        "resilience-host-pure", "error", path, node.lineno,
                        f"resilience host module imports `{mod}` — the "
                        f"injector and journal run inside the fleet tick "
                        f"and pack loop; device libraries here make even "
                        f"*disarmed* runs pay for the harness", "<module>"))
        stack: List[str] = []

        class V(ast.NodeVisitor):
            def visit_FunctionDef(self, node):
                stack.append(node.name)
                self.generic_visit(node)
                stack.pop()

            visit_AsyncFunctionDef = visit_FunctionDef

            def visit_Call(self, node):
                parts = _dotted(node.func)
                name = parts[-1] if parts else ""
                sym = stack[-1] if stack else "<module>"
                is_dev = (len(parts) >= 2
                          and parts[0] in ("np", "numpy", "jnp", "jax"))
                is_sync = name in ("device_get", "block_until_ready")
                is_item = (isinstance(node.func, ast.Attribute)
                           and node.func.attr == "item")
                if is_dev or is_sync or is_item:
                    findings.append(Finding(
                        "resilience-host-pure", "error", path, node.lineno,
                        f"`{'.'.join(parts) or 'item'}` in a resilience "
                        f"host module — fault scheduling and journaling "
                        f"must stay pure host bookkeeping (no device "
                        f"values, no syncs)", sym))
                self.generic_visit(node)

        V().visit(tree)
        return findings


def _not_none_attrs(test: ast.AST) -> Set[str]:
    """Seam attrs proven armed by ``test`` (``self.X is not None``,
    possibly inside an ``and`` chain)."""
    out: Set[str] = set()
    if isinstance(test, ast.BoolOp) and isinstance(test.op, ast.And):
        for v in test.values:
            out |= _not_none_attrs(v)
    elif (isinstance(test, ast.Compare) and len(test.ops) == 1
          and isinstance(test.ops[0], ast.IsNot)
          and isinstance(test.comparators[0], ast.Constant)
          and test.comparators[0].value is None
          and isinstance(test.left, ast.Attribute)
          and isinstance(test.left.value, ast.Name)
          and test.left.value.id == "self"
          and test.left.attr in SEAM_ATTRS):
        out.add(test.left.attr)
    return out


def _is_none_attrs(test: ast.AST) -> Set[str]:
    """Seam attrs proven *disarmed* by a simple ``self.X is None``."""
    if (isinstance(test, ast.Compare) and len(test.ops) == 1
            and isinstance(test.ops[0], ast.Is)
            and isinstance(test.comparators[0], ast.Constant)
            and test.comparators[0].value is None
            and isinstance(test.left, ast.Attribute)
            and isinstance(test.left.value, ast.Name)
            and test.left.value.id == "self"
            and test.left.attr in SEAM_ATTRS):
        return {test.left.attr}
    return set()


class ResilienceArmedGuardRule:
    """Every ``self.<seam>.*()`` call sits under an armed guard."""

    def check(self, path: str, tree: ast.AST, text: str) -> List[Finding]:
        posix = path.replace("\\", "/")
        if not any(posix.endswith(f) for f in ARMED_FILES):
            return []
        findings: List[Finding] = []
        stack: List[str] = []

        def check_expr(expr: ast.AST, armed: Set[str]) -> None:
            if expr is None:
                return
            if isinstance(expr, ast.BoolOp) and isinstance(expr.op,
                                                           ast.And):
                cur = set(armed)
                for v in expr.values:
                    check_expr(v, cur)
                    cur |= _not_none_attrs(v)
                return
            for node in ast.walk(expr):
                if not isinstance(node, ast.Call):
                    continue
                parts = _dotted(node.func)
                if (len(parts) >= 3 and parts[0] == "self"
                        and parts[1] in SEAM_ATTRS
                        and parts[1] not in armed):
                    sym = stack[-1] if stack else "<module>"
                    findings.append(Finding(
                        "resilience-armed-guard", "error", path,
                        node.lineno,
                        f"`{'.'.join(parts)}(...)` outside an "
                        f"`is not None` guard on `self.{parts[1]}` — "
                        f"injection seams are Optional and sit on the "
                        f"hot path; an unguarded call breaks every "
                        f"disarmed run", sym))

        def scan(stmts, armed: Set[str]) -> None:
            armed = set(armed)
            for st in stmts:
                if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    stack.append(st.name)
                    scan(st.body, set())
                    stack.pop()
                elif isinstance(st, ast.ClassDef):
                    scan(st.body, set())
                elif isinstance(st, ast.If):
                    check_expr(st.test, armed)
                    scan(st.body, armed | _not_none_attrs(st.test))
                    scan(st.orelse, armed)
                    # `if self.X is None: return` arms the rest
                    if (_is_none_attrs(st.test) and not st.orelse
                            and st.body
                            and isinstance(st.body[-1],
                                           (ast.Return, ast.Raise,
                                            ast.Continue))):
                        armed |= _is_none_attrs(st.test)
                elif isinstance(st, (ast.For, ast.AsyncFor)):
                    check_expr(st.iter, armed)
                    scan(st.body, armed)
                    scan(st.orelse, armed)
                elif isinstance(st, ast.While):
                    check_expr(st.test, armed)
                    scan(st.body, armed)
                    scan(st.orelse, armed)
                elif isinstance(st, (ast.With, ast.AsyncWith)):
                    for item in st.items:
                        check_expr(item.context_expr, armed)
                    scan(st.body, armed)
                elif isinstance(st, ast.Try):
                    scan(st.body, armed)
                    for h in st.handlers:
                        scan(h.body, armed)
                    scan(st.orelse, armed)
                    scan(st.finalbody, armed)
                else:
                    check_expr(st, armed)

        scan(tree.body, set())
        return findings
