"""Mask-parity lint rule (DESIGN.md §analysis).

``kernels/attention/mask.py`` is the single owner of segment / window /
causal admissibility — the Pallas kernel, the dense XLA path, the
blocked long-sequence path, and the distributed ring/Ulysses loops all
import it, so backends cannot drift apart on who attends to whom
(PR 5's unification). This rule keeps that true statically:

* no module outside the canonical one may DEFINE a function with one of
  the canonical mask names;
* no module outside the canonical one may contain the segment-
  admissibility idiom — an ``==``/``!=`` comparison whose both sides
  name segment ids (``q_seg == k_seg``-shaped code) — reimplementing
  the mask inline;
* every attention backend module MUST import the mask module (losing
  the import means the backend grew its own mask logic or dropped
  masking entirely).
"""
from __future__ import annotations

import ast
from typing import Dict, List, Tuple

from repro.analysis.engine import Finding

CANONICAL = "src/repro/kernels/attention/mask.py"

CANONICAL_FNS = {
    "segment_allowed", "position_allowed", "position_allowed_grid",
    "attention_block_map", "block_position_envelope",
}

#: backend modules that must import the shared mask algebra
REQUIRED_IMPORTERS = (
    "src/repro/models/attention.py",          # dense XLA + blocked paths
    "src/repro/models/dit.py",                # DiT dense _mha
    "src/repro/kernels/attention/flash_attention.py",   # Pallas kernel
    "src/repro/distributed/attention.py",     # ring / Ulysses inner loops
)

_MASK_IMPORT_SUFFIXES = ("kernels.attention.mask", "attention.mask")


def _names_seg(node: ast.AST) -> bool:
    """Does this operand name a segment-id value (identifier containing
    'seg')?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and "seg" in sub.id.lower():
            return True
        if isinstance(sub, ast.Attribute) and "seg" in sub.attr.lower():
            return True
    return False


def _imports_mask(tree: ast.AST) -> bool:
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            if any(a.name.endswith(_MASK_IMPORT_SUFFIXES)
                   for a in node.names):
                return True
        elif isinstance(node, ast.ImportFrom):
            mod = node.module or ""
            if mod.endswith(_MASK_IMPORT_SUFFIXES):
                return True
            if mod.endswith("kernels.attention") \
                    and any(a.name == "mask" for a in node.names):
                return True
    return False


class MaskParityRule:
    """Repo rule: single-source segment/window/causal admissibility."""

    name = "mask-parity"

    def check_repo(self, files: Dict[str, Tuple[ast.AST, str]]
                   ) -> List[Finding]:
        findings: List[Finding] = []
        for path, (tree, _text) in files.items():
            if path == CANONICAL:
                continue
            for node in ast.walk(tree):
                if isinstance(node, (ast.FunctionDef,
                                     ast.AsyncFunctionDef)) \
                        and node.name in CANONICAL_FNS:
                    findings.append(Finding(
                        "mask-parity", "error", path, node.lineno,
                        f"`{node.name}` reimplemented outside "
                        f"{CANONICAL}; import the shared mask module",
                        node.name))
                elif isinstance(node, ast.Compare) \
                        and any(isinstance(op, (ast.Eq, ast.NotEq))
                                for op in node.ops) \
                        and _names_seg(node.left) \
                        and all(_names_seg(c) for c in node.comparators):
                    findings.append(Finding(
                        "mask-parity", "error", path, node.lineno,
                        "inline segment-admissibility comparison; use "
                        "kernels.attention.mask.segment_allowed"))
        for path in REQUIRED_IMPORTERS:
            if path not in files:
                continue          # partial lint run (single file / tests)
            tree, _text = files[path]
            if not _imports_mask(tree):
                findings.append(Finding(
                    "mask-parity-import", "error", path, 1,
                    f"attention backend no longer imports "
                    f"{CANONICAL} — mask semantics can drift"))
        return findings
