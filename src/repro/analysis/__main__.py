"""CLI: ``python -m repro.analysis [--strict] [paths...]``.

Runs Level 1 (AST lint) over the given paths (default ``src/repro``)
plus Level 2 (jaxpr audit, disable with ``--no-jaxpr``), splits the
findings against the committed baseline, prints a report, and — under
``--strict`` — exits non-zero iff any NEW error-severity finding
survives (grandfathered findings and warnings never fail the build).

``--write-baseline`` regenerates ``analysis/baseline.json`` from the
current findings (justifications must then be filled in by hand before
committing — the loader rejects entries without one).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis import engine


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Trace-safety static analysis (DESIGN.md §analysis)")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on any non-baselined error finding")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the Level-2 jaxpr audit (no jax import)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable report on stdout")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite analysis/baseline.json from current "
                         "findings (fill in justifications before commit)")
    args = ap.parse_args(argv)

    paths = [Path(p) for p in (args.paths or
                               [engine.REPO_ROOT / "src" / "repro"])]
    report = engine.run_analysis(paths, with_jaxpr=not args.no_jaxpr)

    if args.write_baseline:
        entries = engine.baseline_entries(report.new + report.baselined)
        engine.BASELINE_PATH.write_text(json.dumps(
            {"findings": entries}, indent=2) + "\n")
        print(f"wrote {len(entries)} entries to {engine.BASELINE_PATH}")
        return 0

    if args.as_json:
        json.dump({
            "new": [vars(f) for f in report.new],
            "baselined": [vars(f) for f in report.baselined],
            "fingerprints": report.fingerprints,
            "ok": report.ok(),
        }, sys.stdout, indent=2)
        print()
    else:
        for f in report.new:
            print(f.render())
        if report.baselined:
            print(f"[baseline] {len(report.baselined)} grandfathered "
                  f"finding(s) suppressed")
        for unit, fp in sorted(report.fingerprints.items()):
            print(f"[fingerprint] {unit}: {fp}")
        n_err = len(report.new_errors)
        print(f"{len(report.new)} new finding(s), {n_err} error(s)")
    if args.strict and not report.ok():
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
