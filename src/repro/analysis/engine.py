"""AST lint engine: rule registry, suppressions, baseline (DESIGN.md
§analysis).

The engine is deliberately jax-free so Level 1 runs anywhere in
milliseconds; Level 2 (:mod:`repro.analysis.jaxpr_audit`) imports jax
and is pulled in lazily by :func:`run_analysis`.

Vocabulary:

* a **source rule** checks one file's AST (``check(path, tree, text)``);
* a **repo rule** checks cross-file properties (``check_repo(files)``) —
  the cache-key and mask-parity rules live here;
* findings carry a ``severity`` (``error`` fails ``--strict``,
  ``warning`` never does) and a stable :meth:`Finding.baseline_key`
  ``rule:path:symbol`` that survives line drift, so the committed
  baseline does not rot on unrelated edits;
* ``# repro: ignore[rule-a,rule-b]`` (or bare ``# repro: ignore``) on
  the offending line suppresses findings there — for *justified*
  exceptions; the baseline is for *grandfathered* ones.
"""
from __future__ import annotations

import ast
import dataclasses
import json
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# src/repro/analysis/engine.py -> repo root (…/src/repro/analysis -> repo)
REPO_ROOT = Path(__file__).resolve().parents[3]
BASELINE_PATH = Path(__file__).resolve().parent / "baseline.json"

SEVERITIES = ("error", "warning")

#: rule id -> one-line description (the rule catalog; DESIGN.md §analysis)
RULE_IDS: Dict[str, str] = {
    "trace-host-cast": "int()/float()/bool()/.item() on a traced value "
                       "inside a jit/scan/shard_map region (host sync)",
    "trace-python-branch": "Python if/while on a value derived from traced "
                           "inputs inside a traced region (structure leak)",
    "trace-python-loop": "Python for-loop iterating a traced value "
                         "(unrolls into the graph)",
    "trace-len": "len() of a traced value inside a traced region "
                 "(shape-static today, a host sync the day shapes go "
                 "dynamic)",
    "trace-fstring": "f-string formatting a traced value (forces "
                     "concretization)",
    "trace-host-np": "host numpy call applied to traced values inside a "
                     "traced region",
    "hot-host-sync": "int()/float()/bool()/.item() on a device value "
                     "inside a host-side hot loop (blocking transfer "
                     "per iteration)",
    "cachekey-hashable": "a plan/spec/layout dataclass stopped being "
                         "hashable (cannot join an executable cache key)",
    "cachekey-missing": "a structural field does not join the "
                        "FlexiPipeline runner / packed-step cache key",
    "cachekey-unclassified": "a new field on a keyed dataclass has no "
                             "structural/data classification",
    "mask-parity": "segment/window/causal admissibility reimplemented "
                   "outside kernels/attention/mask.py",
    "mask-parity-import": "an attention backend does not import the "
                          "shared mask module",
    "jaxpr-trace-failure": "a hot-path step function no longer traces "
                           "(host sync or shape leak inside jit)",
    "jaxpr-fingerprint-drift": "a step-function jaxpr fingerprint differs "
                               "across a data-only switch (recompile "
                               "hazard)",
    "jaxpr-host-callback": "pure_callback/io_callback/debug_callback in a "
                           "hot-path jaxpr",
    "jaxpr-dtype-promotion": "silent widening convert_element_type "
                             "(f32->f64 / bf16->f32) in a hot-path jaxpr",
    "jaxpr-nondonated-hotbuf": "large recurrent buffer not donated on a "
                               "hot-path jit entry point",
    "jaxpr-tap-structure": "DCE-ing the telemetry tap outputs does not "
                           "recover the untapped step jaxpr (taps must be "
                           "data, not structure)",
    "telemetry-host-callback": "telemetry code injects a host callback / "
                               "debug print into a traced region",
    "telemetry-tap-host-sync": "tap arrays forced to host on the dispatch "
                               "path (np.asarray/.item/float outside the "
                               "aggregate sink)",
    "telemetry-attribution-device": "telemetry/attribution.py touches "
                                    "jax/numpy/device values — attribution "
                                    "runs on the serving hot path and must "
                                    "stay pure host integer arithmetic",
    "fleet-host-pure": "a fleet control module (router/membership/health) "
                       "imports jax/numpy or syncs a device value — "
                       "placement must stay pure host bookkeeping",
    "resilience-host-pure": "resilience/faults.py or journal.py imports "
                            "jax/numpy or syncs a device value — fault "
                            "scheduling and journaling run inside the "
                            "fleet tick and must stay pure host "
                            "bookkeeping",
    "resilience-armed-guard": "a fault-injection seam call "
                              "(self._faults/_injector/faults) outside "
                              "an `is not None` guard — seams are "
                              "Optional on the hot path; unguarded calls "
                              "break disarmed runs",
}


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    severity: str                 # 'error' | 'warning'
    path: str                     # repo-relative posix path
    line: int
    message: str
    symbol: str = "<module>"      # enclosing function qualname

    def baseline_key(self) -> str:
        return f"{self.rule}:{self.path}:{self.symbol}"

    def render(self) -> str:
        return (f"{self.path}:{self.line}: [{self.rule}] {self.severity}: "
                f"{self.message} (in {self.symbol})")


def relpath(path: Path) -> str:
    path = Path(path).resolve()
    try:
        return path.relative_to(REPO_ROOT).as_posix()
    except ValueError:
        return path.as_posix()


# ---------------------------------------------------------------------------
# Inline suppressions

_IGNORE_RE = re.compile(r"#\s*repro:\s*ignore(?:\[([\w\-, ]+)\])?")


def parse_suppressions(text: str) -> Dict[int, Optional[frozenset]]:
    """1-based line -> suppressed rule ids (None = all rules)."""
    out: Dict[int, Optional[frozenset]] = {}
    for i, line in enumerate(text.splitlines(), start=1):
        m = _IGNORE_RE.search(line)
        if not m:
            continue
        ids = m.group(1)
        out[i] = (None if ids is None else
                  frozenset(s.strip() for s in ids.split(",") if s.strip()))
    return out


def _suppressed(f: Finding, sup: Dict[int, Optional[frozenset]]) -> bool:
    rules = sup.get(f.line, False)
    if rules is False:
        return False
    return rules is None or f.rule in rules


# ---------------------------------------------------------------------------
# Baseline

def load_baseline(path: Path = BASELINE_PATH) -> List[dict]:
    if not Path(path).exists():
        return []
    data = json.loads(Path(path).read_text())
    entries = data.get("findings", [])
    for e in entries:
        for field in ("rule", "path", "symbol", "justification"):
            if field not in e:
                raise ValueError(f"baseline entry {e} missing {field!r} "
                                 f"(every grandfathered finding needs a "
                                 f"justification)")
    return entries


def split_baselined(findings: Sequence[Finding],
                    baseline: Sequence[dict]
                    ) -> Tuple[List[Finding], List[Finding]]:
    """(new, grandfathered). A baseline entry absorbs every finding with
    its ``rule:path:symbol`` key — the key is line-free on purpose."""
    keys = {f"{e['rule']}:{e['path']}:{e['symbol']}" for e in baseline}
    new = [f for f in findings if f.baseline_key() not in keys]
    old = [f for f in findings if f.baseline_key() in keys]
    return new, old


def baseline_entries(findings: Sequence[Finding],
                     justification: str = "TODO: justify") -> List[dict]:
    """Deduped baseline entries for ``findings`` (the --write-baseline
    path; edit the justifications before committing)."""
    seen, out = set(), []
    for f in findings:
        k = f.baseline_key()
        if k in seen:
            continue
        seen.add(k)
        out.append({"rule": f.rule, "path": f.path, "symbol": f.symbol,
                    "justification": justification})
    return out


# ---------------------------------------------------------------------------
# File iteration + rule dispatch

def iter_py_files(paths: Iterable[Path]) -> List[Path]:
    out: List[Path] = []
    for p in paths:
        p = Path(p)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.py")))
        elif p.suffix == ".py":
            out.append(p)
    return out


def _load_rules():
    # local import: rule modules import Finding from here
    from repro.analysis import (rules_cachekey, rules_fleet, rules_mask,
                                rules_resilience, rules_telemetry,
                                rules_trace)
    source_rules = [rules_trace.TraceSafetyRule(),
                    rules_telemetry.TelemetryRule(),
                    rules_fleet.FleetHostPureRule(),
                    rules_resilience.ResilienceHostPureRule(),
                    rules_resilience.ResilienceArmedGuardRule()]
    repo_rules = [rules_mask.MaskParityRule(),
                  rules_cachekey.CacheKeyRule()]
    return source_rules, repo_rules


def lint_paths(paths: Sequence[Path],
               collect_suppressed: bool = False) -> List[Finding]:
    """Run every Level-1 rule over ``paths`` (files or directories).
    Inline-suppressed findings are dropped (or returned too when
    ``collect_suppressed``, for the analyzer's own tests)."""
    source_rules, repo_rules = _load_rules()
    files: Dict[str, Tuple[ast.AST, str]] = {}
    sups: Dict[str, Dict[int, Optional[frozenset]]] = {}
    findings: List[Finding] = []
    for path in iter_py_files(paths):
        text = path.read_text()
        try:
            tree = ast.parse(text, filename=str(path))
        except SyntaxError as e:
            findings.append(Finding("trace-host-cast", "error",
                                    relpath(path), e.lineno or 0,
                                    f"file does not parse: {e.msg}"))
            continue
        rel = relpath(path)
        files[rel] = (tree, text)
        sups[rel] = parse_suppressions(text)
        for rule in source_rules:
            findings.extend(rule.check(rel, tree, text))
    for rule in repo_rules:
        findings.extend(rule.check_repo(files))
    if collect_suppressed:
        return findings
    return [f for f in findings
            if not _suppressed(f, sups.get(f.path, {}))]


# ---------------------------------------------------------------------------
# Top-level entry (CLI, bench gate, tests)

@dataclasses.dataclass
class AnalysisReport:
    new: List[Finding]
    baselined: List[Finding]
    fingerprints: Dict[str, str]

    @property
    def new_errors(self) -> List[Finding]:
        return [f for f in self.new if f.severity == "error"]

    def ok(self) -> bool:
        return not self.new_errors


def run_analysis(paths: Sequence[Path], *, with_jaxpr: bool = True,
                 baseline_path: Path = BASELINE_PATH) -> AnalysisReport:
    """Level 1 over ``paths`` plus (optionally) the Level 2 jaxpr audit,
    split against the committed baseline."""
    findings = lint_paths(paths)
    fingerprints: Dict[str, str] = {}
    if with_jaxpr:
        from repro.analysis import jaxpr_audit
        report = jaxpr_audit.audit_step_functions()
        findings.extend(report.findings)
        fingerprints = report.fingerprints
    new, old = split_baselined(findings, load_baseline(baseline_path))
    return AnalysisReport(new=new, baselined=old, fingerprints=fingerprints)
