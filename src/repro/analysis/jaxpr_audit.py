"""Level 2 — jaxpr structural auditor (DESIGN.md §analysis).

Traces the repo's REAL step-function families with ``jax.make_jaxpr``
over a tiny (but fully flexified) DiT and computes a **structural
fingerprint** of each closed jaxpr: primitives, operand/result avals,
equation params (sub-jaxprs walked recursively), and — crucially —
value digests of the trace-time *constants*. Arguments are abstracted
by ``make_jaxpr``, so any input-value dependence that survives into the
fingerprint must have leaked through a closure or been baked as a
constant: exactly the recompile-hazard bug class.

Invariances asserted (``jaxpr-fingerprint-drift`` on violation):

* the packed step function, traced at two different timestep-ladder
  metas (a budget switch in the serving engine is *only* a metas
  change);
* the packed cached step, traced at two different refresh-flag
  patterns (a cache-policy switch is *only* a flag change);
* two independently built ``FlexiPipeline`` cached runners whose
  ``CacheSpec`` differ in every data-only knob (policy / interval /
  threshold) at the same split;
* the dense attention backend traced at two different segment-id
  contents at fixed geometry (a pack-layout occupancy change);
* the plain eps + DDIM step at two different timesteps;
* the tapped packed step (``taps=True``, DESIGN.md §telemetry): dead-code
  eliminating the tap outputs must recover the untapped jaxpr **exactly**
  (``pe.dce_jaxpr_consts`` keeping only the primary outputs), proving taps
  are pure extra data — they read the step's existing intermediates and
  feed nothing back; and the tapped family must itself be
  ladder/policy-invariant (turning telemetry on costs zero recompiles
  across budget or policy switches).

What the fingerprint does NOT prove: full phase-runner equality across
*budgets* — a budget switch changes the phase split, so those jaxprs
legitimately differ and zero-recompile there is cache *replay*,
guarded by the cache-key completeness rule plus the runtime recompile
counters in the benches (DESIGN.md §analysis).

Each traced jaxpr is also walked (into every sub-jaxpr) for host
callbacks, silent widening dtype conversions, and the ``jax.jit`` entry
points of the hot pipeline path are checked for buffer donation.
"""
from __future__ import annotations

import ast
import dataclasses
import functools
import hashlib
import re
from typing import Any, Callable, Dict, Iterable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.engine import REPO_ROOT, Finding, relpath

PIPELINE_PATH = "src/repro/pipeline/pipeline.py"

#: primitives that call back into Python from compiled code
HOST_CALLBACK_PRIMS = {"pure_callback", "io_callback", "debug_callback",
                       "outside_call", "host_callback"}

#: silent widenings worth flagging ({} entries are (operand, result))
WIDENINGS = {("float32", "float64"), ("bfloat16", "float32"),
             ("float16", "float32")}

_ADDR_RE = re.compile(r"0x[0-9a-fA-F]+")


# ---------------------------------------------------------------------------
# Fingerprinting


def _digest_value(x: Any) -> str:
    arr = np.asarray(x)
    h = hashlib.sha256()
    h.update(str(arr.dtype).encode())
    h.update(str(arr.shape).encode())
    h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()[:16]


def _aval_str(v: Any) -> str:
    if isinstance(v, jax.core.Literal):
        return f"lit#{_digest_value(v.val)}"
    a = v.aval
    shape = getattr(a, "shape", None)
    dtype = getattr(a, "dtype", None)
    return f"{dtype}{tuple(shape) if shape is not None else ''}"


def _canon_param(v: Any) -> str:
    """Equation params, canonicalized: sub-jaxprs recurse structurally,
    callables reduce to qualnames, arrays to value digests, and memory
    addresses are stripped from reprs."""
    if isinstance(v, jax.core.ClosedJaxpr):
        return "{" + _canon_closed(v) + "}"
    if isinstance(v, jax.core.Jaxpr):
        return "{" + _canon_closed(jax.core.ClosedJaxpr(v, ())) + "}"
    if isinstance(v, (tuple, list)):
        return "(" + ",".join(_canon_param(x) for x in v) + ")"
    if isinstance(v, dict):
        return "{" + ",".join(f"{k}:{_canon_param(x)}"
                              for k, x in sorted(v.items())) + "}"
    if isinstance(v, (np.ndarray, jax.Array)):
        return f"arr#{_digest_value(v)}"
    if callable(v):
        return getattr(v, "__qualname__", None) or type(v).__name__
    return _ADDR_RE.sub("0x", repr(v))


def _canon_closed(closed: jax.core.ClosedJaxpr) -> str:
    j = closed.jaxpr
    parts = ["in:" + ",".join(_aval_str(v) for v in j.invars),
             "const:" + ",".join(
                 f"{_aval_str(v)}#{_digest_value(c)}"
                 for v, c in zip(j.constvars, closed.consts))]
    for eqn in j.eqns:
        ps = ";".join(f"{k}={_canon_param(v)}"
                      for k, v in sorted(eqn.params.items()))
        parts.append(
            f"{eqn.primitive.name}"
            f"({','.join(_aval_str(v) for v in eqn.invars)})"
            f"->({','.join(_aval_str(v) for v in eqn.outvars)})[{ps}]")
    parts.append("out:" + ",".join(_aval_str(v) for v in j.outvars))
    return "\n".join(parts)


def fingerprint(closed: jax.core.ClosedJaxpr) -> str:
    """Stable structural digest of a closed jaxpr (incl. constant
    values — baked data is a per-trace recompile hazard)."""
    return hashlib.sha256(_canon_closed(closed).encode()).hexdigest()[:32]


def _iter_eqns(closed: jax.core.ClosedJaxpr):
    """Every equation, recursing into sub-jaxprs (scan/cond/pjit/...)."""
    stack = [closed.jaxpr]
    while stack:
        j = stack.pop()
        for eqn in j.eqns:
            yield eqn
            for v in eqn.params.values():
                stack.extend(_sub_jaxprs(v))


def _sub_jaxprs(v: Any) -> List[jax.core.Jaxpr]:
    if isinstance(v, jax.core.ClosedJaxpr):
        return [v.jaxpr]
    if isinstance(v, jax.core.Jaxpr):
        return [v]
    if isinstance(v, (tuple, list)):
        out: List[jax.core.Jaxpr] = []
        for x in v:
            out.extend(_sub_jaxprs(x))
        return out
    return []


# ---------------------------------------------------------------------------
# Per-jaxpr violation walks


def check_jaxpr(closed: jax.core.ClosedJaxpr, unit: str,
                path: str = PIPELINE_PATH) -> List[Finding]:
    findings: List[Finding] = []
    for eqn in _iter_eqns(closed):
        name = eqn.primitive.name
        if name in HOST_CALLBACK_PRIMS:
            findings.append(Finding(
                "jaxpr-host-callback", "error", path, 0,
                f"`{name}` in the {unit} jaxpr — compiled hot path "
                f"calls back into Python", unit))
        elif name == "convert_element_type":
            src = eqn.invars[0]
            if isinstance(src, jax.core.Literal):
                continue
            if getattr(src.aval, "weak_type", False):
                continue          # python-scalar promotion, not a leak
            old = str(getattr(src.aval, "dtype", ""))
            new = str(eqn.params.get("new_dtype", ""))
            if (old, new) in WIDENINGS:
                findings.append(Finding(
                    "jaxpr-dtype-promotion", "error", path, 0,
                    f"silent {old}->{new} widening in the {unit} jaxpr",
                    unit))
    return findings


# ---------------------------------------------------------------------------
# Tiny audited model (mirrors tests/conftest.py, kept self-contained so
# `python -m repro.analysis` works outside pytest)


@functools.lru_cache(maxsize=1)
def _tiny():
    from repro.configs.base import AttnConfig, DiTConfig, ModelConfig
    from repro.core import flexify
    from repro.diffusion import schedule as sch
    from repro.models import dit as dit_mod
    cfg = ModelConfig(
        name="audit-dit", family="dit", num_layers=2, d_model=64, d_ff=256,
        vocab_size=0, attn=AttnConfig(4, 4, 16, use_rope=False),
        dit=DiTConfig(latent_shape=(1, 16, 16, 4), patch_size=(1, 2, 2),
                      flex_patch_sizes=(), underlying_patch_size=(1, 2, 2),
                      conditioning="class", num_classes=10),
        mlp_activation="gelu", norm_type="layernorm",
        param_dtype="float32", compute_dtype="float32", remat="none",
        max_seq_len=256)
    params = dit_mod.init_dit(cfg, jax.random.PRNGKey(0))
    fparams, fcfg = flexify(params, cfg, [(1, 4, 4)])
    sched = sch.linear_schedule(100)
    return fparams, fcfg, sched


@dataclasses.dataclass
class AuditReport:
    findings: List[Finding]
    fingerprints: Dict[str, str]


def _drift(unit: str, fps: Dict[str, str], what: str) -> List[Finding]:
    """One finding if the fingerprints in ``fps`` are not all equal."""
    if len(set(fps.values())) <= 1:
        return []
    detail = ", ".join(f"{k}={v[:10]}" for k, v in fps.items())
    return [Finding(
        "jaxpr-fingerprint-drift", "error", PIPELINE_PATH, 0,
        f"{unit}: jaxpr fingerprint differs across {what} — a data-only "
        f"switch recompiles ({detail})", unit)]


def _trace(unit: str, fn: Callable, *args
           ) -> Tuple[jax.core.ClosedJaxpr | None, List[Finding]]:
    try:
        return jax.make_jaxpr(fn)(*args), []
    except Exception as e:      # ConcretizationTypeError, shape leaks, ...
        return None, [Finding(
            "jaxpr-trace-failure", "error", PIPELINE_PATH, 0,
            f"{unit} no longer traces: {type(e).__name__}: {e}", unit)]


# ---------------------------------------------------------------------------
# Audited units


def audit_plain_step() -> AuditReport:
    """Guided eps + DDIM update, traced at two timesteps."""
    from repro.core.guidance import GuidanceConfig, make_eps_fn
    from repro.diffusion import schedule as sch
    fparams, fcfg, sched = _tiny()
    B = 2
    cond = jnp.zeros((B,), jnp.int32)
    null = jnp.full((B,), fcfg.dit.num_classes, jnp.int32)
    eps = make_eps_fn(fparams, fcfg, cond, null,
                      GuidanceConfig(scale=1.5, mode_cond=0, mode_uncond=0))

    def step(x, t, t_next):
        e, _lv = eps(x, t)
        return sch.ddim_step(sched, x, e, t, t_next)

    x = jnp.zeros((B,) + fcfg.dit.latent_shape, jnp.float32)
    findings: List[Finding] = []
    fps: Dict[str, str] = {}
    last = None
    for tag, (t, tn) in {"t=90": (90, 80), "t=10": (10, 0)}.items():
        closed, errs = _trace("plain_step", step, x,
                              jnp.full((B,), t, jnp.int32),
                              jnp.full((B,), tn, jnp.int32))
        findings.extend(errs)
        if closed is None:
            continue
        fps[tag] = fingerprint(closed)
        last = closed
    findings.extend(_drift("plain_step", fps, "timesteps"))
    if last is not None:
        findings.extend(check_jaxpr(last, "plain_step"))
    return AuditReport(findings, {"plain_step": fps.get("t=90", "")})


def _packed_args(layout, k_steps: int, ts: Iterable[int],
                 cache_split: int | None = None):
    from repro.cache import apply as cache_apply
    fparams, fcfg, _sched = _tiny()
    ts = list(ts)
    xs, metas, keys, deltas, refreshes = [], [], [], [], []
    for mode, n in layout.groups:
        xs.append(jnp.zeros((n,) + fcfg.dit.latent_shape, jnp.float32))
        rows = []
        for s in range(k_steps):
            t = ts[s % len(ts)]
            rows.append([[t] * n, [max(t - 10, -1)] * n, [0] * n])
        metas.append(jnp.asarray(rows, jnp.int32))
        keys.append(jnp.zeros((k_steps, n, 2), jnp.uint32))
        if cache_split is not None:
            _eb, N, d = cache_apply.delta_shape(fcfg, mode, n, layout.guided)
            mult = 2 if layout.guided else 1
            deltas.append(jnp.zeros((n, mult, N, d), jnp.float32))
            refreshes.append(jnp.ones((k_steps, n), bool))
    if cache_split is None:
        return fparams, xs, metas, keys
    return fparams, xs, metas, keys, deltas, refreshes


def audit_packed_step() -> AuditReport:
    """Packed step fn: a budget switch is a metas-value change only."""
    from repro.pipeline.packed import PackLayout, make_packed_step_fn
    fparams, fcfg, sched = _tiny()
    layout = PackLayout(groups=((0, 1), (1, 2)), guided=True)
    step = make_packed_step_fn(fcfg, sched, layout, k_steps=2)
    findings: List[Finding] = []
    fps: Dict[str, str] = {}
    last = None
    for tag, ladder in {"ladder-hi": (90, 80), "ladder-lo": (30, 20)}.items():
        args = _packed_args(layout, 2, ladder)
        closed, errs = _trace("packed_step", step, *args)
        findings.extend(errs)
        if closed is None:
            continue
        fps[tag] = fingerprint(closed)
        last = closed
    findings.extend(_drift("packed_step", fps, "budget ladders"))
    if last is not None:
        findings.extend(check_jaxpr(last, "packed_step"))
    return AuditReport(findings, {"packed_step": fps.get("ladder-hi", "")})


def audit_packed_cached_step() -> AuditReport:
    """Cached packed step: a policy switch is a refresh-flag change only."""
    from repro.pipeline.packed import PackLayout, make_packed_step_fn
    fparams, fcfg, sched = _tiny()
    layout = PackLayout(groups=((0, 1), (1, 2)), guided=True)
    step = make_packed_step_fn(fcfg, sched, layout, k_steps=2,
                               cache_split=1)
    findings: List[Finding] = []
    fps: Dict[str, str] = {}
    last = None
    for tag, flip in {"refresh-all": False, "refresh-alt": True}.items():
        args = list(_packed_args(layout, 2, (90, 80), cache_split=1))
        if flip:
            args[5] = [r.at[1::2].set(False) for r in args[5]]
        closed, errs = _trace("packed_cached_step", step, *args)
        findings.extend(errs)
        if closed is None:
            continue
        fps[tag] = fingerprint(closed)
        last = closed
    findings.extend(_drift("packed_cached_step", fps, "refresh policies"))
    if last is not None:
        findings.extend(check_jaxpr(last, "packed_cached_step"))
    return AuditReport(findings,
                       {"packed_cached_step": fps.get("refresh-all", "")})


def audit_cached_runner() -> AuditReport:
    """Two independently built cached runners whose CacheSpec differ in
    every data-only knob (same split) must trace identically."""
    from repro.cache import policy as cache_policy
    from repro.cache.policy import CacheSpec
    from repro.diffusion import schedule as sch
    from repro.pipeline import FlexiPipeline, SamplingPlan
    fparams, fcfg, sched = _tiny()
    pipe = FlexiPipeline(fparams, fcfg, sched)
    B = 2
    findings: List[Finding] = []
    fps: Dict[str, str] = {}
    last = None
    for tag, spec in {
        "interval": CacheSpec(policy="interval", interval=2, split=1),
        "proxy": CacheSpec(policy="proxy", threshold=0.1, split=1),
    }.items():
        plan = SamplingPlan(T=6, cache=spec)
        ts = sch.respaced_timesteps(sched.num_steps, plan.T)
        schedule = plan.resolve_schedule(fcfg)
        runner = pipe._cached_runner(plan, schedule, ts)
        masks = tuple(jnp.asarray(cache_policy.refresh_mask(spec, tsub))
                      for _m, tsub in schedule.split_timesteps(ts))
        x_T = jnp.zeros((B,) + fcfg.dit.latent_shape, jnp.float32)
        cond = jnp.zeros((B,), jnp.int32)
        null = jnp.full((B,), fcfg.dit.num_classes, jnp.int32)
        closed, errs = _trace(
            "cached_runner", runner, (fparams,), x_T, cond, null,
            jax.random.PRNGKey(0), None, None, masks)
        findings.extend(errs)
        if closed is None:
            continue
        fps[tag] = fingerprint(closed)
        last = closed
    findings.extend(_drift("cached_runner", fps,
                           "cache policies (same split)"))
    if last is not None:
        findings.extend(check_jaxpr(last, "cached_runner"))
    return AuditReport(findings, {"cached_runner": fps.get("interval", "")})


def _dce_keep_primary(closed: jax.core.ClosedJaxpr,
                      n_keep: int) -> jax.core.ClosedJaxpr:
    """Dead-code-eliminate all but the first ``n_keep`` outputs, dropping
    the constants whose constvars die with them."""
    from jax.interpreters import partial_eval as pe
    used = [True] * n_keep + [False] * (len(closed.jaxpr.outvars) - n_keep)
    dj, used_consts, _used_in = pe.dce_jaxpr_consts(closed.jaxpr, used)
    consts = [c for c, u in zip(closed.consts, used_consts) if u]
    return jax.core.ClosedJaxpr(dj, consts)


def audit_tapped_step() -> AuditReport:
    """Telemetry taps are data, not structure (DESIGN.md §telemetry).

    For the plain and cached packed families: DCE-ing the tap outputs
    out of the tapped jaxpr must reproduce the untapped jaxpr
    fingerprint byte-for-byte (both sides normalized through the same
    DCE pass), and the tapped jaxpr must be invariant under the same
    data-only switches PR 6 proves for the untapped one."""
    from repro.pipeline.packed import PackLayout, make_packed_step_fn
    fparams, fcfg, sched = _tiny()
    layout = PackLayout(groups=((0, 1), (1, 2)), guided=True)
    findings: List[Finding] = []
    fingerprints: Dict[str, str] = {}
    for split, unit in ((None, "packed_step_tapped"),
                        (1, "packed_cached_step_tapped")):
        off = make_packed_step_fn(fcfg, sched, layout, k_steps=2,
                                  cache_split=split)
        on = make_packed_step_fn(fcfg, sched, layout, k_steps=2,
                                 cache_split=split, taps=True)
        fps: Dict[str, str] = {}
        last = None
        for tag, ladder in {"ladder-hi": (90, 80),
                            "ladder-lo": (30, 20)}.items():
            args = _packed_args(layout, 2, ladder, cache_split=split)
            ct, errs = _trace(unit, on, *args)
            findings.extend(errs)
            if ct is None:
                continue
            fps[tag] = fingerprint(ct)
            last = ct
            co, errs = _trace(unit, off, *args)
            findings.extend(errs)
            if co is None:
                continue
            n_primary = len(co.jaxpr.outvars)
            dce_t = fingerprint(_dce_keep_primary(ct, n_primary))
            dce_o = fingerprint(_dce_keep_primary(co, n_primary))
            if dce_t != dce_o:
                findings.append(Finding(
                    "jaxpr-tap-structure", "error", PIPELINE_PATH, 0,
                    f"{unit} ({tag}): DCE-ing the tap outputs does not "
                    f"recover the untapped jaxpr ({dce_t[:10]} != "
                    f"{dce_o[:10]}) — taps changed the step's structure, "
                    f"not just its outputs", unit))
        findings.extend(_drift(unit, fps, "budget ladders (taps on)"))
        if last is not None:
            findings.extend(check_jaxpr(last, unit))
            fingerprints[unit] = fps.get("ladder-hi", "")
    return AuditReport(findings, fingerprints)


def audit_attention_segments() -> AuditReport:
    """Dense attention backend at fixed geometry, two segment-id
    contents (a pack-layout occupancy change)."""
    from repro.models import attention as attn_mod
    fparams, fcfg, _sched = _tiny()
    a = fcfg.attn
    d = fcfg.d_model
    params = {
        "wq": jnp.zeros((d, a.num_heads, a.head_dim)),
        "wk": jnp.zeros((d, a.num_kv_heads, a.head_dim)),
        "wv": jnp.zeros((d, a.num_kv_heads, a.head_dim)),
        "wo": jnp.zeros((a.num_heads, a.head_dim, d)),
    }
    S = 32
    x = jnp.zeros((1, S, d), jnp.float32)
    seg_a = jnp.concatenate(
        [jnp.zeros((1, S // 2), jnp.int32), jnp.ones((1, S // 2), jnp.int32)],
        axis=1)
    seg_b = jnp.zeros((1, S), jnp.int32)

    def run(x, seg):
        return attn_mod.attention(params, x, a, causal=False,
                                  segment_ids=seg, backend="xla")

    findings: List[Finding] = []
    fps: Dict[str, str] = {}
    last = None
    for tag, seg in {"two-seg": seg_a, "one-seg": seg_b}.items():
        closed, errs = _trace("attention_segments", run, x, seg)
        findings.extend(errs)
        if closed is None:
            continue
        fps[tag] = fingerprint(closed)
        last = closed
    findings.extend(_drift("attention_segments", fps,
                           "segment-id contents"))
    if last is not None:
        findings.extend(check_jaxpr(last, "attention_segments"))
    return AuditReport(findings,
                       {"attention_segments": fps.get("two-seg", "")})


# ---------------------------------------------------------------------------
# Donation check (AST over the hot pipeline path: jit entry points that
# carry large recurrent buffers should donate them)


def audit_donation(path: str = PIPELINE_PATH) -> AuditReport:
    findings: List[Finding] = []
    src = (REPO_ROOT / path)
    if not src.exists():
        return AuditReport([], {})
    tree = ast.parse(src.read_text(), filename=str(src))
    stack: List[str] = []

    class V(ast.NodeVisitor):
        def visit_FunctionDef(self, node):
            stack.append(node.name)
            self.generic_visit(node)
            stack.pop()

        visit_AsyncFunctionDef = visit_FunctionDef

        def visit_Call(self, node):
            f = node.func
            is_jit = (isinstance(f, ast.Attribute) and f.attr == "jit") or \
                     (isinstance(f, ast.Name) and f.id == "jit")
            if is_jit and not any(k.arg and "donate" in k.arg
                                  for k in node.keywords):
                sym = stack[-1] if stack else "<module>"
                findings.append(Finding(
                    "jaxpr-nondonated-hotbuf", "error", path, node.lineno,
                    f"hot-path jax.jit in `{sym}` does not donate its "
                    f"recurrent buffers (x_T/deltas re-allocate per call)",
                    sym))
            self.generic_visit(node)

    V().visit(tree)
    return AuditReport(findings, {})


# ---------------------------------------------------------------------------
# Entry point


def audit_step_functions() -> AuditReport:
    """Run every audit unit; units that cannot even build surface as
    ``jaxpr-trace-failure`` findings rather than crashing the CLI."""
    findings: List[Finding] = []
    fingerprints: Dict[str, str] = {}
    units = [audit_plain_step, audit_packed_step, audit_packed_cached_step,
             audit_cached_runner, audit_tapped_step,
             audit_attention_segments, audit_donation]
    for unit in units:
        try:
            rep = unit()
        except Exception as e:
            findings.append(Finding(
                "jaxpr-trace-failure", "error", PIPELINE_PATH, 0,
                f"audit unit {unit.__name__} failed to build: "
                f"{type(e).__name__}: {e}", unit.__name__))
            continue
        findings.extend(rep.findings)
        fingerprints.update(rep.fingerprints)
    return AuditReport(findings, fingerprints)
