"""Trace-safety static analysis (DESIGN.md §analysis).

Two levels, one goal: *prove* the invariants the whole engine rests on —
budget / cache-policy / pack-layout switches are data, not structure —
instead of only observing them through runtime recompile counters.

* **Level 1 — AST lint** (:mod:`repro.analysis.engine` + the rule
  modules): repo-specific rules over the Python source. Trace-safety
  (host syncs and Python control flow on traced values inside
  jit/scan/shard_map regions), cache-key completeness (every structural
  field of ``SamplingPlan`` / ``CacheSpec`` / ``ParallelSpec`` /
  ``PackLayout`` must join the FlexiPipeline runner / packed-step cache
  key), and mask-parity (only ``kernels/attention/mask.py`` may define
  segment/window/causal admissibility).

* **Level 2 — jaxpr audit** (:mod:`repro.analysis.jaxpr_audit`): traces
  the real step functions with ``jax.make_jaxpr``, computes structural
  fingerprints, and asserts they are bit-identical across budget
  ladders, cache policies, and pack-layout contents — a static proof of
  zero-recompile — while flagging host callbacks, silent dtype
  promotions, and non-donated hot-path buffers.

Findings can be suppressed inline (``# repro: ignore[rule]``) or
grandfathered in ``src/repro/analysis/baseline.json`` with a
justification. CLI::

    python -m repro.analysis --strict src/repro
"""
from repro.analysis.engine import (Finding, lint_paths, load_baseline,
                                   run_analysis, split_baselined)

__all__ = ["Finding", "lint_paths", "load_baseline", "run_analysis",
           "split_baselined"]
