"""Trace-safety lint rule (DESIGN.md §analysis).

Finds host/trace boundary violations with a two-step file analysis:

1. **Region finding** — which function defs are *traced regions*
   (their bodies run under a jax trace)? A def is traced when it is

   * decorated with ``jit``/``pjit`` (bare, attribute, or via
     ``functools.partial(jax.jit, ...)``),
   * passed by name (or as a lambda) to a tracing combinator —
     ``jit``, ``scan``, ``cond``, ``while_loop``, ``fori_loop``,
     ``switch``, ``vmap``, ``pmap``, ``grad``, ``shard_map``,
     ``pallas_call``, ``checkpoint``/``remat``, ``make_jaxpr``,
     ``eval_shape`` — anywhere in the same file,
   * returned from a ``make_*``/``build_*`` factory (the repo's
     ``make_eps_fn`` / ``make_packed_step_fn`` idiom: the factory's
     caller jits the result), or
   * nested inside a traced region.

2. **Taint tracking** — inside a traced region, every parameter (except
   ``self``/``cls``/``cfg``/``config``) and every value derived from one
   (or from any ``jnp.``/``jax.`` call) is *traced*. Shape-space
   attributes (``.shape``/``.ndim``/``.dtype``/``.size``) escape the
   taint. The rule then flags the classic leaks: ``int()``/``float()``/
   ``bool()``/``.item()`` (host sync), ``if``/``while`` on a *derived*
   traced expression (branching on a bare parameter is the standard
   static-flag idiom and stays legal), ``for`` over a traced value
   (graph unrolling), ``len()`` (warning — shape-static today),
   f-strings, and host ``np.`` calls on traced arguments.

Outside traced regions the ``hot-host-sync`` rule applies: a
``float()``/``int()``/``bool()``/``.item()`` of a ``jnp.``-derived value
inside a ``for``/``while`` loop is a blocking device->host transfer per
iteration — exactly the probe-loop pathology ``core/adaptive.py`` had.

Heuristics err toward silence (bare-parameter branches, shape
attributes, ``is None`` checks are all exempt); what they still
over-flag is handled by ``# repro: ignore[rule]`` with a justification.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.engine import Finding

#: `def f(...):  # repro: traced` force-marks a def as a traced region —
#: for functions only ever CALLED from inside jit (dit_forward and
#: friends), which no file-local heuristic can see.
_TRACED_MARK = re.compile(r"#\s*repro:\s*traced\b")

TRACING_CALLS = {
    "jit", "pjit", "make_jaxpr", "eval_shape", "scan", "cond", "while_loop",
    "fori_loop", "switch", "associative_scan", "vmap", "pmap", "grad",
    "value_and_grad", "shard_map", "checkpoint", "remat", "pallas_call",
    "custom_jvp", "custom_vjp",
}
STATIC_PARAM_NAMES = {"self", "cls", "cfg", "config", "parallel", "mesh"}

#: annotation outer types that make a parameter a *host container* — the
#: repo passes phase lists / group tuples / per-group array lists as
#: Python structures that stay static under trace (lengths, indices and
#: iteration over them are host work even though elements may be arrays)
CONTAINER_ANNS = ("Sequence", "List", "Tuple", "Dict", "Mapping",
                  "Iterable", "tuple", "list", "dict")
SHAPE_ATTRS = {"shape", "ndim", "dtype", "size", "sharding", "at"}
ARRAY_MODULES = {"jnp", "jax", "lax", "pl", "plgpu", "pltpu"}
HOST_NP_NAMES = {"np", "numpy", "onp"}
FACTORY_PREFIXES = ("make_", "build_")


def _call_name(func: ast.AST) -> str:
    """Last dotted component of a call target ('jax.lax.scan' -> 'scan')."""
    while isinstance(func, ast.Attribute):
        if isinstance(func.value, ast.Call):   # partial(jax.jit, ...)(f)
            break
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return ""


def _root_name(node: ast.AST) -> str:
    while isinstance(node, (ast.Attribute, ast.Subscript)):
        node = node.value
    return node.id if isinstance(node, ast.Name) else ""


class _RegionFinder(ast.NodeVisitor):
    """Collect function defs and decide which are traced regions."""

    def __init__(self):
        self.defs: List[Tuple[ast.AST, Optional[ast.AST]]] = []
        self.traced_names: Set[str] = set()
        self._stack: List[ast.AST] = []

    def _visit_def(self, node):
        self.defs.append((node, self._stack[-1] if self._stack else None))
        self._stack.append(node)
        self.generic_visit(node)
        self._stack.pop()

    visit_FunctionDef = _visit_def
    visit_AsyncFunctionDef = _visit_def
    visit_Lambda = _visit_def

    def visit_Call(self, node: ast.Call):
        if _call_name(node.func) in TRACING_CALLS:
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name):
                    self.traced_names.add(arg.id)
                elif isinstance(arg, ast.Lambda):
                    arg._repro_traced = True       # mark the lambda itself
        # functools.partial(body_fn, ...) fed to a combinator — conservative:
        # names inside partial() calls count too
        if _call_name(node.func) == "partial":
            for arg in node.args:
                if isinstance(arg, ast.Name) \
                        and arg.id not in TRACING_CALLS:
                    self.traced_names.add(arg.id)
        self.generic_visit(node)

    def visit_Return(self, node: ast.Return):
        # `return step` inside make_*/build_* factories: `step` is traced
        if isinstance(node.value, ast.Name) and self._stack:
            fn = self._stack[-1]
            name = getattr(fn, "name", "")
            if name.startswith(FACTORY_PREFIXES) or name.endswith("_runner"):
                self.traced_names.add(node.value.id)
        self.generic_visit(node)


def _is_traced_decorator(dec: ast.AST) -> bool:
    if isinstance(dec, ast.Call):
        return (_call_name(dec.func) in TRACING_CALLS
                or (_call_name(dec.func) == "partial" and dec.args
                    and _call_name(dec.args[0]) in TRACING_CALLS))
    return _call_name(dec) in TRACING_CALLS


def find_traced_regions(tree: ast.AST,
                        marked_lines: Optional[Set[int]] = None
                        ) -> List[ast.AST]:
    """All function/lambda nodes whose bodies run under a jax trace.
    ``marked_lines``: line numbers carrying a ``# repro: traced`` mark."""
    marked_lines = marked_lines or set()
    finder = _RegionFinder()
    finder.visit(tree)
    traced: Set[int] = set()
    by_node = {id(n): (n, parent) for n, parent in finder.defs}

    def _touches_jax(node) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Name) and sub.id in ARRAY_MODULES:
                return True
        return False

    def is_traced(node) -> bool:
        if id(node) in traced:
            return True
        if getattr(node, "_repro_traced", False):
            return True
        if getattr(node, "lineno", -1) in marked_lines:
            return True
        name = getattr(node, "name", None)
        if name is not None and name in finder.traced_names:
            # name-based evidence (factory returns, combinator args) is
            # weak — require the body to actually touch jax, so host-side
            # factories (data loaders etc.) stay out of scope
            return _touches_jax(node)
        for dec in getattr(node, "decorator_list", []):
            if _is_traced_decorator(dec):
                return True
        return False

    # propagate: nested defs inside traced regions are traced
    changed = True
    while changed:
        changed = False
        for node, parent in finder.defs:
            if id(node) in traced:
                continue
            if is_traced(node) or (parent is not None
                                   and id(parent) in traced):
                traced.add(id(node))
                changed = True
    return [by_node[i][0] for i in traced]


# ---------------------------------------------------------------------------
# Taint analysis inside one region

class _Taint:
    """Set-of-names taint with derived-expression queries."""

    def __init__(self, tainted: Set[str]):
        self.names = set(tainted)

    def expr(self, node: ast.AST) -> bool:
        """Is this expression's VALUE traced?"""
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Attribute):
            if node.attr in SHAPE_ATTRS:
                return False
            return self.expr(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value)
        if isinstance(node, (ast.Tuple, ast.List)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        if isinstance(node, ast.BinOp):
            return self.expr(node.left) or self.expr(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.expr(v) for v in node.values)
        if isinstance(node, ast.IfExp):
            return (self.expr(node.body) or self.expr(node.orelse)
                    or self.expr(node.test))
        if isinstance(node, ast.Compare):
            # `x is None` / isinstance-style structure checks are host-legal
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return False
            return (self.expr(node.left)
                    or any(self.expr(c) for c in node.comparators))
        if isinstance(node, ast.Call):
            name = _call_name(node.func)
            root = _root_name(node.func)
            if name in ("len", "isinstance", "hasattr", "getattr", "range",
                        "enumerate", "zip", "sorted", "type", "id", "print"):
                return False
            if name in ("int", "float", "bool"):
                return False              # result is host (flagged elsewhere)
            if root in ARRAY_MODULES:
                return True               # jnp./jax. results are traced
            if isinstance(node.func, ast.Attribute) \
                    and self.expr(node.func.value):
                return True               # method of a traced value
            return any(self.expr(a) for a in node.args) \
                or any(self.expr(kw.value) for kw in node.keywords)
        if isinstance(node, (ast.GeneratorExp, ast.ListComp, ast.SetComp)):
            return any(self.expr(g.iter) for g in node.generators) \
                or self.expr(getattr(node, "elt", node))
        if isinstance(node, ast.JoinedStr):
            return any(self.expr(v.value) for v in node.values
                       if isinstance(v, ast.FormattedValue))
        return False

    def assign(self, target: ast.AST, tainted: bool) -> None:
        if isinstance(target, ast.Name):
            (self.names.add if tainted else self.names.discard)(target.id)
        elif isinstance(target, (ast.Tuple, ast.List)):
            for e in target.elts:
                self.assign(e, tainted)
        elif isinstance(target, ast.Starred):
            self.assign(target.value, tainted)


def _ann_is_static(ann: Optional[ast.AST]) -> bool:
    """Annotation says this parameter is host-side data: a container
    (Sequence/Tuple/... — element arrays are traced, but the container
    itself, its length and indices are static) or a non-Array scalar /
    config type. No annotation, ``Any``, or an Array-bearing non-container
    annotation keeps the parameter tainted."""
    if ann is None:
        return False
    text = ast.unparse(ann)
    while text.startswith("Optional["):
        text = text[len("Optional["):-1]
    if text.split("[", 1)[0].split(".")[-1] in CONTAINER_ANNS:
        return True
    return "Array" not in text and "Any" not in text


def _params(fn: ast.AST, tainted_only: bool = False) -> List[str]:
    a = fn.args
    pairs = [(p.arg, getattr(p, "annotation", None))
             for p in a.posonlyargs + a.args + a.kwonlyargs]
    if a.vararg:
        pairs.append((a.vararg.arg, getattr(a.vararg, "annotation", None)))
    if a.kwarg:
        pairs.append((a.kwarg.arg, getattr(a.kwarg, "annotation", None)))
    if tainted_only:
        return [n for n, ann in pairs if not _ann_is_static(ann)]
    return [n for n, _ in pairs]


class _RegionChecker(ast.NodeVisitor):
    """Flag trace-safety violations inside ONE traced region (does not
    descend into nested defs — they are checked as their own regions)."""

    def __init__(self, path: str, symbol: str, region: ast.AST,
                 hot_loops: bool = False, taint: Optional[_Taint] = None):
        self.path = path
        self.symbol = symbol
        self.region = region
        self.hot = hot_loops        # hot-host-sync mode (host code in loops)
        self.loop_depth = 0
        self.findings: List[Finding] = []
        if taint is not None:
            self.taint = taint
        elif hot_loops:
            self.taint = _Taint(set())   # only jnp-derived values taint
        else:
            self.taint = _Taint(
                {p for p in _params(region, tainted_only=True)
                 if p not in STATIC_PARAM_NAMES}
                if isinstance(region, (ast.FunctionDef,
                                       ast.AsyncFunctionDef, ast.Lambda))
                else set())

    def _emit(self, rule: str, severity: str, node: ast.AST, msg: str):
        self.findings.append(Finding(rule, severity, self.path,
                                     getattr(node, "lineno", 0), msg,
                                     self.symbol))

    def run(self) -> List[Finding]:
        body = self.region.body
        if isinstance(body, ast.AST):          # lambda
            body = [ast.Expr(value=body)]
        # two passes so taint assigned late in a loop body is seen by
        # earlier statements on the second sweep
        for _ in range(2):
            self.findings = []
            self.loop_depth = 0
            for stmt in body:
                self.visit(stmt)
        return self.findings

    # -- statements -------------------------------------------------------

    def visit_FunctionDef(self, node):   # nested defs: own region
        return

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node):
        return

    def visit_Assign(self, node: ast.Assign):
        self.visit(node.value)
        t = self.taint.expr(node.value)
        for target in node.targets:
            self.taint.assign(target, t)

    def visit_AugAssign(self, node: ast.AugAssign):
        self.visit(node.value)
        if self.taint.expr(node.value):
            self.taint.assign(node.target, True)

    def visit_AnnAssign(self, node: ast.AnnAssign):
        if node.value is not None:
            self.visit(node.value)
            self.taint.assign(node.target, self.taint.expr(node.value))

    def visit_For(self, node: ast.For):
        if not self.hot and self.taint.expr(node.iter):
            self._emit("trace-python-loop", "warning", node,
                       "for-loop over a traced value unrolls into the "
                       "graph; use lax.scan / lax.fori_loop")
            self.taint.assign(node.target, True)
        self.visit(node.iter)
        self.loop_depth += 1
        for stmt in node.body + node.orelse:
            self.visit(stmt)
        self.loop_depth -= 1

    def visit_While(self, node: ast.While):
        self._check_branch(node, "while")
        self.visit(node.test)
        self.loop_depth += 1
        for stmt in node.body + node.orelse:
            self.visit(stmt)
        self.loop_depth -= 1

    def visit_If(self, node: ast.If):
        self._check_branch(node, "if")
        self.visit(node.test)
        for stmt in node.body + node.orelse:
            self.visit(stmt)

    def _check_branch(self, node, kw: str):
        if self.hot:
            return
        test = node.test
        # bare-parameter flags (`if guided:` / `if not cached:`) are the
        # standard static-switch idiom — only DERIVED traced tests leak
        bare = isinstance(test, ast.Name) or (
            isinstance(test, ast.UnaryOp) and isinstance(test.op, ast.Not)
            and isinstance(test.operand, ast.Name))
        if not bare and self.taint.expr(test):
            self._emit("trace-python-branch", "error", node,
                       f"Python `{kw}` on a traced value inside a traced "
                       f"region; use lax.cond / lax.select / jnp.where")

    # -- expressions ------------------------------------------------------

    def visit_Call(self, node: ast.Call):
        name = _call_name(node.func)
        root = _root_name(node.func)
        arg_tainted = (any(self.taint.expr(a) for a in node.args)
                       or any(self.taint.expr(kw.value)
                              for kw in node.keywords))
        if name in ("int", "float", "bool") and node.args and arg_tainted:
            self._flag_sync(node, f"{name}() concretizes a traced value")
        elif name == "item" and isinstance(node.func, ast.Attribute) \
                and self.taint.expr(node.func.value):
            self._flag_sync(node, ".item() concretizes a traced value")
        elif not self.hot and name == "len" and node.args \
                and self.taint.expr(node.args[0]):
            self._emit("trace-len", "warning", node,
                       "len() of a traced value (use .shape[0]; becomes a "
                       "host sync under dynamic shapes)")
        elif not self.hot and root in HOST_NP_NAMES and arg_tainted:
            self._emit("trace-host-np", "error", node,
                       f"host numpy call `{ast.unparse(node.func)}` on "
                       f"traced values inside a traced region; use jnp")
        self.generic_visit(node)

    def _flag_sync(self, node, what: str):
        if self.hot:
            if self.loop_depth > 0:
                self._emit("hot-host-sync", "error", node,
                           f"{what} inside a host loop — one blocking "
                           f"device->host transfer per iteration; batch "
                           f"or hoist it")
        else:
            self._emit("trace-host-cast", "error", node,
                       f"{what} inside a traced region (host sync / "
                       f"ConcretizationTypeError)")

    def visit_JoinedStr(self, node: ast.JoinedStr):
        if not self.hot and self.taint.expr(node):
            self._emit("trace-fstring", "error", node,
                       "f-string formats a traced value (concretizes; "
                       "use jax.debug.print)")
        self.generic_visit(node)


# ---------------------------------------------------------------------------
# The rule object

class TraceSafetyRule:
    """Source rule: trace-safety + hot-loop host syncs for one file."""

    name = "trace-safety"

    def check(self, path: str, tree: ast.AST, text: str) -> List[Finding]:
        findings: List[Finding] = []
        marked = {i for i, line in enumerate(text.splitlines(), start=1)
                  if _TRACED_MARK.search(line)}
        regions = find_traced_regions(tree, marked)
        region_ids = {id(r) for r in regions}
        for region in regions:
            symbol = getattr(region, "name", "<lambda>")
            findings.extend(_RegionChecker(path, symbol, region).run())
        # hot-host-sync over every NON-traced function body
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                    and id(node) not in region_ids:
                findings.extend(
                    _RegionChecker(path, node.name, node,
                                   hot_loops=True).run())
        return findings
