"""Shared padding helpers.

Every subsystem that feeds fixed-shape executables needs the same two
moves — round a count up to a bucket boundary and pad an array along one
axis to a target length — plus the serving-specific KV-cache pad. They
used to be re-implemented inline in ``launch/serve.py`` (LM decode),
``distributed/partition.py`` (pad-to-divisible token shardings), the
distributed engine's pad/shard plumbing, and now the serving batcher;
this module is the single home.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def round_up_to_multiple(n: int, multiple: int) -> int:
    """Smallest multiple of ``multiple`` that is >= ``n``."""
    if multiple < 1:
        raise ValueError(f"multiple must be >= 1, got {multiple}")
    return -(-n // multiple) * multiple


def pad_to(x: jax.Array, target: int, axis: int, value: float = 0.0
           ) -> jax.Array:
    """Pad ``x`` along ``axis`` up to length ``target`` (no-op if equal)."""
    cur = x.shape[axis]
    if cur > target:
        raise ValueError(f"cannot pad axis {axis} of length {cur} down to "
                         f"{target}")
    if cur == target:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - cur)
    return jnp.pad(x, pad, constant_values=value)


def pad_kv_cache(cache: Any, seq_len: int, extra: int) -> Any:
    """Pad every KV-cache leaf (``[..., S, H, hd]`` with ``S == seq_len``)
    by ``extra`` positions along the sequence axis so decode steps can
    write past the prefill length. Non-cache leaves pass through."""
    def pad_seq(x):
        if hasattr(x, "ndim") and x.ndim >= 4 and x.shape[-3] == seq_len:
            return pad_to(x, seq_len + extra, axis=x.ndim - 3)
        return x
    return jax.tree.map(pad_seq, cache)
