"""Elastic scaling: replan the mesh for a changed device count and restore
the latest checkpoint with the new shardings (the checkpointer already
loads to host and ``device_put``s onto the new mesh)."""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np


def plan_mesh_shape(n_devices: int, model_parallel: int = 0
                    ) -> Tuple[int, int]:
    """(data, model) factors for an arbitrary surviving device count.

    Keeps model-parallel width if it still divides; otherwise the largest
    power-of-two divisor ≤ the previous width.
    """
    if model_parallel <= 0:
        model_parallel = 1
    while model_parallel > 1 and n_devices % model_parallel != 0:
        model_parallel //= 2
    return n_devices // model_parallel, model_parallel


def make_elastic_mesh(n_devices: Optional[int] = None,
                      model_parallel: int = 1):
    devs = jax.devices()
    n = n_devices if n_devices is not None else len(devs)
    data, model = plan_mesh_shape(n, model_parallel)
    return jax.make_mesh((data, model), ("data", "model"),
                         devices=devs[:data * model])


def elastic_restore(checkpointer, cfg, mesh, profile: str = "auto",
                    step: Optional[int] = None):
    """Restore a checkpoint onto a (possibly different) mesh."""
    from jax.sharding import NamedSharding
    from repro.models import dit as dit_mod
    from repro.models import lm
    from repro.models.common import spec_tree
    from repro.runtime import sharding as shd

    rules = shd.rules_for(cfg, mesh, profile)
    sizes = shd.axis_sizes(mesh)
    schema = (dit_mod.dit_schema(cfg) if cfg.family == "dit"
              else lm.lm_schema(cfg))
    specs = spec_tree(schema, rules, sizes)
    shardings = jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
    state, extra = checkpointer.restore(step)
    if "params" in state:
        state["params"] = jax.tree.map(
            lambda a, s: jax.device_put(a, s), state["params"], shardings)
    return state, extra
