"""Straggler mitigation for the synchronous-SPMD data path.

In a jit/pjit step every chip waits for the slowest participant, so the
lever is *upstream of the step*: detect persistently slow data workers and
rebalance their shards (or schedule backup fetches). The detector keeps an
EWMA of per-worker step times and flags anything beyond
``threshold ×`` the median; the balancer reassigns shard counts inversely
proportional to observed speed.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import numpy as np


@dataclasses.dataclass
class StragglerReport:
    step: int
    stragglers: List[int]
    median_ms: float
    worst_ms: float


class StragglerDetector:
    def __init__(self, n_workers: int, threshold: float = 2.0,
                 ewma: float = 0.7):
        self.n = n_workers
        self.threshold = threshold
        self.ewma = ewma
        self.times = np.zeros(n_workers)
        self.seen = np.zeros(n_workers, bool)

    def record(self, worker_id: int, ms: float):
        if self.seen[worker_id]:
            self.times[worker_id] = (self.ewma * self.times[worker_id]
                                     + (1 - self.ewma) * ms)
        else:
            self.times[worker_id] = ms
            self.seen[worker_id] = True

    def report(self, step: int) -> StragglerReport:
        active = self.times[self.seen]
        med = float(np.median(active)) if active.size else 0.0
        stragglers = [i for i in range(self.n)
                      if self.seen[i] and med > 0
                      and self.times[i] > self.threshold * med]
        worst = float(self.times[self.seen].max()) if active.size else 0.0
        return StragglerReport(step, stragglers, med, worst)


def rebalance_shards(n_shards: int, worker_times_ms: np.ndarray
                     ) -> List[int]:
    """Assign shard counts ∝ 1/time so the slowest worker stops gating the
    step. Always ≥1 shard per worker; deterministic largest-remainder split."""
    speed = 1.0 / np.maximum(np.asarray(worker_times_ms, float), 1e-6)
    frac = speed / speed.sum() * n_shards
    base = np.maximum(np.floor(frac).astype(int), 1)
    while base.sum() > n_shards:
        base[np.argmax(base)] -= 1
    rem = n_shards - base.sum()
    order = np.argsort(-(frac - np.floor(frac)))
    for i in range(rem):
        base[order[i % len(order)]] += 1
    return base.tolist()


def backup_request_schedule(pending_ms, deadline_ms: float) -> List[int]:
    """Hedged-request policy: workers predicted to miss the step deadline
    get a backup fetch scheduled on the fastest idle worker. Accepts any
    array-like (the fleet health layer passes plain host lists)."""
    pending = np.asarray(pending_ms, float)
    return [int(i) for i in np.nonzero(pending > deadline_ms)[0]]
