"""Fault tolerance for 1000+-node runs: heartbeat failure detection,
checkpoint/restart supervision, and elastic rescaling.

This container has one real device, so node failures are *simulated* via an
injectable clock and fault hooks — the control logic (detection thresholds,
restart policy, rescale planning) is the part that transfers to a real
cluster, where heartbeats arrive over the coordination service.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class WorkerState:
    worker_id: int
    last_heartbeat: float
    alive: bool = True
    incarnation: int = 0


class HeartbeatMonitor:
    """Declares a worker dead after ``timeout_s`` without a heartbeat."""

    def __init__(self, n_workers: int, timeout_s: float = 60.0,
                 clock: Callable[[], float] = time.monotonic):
        self.clock = clock
        self.timeout_s = timeout_s
        now = clock()
        self.workers = {i: WorkerState(i, now) for i in range(n_workers)}

    def heartbeat(self, worker_id: int, at: Optional[float] = None):
        """Record a heartbeat, optionally with the sender's send-time.

        Beats may arrive duplicated or out of order (delayed delivery,
        clock skew): ``last_heartbeat`` is monotone under ``max`` so a
        stale beat landing after a fresher one can never move the stamp
        backwards and spuriously age a live worker toward its timeout.
        """
        w = self.workers[worker_id]
        t = self.clock() if at is None else at
        w.last_heartbeat = max(w.last_heartbeat, t)
        if not w.alive:           # worker came back (restarted)
            w.alive = True
            w.incarnation += 1

    def check(self) -> List[int]:
        """Returns newly-dead worker ids."""
        now = self.clock()
        dead = []
        for w in self.workers.values():
            if w.alive and now - w.last_heartbeat > self.timeout_s:
                w.alive = False
                dead.append(w.worker_id)
        return dead

    @property
    def alive_count(self) -> int:
        return sum(w.alive for w in self.workers.values())


@dataclasses.dataclass
class RecoveryEvent:
    step: int
    kind: str                 # 'failure' | 'restart' | 'rescale'
    detail: str


class TrainingSupervisor:
    """Checkpoint/restart + elastic-rescale policy around a step function.

    The driver calls ``on_step``; injected faults raise ``WorkerFailure``;
    the supervisor restores from the last committed checkpoint (possibly on
    a smaller device count — elastic) and replays.
    """

    def __init__(self, checkpointer, monitor: HeartbeatMonitor,
                 checkpoint_every: int = 50,
                 rescale_plan: Optional[Callable[[int], Any]] = None):
        self.ckpt = checkpointer
        self.monitor = monitor
        self.checkpoint_every = checkpoint_every
        self.rescale_plan = rescale_plan
        self.events: List[RecoveryEvent] = []

    def maybe_checkpoint(self, step: int, state: Any):
        if step % self.checkpoint_every == 0:
            self.ckpt.save(step, state)

    def handle_failure(self, step: int, dead: List[int]
                       ) -> Tuple[int, Any, Any]:
        """Returns (restart_step, restored_state, new_layout)."""
        self.events.append(RecoveryEvent(step, "failure",
                                         f"workers {dead} lost"))
        self.ckpt.wait()
        restart = self.ckpt.latest_step()
        if restart is None:
            raise RuntimeError("failure before first checkpoint")
        layout = None
        if self.rescale_plan is not None:
            layout = self.rescale_plan(self.monitor.alive_count)
            self.events.append(RecoveryEvent(
                step, "rescale",
                f"alive={self.monitor.alive_count} layout={layout}"))
        state, _ = self.ckpt.restore(restart)
        self.events.append(RecoveryEvent(restart, "restart",
                                         f"resumed from step {restart}"))
        return restart, state, layout


class WorkerFailure(Exception):
    def __init__(self, worker_ids: List[int]):
        super().__init__(f"workers failed: {worker_ids}")
        self.worker_ids = worker_ids


def run_with_recovery(train_fn: Callable[[int, Any], Any], state: Any,
                      n_steps: int, supervisor: TrainingSupervisor,
                      fault_hook: Optional[Callable[[int], Optional[List[int]]]]
                      = None) -> Tuple[Any, List[RecoveryEvent]]:
    """Drive training with simulated failures.

    ``fault_hook(step)`` may return worker ids to kill at that step.
    """
    step = 0
    supervisor.maybe_checkpoint(0, state)
    while step < n_steps:
        if fault_hook is not None:
            dead = fault_hook(step)
            if dead:
                for w in dead:
                    supervisor.monitor.workers[w].alive = False
                step, state, _ = supervisor.handle_failure(step, dead)
                # simulated repair: workers rejoin next step
                for w in dead:
                    supervisor.monitor.heartbeat(w)
                continue
        state = train_fn(step, state)
        step += 1
        supervisor.maybe_checkpoint(step, state)
    supervisor.ckpt.wait()
    return state, supervisor.events
