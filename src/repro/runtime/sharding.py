"""Logical→mesh sharding rules and helpers.

Baseline profile ``fsdp2d``: weights 2D-sharded over ('data','model') —
'embed'-type dims over the data axis (FSDP/ZeRO-3 storage; XLA inserts the
per-layer all-gathers) and 'mlp'/'heads'/'vocab'/'expert' dims megatron-style
over the model axis. Optimizer state inherits the same specs, so it is fully
sharded ("ZeRO") with no extra machinery.

``tp_only``: weights sharded over 'model' only (replicated across data) —
lower collective volume per step, higher per-device bytes. Used by the perf
pass for serving cells where weights fit.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

PROFILES = ("auto", "fsdp2d", "fsdp2d_sp", "tp_only", "dp")

# Named mesh axis used by the sequence-parallel inference engine
# (repro.distributed): activations scatter their token dim over it; weights
# never map a dim onto it (replicated across the axis).
SEQ_AXIS = "seq"

# Models whose bf16 params fit comfortably replicated skip FSDP (wrapping
# threshold, like torch FSDP's min_num_params): pure DP avoids pointless
# per-layer weight all-gathers on sub-3B models.
DP_PARAM_THRESHOLD = 3e9


def resolve_profile(cfg: "ModelConfig", profile: str) -> str:
    if profile != "auto":
        return profile
    return "dp" if cfg.num_params() < DP_PARAM_THRESHOLD else "fsdp2d"


def base_profile(profile: str) -> str:
    """Strip feature suffixes (_sp sequence-parallel, _kvq int8 KV cache) —
    the sharding rules are identical."""
    for suf in ("_sp", "_kvq"):
        profile = profile.replace(suf, "")
    return profile


def dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Axes used for data parallelism (batch sharding)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_sizes(mesh: Mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def rules_for(cfg: ModelConfig, mesh: Mesh, profile: str = "auto"
              ) -> Dict[str, Any]:
    """Logical axis rules. Non-divisible shardings are dropped later by
    ``spec_tree(axis_sizes=...)``.

    Every profile also carries the activation-side ``tokens`` rule: on
    meshes with a ``'seq'`` axis the sequence-parallel engine scatters the
    token dim over it (weights never map onto 'seq' — they stay replicated
    across that axis)."""
    profile = base_profile(resolve_profile(cfg, profile))
    tokens = SEQ_AXIS if SEQ_AXIS in mesh.axis_names else None
    if profile == "dp":            # replicated weights, batch-sharded data
        rules = {k: None for k in ("embed", "mlp", "heads", "kv_heads",
                                   "vocab", "expert", "layers")}
        rules["tokens"] = tokens
        return rules
    fsdp = dp_axes(mesh) if profile == "fsdp2d" else None
    rules: Dict[str, Any] = {
        "embed": fsdp,
        "mlp": "model",
        "heads": "model",
        "kv_heads": "model",
        "vocab": "model",
        "expert": "model",
        "layers": None,
        "tokens": tokens,
    }
    return rules


def batch_spec(batch: int, mesh: Mesh) -> P:
    """Shard batch over as many data axes as divide it."""
    axes = []
    prod = 1
    sizes = axis_sizes(mesh)
    for a in dp_axes(mesh):
        prod *= sizes[a]
        if batch % prod == 0:
            axes.append(a)
        else:
            prod //= sizes[a]
    return P(tuple(axes) if axes else None)


def seq_axes_for_cache(batch: int, mesh: Mesh) -> Tuple[Any, Any]:
    """(batch_sharding, seq_sharding) for KV caches: batch over data axes when
    divisible, sequence over the model axis (context-parallel decode); when
    batch==1 the idle data axes also shard the sequence."""
    sizes = axis_sizes(mesh)
    b_axes, s_axes = [], []
    prod = 1
    for a in dp_axes(mesh):
        prod *= sizes[a]
        if batch % prod == 0:
            b_axes.append(a)
        else:
            prod //= sizes[a]
            s_axes.append(a)
    s_axes.append("model")
    return (tuple(b_axes) if b_axes else None,
            tuple(s_axes) if len(s_axes) > 1 else s_axes[0])


def token_spec(batch: int, mesh: Mesh) -> P:
    """[B, N, ...] activation spec for the sequence-parallel engine: batch
    over whichever data axes divide it, tokens over the 'seq' axis."""
    b = batch_spec(batch, mesh)[0]
    seq = SEQ_AXIS if SEQ_AXIS in mesh.axis_names else None
    return P(b, seq)


def named(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def shard_tree(mesh: Mesh, spec_pytree: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_pytree,
        is_leaf=lambda x: isinstance(x, P))


def _ambient_axis_names() -> Tuple[str, ...]:
    """Axis names of the ambient mesh, () if none is set. Handles the jax
    0.4.x API (no public get_abstract_mesh; ``with mesh:`` sets the
    thread-local physical mesh) and the 0.5+ AbstractMesh API."""
    get = getattr(jax.sharding, "get_abstract_mesh", None)
    if get is not None:
        mesh = get()
        if mesh is None or mesh.empty:
            return ()
        return tuple(mesh.axis_names)
    from jax._src import mesh as mesh_lib
    mesh = mesh_lib.get_abstract_mesh()
    if mesh and getattr(mesh, "axis_names", None):
        return tuple(mesh.axis_names)
    phys = mesh_lib.thread_resources.env.physical_mesh
    if phys is not None and not phys.empty:
        return tuple(phys.axis_names)
    return ()


def constrain(x: jax.Array, spec: P) -> jax.Array:
    """with_sharding_constraint if an ambient mesh is set; no-op otherwise
    (keeps single-device tests mesh-free)."""
    names = set(_ambient_axis_names())
    if not names:
        return x
    flat = []
    for part in spec:
        if part is None:
            flat.append(None)
        elif isinstance(part, str):
            flat.append(part if part in names else None)
        else:
            kept = tuple(a for a in part if a in names)
            flat.append(kept if kept else None)
    return jax.lax.with_sharding_constraint(x, P(*flat))
