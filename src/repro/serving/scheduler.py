"""Iteration-level continuous-batching engine (DESIGN.md §serving).

The engine keeps many in-flight requests at *different* denoise steps
and budgets and advances a packed subset of them every iteration:

* **join/leave mid-flight** — new requests enter between any two engine
  steps; finished latents leave without draining anyone else;
* **token packing** — each step's batch is composed token-wise from the
  bucket menu (``serving.batcher``): weak-phase requests contribute
  ``H*W/ratio^2`` tokens, full-mode requests the full grid, packed into
  fixed-capacity rows with segment-id masking (``core.packing``);
* **compile-once** — all executables come from
  ``FlexiPipeline.packed_step``'s runner cache, keyed by the static
  layout only, so steady-state serving never recompiles
  (``cache_stats()`` proves it);
* **SLA awareness** — with ``policy='edf'`` admission and step priority
  follow deadlines; with ``policy='degrade'`` the
  :class:`~repro.serving.controller.BudgetController` demotes queued
  requests to the highest budget level the current arrival rate
  sustains.

Requests are served bit-identically to a standalone
``FlexiPipeline.sample(plan, 1, request.key)`` call: same prior draw,
same per-phase solver-key derivation, same guidance combine.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import ledger as cache_ledger
from repro.cache import policy as cache_policy
from repro.cache.policy import CacheSpec
from repro.cache.store import CacheStore, TransientAllocationError
from repro.core.scheduler import dit_nfe_flops
from repro.diffusion import schedule as sch
from repro.models import dit as dit_mod
from repro.pipeline.packed import PackLayout
from repro.pipeline.pipeline import FlexiPipeline
from repro.pipeline.plan import SamplingPlan
from repro.serving.batcher import BucketMenu
from repro.serving.controller import BudgetController
from repro.serving.metrics import RequestRecord, ServingMetrics
from repro.serving.queue import Request, RequestQueue
from repro.telemetry import TapSample, Telemetry
from repro.telemetry.profile import packed_key as profile_packed_key
from repro.telemetry.trace import REQUEST_PID

ENGINE_POLICIES = ("fifo", "edf", "degrade")


@dataclasses.dataclass(frozen=True)
class LevelPlan:
    """One budget level of the menu, fully resolved for step-wise play."""
    level: float
    plan: SamplingPlan
    ts: np.ndarray               # descending timestep ladder [T]
    t_prev: np.ndarray           # ts shifted, -1 terminated [T]
    modes: np.ndarray            # per-step patch mode [T]
    run_len: np.ndarray          # same-mode steps remaining (incl. self) [T]
    flops: float                 # analytic per-request denoising FLOPs


@dataclasses.dataclass
class InFlight:
    req: Request
    lp: LevelPlan
    x_src: jax.Array             # [k, F, H, W, C] batch holding the latent
    x_row: int                   # ... at this row (kept unsliced so step
    #                              assembly can reuse whole output batches)
    keys: np.ndarray             # [T, 2] per-step solver keys (host-side)
    admit: float
    seq: int
    step: int = 0
    # cross-step activation cache (DESIGN.md §cache): this request's OWN
    # staleness clock over its ladder, plus its slot in the engine's
    # CacheStore (slot follows the request across bucket migrations;
    # forced refreshes — join, phase switch, eviction — flip the mask
    # in place so the retire-time histogram reflects reality)
    refresh_mask: Optional[np.ndarray] = None
    cache_slot: int = -1
    cache_mode: int = -1

    @property
    def x(self) -> jax.Array:
        return self.x_src[self.x_row]

    @property
    def mode(self) -> int:
        return int(self.lp.modes[self.step])

    @property
    def done(self) -> bool:
        return self.step >= len(self.lp.ts)


@dataclasses.dataclass
class ServedResult:
    request: Request
    x0: jax.Array
    budget_served: float
    record: RequestRecord
    # measured per-request served cost (telemetry.attribution.ServedCost)
    # when the engine runs with profiling telemetry; None otherwise
    cost: Optional[Any] = None


class ServingEngine:
    """Continuous-batching DiT serving on top of a FlexiPipeline.

    >>> engine = ServingEngine(pipe, plans, max_tokens_per_step=1024)
    >>> engine.submit(cond=3, budget=0.6)
    >>> results = engine.run()          # drain queue + in-flight
    """

    def __init__(self, pipe: FlexiPipeline,
                 plans: Dict[float, SamplingPlan], *,
                 max_tokens_per_step: Optional[int] = None,
                 policy: str = "fifo",
                 clock: Optional[Callable[[], float]] = None,
                 controller: Optional[BudgetController] = None,
                 max_inflight: Optional[int] = None,
                 base_key: Optional[jax.Array] = None,
                 steps_per_dispatch: int = 8,
                 menu: Optional[BucketMenu] = None,
                 allow_cold: bool = True,
                 cache: Optional[CacheSpec] = None,
                 precapture_small: int = 0,
                 telemetry: Optional[Telemetry] = None,
                 faults: Optional[Any] = None,
                 quarantine: Optional[bool] = None,
                 self_heal: bool = True,
                 max_retries: int = 2,
                 expire_queued: bool = False,
                 cache_integrity: bool = False):
        if policy not in ENGINE_POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: "
                             f"{ENGINE_POLICIES}")
        # resilience (DESIGN.md §resilience): ``faults`` is a per-replica
        # fault-injection facade (resilience.faults.ReplicaFaults); every
        # consultation of it is guarded by ``is not None`` so a disarmed
        # engine runs the exact pre-resilience device-op sequence
        # (lint-enforced: resilience-armed-guard). Quarantine — drop
        # non-finite latents and re-enqueue the request at the most
        # powerful menu level — defaults to armed-only; ``self_heal``
        # re-enqueues locally, the fleet turns it off and escalates
        # through the router instead.
        self._faults = faults
        self._quarantine = (faults is not None) if quarantine is None \
            else quarantine
        self._self_heal = self_heal
        self._max_retries = max_retries
        self._retries: Dict[int, int] = {}
        self.quarantined: List[Request] = []
        self.expired: List[Request] = []
        self._expire_queued = expire_queued
        self.pipe = pipe
        self.cfg = pipe.cfg
        self.clock = clock or time.monotonic
        # telemetry (DESIGN.md §telemetry): spans stamp the engine's own
        # clock; taps route every dispatch through the tapped step family
        # (bit-identical latents, extra data outputs — never structure)
        self.telemetry = telemetry
        self._taps = telemetry is not None and telemetry.taps_enabled
        self._rec = telemetry.recorder if telemetry is not None else None
        # profiling (DESIGN.md §profiling): compiled-cost registry +
        # per-request attribution + SLO watchdog. Profiling only adds a
        # per-dispatch block_until_ready for honest wall measurement —
        # same runners, same keys, same latents bit-for-bit
        self._profile = telemetry.profile if telemetry is not None else None
        self._attr = telemetry.attribution if telemetry is not None else None
        self._watchdog = telemetry.watchdog if telemetry is not None else None
        self._wd_ticks = 0
        if self._profile is not None:
            pipe.enable_cost_profiling()
        if telemetry is not None:
            telemetry.bind_clock(self.clock)
        self.policy = policy
        self._validate_menu(plans)
        ref = next(iter(plans.values()))
        self.solver = ref.solver
        self.guidance_scale = ref.guidance_scale
        self.clip_x0 = ref.clip_x0
        self.guided = ref.guidance_active
        # one engine = one compiled step family = one attention backend
        # (DESIGN.md §attention-backend); 'auto' resolves to the segment-
        # aware Pallas kernel inside packed steps, so FLOPs accounting
        # below prices block-granular attention with cross-segment skips
        self.attn_backend = ref.attn_backend
        self.levels: Dict[float, LevelPlan] = {}
        modes = {0}
        for b in sorted(plans):
            plan = plans[b]
            fs = plan.resolve_schedule(self.cfg)
            ts = sch.respaced_timesteps(pipe.sched.num_steps, plan.T)
            step_modes = np.concatenate(
                [np.full(n, m, np.int64) for m, n in fs.phases if n])
            run_len = np.ones(len(step_modes), np.int64)
            for i in range(len(step_modes) - 2, -1, -1):
                if step_modes[i] == step_modes[i + 1]:
                    run_len[i] = run_len[i + 1] + 1
            self.levels[b] = LevelPlan(
                level=b, plan=plan, ts=ts,
                t_prev=np.concatenate([ts[1:], [-1]]),
                modes=step_modes, run_len=run_len,
                flops=plan.flops(self.cfg))
            modes.update(int(m) for m in step_modes)
        mult = 2 if self.guided else 1
        self._seg_tokens = {m: dit_mod.tokens_for_mode(self.cfg, m)
                            for m in sorted(modes)}
        if max_tokens_per_step is None:
            max_tokens_per_step = 4 * mult * self._seg_tokens[0]
        if steps_per_dispatch < 1:
            raise ValueError(f"steps_per_dispatch must be >= 1, got "
                             f"{steps_per_dispatch}")
        self.steps_per_dispatch = steps_per_dispatch
        self.allow_cold = allow_cold
        self.menu = menu if menu is not None else BucketMenu(
            self.cfg, sorted(modes), max_tokens_per_step, guided=self.guided)
        if menu is not None and menu.guided != self.guided:
            raise ValueError("shared menu's guided flag mismatches the plan "
                             "menu's guidance")
        for m in sorted(modes):
            if not self.menu.greedy_fit([m])[0]:
                raise ValueError(
                    f"max_tokens_per_step={self.menu.max_tokens} cannot fit "
                    f"one mode-{m} request's {mult} segment(s); such "
                    f"requests would starve")
        self.max_inflight = max_inflight or 2 * self.menu.max_requests
        self.cache = cache
        self.cache_split = (cache.resolve_split(self.cfg.num_layers)
                            if cache is not None else None)
        self.store: Optional[CacheStore] = None
        self._level_masks: Dict[float, np.ndarray] = {}
        if cache is not None:
            self.store = CacheStore(self.cfg, sorted(modes),
                                    n_slots=self.max_inflight,
                                    guided=self.guided,
                                    integrity=cache_integrity)
            for b, lp in self.levels.items():
                fs = lp.plan.resolve_schedule(self.cfg)
                self._level_masks[b] = cache_policy.ladder_refresh_mask(
                    cache, fs.split_timesteps(lp.ts))
        self.controller = controller
        if policy == "degrade" and controller is None:
            self.controller = BudgetController(
                self.cfg, plans, cache=cache,
                num_train_steps=pipe.sched.num_steps,
                attn_backend=self.attn_backend)
        self.metrics = ServingMetrics()
        self._layout_costs: Dict[Any, Any] = {}
        self._layout_blocks: Dict[Any, Any] = {}
        self._zero_blocks: Dict[int, jax.Array] = {}
        self._queue = RequestQueue()
        self._inflight: List[InFlight] = []
        self._admitting = True
        self._next_id = 0
        self._seq = 0
        self._base_key = (base_key if base_key is not None
                          else jax.random.PRNGKey(0x5e41))
        self._last_step_at: Optional[float] = None
        self._last_sync_at: Optional[float] = self.clock()
        self._flops_since_sync = 0.0
        self.started_at = self.clock()
        if precapture_small > 0:
            self.precapture_warm_set(max_per_mode=precapture_small)

    # ------------------------------------------------------------------
    # Validation / setup

    def _validate_menu(self, plans: Dict[float, SamplingPlan]) -> None:
        if not plans:
            raise ValueError("engine needs a non-empty plan menu")
        if self.cfg.dit is None or self.cfg.dit.conditioning != "class":
            raise ValueError("the serving engine currently serves "
                             "class-conditioned DiTs")
        if self.cfg.dit.lora_rank > 0:
            raise ValueError("mixed-mode packing needs mode-independent "
                             "blocks (shared-parameter recipe); per-mode "
                             "LoRA serving is a ROADMAP follow-on")
        ref = next(iter(plans.values()))
        for b, plan in plans.items():
            plan.validate(self.cfg)
            if plan.is_adaptive:
                raise ValueError("adaptive plans are per-sample host loops; "
                                 "the engine packs static schedules only")
            if plan.solver not in ("ddim", "ddpm"):
                raise ValueError(f"engine solvers: ddim|ddpm, got "
                                 f"{plan.solver!r} at level {b}")
            if plan.parallel is not None:
                raise ValueError("sequence-parallel plans can't join the "
                                 "packed engine (single-host); route them "
                                 "through FlexiPipeline.sample")
            if plan.guidance_active and plan.guidance_kind != "uncond":
                raise ValueError("packed steps implement vanilla CFG; "
                                 "weak_cond guidance mixes modes inside "
                                 "one NFE pair")
            if (plan.solver, plan.guidance_scale, plan.clip_x0,
                    plan.attn_backend) != \
                    (ref.solver, ref.guidance_scale, ref.clip_x0,
                     ref.attn_backend):
                raise ValueError("all menu plans must share solver, "
                                 "guidance scale, clip_x0, and "
                                 "attn_backend (one engine = one "
                                 "compiled step family)")

    # ------------------------------------------------------------------
    # Request lifecycle

    def quantize(self, budget: float) -> float:
        """Requested budget → menu level: cheapest level >= requested
        (the served sample is at least as powerful as asked)."""
        for b in sorted(self.levels):
            if b >= budget - 1e-9:
                return b
        return max(self.levels)

    def submit(self, cond: int, budget: float,
               deadline: float = math.inf,
               key: Optional[jax.Array] = None) -> int:
        """Enqueue one request; returns its id. ``key`` seeds the prior
        draw and solver noise (default: derived from the request id)."""
        rid = self._next_id
        self._next_id += 1
        if key is None:
            key = jax.random.fold_in(self._base_key, rid)
        now = self.clock()
        req = Request(id=rid, cond=int(cond), budget=float(budget),
                      deadline=deadline, key=key)
        self._queue.submit(req, now)
        if self.controller is not None:
            self.controller.observe_arrival(now)
        return rid

    def _solver_keys(self, key: jax.Array, lp: LevelPlan) -> np.ndarray:
        """Per-step solver keys, matching ``sample_phased``'s derivation
        (fold per non-empty phase, split over its timesteps) so DDPM
        ancestral noise is bit-identical to the pipeline's. Pulled to the
        host once at admission: step assembly then stacks them without a
        device round-trip per request per step."""
        run_key = jax.random.fold_in(key, 1)
        parts, i = [], 0
        fs = lp.plan.resolve_schedule(self.cfg)
        for _mode, tsub in fs.split_timesteps(lp.ts):
            if not len(tsub):
                continue
            parts.append(jax.random.split(jax.random.fold_in(run_key, i),
                                          len(tsub)))
            i += 1
        return np.asarray(jnp.concatenate(parts))

    def stop_admissions(self) -> None:
        """Drain mode (DESIGN.md §fleet): keep stepping the in-flight
        cohort to completion, but stop promoting queued requests. The
        queue itself still accepts ``submit`` — the fleet router is
        responsible for not placing onto a draining replica."""
        self._admitting = False

    def resume_admissions(self) -> None:
        self._admitting = True

    def extract_queued(self) -> List[Request]:
        """Remove and return every not-yet-admitted request (submission
        order). Queued requests hold no device or cache state, so a
        draining replica hands them back to the router loss-free; the
        in-flight cohort is NOT touched — it finishes here."""
        out = sorted(self._queue._pending, key=lambda r: r._seq)
        self._queue._pending.clear()
        return out

    def _admit(self, now: float) -> None:
        if self._expire_queued:
            # deadline-expiry path: a queued request whose deadline has
            # passed is a guaranteed SLA miss — reject it terminally
            # instead of burning a dispatch on it (opt-in: latency-SLA
            # deployments; off by default so best-effort queues still
            # serve late requests)
            for req in self._queue.take_expired(now):
                self.expired.append(req)
                self.metrics.total_expired += 1
                if self._rec is not None:
                    self._rec.instant("expired",
                                      args={"id": req.id,
                                            "deadline": req.deadline})
        if not self._admitting:
            return
        policy = "edf" if self.policy == "edf" else "fifo"
        while self._queue and len(self._inflight) < self.max_inflight:
            req = self._queue.pop(policy)
            level = self.quantize(req.budget)
            if self.controller is not None and self.policy == "degrade":
                level = self.controller.assign(level)
            lp = self.levels[level]
            x_T = jax.random.normal(req.key,
                                    (1,) + self.cfg.dit.latent_shape)
            mask = (self._level_masks[level].copy()
                    if self.cache is not None else None)
            self._inflight.append(InFlight(
                req=req, lp=lp, x_src=x_T, x_row=0,
                keys=self._solver_keys(req.key, lp),
                admit=now, seq=self._seq, refresh_mask=mask))
            self._seq += 1

    def _priority(self, f: InFlight) -> Tuple:
        if self.policy == "edf":
            return (f.req.deadline, f.seq)
        return (f.seq,)

    def _is_warm(self, layout, k: int) -> bool:
        return self.pipe.packed_step_is_warm(
            layout, solver=self.solver,
            guidance_scale=self.guidance_scale, clip_x0=self.clip_x0,
            k_steps=k, cache_split=self.cache_split,
            attn_backend=self.attn_backend, taps=self._taps)

    def _ensure_slot(self, f: InFlight, mode: int) -> bool:
        """Make sure ``f`` owns a live slot in ``mode``'s pool; returns
        True when the request must refresh on this dispatch's first step:
        the slot is fresh (joined / phase-switched / evicted), or the
        allocation failed transiently and the request runs slotless
        (``cache_slot == -1``: deep blocks recomputed exactly, no cache
        reads or writes, re-allocation retried next dispatch)."""
        if f.cache_slot >= 0 and f.cache_mode == mode \
                and self.store.owner_of(mode, f.cache_slot) == f.req.id:
            return False
        if f.cache_slot >= 0 \
                and self.store.owner_of(f.cache_mode,
                                        f.cache_slot) == f.req.id:
            self.store.release(f.cache_mode, f.cache_slot)
        try:
            if self._faults is not None and self._faults.take_alloc_failure():
                raise TransientAllocationError("injected alloc failure")
            f.cache_slot = self.store.alloc(mode, f.req.id)
        except TransientAllocationError:
            f.cache_slot = -1
            self.metrics.total_alloc_failures += 1
        f.cache_mode = mode
        return True

    def _gather_latents(self, sel: List[InFlight], pad: int) -> jax.Array:
        """[cap, F, H, W, C] group input with as few device ops as
        possible: runs of requests holding consecutive rows of the same
        source batch (the common steady state — last step's output array)
        are reused whole; stragglers coalesce into one gather per source;
        dummy tail slots come from a cached zeros block."""
        parts: List[jax.Array] = []
        i = 0
        while i < len(sel):
            src = sel[i].x_src
            idx = [sel[i].x_row]
            i += 1
            while i < len(sel) and sel[i].x_src is src:
                idx.append(sel[i].x_row)
                i += 1
            if idx == list(range(src.shape[0])):
                parts.append(src)                    # whole batch, no op
            else:
                parts.append(src[np.asarray(idx)])   # one gather
        if pad:
            z = self._zero_blocks.get(pad)
            if z is None:
                z = self._zero_blocks[pad] = jnp.zeros(
                    (pad,) + self.cfg.dit.latent_shape)
            parts.append(z)
        return parts[0] if len(parts) == 1 else jnp.concatenate(parts)

    # ------------------------------------------------------------------
    # Warm-set shaping

    def precapture_warm_set(self, max_per_mode: int = 2,
                            k_depths: Optional[Sequence[int]] = None) -> int:
        """Compile (and execute once, with dummy inputs) the SMALL-cohort
        bucket ladder: every menu layout with per-mode counts <=
        ``max_per_mode``, at each micro-step depth in ``k_depths``
        (default: powers of two up to ``steps_per_dispatch``).

        Mid-trace cohorts — a Poisson straggler joining a part-drained
        pack — otherwise fall back to whatever coarse layout happens to
        be warm (bench: packing_eff ~0.6 vs 0.99 at drain). Capturing
        the fine small layouts at startup keeps the frozen planner's
        warm set shaped for them; returns how many executables were
        actually cold (newly compiled)."""
        n_cold = 0
        for layout, k in self.warm_set_ladder(max_per_mode, k_depths):
            n_cold += 1
            self._dummy_dispatch(layout, k)
        return n_cold

    def warm_set_ladder(self, max_per_mode: int = 2,
                        k_depths: Optional[Sequence[int]] = None
                        ) -> List[Tuple[PackLayout, int]]:
        """The still-COLD rungs of the small-cohort bucket ladder, in
        capture order — ``precapture_warm_set``'s work list, exposed so
        a background compile thread (``fleet.warmup``) can walk it one
        ``_dummy_dispatch`` at a time while the engine serves. Already-
        warm rungs are skipped, so the list shrinks to empty as the
        ladder is captured (by either party)."""
        if k_depths is None:
            k_depths, kd = [], 1
            while kd <= self.steps_per_dispatch:
                k_depths.append(kd)
                kd *= 2
        out: List[Tuple[PackLayout, int]] = []
        for layout in self.menu.layouts:
            if any(c > max_per_mode for _m, c in layout.groups):
                continue
            for k in k_depths:
                if not self._is_warm(layout, k):
                    out.append((layout, k))
        return out

    def _dummy_dispatch(self, layout: PackLayout, k: int,
                        record: bool = True) -> None:
        """Run one throwaway dispatch at ``layout`` so the executable is
        compiled AND loaded (a runner that merely exists in the cache
        still stalls its first real step on compilation).

        ``record=False`` skips the span (the background compile thread
        must not interleave writes into the serving thread's
        SpanRecorder ring or stamp a foreign clock)."""
        record = record and self._rec is not None
        t0 = self.clock() if record else 0.0
        runner = self.pipe.packed_step(
            layout, solver=self.solver,
            guidance_scale=self.guidance_scale, clip_x0=self.clip_x0,
            k_steps=k, cache_split=self.cache_split,
            attn_backend=self.attn_backend, taps=self._taps)
        xs, metas, keys, deltas, refreshes = [], [], [], [], []
        for mode, cap in layout.groups:
            xs.append(jnp.zeros((cap,) + self.cfg.dit.latent_shape))
            meta = np.zeros((k, 3, cap), np.int32)
            meta[:, 1, :] = -1
            metas.append(jnp.asarray(meta))
            keys.append(jnp.zeros((k, cap, 2), jnp.uint32))
            if self.cache is not None:
                deltas.append(jnp.zeros(
                    (cap, self.store.mult, self._seg_tokens[mode],
                     self.cfg.d_model), self.store.dtype))
                refreshes.append(jnp.zeros((k, cap), bool))
        if self.cache is not None:
            out = runner(self.pipe.params, tuple(xs), tuple(metas),
                         tuple(keys), tuple(deltas), tuple(refreshes))
        else:
            out = runner(self.pipe.params, tuple(xs), tuple(metas),
                         tuple(keys))
        jax.block_until_ready(out)
        if record:
            self._rec.complete("compile", t0, self.clock(),
                               args={"groups": str(layout.groups), "k": k,
                                     "precapture": True})

    # ------------------------------------------------------------------
    # The engine iteration

    def step(self) -> List[ServedResult]:
        """One engine iteration: admit arrivals, plan (cohort, bucket,
        micro-step depth k), advance the packed cohort k denoise steps in
        one dispatch, and retire finished requests. Requests that don't
        fit the chosen bucket simply wait (no drain, no recompile)."""
        now = self.clock()
        n_before = len(self._inflight)
        self._admit(now)
        if self._rec is not None and len(self._inflight) > n_before:
            self._rec.complete("admit", now, self.clock(),
                               args={"admitted":
                                     len(self._inflight) - n_before,
                                     "queued": len(self._queue)})
        if not self._inflight:
            self._last_step_at = now
            return []
        mult = 2 if self.guided else 1

        # co-optimize the cohort, the bucket, and the micro-step depth k:
        # one dispatch advances the cohort k consecutive same-mode denoise
        # steps under lax.scan (joins wait at most k steps), so the
        # planner maximizes request-steps per dispatch — k x cohort size —
        # over the power-of-two depths the highest-priority request can
        # sustain. Cold dispatches pack an EXACT-fit layout (greedy over
        # the priority order, no dummy slots); frozen serving
        # (``allow_cold=False``: every compile stall is an SLA violation)
        # restricts to already-compiled layouts, falling back to a cold
        # one only when nothing warm can serve at all.
        t_plan = self.clock() if self._rec is not None else 0.0
        prio = sorted(self._inflight, key=self._priority)
        top = prio[0]
        k_cap = 1
        top_run = min(self.steps_per_dispatch,
                      int(top.lp.run_len[top.step]))
        while k_cap * 2 <= top_run:
            k_cap *= 2
        best = None
        for cold_pass in ((True,) if self.allow_cold else (False, True)):
            if not cold_pass:
                # frozen pass: only buckets with room for the highest-
                # priority request's mode — keeps EDF live (top always
                # advances) and k_cap (derived from top) consistent
                warm_layouts = {
                    kk: [l for l in ls if l.capacity_for(top.mode)]
                    for kk, ls in self.pipe.warm_packed_layouts(
                        solver=self.solver,
                        guidance_scale=self.guidance_scale,
                        clip_x0=self.clip_x0,
                        cache_split=self.cache_split,
                        attn_backend=self.attn_backend,
                        taps=self._taps).items()}
            kc = k_cap
            while kc >= 1:
                eligible = [f for f in prio
                            if int(f.lp.run_len[f.step]) >= kc]
                if not eligible:
                    kc //= 2
                    continue
                if cold_pass:
                    idx, counts = self.menu.greedy_fit(
                        [f.mode for f in eligible])
                    if not idx:
                        kc //= 2
                        continue
                    cand = PackLayout.for_counts(
                        counts, guided=self.guided,
                        row_capacity=self.menu.row_capacity)
                    sel_by_mode: Dict[int, List[InFlight]] = {}
                    for i in idx:
                        sel_by_mode.setdefault(eligible[i].mode,
                                               []).append(eligible[i])
                    served = len(idx)
                else:
                    demand: Dict[int, int] = {}
                    for f in eligible:
                        demand[f.mode] = demand.get(f.mode, 0) + 1
                    cand = self.menu.choose(
                        demand, among=warm_layouts.get(kc, ()))
                    if cand is None:
                        kc //= 2
                        continue
                    sel_by_mode = None
                    served = self.menu.served_by(cand, demand)
                score = (kc * served,
                         1 if self._is_warm(cand, kc) else 0,
                         -self.menu.packed_tokens(cand))
                if best is None or score > best[0]:
                    best = (score, kc, cand, sel_by_mode)
                kc //= 2
            if best is not None:
                break                 # frozen pass found a warm bucket
        _, k, layout, sel_by_mode = best
        if sel_by_mode is None:       # warm bucket: fill its capacities
            eligible = [f for f in prio if int(f.lp.run_len[f.step]) >= k]
            sel_by_mode = {}
            for f in eligible:
                sel_by_mode.setdefault(f.mode, []).append(f)
        picked = [sel_by_mode.get(mode, [])[:cap]
                  for mode, cap in layout.groups]
        if self._rec is not None:
            self._rec.complete("plan", t_plan, self.clock(),
                               args={"k": k,
                                     "groups": str(layout.groups),
                                     "inflight": len(self._inflight)})
        t_pack = self.clock() if self._rec is not None else 0.0

        xs, metas, keys = [], [], []
        deltas, refreshes, slot_lists, rf_real = [], [], [], []
        real_tokens = 0
        n_refresh = n_cached_steps = 0
        for (mode, cap), sel in zip(layout.groups, picked):
            pad = cap - len(sel)
            xs.append(self._gather_latents(sel, pad))
            meta = np.zeros((k, 3, cap), np.int32)
            meta[:, 1, :] = -1                   # dummy slots: final step
            kk = np.zeros((k, cap, 2), np.uint32)
            rf = np.zeros((k, cap), bool)        # dummies never refresh
            slots: List[int] = []
            for i, f in enumerate(sel):
                s = f.step
                meta[:, 0, i] = f.lp.ts[s:s + k]
                meta[:, 1, i] = f.lp.t_prev[s:s + k]
                meta[:, 2, i] = f.req.cond
                kk[:, i] = f.keys[s:s + k]
                if self.cache is not None:
                    if self._ensure_slot(f, mode):
                        f.refresh_mask[s] = True     # fresh slot: no replay
                    elif self.store.integrity and not self.store.verify_slot(
                            mode, f.cache_slot):
                        # checksum mismatch: the resident delta was
                        # corrupted out of band — force an exact deep-block
                        # recompute; the scatter below re-records the crc
                        f.refresh_mask[s] = True
                        self.metrics.total_integrity_refreshes += 1
                    if f.cache_slot < 0:
                        # slotless (transient alloc failure): every
                        # micro-step refreshes, so the garbage gathered in
                        # its row is never read and nothing scatters back
                        f.refresh_mask[s:s + k] = True
                    rf[:, i] = f.refresh_mask[s:s + k]
                    slots.append(f.cache_slot)
            metas.append(jnp.asarray(meta))
            keys.append(jnp.asarray(kk))
            real_tokens += mult * self._seg_tokens[mode] * len(sel) * k
            if self.cache is not None:
                refreshes.append(jnp.asarray(rf))
                slot_lists.append(slots)
                rf_real.append(rf[:, :len(sel)])
                if slots and min(slots) < 0:
                    # slotless rows gather slot 0's delta; it is ignored
                    # (their refresh flags are all True)
                    gathered = self.store.gather(
                        mode, [max(sl, 0) for sl in slots])
                else:
                    gathered = (self.store.gather(mode, slots)
                                if slots else None)
                if pad:
                    z = jnp.zeros((pad, self.store.mult,
                                   self._seg_tokens[mode],
                                   self.cfg.d_model), self.store.dtype)
                    gathered = (z if gathered is None
                                else jnp.concatenate([gathered, z]))
                deltas.append(gathered)

        step_flops = 0.0
        if self.cache is not None:
            # honest device-cost accounting: the packed executable's
            # lax.cond is DISPATCH-wide — the deep blocks run for the
            # whole pack whenever any cohort member refreshes a
            # micro-step, so only all-skip micro-steps realize the deep
            # saving. The per-request replay counts below feed the
            # quality/staleness ledger (hit rate, histogram); the FLOPs
            # fed to the capacity EWMA charge what the hardware ran.
            any_ref = np.zeros(k, bool)
            for rf in rf_real:
                if rf.size:
                    any_ref |= rf.any(axis=1)
            deep_skips = k - int(any_ref.sum())
            for (mode, _cap), sel, rf in zip(layout.groups, picked,
                                             rf_real):
                n_refresh += int(rf.sum())
                n_cached_steps += k * len(sel)
                full = dit_nfe_flops(self.cfg, mode,
                                     attn_backend=self.attn_backend)
                deep = cache_ledger.deep_block_flops(
                    self.cfg, mode, self.cache_split,
                    attn_backend=self.attn_backend)
                step_flops += mult * len(sel) * (k * full
                                                 - deep_skips * deep)
        else:
            step_flops = k * sum(
                mult * len(sel)
                * dit_nfe_flops(self.cfg, mode,
                                attn_backend=self.attn_backend)
                for (mode, _cap), sel in zip(layout.groups, picked))

        if self._rec is not None:
            self._rec.complete("pack", t_pack, self.clock(),
                               args={"real_tokens": real_tokens})
        was_warm = (self._is_warm(layout, k) if self._rec is not None
                    else True)
        t_fetch = self.clock() if self._rec is not None else 0.0
        runner = self.pipe.packed_step(
            layout, solver=self.solver,
            guidance_scale=self.guidance_scale, clip_x0=self.clip_x0,
            k_steps=k, cache_split=self.cache_split,
            attn_backend=self.attn_backend, taps=self._taps)
        if self._rec is not None and not was_warm:
            # cold dispatch: the runner fetch traced + lowered a new
            # executable — the stall every frozen-serving SLA fears
            self._rec.complete("compile", t_fetch, self.clock(),
                               args={"groups": str(layout.groups), "k": k})
        t_disp = (self.clock()
                  if self._rec is not None or self._profile is not None
                  else 0.0)
        tap = None
        if self.cache is not None:
            out = runner(self.pipe.params, tuple(xs),
                         tuple(metas), tuple(keys),
                         tuple(deltas), tuple(refreshes))
            (outs, new_deltas, tap) = out if self._taps else (*out, None)
            if self._faults is not None:
                outs = self._apply_poison(outs, picked)
            for (mode, _cap), slots, nd in zip(layout.groups, slot_lists,
                                               new_deltas):
                if not slots:
                    continue
                if min(slots) < 0:
                    # skip slotless rows: scattering them would clobber
                    # slot 0's owner
                    keep = [j for j, sl in enumerate(slots) if sl >= 0]
                    if keep:
                        self.store.scatter(mode, [slots[j] for j in keep],
                                           nd[np.asarray(keep, np.int32)])
                else:
                    self.store.scatter(mode, slots, nd[:len(slots)])
            self.metrics.record_cache(n_refresh,
                                      n_cached_steps - n_refresh)
            self.metrics.set_cache_bytes(self.store.bytes_resident)
        else:
            out = runner(self.pipe.params, tuple(xs), tuple(metas),
                         tuple(keys))
            (outs, tap) = out if self._taps else (out, None)
            if self._faults is not None:
                outs = self._apply_poison(outs, picked)
        if self._profile is not None:
            # profiling waits on the device once per dispatch: wall is
            # meaningless without it. Measurement overhead only — the
            # executables and their outputs are untouched
            jax.block_until_ready(outs)
            wall_s = self.clock() - t_disp
            pkey = profile_packed_key(
                layout, solver=self.solver,
                guidance_scale=self.guidance_scale, clip_x0=self.clip_x0,
                k_steps=k, cache_split=self.cache_split,
                attn_backend=self.attn_backend, taps=self._taps)
            self._profile.observe_wall(pkey, wall_s)
            if self._attr is not None:
                rids: List[int] = []
                weights: List[float] = []
                for gi, ((mode, _cap), sel) in enumerate(
                        zip(layout.groups, picked)):
                    full = dit_nfe_flops(self.cfg, mode,
                                         attn_backend=self.attn_backend)
                    deep = (cache_ledger.deep_block_flops(
                        self.cfg, mode, self.cache_split,
                        attn_backend=self.attn_backend)
                        if self.cache is not None else 0.0)
                    for i, f in enumerate(sel):
                        rids.append(f.req.id)
                        if self.cache is not None:
                            # refresh-aware ledger share: skip steps pay
                            # shallow blocks only
                            w = mult * sum(
                                full if r else full - deep
                                for r in rf_real[gi][:, i])
                        else:
                            w = mult * k * full
                        weights.append(float(w))
                if rids:
                    self._attr.attribute_dispatch(
                        time=now,
                        label=f"k={k} groups={layout.groups}",
                        request_ids=rids, weights=weights,
                        wall_ns=int(wall_s * 1e9),
                        flops=int(step_flops),
                        bytes_=self._profile.xla_bytes(pkey))
            if self.controller is not None:
                fams = {mode for (mode, _c), sel
                        in zip(layout.groups, picked) if sel}
                self.controller.observe_calibration(
                    fams.pop() if len(fams) == 1 else None,
                    step_flops, wall_s)
        if self._rec is not None:
            self._rec.complete(
                "dispatch", t_disp, self.clock(),
                args={"k": k, "groups": str(layout.groups),
                      "requests": sum(len(s) for s in picked),
                      "warm": was_warm})
        if tap is not None:
            # still device arrays — the aggregator syncs at export time
            self.telemetry.taps.add(TapSample(
                time=now, k=k, groups=layout.groups,
                n_real=tuple(len(s) for s in picked),
                eps_norm=tap["eps_norm"], drift=tap.get("drift"),
                attn_blocks=tap.get("attn_blocks"),
                finite=tap.get("finite")))
        self._flops_since_sync += step_flops
        synced = False
        if any(f.step + k >= len(f.lp.ts) for sel in picked for f in sel):
            synced = True
            # someone completes on this dispatch: a result only counts as
            # served once it is materialized, so the finish stamp (and any
            # latency derived from it) waits for the device. This is also
            # the only honest capacity sample — between syncs the clock
            # only sees host-side batch assembly, not device compute
            t_mat = self.clock() if self._rec is not None else 0.0
            jax.block_until_ready(outs)
            now = self.clock()
            if self._rec is not None:
                self._rec.complete("materialize", t_mat, now,
                                   args={"k": k})
            if self.controller is not None and self._last_sync_at is not None \
                    and now > self._last_sync_at:
                self.controller.observe_service(self._flops_since_sync,
                                                now - self._last_sync_at)
            self._flops_since_sync = 0.0
            self._last_sync_at = now

        finished: List[ServedResult] = []
        stepped = 0
        # quarantine detection rides existing sync points only: the
        # in-graph finite tap is read on the host after the completion
        # branch's block_until_ready, and the retire-time check reads a
        # latent that same sync already materialized
        bad: set = set()
        if self._quarantine and synced and tap is not None:
            bad = self._scan_finite(tap, picked)
        for g, sel in enumerate(picked):
            for i, f in enumerate(sel):
                f.x_src, f.x_row = outs[g], i
                f.step += k
                stepped += 1
                if self._quarantine and (
                        f.req.id in bad
                        or (f.done
                            and not np.isfinite(np.asarray(f.x)).all())):
                    self._inflight.remove(f)
                    self._quarantine_request(f, now)
                elif f.done:
                    self._inflight.remove(f)
                    finished.append(self._retire(f, now))
        cost = self._layout_costs.get(layout)
        if cost is None:
            cost = self._layout_costs[layout] = layout.cost(self.cfg)
        self.metrics.record_step(now, real_tokens, cost.packed_tokens * k,
                                 stepped)
        if self.attn_backend in ("auto", "pallas"):
            # cross-segment block skip ledger (DESIGN.md
            # §attention-backend): what fraction of the pack's score
            # tiles the segment-aware kernel never issued
            blk = self._layout_blocks.get(layout)
            if blk is None:
                blk = self._layout_blocks[layout] = \
                    layout.attention_block_stats(self.cfg)
            self.metrics.record_attention_blocks(blk[0] * k, blk[1] * k)
        if self._rec is not None:
            self._rec.counter("engine", {"inflight": len(self._inflight),
                                         "queued": len(self._queue)})
        if self._watchdog is not None:
            self._wd_ticks += 1
            drift = None
            if self._taps and (self._wd_ticks
                               % self._watchdog.config.taps_every == 0):
                # the one deliberate host sync: tap aggregation, at the
                # watchdog's configured cadence, never per dispatch
                sub = self.telemetry.taps.aggregate().get("drift")
                if sub:
                    drift = float(sub.get("max", 0.0))
            self._watchdog.observe_step(
                now=now, queued=len(self._queue),
                inflight=len(self._inflight),
                compiled=self.pipe.cache_stats()["compiled"],
                latencies=[r.latency for r in self.metrics.requests],
                drift_max=drift,
                nonfinite=self.metrics.total_quarantined)
            if self._watchdog.should_dump():
                self._watchdog.dump(
                    reason="alert", engine_snapshot=self.snapshot_state(),
                    attribution=self._attr, registry=self._profile)
        self._last_step_at = now
        return finished

    def _apply_poison(self, outs: Tuple, picked: List[List[InFlight]]
                      ) -> Tuple:
        """Fault seam (post-dispatch host hook): overwrite targeted
        requests' packed-step output rows with NaN — the failure a
        silently degraded weak step would have produced in-graph. Only
        reachable when a FaultPlan is armed."""
        outs = list(outs)
        for g, sel in enumerate(picked):
            for i, f in enumerate(sel):
                if self._faults is not None \
                        and self._faults.take_poison(f.req.id):
                    outs[g] = outs[g].at[i].set(jnp.nan)
                    self.metrics.total_poisoned += 1
        return tuple(outs)

    def _scan_finite(self, tap: Dict[str, Any],
                     picked: List[List[InFlight]]) -> set:
        """Host read of the in-graph finite tap: ids of requests whose
        latent rows went non-finite during this dispatch. Called only
        after the completion branch's existing ``block_until_ready`` —
        never adds a sync point."""
        out: set = set()
        fin = tap.get("finite")
        if fin is None:
            return out
        for g, sel in enumerate(picked):
            if not sel:
                continue
            ok = np.asarray(fin[g])[:, :len(sel)].all(axis=0)
            for i, f in enumerate(sel):
                if not ok[i]:
                    out.add(f.req.id)
        return out

    def _quarantine_request(self, f: InFlight, now: float) -> None:
        """Non-finite latents detected: drop the poisoned trajectory,
        release its cache slot, and re-enqueue the request at the MOST
        POWERFUL menu level, restarting from step 0 with the same key —
        the recovered sample is exactly the clean powerful-path sample.
        With ``self_heal=False`` (fleet mode) the request is parked in
        ``quarantined`` instead, for the router to escalate with
        deadline-aware backoff."""
        if self.store is not None and f.cache_slot >= 0 \
                and self.store.owner_of(f.cache_mode,
                                        f.cache_slot) == f.req.id:
            self.store.release(f.cache_mode, f.cache_slot)
        self.metrics.total_quarantined += 1
        if self._rec is not None:
            self._rec.instant("quarantine",
                              args={"id": f.req.id, "step": f.step,
                                    "level": f.lp.level})
        if not self._self_heal:
            self.quarantined.append(f.req)
            return
        n = self._retries.get(f.req.id, 0)
        if n >= self._max_retries:
            # retry budget exhausted: park the request instead of looping
            # — the caller decides (losing it silently is never an option)
            self.quarantined.append(f.req)
            return
        self._retries[f.req.id] = n + 1
        self._queue.submit(
            Request(id=f.req.id, cond=f.req.cond,
                    budget=max(self.levels), deadline=f.req.deadline,
                    key=f.req.key), now)

    def take_quarantined(self) -> List[Request]:
        """Drain quarantined requests awaiting external escalation (the
        fleet routes them through ``Router.escalate``)."""
        out, self.quarantined = self.quarantined, []
        return out

    def take_expired(self) -> List[Request]:
        """Drain terminally expired requests (deadline passed while
        queued) for the caller's bookkeeping."""
        out, self.expired = self.expired, []
        return out

    def _retire(self, f: InFlight, now: float) -> ServedResult:
        mult = 2 if self.guided else 1
        tokens = int(mult * sum(self._seg_tokens[int(m)] for m in f.lp.modes))
        if self.store is not None and f.cache_slot >= 0 \
                and self.store.owner_of(f.cache_mode,
                                        f.cache_slot) == f.req.id:
            self.store.release(f.cache_mode, f.cache_slot)
        if f.refresh_mask is not None:
            self.metrics.record_refresh_intervals(
                cache_policy.refresh_intervals(f.refresh_mask))
            self.metrics.set_cache_bytes(self.store.bytes_resident)
        rec = RequestRecord(
            id=f.req.id, arrival=f.req.arrival, admit=f.admit, finish=now,
            deadline=f.req.deadline, budget_requested=f.req.budget,
            budget_served=f.lp.level, tokens=tokens, flops=f.lp.flops)
        self.metrics.record_request(rec)
        cost = None
        if self._attr is not None:
            cost = self._attr.finalize(
                f.req.id, queue_wait_s=f.admit - f.req.arrival,
                budget=str(f.lp.level))
        if self._rec is not None:
            # one row per request under the "requests" track (tid = id)
            self._rec.complete(
                f"req{f.req.id}", f.admit, now,
                pid=REQUEST_PID, tid=f.req.id,
                args={"budget_requested": f.req.budget,
                      "budget_served": f.lp.level,
                      "steps": len(f.lp.ts), "flops": f.lp.flops,
                      "queue_wait": f.admit - f.req.arrival})
        return ServedResult(request=f.req, x0=f.x,
                            budget_served=f.lp.level, record=rec,
                            cost=cost)

    # ------------------------------------------------------------------

    def run(self, max_steps: int = 100_000) -> List[ServedResult]:
        """Drain: step until queue and in-flight are empty. An uncaught
        exception first dumps a post-mortem bundle (when a watchdog with
        a postmortem dir is attached), then propagates unchanged."""
        out: List[ServedResult] = []
        steps = 0
        try:
            while (self._queue or self._inflight) and steps < max_steps:
                out.extend(self.step())
                steps += 1
        except Exception:
            if self._watchdog is not None:
                self._watchdog.dump(
                    reason="engine-exception",
                    engine_snapshot=self.snapshot_state(),
                    attribution=self._attr, registry=self._profile)
            raise
        return out

    def snapshot_state(self) -> Dict[str, Any]:
        """Flight-recorder view of engine state: queue, in-flight
        request positions, compile-cache counters, cache residency. All
        host-side — safe to call from the crash path."""
        snap: Dict[str, Any] = {
            "queued": [{"id": r.id, "budget": r.budget,
                        "deadline": r.deadline, "arrival": r.arrival}
                       for r in self._queue._pending],
            "inflight": [{"id": f.req.id, "level": f.lp.level,
                          "step": f.step, "of": len(f.lp.ts),
                          "mode": f.mode, "admit": f.admit,
                          "cache_slot": f.cache_slot}
                         for f in self._inflight],
            "compile": self.pipe.cache_stats(),
            "policy": self.policy,
        }
        if self.store is not None:
            snap["cache_bytes"] = self.store.bytes_resident
        return snap

    @property
    def idle(self) -> bool:
        return not self._queue and not self._inflight

    @property
    def n_queued(self) -> int:
        return len(self._queue)

    @property
    def n_inflight(self) -> int:
        return len(self._inflight)

    def cache_stats(self) -> Dict[str, int]:
        """The pipeline's compile-cache counters (packed-step runners are
        cached there; zero growth after warmup = zero recompiles)."""
        return self.pipe.cache_stats()
