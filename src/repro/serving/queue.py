"""Request admission queue (DESIGN.md §serving).

A :class:`Request` is one image to generate: class label, requested
relative-compute budget, optional latency deadline, and the PRNG key that
seeds its prior draw and solver noise (so a served request reproduces the
same sample as a standalone ``FlexiPipeline.sample`` call with that key).
The queue orders admission by policy: ``fifo`` (arrival order) or ``edf``
(earliest deadline first). All timestamps come from the caller's clock,
so tests drive a simulated clock deterministically.
"""
from __future__ import annotations

import dataclasses
import math
from typing import List, Optional

import jax

POLICIES = ("fifo", "edf")


@dataclasses.dataclass
class Request:
    id: int
    cond: int                            # class label
    budget: float                        # requested relative-compute level
    deadline: float = math.inf           # absolute time (caller's clock)
    key: Optional[jax.Array] = None      # PRNG key; engine derives if None
    arrival: float = 0.0                 # stamped by the queue
    _seq: int = dataclasses.field(default=0, repr=False)


class RequestQueue:
    """Pending requests, ordered by an admission policy at pop time."""

    def __init__(self):
        self._pending: List[Request] = []
        self._seq = 0

    def __len__(self) -> int:
        return len(self._pending)

    def __bool__(self) -> bool:
        return bool(self._pending)

    def submit(self, req: Request, now: float) -> Request:
        req.arrival = now
        req._seq = self._seq
        self._seq += 1
        self._pending.append(req)
        return req

    def pop(self, policy: str = "fifo") -> Request:
        if policy not in POLICIES:
            raise ValueError(f"unknown policy {policy!r}; known: {POLICIES}")
        if not self._pending:
            raise IndexError("pop from empty request queue")
        if policy == "edf":
            req = min(self._pending, key=lambda r: (r.deadline, r._seq))
        else:
            req = min(self._pending, key=lambda r: r._seq)
        self._pending.remove(req)
        return req

    def take_expired(self, now: float) -> List[Request]:
        """Remove and return every queued request whose deadline has
        already passed — dispatching one would burn compute on a
        guaranteed SLA miss. Returned in arrival order so the caller's
        terminal accounting is deterministic."""
        expired = [r for r in self._pending if r.deadline < now]
        for r in expired:
            self._pending.remove(r)
        return sorted(expired, key=lambda r: r._seq)

    def peek_deadlines(self) -> List[float]:
        return sorted(r.deadline for r in self._pending)
