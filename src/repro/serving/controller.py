"""SLA-aware budget control (DESIGN.md §serving).

FlexiDiT's per-step elasticity gives the scheduler a knob no fixed-
compute model has: under load, requests can be *demoted* to a weaker
(cheaper) sampling plan instead of queueing without bound. The
controller solves, from the analytic FLOPs ledger, for the highest
uniform budget level the current arrival rate sustains:

    highest b  s.t.  lambda * F(b) <= target_util * capacity

where ``F(b)`` is the per-request denoising FLOPs of level ``b``'s plan
(``core.scheduler.schedule_flops`` via ``SamplingPlan.flops``, plus the
sequence-parallel padding waste from ``distributed.partition`` when the
plan shards over a mesh) and ``capacity`` is the engine's measured
FLOPs/s. Both rates are EWMA estimates fed by ``observe_*`` hooks, so
deterministic tests can inject them directly.

With profiling on (DESIGN.md §profiling) the engine additionally feeds
``observe_calibration`` a measured wall-per-analytic-FLOP per step
family (family = patch mode; mixed-mode dispatches calibrate only the
global factor). Once calibrated the solve switches to seconds-space —
``cost_seconds(b) = Σ_m mode_flops[b][m] · wpf(m) <= target_util / λ``
— so SLA pricing uses *measured* cost: a mode whose analytic savings
don't survive compilation (e.g. block-sparse attention that compiled
dense) prices at what it actually costs. ``solve_analytic`` keeps the
pure-arithmetic solve for comparison; uncalibrated controllers behave
exactly as before.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

from repro.cache.policy import CacheSpec
from repro.configs.base import ModelConfig
from repro.pipeline.plan import SamplingPlan


def request_cost_flops(cfg: ModelConfig, plan: SamplingPlan,
                       sp: int = 1,
                       cache: Optional[CacheSpec] = None,
                       num_train_steps: int = 1000,
                       attn_backend: Optional[str] = None) -> float:
    """Analytic FLOPs one request at ``plan`` costs the engine. With
    ``sp`` sequence-parallel shards the pad-to-divisible waste from the
    partition plan is real compute and is charged too. With ``cache``
    (the engine's cross-step activation cache) skip steps only pay the
    shallow blocks, so the sustainable-budget solve sees the cheaper
    cache-adjusted cost — caching raises the budget level a given
    arrival rate sustains. ``num_train_steps`` must match the serving
    pipeline's diffusion-schedule length: banded/proxy refresh masks
    depend on the ladder's actual ``t`` values.

    Attention is priced at what the plan's backend actually issues
    (DESIGN.md §attention-backend): under 'pallas'/'auto' the segment-
    aware kernel computes block-granular score tiles — a pack's cross-
    segment blocks are skipped, never charged — while the XLA backends
    pay the dense N² convention. Override with ``attn_backend``."""
    backend = plan.attn_backend if attn_backend is None else attn_backend
    if cache is not None and plan.cache is None:
        import dataclasses
        plan = dataclasses.replace(plan, cache=cache)
    fl = (plan.cached_flops(cfg, num_train_steps=num_train_steps,
                            attn_backend=backend)
          if plan.cache is not None
          else plan.flops(cfg, attn_backend=backend))
    if sp > 1:
        from repro.distributed.partition import plan_partition
        part = plan_partition(cfg, plan.resolve_schedule(cfg), sp,
                              plan.parallel)
        fl += part.pad_flops(cfg, cfg_scale_active=plan.guidance_active)
    return fl


def plan_mode_flops(cfg: ModelConfig, plan: SamplingPlan,
                    sp: int = 1,
                    cache: Optional[CacheSpec] = None,
                    num_train_steps: int = 1000,
                    attn_backend: Optional[str] = None
                    ) -> Dict[int, float]:
    """``request_cost_flops`` split by step family (patch mode): the
    fraction of a request's cost each mode's NFEs account for, scaled so
    the values sum exactly to the request total (guidance/LoRA/sp-pad
    overheads smear proportionally). This is what seconds-space pricing
    multiplies by per-family wall-per-FLOP calibration factors."""
    backend = plan.attn_backend if attn_backend is None else attn_backend
    if cache is not None and plan.cache is None:
        import dataclasses
        plan = dataclasses.replace(plan, cache=cache)
    total = request_cost_flops(cfg, plan, sp,
                               num_train_steps=num_train_steps,
                               attn_backend=attn_backend)
    if plan.is_adaptive:
        # no static phase split — the probe decides at runtime; price it
        # all at the powerful family
        return {0: total}
    from repro.core.scheduler import dit_nfe_flops
    from repro.diffusion import schedule as sch
    schedule = plan.resolve_schedule(cfg)
    raw: Dict[int, float] = {}
    if plan.cache is not None:
        from repro.cache import ledger as cache_ledger
        from repro.cache import policy as cache_policy
        ts = sch.respaced_timesteps(num_train_steps, plan.T)
        split = plan.cache.resolve_split(cfg.num_layers)
        for mode, tsub in schedule.split_timesteps(ts):
            mask = cache_policy.refresh_mask(plan.cache, tsub)
            fl = sum(cache_ledger.cached_nfe_flops(
                cfg, mode, split, bool(r), attn_backend=backend)
                for r in mask)
            raw[mode] = raw.get(mode, 0.0) + fl
    else:
        for mode, n_steps in schedule.phases:
            if n_steps:
                raw[mode] = (raw.get(mode, 0.0) + n_steps
                             * dit_nfe_flops(cfg, mode,
                                             attn_backend=backend))
    rsum = sum(raw.values())
    if rsum <= 0:
        return {0: total}
    return {m: total * fl / rsum for m, fl in raw.items()}


class BudgetController:
    """Solves for the degradation level; stateless apart from two EWMAs."""

    def __init__(self, cfg: ModelConfig, plans: Dict[float, SamplingPlan], *,
                 target_util: float = 0.85, alpha: float = 0.3, sp: int = 1,
                 cache: Optional[CacheSpec] = None,
                 num_train_steps: int = 1000,
                 attn_backend: Optional[str] = None):
        if not plans:
            raise ValueError("controller needs a non-empty plan menu")
        if not 0.0 < target_util <= 1.0:
            raise ValueError(f"target_util must be in (0, 1], got "
                             f"{target_util}")
        self.levels = tuple(sorted(plans))            # ascending budgets
        self.costs = {b: request_cost_flops(cfg, p, sp, cache=cache,
                                            num_train_steps=num_train_steps,
                                            attn_backend=attn_backend)
                      for b, p in plans.items()}
        self.mode_costs = {b: plan_mode_flops(
            cfg, p, sp, cache=cache, num_train_steps=num_train_steps,
            attn_backend=attn_backend) for b, p in plans.items()}
        self.target_util = target_util
        self.alpha = alpha
        self._interarrival: Optional[float] = None    # EWMA seconds
        self._last_arrival: Optional[float] = None
        self._flops_per_s: Optional[float] = None     # EWMA capacity
        self._wpf: Dict[Any, float] = {}              # wall/FLOP per family
        self._wpf_global: Optional[float] = None

    # ------------------------------------------------------------------
    # Rate estimation

    def observe_arrival(self, now: float) -> None:
        if self._last_arrival is not None:
            gap = max(now - self._last_arrival, 1e-9)
            self._interarrival = (gap if self._interarrival is None else
                                  (1 - self.alpha) * self._interarrival
                                  + self.alpha * gap)
        self._last_arrival = now

    def observe_service(self, flops: float, dt: float) -> None:
        """Feed one completed chunk of work: ``flops`` retired in ``dt``
        seconds of engine time."""
        if dt <= 0:
            return
        rate = flops / dt
        self._flops_per_s = (rate if self._flops_per_s is None else
                             (1 - self.alpha) * self._flops_per_s
                             + self.alpha * rate)

    def observe_calibration(self, family: Optional[Any],
                            analytic_flops: float, wall_s: float) -> None:
        """Feed one measured dispatch: ``wall_s`` of device time for
        ``analytic_flops`` of ledger work. ``family`` is the patch mode
        when the dispatch was single-family, else None (mixed packs
        calibrate only the global factor — their wall is not separable
        by family without the attribution model this factor feeds)."""
        if analytic_flops <= 0 or wall_s <= 0:
            return
        r = wall_s / analytic_flops
        if family is not None:
            prev = self._wpf.get(family)
            self._wpf[family] = (r if prev is None else
                                 (1 - self.alpha) * prev + self.alpha * r)
        self._wpf_global = (r if self._wpf_global is None else
                            (1 - self.alpha) * self._wpf_global
                            + self.alpha * r)

    @property
    def arrival_rate(self) -> Optional[float]:
        return None if not self._interarrival else 1.0 / self._interarrival

    @property
    def capacity_flops_per_s(self) -> Optional[float]:
        return self._flops_per_s

    @property
    def calibration(self) -> Optional[Dict[str, Any]]:
        """Measured wall-per-analytic-FLOP factors (None before any
        ``observe_calibration``)."""
        if self._wpf_global is None:
            return None
        return {"global": self._wpf_global, "per_family": dict(self._wpf)}

    # ------------------------------------------------------------------
    # The solve

    def cost_seconds(self, b: float) -> Optional[float]:
        """Measured seconds of engine time one request at level ``b``
        costs: per-family analytic FLOPs × calibrated wall-per-FLOP
        (global factor for families never seen alone)."""
        if self._wpf_global is None:
            return None
        return sum(fl * self._wpf.get(m, self._wpf_global)
                   for m, fl in self.mode_costs[b].items())

    def solve(self) -> float:
        """Highest budget level sustaining the current arrival rate.
        Calibrated (``observe_calibration`` seen): seconds-space —
        ``cost_seconds(b) <= target_util / λ`` needs no separate
        capacity estimate, the calibration *is* capacity. Uncalibrated:
        the legacy analytic solve, unchanged."""
        if self._wpf_global is not None:
            lam = self.arrival_rate
            if lam is None:
                return self.levels[-1]
            budget_s = self.target_util / lam      # engine-seconds/request
            for b in reversed(self.levels):
                if self.cost_seconds(b) <= budget_s:
                    return b
            return self.levels[0]
        return self.solve_analytic()

    def solve_analytic(self) -> float:
        """The pure-arithmetic solve (pre-calibration behavior): highest
        level sustaining the arrival rate against EWMA FLOPs/s capacity;
        the lowest when even it is overloaded; the highest when either
        rate is unknown (no evidence of pressure yet)."""
        lam = self.arrival_rate
        cap = self.capacity_flops_per_s
        if lam is None or cap is None:
            return self.levels[-1]
        budget_flops = self.target_util * cap / lam    # per-request allowance
        for b in reversed(self.levels):
            if self.costs[b] <= budget_flops:
                return b
        return self.levels[0]

    def assign(self, requested: float) -> float:
        """Demote ``requested`` to the solved sustainable level (never
        promote): the highest menu level <= min(requested, solve())."""
        ceiling = min(requested, self.solve())
        eligible = [b for b in self.levels if b <= ceiling]
        return max(eligible) if eligible else self.levels[0]
