"""SLA-aware budget control (DESIGN.md §serving).

FlexiDiT's per-step elasticity gives the scheduler a knob no fixed-
compute model has: under load, requests can be *demoted* to a weaker
(cheaper) sampling plan instead of queueing without bound. The
controller solves, from the analytic FLOPs ledger, for the highest
uniform budget level the current arrival rate sustains:

    highest b  s.t.  lambda * F(b) <= target_util * capacity

where ``F(b)`` is the per-request denoising FLOPs of level ``b``'s plan
(``core.scheduler.schedule_flops`` via ``SamplingPlan.flops``, plus the
sequence-parallel padding waste from ``distributed.partition`` when the
plan shards over a mesh) and ``capacity`` is the engine's measured
FLOPs/s. Both rates are EWMA estimates fed by ``observe_*`` hooks, so
deterministic tests can inject them directly.
"""
from __future__ import annotations

from typing import Dict, Optional

from repro.cache.policy import CacheSpec
from repro.configs.base import ModelConfig
from repro.pipeline.plan import SamplingPlan


def request_cost_flops(cfg: ModelConfig, plan: SamplingPlan,
                       sp: int = 1,
                       cache: Optional[CacheSpec] = None,
                       num_train_steps: int = 1000,
                       attn_backend: Optional[str] = None) -> float:
    """Analytic FLOPs one request at ``plan`` costs the engine. With
    ``sp`` sequence-parallel shards the pad-to-divisible waste from the
    partition plan is real compute and is charged too. With ``cache``
    (the engine's cross-step activation cache) skip steps only pay the
    shallow blocks, so the sustainable-budget solve sees the cheaper
    cache-adjusted cost — caching raises the budget level a given
    arrival rate sustains. ``num_train_steps`` must match the serving
    pipeline's diffusion-schedule length: banded/proxy refresh masks
    depend on the ladder's actual ``t`` values.

    Attention is priced at what the plan's backend actually issues
    (DESIGN.md §attention-backend): under 'pallas'/'auto' the segment-
    aware kernel computes block-granular score tiles — a pack's cross-
    segment blocks are skipped, never charged — while the XLA backends
    pay the dense N² convention. Override with ``attn_backend``."""
    backend = plan.attn_backend if attn_backend is None else attn_backend
    if cache is not None and plan.cache is None:
        import dataclasses
        plan = dataclasses.replace(plan, cache=cache)
    fl = (plan.cached_flops(cfg, num_train_steps=num_train_steps,
                            attn_backend=backend)
          if plan.cache is not None
          else plan.flops(cfg, attn_backend=backend))
    if sp > 1:
        from repro.distributed.partition import plan_partition
        part = plan_partition(cfg, plan.resolve_schedule(cfg), sp,
                              plan.parallel)
        fl += part.pad_flops(cfg, cfg_scale_active=plan.guidance_active)
    return fl


class BudgetController:
    """Solves for the degradation level; stateless apart from two EWMAs."""

    def __init__(self, cfg: ModelConfig, plans: Dict[float, SamplingPlan], *,
                 target_util: float = 0.85, alpha: float = 0.3, sp: int = 1,
                 cache: Optional[CacheSpec] = None,
                 num_train_steps: int = 1000,
                 attn_backend: Optional[str] = None):
        if not plans:
            raise ValueError("controller needs a non-empty plan menu")
        if not 0.0 < target_util <= 1.0:
            raise ValueError(f"target_util must be in (0, 1], got "
                             f"{target_util}")
        self.levels = tuple(sorted(plans))            # ascending budgets
        self.costs = {b: request_cost_flops(cfg, p, sp, cache=cache,
                                            num_train_steps=num_train_steps,
                                            attn_backend=attn_backend)
                      for b, p in plans.items()}
        self.target_util = target_util
        self.alpha = alpha
        self._interarrival: Optional[float] = None    # EWMA seconds
        self._last_arrival: Optional[float] = None
        self._flops_per_s: Optional[float] = None     # EWMA capacity

    # ------------------------------------------------------------------
    # Rate estimation

    def observe_arrival(self, now: float) -> None:
        if self._last_arrival is not None:
            gap = max(now - self._last_arrival, 1e-9)
            self._interarrival = (gap if self._interarrival is None else
                                  (1 - self.alpha) * self._interarrival
                                  + self.alpha * gap)
        self._last_arrival = now

    def observe_service(self, flops: float, dt: float) -> None:
        """Feed one completed chunk of work: ``flops`` retired in ``dt``
        seconds of engine time."""
        if dt <= 0:
            return
        rate = flops / dt
        self._flops_per_s = (rate if self._flops_per_s is None else
                             (1 - self.alpha) * self._flops_per_s
                             + self.alpha * rate)

    @property
    def arrival_rate(self) -> Optional[float]:
        return None if not self._interarrival else 1.0 / self._interarrival

    @property
    def capacity_flops_per_s(self) -> Optional[float]:
        return self._flops_per_s

    # ------------------------------------------------------------------
    # The solve

    def solve(self) -> float:
        """Highest budget level sustaining the current arrival rate; the
        lowest level when even it is overloaded; the highest when either
        rate is still unknown (no evidence of pressure yet)."""
        lam = self.arrival_rate
        cap = self.capacity_flops_per_s
        if lam is None or cap is None:
            return self.levels[-1]
        budget_flops = self.target_util * cap / lam    # per-request allowance
        for b in reversed(self.levels):
            if self.costs[b] <= budget_flops:
                return b
        return self.levels[0]

    def assign(self, requested: float) -> float:
        """Demote ``requested`` to the solved sustainable level (never
        promote): the highest menu level <= min(requested, solve())."""
        ceiling = min(requested, self.solve())
        eligible = [b for b in self.levels if b <= ceiling]
        return max(eligible) if eligible else self.levels[0]
