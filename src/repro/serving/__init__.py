"""Continuous-batching DiT serving engine (DESIGN.md §serving).

Iteration-level scheduling over a FlexiPipeline: requests at different
denoise steps and compute budgets are packed token-wise into
compile-once bucket layouts every engine step, with SLA-aware admission
(FIFO / earliest-deadline-first) and load-adaptive budget degradation.
"""
from repro.cache.policy import CacheSpec  # noqa: F401
from repro.cache.store import CacheStore  # noqa: F401
from repro.serving.batcher import BucketMenu, count_chain  # noqa: F401
from repro.serving.controller import (BudgetController,  # noqa: F401
                                      request_cost_flops)
from repro.serving.metrics import (RequestRecord, ServingMetrics,  # noqa: F401
                                   StepRecord)
from repro.serving.queue import Request, RequestQueue  # noqa: F401
from repro.serving.scheduler import (ENGINE_POLICIES, InFlight,  # noqa: F401
                                     LevelPlan, ServedResult, ServingEngine)
