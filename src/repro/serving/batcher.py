"""Token-packing bucket menu (DESIGN.md §serving).

The engine composes every step from a FIXED menu of
:class:`~repro.pipeline.packed.PackLayout` buckets so each bucket
compiles exactly once (geometric count chains keep the menu small — a
handful of shapes covers any demand). ``choose`` picks, for the current
per-mode demand, the bucket serving the most requests with the fewest
packed tokens; requests that don't fit simply wait one iteration
(iteration-level scheduling), and unused slots are padded with dummy
segments whose outputs are discarded (counted by the packing-efficiency
metric, never returned).
"""
from __future__ import annotations

import itertools
from typing import Dict, List, Optional, Sequence, Tuple

from repro.configs.base import ModelConfig
from repro.models import dit as dit_mod
from repro.pipeline.packed import PackLayout


def count_chain(n_max: int) -> Tuple[int, ...]:
    """Geometric bucket sizes (ratio ~1.5) capped at (and including)
    ``n_max`` — demand is rounded up to the next chain value, so at most
    a third of a chosen bucket's slots are ever dummies, while the menu
    stays logarithmic in ``n_max``."""
    if n_max < 1:
        return ()
    out = []
    c = 1
    while c < n_max:
        out.append(c)
        c = max(c + 1, (c * 3) // 2)
    out.append(n_max)
    return tuple(out)


class BucketMenu:
    """All pack layouts the engine may run, derived from the plan menu's
    patch modes and a token budget per engine step."""

    def __init__(self, cfg: ModelConfig, modes: Sequence[int],
                 max_tokens_per_step: int, *, guided: bool = True,
                 row_capacity: int = 0):
        self.cfg = cfg
        self.guided = guided
        self.row_capacity = row_capacity or dit_mod.tokens_for_mode(cfg, 0)
        if max_tokens_per_step < self.row_capacity:
            raise ValueError(
                f"max_tokens_per_step={max_tokens_per_step} below one row "
                f"({self.row_capacity} tokens); nothing can be packed")
        self.max_tokens = max_tokens_per_step
        self.modes = tuple(sorted(set(modes)))
        mult = 2 if guided else 1
        self._seg_tokens = {m: dit_mod.tokens_for_mode(cfg, m)
                            for m in self.modes}
        chains: Dict[int, Tuple[int, ...]] = {}
        for m in self.modes:
            per_req = mult * self._seg_tokens[m]
            chains[m] = count_chain(max_tokens_per_step // per_req)
        self.chains = chains
        budget = max(self.max_tokens, self.row_capacity)
        self.layouts: List[PackLayout] = []
        for combo in itertools.product(
                *[(0,) + chains[m] for m in self.modes]):
            counts = {m: c for m, c in zip(self.modes, combo) if c > 0}
            if not counts:
                continue
            seg_tokens = sum(mult * c * self._seg_tokens[m]
                             for m, c in counts.items())
            if seg_tokens > budget:      # cheap bound before bin packing
                continue
            layout = PackLayout.for_counts(counts, guided=guided,
                                           row_capacity=self.row_capacity)
            if layout.cost(cfg).packed_tokens <= budget:
                self.layouts.append(layout)
        if not self.layouts:
            raise ValueError("empty bucket menu — max_tokens_per_step too "
                             "small for the plan menu's modes")
        # the ledger is pure arithmetic over static layouts: memoize it so
        # per-step bucket selection never recomputes bin packing
        self._ptokens = {l: l.cost(cfg).packed_tokens for l in self.layouts}

    def _packed_tokens(self, layout: PackLayout) -> int:
        """Tokens the hardware computes for one step at ``layout`` —
        row-count (segments never split rows) × capacity (memoized; the
        engine's exact-fit layouts land here on first sight)."""
        pt = self._ptokens.get(layout)
        if pt is None:
            pt = self._ptokens[layout] = layout.cost(self.cfg).packed_tokens
        return pt

    packed_tokens = _packed_tokens

    def greedy_fit(self, req_modes: Sequence[int]
                   ) -> Tuple[List[int], Dict[int, int]]:
        """Pack requests (given in priority order by patch mode) into the
        step's token budget with NO dummy slots: each accepted request
        contributes its CFG segment pair to rows of ``row_capacity``
        tokens, segments of a mode sharing partially-filled rows. Returns
        (accepted indices, per-mode counts) — the exact-fit layout the
        cold planner dispatches."""
        mult = 2 if self.guided else 1
        budget_rows = max(1, self.max_tokens // self.row_capacity)
        rows_used = 0
        free: Dict[int, int] = {}          # mode → open-row slots left
        counts: Dict[int, int] = {}
        accepted: List[int] = []
        for i, m in enumerate(req_modes):
            per_row = max(1, self.row_capacity // self._seg_tokens[m])
            need = mult
            take = min(free.get(m, 0), need)
            new_rows = -(-(need - take) // per_row)
            if rows_used + new_rows > budget_rows:
                continue                   # doesn't fit; try the next one
            free[m] = free.get(m, 0) - take + new_rows * per_row \
                - (need - take)
            rows_used += new_rows
            counts[m] = counts.get(m, 0) + 1
            accepted.append(i)
        return accepted, counts

    @property
    def max_requests(self) -> int:
        """Most requests any single bucket can step at once."""
        return max(l.n_requests for l in self.layouts)

    def choose(self, demand: Dict[int, int],
               among: Optional[Sequence[PackLayout]] = None
               ) -> Optional[PackLayout]:
        """Bucket maximizing requests served for ``demand`` ({mode:
        count}); ties broken by fewest packed tokens, then by the layout
        tuple for determinism. ``among`` restricts the search (the engine
        passes its warm set). None when demand is empty or nothing in
        ``among`` serves it."""
        demand = {m: n for m, n in demand.items() if n > 0}
        if not demand:
            return None
        for m in demand:
            if m not in self.chains:
                raise ValueError(f"mode {m} not in the bucket menu "
                                 f"(modes: {self.modes})")
        best, best_key = None, None
        for layout in (self.layouts if among is None else among):
            served = sum(min(layout.capacity_for(m), n)
                         for m, n in demand.items())
            if served == 0:
                continue
            key = (-served, self._packed_tokens(layout), layout.groups)
            if best_key is None or key < best_key:
                best, best_key = layout, key
        return best

    def served_by(self, layout: PackLayout, demand: Dict[int, int]) -> int:
        return sum(min(layout.capacity_for(m), n)
                   for m, n in demand.items())

    def describe(self) -> str:
        mult = 2 if self.guided else 1
        lines = [f"bucket menu: {len(self.layouts)} layouts, row capacity "
                 f"{self.row_capacity} tok, step budget {self.max_tokens} "
                 f"tok (CFG x{mult})"]
        for m in self.modes:
            lines.append(f"  mode {m}: {self._seg_tokens[m]} tok/segment, "
                         f"counts {self.chains[m]}")
        return "\n".join(lines)
