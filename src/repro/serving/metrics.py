"""Serving telemetry (DESIGN.md §serving).

Tracks per-request lifecycle (arrival → admit → finish, requested vs
served budget, deadline) and per-step token ledgers (real segment tokens
vs what the packed layout computed). All timestamps come from the
engine's clock; percentiles are computed at summary time so a simulated
clock gives deterministic numbers.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Dict, Optional

import numpy as np


@dataclasses.dataclass
class RequestRecord:
    id: int
    arrival: float
    admit: float
    finish: float
    deadline: float
    budget_requested: float
    budget_served: float
    tokens: int                  # useful token-steps this request consumed
    flops: float

    @property
    def latency(self) -> float:
        return self.finish - self.arrival

    @property
    def met_deadline(self) -> bool:
        return self.finish <= self.deadline

    @property
    def degraded(self) -> bool:
        return self.budget_served < self.budget_requested


@dataclasses.dataclass
class StepRecord:
    time: float
    real_tokens: int             # tokens belonging to live requests
    packed_tokens: int           # rows x capacity the hardware computed
    n_requests: int


class ServingMetrics:
    """Lifetime counters plus a bounded sliding window of recent records:
    an engine serving indefinitely must not grow memory per step, and
    percentiles should reflect recent traffic, not the process lifetime.
    ``window=None`` keeps everything (fine for tests and benches)."""

    def __init__(self, window: Optional[int] = 8192):
        self.requests: collections.deque = collections.deque(maxlen=window)
        self.steps: collections.deque = collections.deque(maxlen=window)
        self.total_served = 0
        self.total_steps = 0
        self.total_request_steps = 0   # request-dispatches (Σ cohort sizes)
        self.total_tokens = 0
        self.total_flops = 0.0
        self.total_degraded = 0
        # activation-cache ledger (DESIGN.md §cache): refresh vs skip
        # request-steps, a refresh-interval histogram (gap in denoise
        # steps between consecutive refreshes), and a bytes-resident
        # gauge fed by the engine's CacheStore
        self.cache_refreshes = 0
        self.cache_skips = 0
        self.cache_bytes_resident = 0
        self.refresh_interval_hist: collections.Counter = \
            collections.Counter()
        # segment-aware attention ledger (DESIGN.md §attention-backend):
        # score-block tiles the Pallas kernel visited vs the dense grid —
        # the skip rate is packing's cross-segment work never issued
        self.attn_blocks_active = 0
        self.attn_blocks_total = 0
        # resilience ledger (DESIGN.md §resilience): terminal expiries,
        # non-finite quarantines (each one re-enqueued at full compute),
        # injected poisonings observed, transient slot-alloc failures
        # absorbed, and checksum-forced cache refreshes
        self.total_expired = 0
        self.total_quarantined = 0
        self.total_poisoned = 0
        self.total_alloc_failures = 0
        self.total_integrity_refreshes = 0

    def record_step(self, now: float, real_tokens: int, packed_tokens: int,
                    n_requests: int) -> None:
        self.steps.append(StepRecord(now, real_tokens, packed_tokens,
                                     n_requests))
        self.total_steps += 1
        self.total_request_steps += n_requests

    def record_request(self, rec: RequestRecord) -> None:
        self.requests.append(rec)
        self.total_served += 1
        self.total_tokens += rec.tokens
        self.total_flops += rec.flops
        self.total_degraded += int(rec.degraded)

    def record_cache(self, refreshes: int, skips: int) -> None:
        """One dispatch's refresh/skip request-step counts."""
        self.cache_refreshes += refreshes
        self.cache_skips += skips

    def record_attention_blocks(self, active: int, total: int) -> None:
        """One dispatch's attention block-tile ledger (active <= total)."""
        self.attn_blocks_active += int(active)
        self.attn_blocks_total += int(total)

    def set_cache_bytes(self, n_bytes: int) -> None:
        self.cache_bytes_resident = int(n_bytes)

    def record_refresh_intervals(self, intervals) -> None:
        """A retired request's realized refresh gaps (denoise steps)."""
        self.refresh_interval_hist.update(int(i) for i in intervals)

    # ------------------------------------------------------------------

    @property
    def packing_efficiency(self) -> float:
        """Real segment tokens / packed (computed) tokens, over all steps.
        1.0 means no row padding and no dummy slots."""
        packed = sum(s.packed_tokens for s in self.steps)
        return sum(s.real_tokens for s in self.steps) / packed if packed \
            else 1.0

    @property
    def attn_block_skip_rate(self) -> float:
        """Fraction of score-block tiles the segment-aware kernel skipped
        (cross-segment / padding blocks); 0.0 before any dispatch."""
        if not self.attn_blocks_total:
            return 0.0
        return 1.0 - self.attn_blocks_active / self.attn_blocks_total

    @property
    def cache_hit_rate(self) -> float:
        """Skipped (deep-block replay) request-steps / all cached
        request-steps; 0.0 before any cached dispatch."""
        total = self.cache_refreshes + self.cache_skips
        return self.cache_skips / total if total else 0.0

    def cache_summary(self) -> Dict[str, object]:
        """Activation-cache ledger view (json-friendly; the histogram
        maps refresh gap → count)."""
        return {
            "enabled": bool(self.cache_refreshes + self.cache_skips),
            "hit_rate": self.cache_hit_rate,
            "refreshes": self.cache_refreshes,
            "skips": self.cache_skips,
            "bytes_resident": self.cache_bytes_resident,
            "refresh_interval_hist": {
                str(k): v for k, v in
                sorted(self.refresh_interval_hist.items())},
        }

    def latency_percentiles(self, qs=(50, 99)) -> Dict[str, float]:
        """Latency percentiles over the window; empty window → empty
        dict (absent beats NaN: exporters and log lines just omit the
        keys instead of printing a poisoned value)."""
        if not self.requests:
            return {}
        lat = np.asarray([r.latency for r in self.requests])
        return {f"p{q}": float(np.percentile(lat, q)) for q in qs}

    def summary(self, wall: Optional[float] = None) -> Dict[str, float]:
        """Aggregate view; ``wall`` (seconds of serving) prices tokens/s.
        ``tokens`` counts only useful (real-request) token-steps, so the
        throughput number is directly comparable across batching
        strategies with different padding waste. Counts/tokens/FLOPs are
        lifetime totals; percentiles, hit rates, and packing efficiency
        cover the sliding window."""
        out: Dict[str, float] = {
            "served": float(self.total_served),
            "steps": float(self.total_steps),
            "tokens": float(self.total_tokens),
            "packing_efficiency": self.packing_efficiency,
            "degraded": float(self.total_degraded),
        }
        if self.requests:
            out.update(self.latency_percentiles())
            out["deadline_hit_rate"] = float(
                np.mean([r.met_deadline for r in self.requests]))
            out["flops"] = self.total_flops
        if self.cache_refreshes + self.cache_skips:
            out["cache_hit_rate"] = self.cache_hit_rate
            out["cache_bytes_resident"] = float(self.cache_bytes_resident)
        if self.attn_blocks_total:
            out["attn_block_skip_rate"] = self.attn_block_skip_rate
        # resilience counters appear only once the corresponding event
        # class has occurred, keeping the summary key set stable for
        # clean runs
        if self.total_expired:
            out["expired"] = float(self.total_expired)
        if self.total_quarantined:
            out["quarantined"] = float(self.total_quarantined)
        if self.total_poisoned:
            out["poisoned"] = float(self.total_poisoned)
        if self.total_alloc_failures:
            out["alloc_failures"] = float(self.total_alloc_failures)
        if self.total_integrity_refreshes:
            out["integrity_refreshes"] = float(self.total_integrity_refreshes)
        if wall is not None:
            # wall_s always reports what was passed; rates only when the
            # denominator is meaningful (a zero-wall snapshot — e.g. a
            # simulated clock that has not advanced — must not divide)
            out["wall_s"] = float(wall)
            if wall > 0:
                out["tokens_per_s"] = self.total_tokens / wall
                out["requests_per_s"] = self.total_served / wall
        return out
