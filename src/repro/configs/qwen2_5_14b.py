"""qwen2.5-14b — 48L d_model=5120 40H (GQA kv=8) d_ff=13824 vocab=152064,
QKV bias. [hf:Qwen/Qwen2.5-0.5B; hf]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-14b",
    family="dense",
    num_layers=48,
    d_model=5120,
    d_ff=13824,
    vocab_size=152064,
    attn=AttnConfig(num_heads=40, num_kv_heads=8, head_dim=128,
                    rope_theta=1_000_000.0, qkv_bias=True),
    mlp_activation="swiglu",
    norm_type="rmsnorm",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=32768,
)
