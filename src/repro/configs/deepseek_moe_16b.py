"""deepseek-moe-16b — 28L d_model=2048 16H (MHA kv=16) expert d_ff=1408
vocab=102400, 2 shared + 64 routed top-6 fine-grained experts.
[arXiv:2401.06066; hf]"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    num_layers=28,
    d_model=2048,
    d_ff=1408,
    vocab_size=102400,
    attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=128,
                    rope_theta=10_000.0),
    moe=MoEConfig(num_experts=64, num_experts_per_tok=6,
                  num_shared_experts=2, expert_d_ff=1408,
                  capacity_factor=1.25),
    mlp_activation="swiglu",
    norm_type="rmsnorm",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=32768,
)
