"""Config dataclasses for the repro framework.

Every architecture (the 10 assigned LM-family archs + the paper's own DiT
configs) is described by a ``ModelConfig``. Shapes (train_4k / prefill_32k /
decode_32k / long_500k and the DiT shapes) are described by ``ShapeConfig``.

Configs are plain dataclasses — no framework magic — so they can be
constructed statically in ``src/repro/configs/<arch>.py`` and reduced for
smoke tests via ``reduced()``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Any, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Attention


@dataclass(frozen=True)
class AttnConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    # RoPE
    rope_theta: float = 10_000.0
    use_rope: bool = True
    # Gemma-style logit soft-capping (0 disables).
    logit_softcap: float = 0.0
    # Qwen-style bias on the QKV projections.
    qkv_bias: bool = False
    # Sliding-window size for *local* layers (0 = full attention).
    sliding_window: int = 0
    # Pattern of local(L)/global(G) layers, tiled over depth. "G" = all global.
    # gemma3: "LLLLLG" (5 local : 1 global); gemma2: "LG" alternating.
    local_global_pattern: str = "G"
    # QK-norm (RMS over head_dim) — used by Emu-style DiTs and gemma3.
    qk_norm: bool = False

    def window_for_layer(self, layer: int) -> int:
        """Static per-layer window (0 = full)."""
        pat = self.local_global_pattern
        kind = pat[layer % len(pat)]
        return self.sliding_window if kind == "L" else 0


# ---------------------------------------------------------------------------
# MoE


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    num_experts_per_tok: int
    num_shared_experts: int = 0
    # d_ff of each routed expert (deepseek-moe uses fine-grained experts).
    expert_d_ff: int = 0
    # capacity factor for sort-based dispatch.
    capacity_factor: float = 1.25
    # router jitter / z-loss coefficients.
    router_z_loss: float = 1e-3
    load_balance_loss: float = 1e-2


# ---------------------------------------------------------------------------
# SSM (Mamba2 / SSD)


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128
    num_heads: int = 0        # SSD heads; 0 → derived as d_inner // head_dim
    head_dim: int = 64
    expand: int = 2           # d_inner = expand * d_model
    chunk_size: int = 64      # SSD chunk length
    conv_width: int = 4       # depthwise conv width


# ---------------------------------------------------------------------------
# DiT / FlexiDiT


@dataclass(frozen=True)
class DiTConfig:
    # Latent input: (frames, height, width, channels). frames=1 → image.
    latent_shape: Tuple[int, int, int, int] = (1, 32, 32, 4)
    # Pre-trained ("powerful") patch size (p_f, p_h, p_w).
    patch_size: Tuple[int, int, int] = (1, 2, 2)
    # Additional ("weak") patch sizes the model is flexified to.
    flex_patch_sizes: Tuple[Tuple[int, int, int], ...] = ((1, 4, 4),)
    # Underlying patch size p' the flexible embed weights are stored at.
    underlying_patch_size: Tuple[int, int, int] = (1, 4, 4)
    # Conditioning: 'class' (adaLN label embedding), 'text' (cross-attn), 'none'
    conditioning: str = "class"
    num_classes: int = 1000
    text_len: int = 77
    text_dim: int = 0            # 0 → d_model
    learn_sigma: bool = True     # c_out = 2 * c_in
    # LoRA conversion recipe (Sec 3.2); 0 = shared-params recipe (Sec 3.1).
    lora_rank: int = 0


# ---------------------------------------------------------------------------
# Model


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | vlm | audio | dit
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    attn: Optional[AttnConfig] = None
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    dit: Optional[DiTConfig] = None
    # Activation for the MLP: 'swiglu' | 'gelu' | 'geglu'
    mlp_activation: str = "swiglu"
    norm_type: str = "rmsnorm"   # rmsnorm | layernorm
    tie_embeddings: bool = False
    # Gemma-style final-logit softcap (0 disables).
    final_logit_softcap: float = 0.0
    # Gemma multiplies embeddings by sqrt(d_model).
    scale_embeddings: bool = False
    # Gemma-2/3 style post-attention/post-ffw norms in addition to pre-norms.
    use_post_norm: bool = False
    # VLM: insert a cross-attention layer every k self-attn layers (0 = none).
    cross_attn_every: int = 0
    vision_tokens: int = 0
    # audio (whisper): encoder layers (decoder layers = num_layers).
    encoder_layers: int = 0
    audio_frames: int = 0
    # dtypes
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    # remat policy for training: 'none' | 'block' | 'dots'
    remat: str = "block"
    # Unroll layer/block scans into straight-line HLO. Used by the dry-run
    # cost calibration: XLA cost_analysis counts while-loop bodies ONCE, so
    # FLOPs/collectives inside lax.scan are undercounted by ~L×. The dry-run
    # compiles unrolled 1- and 2-layer variants and extrapolates (see
    # launch/dryrun.py); the scanned compile is kept for the memory proof.
    unroll: bool = False
    # KV-cache storage dtype for decode: "compute" (bf16) or "int8"
    # (per-(position, head) absmax quantization — §Perf addendum: decode is
    # HBM-bound on weights+cache; int8 halves cache bytes).
    kv_cache_dtype: str = "compute"
    # Megatron-style sequence parallelism: shard the residual stream's
    # sequence dim over the model axis between blocks. Cuts saved-activation
    # memory (→ fewer grad-accumulation microbatches → less collective
    # traffic) and converts activation all-reduces into rs/ag pairs. §Perf.
    sequence_parallel: bool = False
    max_seq_len: int = 8192

    # ---- derived -----------------------------------------------------------
    @property
    def head_dim(self) -> int:
        assert self.attn is not None
        return self.attn.head_dim

    def num_params(self) -> int:
        """Analytic parameter count (approximate; embeddings included)."""
        d, f, L, V = self.d_model, self.d_ff, self.num_layers, self.vocab_size
        total = 0
        if self.family != "dit":
            total += V * d                       # token embedding
            if not self.tie_embeddings:
                total += V * d                   # lm head
        att = 0
        if self.attn is not None:
            a = self.attn
            att = d * a.num_heads * a.head_dim + 2 * d * a.num_kv_heads * a.head_dim \
                + a.num_heads * a.head_dim * d
        mlp_mult = 3 if self.mlp_activation in ("swiglu", "geglu") else 2
        ffn = mlp_mult * d * f if f else 0
        moe = 0
        if self.moe is not None:
            m = self.moe
            e_ff = m.expert_d_ff or f
            moe = m.num_experts * mlp_mult * d * e_ff \
                + m.num_shared_experts * mlp_mult * d * e_ff + d * m.num_experts
            ffn = 0
        ssm = 0
        if self.ssm is not None:
            s = self.ssm
            d_in = s.expand * d
            nheads = s.num_heads or max(1, d_in // s.head_dim)
            # in-proj (z, x), B/C projections, dt head bias, out-proj (mamba2)
            ssm = d * 2 * d_in + d * 2 * s.state_dim + d * nheads + d_in * d
        per_layer = att + ffn + moe + ssm + 2 * d  # + norms
        total += L * per_layer
        return total

    def active_params(self) -> int:
        """Active parameters per token (for MoE rooflines)."""
        if self.moe is None:
            return self.num_params()
        d, L = self.d_model, self.num_layers
        m = self.moe
        mlp_mult = 3 if self.mlp_activation in ("swiglu", "geglu") else 2
        e_ff = m.expert_d_ff or self.d_ff
        dense = self.num_params() - L * m.num_experts * mlp_mult * d * e_ff
        active = L * m.num_experts_per_tok * mlp_mult * d * e_ff
        return dense + active

    def reduced(self, **overrides: Any) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        d = 64
        attn = None
        if self.attn is not None:
            a = self.attn
            kv = max(1, min(2, a.num_kv_heads))
            attn = replace(
                a, num_heads=4, num_kv_heads=kv if 4 % kv == 0 else 1,
                head_dim=16, sliding_window=min(a.sliding_window, 32) if a.sliding_window else 0,
            )
        moe = None
        if self.moe is not None:
            moe = replace(self.moe, num_experts=4,
                          num_experts_per_tok=min(2, self.moe.num_experts_per_tok),
                          num_shared_experts=min(1, self.moe.num_shared_experts),
                          expert_d_ff=32 if self.moe.expert_d_ff else 0)
        ssm = None
        if self.ssm is not None:
            ssm = replace(self.ssm, state_dim=16, head_dim=16, chunk_size=16)
        dit = None
        if self.dit is not None:
            dit = replace(self.dit, latent_shape=(self.dit.latent_shape[0] if
                          self.dit.latent_shape[0] == 1 else 4, 16, 16, 4),
                          num_classes=10, text_len=8)
        kw: dict = dict(
            num_layers=2, d_model=d, d_ff=128 if self.d_ff else 0,
            vocab_size=256 if self.vocab_size else 0,
            attn=attn, moe=moe, ssm=ssm, dit=dit,
            encoder_layers=2 if self.encoder_layers else 0,
            audio_frames=16 if self.audio_frames else 0,
            vision_tokens=8 if self.vision_tokens else 0,
            cross_attn_every=2 if self.cross_attn_every else 0,
            param_dtype="float32", compute_dtype="float32",
            max_seq_len=128, remat="none",
        )
        kw.update(overrides)
        return replace(self, **kw)


# ---------------------------------------------------------------------------
# Shapes


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str    # 'train' | 'prefill' | 'decode'


LM_SHAPES: Tuple[ShapeConfig, ...] = (
    ShapeConfig("train_4k", 4_096, 256, "train"),
    ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    ShapeConfig("decode_32k", 32_768, 128, "decode"),
    ShapeConfig("long_500k", 524_288, 1, "decode"),
)


def get_shape(name: str) -> ShapeConfig:
    for s in LM_SHAPES:
        if s.name == name:
            return s
    raise KeyError(name)


# Archs for which long_500k is skipped (pure full attention — see DESIGN.md).
LONG_CONTEXT_OK = {"mamba2-130m", "hymba-1.5b", "gemma3-4b", "gemma2-9b"}


def cell_is_skipped(arch: str, shape: str) -> Optional[str]:
    """Return a skip-reason string if this (arch, shape) cell is skipped."""
    if shape == "long_500k" and arch not in LONG_CONTEXT_OK and not arch.startswith("dit"):
        return "pure full-attention arch: long_500k needs sub-quadratic mixing (DESIGN.md)"
    return None


# ---------------------------------------------------------------------------
# Training


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 1e-4
    weight_decay: float = 0.0
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 1_000
    schedule: str = "cosine"            # cosine | linear | constant
    ema_rate: float = 0.9999
    microbatch: int = 0                 # 0 = no gradient accumulation
    zero_sharded_opt_state: bool = True
    grad_compression: str = "none"      # none | int8_ef
    opt_dtype: str = "float32"          # bf16 moments for 100B+ models
    seed: int = 0
