"""gemma2-9b — 42L d_model=3584 16H (GQA kv=8) d_ff=14336 vocab=256000,
local/global alternating attention, logit softcap. [arXiv:2408.00118; hf]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    d_ff=14336,
    vocab_size=256000,
    attn=AttnConfig(num_heads=16, num_kv_heads=8, head_dim=256,
                    rope_theta=10_000.0, sliding_window=4096,
                    local_global_pattern="LG", logit_softcap=50.0),
    mlp_activation="geglu",
    norm_type="rmsnorm",
    tie_embeddings=True,
    scale_embeddings=True,
    use_post_norm=True,
    final_logit_softcap=30.0,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=524288,
)
