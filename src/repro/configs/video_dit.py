"""video-dit — the paper's 4.9B text-to-video DiT (§4.3, MovieGen-style):
32×88×48 latent space, pre-trained patch (1,2,2) → 33792 tokens, flexified
to 'temporal' (2,2,2) and 'spatial' (1,4,4) weak modes; LoRA rank 64."""
from repro.configs.base import AttnConfig, DiTConfig, ModelConfig

CONFIG = ModelConfig(
    name="video-dit",
    family="dit",
    num_layers=32,
    d_model=3072,
    d_ff=12288,
    vocab_size=0,
    attn=AttnConfig(num_heads=24, num_kv_heads=24, head_dim=128,
                    use_rope=False, qk_norm=True),
    dit=DiTConfig(latent_shape=(32, 88, 48, 8), patch_size=(1, 2, 2),
                  flex_patch_sizes=((2, 2, 2), (1, 4, 4)),
                  underlying_patch_size=(2, 4, 4),
                  conditioning="text", text_len=256, text_dim=3072,
                  learn_sigma=False, lora_rank=64),
    mlp_activation="gelu",
    norm_type="layernorm",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=65536,
)
