"""gemma3-4b — 34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144,
5:1 local:global sliding-window attention, 128k context.
[hf:google/gemma-3-1b-pt; unverified]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-4b",
    family="dense",
    num_layers=34,
    d_model=2560,
    d_ff=10240,
    vocab_size=262144,
    attn=AttnConfig(num_heads=8, num_kv_heads=4, head_dim=256,
                    rope_theta=1_000_000.0, sliding_window=1024,
                    local_global_pattern="LLLLLG", qk_norm=True),
    mlp_activation="geglu",
    norm_type="rmsnorm",
    tie_embeddings=True,
    scale_embeddings=True,
    use_post_norm=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=524288,
)
