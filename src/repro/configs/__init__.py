"""Architecture registry: ``get_config(arch_id)`` → ModelConfig."""
from typing import Dict, List

from repro.configs.base import (AttnConfig, DiTConfig, LM_SHAPES, MoEConfig,
                                ModelConfig, SSMConfig, ShapeConfig,
                                TrainConfig, cell_is_skipped, get_shape)

from repro.configs.grok_1_314b import CONFIG as _grok
from repro.configs.deepseek_moe_16b import CONFIG as _dsmoe
from repro.configs.deepseek_7b import CONFIG as _ds7
from repro.configs.gemma3_4b import CONFIG as _g3
from repro.configs.qwen2_5_14b import CONFIG as _qwen
from repro.configs.gemma2_9b import CONFIG as _g2
from repro.configs.llama_3_2_vision_90b import CONFIG as _lv
from repro.configs.whisper_small import CONFIG as _wh
from repro.configs.hymba_1_5b import CONFIG as _hy
from repro.configs.mamba2_130m import CONFIG as _m2
from repro.configs.dit_xl_2 import CONFIG as _dit
from repro.configs.t2i_transformer import CONFIG as _t2i
from repro.configs.video_dit import CONFIG as _vdit

ASSIGNED_ARCHS: List[str] = [
    "grok-1-314b", "deepseek-moe-16b", "deepseek-7b", "gemma3-4b",
    "qwen2.5-14b", "gemma2-9b", "llama-3.2-vision-90b", "whisper-small",
    "hymba-1.5b", "mamba2-130m",
]

DIT_ARCHS: List[str] = ["dit-xl-2", "t2i-transformer", "video-dit"]

REGISTRY: Dict[str, ModelConfig] = {c.name: c for c in [
    _grok, _dsmoe, _ds7, _g3, _qwen, _g2, _lv, _wh, _hy, _m2,
    _dit, _t2i, _vdit,
]}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
