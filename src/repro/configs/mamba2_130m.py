"""mamba2-130m — 24L d_model=768 attention-free SSD (state-space duality),
ssm_state=128 vocab=50280. [arXiv:2405.21060; unverified]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    d_ff=0,
    vocab_size=50280,
    attn=None,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_size=128),
    norm_type="rmsnorm",
    tie_embeddings=True,
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=524288,
)
