"""deepseek-7b — 30L d_model=4096 32H (MHA kv=32) d_ff=11008 vocab=102400,
llama architecture. [arXiv:2401.02954; hf]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    d_ff=11008,
    vocab_size=102400,
    attn=AttnConfig(num_heads=32, num_kv_heads=32, head_dim=128,
                    rope_theta=10_000.0),
    mlp_activation="swiglu",
    norm_type="rmsnorm",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=32768,
)
