"""llama-3.2-vision-90b — 100L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256, gated cross-attention image layers every 5 layers (stub vision
frontend: input_specs provides precomputed patch embeddings).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    d_ff=28672,
    vocab_size=128256,
    attn=AttnConfig(num_heads=64, num_kv_heads=8, head_dim=128,
                    rope_theta=500_000.0),
    cross_attn_every=5,
    vision_tokens=1600,
    mlp_activation="swiglu",
    norm_type="rmsnorm",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=32768,
)
