"""hymba-1.5b — 32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001,
parallel attention + mamba heads in every block, ssm_state=16.
[arXiv:2411.13676; hf]"""
from repro.configs.base import AttnConfig, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    d_ff=5504,
    vocab_size=32001,
    attn=AttnConfig(num_heads=25, num_kv_heads=5, head_dim=64,
                    rope_theta=10_000.0),
    ssm=SSMConfig(state_dim=16, head_dim=64, expand=2, chunk_size=128),
    mlp_activation="swiglu",
    norm_type="rmsnorm",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=524288,
)
