"""whisper-small — enc-dec, 12L encoder + 12L decoder, d_model=768 12H
d_ff=3072 vocab=51865, conv frontend stubbed (input_specs provides
precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import AttnConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    encoder_layers=12,
    audio_frames=1500,
    d_model=768,
    d_ff=3072,
    vocab_size=51865,
    attn=AttnConfig(num_heads=12, num_kv_heads=12, head_dim=64,
                    use_rope=False),
    mlp_activation="gelu",
    norm_type="layernorm",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=32768,
)
