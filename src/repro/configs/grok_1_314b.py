"""grok-1-314b — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2. [hf:xai-org/grok-1; unverified]"""
from repro.configs.base import AttnConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    d_ff=32768,
    vocab_size=131072,
    attn=AttnConfig(num_heads=48, num_kv_heads=8, head_dim=128,
                    rope_theta=10_000.0),
    moe=MoEConfig(num_experts=8, num_experts_per_tok=2,
                  expert_d_ff=32768, capacity_factor=1.25),
    mlp_activation="gelu",
    norm_type="rmsnorm",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=32768,
)
