"""t2i-transformer — the paper's text-to-image DiT (Emu-like config used in
Fig. 9: 24L d=2048; cross-attention text conditioning; 128×128 latent space,
patch 2 → 4096 tokens; LoRA recipe §3.2 with rank 64)."""
from repro.configs.base import AttnConfig, DiTConfig, ModelConfig

CONFIG = ModelConfig(
    name="t2i-transformer",
    family="dit",
    num_layers=24,
    d_model=2048,
    d_ff=8192,
    vocab_size=0,
    attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=128,
                    use_rope=False, qk_norm=True),
    dit=DiTConfig(latent_shape=(1, 128, 128, 8), patch_size=(1, 2, 2),
                  flex_patch_sizes=((1, 4, 4),),
                  underlying_patch_size=(1, 4, 4),
                  conditioning="text", text_len=77, text_dim=2048,
                  learn_sigma=False, lora_rank=64),
    mlp_activation="gelu",
    norm_type="layernorm",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=16384,
)
