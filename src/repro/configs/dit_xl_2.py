"""dit-xl-2 — the paper's class-conditioned ImageNet model (DiT-XL/2,
Peebles & Xie 2023): 28L d=1152 16H d_ff=4608, 256×256 images → 32×32×4
latents, patch size 2, flexified to patch size 4 (§4.1, shared-params
recipe)."""
from repro.configs.base import AttnConfig, DiTConfig, ModelConfig

CONFIG = ModelConfig(
    name="dit-xl-2",
    family="dit",
    num_layers=28,
    d_model=1152,
    d_ff=4608,
    vocab_size=0,
    attn=AttnConfig(num_heads=16, num_kv_heads=16, head_dim=72,
                    use_rope=False),
    dit=DiTConfig(latent_shape=(1, 32, 32, 4), patch_size=(1, 2, 2),
                  flex_patch_sizes=((1, 4, 4),),
                  underlying_patch_size=(1, 4, 4),
                  conditioning="class", num_classes=1000,
                  learn_sigma=True, lora_rank=0),
    mlp_activation="gelu",
    norm_type="layernorm",
    param_dtype="bfloat16",
    compute_dtype="bfloat16",
    max_seq_len=1024,
)
