"""Pallas TPU kernel for the FlexiDiT tokenizer hot-path.

The flexible patch embedding is a strided conv ≡ ``[N, p³·c] × [p³·c, d]``
matmul after patch extraction. On TPU this is an MXU matmul whose LHS is
re-laid-out per patch size; the kernel tiles N and d in 128-aligned VMEM
blocks with the (small) contraction dim resident. The PI-resize projection
is folded into the weight once per mode instantiation (App. C.2), so the
kernel itself is patch-size-agnostic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; 0.5+ renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _embed_kernel(x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...]                     # [bn, K]
    w = w_ref[...]                     # [K, bd]
    acc = jax.lax.dot_general(x, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] = (acc + b_ref[...].astype(jnp.float32)[None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "block_d", "interpret"))
def patch_embed_pallas(patches: jax.Array, w: jax.Array, b: jax.Array, *,
                       block_n: int = 256, block_d: int = 256,
                       interpret: bool = True) -> jax.Array:
    """patches: [N, K] (K = p_f·p_h·p_w·c); w: [K, d]; b: [d] → [N, d]."""
    N, K = patches.shape
    d = w.shape[1]
    bn = min(block_n, N)
    bd = min(block_d, d)
    assert N % bn == 0 and d % bd == 0, (N, d, bn, bd)

    return pl.pallas_call(
        _embed_kernel,
        grid=(N // bn, d // bd),
        in_specs=[
            pl.BlockSpec((bn, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, bd), lambda i, j: (0, j)),
            pl.BlockSpec((bd,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bn, bd), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((N, d), patches.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(patches, w, b)


def _deembed_kernel(t_ref, w_ref, b_ref, o_ref):
    t = t_ref[...]                     # [bn, d]
    w = w_ref[...]                     # [d, bk]
    acc = jax.lax.dot_general(t, w, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    o_ref[...] = (acc + b_ref[...].astype(jnp.float32)[None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def patch_deembed_pallas(tokens: jax.Array, w: jax.Array, b: jax.Array, *,
                         block_n: int = 256,
                         interpret: bool = True) -> jax.Array:
    """tokens: [N, d]; w: [d, K_out]; b: [K_out] → [N, K_out]."""
    N, d = tokens.shape
    K = w.shape[1]
    bn = min(block_n, N)
    assert N % bn == 0

    return pl.pallas_call(
        _deembed_kernel,
        grid=(N // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d, K), lambda i: (0, 0)),
            pl.BlockSpec((K,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, K), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, K), tokens.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(tokens, w, b)
