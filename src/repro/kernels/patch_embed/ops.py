"""Jitted wrappers: flexible tokenize/de-tokenize via the Pallas kernels.

Drop-in accelerated versions of ``repro.core.patch.embed_tokens_flex`` /
``deembed_tokens_flex`` (the PI-resize projection is folded into the weight
before the kernel runs, so mode switching costs nothing per NFE).
"""
from __future__ import annotations

import os
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import patch as patch_mod
from repro.core import resize
from repro.kernels.patch_embed.patch_embed import (patch_deembed_pallas,
                                                   patch_embed_pallas)

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"

Patch = Tuple[int, int, int]


def embed_tokens_flex(w_flex, b, x, p: Patch, p_prime: Patch,
                      block_n: int = 256, block_d: int = 256):
    W = resize.project_embed(w_flex, p, p_prime)            # [pp, c, d]
    K = W.shape[0] * W.shape[1]
    d = W.shape[2]
    patches = patch_mod.patchify(x, p)                      # [B,N,pp,c]
    B, N = patches.shape[:2]
    flat = patches.reshape(B * N, K)
    tok = patch_embed_pallas(flat, W.reshape(K, d).astype(x.dtype),
                             b.astype(x.dtype),
                             block_n=min(block_n, B * N),
                             block_d=min(block_d, d), interpret=INTERPRET)
    return tok.reshape(B, N, d)


def deembed_tokens_flex(w_flex, b_flex, tok, latent_shape, p: Patch,
                        p_prime: Patch, c_out: int, block_n: int = 256):
    W = resize.project_deembed(w_flex, p, p_prime)          # [d, c, pp]
    Bb = resize.project_deembed_bias(b_flex, p, p_prime)    # [c, pp]
    d = W.shape[0]
    K = W.shape[1] * W.shape[2]
    B, N = tok.shape[:2]
    out = patch_deembed_pallas(tok.reshape(B * N, d),
                               W.reshape(d, K).astype(tok.dtype),
                               Bb.reshape(K).astype(tok.dtype),
                               block_n=min(block_n, B * N),
                               interpret=INTERPRET)
    # kernel output layout is [.., c*pp]; unpatchify expects [.., pp, c]
    pp = W.shape[2]
    patches = out.reshape(B, N, c_out, pp).transpose(0, 1, 3, 2)
    return patch_mod.unpatchify(patches, latent_shape, p)
