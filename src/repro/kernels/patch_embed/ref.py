"""Pure-jnp oracle for the patch embed/de-embed kernels."""
import jax
import jax.numpy as jnp


def patch_embed_ref(patches: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    out = jnp.einsum("nk,kd->nd", patches.astype(jnp.float32),
                     w.astype(jnp.float32)) + b.astype(jnp.float32)
    return out.astype(patches.dtype)


def patch_deembed_ref(tokens: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    out = jnp.einsum("nd,dk->nk", tokens.astype(jnp.float32),
                     w.astype(jnp.float32)) + b.astype(jnp.float32)
    return out.astype(tokens.dtype)
