"""Jitted public wrapper for the flash attention kernel."""
from __future__ import annotations

import jax

from repro.kernels.attention.flash_attention import flash_attention as _fa

# On this CPU-only container the kernel body executes via interpret mode;
# on TPU set REPRO_PALLAS_INTERPRET=0.
import os
INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def flash_attention(q, k, v, *, causal=True, softcap=0.0, window=0,
                    segment_ids=None, block_map=None,
                    block_q=128, block_k=128):
    return _fa(q, k, v, causal=causal, softcap=softcap, window=window,
               segment_ids=segment_ids, block_map=block_map,
               block_q=block_q, block_k=block_k, interpret=INTERPRET)


def compile_cache_size() -> int:
    """Number of compiled flash-attention executables (tests assert this
    stays flat across pack-layout switches under a fixed bucket shape)."""
    return _fa._cache_size()
