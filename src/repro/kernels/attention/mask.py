"""Shared attention-mask algebra (DESIGN.md §attention-backend).

One module owns the segment/position mask semantics so the Pallas flash
kernel, the XLA dense path (``models.attention.make_attention_bias``),
the blocked long-sequence path, and the distributed ring/Ulysses inner
loops cannot drift apart:

* :func:`segment_allowed` — the elementwise mask tile. Padding tokens
  carry segment id < 0 and neither attend nor are attended to; real
  tokens attend only within their segment.
* :func:`position_allowed` — causal / sliding-window tile (``window``
  may be a traced int32 scalar; 0 means no window).
* :func:`attention_block_map` — the per-(q block, k block) activity map
  the Pallas kernel uses to SKIP kv blocks whose segment range cannot
  intersect the query block. Built from per-block segment-id intervals,
  it is exact when segment ids are sorted along the row (how
  ``core.packing`` lays packs out) and a conservative superset
  otherwise — the elementwise mask inside the kernel stays the source
  of truth either way. The map is plain int32 DATA: inside jit it is a
  traced array, so swapping pack layouts under a fixed bucket shape
  never recompiles the kernel.

Everything here runs on numpy arrays too (the analytic FLOPs ledger
builds host-side block maps from static pack layouts via
``kernels.attention.costing``).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _xp(*arrays):
    """numpy for host values, jnp once anything is traced/device-placed."""
    return jnp if any(isinstance(a, jax.Array) for a in arrays) else np


def segment_allowed(q_seg, k_seg):
    """[..., Sq] x [..., Sk] segment ids → [..., Sq, Sk] bool allowed.

    Tokens attend only within their own segment; ids < 0 mark padding,
    which never attends (query side) nor is attended to (key side).
    """
    xp = _xp(q_seg, k_seg)
    qs = q_seg[..., :, None]
    ks = k_seg[..., None, :]
    return xp.logical_and(xp.logical_and(qs == ks, qs >= 0), ks >= 0)


def position_allowed_grid(q_pos, k_pos, *, causal: bool, window=0):
    """Elementwise position mask over broadcast-compatible grids.

    The Pallas tile path feeds full [bq, bk] rank-2 position grids (TPU
    Mosaic rejects 1-D iota); the vector variant below feeds expanded
    [..., Sq, 1] x [..., 1, Sk] axes. ``window`` may be a traced int32
    scalar: 0 means full attention, w > 0 keeps only
    |q_pos - k_pos| < w (plus causality when set).
    """
    xp = _xp(q_pos, k_pos, window)
    window = xp.asarray(window, np.int32)
    in_window = xp.logical_and(q_pos - k_pos < window,
                               k_pos - q_pos < window)
    allowed = xp.where(window > 0, in_window, True)
    if causal:
        allowed = xp.logical_and(allowed, q_pos >= k_pos)
    return allowed


def position_allowed(q_pos, k_pos, *, causal: bool, window=0):
    """[..., Sq] x [..., Sk] positions → [..., Sq, Sk] bool allowed."""
    return position_allowed_grid(q_pos[..., :, None], k_pos[..., None, :],
                                 causal=causal, window=window)


def _block_seg_ranges(seg, block: int):
    """[B, S] ids → per-block (min, max) over real (id >= 0) tokens.
    Blocks holding no real token get (BIG, -1), an empty interval."""
    xp = _xp(seg)
    B, S = seg.shape
    assert S % block == 0, (S, block)
    tiles = seg.reshape(B, S // block, block)
    big = np.int32(np.iinfo(np.int32).max)
    lo = xp.min(xp.where(tiles >= 0, tiles, big), axis=2)
    hi = xp.max(xp.where(tiles >= 0, tiles, -1), axis=2)
    return lo, hi


def block_position_envelope(n_q: int, n_k: int, block_q: int, block_k: int, *,
                            causal: bool, window: int = 0) -> np.ndarray:
    """Static [n_q, n_k] bool: can ANY (q, k) pair in the block pair be
    position-visible? Pure numpy — shapes and window are static here."""
    q_lo = np.arange(n_q) * block_q
    q_hi = q_lo + block_q - 1
    k_lo = np.arange(n_k) * block_k
    k_hi = k_lo + block_k - 1
    env = np.ones((n_q, n_k), bool)
    if causal:
        env &= q_hi[:, None] >= k_lo[None, :]
    if int(window) > 0:
        w = int(window)
        env &= (q_lo[:, None] - k_hi[None, :] < w) \
            & (k_lo[None, :] - q_hi[:, None] < w)
    return env


def attention_block_map(q_seg, k_seg, *, block_q: int, block_k: int,
                        causal: bool = False, window: int = 0):
    """[B, Sq] x [B, Sk] segment ids → [B, n_q, n_k] int32 block map
    (1 = the kernel must visit the block, 0 = provably fully masked).

    A block pair is active when the q block's [min, max] real-segment
    interval intersects the k block's AND the static position envelope
    (causal / window over whole blocks) allows at least one pair.
    Always a superset of the exact elementwise mask; exact for
    row-sorted segment ids. ``window`` must be static here (traced
    windows route to the XLA backends, see ``models.attention``).
    """
    xp = _xp(q_seg, k_seg)
    q_lo, q_hi = _block_seg_ranges(q_seg, block_q)
    k_lo, k_hi = _block_seg_ranges(k_seg, block_k)
    active = xp.logical_and(q_lo[:, :, None] <= k_hi[:, None, :],
                            k_lo[:, None, :] <= q_hi[:, :, None])
    env = block_position_envelope(q_lo.shape[1], k_lo.shape[1],
                                  block_q, block_k,
                                  causal=causal, window=window)
    return xp.logical_and(active, xp.asarray(env)[None]).astype(np.int32)


def pad_to_block_multiple(seg: Optional[jax.Array], B: int, S: int,
                          block: int) -> Tuple[jax.Array, int]:
    """Segment ids padded to a block multiple (-1 = padding), synthesizing
    all-zeros ids when none were given. Returns (ids [B, S_pad], S_pad)."""
    xp = _xp(seg)
    target = -(-S // block) * block
    if seg is None:
        seg = xp.zeros((B, S), np.int32)
    if target != S:
        pad = xp.full((B, target - S), -1, np.int32)
        seg = xp.concatenate([seg, pad], axis=1)
    return seg, target
