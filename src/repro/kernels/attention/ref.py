"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, softcap: float = 0.0,
                  window: int = 0) -> jax.Array:
    """q: [B,S,H,hd]; k,v: [B,Sk,K,hd] (GQA) → [B,S,H,hd], f32 math."""
    B, S, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    qh = q.reshape(B, S, K, G, hd).astype(jnp.float32)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qh, k.astype(jnp.float32))
    s = s / np.sqrt(hd)
    if softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    qp = jnp.arange(S)[:, None]
    kp = jnp.arange(Sk)[None, :]
    mask = jnp.ones((S, Sk), bool)
    if causal:
        mask &= qp >= kp
    if window > 0:
        mask &= (qp - kp < window) & (kp - qp < window)
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p, v.astype(jnp.float32))
    return o.reshape(B, S, H, hd).astype(q.dtype)
