"""Analytic FLOPs for dense vs block-sparse (Pallas) attention
(DESIGN.md §attention-backend).

The segment-aware flash kernel skips every kv block whose segment range
cannot intersect the query block, so the score/value FLOPs of a packed
row are ``4 · d · Σ_active(block_q · block_k)`` — not the dense
``4 · d · C²``. These helpers price that from the SAME block-map code
the kernel runs (``kernels.attention.mask``), on the host with plain
numpy, so the serving controller, the cache ledger, and the benches
agree with the device to the block.

All counts are per layer, batch 1, mul+add counted separately (the
repo-wide convention of ``core.scheduler``).
"""
from __future__ import annotations

from typing import Sequence, Tuple

import numpy as np

from repro.kernels.attention.mask import attention_block_map

# Must match the flash_attention defaults — the ledger prices what the
# default kernel launch computes.
DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


def effective_blocks(S: int, block_q: int = DEFAULT_BLOCK_Q,
                     block_k: int = DEFAULT_BLOCK_K) -> Tuple[int, int]:
    """The (block_q, block_k) a ``flash_attention`` launch actually tiles
    an S-token sequence with: clamped to S (each axis pads independently
    to its own block multiple, mirroring the kernel wrapper)."""
    return min(block_q, S), min(block_k, S)


def dense_attention_flops(n_q: int, n_k: int, d_model: int) -> float:
    """QK^T + PV over full [n_q, n_k] scores (one layer, all heads)."""
    return float(2 * 2 * n_q * n_k * d_model)


def segments_to_ids(seg_lengths: Sequence[int], capacity: int) -> np.ndarray:
    """One packed row's segment-id vector [1, capacity]: segments laid
    out contiguously in order, -1 padding to capacity (exactly how
    ``core.packing.packed_mixed_forward`` assembles rows)."""
    total = int(sum(seg_lengths))
    if total > capacity:
        raise ValueError(f"segments ({total} tokens) exceed row capacity "
                         f"{capacity}")
    ids = np.full((1, capacity), -1, np.int32)
    off = 0
    for s, n in enumerate(seg_lengths):
        ids[0, off:off + n] = s
        off += n
    return ids


def block_map_counts(seg_ids: np.ndarray, *, block_q: int = DEFAULT_BLOCK_Q,
                     block_k: int = DEFAULT_BLOCK_K, causal: bool = False,
                     window: int = 0) -> Tuple[int, int, int, int]:
    """(active, total, bq, bk) kv-block visits for [B, S] segment ids,
    padded to block multiples exactly as the kernel pads."""
    B, S = seg_ids.shape
    bq, bk = effective_blocks(S, block_q, block_k)

    def padded(ids, b):
        pad = (-S) % b
        if not pad:
            return ids
        return np.concatenate([ids, np.full((B, pad), -1, np.int32)], axis=1)

    bm = np.asarray(attention_block_map(padded(seg_ids, bq),
                                        padded(seg_ids, bk), block_q=bq,
                                        block_k=bk, causal=causal,
                                        window=window))
    return int(bm.sum()), int(bm.size), bq, bk


def block_sparse_attention_flops(seg_lengths: Sequence[int], capacity: int,
                                 d_model: int, *,
                                 block_q: int = DEFAULT_BLOCK_Q,
                                 block_k: int = DEFAULT_BLOCK_K) -> float:
    """Score/value FLOPs (one layer) the segment-aware kernel issues for
    one packed row: 4·d per visited (block_q · block_k) score tile."""
    ids = segments_to_ids(seg_lengths, capacity)
    active, _total, bq, bk = block_map_counts(ids, block_q=block_q,
                                              block_k=block_k)
    return float(active) * dense_attention_flops(bq, bk, d_model)


def pack_attention_stats(row_seg_lengths: Sequence[Sequence[int]],
                         capacity: int, *,
                         block_q: int = DEFAULT_BLOCK_Q,
                         block_k: int = DEFAULT_BLOCK_K
                         ) -> Tuple[int, int]:
    """(active, total) block visits for a whole pack — one entry per row,
    each a list of segment lengths. The skip rate ``1 - active/total``
    is what ``serving.metrics`` reports per engine step."""
    active = total = 0
    for lengths in row_seg_lengths:
        ids = segments_to_ids(lengths, capacity)
        a, t, _bq, _bk = block_map_counts(ids, block_q=block_q,
                                          block_k=block_k)
        active += a
        total += t
    return active, total
