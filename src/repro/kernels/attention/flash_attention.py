"""Pallas TPU flash attention (blocked online softmax).

TPU-native layout: grid ``(batch·q_heads, num_q_blocks, num_kv_blocks)``, the
kv-block axis iterated sequentially ("arbitrary" semantics) with the running
max / normalizer / accumulator held in VMEM scratch. Block sizes default to
128 (MXU-aligned). Supports GQA (kv-head index map), causal masks, sliding
windows, and Gemma-style logit soft-capping — the same semantics as the XLA
reference in ``repro.models.attention`` (= ``ref.py``'s oracle).

Validated with ``interpret=True`` on CPU; compiled path targets TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; 0.5+ renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, softcap: float, window: int,
                  block_q: int, block_k: int, sm_scale: float, num_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # [bq, hd]
    k = k_ref[0]                                   # [bk, hd]
    v = v_ref[0]
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * sm_scale
    if softcap > 0.0:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = jnp.ones(s.shape, jnp.bool_)
    if causal:
        mask &= q_pos >= k_pos
    if window > 0:
        mask &= (q_pos - k_pos < window) & (k_pos - q_pos < window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
    alpha = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
    m_scr[...] = m_cur
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(ki == num_kv - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "softcap", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, softcap: float = 0.0,
                    window: int = 0, segment_ids=None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: [B,S,H,hd]; k,v: [B,Sk,K,hd] (GQA) → [B,S,H,hd].

    ``interpret=True`` runs the kernel body on CPU (this container);
    pass False on real TPU hardware.
    """
    assert segment_ids is None, "packing masks: use the XLA path"
    B, S, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, Sk)
    nq = -(-S // bq)
    nk = -(-Sk // bk)
    assert S % bq == 0 and Sk % bk == 0, "pad sequences to block multiples"

    qt = q.transpose(0, 2, 1, 3).reshape(B * H, S, hd)
    kt = k.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)
    vt = v.transpose(0, 2, 1, 3).reshape(B * K, Sk, hd)

    kernel = functools.partial(
        _flash_kernel, causal=causal, softcap=softcap, window=window,
        block_q=bq, block_k=bk, sm_scale=1.0 / np.sqrt(hd), num_kv=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, G=G: (b // G, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j, G=G: (b // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qt, kt, vt)
    return out.reshape(B, H, S, hd).transpose(0, 2, 1, 3)
