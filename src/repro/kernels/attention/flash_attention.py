"""Pallas TPU flash attention (blocked online softmax, segment-aware).

TPU-native layout: grid ``(batch·q_heads, num_q_blocks, num_kv_blocks)``, the
kv-block axis iterated sequentially ("arbitrary" semantics) with the running
max / normalizer / accumulator held in VMEM scratch. Block sizes default to
128 (MXU-aligned). Supports GQA (kv-head index map), causal masks, sliding
windows, Gemma-style logit soft-capping, and NaViT-style packing segment
masks — the same semantics as the XLA reference in ``repro.models.attention``
(= ``ref.py``'s oracle), sharing its mask algebra via
``kernels.attention.mask`` so the two backends cannot drift.

Block-sparse cross-segment skipping (DESIGN.md §attention-backend): a
host/graph-side block map marks every (q block, kv block) pair whose segment
ranges cannot intersect (including the causal/window envelope), and the
kernel skips the whole score tile under ``pl.when`` — packing's masked-out
work is never issued. The map is int32 DATA (a traced operand), so swapping
pack layouts under a fixed bucket shape replays the same executable.

Padding: sequences are padded internally to block multiples; padded keys
carry segment id -1 and are never attended, padded query rows are sliced
off. Rows whose segment has no visible key (e.g. padding queries) return 0.

Validated with ``interpret=True`` on CPU; compiled path targets TPU.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.attention import mask as mask_mod
from repro.runtime.padding import pad_to

# jax 0.4.x names this TPUCompilerParams; 0.5+ renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))

NEG_INF = -1e30


def _flash_kernel(*refs, causal: bool, softcap: float, window: int,
                  block_q: int, block_k: int, sm_scale: float, num_kv: int,
                  segmented: bool):
    if segmented:
        (bmap_ref, qseg_ref, kseg_ref, q_ref, k_ref, v_ref, o_ref,
         m_scr, l_scr, acc_scr) = refs
    else:
        (bmap_ref, q_ref, k_ref, v_ref, o_ref,
         m_scr, l_scr, acc_scr) = refs
        qseg_ref = kseg_ref = None
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Skip the whole score tile when the block map proves it fully masked
    # (cross-segment, outside the window, or acausal). The map is traced
    # data: layout switches replay this executable.
    @pl.when(bmap_ref[0, 0, 0] > 0)
    def _visit():
        q = q_ref[0]                                   # [bq, hd]
        k = k_ref[0]                                   # [bk, hd]
        v = v_ref[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * sm_scale
        if softcap > 0.0:
            s = jnp.tanh(s / softcap) * softcap

        # rank-2 iotas: TPU Mosaic rejects 1-D iota, so the tile path
        # builds full [bq, bk] position grids and uses the elementwise
        # variant of the shared position mask
        tile = (block_q, block_k)
        q_pos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, tile, 0)
        k_pos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, tile, 1)
        allowed = mask_mod.position_allowed_grid(q_pos, k_pos, causal=causal,
                                                 window=window)
        if segmented:
            allowed &= mask_mod.segment_allowed(qseg_ref[0], kseg_ref[0])

        # Streaming softmax with fully-masked-tile safety: probabilities
        # are zeroed where masked (a conservative block map may admit a
        # tile with no visible key — the running max must not poison it).
        s = jnp.where(allowed, s, NEG_INF)
        m_prev = m_scr[...]
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        alpha = jnp.exp(m_prev - m_cur)
        p = jnp.where(allowed, jnp.exp(s - m_cur[:, None]), 0.0)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1)
        m_scr[...] = m_cur
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == num_kv - 1)
    def _done():
        denom = jnp.maximum(l_scr[...], 1e-30)[:, None]
        o_ref[0] = (acc_scr[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "softcap", "window", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, softcap: float = 0.0,
                    window: int = 0,
                    segment_ids: Optional[jax.Array] = None,
                    block_map: Optional[jax.Array] = None,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: [B,S,H,hd]; k,v: [B,Sk,K,hd] (GQA) → [B,S,H,hd].

    ``segment_ids``: optional [B, S] int32 shared by queries and keys
    (self-attention packing); tokens attend within their segment only,
    ids < 0 mark padding (never attends, never attended). ``block_map``:
    optional precomputed [B, ceil(S/bq), ceil(Sk/bk)] int32 activity map;
    derived from the segment ids / causal / window envelope when absent.
    Both are traced operands — pack-layout switches never recompile.

    ``interpret=True`` runs the kernel body on CPU (this container);
    pass False on real TPU hardware.
    """
    B, S, H, hd = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = H // K
    bq = min(block_q, S)
    bk = min(block_k, Sk)
    nq = -(-S // bq)
    nk = -(-Sk // bk)
    Sp, Skp = nq * bq, nk * bk

    if segment_ids is not None:
        assert segment_ids.shape == (B, S), (segment_ids.shape, (B, S))
        assert S == Sk, "segment packing is self-attention only"
    segmented = segment_ids is not None or Sp != S or Skp != Sk
    q_seg = k_seg = None
    if segmented:
        q_seg, _ = mask_mod.pad_to_block_multiple(segment_ids, B, S, bq)
        k_seg, _ = mask_mod.pad_to_block_multiple(segment_ids, B, Sk, bk)
    if block_map is None:
        if segmented:
            block_map = mask_mod.attention_block_map(
                q_seg, k_seg, block_q=bq, block_k=bk, causal=causal,
                window=window)
        else:
            env = mask_mod.block_position_envelope(
                nq, nk, bq, bk, causal=causal, window=window)
            # env is static host numpy (window/causal are compile-time
            # here; resolve_backend rejects traced windows for Pallas)
            block_map = jnp.asarray(
                np.broadcast_to(env.astype(np.int32), (B, nq, nk)))  # repro: ignore[trace-host-np]
    assert block_map.shape == (B, nq, nk), (block_map.shape, (B, nq, nk))

    qt = pad_to(q, Sp, axis=1).transpose(0, 2, 1, 3).reshape(B * H, Sp, hd)
    kt = pad_to(k, Skp, axis=1).transpose(0, 2, 1, 3).reshape(B * K, Skp, hd)
    vt = pad_to(v, Skp, axis=1).transpose(0, 2, 1, 3).reshape(B * K, Skp, hd)

    kernel = functools.partial(
        _flash_kernel, causal=causal, softcap=softcap, window=window,
        block_q=bq, block_k=bk, sm_scale=1.0 / np.sqrt(hd), num_kv=nk,
        segmented=segmented)

    in_specs = [
        pl.BlockSpec((1, 1, 1), lambda b, i, j, H=H: (b // H, i, j),
                     memory_space=pltpu.SMEM),
    ]
    inputs = [jnp.asarray(block_map, jnp.int32)]
    if segmented:
        in_specs += [
            pl.BlockSpec((1, bq), lambda b, i, j, H=H: (b // H, i)),
            pl.BlockSpec((1, bk), lambda b, i, j, H=H: (b // H, j)),
        ]
        inputs += [q_seg, k_seg]
    in_specs += [
        pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        pl.BlockSpec((1, bk, hd), lambda b, i, j, G=G: (b // G, j, 0)),
        pl.BlockSpec((1, bk, hd), lambda b, i, j, G=G: (b // G, j, 0)),
    ]
    inputs += [qt, kt, vt]

    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sp, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(*inputs)
    return out.reshape(B, H, Sp, hd).transpose(0, 2, 1, 3)[:, :S]
