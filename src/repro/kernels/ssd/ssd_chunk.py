"""Pallas TPU kernel for the Mamba2 SSD intra-chunk computation.

One grid step processes one (batch, chunk) tile entirely in VMEM:
  y_intra[q] = Σ_{k≤q} (C_q·B_k) · exp(L_q − L_k) · dt_k · x_k
  Sc         = Σ_k exp(L_tot − L_k) · dt_k · x_k ⊗ B_k      (chunk summary)
  Ltot       = Σ_q log a_q
The O(S)-state inter-chunk recurrence stays in a tiny ``lax.scan`` on the
host graph (``ops.ssd``), exactly like the reference ``ssd_chunked``.

VMEM working set per step (Q=128, H≤64, P=64, N=128):
  x [Q,H,P] + M [Q,Q,H] + B/C [Q,N] ≈ 2–6 MB — fits comfortably.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax 0.4.x names this TPUCompilerParams; 0.5+ renamed it CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams",
                          getattr(pltpu, "TPUCompilerParams", None))


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref,
                y_ref, sc_ref, ltot_ref):
    x = x_ref[0].astype(jnp.float32)          # [Q,H,P]
    dt = dt_ref[0].astype(jnp.float32)        # [Q,H]
    A = a_ref[...].astype(jnp.float32)        # [H]
    Bm = b_ref[0].astype(jnp.float32)         # [Q,N]
    Cm = c_ref[0].astype(jnp.float32)         # [Q,N]

    la = dt * A[None, :]                      # [Q,H]
    L = jnp.cumsum(la, axis=0)                # [Q,H]
    Ltot = L[-1]                              # [H]

    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    diff = L[:, None, :] - L[None, :, :]      # [Q,Q,H]
    Q = L.shape[0]
    qi = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    ki = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    causal = (qi >= ki)[:, :, None]
    decay = jnp.where(causal, jnp.exp(diff), 0.0)
    M = CB[:, :, None] * decay * dt[None, :, :]           # [Q,K,H]
    y = jnp.einsum("qkh,khp->qhp", M, x)                  # [Q,H,P]

    w = jnp.exp(Ltot[None, :] - L) * dt                   # [Q,H]
    sc = jnp.einsum("qh,qhp,qn->hpn", w, x, Bm)           # [H,P,N]

    y_ref[0] = y.astype(y_ref.dtype)
    sc_ref[0] = sc
    ltot_ref[0] = Ltot


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                     Bm: jax.Array, Cm: jax.Array, *, chunk: int,
                     interpret: bool = True):
    """x: [B,S,H,P]; dt: [B,S,H]; A: [H]; Bm/Cm: [B,S,N].
    Returns (y_intra [B,S,H,P], Sc [B,nc,H,P,N], Ltot [B,nc,H])."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, H, P).reshape(B * nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H).reshape(B * nc, chunk, H)
    Bc = Bm.reshape(B, nc, chunk, N).reshape(B * nc, chunk, N)
    Cc = Cm.reshape(B, nc, chunk, N).reshape(B * nc, chunk, N)

    y, sc, ltot = pl.pallas_call(
        _ssd_kernel,
        grid=(B * nc,),
        in_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, chunk, H), lambda i: (i, 0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((1, chunk, N), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, chunk, N), lambda i: (i, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, H, P), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, H, P, N), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, H), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B * nc, chunk, H, P), x.dtype),
            jax.ShapeDtypeStruct((B * nc, H, P, N), jnp.float32),
            jax.ShapeDtypeStruct((B * nc, H), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(xc, dtc, A, Bc, Cc)
    return (y.reshape(B, nc, chunk, H, P).reshape(B, S, H, P),
            sc.reshape(B, nc, H, P, N),
            ltot.reshape(B, nc, H))
