"""Jitted SSD wrapper: Pallas intra-chunk kernel + host-graph inter-chunk
recurrence. Drop-in for ``repro.models.ssm.ssd_chunked``."""
from __future__ import annotations

import os
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ssd_chunk import ssd_chunk_pallas

INTERPRET = os.environ.get("REPRO_PALLAS_INTERPRET", "1") == "1"


def ssd(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
        Cm: jax.Array, chunk: int, h0: Optional[jax.Array] = None
        ) -> Tuple[jax.Array, jax.Array]:
    """Same contract as models.ssm.ssd_chunked (pads internally)."""
    B, S, H, P = x.shape
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    y_intra, Sc, Ltot = ssd_chunk_pallas(x, dt, A, Bm, Cm, chunk=chunk,
                                         interpret=INTERPRET)
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        Sc_c, Ltot_c = inp
        h_new = h * jnp.exp(Ltot_c)[:, :, None, None] + Sc_c
        return h_new, h

    h_final, h_prevs = jax.lax.scan(
        step, h0, (Sc.transpose(1, 0, 2, 3, 4), Ltot.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)      # [B,nc,H,P,N]

    la = (dt * A[None, None, :]).reshape(B, nc, chunk, H)
    L = jnp.cumsum(la, axis=2)
    Cc = Cm.reshape(B, nc, chunk, N)
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp", jnp.exp(L), Cc, h_prevs)
    y = y_intra + y_inter.reshape(B, nc * chunk, H, P).astype(y_intra.dtype)
    return y[:, :S], h_final
