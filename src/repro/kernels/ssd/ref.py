"""Pure-jnp oracles for the SSD kernel: the chunked algorithm AND the naive
O(S·N·P) sequential recurrence (ground truth)."""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.ssm import ssd_chunked as ssd_chunked_ref  # noqa: F401


def ssd_recurrence_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                       Bm: jax.Array, Cm: jax.Array,
                       h0: Optional[jax.Array] = None
                       ) -> Tuple[jax.Array, jax.Array]:
    """Naive step-by-step recurrence — the mathematical definition."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    h = h0 if h0 is not None else jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp
        a = jnp.exp(dt_t * A[None, :])                        # [B,H]
        h = h * a[:, :, None, None] \
            + (dt_t[:, :, None] * x_t.astype(jnp.float32))[..., None] \
            * B_t[:, None, None, :].astype(jnp.float32)
        y = jnp.einsum("bhpn,bn->bhp", h, C_t.astype(jnp.float32))
        return h, y

    h, ys = jax.lax.scan(step, h, (x.transpose(1, 0, 2, 3),
                                   dt.transpose(1, 0, 2),
                                   Bm.transpose(1, 0, 2),
                                   Cm.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), h
