"""FlexiDiT core — the paper's contribution as a composable JAX module."""
from repro.core.flexify import flexify, merge_lora, trainable_mask  # noqa: F401
from repro.core.guidance import GuidanceConfig, make_eps_fn  # noqa: F401
from repro.core.scheduler import (FlexiSchedule, dit_nfe_flops,  # noqa: F401
                                  relative_compute, schedule_flops)
