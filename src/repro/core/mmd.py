"""Bootstrapped MMD distribution-matching loss (App. B.1).

Corrects weak-model exposure bias for the shared-parameters recipe: run a
short denoising chain from t_start → t_end (first steps with the weak mode,
rest with the powerful mode — mirroring the inference scheduler), and match
the distribution of the chain's output against real images corrupted
directly to t_end, via RBF-kernel maximum mean discrepancy.

Timestep sampling is biased toward small t (where the measured MMD gap is
largest — Fig. 11 left), as in the paper.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.diffusion import schedule as sch
from repro.models import dit as dit_mod
from repro.models.common import dtype_of
from repro.optim import adamw


def rbf_mmd2(x: jax.Array, y: jax.Array,
             bandwidths: Sequence[float] = (1.0, 2.0, 4.0, 8.0)) -> jax.Array:
    """Unbiased-ish MMD² with a mixture of RBF kernels. x,y: [B, D]."""
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)

    def pdist2(a, b):
        return (jnp.sum(a * a, 1)[:, None] + jnp.sum(b * b, 1)[None]
                - 2.0 * a @ b.T)

    dxx, dyy, dxy = pdist2(x, x), pdist2(y, y), pdist2(x, y)
    # median-heuristic bandwidth: not a differentiation target — stop the
    # gradient BEFORE the sort (this jaxlib's sort-JVP gather rule is broken)
    flat = jnp.sort(jax.lax.stop_gradient(dxy).reshape(-1))
    med = flat[flat.shape[0] // 2] + 1e-6
    total = 0.0
    for bw in bandwidths:
        g = 1.0 / (bw * med)
        kxx = jnp.exp(-g * dxx)
        kyy = jnp.exp(-g * dyy)
        kxy = jnp.exp(-g * dxy)
        n = x.shape[0]
        total = total + (jnp.sum(kxx) - n) / (n * (n - 1)) \
            + (jnp.sum(kyy) - n) / (n * (n - 1)) \
            - 2.0 * jnp.mean(kxy)
    return total


def _chain_denoise(params: Any, x: jax.Array, cond: Any, cfg: ModelConfig,
                   sched: sch.DiffusionSchedule, timesteps: jax.Array,
                   modes: Sequence[int], key: jax.Array) -> jax.Array:
    """Run len(modes) DDPM steps with per-step (static) patch modes."""
    for i, mode in enumerate(modes):
        t = timesteps[:, i]
        out = dit_mod.dit_forward(params, x, t, cond, cfg, mode=mode)
        eps = dit_mod.eps_prediction(out, cfg)
        logvar = out[..., cfg.dit.latent_shape[-1]:] if cfg.dit.learn_sigma else None
        x = sch.ddpm_step(sched, x, eps, t, jax.random.fold_in(key, i),
                          logvar)
    return x


def bootstrap_mmd_loss(params: Any, batch: Dict[str, jax.Array],
                       key: jax.Array, cfg: ModelConfig,
                       sched: sch.DiffusionSchedule, *,
                       n_weak: int = 2, n_powerful: int = 2,
                       weak_mode: int = 1,
                       t_bias: float = 2.0
                       ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Fig. 11 (right): corrupt x̃0 to t_start, denoise n_weak weak steps then
    n_powerful powerful steps down to t_end, and MMD-match against q(x_{t_end}|x0)
    samples of independent reals."""
    x0 = batch["x0"].astype(dtype_of(cfg.compute_dtype))
    x0_other = batch.get("x0_target", x0[::-1]).astype(x0.dtype)
    B = x0.shape[0]
    n_chain = n_weak + n_powerful
    k_t, k_n1, k_n2, k_c = jax.random.split(key, 4)

    # biased sampling of t_end toward 0 (MMD gap grows near x0)
    u = jax.random.uniform(k_t, (B,))
    t_end = (u ** t_bias * (sched.num_steps - n_chain - 1)).astype(jnp.int32)
    steps = t_end[:, None] + jnp.arange(n_chain, 0, -1)[None]    # descending
    t_start = steps[:, 0]

    noise = jax.random.normal(k_n1, x0.shape, x0.dtype)
    x_t = sch.q_sample(sched, x0, t_start, noise)
    modes = [weak_mode] * n_weak + [0] * n_powerful
    x_pred = _chain_denoise(params, x_t, batch.get("cond"), cfg, sched,
                            steps, modes, k_c)

    noise2 = jax.random.normal(k_n2, x0.shape, x0.dtype)
    x_target = sch.q_sample(sched, x0_other, t_end, noise2)

    loss = rbf_mmd2(x_pred.reshape(B, -1), x_target.reshape(B, -1))
    return loss, {"mmd_loss": loss}


def make_mmd_finetune_step(cfg: ModelConfig, tc: TrainConfig,
                           sched: Optional[sch.DiffusionSchedule] = None,
                           denoise_weight: float = 1.0,
                           mmd_weight: float = 0.1,
                           weak_mode: int = 1, train_mode: int = 0):
    """Shared-params recipe (§4.1): standard denoising loss at a (per-step
    static) patch mode + the bootstrapped MMD correction."""
    sched = sched or sch.linear_schedule(1000)

    def loss_fn(params, batch, key):
        from repro.launch.steps import make_dit_train_step  # noqa: F401
        x0 = batch["x0"].astype(dtype_of(cfg.compute_dtype))
        k1, k2, k3 = jax.random.split(key, 3)
        B = x0.shape[0]
        t = jax.random.randint(k1, (B,), 0, sched.num_steps)
        noise = jax.random.normal(k2, x0.shape, x0.dtype)
        x_t = sch.q_sample(sched, x0, t, noise)
        out = dit_mod.dit_forward(params, x_t, t, batch.get("cond"), cfg,
                                  mode=train_mode)
        eps = dit_mod.eps_prediction(out, cfg).astype(jnp.float32)
        den = jnp.mean(jnp.square(eps - noise.astype(jnp.float32)))
        mmd, _ = bootstrap_mmd_loss(params, batch, k3, cfg, sched,
                                    weak_mode=weak_mode)
        loss = denoise_weight * den + mmd_weight * mmd
        return loss, {"denoise_loss": den, "mmd_loss": mmd}

    def step(params, opt_state, batch, key):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch, key)
        params, opt_state, om = adamw.adamw_update(params, grads, opt_state, tc)
        return params, opt_state, {**metrics, **om}

    return step
