"""Flexify a pre-trained DiT: §3.1 (shared parameters) and §3.2 (LoRA).

``flexify(params, cfg, new_patch_sizes, lora_rank)`` returns
``(flex_params, flex_cfg)`` where:

* embed/de-embed weights are lifted to the (larger) underlying patch size
  ``p'`` with the PI-resize init, so the pre-trained functional form is
  preserved exactly at the pre-trained patch size (verified in tests);
* new parameters (patch-size embedding, per-mode LN, LoRA adapters, per-mode
  embed layers in the LoRA recipe) are added with functional-preservation
  inits (zeros / PI-resize);
* ``trainable_mask`` marks which leaves each recipe fine-tunes.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import resize
from repro.models import dit as dit_mod
from repro.models.common import dtype_of, init_tree

Params = Dict[str, Any]
Patch = Tuple[int, int, int]


def _max_patch(sizes: Sequence[Patch]) -> Patch:
    return tuple(max(p[i] for p in sizes) for i in range(3))  # type: ignore


def flexify(params: Params, cfg: ModelConfig,
            new_patch_sizes: Sequence[Patch],
            lora_rank: int = 0, key: jax.Array | None = None
            ) -> Tuple[Params, ModelConfig]:
    """Convert a (pre-trained) single-patch-size DiT into a FlexiDiT."""
    assert cfg.dit is not None
    key = key if key is not None else jax.random.PRNGKey(0)
    p_pre = cfg.dit.patch_size
    old_pp = cfg.dit.underlying_patch_size
    # LoRA recipe (§3.2): mode 0 must stay BIT-exact, so the shared flex
    # storage is left untouched (weak modes get brand-new layers anyway);
    # shared recipe lifts storage to the largest patch size.
    new_pp = (old_pp if lora_rank > 0
              else _max_patch([old_pp, p_pre, *new_patch_sizes]))
    flex_cfg = dataclasses.replace(
        cfg, dit=dataclasses.replace(
            cfg.dit, flex_patch_sizes=tuple(new_patch_sizes),
            underlying_patch_size=new_pp, lora_rank=lora_rank))

    fresh = init_tree(dit_mod.dit_schema(flex_cfg), key,
                      dtype_of(cfg.param_dtype))

    # Copy every leaf that exists in the old tree (blocks, conditioning, ...).
    def merge(new_tree: Any, old_tree: Any) -> Any:
        if isinstance(new_tree, dict):
            return {k: merge(v, old_tree[k]) if (isinstance(old_tree, dict)
                                                 and k in old_tree) else v
                    for k, v in new_tree.items()}
        return old_tree if old_tree is not None else new_tree

    flex = merge(fresh, params)

    # Lift the pre-trained embed/de-embed to the new underlying patch size.
    w_emb = params["embed"]["w_flex"]
    if old_pp != new_pp:
        # collapse old flex storage to the pre-trained size first
        w_pre = resize.project_embed(w_emb, p_pre, old_pp)
        flex["embed"] = {"w_flex": resize.lift_embed(w_pre, p_pre, new_pp),
                         "b": params["embed"]["b"]}
        wd_pre = resize.project_deembed(params["deembed"]["w_flex"], p_pre, old_pp)
        bd_pre = resize.project_deembed_bias(params["deembed"]["b_flex"], p_pre,
                                             old_pp)
        flex["deembed"] = {
            "w_flex": resize.lift_deembed(wd_pre, p_pre, new_pp),
            "b_flex": resize.lift_deembed_bias(bd_pre, p_pre, new_pp)}
    else:
        flex["embed"] = dict(params["embed"])
        flex["deembed"] = dict(params["deembed"])

    # LoRA recipe: per-new-mode embed layers init'd by PI-resize from the
    # pre-trained weights (paper App. C.2: w' = Q w, w_de' = w_de Q_de):
    # W(p_k) = B_up(p_pre→p_k)·w_pre (exact at p_pre by construction).
    if lora_rank > 0:
        w_pre = resize.project_embed(params["embed"]["w_flex"], p_pre, old_pp)
        wd_pre = resize.project_deembed(params["deembed"]["w_flex"], p_pre,
                                        old_pp)
        bd_pre = resize.project_deembed_bias(params["deembed"]["b_flex"],
                                             p_pre, old_pp)
        for m, p_new in enumerate(new_patch_sizes, start=1):
            flex["embed_new"][f"m{m}"] = {
                "w": resize.lift_embed(w_pre, p_pre, p_new),
                "b": params["embed"]["b"]}
            flex["deembed_new"][f"m{m}"] = {
                "w": resize.lift_deembed(wd_pre, p_pre, p_new),
                "b": resize.lift_deembed_bias(bd_pre, p_pre, p_new)}
    return flex, flex_cfg


TRAINABLE_LORA_KEYS = ("lora", "ps_embed", "ps_ln", "embed_new", "deembed_new")


def trainable_mask(flex_params: Params, recipe: str) -> Params:
    """Boolean pytree: which leaves train under 'shared' (§3.1, everything)
    vs 'lora' (§3.2, only adapters + new layers; base frozen)."""
    if recipe == "shared":
        return jax.tree.map(lambda _: True, flex_params)

    def mark(tree: Any, on: bool) -> Any:
        if isinstance(tree, dict):
            return {k: mark(v, on or k in TRAINABLE_LORA_KEYS)
                    for k, v in tree.items()}
        return jax.tree.map(lambda _: on, tree)

    return mark(flex_params, False)


def merge_lora(flex_params: Params, cfg: ModelConfig, mode: int,
               lora_scale: float = 2.0) -> Params:
    """Merge mode-``mode`` LoRAs into dense weights (paper Fig. 5: 'Inference
    without LoRAs' — zero FLOPs overhead, extra memory for the copy)."""
    assert mode > 0
    blocks = flex_params["blocks"]
    merged_blocks = jax.tree.map(lambda x: x, blocks)   # shallow copy tree

    def merge_one(w: jax.Array, pair: Params) -> jax.Array:
        # stacked over layers: w [L,din,dout]; a [L,n_new,din,r]; b [L,n_new,r,dout]
        a = pair["a"][:, mode - 1].astype(jnp.float32)
        b = pair["b"][:, mode - 1].astype(jnp.float32)
        r = a.shape[-1]
        delta = jnp.einsum("ldr,lre->lde", a, b) * (lora_scale / r)
        return (w.astype(jnp.float32) + delta).astype(w.dtype)

    lora = blocks.get("lora")
    if lora is not None:
        for grp, names in (("attn", ("wq", "wk", "wv", "wo")),
                           ("mlp", ("w_in", "w_out"))):
            for n in names:
                if n in lora.get(grp, {}):
                    merged_blocks[grp][n] = merge_one(blocks[grp][n],
                                                      lora[grp][n])
        merged_blocks = {k: v for k, v in merged_blocks.items() if k != "lora"}
    out = dict(flex_params)
    out["blocks"] = merged_blocks
    return out
