"""FlexiDiT inference scheduler (§3.3) + analytic FLOPs accounting.

The scheduler assigns a *mode* (patch size index) to each denoising step:
weak mode for the first ``T_weak`` steps, powerful mode for the rest. FLOPs
are counted analytically per NFE (mul+add counted separately, paper App C.1)
so compute budgets in benchmarks match the paper's reporting convention.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.configs.base import ModelConfig
from repro.models import dit as dit_mod


@dataclasses.dataclass(frozen=True)
class FlexiSchedule:
    """phases: ((mode, n_steps), ...) executed in order from t=T-1 down."""
    phases: Tuple[Tuple[int, int], ...]

    @property
    def total_steps(self) -> int:
        return sum(n for _, n in self.phases)

    def split_timesteps(self, timesteps: np.ndarray) -> List[Tuple[int, np.ndarray]]:
        """Split a descending timestep ladder across phases."""
        assert len(timesteps) == self.total_steps, (len(timesteps), self)
        out, i = [], 0
        for mode, n in self.phases:
            out.append((mode, timesteps[i:i + n]))
            i += n
        return out

    @staticmethod
    def weak_first(T: int, T_weak: int, weak_mode: int = 1) -> "FlexiSchedule":
        """The paper's scheduler: weak for the first T_weak steps."""
        assert 0 <= T_weak <= T
        return FlexiSchedule(((weak_mode, T_weak), (0, T - T_weak)))

    @staticmethod
    def powerful_first(T: int, T_weak: int, weak_mode: int = 1) -> "FlexiSchedule":
        """Ablation scheduler (App. B.4, shown to be worse)."""
        return FlexiSchedule(((0, T - T_weak), (weak_mode, T_weak)))


# ---------------------------------------------------------------------------
# Analytic FLOPs (mul + add counted separately → factor 2 per MAC)


def dit_block_flops(cfg: ModelConfig, n_tokens: int,
                    text_len: Optional[int] = None,
                    attn_backend: str = "dense") -> float:
    """FLOPs of all transformer blocks over ``n_tokens`` tokens (batch 1).

    Split out from :func:`dit_nfe_flops` so the distributed engine can
    price sequence padding exactly: padded tokens flow through the blocks
    only, never the (de-)embedding (``distributed.partition``).

    ``attn_backend='pallas'``/``'auto'`` prices self-attention at the
    block granularity the flash kernel launches (tiles of 128, rounded
    up) instead of the exact N² — what the device actually issues when
    the Pallas backend serves the request (DESIGN.md §attention-backend).
    """
    N = n_tokens
    d, L, f = cfg.d_model, cfg.num_layers, cfg.d_ff
    per_layer = 0.0
    per_layer += 2 * N * d * (3 * d)          # qkv proj
    per_layer += 2 * N * d * d                # out proj
    if attn_backend in ("pallas", "auto"):
        from repro.kernels.attention import costing
        per_layer += costing.block_sparse_attention_flops([N], N, d)
    else:
        per_layer += 2 * 2 * N * N * d        # QK^T and PV
    per_layer += 2 * 2 * N * d * f            # mlp in/out
    per_layer += 2 * d * 6 * d                # adaLN linear (per sample)
    if cfg.dit.conditioning == "text":
        T = text_len or cfg.dit.text_len
        dc = cfg.dit.text_dim or d
        per_layer += 2 * N * d * d            # xattn q
        per_layer += 2 * 2 * T * dc * d       # xattn k,v
        per_layer += 2 * 2 * N * T * d        # scores + values
        per_layer += 2 * N * d * d            # xattn out
    return float(L * per_layer)


def dit_nfe_flops(cfg: ModelConfig, mode: int = 0,
                  text_len: Optional[int] = None,
                  attn_backend: str = "dense") -> float:
    """FLOPs of one DiT forward (batch 1) at the given patch mode."""
    N = dit_mod.tokens_for_mode(cfg, mode)
    d = cfg.d_model
    p = dit_mod.patch_sizes(cfg)[mode]
    c_in = cfg.dit.latent_shape[-1]
    c_out = dit_mod.c_out_dim(cfg)
    npix = int(np.prod(p))

    total = dit_block_flops(cfg, N, text_len, attn_backend=attn_backend)
    total += 2 * N * npix * c_in * d          # embed
    total += 2 * N * d * npix * c_out         # de-embed
    total += 2 * d * 2 * d                    # final adaLN
    return float(total)


def lora_nfe_overhead(cfg: ModelConfig, mode: int) -> float:
    """Extra FLOPs/NFE when LoRAs stay unmerged (paper §3.2):
    N·(d_in·r + r·d_out) per adapted projection."""
    if cfg.dit.lora_rank <= 0 or mode == 0:
        return 0.0
    N = dit_mod.tokens_for_mode(cfg, mode)
    d, L, f, r = cfg.d_model, cfg.num_layers, cfg.d_ff, cfg.dit.lora_rank
    per_layer = 0.0
    for d_in, d_out in [(d, d)] * 4 + [(d, f), (f, d)]:
        per_layer += 2 * N * (d_in * r + r * d_out)
    return float(L * per_layer)


def schedule_flops(cfg: ModelConfig, schedule: FlexiSchedule, *,
                   cfg_scale_active: bool = True,
                   guidance_modes: Optional[Sequence[Tuple[int, int]]] = None,
                   lora_unmerged: bool = False,
                   attn_backend: str = "dense") -> float:
    """Total denoising FLOPs for a batch-1 sample under the scheduler.

    ``guidance_modes``: optional per-phase (mode_cond, mode_uncond) for CFG;
    default both at the phase's mode. Without CFG each step is one NFE.
    """
    total = 0.0
    for i, (mode, n) in enumerate(schedule.phases):
        def nfe(m: int) -> float:
            fl = dit_nfe_flops(cfg, m, attn_backend=attn_backend)
            if lora_unmerged:
                fl += lora_nfe_overhead(cfg, m)
            return fl
        if cfg_scale_active:
            mc, mu = (guidance_modes[i] if guidance_modes is not None
                      else (mode, mode))
            total += n * (nfe(mc) + nfe(mu))
        else:
            total += n * nfe(mode)
    return total


def relative_compute(cfg: ModelConfig, schedule: FlexiSchedule, **kw) -> float:
    """Compute fraction vs the all-powerful baseline with the same T."""
    base = FlexiSchedule(((0, schedule.total_steps),))
    return schedule_flops(cfg, schedule, **kw) / schedule_flops(cfg, base, **kw)
