"""Adaptive per-sample inference scheduler — the extension the paper marks
as future work (App. A: "adapting the inference scheduler ... based on the
requirements of each sample").

Mechanism: at probe steps, run BOTH modes on a cheap probe (the weak NFE is
<¼ the powerful one, so a dual probe costs ~25% extra *at that step only*)
and measure the relative prediction gap ‖ε_w − ε_p‖²/‖ε_p‖². While the gap
is below ``threshold`` the sampler stays in the weak mode; the first probe
exceeding it switches to powerful for all remaining steps (the gap is
monotone-ish in t — Fig. 4 — so a single switch point is near-optimal).

The weak loop takes solver steps directly from the probe's ε — the probe
prediction is never recomputed — so the FLOPs ledger matches what actually
ran. Under CFG (``guided=True``, the default: ``make_mode_eps_fns`` and
the pipeline both build guided NFEs) every model call costs 2 NFEs, and
``flops_static_powerful`` uses the same multiplier so reported savings are
consistent.

This runs OUTSIDE jit across phases (mode changes recompile), using the two
per-mode compiled NFEs — the same two executables the static scheduler uses,
so there is no compile-time overhead beyond them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.scheduler import dit_nfe_flops, lora_nfe_overhead
from repro.diffusion import sampler, schedule as sch


@jax.jit
def _relative_gap(e_w: jax.Array, e_p: jax.Array) -> jax.Array:
    """Fused relative prediction gap ‖ε_w − ε_p‖²/‖ε_p‖² as one device
    scalar — a single kernel and a single host transfer per probe."""
    num = jnp.mean(jnp.square(e_w - e_p))
    den = jnp.maximum(jnp.mean(jnp.square(e_p)), 1e-12)
    return num / den


@dataclasses.dataclass
class AdaptiveResult:
    x0: jax.Array
    switch_step: int            # index in the ladder where powerful took over
    gaps: List[float]           # measured relative gaps at probe steps
    flops: float                # actual FLOPs spent (incl. probe overhead)
    flops_static_powerful: float


def adaptive_sample(eps_fns: Sequence[Callable], sched: sch.DiffusionSchedule,
                    x_T: jax.Array, timesteps: np.ndarray, key: jax.Array,
                    cfg: ModelConfig, *, threshold: float = 0.35,
                    probe_every: int = 2, weak_mode: int = 1,
                    solver: str = "ddim", guided: bool = True,
                    lora_unmerged: bool = False) -> AdaptiveResult:
    """eps_fns[mode] -> (eps, logvar) at that patch mode (compiled once).

    ``guided``: the eps_fns implement CFG (two NFEs of compute per call).
    ``lora_unmerged``: the weak NFEs apply LoRA adapters dynamically (§3.2)
    and pay the adapter FLOPs. Solvers: 'ddim' | 'ddpm' — single-ε solvers,
    so each weak step reuses the probe's prediction directly.

    Returns the sample plus the decision trace and FLOPs accounting.
    """
    if solver not in ("ddim", "ddpm"):
        raise ValueError(f"adaptive_sample supports 'ddim'|'ddpm' (single-ε "
                         f"steps, probe reuse), got {solver!r}")
    T = len(timesteps)
    B = x_T.shape[0]
    x = x_T
    gaps: List[float] = []
    switch = T
    mult = 2.0 if guided else 1.0               # CFG: 2 NFEs per model call
    f_weak = mult * dit_nfe_flops(cfg, weak_mode)
    if lora_unmerged:
        f_weak += mult * lora_nfe_overhead(cfg, weak_mode)
    f_pow = mult * dit_nfe_flops(cfg, 0)
    flops = 0.0
    # the whole (t, t_next) ladder moves to device ONCE, up front — the
    # loop below only slices it, so no per-step host->device transfer and
    # no per-step int()/jnp.full host work
    ts_host = np.asarray(timesteps, dtype=np.int32)
    tnext_host = np.concatenate([ts_host[1:], np.array([-1], np.int32)])
    tb_all = jnp.asarray(np.broadcast_to(ts_host[:, None], (T, B)))
    tnb_all = jnp.asarray(np.broadcast_to(tnext_host[:, None], (T, B)))
    for i in range(T):
        tb = tb_all[i]
        e_w, lv_w = eps_fns[weak_mode](x, tb)
        flops += f_weak * B
        if i % probe_every == 0:
            e_p, _ = eps_fns[0](x, tb)
            flops += f_pow * B
            # one fused reduction, one inherent sync: the switch decision
            # is host control flow (grandfathered in analysis/baseline.json)
            gap = float(_relative_gap(e_w, e_p))
            gaps.append(gap)
            if gap > threshold:
                switch = i
                break
        # take the weak step from the ε just computed (probe or not)
        if solver == "ddim":
            x = sch.ddim_step(sched, x, e_w, tb, tnb_all[i])
        else:
            x = sch.ddpm_step(sched, x, e_w, tb, jax.random.fold_in(key, i),
                              lv_w)

    if switch < T:
        x = sampler.sample_phased(
            [(eps_fns[0], timesteps[switch:])], sched, x,
            jax.random.fold_in(key, 10_000 + switch), solver=solver)
        flops += f_pow * B * (T - switch)

    return AdaptiveResult(
        x0=x, switch_step=switch, gaps=gaps, flops=flops,
        flops_static_powerful=f_pow * B * T)


def make_mode_eps_fns(params: Any, cfg: ModelConfig, cond: Any, null_cond: Any,
                      cfg_scale: float = 1.5) -> List[Callable]:
    """Jitted per-mode guided NFEs (one executable per mode, as in §3.3)."""
    from repro.core.guidance import GuidanceConfig, make_eps_fn
    fns = []
    for mode in range(1 + len(cfg.dit.flex_patch_sizes)):
        g = GuidanceConfig(scale=cfg_scale, mode_cond=mode, mode_uncond=mode)
        fns.append(jax.jit(make_eps_fn(params, cfg, cond, null_cond, g)))
    return fns
