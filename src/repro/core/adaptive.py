"""Adaptive per-sample inference scheduler — the extension the paper marks
as future work (App. A: "adapting the inference scheduler ... based on the
requirements of each sample").

Mechanism: at probe steps, run BOTH modes on a cheap probe (the weak NFE is
<¼ the powerful one, so a dual probe costs ~25% extra *at that step only*)
and measure the relative prediction gap ‖ε_w − ε_p‖²/‖ε_p‖². While the gap
is below ``threshold`` the sampler stays in the weak mode; the first probe
exceeding it switches to powerful for all remaining steps (the gap is
monotone-ish in t — Fig. 4 — so a single switch point is near-optimal).

This runs OUTSIDE jit across phases (mode changes recompile), using the two
per-mode compiled NFEs — the same two executables the static scheduler uses,
so there is no compile-time overhead beyond them.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.scheduler import FlexiSchedule, dit_nfe_flops
from repro.diffusion import sampler, schedule as sch


@dataclasses.dataclass
class AdaptiveResult:
    x0: jax.Array
    switch_step: int            # index in the ladder where powerful took over
    gaps: List[float]           # measured relative gaps at probe steps
    flops: float                # actual FLOPs spent (incl. probe overhead)
    flops_static_powerful: float


def adaptive_sample(eps_fns: Sequence[Callable], sched: sch.DiffusionSchedule,
                    x_T: jax.Array, timesteps: np.ndarray, key: jax.Array,
                    cfg: ModelConfig, *, threshold: float = 0.35,
                    probe_every: int = 2, weak_mode: int = 1,
                    solver: str = "ddim") -> AdaptiveResult:
    """eps_fns[mode] -> (eps, logvar) at that patch mode (compiled once).

    Returns the sample plus the decision trace and FLOPs accounting.
    """
    T = len(timesteps)
    x = x_T
    gaps: List[float] = []
    switch = T
    f_weak = dit_nfe_flops(cfg, weak_mode)
    f_pow = dit_nfe_flops(cfg, 0)
    flops = 0.0
    i = 0
    while i < T:
        t = timesteps[i]
        probe = (i % probe_every == 0)
        if probe:
            e_w, _ = eps_fns[weak_mode](x, jnp.full((x.shape[0],), float(t)))
            e_p, _ = eps_fns[0](x, jnp.full((x.shape[0],), float(t)))
            gap = float(jnp.mean(jnp.square(e_w - e_p))
                        / jnp.maximum(jnp.mean(jnp.square(e_p)), 1e-12))
            gaps.append(gap)
            flops += (f_weak + f_pow) * x.shape[0]
            if gap > threshold:
                switch = i
                break
        # take the weak step (reusing the weak probe when available)
        x = sampler.sample_phased(
            [(eps_fns[weak_mode], timesteps[i:i + 1])], sched, x,
            jax.random.fold_in(key, i), solver=solver)
        if not probe:
            flops += f_weak * x.shape[0]
        i += 1

    if switch < T:
        x = sampler.sample_phased(
            [(eps_fns[0], timesteps[switch:])], sched, x,
            jax.random.fold_in(key, 10_000 + switch), solver=solver)
        flops += f_pow * x.shape[0] * (T - switch)

    return AdaptiveResult(
        x0=x, switch_step=switch, gaps=gaps, flops=flops,
        flops_static_powerful=f_pow * x.shape[0] * T)


def make_mode_eps_fns(params: Any, cfg: ModelConfig, cond: Any, null_cond: Any,
                      cfg_scale: float = 1.5) -> List[Callable]:
    """Jitted per-mode guided NFEs (one executable per mode, as in §3.3)."""
    from repro.core.guidance import GuidanceConfig, make_eps_fn
    fns = []
    for mode in range(1 + len(cfg.dit.flex_patch_sizes)):
        g = GuidanceConfig(scale=cfg_scale, mode_cond=mode, mode_uncond=mode)
        fns.append(jax.jit(make_eps_fn(params, cfg, cond, null_cond, g)))
    return fns
