"""PI-resize (pseudo-inverse bilinear) weight projections — FlexiDiT §3.1.

Conventions (matching the paper exactly; see DESIGN.md §1):

* ``b_up(a, p')`` — the (tri)linear *upsampling* matrix ``B ∈ R^{Πp'ᵢ × Πaᵢ}``
  mapping a flattened patch at resolution ``a`` to resolution ``p'`` (p' ≥ a
  elementwise). Built by resizing basis vectors with ``jax.image.resize``.
* Embedding instantiation:   ``W(a)   = Q_embed(a) · w_flex`` with
  ``Q_embed(a) = pinv(B)``  (paper: "pseudo-inverse of the bilinear
  interpolation projection", ``Q ∈ R^{a²×p'²}``), applied per channel.
* Embedding init:            ``w_flex = B(p_pre→p') · w_pre`` — i.e.
  ``Q_embed(p_pre)† w_pre``. Then ``W(p_pre) = pinv(B)·B·w_pre = w_pre``
  **exactly** (B has full column rank), preserving the pre-trained forward.
* De-embedding instantiation: ``W_de(a) = w_de_flex · Q_de(a)`` with
  ``Q_de(a) = pinv(Bᵀ) = pinv(B)ᵀ ∈ R^{p'²×a²}`` ("flipped dimensions").
* De-embedding init:          ``w_de_flex = w_de_pre · Bᵀ`` — then
  ``W_de(p_pre) = w_de_pre·Bᵀ·pinv(Bᵀ) = w_de_pre`` exactly (Bᵀ full row
  rank).

All projection matrices are tiny (≤ p'³ × p³) and computed once with numpy;
they are constants folded into the instantiated weights, so switching modes
costs nothing at inference (paper App. C.2).
"""
from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@functools.lru_cache(maxsize=64)
def b_up(a: Tuple[int, ...], p_prime: Tuple[int, ...]) -> np.ndarray:
    """(Tri)linear upsampling matrix B: R^{prod(a)} → R^{prod(p')}.

    ``a`` and ``p_prime`` are patch shapes, e.g. (2, 2) or (1, 4, 4).
    Requires p'ᵢ ≥ aᵢ for full column rank (checked).
    """
    a = tuple(int(x) for x in a)
    p_prime = tuple(int(x) for x in p_prime)
    assert len(a) == len(p_prime)
    assert all(q >= b for q, b in zip(p_prime, a)), (a, p_prime)
    n_in = int(np.prod(a))
    n_out = int(np.prod(p_prime))
    basis = np.eye(n_in, dtype=np.float64).reshape((n_in,) + a)
    # ensure_compile_time_eval: this constant may first be requested while
    # tracing inside jit; the resize must still evaluate eagerly.
    with jax.ensure_compile_time_eval():
        resized = jax.image.resize(jnp.asarray(basis),
                                   (n_in,) + p_prime, method="linear")
        mat = np.asarray(resized, np.float64).reshape(n_in, n_out).T
    return mat  # [out, in]


@functools.lru_cache(maxsize=64)
def q_embed(a: Tuple[int, ...], p_prime: Tuple[int, ...]) -> np.ndarray:
    """Q_embed(a) = pinv(B_up(a→p')) ∈ R^{prod(a) × prod(p')}"""
    return np.linalg.pinv(b_up(a, p_prime))


@functools.lru_cache(maxsize=64)
def q_deembed(a: Tuple[int, ...], p_prime: Tuple[int, ...]) -> np.ndarray:
    """Q_de(a) = pinv(B_upᵀ) = Q_embed(a)ᵀ ∈ R^{prod(p') × prod(a)}"""
    return q_embed(a, p_prime).T


# ---------------------------------------------------------------------------
# Weight projection helpers. Embedding weights are stored as
#   w_flex: [prod(p'), c_in, d]        (per-channel projection)
# and de-embedding weights as
#   w_de_flex: [d, c_out, prod(p')],  b_de_flex: [c_out, prod(p')]


def project_embed(w_flex: jax.Array, a: Tuple[int, ...],
                  p_prime: Tuple[int, ...]) -> jax.Array:
    """[prod(p'), c, d] → [prod(a), c, d]"""
    Q = jnp.asarray(q_embed(a, p_prime), w_flex.dtype)
    return jnp.einsum("qp,pcd->qcd", Q, w_flex)


def project_deembed(w_flex: jax.Array, a: Tuple[int, ...],
                    p_prime: Tuple[int, ...]) -> jax.Array:
    """[d, c, prod(p')] → [d, c, prod(a)]"""
    Q = jnp.asarray(q_deembed(a, p_prime), w_flex.dtype)
    return jnp.einsum("dcp,pq->dcq", w_flex, Q)


def project_deembed_bias(b_flex: jax.Array, a: Tuple[int, ...],
                         p_prime: Tuple[int, ...]) -> jax.Array:
    """[c, prod(p')] → [c, prod(a)]"""
    Q = jnp.asarray(q_deembed(a, p_prime), b_flex.dtype)
    return jnp.einsum("cp,pq->cq", b_flex, Q)


def lift_embed(w_pre: jax.Array, p_pre: Tuple[int, ...],
               p_prime: Tuple[int, ...]) -> jax.Array:
    """Init: w_flex = B_up(p_pre→p') · w_pre.  [prod(p_pre),c,d] → [prod(p'),c,d]"""
    B = jnp.asarray(b_up(p_pre, p_prime), w_pre.dtype)
    return jnp.einsum("qp,pcd->qcd", B, w_pre)


def lift_deembed(w_pre: jax.Array, p_pre: Tuple[int, ...],
                 p_prime: Tuple[int, ...]) -> jax.Array:
    """Init: w_de_flex = w_de_pre · B_upᵀ.  [d,c,prod(p_pre)] → [d,c,prod(p')]"""
    B = jnp.asarray(b_up(p_pre, p_prime), w_pre.dtype)
    return jnp.einsum("dcp,qp->dcq", w_pre, B)


def lift_deembed_bias(b_pre: jax.Array, p_pre: Tuple[int, ...],
                      p_prime: Tuple[int, ...]) -> jax.Array:
    B = jnp.asarray(b_up(p_pre, p_prime), b_pre.dtype)
    return jnp.einsum("cp,qp->cq", b_pre, B)
