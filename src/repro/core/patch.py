"""Flexible (de-)tokenization: patchify / unpatchify for 2D images and 3D
videos, plus the flexible patch embed / de-embed built on ``core.resize``.

Latents are laid out ``[B, F, H, W, C]`` (F=1 for images). A patch size is a
triple ``(p_f, p_h, p_w)``. Tokenization with patch size p gives
``N = (F/p_f)·(H/p_h)·(W/p_w)`` tokens.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import resize

Patch = Tuple[int, int, int]


def num_tokens(latent_shape: Tuple[int, int, int, int], p: Patch) -> int:
    F, H, W, _ = latent_shape
    assert F % p[0] == 0 and H % p[1] == 0 and W % p[2] == 0, (latent_shape, p)
    return (F // p[0]) * (H // p[1]) * (W // p[2])


def patchify(x: jax.Array, p: Patch) -> jax.Array:
    """[B,F,H,W,C] → [B,N,prod(p),C]"""
    B, F, H, W, C = x.shape
    pf, ph, pw = p
    x = x.reshape(B, F // pf, pf, H // ph, ph, W // pw, pw, C)
    x = x.transpose(0, 1, 3, 5, 2, 4, 6, 7)
    return x.reshape(B, (F // pf) * (H // ph) * (W // pw), pf * ph * pw, C)


def unpatchify(tok: jax.Array, latent_shape: Tuple[int, int, int, int],
               p: Patch) -> jax.Array:
    """[B,N,prod(p),C] → [B,F,H,W,C]"""
    F, H, W, _ = latent_shape
    pf, ph, pw = p
    B, N, PP, C = tok.shape
    gf, gh, gw = F // pf, H // ph, W // pw
    x = tok.reshape(B, gf, gh, gw, pf, ph, pw, C)
    x = x.transpose(0, 1, 4, 2, 5, 3, 6, 7)
    return x.reshape(B, F, H, W, C)


def patch_centers(latent_shape: Tuple[int, int, int, int], p: Patch
                  ) -> np.ndarray:
    """Pixel-coordinate centers of every patch in the ORIGINAL latent frame
    (paper App. C.2: positions are identified by original-image coordinates,
    so all patch sizes share one coordinate system).  → [N, 3] float."""
    F, H, W, _ = latent_shape
    pf, ph, pw = p
    f = (np.arange(F // pf) + 0.5) * pf
    h = (np.arange(H // ph) + 0.5) * ph
    w = (np.arange(W // pw) + 0.5) * pw
    grid = np.stack(np.meshgrid(f, h, w, indexing="ij"), axis=-1)
    return grid.reshape(-1, 3)


def sincos_pos_embed(d: int, coords: np.ndarray) -> np.ndarray:
    """Fixed sin-cos embedding evaluated at fractional pixel coords [N,3].

    d is split across the 3 axes (f gets the remainder). Matches the DiT
    convention of sincos grids, generalized to arbitrary (shared) coords.
    """
    n_axes = coords.shape[1]
    d_axis = d // n_axes
    outs = []
    for ax in range(n_axes):
        dd = d - d_axis * (n_axes - 1) if ax == 0 else d_axis
        half = dd // 2
        freqs = 1.0 / (10_000.0 ** (np.arange(half) / max(1, half)))
        args = coords[:, ax:ax + 1] * freqs[None]
        emb = np.concatenate([np.sin(args), np.cos(args)], axis=1)
        if emb.shape[1] < dd:
            emb = np.pad(emb, ((0, 0), (0, dd - emb.shape[1])))
        outs.append(emb)
    return np.concatenate(outs, axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# Flexible embed / de-embed application


def embed_tokens_flex(w_flex: jax.Array, b: jax.Array, x: jax.Array,
                      p: Patch, p_prime: Patch) -> jax.Array:
    """Tokenize latent x [B,F,H,W,C] with patch size p using flexible weights.

    w_flex: [prod(p'), C, d]; b: [d] → tokens [B,N,d].
    Equivalent to a strided conv whose kernel is the PI-resized weight.
    """
    W = resize.project_embed(w_flex, p, p_prime)       # [prod(p), C, d]
    patches = patchify(x, p)                           # [B,N,prod(p),C]
    tok = jnp.einsum("bnpc,pcd->bnd", patches, W.astype(x.dtype),
                     preferred_element_type=jnp.float32).astype(x.dtype)
    return tok + b.astype(x.dtype)


def deembed_tokens_flex(w_flex: jax.Array, b_flex: jax.Array, tok: jax.Array,
                        latent_shape: Tuple[int, int, int, int], p: Patch,
                        p_prime: Patch, c_out: int) -> jax.Array:
    """De-tokenize [B,N,d] → latent [B,F,H,W,c_out] with patch size p.

    w_flex: [d, c_out, prod(p')]; b_flex: [c_out, prod(p')].
    """
    W = resize.project_deembed(w_flex, p, p_prime)     # [d, c_out, prod(p)]
    Bb = resize.project_deembed_bias(b_flex, p, p_prime)
    patches = jnp.einsum("bnd,dcq->bnqc", tok, W.astype(tok.dtype),
                         preferred_element_type=jnp.float32)
    patches = (patches + Bb.T.astype(jnp.float32)[None, None]).astype(tok.dtype)
    return unpatchify(patches, latent_shape, p)
