"""Packed CFG inference (App. B.2, Fig. 12).

When the conditional and guidance branches use different patch sizes, the
two NFEs propagate different sequence lengths. Four approaches:

  1. two separate NFEs (one powerful, one weak);
  2. one NFE per patch size with batch-2 stacking when both branches share a
     size (vanilla CFG fast path — ``core.guidance`` implements it);
  3. pad the weak sequence to the powerful length and batch both → 1 call,
     wasted FLOPs on padding;
  4. pack r = N_p/N_w weak sequences into one powerful-length row with
     block-diagonal (segment-id) attention masks (NaViT-style).

On TPU shapes must be static, so approach 4 packs to a fixed row length and
masks via segment ids inside attention (never materializing a [N,N] bool
mask in HBM). ``packed_weak_forward`` runs mode-m NFEs for ``r`` different
samples in one fused sequence; FLOPs/latency accounting for all four
approaches is in ``packing_cost``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.scheduler import dit_nfe_flops
from repro.models import dit as dit_mod


def pack_ratio(cfg: ModelConfig, mode: int) -> int:
    """How many mode-``mode`` sequences fit in one powerful-length row."""
    return dit_mod.tokens_for_mode(cfg, 0) // dit_mod.tokens_for_mode(cfg, mode)


def packed_weak_forward(params: Any, x_ts: jax.Array, t: jax.Array,
                        conds: jax.Array, cfg: ModelConfig, mode: int
                        ) -> jax.Array:
    """Run ``r`` weak NFEs packed into one sequence row per batch element.

    x_ts: [r, B, F, H, W, C] — r independent latents (e.g. the conditional
    and unconditional branches of several samples);
    t: [B]; conds: [r, B] class labels.
    Returns eps for each: [r, B, F, H, W, c_out].

    Implementation: tokens of the r latents are concatenated along the
    sequence axis with segment ids, attention is block-diagonal, adaLN
    conditioning is applied per segment.
    """
    r, B = x_ts.shape[:2]
    dit = cfg.dit
    p = dit_mod.patch_sizes(cfg)[mode]
    pp = dit.underlying_patch_size
    from repro.core import patch as patch_mod
    from repro.models.common import dtype_of, layer_norm
    dtype = dtype_of(cfg.compute_dtype)

    # tokenize each latent (shared flex weights → same as unpacked)
    toks = []
    for i in range(r):
        x_i = x_ts[i].astype(dtype)
        if mode > 0 and "embed_new" in params:
            pn = params["embed_new"][f"m{mode}"]
            patches = patch_mod.patchify(x_i, p)
            tok = jnp.einsum("bnqc,qcd->bnd", patches, pn["w"].astype(dtype)
                             ) + pn["b"].astype(dtype)
        else:
            tok = patch_mod.embed_tokens_flex(params["embed"]["w_flex"],
                                              params["embed"]["b"], x_i, p, pp)
        pos = jnp.asarray(dit_mod._pos_embed_np(dit.latent_shape, p,
                                                cfg.d_model), dtype)
        tok = tok + pos[None]
        if mode > 0:
            tok = tok + params["ps_embed"][mode - 1].astype(dtype)[None, None]
            tok = layer_norm(tok, 1.0 + params["ps_ln"]["scale"][mode - 1],
                             params["ps_ln"]["bias"][mode - 1])
        toks.append(tok)
    N_w = toks[0].shape[1]
    packed = jnp.concatenate(toks, axis=1)               # [B, r·N_w, d]
    segment_ids = jnp.repeat(jnp.arange(r, dtype=jnp.int32), N_w)[None]
    segment_ids = jnp.broadcast_to(segment_ids, (B, r * N_w))

    # per-segment conditioning vector: broadcast to token level via adaLN
    # (we fold the r conditionings by running blocks with per-token c).
    cs = [dit_mod.condition_vector(params, t, conds[i], cfg, dtype)
          for i in range(r)]                             # r × [B, d]
    c_tok = jnp.concatenate([jnp.repeat(c[:, None], N_w, axis=1)
                             for c in cs], axis=1)       # [B, r·N_w, d]

    def body(h, bp):
        h = _packed_block(bp, h, c_tok, cfg, mode, segment_ids)
        return h, None

    from repro.models.common import scan_or_unroll
    tok, _ = scan_or_unroll(body, packed, params["blocks"], cfg.unroll)

    ada = dit_mod._linear(jax.nn.silu(c_tok.astype(jnp.float32)).astype(dtype),
                          params["final"]["ada"]["w"],
                          params["final"]["ada"]["b"])
    sh, sc = jnp.split(ada, 2, axis=-1)
    tok = dit_mod._ln(tok) * (1.0 + sc) + sh

    outs = []
    for i in range(r):
        ti = tok[:, i * N_w:(i + 1) * N_w]
        if mode > 0 and "deembed_new" in params:
            pn = params["deembed_new"][f"m{mode}"]
            patches = jnp.einsum("bnd,dcq->bnqc", ti, pn["w"].astype(dtype))
            patches = patches + pn["b"].T.astype(patches.dtype)[None, None]
            out = patch_mod.unpatchify(patches, dit.latent_shape, p)
        else:
            out = patch_mod.deembed_tokens_flex(
                params["deembed"]["w_flex"], params["deembed"]["b_flex"],
                ti, dit.latent_shape, p, pp, dit_mod.c_out_dim(cfg))
        outs.append(out)
    return jnp.stack(outs)


def _packed_block(p: Any, x: jax.Array, c_tok: jax.Array, cfg: ModelConfig,
                  mode: int, segment_ids: jax.Array) -> jax.Array:
    """DiT block with per-token adaLN conditioning + segment-masked attention."""
    from repro.models.common import dtype_of
    H = cfg.attn.num_heads
    dtype = x.dtype
    ada = dit_mod._linear(jax.nn.silu(c_tok.astype(jnp.float32)).astype(dtype),
                          p["ada"]["w"], p["ada"]["b"])
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(ada, 6, axis=-1)
    lora = p.get("lora", {})
    h = dit_mod._ln(x) * (1.0 + sc1) + sh1
    attn = dit_mod._mha(p["attn"], h, H, lora=lora.get("attn"), mode=mode,
                        segment_ids=segment_ids)
    x = x + g1 * attn
    h2 = dit_mod._ln(x) * (1.0 + sc2) + sh2
    mlp_lora = lora.get("mlp", {})
    h2 = dit_mod._linear(h2, p["mlp"]["w_in"], p["mlp"]["b_in"],
                         lora=mlp_lora.get("w_in"), mode=mode)
    h2 = jax.nn.gelu(h2.astype(jnp.float32), approximate=True).astype(dtype)
    h2 = dit_mod._linear(h2, p["mlp"]["w_out"], p["mlp"]["b_out"],
                         lora=mlp_lora.get("w_out"), mode=mode)
    return x + g2 * h2


# ---------------------------------------------------------------------------
# FLOPs / latency accounting for the four approaches (Fig. 12)


@dataclasses.dataclass(frozen=True)
class PackingCost:
    approach: int
    nfe_calls: int          # sequential NFE launches
    flops: float            # total FLOPs
    longest_row_tokens: int  # latency proxy: tokens in the critical NFE


def packing_cost(cfg: ModelConfig, mode_weak: int, n_images: int
                 ) -> List[PackingCost]:
    """Costs for generating ``n_images`` with CFG where the conditional runs
    powerful and the guidance weak (per denoising step)."""
    f_p = dit_nfe_flops(cfg, 0)
    f_w = dit_nfe_flops(cfg, mode_weak)
    N_p = dit_mod.tokens_for_mode(cfg, 0)
    N_w = dit_mod.tokens_for_mode(cfg, mode_weak)
    r = max(1, N_p // N_w)
    n = n_images
    out = [
        # 1: separate sequential calls per branch
        PackingCost(1, 2, n * (f_p + f_w), N_p),
        # 2: batch conditional calls together; batch weak calls together
        PackingCost(2, 2, n * (f_p + f_w), N_p),
        # 3: pad weak rows to powerful length, single batched call
        PackingCost(3, 1, n * 2 * f_p, N_p),
        # 4: pack r weak rows into powerful-length rows, single call
        PackingCost(4, 1, n * f_p + int(np.ceil(n / r)) * f_p, N_p),
    ]
    return out
