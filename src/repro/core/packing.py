"""Packed inference (App. B.2, Fig. 12) — uniform and mixed-mode packs.

When NFEs at different patch sizes must run together, their sequence
lengths differ. Four approaches for packed CFG (Fig. 12):

  1. two separate NFEs (one powerful, one weak);
  2. one NFE per patch size with batch-2 stacking when both branches share a
     size (vanilla CFG fast path — ``core.guidance`` implements it);
  3. pad the weak sequence to the powerful length and batch both → 1 call,
     wasted FLOPs on padding;
  4. pack r = N_p/N_w weak sequences into one powerful-length row with
     block-diagonal (segment-id) attention masks (NaViT-style).

On TPU shapes must be static, so approach 4 packs to a fixed row length and
masks via segment ids inside attention (never materializing a [N,N] bool
mask in HBM). :func:`packed_mixed_forward` generalizes this to *mixed-mode*
packs — segments of different patch modes (weak AND powerful) share rows —
which is what the serving engine's continuous batcher composes every step
(``repro.serving``, DESIGN.md §serving). :func:`packed_weak_forward` is the
uniform special case. FLOPs/latency accounting (including the per-token
adaLN conditioning overhead packing introduces) is in :func:`packing_cost`
/ :func:`packed_row_flops` / :func:`mixed_pack_cost`.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.scheduler import dit_block_flops, dit_nfe_flops
from repro.models import dit as dit_mod


def pack_ratio(cfg: ModelConfig, mode: int) -> int:
    """How many mode-``mode`` sequences fit in one powerful-length row."""
    return dit_mod.tokens_for_mode(cfg, 0) // dit_mod.tokens_for_mode(cfg, mode)


# ---------------------------------------------------------------------------
# Static row assembly (shared by execution and cost accounting)


def assign_rows(seg_tokens: Sequence[int], capacity: int) -> List[List[int]]:
    """First-fit-decreasing bin packing: place segments (by token count)
    into rows of ``capacity`` tokens; a segment never splits across rows.
    Returns rows of segment *indices* (into ``seg_tokens``)."""
    for i, n in enumerate(seg_tokens):
        if n > capacity:
            raise ValueError(f"segment {i} ({n} tokens) exceeds row "
                             f"capacity {capacity}")
    order = sorted(range(len(seg_tokens)), key=lambda i: -seg_tokens[i])
    rows: List[List[int]] = []
    free: List[int] = []
    for i in order:
        n = seg_tokens[i]
        for r, rem in enumerate(free):
            if rem >= n:
                rows[r].append(i)
                free[r] = rem - n
                break
        else:
            rows.append([i])
            free.append(capacity - n)
    for row in rows:                 # deterministic within-row order
        row.sort()
    return rows


# ---------------------------------------------------------------------------
# Packed forwards


def packed_mixed_forward(params: Any, cfg: ModelConfig,  # repro: traced
                         groups: Tuple[Tuple[int, int], ...],
                         xs: Sequence[jax.Array], ts: Sequence[jax.Array],
                         conds: Sequence[jax.Array], *,
                         row_capacity: Optional[int] = None,
                         cache_deltas: Optional[Sequence[jax.Array]] = None,
                         cache_refresh: Optional[Sequence[jax.Array]] = None,
                         cache_split: Optional[int] = None,
                         attn_backend: str = "auto") -> Any:
    """Run NFEs for segments of (possibly) different patch modes packed
    token-wise into fixed-capacity rows.

    ``groups``: static ``((mode, n_segments), ...)``, one entry per mode;
    ``xs[g]``: [n_g, F, H, W, C] latents; ``ts[g]``: [n_g] timesteps;
    ``conds[g]``: [n_g] class labels. Rows of ``row_capacity`` tokens
    (default: the mode-0 sequence length) are filled first-fit-decreasing,
    attention is block-diagonal via segment ids, and adaLN conditioning is
    applied per token — so each segment's output equals its unpacked NFE.
    Returns one [n_g, F, H, W, c_out] array per group.

    Mixing modes inside one forward requires mode-independent transformer
    *blocks* (the shared-parameter recipe): per-mode LoRA adapters pick
    weights per row, not per token. Uniform packs (one group) work on any
    recipe.

    Cross-step activation cache (DESIGN.md §cache): with ``cache_split``
    set, ``cache_deltas[g]`` ([n_g, N_m, d] per segment) and
    ``cache_refresh[g]`` ([n_g] bool) thread each segment's OWN
    staleness clock through the pack. Shallow blocks always recompute on
    the packed rows; the deep blocks run under ``lax.cond`` only when
    ANY segment refreshes this step (attention is segment-masked, so a
    refreshing segment's fresh features never leak into a stale
    neighbour), and each token picks fresh vs replayed deltas by its
    segment's flag. Returns ``(outs, new_deltas)`` instead of ``outs``;
    a step where every segment refreshes is bit-identical to the
    uncached forward.
    """
    modes_present = [m for m, n in groups if n > 0]
    if len(modes_present) > 1 and cfg.dit.lora_rank > 0:
        raise ValueError("mixed-mode packs need mode-independent blocks "
                         "(LoRA recipe adapters are per-mode); pack "
                         "uniformly or merge/disable LoRA")
    block_mode = modes_present[0] if len(modes_present) == 1 else 0
    d = cfg.d_model
    from repro.models.common import dtype_of
    dtype = dtype_of(cfg.compute_dtype)
    seg_n = [dit_mod.tokens_for_mode(cfg, m) for m, _ in groups]
    capacity = row_capacity or max([dit_mod.tokens_for_mode(cfg, 0)] + seg_n)

    # per-group token streams [n_g, N_m, d] and conditioning vectors [n_g, d]
    toks, cvecs = [], []
    for g, (mode, n) in enumerate(groups):
        toks.append(dit_mod.embed_mode_tokens(params, xs[g], cfg, mode))
        cvecs.append(dit_mod.condition_vector(params, ts[g], conds[g], cfg,
                                              dtype))

    # flat segment list (group, index-within-group, tokens)
    segs: List[Tuple[int, int, int]] = []
    for g, (mode, n) in enumerate(groups):
        segs.extend((g, i, seg_n[g]) for i in range(n))
    rows = assign_rows([s[2] for s in segs], capacity)
    n_seg = len(segs)

    # adaLN conditioning is applied per token but COMPUTED per segment:
    # every block projects the [S+1, d] segment conditioning (last row =
    # zeros for padding) and gathers it token-wise — identical values to
    # a per-token projection at 1/N_seg the matmul cost
    seg_c = jnp.concatenate(
        [jnp.stack([cvecs[segs[s][0]][segs[s][1]] for s in range(n_seg)]),
         jnp.zeros((1, d), dtype)]) if n_seg else jnp.zeros((1, d), dtype)

    row_toks, row_seg, row_idx, placement = [], [], [], {}
    sid = 0
    for r, row in enumerate(rows):
        parts, sparts, iparts, off = [], [], [], 0
        for si in row:
            g, i, n = segs[si]
            parts.append(toks[g][i])
            sparts.append(jnp.full((n,), sid, jnp.int32))
            iparts.append(jnp.full((n,), si, jnp.int32))
            placement[(g, i)] = (r, off)
            sid += 1
            off += n
        if off < capacity:
            pad = capacity - off
            parts.append(jnp.zeros((pad, d), dtype))
            sparts.append(jnp.full((pad,), -1, jnp.int32))
            iparts.append(jnp.full((pad,), n_seg, jnp.int32))
        row_toks.append(jnp.concatenate(parts))
        row_seg.append(jnp.concatenate(sparts))
        row_idx.append(jnp.concatenate(iparts))
    packed = jnp.stack(row_toks)                     # [R, C, d]
    segment_ids = jnp.stack(row_seg)                 # [R, C]
    token_idx = jnp.stack(row_idx)                   # [R, C] → seg_c row

    def body(h, bp):
        h = _packed_block(bp, h, seg_c, token_idx, cfg, block_mode,
                          segment_ids, attn_backend)
        return h, None

    from repro.models.common import scan_or_unroll
    cached = cache_split is not None
    if not cached:
        tok, _ = scan_or_unroll(body, packed, params["blocks"], cfg.unroll)
    else:
        # cached deltas packed row-wise with the SAME placement as the
        # tokens; each token selects fresh vs replayed by its segment's
        # refresh flag (padding rides along with flag False, delta 0)
        drow_parts = []
        for row in rows:
            parts, off = [], 0
            for si in row:
                g, i, n = segs[si]
                parts.append(cache_deltas[g][i].astype(dtype))
                off += n
            if off < capacity:
                parts.append(jnp.zeros((capacity - off, d), dtype))
            drow_parts.append(jnp.concatenate(parts))
        delta_rows = jnp.stack(drow_parts)           # [R, C, d]
        refresh_flat = jnp.concatenate(
            [jnp.asarray(cache_refresh[g]).reshape(-1).astype(bool)
             for g in range(len(groups))])           # [n_seg]
        rf_pad = jnp.concatenate([refresh_flat, jnp.zeros((1,), bool)])
        rmask = jnp.take(rf_pad, token_idx)[..., None]   # [R, C, 1]

        shallow, deep = dit_mod.split_blocks(params["blocks"], cache_split)
        h_s, _ = scan_or_unroll(body, packed, shallow, cfg.unroll)

        def _with_deep(args):
            h, cached_rows = args
            h_d, _ = scan_or_unroll(body, h, deep, cfg.unroll)
            return (jnp.where(rmask, h_d, h + cached_rows),
                    jnp.where(rmask, h_d - h, cached_rows))

        def _no_deep(args):
            h, cached_rows = args
            return h + cached_rows, cached_rows

        tok, new_rows = jax.lax.cond(jnp.any(refresh_flat), _with_deep,
                                     _no_deep, (h_s, delta_rows))

    ada = dit_mod._linear(jax.nn.silu(seg_c.astype(jnp.float32)).astype(dtype),
                          params["final"]["ada"]["w"],
                          params["final"]["ada"]["b"])
    sh, sc = jnp.split(jnp.take(ada, token_idx, axis=0), 2, axis=-1)
    tok = dit_mod._ln(tok) * (1.0 + sc) + sh

    outs: List[jax.Array] = []
    new_deltas: List[jax.Array] = []
    for g, (mode, n) in enumerate(groups):
        if n == 0:
            outs.append(jnp.zeros((0,) + cfg.dit.latent_shape[:-1]
                                  + (dit_mod.c_out_dim(cfg),), dtype))
            if cached:
                new_deltas.append(jnp.zeros((0, seg_n[g], d), dtype))
            continue
        slices, dslices = [], []
        for i in range(n):
            r, off = placement[(g, i)]
            slices.append(tok[r, off:off + seg_n[g]])
            if cached:
                dslices.append(new_rows[r, off:off + seg_n[g]])
        outs.append(dit_mod.deembed_mode_tokens(
            params, jnp.stack(slices), cfg, mode))
        if cached:
            new_deltas.append(jnp.stack(dslices))
    return (outs, new_deltas) if cached else outs


def packed_weak_forward(params: Any, x_ts: jax.Array, t: jax.Array,
                        conds: jax.Array, cfg: ModelConfig, mode: int
                        ) -> jax.Array:
    """Run ``r`` weak NFEs packed into one sequence row per batch element
    (the uniform special case of :func:`packed_mixed_forward`).

    x_ts: [r, B, F, H, W, C] — r independent latents (e.g. the conditional
    and unconditional branches of several samples);
    t: [B]; conds: [r, B] class labels.
    Returns eps for each: [r, B, F, H, W, c_out].
    """
    r, B = x_ts.shape[:2]
    N_w = dit_mod.tokens_for_mode(cfg, mode)
    # flatten b-major so first-fit fills row b with that element's r segments
    xs = jnp.swapaxes(x_ts, 0, 1).reshape((B * r,) + x_ts.shape[2:])
    ts = jnp.repeat(t, r)
    cs = conds.T.reshape(-1)
    out = packed_mixed_forward(params, cfg, ((mode, B * r),), [xs], [ts],
                               [cs], row_capacity=r * N_w)[0]
    out = out.reshape((B, r) + out.shape[1:])
    return jnp.swapaxes(out, 0, 1)


def _packed_block(p: Any, x: jax.Array, seg_c: jax.Array,
                  token_idx: jax.Array, cfg: ModelConfig,
                  mode: int, segment_ids: jax.Array,
                  attn_backend: str = "auto") -> jax.Array:
    """DiT block with per-segment adaLN conditioning (gathered to token
    level via ``token_idx``) + segment-masked attention."""
    H = cfg.attn.num_heads
    dtype = x.dtype
    ada = dit_mod._linear(jax.nn.silu(seg_c.astype(jnp.float32)).astype(dtype),
                          p["ada"]["w"], p["ada"]["b"])
    ada = jnp.take(ada, token_idx, axis=0)           # [R, C, 6d]
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(ada, 6, axis=-1)
    lora = p.get("lora", {})
    h = dit_mod._ln(x) * (1.0 + sc1) + sh1
    attn = dit_mod._mha(p["attn"], h, H, lora=lora.get("attn"), mode=mode,
                        segment_ids=segment_ids, attn_backend=attn_backend)
    x = x + g1 * attn
    h2 = dit_mod._ln(x) * (1.0 + sc2) + sh2
    mlp_lora = lora.get("mlp", {})
    h2 = dit_mod._linear(h2, p["mlp"]["w_in"], p["mlp"]["b_in"],
                         lora=mlp_lora.get("w_in"), mode=mode)
    h2 = jax.nn.gelu(h2.astype(jnp.float32), approximate=True).astype(dtype)
    h2 = dit_mod._linear(h2, p["mlp"]["w_out"], p["mlp"]["b_out"],
                         lora=mlp_lora.get("w_out"), mode=mode)
    return x + g2 * h2


# ---------------------------------------------------------------------------
# FLOPs / latency accounting (Fig. 12 + serving packs)


@dataclasses.dataclass(frozen=True)
class PackingCost:
    approach: int
    nfe_calls: int          # sequential NFE launches
    flops: float            # total FLOPs
    longest_row_tokens: int  # latency proxy: tokens in the critical NFE


def packed_row_flops(cfg: ModelConfig, modes: Sequence[int],
                     capacity: Optional[int] = None,
                     attn_backend: str = "dense") -> float:
    """FLOPs of ONE packed row holding segments of the given modes.

    Accounts for the conditioning overhead packing introduces: every
    packed segment carries its OWN adaLN conditioning (the 6d block
    projection and the 2d final projection run once per segment, then
    gather to token level), the blocks see the full (padded) row, and
    (de-)embedding runs per segment at that segment's real length.

    ``attn_backend``: 'dense'/'xla-blocked' price the row's attention at
    the full C² score matrix (what the XLA paths compute, masked or
    not); 'pallas'/'auto' price only the block tiles the segment-aware
    flash kernel visits (cross-segment and padding tiles are skipped) —
    the serving controller and benches use this to charge what the
    default backend actually issues.
    """
    from repro.kernels.attention import costing
    seg_tokens = [dit_mod.tokens_for_mode(cfg, m) for m in modes]
    C = capacity if capacity is not None else sum(seg_tokens)
    if sum(seg_tokens) > C:
        raise ValueError(f"segments ({sum(seg_tokens)} tokens) exceed row "
                         f"capacity {C}")
    d, L = cfg.d_model, cfg.num_layers
    S = len(modes)
    fl = dit_block_flops(cfg, C)
    if attn_backend in ("pallas", "auto"):
        fl += L * (costing.block_sparse_attention_flops(seg_tokens, C, d)
                   - costing.dense_attention_flops(C, C, d))
    fl += L * 2 * (S - 1) * d * 6 * d        # block adaLN: one per SEGMENT
    fl += 2 * S * d * 2 * d                  # final adaLN, per segment
    c_in = cfg.dit.latent_shape[-1]
    c_out = dit_mod.c_out_dim(cfg)
    for m, N in zip(modes, seg_tokens):
        npix = int(np.prod(dit_mod.patch_sizes(cfg)[m]))
        fl += 2 * N * npix * c_in * d        # per-segment embed
        fl += 2 * N * d * npix * c_out       # per-segment de-embed
    return float(fl)


@dataclasses.dataclass(frozen=True)
class MixedPackCost:
    """Static cost of one mixed pack: rows actually assembled (first-fit,
    mirroring :func:`packed_mixed_forward`), total FLOPs, and the token
    ledger used for packing-efficiency metrics."""
    rows: int
    flops: float
    real_tokens: int        # sum of segment lengths
    packed_tokens: int      # rows * capacity (what the hardware computes)

    @property
    def efficiency(self) -> float:
        return self.real_tokens / self.packed_tokens if self.packed_tokens \
            else 1.0


def mixed_pack_cost(cfg: ModelConfig, modes: Sequence[int],
                    row_capacity: Optional[int] = None,
                    attn_backend: str = "dense") -> MixedPackCost:
    """Cost of packing one segment per entry of ``modes`` into rows of
    ``row_capacity`` tokens (default: the mode-0 length)."""
    seg_tokens = [dit_mod.tokens_for_mode(cfg, m) for m in modes]
    capacity = row_capacity or max([dit_mod.tokens_for_mode(cfg, 0)]
                                   + seg_tokens)
    rows = assign_rows(seg_tokens, capacity)
    fl = sum(packed_row_flops(cfg, [modes[i] for i in row], capacity,
                              attn_backend=attn_backend)
             for row in rows)
    return MixedPackCost(rows=len(rows), flops=fl,
                         real_tokens=sum(seg_tokens),
                         packed_tokens=len(rows) * capacity)


def pack_attention_block_stats(cfg: ModelConfig, modes: Sequence[int],
                               row_capacity: Optional[int] = None
                               ) -> Tuple[int, int]:
    """(active, total) attention block-tile visits for the pack one
    segment-per-``modes``-entry assembles (same first-fit row assembly
    as :func:`packed_mixed_forward`). ``1 - active/total`` is the
    cross-segment block skip rate ``serving.metrics`` reports."""
    from repro.kernels.attention import costing
    seg_tokens = [dit_mod.tokens_for_mode(cfg, m) for m in modes]
    capacity = row_capacity or max([dit_mod.tokens_for_mode(cfg, 0)]
                                   + seg_tokens)
    rows = assign_rows(seg_tokens, capacity)
    return costing.pack_attention_stats(
        [[seg_tokens[i] for i in row] for row in rows], capacity)


def packing_cost(cfg: ModelConfig, mode_weak: int, n_images: int
                 ) -> List[PackingCost]:
    """Costs for generating ``n_images`` with CFG where the conditional runs
    powerful and the guidance weak (per denoising step)."""
    f_p = dit_nfe_flops(cfg, 0)
    f_w = dit_nfe_flops(cfg, mode_weak)
    N_p = dit_mod.tokens_for_mode(cfg, 0)
    N_w = dit_mod.tokens_for_mode(cfg, mode_weak)
    r = max(1, N_p // N_w)
    n = n_images
    n_rows = int(np.ceil(n / r))
    # approach 4: the weak branch packs r segments per powerful-length row;
    # each row pays the per-token conditioning overhead (the last row is
    # padded to capacity, so it costs the same as a full one)
    packed_rows = n_rows * packed_row_flops(cfg, [mode_weak] * r,
                                            capacity=N_p)
    out = [
        # 1: separate sequential calls per branch
        PackingCost(1, 2, n * (f_p + f_w), N_p),
        # 2: batch conditional calls together; batch weak calls together
        PackingCost(2, 2, n * (f_p + f_w), N_p),
        # 3: pad weak rows to powerful length, single batched call
        PackingCost(3, 1, n * 2 * f_p, N_p),
        # 4: pack r weak rows into powerful-length rows, single call
        PackingCost(4, 1, n * f_p + packed_rows, N_p),
    ]
    return out
