"""Generation guidance (§3.4 + App. B.4).

Builds the per-phase ``eps_fn`` used by the sampler, implementing:

* vanilla CFG (p_cond == p_uncond): both NFEs in one batched call;
* the paper's weak-model guidance (p_cond < p_uncond): the *conditional*
  prediction of the weak model is the guidance signal —
  ``ε_w(c) + s₂·(ε_p(c) − ε_w(c))`` — two NFEs at different patch modes;
* the App. B.4 scale rule ``(1 − s₁)/(1 − s₂) = 2.5`` mapping a vanilla scale
  s₁ to the weak-guidance scale s₂.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import dit as dit_mod

SCALE_RULE = 2.5


@dataclasses.dataclass(frozen=True)
class GuidanceConfig:
    scale: float = 4.0           # s_cfg (vanilla scale, s₁)
    mode_cond: int = 0           # patch mode for the conditional NFE
    mode_uncond: int = 0         # patch mode for the guidance NFE
    # 'uncond'   → guidance signal is the unconditional prediction
    # 'weak_cond'→ guidance signal is the weak model's *conditional* pred.
    kind: str = "uncond"

    def effective_scale(self) -> float:
        if self.kind == "uncond":
            return self.scale
        # (1 - s1)/(1 - s2) = 2.5  →  s2 = 1 - (1 - s1)/2.5
        return 1.0 - (1.0 - self.scale) / SCALE_RULE


def split_model_out(out: jax.Array, cfg: ModelConfig
                    ) -> Tuple[jax.Array, Optional[jax.Array]]:
    c_in = cfg.dit.latent_shape[-1]
    if cfg.dit.learn_sigma:
        return out[..., :c_in], out[..., c_in:]
    return out, None


def make_eps_fn(params: Any, cfg: ModelConfig, cond: Any, null_cond: Any,
                g: GuidanceConfig,
                text_mask: Optional[jax.Array] = None,
                null_text_mask: Optional[jax.Array] = None,
                guidance_params: Any = None,
                parallel: Any = None,
                attn_backend: str = "auto") -> Callable:
    """Returns eps_fn(x, t) → (eps_guided, logvar_frac).

    ``guidance_params``: optional separate tree for the guidance NFE in the
    two-NFE (mixed patch size) path — e.g. the LoRA-merged weights for the
    weak mode while the conditional NFE runs the base weights.

    ``parallel``: optional ``distributed.engine.SeqParallel`` threaded into
    every NFE so all guidance variants run sequence-parallel.

    ``attn_backend`` selects the attention implementation inside every
    NFE (DESIGN.md §attention-backend).
    """
    s = g.effective_scale()
    g_params = params if guidance_params is None else guidance_params

    if g.scale == 0.0 or cond is None:
        def eps_plain(x, t):
            out = dit_mod.dit_forward(params, x, t, cond, cfg, mode=g.mode_cond,
                                      text_mask=text_mask, parallel=parallel,
                                      attn_backend=attn_backend)
            return split_model_out(out, cfg)
        return eps_plain

    if g.mode_cond == g.mode_uncond and g.kind == "uncond":
        # vanilla CFG — one NFE at 2× batch (same sequence length)
        def eps_cfg(x, t):
            x2 = jnp.concatenate([x, x], axis=0)
            t2 = jnp.concatenate([t, t], axis=0)
            if cond.ndim >= 2:    # text embeddings
                c2 = jnp.concatenate([cond, null_cond], axis=0)
                m2 = None
                if text_mask is not None:
                    m2 = jnp.concatenate([text_mask, null_text_mask], axis=0)
            else:                 # class labels
                c2 = jnp.concatenate([cond, null_cond], axis=0)
                m2 = None
            out = dit_mod.dit_forward(params, x2, t2, c2, cfg,
                                      mode=g.mode_cond, text_mask=m2,
                                      parallel=parallel,
                                      attn_backend=attn_backend)
            eps, logvar = split_model_out(out, cfg)
            e_c, e_u = jnp.split(eps, 2, axis=0)
            lv = None if logvar is None else jnp.split(logvar, 2, axis=0)[0]
            return e_u + g.scale * (e_c - e_u), lv
        return eps_cfg

    # mixed patch sizes — two NFEs (packing alternatives in core.packing)
    def eps_weak_guided(x, t):
        out_c = dit_mod.dit_forward(params, x, t, cond, cfg, mode=g.mode_cond,
                                    text_mask=text_mask, parallel=parallel,
                                    attn_backend=attn_backend)
        e_c, lv = split_model_out(out_c, cfg)
        if g.kind == "weak_cond":
            # paper: guidance = weak *conditional* prediction
            out_g = dit_mod.dit_forward(g_params, x, t, cond, cfg,
                                        mode=g.mode_uncond, text_mask=text_mask,
                                        parallel=parallel,
                                        attn_backend=attn_backend)
        else:
            out_g = dit_mod.dit_forward(g_params, x, t, null_cond, cfg,
                                        mode=g.mode_uncond,
                                        text_mask=null_text_mask,
                                        parallel=parallel,
                                        attn_backend=attn_backend)
        e_g, _ = split_model_out(out_g, cfg)
        return e_g + s * (e_c - e_g), lv

    return eps_weak_guided
