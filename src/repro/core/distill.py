"""Distillation fine-tuning for the LoRA recipe (§3.2).

Train to minimize  E‖ε_θ(x_t; p_powerful) − ε_θ(x_t; p_weak)‖²  where the
teacher (powerful mode, no LoRAs) is frozen — its pass has no trainable
parameters by construction of the recipe.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, TrainConfig
from repro.diffusion import schedule as sch
from repro.models import dit as dit_mod
from repro.models.common import dtype_of
from repro.optim import adamw


def distill_loss(params: Any, batch: Dict[str, jax.Array], key: jax.Array,
                 cfg: ModelConfig, sched: sch.DiffusionSchedule,
                 mode_weak: int) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    x0 = batch["x0"].astype(dtype_of(cfg.compute_dtype))
    k_t, k_n = jax.random.split(key)
    B = x0.shape[0]
    t = jax.random.randint(k_t, (B,), 0, sched.num_steps)
    noise = jax.random.normal(k_n, x0.shape, x0.dtype)
    x_t = sch.q_sample(sched, x0, t, noise)

    teacher = dit_mod.dit_forward(jax.lax.stop_gradient(params), x_t, t,
                                  batch.get("cond"), cfg, mode=0)
    student = dit_mod.dit_forward(params, x_t, t, batch.get("cond"), cfg,
                                  mode=mode_weak)
    e_t = dit_mod.eps_prediction(teacher, cfg).astype(jnp.float32)
    e_s = dit_mod.eps_prediction(student, cfg).astype(jnp.float32)
    loss = jnp.mean(jnp.square(e_t - e_s))
    return loss, {"distill_loss": loss}


def make_distill_step(cfg: ModelConfig, tc: TrainConfig,
                      sched: Optional[sch.DiffusionSchedule] = None,
                      mode_weak: int = 1,
                      trainable: Optional[Any] = None):
    """Jittable (params, opt_state, batch, key) → (params, opt_state, metrics).
    ``trainable`` comes from ``core.flexify.trainable_mask(params, 'lora')``."""
    sched = sched or sch.linear_schedule(1000)

    def step(params, opt_state, batch, key):
        (loss, metrics), grads = jax.value_and_grad(
            distill_loss, has_aux=True)(params, batch, key, cfg, sched,
                                        mode_weak)
        params, opt_state, om = adamw.adamw_update(params, grads, opt_state,
                                                   tc, trainable)
        return params, opt_state, {**metrics, **om}

    return step
