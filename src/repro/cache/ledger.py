"""Analytic FLOPs / bytes costing for cache-hit steps (DESIGN.md §cache).

Layered on ``core.scheduler.dit_block_flops``: a cache-skip step pays the
shallow blocks, the (de-)embedding, and the conditioning projections,
but not the deep blocks it replays. All functions are pure arithmetic
over static shapes — the serving controller prices cache-adjusted
budgets from them, and benches report FLOPs saved without touching the
device.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.cache.policy import CacheSpec, refresh_mask
from repro.configs.base import ModelConfig
from repro.core.scheduler import (FlexiSchedule, dit_block_flops,
                                  dit_nfe_flops, lora_nfe_overhead)
from repro.models import dit as dit_mod

_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2}


def deep_block_flops(cfg: ModelConfig, mode: int, split: int,
                     attn_backend: str = "dense") -> float:
    """FLOPs of the deep blocks ``[split, L)`` a cache-skip step avoids
    (batch 1, one NFE). ``dit_block_flops`` is linear in the layer count,
    so the deep share is exact, not an estimate. ``attn_backend`` prices
    attention at what the serving backend actually issues (block-granular
    under Pallas — DESIGN.md §attention-backend)."""
    L = cfg.num_layers
    N = dit_mod.tokens_for_mode(cfg, mode)
    return dit_block_flops(cfg, N, attn_backend=attn_backend) \
        * (L - split) / L


def cached_nfe_flops(cfg: ModelConfig, mode: int, split: int,
                     refresh: bool, attn_backend: str = "dense") -> float:
    """FLOPs of one NFE at ``mode`` under the cache: full on refresh,
    shallow-only (plus embed/de-embed/conditioning) on skip."""
    full = dit_nfe_flops(cfg, mode, attn_backend=attn_backend)
    if refresh:
        return full
    return full - deep_block_flops(cfg, mode, split,
                                   attn_backend=attn_backend)


def delta_bytes(cfg: ModelConfig, mode: int, guided: bool = True) -> int:
    """Bytes one request's cached deep-block residual occupies: one
    ``[N_mode, d]`` activation delta per CFG branch at compute dtype."""
    mult = 2 if guided else 1
    n_bytes = _DTYPE_BYTES.get(cfg.compute_dtype, 4)
    return mult * dit_mod.tokens_for_mode(cfg, mode) * cfg.d_model * n_bytes


def schedule_cached_flops(cfg: ModelConfig, schedule: FlexiSchedule,
                          ts: np.ndarray, spec: CacheSpec, *,
                          cfg_scale_active: bool = True,
                          lora_unmerged: bool = False,
                          attn_backend: str = "dense"
                          ) -> Tuple[float, int, int]:
    """Denoising FLOPs of one batch-1 sample under ``spec``'s refresh
    policy (both CFG branches share the request's staleness clock).
    Unmerged-LoRA overhead scales with the blocks that actually run:
    full on refresh, the shallow ``split/L`` share on skip. Returns
    ``(flops, n_refresh, n_steps)``."""
    split = spec.resolve_split(cfg.num_layers)
    mult = 2.0 if cfg_scale_active else 1.0
    skip_frac = split / cfg.num_layers
    total, n_refresh, n_steps = 0.0, 0, 0
    for mode, tsub in schedule.split_timesteps(np.asarray(ts)):
        mask = refresh_mask(spec, tsub)
        lora = lora_nfe_overhead(cfg, mode) if lora_unmerged else 0.0
        for rf in mask:
            total += mult * (cached_nfe_flops(cfg, mode, split, bool(rf),
                                              attn_backend=attn_backend)
                             + lora * (1.0 if rf else skip_frac))
        n_refresh += int(mask.sum())
        n_steps += len(mask)
    return total, n_refresh, n_steps


def cache_savings(cfg: ModelConfig, schedule: FlexiSchedule, ts: np.ndarray,
                  spec: CacheSpec, *, cfg_scale_active: bool = True
                  ) -> Dict[str, float]:
    """FLOPs ledger of a cached run vs its own uncached baseline (same
    schedule, same T): absolute FLOPs, the saved fraction, and the
    realized refresh rate."""
    from repro.core.scheduler import schedule_flops
    cached, n_refresh, n_steps = schedule_cached_flops(
        cfg, schedule, ts, spec, cfg_scale_active=cfg_scale_active)
    base = schedule_flops(cfg, schedule, cfg_scale_active=cfg_scale_active)
    return {"flops": cached, "flops_uncached": base,
            "flops_saved_frac": 1.0 - cached / base if base else 0.0,
            "refresh_rate": n_refresh / n_steps if n_steps else 1.0,
            "n_refresh": float(n_refresh), "n_steps": float(n_steps)}


def store_bytes(cfg: ModelConfig, slot_counts: Dict[int, int],
                guided: bool = True) -> int:
    """Total bytes a :class:`~repro.cache.store.CacheStore` holds for
    ``{mode: n_slots}``."""
    return sum(n * delta_bytes(cfg, m, guided)
               for m, n in slot_counts.items())
