"""Cached sampling loops (DESIGN.md §cache).

Builds the per-phase ``eps_fn_c(x, t, delta, refresh) → (eps, logvar,
new_delta)`` used by the cached pipeline runner, mirroring
``core.guidance.make_eps_fn`` (plain + vanilla-CFG branches; weak_cond
guidance mixes patch modes inside one step and is rejected at plan
validation), and the cached ddim/ddpm phase loops that thread the
deep-block residual delta through the ``lax.scan`` carry.

The refresh mask is a *scanned input*, not structure: one compiled
runner serves every policy/interval/threshold — switching policies
never recompiles. Solver-key derivation matches
``diffusion.sampler.sample_phased`` exactly (fold per non-empty phase,
split over its timesteps) so a refresh-every-step run is bit-identical
to the uncached pipeline.
"""
from __future__ import annotations

from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.guidance import GuidanceConfig, split_model_out
from repro.diffusion import schedule as sch
from repro.models import dit as dit_mod
from repro.telemetry import taps as taps_mod

# eps_fn_c(x, t[B], delta, refresh) -> (eps, logvar | None, new_delta)
CachedEpsFn = Callable[[jax.Array, jax.Array, jax.Array, jax.Array], Tuple]


def eff_batch(guided: bool, n: int) -> int:
    """Leading dim of the delta carry: CFG doubles the token stream."""
    return 2 * n if guided else n


def delta_shape(cfg: ModelConfig, mode: int, batch: int, guided: bool
                ) -> Tuple[int, int, int]:
    return (eff_batch(guided, batch),
            dit_mod.tokens_for_mode(cfg, mode), cfg.d_model)


def make_cached_eps_fn(params: Any, cfg: ModelConfig, cond: Any,
                       null_cond: Any, g: GuidanceConfig,
                       text_mask: Optional[jax.Array],
                       null_text_mask: Optional[jax.Array],
                       split: int,
                       attn_backend: str = "auto") -> CachedEpsFn:
    """Cached counterpart of ``core.guidance.make_eps_fn``. ``delta``
    covers the NFE's full token stream ([2B, N, d] under CFG — both
    branches share the request's staleness clock but carry their own
    features)."""
    if g.kind != "uncond" or g.mode_cond != g.mode_uncond:
        raise ValueError("the activation cache supports plain and "
                         "vanilla-CFG guidance only (weak_cond mixes "
                         "patch modes inside one step)")

    if g.scale == 0.0 or cond is None:
        def eps_plain(x, t, delta, refresh):
            out, nd = dit_mod.dit_forward(
                params, x, t, cond, cfg, mode=g.mode_cond,
                text_mask=text_mask, attn_backend=attn_backend,
                block_cache=dit_mod.BlockCache(delta, refresh, split))
            eps, lv = split_model_out(out, cfg)
            return eps, lv, nd
        return eps_plain

    def eps_cfg(x, t, delta, refresh):
        x2 = jnp.concatenate([x, x], axis=0)
        t2 = jnp.concatenate([t, t], axis=0)
        c2 = jnp.concatenate([cond, null_cond], axis=0)
        m2 = None
        if cond.ndim >= 2 and text_mask is not None:
            m2 = jnp.concatenate([text_mask, null_text_mask], axis=0)
        out, nd = dit_mod.dit_forward(
            params, x2, t2, c2, cfg, mode=g.mode_cond, text_mask=m2,
            attn_backend=attn_backend,
            block_cache=dit_mod.BlockCache(delta, refresh, split))
        eps, logvar = split_model_out(out, cfg)
        e_c, e_u = jnp.split(eps, 2, axis=0)
        lv = None if logvar is None else jnp.split(logvar, 2, axis=0)[0]
        return e_u + g.scale * (e_c - e_u), lv, nd

    return eps_cfg


# ---------------------------------------------------------------------------
# Cached phase loops (ddim / ddpm — the packed-step solver family)


def cached_ddim_phase(eps_fn_c: CachedEpsFn, sched: sch.DiffusionSchedule,
                      x: jax.Array, timesteps: np.ndarray,
                      refresh: jax.Array, key: jax.Array,
                      delta0: jax.Array, t_final: int = -1,
                      taps: bool = False):
    ts = jnp.asarray(timesteps, jnp.int32)
    ts_prev = jnp.concatenate([ts[1:], jnp.asarray([t_final], jnp.int32)])
    keys = jax.random.split(key, len(timesteps))

    def body(carry, inp):
        x, delta = carry
        t, tp, k, rf = inp
        tb = jnp.full((x.shape[0],), t, jnp.int32)
        tpb = jnp.full((x.shape[0],), tp, jnp.int32)
        eps, _, nd = eps_fn_c(x, tb, delta, rf)
        ys = ({"eps_norm": taps_mod.eps_norm_tap(eps),
               "drift": taps_mod.drift_tap(nd, delta)} if taps else None)
        return (sch.ddim_step(sched, x, eps, tb, tpb, 0.0, k), nd), ys

    (x, _), tap = jax.lax.scan(body, (x, delta0),
                               (ts, ts_prev, keys, refresh))
    return (x, tap) if taps else x


def cached_ddpm_phase(eps_fn_c: CachedEpsFn, sched: sch.DiffusionSchedule,
                      x: jax.Array, timesteps: np.ndarray,
                      refresh: jax.Array, key: jax.Array,
                      delta0: jax.Array, clip_x0: float = 0.0,
                      taps: bool = False):
    ts = jnp.asarray(timesteps, jnp.int32)
    keys = jax.random.split(key, len(timesteps))

    def body(carry, inp):
        x, delta = carry
        t, k, rf = inp
        tb = jnp.full((x.shape[0],), t, jnp.int32)
        eps, logvar, nd = eps_fn_c(x, tb, delta, rf)
        ys = ({"eps_norm": taps_mod.eps_norm_tap(eps),
               "drift": taps_mod.drift_tap(nd, delta)} if taps else None)
        return (sch.ddpm_step(sched, x, eps, tb, k, logvar, clip_x0),
                nd), ys

    (x, _), tap = jax.lax.scan(body, (x, delta0), (ts, keys, refresh))
    return (x, tap) if taps else x


def sample_phased_cached(phases: Sequence[Tuple[CachedEpsFn, np.ndarray,  # repro: traced
                                                jax.Array, jax.Array]],
                         sched: sch.DiffusionSchedule, x_T: jax.Array,
                         key: jax.Array, solver: str = "ddim",
                         clip_x0: float = 0.0, taps: bool = False):
    """Chain cached phases — each ``(eps_fn_c, timesteps, refresh_mask,
    delta0)``. Key folding matches ``sampler.sample_phased`` so
    refresh-every-step reproduces it bit-for-bit.

    ``taps`` (DESIGN.md §telemetry) additionally returns one tap dict
    per phase — ``{"eps_norm": [T_phase, B], "drift": [T_phase, effB]}``
    stacked by the phase scan — as pure extra data outputs; the sampled
    latents are bit-identical to ``taps=False``."""
    x = x_T
    phase_taps = []
    active = [p for p in phases if len(p[1])]
    for i, (eps_fn_c, ts, refresh, delta0) in enumerate(active):
        k = jax.random.fold_in(key, i)
        t_final = int(active[i + 1][1][0]) if i + 1 < len(active) else -1
        if solver == "ddpm":
            x = cached_ddpm_phase(eps_fn_c, sched, x, ts, refresh, k,
                                  delta0, clip_x0, taps=taps)
        elif solver == "ddim":
            x = cached_ddim_phase(eps_fn_c, sched, x, ts, refresh, k,
                                  delta0, t_final=t_final, taps=taps)
        else:
            raise ValueError(f"cached sampling supports ddim|ddpm, "
                             f"got {solver!r}")
        if taps:
            x, tap = x
            phase_taps.append(tap)
    return (x, tuple(phase_taps)) if taps else x
