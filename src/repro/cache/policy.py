"""Cross-step activation-cache refresh policies (DESIGN.md §cache).

A :class:`CacheSpec` declares how a sampling run reuses deep-block
features across denoise steps: the *split point* (how many shallow
blocks always recompute) and the *refresh policy* deciding, per step of
the timestep ladder, whether the deep blocks recompute (refresh) or
replay the cached residual delta (skip).

Every policy resolves ON THE HOST to a boolean refresh mask over a
phase's timestep ladder — the mask is data (a traced scan input), never
structure, so switching policies or thresholds on a warm runner never
recompiles. The clock resets at every phase boundary (the token count
changes with the patch mode, so the cache cannot carry over) and index 0
of each phase is always a refresh.

Policies:

* ``interval`` — refresh every ``interval`` steps (interval=1 refreshes
  every step, which is bit-identical to uncached sampling);
* ``banded`` — per timestep band: ``bands = ((t_lo, k), ...)`` uses
  interval ``k`` while ``t >= t_lo`` (first match in descending ``t_lo``
  order), falling back to ``interval`` below all bands;
* ``proxy`` — analytic error proxy: refresh when the *conditioning
  drift* since the last refresh exceeds ``threshold``. The conditioning
  vector is an MLP of the sinusoidal timestep embedding (plus a
  step-constant class/text term), so its drift is driven entirely by
  the embedding: we use the cosine distance between sinusoidal
  embeddings, computed analytically from the ladder with no model
  evaluation.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple

import numpy as np

CACHE_POLICIES = ("interval", "banded", "proxy")


def _temb_half() -> int:
    # derived from the model's actual embedding width so the analytic
    # drift can't silently diverge from the conditioning it stands in for
    from repro.models.dit import T_EMB_DIM
    return T_EMB_DIM // 2


_TEMB_MAX_PERIOD = 10_000.0   # models.common.timestep_embedding default


@dataclasses.dataclass(frozen=True)
class CacheSpec:
    """Declarative cross-step cache config (hashable — joins plan/runner
    cache keys). ``split=0`` resolves to ``max(1, num_layers // 4)``
    shallow blocks at apply time."""
    policy: str = "proxy"
    interval: int = 2                             # 'interval' + band fallback
    bands: Tuple[Tuple[int, int], ...] = ()       # ((t_lo, interval), ...)
    threshold: float = 0.05                       # 'proxy' drift trigger
    split: int = 0                                # shallow blocks (0 = auto)

    def __post_init__(self):
        if self.policy not in CACHE_POLICIES:
            raise ValueError(f"unknown cache policy {self.policy!r}; known: "
                             f"{CACHE_POLICIES}")
        if self.interval < 1:
            raise ValueError(f"interval must be >= 1, got {self.interval}")
        if self.threshold <= 0:
            raise ValueError(f"threshold must be > 0, got {self.threshold}")
        if self.split < 0:
            raise ValueError(f"split must be >= 0, got {self.split}")
        for band in self.bands:
            if len(band) != 2 or band[0] < 0 or band[1] < 1:
                raise ValueError(f"bands entries are (t_lo >= 0, "
                                 f"interval >= 1), got {band}")

    def resolve_split(self, num_layers: int) -> int:
        split = self.split or max(1, num_layers // 4)
        if not 1 <= split < num_layers:
            raise ValueError(f"cache split {split} must leave at least one "
                             f"deep block (model has {num_layers} layers)")
        return split

    @property
    def exact(self) -> bool:
        """Whether this spec can never skip (bit-identical to uncached)."""
        return (self.policy == "interval" and self.interval == 1
                and not self.bands)


# ---------------------------------------------------------------------------
# Analytic conditioning drift (the 'proxy' policy)


def timestep_embedding_np(t: np.ndarray,
                          low_frac: float = 1.0) -> np.ndarray:
    """Host-side sinusoidal timestep embedding, numerically matching
    ``models.common.timestep_embedding`` at ``models.dit.T_EMB_DIM``.
    ``low_frac`` keeps only the lowest-frequency fraction of the
    spectrum."""
    half = _temb_half()
    freqs = np.exp(-np.log(_TEMB_MAX_PERIOD)
                   * np.arange(half, dtype=np.float64) / half)
    if low_frac < 1.0:
        freqs = freqs[int(half * (1.0 - low_frac)):]
    args = np.asarray(t, np.float64).reshape(-1, 1) * freqs[None]
    return np.concatenate([np.cos(args), np.sin(args)], axis=-1)


def conditioning_drift(t_a, t_b) -> np.ndarray:
    """Cosine distance between the sinusoidal embeddings of two timestep
    ladders (elementwise over the leading axis) — the analytic stand-in
    for how far the adaLN conditioning has moved between two steps.

    Only the lowest-frequency HALF of the spectrum enters the metric:
    the high-frequency components rotate through full periods within a
    single ladder gap (they exist to make nearby timesteps separable,
    not to track closeness), so including them saturates the distance at
    ~O(1) for ANY gap and destroys the knob. The low half drifts
    smoothly and superlinearly with the gap — thresholding its
    accumulated value since the last refresh is a usable error proxy at
    every ladder density, and denser ladders (less change per step)
    naturally earn longer skip runs."""
    ea = timestep_embedding_np(t_a, low_frac=0.5)
    eb = timestep_embedding_np(t_b, low_frac=0.5)
    num = np.sum(ea * eb, axis=-1)
    den = np.linalg.norm(ea, axis=-1) * np.linalg.norm(eb, axis=-1)
    return 1.0 - num / np.maximum(den, 1e-20)


# ---------------------------------------------------------------------------
# Mask resolution


def _interval_for(spec: CacheSpec, t: int) -> int:
    for t_lo, k in sorted(spec.bands, key=lambda b: -b[0]):
        if t >= t_lo:
            return k
    return spec.interval


def refresh_mask(spec: CacheSpec, ts: np.ndarray) -> np.ndarray:
    """Boolean refresh mask over ONE phase's (descending) timestep
    ladder. Index 0 is always True (a fresh phase has no cache)."""
    ts = np.asarray(ts)
    n = len(ts)
    mask = np.zeros(n, bool)
    if n == 0:
        return mask
    mask[0] = True
    if spec.policy == "proxy":
        ref = ts[0]
        for i in range(1, n):
            if conditioning_drift(ts[i:i + 1], np.asarray([ref]))[0] \
                    > spec.threshold:
                mask[i] = True
                ref = ts[i]
        return mask
    since = 0
    for i in range(1, n):
        since += 1
        if since >= _interval_for(spec, int(ts[i])):
            mask[i] = True
            since = 0
    return mask


def ladder_refresh_mask(spec: CacheSpec,
                        phases: Sequence[Tuple[int, np.ndarray]]
                        ) -> np.ndarray:
    """Refresh mask over a full multi-phase ladder (``FlexiSchedule
    .split_timesteps`` output). The staleness clock resets at every phase
    boundary — the patch mode (and hence the token count) changes there,
    so the first step of each phase always refreshes."""
    parts: List[np.ndarray] = [refresh_mask(spec, tsub)
                               for _mode, tsub in phases]
    return np.concatenate(parts) if parts else np.zeros(0, bool)


def refresh_intervals(mask: np.ndarray) -> List[int]:
    """Gaps between consecutive refreshes in a realized mask (for the
    serving ledger's refresh-interval histogram)."""
    idx = np.flatnonzero(np.asarray(mask))
    return np.diff(idx).tolist()
