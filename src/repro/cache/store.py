"""Per-request activation-cache slots for the serving engine
(DESIGN.md §cache).

The :class:`CacheStore` is the engine's first stateful-across-dispatch
structure: one device-resident pytree per patch mode holding every
in-flight request's deep-block residual delta, addressed by *slot*. The
packed step gathers the dispatched cohort's slots into the layout's
group order, and scatters the updated deltas back afterwards — cache
state survives bucket migrations because slots are keyed by mode, never
by layout.

Slot management is host-side and O(1): a free list per mode, LRU
eviction when a mode's pool is exhausted (the evicted request silently
loses its cache and re-refreshes — correctness never depends on a slot
surviving), and an owner tag so the engine can detect eviction. Bytes
accounting (resident vs total) feeds the serving metrics ledger.
"""
from __future__ import annotations

import itertools
import zlib
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.cache import ledger
from repro.configs.base import ModelConfig
from repro.models import dit as dit_mod


class TransientAllocationError(RuntimeError):
    """A slot allocation failed transiently; retry on a later dispatch.

    The engine treats the request as slotless for the current dispatch
    (deep blocks recomputed exactly, no cache writes) and re-allocates
    next time — correctness never depends on the slot existing."""


class CacheStore:
    """Slotted deep-block residual deltas, one pool per patch mode.

    Each mode's pool is a ``[n_slots, mult, N_mode, d]`` array (``mult``
    = 2 under CFG: conditional and unconditional branches share the
    request's staleness clock but carry distinct features).
    """

    def __init__(self, cfg: ModelConfig, modes: Sequence[int],
                 n_slots: int, *, guided: bool = True,
                 dtype: Optional[jnp.dtype] = None,
                 integrity: bool = False):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        from repro.models.common import dtype_of
        self.cfg = cfg
        self.guided = guided
        self.n_slots = n_slots
        self.mult = 2 if guided else 1
        self.dtype = dtype or dtype_of(cfg.compute_dtype)
        self.modes = tuple(sorted(set(modes)))
        #: when True every scatter records a CRC32 per slot and
        #: :meth:`verify_slot` can detect out-of-band corruption. Costs a
        #: host readback of each scattered row, so it is opt-in (chaos /
        #: integrity-sensitive deployments only).
        self.integrity = integrity
        self._deltas: Dict[int, jax.Array] = {}
        self._free: Dict[int, List[int]] = {}
        self._owner: Dict[int, Dict[int, int]] = {}    # mode → slot → owner
        self._stamp: Dict[int, Dict[int, int]] = {}    # mode → slot → LRU tick
        self._crc: Dict[int, Dict[int, int]] = {}      # mode → slot → crc32
        self._tick = itertools.count()
        self.evictions = 0
        self.corruptions = 0
        self.integrity_failures = 0
        self._fail_allocs = 0
        for m in self.modes:
            n_tok = dit_mod.tokens_for_mode(cfg, m)
            self._deltas[m] = jnp.zeros(
                (n_slots, self.mult, n_tok, cfg.d_model), self.dtype)
            self._free[m] = list(range(n_slots - 1, -1, -1))
            self._owner[m] = {}
            self._stamp[m] = {}
            self._crc[m] = {}

    # ------------------------------------------------------------------
    # Slot lifecycle

    def alloc(self, mode: int, owner: int) -> int:
        """Claim a slot in ``mode``'s pool for ``owner`` (a request id).
        When the pool is exhausted the least-recently-touched active
        slot is evicted — its previous owner simply stops matching
        ``owner_of`` and must refresh on its next dispatch."""
        if self._fail_allocs > 0:
            self._fail_allocs -= 1
            raise TransientAllocationError(
                f"injected transient allocation failure (mode={mode}, "
                f"owner={owner})")
        if self._free[mode]:
            slot = self._free[mode].pop()
        else:
            slot = min(self._stamp[mode], key=self._stamp[mode].get)
            self.evictions += 1
        self._owner[mode][slot] = owner
        self._stamp[mode][slot] = next(self._tick)
        return slot

    def release(self, mode: int, slot: int) -> None:
        if slot in self._owner[mode]:
            del self._owner[mode][slot]
            del self._stamp[mode][slot]
            self._crc[mode].pop(slot, None)
            self._free[mode].append(slot)

    def owner_of(self, mode: int, slot: int) -> Optional[int]:
        return self._owner[mode].get(slot)

    def touch(self, mode: int, slot: int) -> None:
        if slot in self._stamp[mode]:
            self._stamp[mode][slot] = next(self._tick)

    # ------------------------------------------------------------------
    # Device state

    def gather(self, mode: int, slots: Sequence[int]) -> jax.Array:
        """[len(slots), mult, N_mode, d] deltas for a dispatch, in the
        layout's request order (one device gather)."""
        return self._deltas[mode][np.asarray(slots, np.int32)]

    def scatter(self, mode: int, slots: Sequence[int],
                values: jax.Array) -> None:
        """Write a dispatch's updated deltas back (one scatter)."""
        idx = np.asarray(slots, np.int32)
        self._deltas[mode] = self._deltas[mode].at[idx].set(
            values.astype(self.dtype))
        for s in slots:
            self.touch(mode, int(s))
        if self.integrity:
            host = np.asarray(values.astype(self.dtype))
            for i, s in enumerate(slots):
                self._crc[mode][int(s)] = zlib.crc32(host[i].tobytes())

    # ------------------------------------------------------------------
    # Integrity

    def verify_slot(self, mode: int, slot: int) -> bool:
        """True when the slot's resident bytes still match the checksum
        recorded at its last scatter (or no checksum exists yet — a
        fresh slot refreshes anyway). Requires ``integrity=True``."""
        want = self._crc[mode].get(int(slot))
        if want is None:
            return True
        got = zlib.crc32(np.asarray(self._deltas[mode][int(slot)]).tobytes())
        if got != want:
            self.integrity_failures += 1
            return False
        return True

    def corrupt_slot(self, mode: int, slot: int) -> None:
        """Overwrite a resident slot's delta with *finite* garbage — only
        a checksum mismatch can tell (fault-injection seam)."""
        row = self._deltas[mode][int(slot)]
        self._deltas[mode] = self._deltas[mode].at[int(slot)].set(
            row * jnp.asarray(-1.0, self.dtype)
            + jnp.asarray(0.37, self.dtype))
        self.corruptions += 1

    def fail_allocs(self, count: int) -> None:
        """Make the next ``count`` :meth:`alloc` calls raise
        :class:`TransientAllocationError` (fault-injection seam)."""
        self._fail_allocs += int(count)

    def active_slots(self) -> List[Tuple[int, int]]:
        """Every owned ``(mode, slot)`` pair, deterministic order."""
        return [(m, s) for m in self.modes for s in sorted(self._owner[m])]

    # ------------------------------------------------------------------
    # Accounting

    @property
    def n_active(self) -> int:
        return sum(len(o) for o in self._owner.values())

    def active_by_mode(self) -> Dict[int, int]:
        return {m: len(self._owner[m]) for m in self.modes}

    @property
    def bytes_resident(self) -> int:
        """Bytes of delta state belonging to live requests."""
        return ledger.store_bytes(self.cfg, self.active_by_mode(),
                                  self.guided)

    @property
    def bytes_total(self) -> int:
        """Bytes the pools occupy on device (allocated up front)."""
        return ledger.store_bytes(self.cfg,
                                  {m: self.n_slots for m in self.modes},
                                  self.guided)
