"""Cross-step activation cache (DESIGN.md §cache).

Adjacent denoise steps are highly redundant; this subsystem caches the
deep transformer blocks' residual contribution at *refresh* steps and
replays it (shallow blocks still recompute) at *skip* steps —
composable with FlexiDiT's token-reduction on both the plain pipeline
and the packed serving engine. ``policy`` decides when to refresh,
``store`` carries per-request state across packed dispatches,
``apply`` builds the cached sampling loops, and ``ledger`` prices
cache-hit steps analytically.
"""
from repro.cache.apply import (make_cached_eps_fn,  # noqa: F401
                               sample_phased_cached)
from repro.cache.ledger import (cache_savings, cached_nfe_flops,  # noqa: F401
                                deep_block_flops, delta_bytes,
                                schedule_cached_flops, store_bytes)
from repro.cache.policy import (CACHE_POLICIES, CacheSpec,  # noqa: F401
                                conditioning_drift, ladder_refresh_mask,
                                refresh_intervals, refresh_mask)
from repro.cache.store import CacheStore  # noqa: F401
