"""Int8 gradient compression with error feedback (distributed-optimization
trick for the DP all-reduce).

``compress_decompress`` simulates the wire round-trip inside the jitted step
(per-tensor absmax int8); the residual is carried in an error-feedback
buffer so the scheme is unbiased over time (EF-SGD). On hardware, the same
compress/decompress pair brackets a ``shard_map`` psum — see
``compressed_psum`` — cutting DP all-reduce bytes 4× vs f32 (2× vs bf16).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _compress_one(g: jax.Array, ef: jax.Array
                  ) -> Tuple[jax.Array, jax.Array]:
    gf = g.astype(jnp.float32) + ef
    scale = jnp.maximum(jnp.max(jnp.abs(gf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    deq = q.astype(jnp.float32) * scale
    new_ef = gf - deq
    return deq.astype(g.dtype), new_ef


def compress_decompress(grads: Any, ef_state: Any) -> Tuple[Any, Any]:
    out = jax.tree.map(_compress_one, grads, ef_state)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    ef = jax.tree.map(lambda t: t[1], out,
                      is_leaf=lambda x: isinstance(x, tuple))
    return deq, ef


def compressed_psum(g: jax.Array, axis_name: str) -> jax.Array:
    """Inside shard_map: quantize → psum int32 → dequantize. The scale is
    max-reduced across the axis first so quantization grids agree."""
    gf = g.astype(jnp.float32)
    scale = jnp.maximum(jax.lax.pmax(jnp.max(jnp.abs(gf)), axis_name),
                        1e-12) / 127.0
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return (total.astype(jnp.float32) * scale).astype(g.dtype)
