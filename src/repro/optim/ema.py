"""Exponential moving average of parameters (paper trains with EMA 0.9999)."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def init_ema(params: Any) -> Any:
    return jax.tree.map(lambda p: p.astype(jnp.float32), params)


def ema_update(ema: Any, params: Any, rate: float = 0.9999) -> Any:
    return jax.tree.map(
        lambda e, p: e * rate + p.astype(jnp.float32) * (1.0 - rate),
        ema, params)


def ema_params(ema: Any, like: Any) -> Any:
    return jax.tree.map(lambda e, p: e.astype(p.dtype), ema, like)
