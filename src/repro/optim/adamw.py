"""AdamW + LR schedules + global-norm clipping (no optax offline).

Optimizer state is a pytree mirroring params — under the fsdp2d sharding
profile it inherits the fully-2D-sharded specs, i.e. ZeRO-sharded for free.
``opt_dtype`` allows bf16 moments for the 314B-class models (see DESIGN.md
memory budget)."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig

Params = Any


def init_opt_state(params: Params, opt_dtype: jnp.dtype = jnp.float32) -> Dict:
    zeros = lambda p: jnp.zeros(p.shape, opt_dtype)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def abstract_opt_state(params: Params, opt_dtype: jnp.dtype = jnp.float32) -> Dict:
    sds = lambda p: jax.ShapeDtypeStruct(p.shape, opt_dtype)
    return {"m": jax.tree.map(sds, params),
            "v": jax.tree.map(sds, params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}


def lr_at(tc: TrainConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1.0) / max(1, tc.warmup_steps))
    frac = jnp.clip((step - tc.warmup_steps)
                    / max(1, tc.total_steps - tc.warmup_steps), 0.0, 1.0)
    if tc.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
    elif tc.schedule == "linear":
        decay = 1.0 - frac
    else:
        decay = jnp.asarray(1.0)
    return tc.learning_rate * warm * decay


def global_norm(tree: Params) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads: Params, max_norm: float
                        ) -> Tuple[Params, jax.Array]:
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype),
                        grads), g


def adamw_update(params: Params, grads: Params, opt_state: Dict,
                 tc: TrainConfig,
                 trainable: Optional[Params] = None
                 ) -> Tuple[Params, Dict, Dict[str, jax.Array]]:
    """One AdamW step. ``trainable``: optional bool pytree freezing leaves
    (used by the FlexiDiT LoRA recipe)."""
    step = opt_state["step"] + 1
    lr = lr_at(tc, step)
    if tc.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
    else:
        gnorm = global_norm(grads)
    b1, b2, eps = tc.beta1, tc.beta2, tc.eps
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v, t=True):
        gf = g.astype(jnp.float32)
        m_new = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v_new = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        mh = m_new / c1
        vh = v_new / c2
        delta = lr * (mh / (jnp.sqrt(vh) + eps) + tc.weight_decay
                      * p.astype(jnp.float32))
        p_new = (p.astype(jnp.float32) - delta).astype(p.dtype)
        if t is not True:    # traced/bool leaf freezing
            keep = jnp.asarray(t, jnp.bool_)
            p_new = jnp.where(keep, p_new, p)
            m_new = jnp.where(keep, m_new, m.astype(jnp.float32))
            v_new = jnp.where(keep, v_new, v.astype(jnp.float32))
        return p_new, m_new.astype(m.dtype), v_new.astype(v.dtype)

    if trainable is None:
        out = jax.tree.map(upd, params, grads, opt_state["m"], opt_state["v"])
    else:
        out = jax.tree.map(upd, params, grads, opt_state["m"],
                           opt_state["v"], trainable)
    p_new = jax.tree.map(lambda x: x[0], out, is_leaf=lambda x: isinstance(x, tuple))
    m_new = jax.tree.map(lambda x: x[1], out, is_leaf=lambda x: isinstance(x, tuple))
    v_new = jax.tree.map(lambda x: x[2], out, is_leaf=lambda x: isinstance(x, tuple))
    new_state = {"m": m_new, "v": v_new, "step": step}
    return p_new, new_state, {"lr": lr, "grad_norm": gnorm}
