"""Deterministic fault injection for the serving fleet (host-pure).

A :class:`FaultPlan` is a scripted schedule of :class:`FaultEvent`\\ s on
the fleet's injectable clock; the :class:`FaultInjector` is the armed
referee the control plane consults at its existing seams (replica pump,
heartbeat delivery, cache-slot alloc, post-dispatch step outputs).  The
module is deliberately **host-pure** — no jax, no numpy — it only
*decides* what goes wrong and when; the data plane (scheduler / store /
fleet) performs the actual device mutations.  ``analysis/
rules_resilience.py`` lint-enforces both halves of that contract: this
module stays host-pure, and every seam call is lexically guarded by an
``is not None`` armed check so a disarmed run executes the exact same
device-op sequence as before this layer existed.

Fault taxonomy (see DESIGN.md §resilience):

======================  =====================================================
kind                    effect when due
======================  =====================================================
``crash``               replica killed (heartbeats stop, in-flight orphaned)
``hang``                replica stops pumping but keeps heart beating
``unhang``              lifts a prior ``hang``
``heartbeat_delay``     beats from the replica delivered late, out of order,
                        with their *original* send timestamp
``partition``           beats from the replica dropped for a window
``slowdown``            replica's modeled dispatch time multiplied
``poison``              one fleet request's next packed-step latent row
                        overwritten with NaN (post-dispatch host hook)
``corrupt_slot``        one resident cache slot's delta overwritten with
                        finite garbage (only the checksum can tell)
``alloc_fail``          the replica's next N cache-slot allocations fail
                        transiently
======================  =====================================================
"""
from __future__ import annotations

import heapq
import random
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

CRASH = "crash"
HANG = "hang"
UNHANG = "unhang"
HEARTBEAT_DELAY = "heartbeat_delay"
PARTITION = "partition"
SLOWDOWN = "slowdown"
POISON = "poison"
CORRUPT_SLOT = "corrupt_slot"
ALLOC_FAIL = "alloc_fail"

FAULT_KINDS = (CRASH, HANG, UNHANG, HEARTBEAT_DELAY, PARTITION, SLOWDOWN,
               POISON, CORRUPT_SLOT, ALLOC_FAIL)


@dataclass(frozen=True)
class FaultEvent:
    """One scripted fault, due at fleet-clock time ``at``."""

    at: float
    kind: str
    replica: int = -1       # target replica (all kinds except poison-by-rid)
    rid: int = -1           # target fleet request id (poison)
    duration: float = 0.0   # window length (delay / partition / slowdown)
    delay: float = 0.0      # heartbeat delivery delay (heartbeat_delay)
    factor: float = 1.0     # dispatch-time multiplier (slowdown)
    count: int = 1          # number of transient failures (alloc_fail)

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; known: {FAULT_KINDS}")


@dataclass
class FaultPlan:
    """A seeded, scripted schedule of faults on the injectable clock."""

    events: List[FaultEvent] = field(default_factory=list)
    seed: int = 0

    def add(self, at: float, kind: str, **kw) -> FaultEvent:
        ev = FaultEvent(at=at, kind=kind, **kw)
        self.events.append(ev)
        return ev

    def __len__(self) -> int:
        return len(self.events)


class FaultInjector:
    """Armed referee over a :class:`FaultPlan`.

    The fleet pops :meth:`due` events each tick and applies them; window
    faults (slowdown / beat delay / partition) are recorded here and
    consulted by the seams through cheap host-pure queries.  Events whose
    target is not actionable yet (e.g. poisoning a request that has not
    been placed) are re-queued via :meth:`defer` and retried next tick.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.rng = random.Random(plan.seed)
        self._queue: List[Tuple[float, int, FaultEvent]] = []
        for i, ev in enumerate(plan.events):
            heapq.heappush(self._queue, (ev.at, i, ev))
        self._seq = len(plan.events)
        # window state
        self._slow: Dict[int, Tuple[float, float]] = {}      # rid -> (until, x)
        self._beat_delay: Dict[int, Tuple[float, float]] = {}
        self._partition: Dict[int, float] = {}               # rid -> until
        self._held_beats: List[Tuple[float, int, int, float]] = []
        self._beat_seq = 0
        # targeted state
        self.pending_poison: Set[Tuple[int, int]] = set()    # (replica, erid)
        self.poison_targets: Set[Tuple[int, int]] = set()    # ever poisoned
        self.alloc_failures: Dict[int, int] = {}
        self.counters: Dict[str, int] = {
            "applied": 0, "deferred": 0, "poisoned": 0, "alloc_failed": 0,
            "beats_dropped": 0, "beats_delayed": 0, "corrupted": 0,
        }

    # ------------------------------------------------------------- schedule
    def due(self, now: float) -> List[FaultEvent]:
        """Pop every event whose time has come (stable order)."""
        out: List[FaultEvent] = []
        while self._queue and self._queue[0][0] <= now:
            out.append(heapq.heappop(self._queue)[2])
        self.counters["applied"] += len(out)
        return out

    def defer(self, ev: FaultEvent) -> None:
        """Re-queue an event whose target is not actionable yet."""
        self.counters["applied"] -= 1
        self.counters["deferred"] += 1
        self._seq += 1
        heapq.heappush(self._queue, (ev.at, self._seq, ev))

    def exhausted(self) -> bool:
        return not self._queue

    # -------------------------------------------------------------- windows
    def slow(self, replica: int, until: float, factor: float) -> None:
        self._slow[replica] = (until, factor)

    def slowdown_factor(self, replica: int, now: float) -> float:
        w = self._slow.get(replica)
        if w is None or now >= w[0]:
            return 1.0
        return w[1]

    def delay_beats(self, replica: int, until: float, delay: float) -> None:
        self._beat_delay[replica] = (until, delay)

    def partition(self, replica: int, until: float) -> None:
        self._partition[replica] = until

    def route_beat(self, replica: int, now: float) -> Optional[float]:
        """Decide the fate of a heartbeat sent by ``replica`` at ``now``.

        Returns the timestamp to deliver immediately, or ``None`` when the
        beat is dropped (partition) or buffered (delay).  Buffered beats
        surface later through :meth:`due_beats` carrying their *original*
        send time — deliberately out of order with fresher direct beats,
        exercising the monitor's clock-skew tolerance.
        """
        until = self._partition.get(replica)
        if until is not None and now < until:
            self.counters["beats_dropped"] += 1
            return None
        w = self._beat_delay.get(replica)
        if w is not None and now < w[0]:
            self._beat_seq += 1
            heapq.heappush(self._held_beats,
                           (now + w[1], self._beat_seq, replica, now))
            self.counters["beats_delayed"] += 1
            return None
        return now

    def due_beats(self, now: float) -> List[Tuple[int, float]]:
        """Buffered ``(replica, original_stamp)`` beats due for delivery."""
        out: List[Tuple[int, float]] = []
        while self._held_beats and self._held_beats[0][0] <= now:
            _, _, rid, stamp = heapq.heappop(self._held_beats)
            out.append((rid, stamp))
        return out

    # ------------------------------------------------------------- targeted
    def add_poison(self, replica: int, engine_rid: int) -> None:
        self.pending_poison.add((replica, engine_rid))
        self.poison_targets.add((replica, engine_rid))

    def take_poison(self, replica: int, engine_rid: int) -> bool:
        try:
            self.pending_poison.remove((replica, engine_rid))
        except KeyError:
            return False
        self.counters["poisoned"] += 1
        return True

    def is_poison_target(self, replica: int, engine_rid: int) -> bool:
        """True when the request was ever scheduled for poisoning on
        this replica (pending *or* already applied) — such a request is
        headed for quarantine, so its cache slot is a poor corruption
        target (released before any pack could verify it)."""
        return (replica, engine_rid) in self.poison_targets

    def add_alloc_failures(self, replica: int, count: int) -> None:
        self.alloc_failures[replica] = \
            self.alloc_failures.get(replica, 0) + int(count)

    def take_alloc_failure(self, replica: int) -> bool:
        left = self.alloc_failures.get(replica, 0)
        if left <= 0:
            return False
        self.alloc_failures[replica] = left - 1
        self.counters["alloc_failed"] += 1
        return True

    def note_corruption(self) -> None:
        self.counters["corrupted"] += 1

    # ---------------------------------------------------------------- views
    def for_replica(self, rid: int) -> "ReplicaFaults":
        return ReplicaFaults(self, rid)

    def summary(self) -> Dict[str, int]:
        out = dict(self.counters)
        out["events"] = len(self.plan.events)
        out["pending"] = len(self._queue)
        return out


class ReplicaFaults:
    """Per-replica facade handed to a ServingEngine / Replica.

    Engine request ids are replica-local, so the engine-facing queries
    carry the replica id implicitly.  Also usable standalone (tests) by
    constructing ``FaultInjector(plan).for_replica(0)``.
    """

    __slots__ = ("_inj", "rid")

    def __init__(self, injector: FaultInjector, rid: int):
        self._inj = injector
        self.rid = rid

    def take_poison(self, engine_rid: int) -> bool:
        return self._inj.take_poison(self.rid, engine_rid)

    def take_alloc_failure(self) -> bool:
        return self._inj.take_alloc_failure(self.rid)

    def slowdown_factor(self, now: float) -> float:
        return self._inj.slowdown_factor(self.rid, now)
