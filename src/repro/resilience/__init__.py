"""Deterministic fault injection and end-to-end recovery (DESIGN.md
§resilience).

``faults`` and ``journal`` are host-pure (lint-enforced); the chaos
driver ``repro.resilience.chaos`` pulls in the full fleet stack and is
imported explicitly, not here, to keep this package importable from
control-plane code without touching jax.
"""
from repro.resilience.faults import (ALLOC_FAIL, CORRUPT_SLOT,  # noqa: F401
                                     CRASH, FAULT_KINDS, HANG,
                                     HEARTBEAT_DELAY, PARTITION, POISON,
                                     SLOWDOWN, UNHANG, FaultEvent,
                                     FaultInjector, FaultPlan, ReplicaFaults)
from repro.resilience.journal import RequestJournal  # noqa: F401
