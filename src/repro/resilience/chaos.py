"""Chaos harness: scripted fault schedules against a live fleet
(DESIGN.md §resilience).

``run_chaos`` drives an N-replica fleet through a deterministic
:class:`~repro.resilience.faults.FaultPlan` — replica crash, transient
hang, heartbeat delay and partition, dispatch slowdown, NaN poisoning
of packed-step outputs, cache-slot corruption, transient allocation
failure — and returns the recovery ledger the chaos suite gates on:

* **zero requests lost** — every admitted request reaches a terminal
  state (served; expiry is disabled here by infinite deadlines);
* **all final latents finite** — every NaN/Inf trajectory was
  quarantined and re-executed, none leaked to a caller;
* **escalation correctness** — each quarantined request's final sample
  matches the clean powerful-path run of the same key (the escalation
  restarts from step 0 at the most powerful level with the original
  key, so the recovered sample carries no trace of the fault);
* **compile-once** — the whole chaos scenario replays after a rehearsal
  with zero new XLA compiles (faults change data and placement, never
  compiled structure).

``run_replay`` is the router-crash scenario: a journaled fleet is
abandoned mid-drain, a fresh fleet replays the journal's unfinished
set exactly-once, and every replayed sample must match its
uninterrupted single-request reference to <=1e-4.

The harness drives ``Fleet.tick`` on an injectable clock advanced a
fixed ``tick_dt`` per round, so fault times, heartbeat timeouts, and
escalation backoffs all land deterministically — the same scenario
byte-replays under ``--seed``-style reruns and across the rehearsal /
measured pair.
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.fleet import Fleet
from repro.resilience.faults import (ALLOC_FAIL, CORRUPT_SLOT, CRASH,
                                     HANG, HEARTBEAT_DELAY, PARTITION,
                                     POISON, SLOWDOWN, UNHANG, FaultPlan)
from repro.resilience.journal import RequestJournal


class ChaosClock:
    """Injectable fleet clock (callable like ``time.monotonic``)."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        self.t += float(dt)
        return self.t


def default_fault_plan(*, seed: int = 0,
                       poison_rids: Sequence[int] = (1, 7, 13)
                       ) -> FaultPlan:
    """The standard chaos schedule over a 4-replica fleet: every fault
    kind fires at least once, early enough that recovery happens while
    the drain is still under load. Times are fleet-clock seconds with
    the harness's default ``tick_dt=1e-3`` (so 0.006 = the 6th round);
    the heartbeat timeout is 0.005, which the transient hang and the
    delayed beats stay safely under while the partition blows through
    it (death by missed beats ~0.013)."""
    p = FaultPlan(seed=seed)
    for rid in poison_rids:
        p.add(0.001, POISON, rid=int(rid))
    p.add(0.003, CORRUPT_SLOT, replica=0)
    p.add(0.003, ALLOC_FAIL, replica=2, count=2)
    p.add(0.004, SLOWDOWN, replica=3, duration=0.01, factor=2.5)
    p.add(0.004, HEARTBEAT_DELAY, replica=2, duration=0.004, delay=0.001)
    p.add(0.006, CRASH, replica=1)
    p.add(0.007, HANG, replica=2)
    p.add(0.009, UNHANG, replica=2)      # transient stall < timeout
    p.add(0.008, PARTITION, replica=3, duration=1.0)  # >> timeout: death
    return p


def drive(fleet: Fleet, clk: ChaosClock, *, tick_dt: float = 1e-3,
          max_ticks: int = 20000) -> int:
    """Tick the fleet to drain, advancing the injectable clock a fixed
    ``tick_dt`` per round (unlike ``Fleet.run``, which leaves a caller
    clock alone, so scripted fault times / heartbeat timeouts would
    never come due)."""
    ticks = 0
    while fleet.router.unfinished() and ticks < max_ticks:
        fleet.tick()
        clk.advance(tick_dt)
        ticks += 1
    return ticks


def _submit_workload(fleet: Fleet, n_requests: int, levels: Sequence[float],
                     num_classes: int, seed: int) -> List[int]:
    rng = np.random.default_rng(seed)
    rids = []
    for _ in range(n_requests):
        cond = int(rng.integers(0, num_classes))
        lvl = float(levels[int(rng.integers(0, len(levels)))])
        rids.append(fleet.submit(cond=cond, budget=lvl))
    return rids


def run_chaos(pipe, plans: Dict[float, Any], *,
              n_replicas: int = 4, n_requests: int = 32,
              fault_plan: Optional[FaultPlan] = None,
              journal: Optional[RequestJournal] = None,
              seconds_per_token: float = 1e-4,
              tick_dt: float = 1e-3,
              heartbeat_timeout_s: float = 0.005,
              backoff_base_s: float = 2e-3,
              max_retries: int = 3,
              seed: int = 0,
              engine_kwargs: Optional[Dict[str, Any]] = None,
              max_ticks: int = 20000) -> Dict[str, Any]:
    """One scripted chaos drain; returns the recovery ledger plus the
    fleet (under ``"fleet"``) for reference checks by the caller."""
    faults = fault_plan if fault_plan is not None else default_fault_plan(
        seed=seed)
    clk = ChaosClock()
    fleet = Fleet(pipe, plans, n_replicas, router="affinity", clock=clk,
                  seconds_per_token=seconds_per_token,
                  heartbeat_timeout_s=heartbeat_timeout_s,
                  faults=faults, journal=journal,
                  max_retries=max_retries, backoff_base_s=backoff_base_s,
                  engine_kwargs=engine_kwargs)
    rids = _submit_workload(fleet, n_requests, sorted(plans),
                            pipe.cfg.dit.num_classes, seed)
    ticks = drive(fleet, clk, tick_dt=tick_dt, max_ticks=max_ticks)
    lost = sorted(set(rids) - set(fleet.results))
    nonfinite = sum(
        0 if bool(np.isfinite(np.asarray(r.x0)).all()) else 1
        for r in fleet.results.values())
    escalated = sorted(r.rid for r in fleet.router.requests.values()
                       if r.escalated)
    moved = sorted(r.rid for r in fleet.router.requests.values()
                   if r.readmits or r.handbacks)
    summ = fleet.summary()
    inj = fleet._injector
    return {
        "fleet": fleet,
        "rids": rids,
        "ticks": ticks,
        "requests": n_requests,
        "replicas": n_replicas,
        "requests_lost": len(lost),
        "nonfinite_outputs": nonfinite,
        "escalated_rids": escalated,
        "moved_rids": moved,
        "escalations": summ["router"]["escalations"],
        "expirations": summ["router"]["expirations"],
        "deaths": sum(1 for rid in fleet.replicas
                      if fleet.membership.state(rid) == "dead"),
        "faults": inj.summary() if inj is not None else {},
        "faults_exhausted": bool(inj.exhausted()) if inj is not None
        else True,
        "recovery": {
            "escalation_count": summ["escalation"]["count"],
            "escalation_mean_s": summ["escalation"]["mean_s"],
            "escalation_max_s": summ["escalation"]["max_s"],
            "readmit_count": summ["readmit"]["count"],
            "readmit_mean_s": summ["readmit"]["mean_s"],
            "readmit_max_s": summ["readmit"]["max_s"],
        },
        "integrity_refreshes": sum(
            rep.engine.metrics.total_integrity_refreshes
            for rep in fleet.replicas.values()),
        "alloc_failures": sum(
            rep.engine.metrics.total_alloc_failures
            for rep in fleet.replicas.values()),
        "quarantined": sum(
            rep.engine.metrics.total_quarantined
            for rep in fleet.replicas.values()),
    }


def powerful_reference(pipe, plans: Dict[float, Any], key, cond: int, *,
                       seconds_per_token: float = 1e-4,
                       engine_kwargs: Optional[Dict[str, Any]] = None):
    """The clean powerful-path sample for one request: a fresh fault-free
    single-replica fleet serving only this request at the most powerful
    menu level with the original key. This is the exact computation an
    escalated quarantine re-runs, so recovered latents are compared
    against it bitwise."""
    clk = ChaosClock()
    fleet = Fleet(pipe, plans, 1, clock=clk,
                  seconds_per_token=seconds_per_token,
                  engine_kwargs=engine_kwargs)
    rid = fleet.submit(cond=cond, budget=max(plans), key=key)
    drive(fleet, clk)
    return fleet.results[rid].x0


def verify_escalations(pipe, plans: Dict[float, Any],
                       chaos: Dict[str, Any], *,
                       seconds_per_token: float = 1e-4,
                       engine_kwargs: Optional[Dict[str, Any]] = None
                       ) -> Dict[str, Any]:
    """Compare every escalated request's served latents against its
    clean powerful-path reference (bitwise + max abs err) and every
    moved (re-admitted / handed-back) request against the uninterrupted
    per-request pipeline sample (<=1e-4, PR 9's packing bar)."""
    fleet = chaos["fleet"]
    esc_err, esc_bitwise = 0.0, 1
    for rid in chaos["escalated_rids"]:
        req = fleet.router.requests[rid]
        got = np.asarray(fleet.results[rid].x0)
        ref = np.asarray(powerful_reference(
            pipe, plans, req.key, req.cond,
            seconds_per_token=seconds_per_token,
            engine_kwargs=engine_kwargs))
        esc_err = max(esc_err, float(np.abs(got - ref).max()))
        if not np.array_equal(got, ref):
            esc_bitwise = 0
    moved_err = 0.0
    for rid in chaos["moved_rids"]:
        if rid in chaos["escalated_rids"]:
            continue                  # already held to the stronger bar
        req = fleet.router.requests[rid]
        res = fleet.results[rid]
        ref = np.asarray(
            pipe.sample(plans[res.budget_served], 1, req.key,
                        cond=jnp.asarray([req.cond], jnp.int32)).x0[0])
        moved_err = max(moved_err,
                        float(np.abs(np.asarray(res.x0) - ref).max()))  # repro: ignore[hot-host-sync] — offline verification, one readback per served sample is the point
    return {"escalated": len(chaos["escalated_rids"]),
            "escalated_max_err": esc_err,
            "escalated_bitwise": esc_bitwise,
            "moved": len(chaos["moved_rids"]),
            "moved_max_err": moved_err}


def run_replay(pipe, plans: Dict[float, Any], journal_path: str, *,
               n_replicas: int = 2, n_requests: int = 8,
               crash_after_finished: int = 2,
               seconds_per_token: float = 1e-4,
               tick_dt: float = 1e-3, seed: int = 1,
               engine_kwargs: Optional[Dict[str, Any]] = None,
               max_ticks: int = 20000) -> Dict[str, Any]:
    """Router-crash replay: fleet A journals to ``journal_path`` and is
    abandoned once ``crash_after_finished`` requests completed (in-flight
    and queued requests lost with it); fleet B — sharing only the
    journal file and the base key — replays the unfinished set
    exactly-once and its samples are compared against the uninterrupted
    per-request references."""
    clk = ChaosClock()
    journal = RequestJournal(journal_path)
    fa = Fleet(pipe, plans, n_replicas, clock=clk,
               seconds_per_token=seconds_per_token, journal=journal,
               engine_kwargs=engine_kwargs)
    rids = _submit_workload(fa, n_requests, sorted(plans),
                            pipe.cfg.dit.num_classes, seed)
    ticks = 0
    while len(fa.results) < crash_after_finished and ticks < max_ticks:
        fa.tick()
        clk.advance(tick_dt)
        ticks += 1
    finished_before = sorted(fa.results)
    journal.close()                   # the crash: fleet A is abandoned

    loaded = RequestJournal.load(journal_path)
    unfinished = loaded.unfinished()
    clk2 = ChaosClock()
    fb = Fleet(pipe, plans, n_replicas, clock=clk2,
               seconds_per_token=seconds_per_token,
               engine_kwargs=engine_kwargs)
    new_ids = fb.resubmit_from_journal(loaded)
    drive(fb, clk2, tick_dt=tick_dt, max_ticks=max_ticks)

    # exactly-once: finished ∪ replayed covers every admit, no overlap
    replayed_orig = [int(r["rid"]) for r in unfinished]
    missing = sorted(set(rids) - set(finished_before) - set(replayed_orig))
    duplicates = sorted(set(finished_before) & set(replayed_orig))
    max_err = 0.0
    for rec, nid in zip(unfinished, new_ids):
        res = fb.results[nid]
        ref = np.asarray(
            pipe.sample(plans[res.budget_served], 1,
                        jax.random.fold_in(fb._base_key,
                                           int(rec["rid"])),
                        cond=jnp.asarray([int(rec["cond"])],
                                         jnp.int32)).x0[0])
        max_err = max(max_err,
                      float(np.abs(np.asarray(res.x0) - ref).max()))  # repro: ignore[hot-host-sync] — offline verification, one readback per replayed sample is the point
    return {"requests": n_requests,
            "finished_before_crash": len(finished_before),
            "replayed": len(replayed_orig),
            "missing": len(missing),
            "duplicates": len(duplicates),
            "max_readmit_err": max_err,
            "journal": loaded.summary()}
