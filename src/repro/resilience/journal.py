"""Write-ahead request journal for exactly-once replay (host-pure).

The fleet writes an ``admit`` record *before* the request enters the
router ledger, a ``dispatch`` record at every placement, and a terminal
``finish`` / ``expire`` record when the request leaves the system.  After
a router crash, :meth:`RequestJournal.unfinished` is exactly the set of
requests that were admitted but never reached a terminal state — each
appears once, in admission order, carrying everything needed to
re-derive its sampling key (``key = fold_in(base_key, rid)``), so a
fresh fleet can replay them exactly-once with re-admission error bounded
by the packing tolerance (≤1e-4, same bar as PR 9's mid-drain kill).

Records are plain dicts; with a ``path`` the journal also appends one
JSON line per record and flushes before returning (write-ahead on the
process level: a record is durable before the action it describes).
Host-pure — no jax, no numpy — enforced by ``rules_resilience.py``.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional

RECORD_KINDS = ("admit", "dispatch", "finish", "expire", "escalate")


class RequestJournal:
    """In-memory request journal with an optional JSONL write-ahead log."""

    def __init__(self, path: Optional[str] = None):
        self.path = str(path) if path is not None else None
        self._records: List[Dict] = []
        self._file = open(self.path, "a") if self.path is not None else None

    # ----------------------------------------------------------- recording
    def _append(self, rec: Dict) -> Dict:
        self._records.append(rec)
        if self._file is not None:
            self._file.write(json.dumps(rec, sort_keys=True) + "\n")
            self._file.flush()
        return rec

    def admit(self, rid: int, *, cond: int, budget: float, deadline: float,
              time: float) -> Dict:
        """Record admission. MUST be written before the router ledger."""
        return self._append({"kind": "admit", "rid": int(rid),
                             "cond": int(cond), "budget": float(budget),
                             "deadline": float(deadline),
                             "time": float(time)})

    def dispatch(self, rid: int, *, replica: int, time: float) -> Dict:
        return self._append({"kind": "dispatch", "rid": int(rid),
                             "replica": int(replica), "time": float(time)})

    def finish(self, rid: int, *, replica: int, time: float) -> Dict:
        return self._append({"kind": "finish", "rid": int(rid),
                             "replica": int(replica), "time": float(time)})

    def expire(self, rid: int, *, time: float) -> Dict:
        return self._append({"kind": "expire", "rid": int(rid),
                             "time": float(time)})

    def escalate(self, rid: int, *, time: float, retries: int) -> Dict:
        return self._append({"kind": "escalate", "rid": int(rid),
                             "retries": int(retries), "time": float(time)})

    def close(self) -> None:
        if self._file is not None:
            self._file.close()
            self._file = None

    # -------------------------------------------------------------- replay
    @property
    def records(self) -> List[Dict]:
        return list(self._records)

    def unfinished(self) -> List[Dict]:
        """Admit records with no terminal record, in admission order.

        Each admitted rid appears at most once (exactly-once replay): a
        duplicate admit line for a rid already journaled is ignored.
        """
        done = {r["rid"] for r in self._records
                if r["kind"] in ("finish", "expire")}
        out, seen = [], set()
        for r in self._records:
            if r["kind"] == "admit" and r["rid"] not in done \
                    and r["rid"] not in seen:
                seen.add(r["rid"])
                out.append(dict(r))
        return out

    def summary(self) -> Dict[str, int]:
        kinds: Dict[str, int] = {}
        for r in self._records:
            kinds[r["kind"]] = kinds.get(r["kind"], 0) + 1
        kinds["unfinished"] = len(self.unfinished())
        return kinds

    # -------------------------------------------------------------- loading
    @classmethod
    def load(cls, path: str) -> "RequestJournal":
        """Read a JSONL journal back for replay (read-only: the returned
        journal does not append to the file)."""
        j = cls(None)
        with open(path) as f:
            for line in f:
                line = line.strip()
                if line:
                    j._records.append(json.loads(line))
        j.path = str(path)
        return j
