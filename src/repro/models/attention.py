"""Grouped-query attention with RoPE, sliding windows, soft-capping, packing
segment masks, QKV bias, QK-norm, KV-cache decode, and cross-attention.

The XLA path below is the reference; ``repro.kernels.attention`` provides the
Pallas TPU kernel with identical semantics (selected via ``backend``).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import AttnConfig
from repro.kernels.attention import mask as mask_mod
from repro.models.common import (ParamSpec, apply_rope, norm_schema, rms_norm,
                                 softcap)

Params = Dict[str, Any]

# ---------------------------------------------------------------------------
# Backend selection (DESIGN.md §attention-backend)

ATTN_BACKENDS = ("auto", "pallas", "xla-blocked", "dense")


def resolve_backend(backend: str, *, n_tokens: int, segmented: bool,
                    window_traced: bool = False) -> str:
    """Resolve an ``attn_backend`` name to a concrete implementation.

    ``auto`` picks the segment-aware Pallas flash kernel whenever segment
    ids are in play (packed serving, distributed padding) or the sequence
    is long, the dense XLA path otherwise; a *traced* sliding window
    (per-phase window schedules) stays on the XLA paths — the kernel's
    window is a static compile-time parameter. ``xla`` is accepted as a
    legacy alias for the pre-backend auto (never Pallas)."""
    if backend in ("auto", "xla"):
        long = n_tokens > BLOCKED_ATTN_THRESHOLD
        if window_traced or backend == "xla":
            return "xla-blocked" if long else "dense"
        return "pallas" if (segmented or long) else "dense"
    if backend not in ATTN_BACKENDS:
        raise ValueError(f"unknown attn_backend {backend!r}; known: "
                         f"{ATTN_BACKENDS}")
    if backend == "pallas" and window_traced:
        raise ValueError("the Pallas kernel takes a static window; traced "
                         "window schedules need an XLA backend")
    return backend


def attention_schema(d_model: int, cfg: AttnConfig) -> Params:
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s: Params = {
        "wq": ParamSpec((d_model, H, hd), ("embed", "heads", None)),
        "wk": ParamSpec((d_model, K, hd), ("embed", "kv_heads", None)),
        "wv": ParamSpec((d_model, K, hd), ("embed", "kv_heads", None)),
        "wo": ParamSpec((H, hd, d_model), ("heads", None, "embed")),
    }
    if cfg.qkv_bias:
        s["bq"] = ParamSpec((H, hd), ("heads", None), init="zeros")
        s["bk"] = ParamSpec((K, hd), ("kv_heads", None), init="zeros")
        s["bv"] = ParamSpec((K, hd), ("kv_heads", None), init="zeros")
    if cfg.qk_norm:
        s["q_norm"] = {"scale": ParamSpec((hd,), (None,), init="zeros")}
        s["k_norm"] = {"scale": ParamSpec((hd,), (None,), init="zeros")}
    return s


def cross_attention_schema(d_model: int, cfg: AttnConfig, kv_dim: int = 0) -> Params:
    kv_dim = kv_dim or d_model
    H, K, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    return {
        "wq": ParamSpec((d_model, H, hd), ("embed", "heads", None)),
        "wk": ParamSpec((kv_dim, K, hd), ("embed", "kv_heads", None)),
        "wv": ParamSpec((kv_dim, K, hd), ("embed", "kv_heads", None)),
        "wo": ParamSpec((H, hd, d_model), ("heads", None, "embed")),
    }


# ---------------------------------------------------------------------------
# Masking


def make_attention_bias(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
                        window: jax.Array | int = 0,
                        q_segment: Optional[jax.Array] = None,
                        k_segment: Optional[jax.Array] = None,
                        k_valid: Optional[jax.Array] = None) -> jax.Array:
    """Additive bias [..., Sq, Sk] built from arithmetic (scan-friendly) masks.

    ``window`` may be a traced int32 scalar: 0 means full attention; w>0 means
    only keys with q_pos - k_pos < w are visible (plus causality if set).

    The position and segment tiles come from ``kernels.attention.mask`` —
    the SAME helpers the Pallas flash kernel applies per block, so the XLA
    and kernel backends share one mask semantics: tokens attend within
    their segment, and segment ids < 0 (packing padding) neither attend
    nor are attended to.
    """
    allowed = mask_mod.position_allowed(q_pos, k_pos, causal=causal,
                                        window=window)
    if q_segment is not None and k_segment is not None:
        allowed &= mask_mod.segment_allowed(q_segment, k_segment)
    if k_valid is not None:
        allowed &= k_valid[..., None, :]
    return jnp.where(allowed, 0.0, -1e30).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Core attention math (GQA, no repeated-KV materialization)


def gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array, bias: jax.Array,  # repro: traced
               cfg: AttnConfig) -> jax.Array:
    """q: [B,Sq,H,hd]; k,v: [B,Sk,K,hd]; bias: [B,Sq,Sk] additive (f32).

    QK^T and PV run with bf16 inputs and f32 accumulation
    (``preferred_element_type``) — the MXU-native mixed-precision contract.
    """
    B, Sq, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qh = q.reshape(B, Sq, K, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qh, k,
                        preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(hd).astype(jnp.float32)
    scores = softcap(scores, cfg.logit_softcap)
    if bias.ndim == 3:
        bias = bias[:, None, None]                       # [B,1,1,Sq,Sk]
    scores = scores + bias
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", probs.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def blocked_gqa_attend(q: jax.Array, k: jax.Array, v: jax.Array, *,  # repro: traced
                       positions: jax.Array, causal: bool,
                       window: jax.Array | int, cfg: AttnConfig,
                       q_block: int = 1024, unroll: bool = False,
                       segment_ids: Optional[jax.Array] = None) -> jax.Array:
    """Flash-style attention for long sequences: ``lax.scan`` over query
    blocks, each block attending over the full K with an arithmetic mask.

    Peak memory per step is O(B·H·q_block·Sk) instead of O(B·H·Sq·Sk) —
    required for prefill_32k to fit per-device HBM without a Pallas kernel
    (the dry-run graph must be pure XLA on the CPU backend).

    ``segment_ids``: optional [B, S] int32 shared by queries and keys;
    tokens attend only within their segment (packed sequences / padding
    with id -1). The mask is applied per q block without ever
    materializing a [B, H, S, S] score tensor.
    """
    import numpy as _np
    from jax.sharding import PartitionSpec as _P
    from repro.runtime.sharding import constrain as _constrain

    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    nq = -(-S // q_block)
    pad = nq * q_block - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        positions = jnp.pad(positions, ((0, 0), (0, pad)), constant_values=-1)
    qb = q.reshape(B, nq, q_block, H, hd).transpose(1, 0, 2, 3, 4)
    pb = positions.reshape(B, nq, q_block).transpose(1, 0, 2)
    k_pos_full = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    if segment_ids is not None:
        k_seg_full = segment_ids                         # [B, S] (unpadded)
        sq = segment_ids
        if pad:
            sq = jnp.pad(sq, ((0, 0), (0, pad)), constant_values=-1)
        sb = sq.reshape(B, nq, q_block).transpose(1, 0, 2)
    else:
        k_seg_full = None
        sb = jnp.zeros((nq, B, q_block), jnp.int32)      # scan filler

    # Static per-layer window (unrolled cost path / eager) → sliced-K fast
    # path: each causal q block only visits keys in [start, start+qb+w).
    static_window = isinstance(window, (int, _np.integer)) and int(window) > 0  # repro: ignore[trace-host-cast] — isinstance-guarded
    if static_window and causal and int(window) < S:  # repro: ignore[trace-host-cast] — only reached when window is a host int
        w = int(window)  # repro: ignore[trace-host-cast] — guarded by static_window
        k_span = min(q_block + w, S)

        def step(_, inp):
            i, q_i, pos_i, seg_i = inp
            # shard queries within the block over the model axis: balances
            # attention compute when head count doesn't divide the axis
            q_i = _constrain(q_i, _P(("pod", "data"), "model", None, None))
            start = jnp.clip(i * q_block - w, 0, S - k_span)
            k_s = jax.lax.dynamic_slice_in_dim(k, start, k_span, axis=1)
            v_s = jax.lax.dynamic_slice_in_dim(v, start, k_span, axis=1)
            kp = start + jnp.arange(k_span, dtype=jnp.int32)
            qh = q_i.reshape(B, q_block, K, G, hd)
            s = jnp.einsum("bqkgh,bskh->bkgqs", qh, k_s,
                           preferred_element_type=jnp.float32)
            s = s / jnp.sqrt(hd).astype(jnp.float32)
            s = softcap(s, cfg.logit_softcap)
            dq = pos_i[:, :, None]
            dk = kp[None, None, :]
            allowed = (dq >= dk) & (dq - dk < w) & (dq >= 0)
            if k_seg_full is not None:
                ks = jax.lax.dynamic_slice_in_dim(k_seg_full, start, k_span,
                                                  axis=1)
                allowed &= mask_mod.segment_allowed(seg_i, ks)
            s = s + jnp.where(allowed, 0.0, -1e30)[:, None, None]
            p = jax.nn.softmax(s, axis=-1)
            o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v_s,
                           preferred_element_type=jnp.float32)
            return None, o.reshape(B, q_block, H, hd).astype(q_i.dtype)

        from repro.models.common import scan_or_unroll
        idx = jnp.arange(nq, dtype=jnp.int32)
        _, out = scan_or_unroll(step, None, (idx, qb, pb, sb), unroll)
        out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_block, H, hd)
        return out[:, :S]

    window = jnp.asarray(window, jnp.int32)

    def step(_, inp):
        q_i, pos_i, seg_i = inp                          # [B,qb,H,hd], [B,qb]
        q_i = _constrain(q_i, _P(("pod", "data"), "model", None, None))
        qh = q_i.reshape(B, q_block, K, G, hd)
        s = jnp.einsum("bqkgh,bskh->bkgqs", qh, k,
                       preferred_element_type=jnp.float32)
        s = s / jnp.sqrt(hd).astype(jnp.float32)
        s = softcap(s, cfg.logit_softcap)
        dq = pos_i[:, :, None]
        dk = k_pos_full[:, None, :]
        allowed = (dq >= dk) if causal else jnp.ones_like(dq >= dk)
        in_w = (dq - dk < window) & (dq - dk > -window)
        allowed &= jnp.where(window > 0, in_w, True)
        allowed &= dq >= 0                               # padded queries
        if k_seg_full is not None:
            allowed &= mask_mod.segment_allowed(seg_i, k_seg_full)
        s = s + jnp.where(allowed, 0.0, -1e30)[:, None, None]
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                       preferred_element_type=jnp.float32)
        return None, o.reshape(B, q_block, H, hd).astype(q_i.dtype)

    from repro.models.common import scan_or_unroll
    _, out = scan_or_unroll(step, None, (qb, pb, sb), unroll)
    out = out.transpose(1, 0, 2, 3, 4).reshape(B, nq * q_block, H, hd)
    return out[:, :S]


# Sequence length above which the blocked path is used.
BLOCKED_ATTN_THRESHOLD = 8192


def project_qkv(params: Params, x: jax.Array, kv_x: jax.Array, cfg: AttnConfig
                ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_x, params["wv"].astype(x.dtype))
    if "bq" in params:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if "q_norm" in params:
        q = rms_norm(q, params["q_norm"]["scale"])
        k = rms_norm(k, params["k_norm"]["scale"])
    return q, k, v


def attention(params: Params, x: jax.Array, cfg: AttnConfig, *,
              positions: Optional[jax.Array] = None,
              causal: bool = True,
              window: jax.Array | int = 0,
              segment_ids: Optional[jax.Array] = None,
              backend: str = "auto", unroll: bool = False) -> jax.Array:
    """Self-attention over x: [B,S,d] → [B,S,d]."""
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = project_qkv(params, x, x, cfg)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    resolved = resolve_backend(backend, n_tokens=S,
                               segmented=segment_ids is not None,
                               window_traced=hasattr(window, "dtype"))
    if resolved == "pallas":
        from repro.kernels.attention import ops as attn_ops
        out = attn_ops.flash_attention(
            q, k, v, causal=causal, window=int(window),
            softcap=cfg.logit_softcap, segment_ids=segment_ids)
    elif resolved == "xla-blocked":
        out = blocked_gqa_attend(q, k, v, positions=positions, causal=causal,
                                 window=window, cfg=cfg, unroll=unroll,
                                 segment_ids=segment_ids)
    else:
        bias = make_attention_bias(positions, positions, causal=causal,
                                   window=window, q_segment=segment_ids,
                                   k_segment=segment_ids)
        out = gqa_attend(q, k, v, bias, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def cross_attention(params: Params, x: jax.Array, kv: jax.Array, cfg: AttnConfig,
                    kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """x: [B,Sq,d] attends to kv: [B,Sk,d_kv] (non-causal, no RoPE)."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv, params["wv"].astype(x.dtype))
    B, Sq = x.shape[:2]
    Sk = kv.shape[1]
    zeros_q = jnp.zeros((B, Sq), jnp.int32)
    zeros_k = jnp.zeros((B, Sk), jnp.int32)
    bias = make_attention_bias(zeros_q, zeros_k, causal=False, window=0,
                               k_valid=kv_valid)
    out = gqa_attend(q, k, v, bias, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


# ---------------------------------------------------------------------------
# KV-cache decode


def init_kv_cache(batch: int, max_len: int, cfg: AttnConfig,
                  dtype: jnp.dtype) -> Params:
    K, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, K, hd), dtype),
        "v": jnp.zeros((batch, max_len, K, hd), dtype),
    }


def kv_cache_spec(batch: int, max_len: int, cfg: AttnConfig, dtype: jnp.dtype) -> Params:
    K, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jax.ShapeDtypeStruct((batch, max_len, K, hd), dtype),
        "v": jax.ShapeDtypeStruct((batch, max_len, K, hd), dtype),
    }


def _quantize_kv(t: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """[.., hd] → (int8, per-(...)-absmax scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(t.astype(jnp.float32)), axis=-1),
                        1e-6) / 127.0
    q = jnp.clip(jnp.round(t.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.bfloat16)


def decode_attention(params: Params, cache: Params, x: jax.Array,
                     pos: jax.Array, cfg: AttnConfig, *,
                     window: jax.Array | int = 0) -> Tuple[jax.Array, Params]:
    """One decode step. x: [B,1,d]; pos: [B] current position (int32).

    Writes the new K/V at ``pos`` then attends over the whole cache with a
    validity mask ``k_pos <= pos`` (and optional sliding window). When the
    cache carries ``k_scale``/``v_scale`` it is int8-quantized (per
    position+head absmax): the new entry is quantized on write and the
    cache dequantized on read (halved HBM cache traffic).
    """
    B, one, _ = x.shape
    assert one == 1
    q, k_new, v_new = project_qkv(params, x, x, cfg)
    if cfg.use_rope:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)

    S = cache["k"].shape[1]
    onehot = jax.nn.one_hot(pos, S, dtype=jnp.float32)              # [B,S]
    quantized = "k_scale" in cache
    new_cache: Params = {}
    if quantized:
        kq, ks = _quantize_kv(k_new)        # [B,1,K,hd], [B,1,K]
        vq, vs = _quantize_kv(v_new)
        sel = onehot[..., None, None]
        k_int = jnp.where(sel > 0, kq, cache["k"])
        v_int = jnp.where(sel > 0, vq, cache["v"])
        k_sc = jnp.where(onehot[..., None] > 0, ks, cache["k_scale"])
        v_sc = jnp.where(onehot[..., None] > 0, vs, cache["v_scale"])
        k = k_int.astype(x.dtype) * k_sc[..., None].astype(x.dtype)
        v = v_int.astype(x.dtype) * v_sc[..., None].astype(x.dtype)
        new_cache = {"k": k_int, "v": v_int, "k_scale": k_sc, "v_scale": v_sc}
    else:
        oh = onehot.astype(cache["k"].dtype)
        k = cache["k"] * (1 - oh)[..., None, None] + oh[..., None, None] * k_new
        v = cache["v"] * (1 - oh)[..., None, None] + oh[..., None, None] * v_new
        new_cache = {"k": k, "v": v}

    k_pos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    bias = make_attention_bias(pos[:, None], k_pos, causal=True, window=window)
    out = gqa_attend(q, k, v, bias, cfg)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, new_cache


def prefill_attention(params: Params, x: jax.Array, cfg: AttnConfig, *,
                      window: jax.Array | int = 0,
                      backend: str = "xla",
                      unroll: bool = False) -> Tuple[jax.Array, Params]:
    """Prefill: causal self-attention that also returns the populated cache."""
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    q, k, v = project_qkv(params, x, x, cfg)
    if cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    resolved = resolve_backend(backend, n_tokens=S, segmented=False,
                               window_traced=hasattr(window, "dtype"))
    if resolved == "pallas":
        from repro.kernels.attention import ops as attn_ops
        out = attn_ops.flash_attention(q, k, v, causal=True,
                                       window=int(window),
                                       softcap=cfg.logit_softcap)
    elif resolved == "xla-blocked":
        out = blocked_gqa_attend(q, k, v, positions=positions, causal=True,
                                 window=window, cfg=cfg, unroll=unroll)
    else:
        bias = make_attention_bias(positions, positions, causal=True, window=window)
        out = gqa_attend(q, k, v, bias, cfg)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, {"k": k, "v": v}
