"""Common model components and the parameter-schema system.

Parameters are plain nested dicts of ``jnp.ndarray``. To keep parameter
initialization and sharding specs in one place, each module declares a
*schema*: a nested dict whose leaves are :class:`ParamSpec` (shape + logical
axes + init). ``init_tree`` materializes arrays; ``spec_tree`` materializes
``jax.sharding.PartitionSpec`` given logical→mesh rules. This is the same
idea as MaxText's logical axis rules, without a framework dependency.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec

# ---------------------------------------------------------------------------
# Parameter schema


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]          # logical axis name per dim
    init: str = "normal"                     # normal | zeros | ones | embed
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_leaf(x: Any) -> bool:
    return isinstance(x, ParamSpec)


def init_tree(schema: Any, key: jax.Array, dtype: jnp.dtype) -> Any:
    """Materialize a parameter pytree from a schema tree."""
    leaves, treedef = jax.tree.flatten(schema, is_leaf=is_leaf)
    keys = jax.random.split(key, max(1, len(leaves)))
    out = []
    for spec, k in zip(leaves, keys):
        if spec.init == "zeros":
            arr = jnp.zeros(spec.shape, dtype)
        elif spec.init == "ones":
            arr = jnp.ones(spec.shape, dtype)
        elif spec.init == "embed":
            arr = (jax.random.normal(k, spec.shape, jnp.float32) * spec.scale).astype(dtype)
        else:  # truncated-normal fan-in style
            fan_in = spec.shape[0] if len(spec.shape) > 1 else max(1, spec.shape[0])
            if len(spec.shape) >= 2:
                fan_in = int(np.prod(spec.shape[:-1]))
            std = spec.scale if spec.scale != 0.02 else 1.0 / math.sqrt(max(1, fan_in))
            arr = (jax.random.truncated_normal(k, -2.0, 2.0, spec.shape, jnp.float32)
                   * std).astype(dtype)
        out.append(arr)
    return jax.tree.unflatten(treedef, out)


def abstract_tree(schema: Any, dtype: jnp.dtype) -> Any:
    """ShapeDtypeStruct tree (for dry-run lowering without allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), schema, is_leaf=is_leaf)


def spec_tree(schema: Any, rules: Dict[str, Optional[Any]],
              axis_sizes: Optional[Dict[str, int]] = None) -> Any:
    """PartitionSpec tree from logical→mesh axis rules.

    ``rules`` maps logical axis name → mesh axis name (str or tuple) or None.
    Unknown logical axes are unsharded. A mesh axis may appear at most once in
    a spec; later duplicate uses are dropped (replicated) automatically.
    ``axis_sizes`` (mesh axis → size) drops shardings that do not divide the
    dimension evenly.
    """
    def one(spec: ParamSpec) -> PartitionSpec:
        used: set = set()
        parts = []
        for dim, ax in zip(spec.shape, spec.axes):
            mesh_ax = rules.get(ax) if ax is not None else None
            if mesh_ax is None:
                parts.append(None)
                continue
            flat = (mesh_ax,) if isinstance(mesh_ax, str) else tuple(mesh_ax)
            keep = tuple(a for a in flat if a not in used)
            if axis_sizes is not None:
                # greedily keep the prefix of axes that divides the dim
                ok = []
                prod = 1
                for a in keep:
                    prod *= axis_sizes.get(a, 1)
                    if dim % prod == 0:
                        ok.append(a)
                    else:
                        prod //= axis_sizes.get(a, 1)
                keep = tuple(ok)
            if not keep:
                parts.append(None)
                continue
            used.update(keep)
            parts.append(keep[0] if len(keep) == 1 else keep)
        return PartitionSpec(*parts)

    return jax.tree.map(one, schema, is_leaf=is_leaf)


def stack_schema(schema: Any, n: int, axis_name: Optional[str] = "layers") -> Any:
    """Prepend a stacking dimension (for scan-over-layers parameters)."""
    def one(s: ParamSpec) -> ParamSpec:
        return ParamSpec((n,) + s.shape, (axis_name,) + s.axes, s.init, s.scale)
    return jax.tree.map(one, schema, is_leaf=is_leaf)


# ---------------------------------------------------------------------------
# Norms


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6,
             zero_centered: bool = True) -> jax.Array:
    """RMSNorm. Gemma-style ``(1 + scale)`` when ``zero_centered``."""
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    w = (1.0 + scale.astype(jnp.float32)) if zero_centered else scale.astype(jnp.float32)
    return (y * w).astype(dtype)


def layer_norm(x: jax.Array, scale: jax.Array, bias: Optional[jax.Array] = None,
               eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)
    if bias is not None:
        y = y + bias.astype(jnp.float32)
    return y.astype(dtype)


def norm_schema(d: int, norm_type: str) -> Any:
    if norm_type == "rmsnorm":
        return {"scale": ParamSpec((d,), ("embed",), init="zeros")}
    return {"scale": ParamSpec((d,), ("embed",), init="ones"),
            "bias": ParamSpec((d,), ("embed",), init="zeros")}


def apply_norm(params: Dict[str, jax.Array], x: jax.Array, norm_type: str) -> jax.Array:
    if norm_type == "rmsnorm":
        return rms_norm(x, params["scale"])
    return layer_norm(x, params["scale"], params.get("bias"))


# ---------------------------------------------------------------------------
# RoPE


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., S, H, head_dim]; positions: [..., S] int32."""
    freqs = rope_frequencies(x.shape[-1], theta)                 # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs    # [..., S, hd/2]
    angles = angles[..., None, :]                                # [..., S, 1, hd/2]
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Misc


def softcap(x: jax.Array, cap: float) -> jax.Array:
    """Gemma-2 style logit soft-capping; no-op when cap == 0."""
    if cap <= 0.0:
        return x
    return jnp.tanh(x / cap) * cap


def gelu(x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x, approximate=True)


def mlp_act(gate: jax.Array, up: Optional[jax.Array], kind: str) -> jax.Array:
    if kind == "swiglu":
        assert up is not None
        return jax.nn.silu(gate) * up
    if kind == "geglu":
        assert up is not None
        return gelu(gate) * up
    return gelu(gate)


def dtype_of(name: str) -> jnp.dtype:
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def count_params(tree: Any) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree.leaves(tree))


def scan_or_unroll(body, init, xs, unroll: bool = False):
    """``lax.scan`` or a python unroll (straight-line HLO for the dry-run
    cost calibration — see ModelConfig.unroll)."""
    if not unroll:
        return jax.lax.scan(body, init, xs)
    length = jax.tree.leaves(xs)[0].shape[0]
    carry = init
    ys = []
    for i in range(length):
        x_i = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, x_i)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    else:
        ys = None
    return carry, ys


# Timestep embedding (sinusoidal) used by DiT.
def timestep_embedding(t: jax.Array, dim: int, max_period: float = 10_000.0) -> jax.Array:
    half = dim // 2
    freqs = jnp.exp(-math.log(max_period) * jnp.arange(half, dtype=jnp.float32) / half)
    args = t.astype(jnp.float32)[:, None] * freqs[None]
    emb = jnp.concatenate([jnp.cos(args), jnp.sin(args)], axis=-1)
    if dim % 2:
        emb = jnp.pad(emb, ((0, 0), (0, 1)))
    return emb
