"""Decoder blocks for every assigned architecture family.

One ``block_schema``/``block_apply`` pair covers dense, MoE, SSM (mamba2),
and hybrid (hymba) layers; cross-attention blocks (VLM / whisper decoder)
have their own schema. Blocks are stacked with ``stack_schema`` and driven
by ``lax.scan`` in ``repro.models.lm``.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import ssm as ssm_mod
from repro.models.attention import (attention_schema, cross_attention,
                                    cross_attention_schema, decode_attention,
                                    prefill_attention)
from repro.models.common import apply_norm, norm_schema
from repro.models.mlp import mlp_apply, mlp_schema
from repro.models.moe import moe_apply_sorted, moe_schema

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Schemas


def block_schema(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    s: Params = {}
    if cfg.family == "ssm":          # pure mamba2: norm → ssm → residual
        s["ln1"] = norm_schema(d, cfg.norm_type)
        s["ssm"] = ssm_mod.ssm_schema(d, cfg.ssm)
        return s
    s["ln1"] = norm_schema(d, cfg.norm_type)
    s["attn"] = attention_schema(d, cfg.attn)
    if cfg.family == "hybrid":       # hymba: parallel attn + ssm heads
        s["ssm"] = ssm_mod.ssm_schema(d, cfg.ssm)
        s["ln_attn_out"] = norm_schema(d, cfg.norm_type)
        s["ln_ssm_out"] = norm_schema(d, cfg.norm_type)
    if cfg.use_post_norm:
        s["post_ln1"] = norm_schema(d, cfg.norm_type)
    s["ln2"] = norm_schema(d, cfg.norm_type)
    if cfg.family == "moe" or cfg.moe is not None:
        s["moe"] = moe_schema(d, cfg.moe, cfg.d_ff, cfg.mlp_activation)
    else:
        s["mlp"] = mlp_schema(d, cfg.d_ff, cfg.mlp_activation)
    if cfg.use_post_norm:
        s["post_ln2"] = norm_schema(d, cfg.norm_type)
    return s


def cross_block_schema(cfg: ModelConfig, kv_dim: int = 0) -> Params:
    """Gated cross-attention block (llama-3.2-vision style)."""
    from repro.models.common import ParamSpec
    d = cfg.d_model
    return {
        "ln1": norm_schema(d, cfg.norm_type),
        "xattn": cross_attention_schema(d, cfg.attn, kv_dim),
        "gate_attn": ParamSpec((1,), (None,), init="zeros"),
        "ln2": norm_schema(d, cfg.norm_type),
        "mlp": mlp_schema(d, cfg.d_ff, cfg.mlp_activation),
        "gate_mlp": ParamSpec((1,), (None,), init="zeros"),
    }


# ---------------------------------------------------------------------------
# Apply


def _ffn(p: Params, h: jax.Array, cfg: ModelConfig
         ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    if "moe" in p:
        return moe_apply_sorted(p["moe"], h, cfg.moe, cfg.mlp_activation)
    return mlp_apply(p["mlp"], h, cfg.mlp_activation), {}


def block_apply(p: Params, x: jax.Array, cfg: ModelConfig, *,
                window: jax.Array | int = 0,
                mode: str = "train",
                cache: Optional[Params] = None,
                pos: Optional[jax.Array] = None,
                segment_ids: Optional[jax.Array] = None,
                backend: str = "xla"
                ) -> Tuple[jax.Array, Optional[Params], Dict[str, jax.Array]]:
    """Apply one decoder block.

    mode: 'train' | 'prefill' | 'decode' | 'encode' (non-causal, whisper enc).
    cache (decode/prefill): {'k','v'} and/or {'h','conv'} per family.
    Returns (x, new_cache, aux_losses).
    """
    aux: Dict[str, jax.Array] = {}
    new_cache: Params = {}

    if cfg.family == "ssm":
        h = apply_norm(p["ln1"], x, cfg.norm_type)
        state = cache if (cache and "h" in cache) else None
        y, st = ssm_mod.ssm_apply(p["ssm"], h, cfg.ssm, cfg.d_model, state)
        if mode in ("prefill", "decode"):
            new_cache.update(st)
        return x + y, (new_cache or None), aux

    # --- attention (and hybrid ssm branch) --------------------------------
    h = apply_norm(p["ln1"], x, cfg.norm_type)
    causal = mode != "encode"
    if mode == "decode":
        kv_in = {k: cache[k] for k in ("k", "v", "k_scale", "v_scale")
                 if k in cache}
        attn_out, kvc = decode_attention(p["attn"], kv_in, h, pos, cfg.attn,
                                         window=window)
        new_cache.update(kvc)
    elif mode == "prefill":
        attn_out, kvc = prefill_attention(p["attn"], h, cfg.attn, window=window,
                                          backend=backend, unroll=cfg.unroll)
        new_cache.update(kvc)
    else:
        attn_out = attn_mod.attention(p["attn"], h, cfg.attn, causal=causal,
                                      window=window, segment_ids=segment_ids,
                                      backend=backend, unroll=cfg.unroll)

    if cfg.family == "hybrid":
        state = {k: cache[k] for k in ("h", "conv")} if (cache and "h" in cache) else None
        ssm_out, st = ssm_mod.ssm_apply(p["ssm"], h, cfg.ssm, cfg.d_model, state)
        if mode in ("prefill", "decode"):
            new_cache.update(st)
        attn_out = 0.5 * (apply_norm(p["ln_attn_out"], attn_out, cfg.norm_type)
                          + apply_norm(p["ln_ssm_out"], ssm_out, cfg.norm_type))

    if cfg.use_post_norm:
        attn_out = apply_norm(p["post_ln1"], attn_out, cfg.norm_type)
    x = x + attn_out

    h2 = apply_norm(p["ln2"], x, cfg.norm_type)
    ffn_out, moe_aux = _ffn(p, h2, cfg)
    aux.update(moe_aux)
    if cfg.use_post_norm:
        ffn_out = apply_norm(p["post_ln2"], ffn_out, cfg.norm_type)
    x = x + ffn_out
    return x, (new_cache or None), aux


def cross_block_apply(p: Params, x: jax.Array, kv: jax.Array, cfg: ModelConfig,
                      kv_valid: Optional[jax.Array] = None) -> jax.Array:
    """Gated cross-attention block (vision / encoder conditioning)."""
    h = apply_norm(p["ln1"], x, cfg.norm_type)
    y = cross_attention(p["xattn"], h, kv, cfg.attn, kv_valid=kv_valid)
    x = x + jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(x.dtype) * y
    h2 = apply_norm(p["ln2"], x, cfg.norm_type)
    y2 = mlp_apply(p["mlp"], h2, cfg.mlp_activation)
    x = x + jnp.tanh(p["gate_mlp"].astype(jnp.float32)).astype(x.dtype) * y2
    return x
