"""Mamba2 (SSD — state-space duality) layer.

Implements the chunked SSD algorithm (Dao & Gu, 2024) in pure JAX for
training/prefill, and the O(1)-per-token recurrence for decode. The Pallas
kernel in ``repro.kernels.ssd`` accelerates the intra-chunk part on TPU.

Layer layout (n_groups = 1):
  in_proj:  d → [z (d_in), x (d_in), B (N), C (N), dt (H)]
  conv1d:   depthwise causal conv width W over the (x, B, C) channels
  SSD:      h_t = a_t h_{t-1} + dt_t · x_t ⊗ B_t ;  y_t = C_t · h_t + D x_t
            with a_t = exp(-exp(A_log) · dt_t), dt_t = softplus(raw + bias)
  gate:     y = RMSNorm(y) * silu(z), then out_proj: d_in → d
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.models.common import ParamSpec, rms_norm

Params = Dict[str, Any]


def ssm_dims(d_model: int, cfg: SSMConfig) -> Tuple[int, int, int]:
    d_in = cfg.expand * d_model
    nheads = cfg.num_heads or max(1, d_in // cfg.head_dim)
    return d_in, nheads, cfg.head_dim


def ssm_schema(d_model: int, cfg: SSMConfig) -> Params:
    d_in, H, P = ssm_dims(d_model, cfg)
    N = cfg.state_dim
    conv_ch = d_in + 2 * N
    return {
        "in_proj": ParamSpec((d_model, 2 * d_in + 2 * N + H), ("embed", "mlp")),
        "conv_w": ParamSpec((cfg.conv_width, conv_ch), (None, "mlp"), scale=0.5),
        "conv_b": ParamSpec((conv_ch,), ("mlp",), init="zeros"),
        "A_log": ParamSpec((H,), (None,), init="zeros"),
        "D": ParamSpec((H,), (None,), init="ones"),
        "dt_bias": ParamSpec((H,), (None,), init="zeros"),
        "norm": {"scale": ParamSpec((d_in,), ("mlp",), init="zeros")},
        "out_proj": ParamSpec((d_in, d_model), ("mlp", "embed")),
    }


def _split_proj(params: Params, u: jax.Array, d_in: int, N: int, H: int):
    zxbcdt = jnp.einsum("...d,de->...e", u, params["in_proj"].astype(u.dtype),
                        preferred_element_type=jnp.float32).astype(u.dtype)
    z = zxbcdt[..., :d_in]
    xBC = zxbcdt[..., d_in:d_in + d_in + 2 * N]
    dt_raw = zxbcdt[..., d_in + d_in + 2 * N:]
    return z, xBC, dt_raw


def _causal_conv(params: Params, xBC: jax.Array,
                 conv_state: Optional[jax.Array] = None
                 ) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. xBC: [B,S,Cch]. Returns (out, new_conv_state).

    ``conv_state``: [B, W-1, Cch] holds the last W-1 inputs for decode.
    """
    W = params["conv_w"].shape[0]
    B, S, Cch = xBC.shape
    if conv_state is None:
        conv_state = jnp.zeros((B, W - 1, Cch), xBC.dtype)
    padded = jnp.concatenate([conv_state, xBC], axis=1)           # [B,S+W-1,C]
    out = jnp.zeros((B, S, Cch), jnp.float32)
    for i in range(W):
        out = out + padded[:, i:i + S].astype(jnp.float32) * \
            params["conv_w"][i].astype(jnp.float32)
    out = out + params["conv_b"].astype(jnp.float32)
    out = jax.nn.silu(out).astype(xBC.dtype)
    new_state = padded[:, S:]
    return out, new_state


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int,
                h0: Optional[jax.Array] = None
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x:  [B,S,H,P]  (already multiplied by nothing; dt applied inside)
    dt: [B,S,H]    (softplus'd, positive)
    A:  [H]        (negative decay rates)
    Bm, Cm: [B,S,N]
    Returns (y [B,S,H,P], h_final [B,H,P,N]).
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    S_orig = S
    nc = -(-S // chunk)
    pad = nc * chunk - S
    if pad:
        # Zero padding is exact: dt=0 → decay exp(0)=1 and contribution 0,
        # so the final state and the unpadded outputs are unchanged.
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
        S = nc * chunk

    xc = x.reshape(Bsz, nc, chunk, H, P)
    dtc = dt.reshape(Bsz, nc, chunk, H)
    Bc = Bm.reshape(Bsz, nc, chunk, N)
    Cc = Cm.reshape(Bsz, nc, chunk, N)

    # log decay per step: log a_t = A * dt_t  (A < 0)
    la = dtc * A[None, None, None, :]                             # [B,nc,Q,H]
    L = jnp.cumsum(la, axis=2)                                    # inclusive cumsum
    Ltot = L[:, :, -1, :]                                         # [B,nc,H]

    # --- intra-chunk (quadratic within chunk) ----------------------------
    # M[q,k] = C_q·B_k * exp(L_q - L_k) * dt_k  for k <= q
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc,
                    preferred_element_type=jnp.float32)           # [B,nc,Q,Q]
    diff = L[:, :, :, None, :] - L[:, :, None, :, :]              # [B,nc,Q,Q,H]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(mask[None, None, :, :, None], jnp.exp(diff), 0.0)
    M = CB[..., None] * decay * dtc[:, :, None, :, :]             # [B,nc,Q,K,H]
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", M, xc.astype(jnp.float32))

    # --- chunk summaries ---------------------------------------------------
    # S_c = sum_k exp(Ltot - L_k) dt_k x_k ⊗ B_k   → [B,nc,H,P,N]
    w = jnp.exp(Ltot[:, :, None, :] - L) * dtc                    # [B,nc,Q,H]
    Sc = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", w, xc.astype(jnp.float32), Bc)

    # --- inter-chunk recurrence over chunk index ---------------------------
    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(h, inp):
        Sc_c, Ltot_c = inp                                        # [B,H,P,N],[B,H]
        h_new = h * jnp.exp(Ltot_c)[:, :, None, None] + Sc_c
        return h_new, h                                           # emit h_prev

    (h_final, h_prevs) = jax.lax.scan(
        step, h0, (Sc.transpose(1, 0, 2, 3, 4), Ltot.transpose(1, 0, 2)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                    # [B,nc,H,P,N]

    # y_inter[q] = exp(L_q) * C_q · h_prev
    y_inter = jnp.einsum("bcqh,bcqn,bchpn->bcqhp",
                         jnp.exp(L), Cc, h_prevs)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P)[:, :S_orig]
    return y.astype(x.dtype), h_final


def ssd_recurrent_step(h: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
                       Bm: jax.Array, Cm: jax.Array
                       ) -> Tuple[jax.Array, jax.Array]:
    """One decode step. h: [B,H,P,N]; x: [B,H,P]; dt: [B,H]; Bm,Cm: [B,N]."""
    a = jnp.exp(dt * A[None, :])                                  # [B,H]
    h_new = h * a[:, :, None, None] + \
        (dt[:, :, None] * x.astype(jnp.float32))[..., None] * Bm[:, None, None, :]
    y = jnp.einsum("bhpn,bn->bhp", h_new, Cm)
    return h_new, y.astype(x.dtype)


def ssm_apply(params: Params, u: jax.Array, cfg: SSMConfig, d_model: int,
              state: Optional[Params] = None, use_kernel: bool = False
              ) -> Tuple[jax.Array, Params]:
    """Full Mamba2 layer. u: [B,S,d]. ``state`` enables streaming decode:
    {"h": [B,H,P,N], "conv": [B,W-1,Cch]}. Returns (out, new_state)."""
    B, S, d = u.shape
    d_in, H, P = ssm_dims(d_model, cfg)
    N = cfg.state_dim
    z, xBC, dt_raw = _split_proj(params, u, d_in, N, H)
    conv_state = state["conv"] if state is not None else None
    xBC, new_conv = _causal_conv(params, xBC, conv_state)
    xs = xBC[..., :d_in].reshape(B, S, H, P)
    Bm = xBC[..., d_in:d_in + N]
    Cm = xBC[..., d_in + N:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))  # [B,S,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))              # [H]

    if S == 1 and state is not None:
        h_new, y = ssd_recurrent_step(state["h"], xs[:, 0], dt[:, 0], A,
                                      Bm[:, 0].astype(jnp.float32),
                                      Cm[:, 0].astype(jnp.float32))
        y = y[:, None]
    else:
        h0 = state["h"] if state is not None else None
        if use_kernel:
            from repro.kernels.ssd import ops as ssd_ops
            y, h_new = ssd_ops.ssd(xs, dt, A, Bm.astype(jnp.float32),
                                   Cm.astype(jnp.float32), cfg.chunk_size)
        else:
            y, h_new = ssd_chunked(xs, dt, A, Bm.astype(jnp.float32),
                                   Cm.astype(jnp.float32), cfg.chunk_size, h0)

    y = y + xs * params["D"].astype(y.dtype)[None, None, :, None]
    y = y.reshape(B, S, d_in)
    y = rms_norm(y, params["norm"]["scale"]) * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(y.dtype),
                     preferred_element_type=jnp.float32).astype(u.dtype)
    new_state = {"h": h_new, "conv": new_conv}
    return out, new_state


def init_ssm_state(batch: int, d_model: int, cfg: SSMConfig,
                   dtype: jnp.dtype) -> Params:
    d_in, H, P = ssm_dims(d_model, cfg)
    N = cfg.state_dim
    return {
        "h": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, d_in + 2 * N), dtype),
    }


def ssm_state_spec(batch: int, d_model: int, cfg: SSMConfig,
                   dtype: jnp.dtype) -> Params:
    d_in, H, P = ssm_dims(d_model, cfg)
    N = cfg.state_dim
    return {
        "h": jax.ShapeDtypeStruct((batch, H, P, N), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.conv_width - 1, d_in + 2 * N), dtype),
    }
