"""Dense feed-forward blocks (SwiGLU / GeGLU / GELU)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models.common import ParamSpec, mlp_act

Params = Dict[str, Any]


def mlp_schema(d_model: int, d_ff: int, activation: str = "swiglu",
               bias: bool = False) -> Params:
    gated = activation in ("swiglu", "geglu")
    s: Params = {
        "w_in": ParamSpec((d_model, d_ff), ("embed", "mlp")),
        "w_out": ParamSpec((d_ff, d_model), ("mlp", "embed")),
    }
    if gated:
        s["w_gate"] = ParamSpec((d_model, d_ff), ("embed", "mlp"))
    if bias:
        s["b_in"] = ParamSpec((d_ff,), ("mlp",), init="zeros")
        s["b_out"] = ParamSpec((d_model,), ("embed",), init="zeros")
    return s


def mlp_apply(params: Params, x: jax.Array, activation: str = "swiglu") -> jax.Array:
    dt = x.dtype
    # NOTE: no preferred_element_type=f32 — bf16 outputs keep activation
    # (and their GSPMD collective) bytes at 2B; the MXU still accumulates
    # in f32 internally (EXPERIMENTS.md §Perf iteration 4).
    up = jnp.einsum("...d,df->...f", x, params["w_in"].astype(dt))
    if "b_in" in params:
        up = up + params["b_in"].astype(dt)
    if activation in ("swiglu", "geglu"):
        gate = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dt))
        h = mlp_act(gate, up, activation)
    else:
        h = mlp_act(up, None, activation)
    out = jnp.einsum("...f,fd->...d", h.astype(dt), params["w_out"].astype(dt))
    if "b_out" in params:
        out = out + params["b_out"].astype(dt)
    return out.astype(dt)
