"""LM wrapper: schema, init, train forward, prefill, and decode for all
assigned architecture families (dense / moe / ssm / hybrid / vlm / audio).

Layers are stacked and driven by ``lax.scan`` so the lowered HLO contains a
single block body regardless of depth — essential to keep dry-run compile
times and executable sizes sane at 64–100 layers.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import ssm as ssm_mod
from repro.models.blocks import (block_apply, block_schema, cross_block_apply,
                                 cross_block_schema)
from repro.models.attention import (attention_schema, cross_attention,
                                    cross_attention_schema)
from repro.models.common import (ParamSpec, apply_norm, dtype_of, init_tree,
                                 norm_schema, scan_or_unroll, softcap,
                                 spec_tree, stack_schema)
from repro.models.mlp import mlp_schema, mlp_apply

Params = Dict[str, Any]

VLM_GROUP = 5     # llama-3.2-vision: 1 cross-attn layer per 5 layers


# ---------------------------------------------------------------------------
# Schema


def _audio_dec_block_schema(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    return {
        "ln1": norm_schema(d, cfg.norm_type),
        "attn": attention_schema(d, cfg.attn),
        "lnx": norm_schema(d, cfg.norm_type),
        "xattn": cross_attention_schema(d, cfg.attn),
        "ln2": norm_schema(d, cfg.norm_type),
        "mlp": mlp_schema(d, cfg.d_ff, cfg.mlp_activation),
    }


def lm_schema(cfg: ModelConfig) -> Params:
    d, V, L = cfg.d_model, cfg.vocab_size, cfg.num_layers
    s: Params = {
        "embed": ParamSpec((V, d), ("vocab", "embed"), init="embed"),
        "final_norm": norm_schema(d, cfg.norm_type),
    }
    if not cfg.tie_embeddings:
        s["lm_head"] = ParamSpec((d, V), ("embed", "vocab"))

    if cfg.family == "vlm":
        k = cfg.cross_attn_every or VLM_GROUP
        assert L % k == 0, (L, k)
        G = L // k
        s["groups"] = {
            "self": stack_schema(stack_schema(block_schema(cfg), k - 1, None), G),
            "cross": stack_schema(cross_block_schema(cfg), G),
        }
        s["vision_proj"] = ParamSpec((d, d), ("embed", "mlp"))
    elif cfg.family == "audio":
        s["enc_blocks"] = stack_schema(block_schema(cfg), cfg.encoder_layers)
        s["enc_norm"] = norm_schema(d, cfg.norm_type)
        s["dec_blocks"] = stack_schema(_audio_dec_block_schema(cfg), L)
        s["pos_embed"] = ParamSpec((cfg.max_seq_len, d), (None, "embed"),
                                   init="embed")
    else:
        s["blocks"] = stack_schema(block_schema(cfg), L)
    return s


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    return init_tree(lm_schema(cfg), key, dtype_of(cfg.param_dtype))


def param_partition_specs(cfg: ModelConfig, rules: Dict[str, Any]) -> Params:
    return spec_tree(lm_schema(cfg), rules)


def layer_windows(cfg: ModelConfig) -> np.ndarray:
    if cfg.attn is None:
        return np.zeros((cfg.num_layers,), np.int32)
    return np.asarray([cfg.attn.window_for_layer(i)
                       for i in range(cfg.num_layers)], np.int32)


# ---------------------------------------------------------------------------
# Embedding / unembedding


def embed_tokens(params: Params, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0)
    x = x.astype(dtype_of(cfg.compute_dtype))
    if cfg.scale_embeddings:
        x = x * jnp.sqrt(float(cfg.d_model)).astype(x.dtype)
    return x


def unembed(params: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    head = params["lm_head"] if "lm_head" in params else params["embed"].T
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype),
                        preferred_element_type=jnp.float32)
    return softcap(logits, cfg.final_logit_softcap)


# ---------------------------------------------------------------------------
# Aux-loss accumulation helpers (fixed structure for scan carries)


def _aux_zero(cfg: ModelConfig) -> Dict[str, jax.Array]:
    if cfg.moe is None:
        return {}
    return {"load_balance": jnp.zeros((), jnp.float32),
            "router_z": jnp.zeros((), jnp.float32),
            "dropped_fraction": jnp.zeros((), jnp.float32)}


def _aux_add(acc: Dict[str, jax.Array], aux: Dict[str, jax.Array]
             ) -> Dict[str, jax.Array]:
    return {k: acc[k] + aux.get(k, 0.0) for k in acc}


# ---------------------------------------------------------------------------
# Train forward


def forward_train(params: Params, tokens: jax.Array, cfg: ModelConfig, *,
                  extra: Optional[Dict[str, jax.Array]] = None,
                  segment_ids: Optional[jax.Array] = None,
                  backend: str = "xla"
                  ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """tokens: [B,S] int32 → (logits [B,S,V] f32, aux losses)."""
    x = embed_tokens(params, tokens, cfg)
    # unrolled mode keeps windows as a host array → static per-layer windows
    # (enables the window-sliced attention fast path)
    windows = layer_windows(cfg) if cfg.unroll else jnp.asarray(layer_windows(cfg))
    aux0 = _aux_zero(cfg)

    if cfg.family == "vlm":
        vis = extra["vision"].astype(x.dtype)              # [B,Tv,d]
        vis = jnp.einsum("btd,de->bte", vis, params["vision_proj"].astype(x.dtype))
        x = _vlm_scan(params["groups"], x, vis, cfg, backend)
    elif cfg.family == "audio":
        enc = _audio_encode(params, extra["frames"], cfg, backend)
        S = x.shape[1]
        x = x + params["pos_embed"][:S].astype(x.dtype)[None]
        x, _ = _audio_decoder_scan(params["dec_blocks"], x, enc, cfg,
                                   mode="train")
        return unembed(params, x, cfg), aux0
    else:
        def body(carry, xs):
            h, acc = carry
            p, w = xs
            h, _, aux = block_apply(p, h, cfg, window=w, mode="train",
                                    segment_ids=segment_ids, backend=backend)
            if cfg.sequence_parallel:
                from jax.sharding import PartitionSpec as P
                from repro.runtime.sharding import constrain
                h = constrain(h, P(("pod", "data"), "model", None))
            return (h, _aux_add(acc, aux)), None
        if cfg.remat == "block":
            body = jax.checkpoint(body, prevent_cse=False)
        (x, aux0), _ = scan_or_unroll(body, (x, aux0),
                                      (params["blocks"], windows), cfg.unroll)

    return unembed(params, x, cfg), aux0


def _vlm_scan(groups: Params, x: jax.Array, vis: jax.Array, cfg: ModelConfig,
              backend: str) -> jax.Array:
    def inner(h, p):
        h, _, _ = block_apply(p, h, cfg, window=0, mode="train", backend=backend)
        return h, None

    def body(h, xs):
        p_self, p_cross = xs
        h, _ = scan_or_unroll(inner, h, p_self, cfg.unroll)
        h = cross_block_apply(p_cross, h, vis, cfg)
        return h, None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = scan_or_unroll(body, x, (groups["self"], groups["cross"]),
                          cfg.unroll)
    return x


def _audio_encode(params: Params, frames: jax.Array, cfg: ModelConfig,
                  backend: str = "xla") -> jax.Array:
    """Whisper encoder over stub frame embeddings [B,F,d] (conv frontend is a
    stub per the assignment: frames arrive pre-embedded)."""
    h = frames.astype(dtype_of(cfg.compute_dtype))

    def body(carry, p):
        carry, _, _ = block_apply(p, carry, cfg, window=0, mode="encode",
                                  backend=backend)
        return carry, None

    h, _ = scan_or_unroll(body, h, params["enc_blocks"], cfg.unroll)
    return apply_norm(params["enc_norm"], h, cfg.norm_type)


def _audio_decoder_scan(dec_p: Params, x: jax.Array, enc: jax.Array,
                        cfg: ModelConfig, mode: str,
                        cache: Optional[Params] = None,
                        pos: Optional[jax.Array] = None
                        ) -> Tuple[jax.Array, Optional[Params]]:
    from repro.models.attention import (decode_attention, prefill_attention)

    def one(p, h, c):
        a_in = apply_norm(p["ln1"], h, cfg.norm_type)
        new_c = None
        if mode == "decode":
            a, new_c = decode_attention(p["attn"], c, a_in, pos, cfg.attn)
        elif mode == "prefill":
            a, new_c = prefill_attention(p["attn"], a_in, cfg.attn)
        else:
            from repro.models.attention import attention
            a = attention(p["attn"], a_in, cfg.attn, causal=True)
        h = h + a
        xa_in = apply_norm(p["lnx"], h, cfg.norm_type)
        h = h + cross_attention(p["xattn"], xa_in, enc, cfg.attn)
        m_in = apply_norm(p["ln2"], h, cfg.norm_type)
        h = h + mlp_apply(p["mlp"], m_in, cfg.mlp_activation)
        return h, new_c

    if mode == "train":
        def body(h, p):
            h, _ = one(p, h, None)
            return h, None
        x, _ = scan_or_unroll(body, x, dec_p, cfg.unroll)
        return x, None
    if mode == "prefill":
        def body(h, p):
            h, c = one(p, h, None)
            return h, c
        x, caches = scan_or_unroll(body, x, dec_p, cfg.unroll)
        return x, caches
    # decode
    def body(h, xs):
        p, c = xs
        h, c_new = one(p, h, c)
        return h, c_new
    x, caches = scan_or_unroll(body, x, (dec_p, cache), cfg.unroll)
    return x, caches


# ---------------------------------------------------------------------------
# Loss


def lm_loss(params: Params, batch: Dict[str, jax.Array], cfg: ModelConfig,
            backend: str = "xla") -> Tuple[jax.Array, Dict[str, jax.Array]]:
    logits, aux = forward_train(params, batch["tokens"], cfg,
                                extra=batch, backend=backend,
                                segment_ids=batch.get("segment_ids"))
    targets = batch["targets"]
    mask = batch.get("loss_mask")
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None].astype(jnp.int32),
                               axis=-1)[..., 0]
    if mask is not None:
        loss = jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    else:
        loss = jnp.mean(nll)
    total = loss + sum(aux.values()) if aux else loss
    metrics = {"loss": loss, **aux}
    return total, metrics


# ---------------------------------------------------------------------------
# Serving: prefill + decode


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               abstract: bool = False) -> Params:
    """Stacked per-layer cache pytree (zeros or ShapeDtypeStructs)."""
    dt = dtype_of(cfg.compute_dtype)
    L = cfg.num_layers

    def zeros(shape, dtype):
        return (jax.ShapeDtypeStruct(shape, dtype) if abstract
                else jnp.zeros(shape, dtype))

    kv_dt = jnp.int8 if cfg.kv_cache_dtype == "int8" else dt
    c: Params = {}
    if cfg.attn is not None:
        K, hd = cfg.attn.num_kv_heads, cfg.attn.head_dim
        if cfg.family == "vlm":
            k = cfg.cross_attn_every or VLM_GROUP
            G = L // k
            c["k"] = zeros((G, k - 1, batch, max_len, K, hd), kv_dt)
            c["v"] = zeros((G, k - 1, batch, max_len, K, hd), kv_dt)
            if cfg.kv_cache_dtype == "int8":
                c["k_scale"] = zeros((G, k - 1, batch, max_len, K), jnp.bfloat16)
                c["v_scale"] = zeros((G, k - 1, batch, max_len, K), jnp.bfloat16)
            Tv = cfg.vision_tokens
            c["xk"] = zeros((G, batch, Tv, K, hd), dt)
            c["xv"] = zeros((G, batch, Tv, K, hd), dt)
        elif cfg.family == "audio":
            c["k"] = zeros((L, batch, max_len, K, hd), kv_dt)
            c["v"] = zeros((L, batch, max_len, K, hd), kv_dt)
            if cfg.kv_cache_dtype == "int8":
                c["k_scale"] = zeros((L, batch, max_len, K), jnp.bfloat16)
                c["v_scale"] = zeros((L, batch, max_len, K), jnp.bfloat16)
            c["enc"] = zeros((batch, cfg.audio_frames, cfg.d_model), dt)
        else:
            c["k"] = zeros((L, batch, max_len, K, hd), kv_dt)
            c["v"] = zeros((L, batch, max_len, K, hd), kv_dt)
            if cfg.kv_cache_dtype == "int8":
                c["k_scale"] = zeros((L, batch, max_len, K), jnp.bfloat16)
                c["v_scale"] = zeros((L, batch, max_len, K), jnp.bfloat16)
    if cfg.ssm is not None:
        d_in, H, P = ssm_mod.ssm_dims(cfg.d_model, cfg.ssm)
        N = cfg.ssm.state_dim
        W = cfg.ssm.conv_width
        c["h"] = zeros((L, batch, H, P, N), jnp.float32)
        c["conv"] = zeros((L, batch, W - 1, d_in + 2 * N), dt)
    return c


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig, *,
            extra: Optional[Dict[str, jax.Array]] = None,
            backend: str = "xla") -> Tuple[jax.Array, Params]:
    """Process the prompt, return (last-position logits [B,V], cache)."""
    x = embed_tokens(params, tokens, cfg)
    windows = layer_windows(cfg) if cfg.unroll else jnp.asarray(layer_windows(cfg))
    cache: Params = {}

    if cfg.family == "audio":
        enc = _audio_encode(params, extra["frames"], cfg, backend)
        S = x.shape[1]
        x = x + params["pos_embed"][:S].astype(x.dtype)[None]
        x, kv = _audio_decoder_scan(params["dec_blocks"], x, enc, cfg,
                                    mode="prefill")
        cache = {"k": kv["k"], "v": kv["v"], "enc": enc}
    elif cfg.family == "vlm":
        vis = extra["vision"].astype(x.dtype)
        vis = jnp.einsum("btd,de->bte", vis, params["vision_proj"].astype(x.dtype))
        x, cache = _vlm_prefill(params["groups"], x, vis, cfg, backend)
    else:
        def body(h, xs):
            p, w = xs
            h, c, _ = block_apply(p, h, cfg, window=w, mode="prefill",
                                  backend=backend)
            return h, c
        x, cache = scan_or_unroll(body, x, (params["blocks"], windows),
                                  cfg.unroll)

    logits = unembed(params, x[:, -1:], cfg)[:, 0]
    return logits, cache


def _vlm_prefill(groups: Params, x: jax.Array, vis: jax.Array,
                 cfg: ModelConfig, backend: str) -> Tuple[jax.Array, Params]:
    def inner(h, p):
        h, c, _ = block_apply(p, h, cfg, window=0, mode="prefill",
                              backend=backend)
        return h, c

    def body(h, xs):
        p_self, p_cross = xs
        h, kv = scan_or_unroll(inner, h, p_self, cfg.unroll)
        xk = jnp.einsum("btd,dhk->bthk", vis, p_cross["xattn"]["wk"].astype(h.dtype))
        xv = jnp.einsum("btd,dhk->bthk", vis, p_cross["xattn"]["wv"].astype(h.dtype))
        h = cross_block_apply(p_cross, h, vis, cfg)
        return h, {"k": kv["k"], "v": kv["v"], "xk": xk, "xv": xv}

    x, cache = scan_or_unroll(body, x, (groups["self"], groups["cross"]),
                              cfg.unroll)
    return x, cache


def decode_step(params: Params, cache: Params, token: jax.Array,
                pos: jax.Array, cfg: ModelConfig
                ) -> Tuple[jax.Array, Params]:
    """One decode step. token: [B,1] int32; pos: [B] int32.
    Returns (logits [B,V] f32, updated cache)."""
    x = embed_tokens(params, token, cfg)
    windows = jnp.asarray(layer_windows(cfg))

    if cfg.family == "audio":
        x = x + jnp.take(params["pos_embed"], pos, axis=0)[:, None].astype(x.dtype)
        kv = {"k": cache["k"], "v": cache["v"]}
        x, kv_new = _audio_decoder_scan(params["dec_blocks"], x, cache["enc"],
                                        cfg, mode="decode", cache=kv, pos=pos)
        new_cache = {**kv_new, "enc": cache["enc"]}
        return unembed(params, x, cfg)[:, 0], new_cache

    if cfg.family == "vlm":
        x, new_cache = _vlm_decode(params["groups"], x, cache, pos, cfg)
        return unembed(params, x, cfg)[:, 0], new_cache

    def body(carry, xs):
        h = carry
        p, w, c = xs
        h, c_new, _ = block_apply(p, h, cfg, window=w, mode="decode",
                                  cache=c, pos=pos)
        return h, c_new

    x, new_cache = scan_or_unroll(body, x, (params["blocks"], windows, cache),
                                  cfg.unroll)
    return unembed(params, x, cfg)[:, 0], new_cache


def _vlm_decode(groups: Params, x: jax.Array, cache: Params, pos: jax.Array,
                cfg: ModelConfig) -> Tuple[jax.Array, Params]:
    from repro.models.attention import gqa_attend, make_attention_bias

    def inner(h, xs):
        p, c = xs
        h, c_new, _ = block_apply(p, h, cfg, window=0, mode="decode",
                                  cache=c, pos=pos)
        return h, c_new

    def body(h, xs):
        p_self, p_cross, c_self, xk, xv = xs
        h, c_new = scan_or_unroll(inner, h, (p_self, {"k": c_self["k"],
                                                      "v": c_self["v"]}),
                                  cfg.unroll)
        # cross attention against precomputed vision KV
        a_in = apply_norm(p_cross["ln1"], h, cfg.norm_type)
        q = jnp.einsum("bsd,dhk->bshk", a_in,
                       p_cross["xattn"]["wq"].astype(h.dtype))
        B, Tv = xk.shape[0], xk.shape[1]
        bias = jnp.zeros((B, 1, Tv), jnp.float32)
        o = gqa_attend(q, xk, xv, bias, cfg.attn)
        o = jnp.einsum("bshk,hkd->bsd", o,
                       p_cross["xattn"]["wo"].astype(h.dtype))
        h = h + jnp.tanh(p_cross["gate_attn"].astype(jnp.float32)).astype(h.dtype) * o
        m_in = apply_norm(p_cross["ln2"], h, cfg.norm_type)
        m = mlp_apply(p_cross["mlp"], m_in, cfg.mlp_activation)
        h = h + jnp.tanh(p_cross["gate_mlp"].astype(jnp.float32)).astype(h.dtype) * m
        return h, c_new

    x, kv_new = scan_or_unroll(
        body, x, (groups["self"], groups["cross"],
                  {"k": cache["k"], "v": cache["v"]}, cache["xk"],
                  cache["xv"]), cfg.unroll)
    new_cache = {"k": kv_new["k"], "v": kv_new["v"],
                 "xk": cache["xk"], "xv": cache["xv"]}
    return x, new_cache
