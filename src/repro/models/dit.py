"""Diffusion Transformer (DiT) with FlexiDiT patch-size modes.

Covers the paper's three model classes:
  * class-conditioned DiT (adaLN-Zero, DiT-XL/2 style)      — cfg.dit.conditioning == 'class'
  * text-conditioned T2I/Emu (cross-attention conditioning) — 'text'
  * video DiT (3D patches; same blocks, longer sequences)   — latent_shape[0] > 1

A *mode* is an index into ``patch_sizes(cfg) = [p_powerful, *flex sizes]``.
mode 0 is the pre-trained ("powerful") patch size; higher modes are "weak".
Mode selection is static (token count changes), so each mode jit-compiles to
its own executable — exactly the two-executable scheme used at inference.

LoRA recipe (§3.2): ``blocks.lora`` holds per-new-mode adapters on the self-
attention and MLP projections (cross-attention deliberately frozen, App. C.2).
mode 0 never touches LoRAs / the patch-size embedding / the per-mode LN, so
the pre-trained forward pass is preserved bit-exactly.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import patch as patch_mod
from repro.core import resize
from repro.models.common import (ParamSpec, dtype_of, init_tree, layer_norm,
                                 softcap, spec_tree, stack_schema,
                                 timestep_embedding)

Params = Dict[str, Any]
Patch = Tuple[int, int, int]

T_EMB_DIM = 256


@functools.partial(jax.tree_util.register_dataclass,
                   data_fields=["delta", "refresh"], meta_fields=["split"])
@dataclasses.dataclass
class BlockCache:
    """Cross-step activation cache handed to :func:`dit_forward`
    (DESIGN.md §cache): ``delta`` is the deep-block residual recorded at
    the last refresh ([B_eff, N, d], matching the token stream),
    ``refresh`` a traced scalar bool, and ``split`` the static number of
    shallow blocks that always recompute. When present, the forward
    returns ``(out, new_delta)`` and the deep blocks [split, L) only run
    on refresh steps (``lax.cond`` — skip steps pay shallow compute
    only, then replay ``delta``)."""
    delta: jax.Array
    refresh: jax.Array
    split: int


def patch_sizes(cfg: ModelConfig) -> Tuple[Patch, ...]:
    return (cfg.dit.patch_size,) + tuple(cfg.dit.flex_patch_sizes)


def tokens_for_mode(cfg: ModelConfig, mode: int) -> int:
    return patch_mod.num_tokens(cfg.dit.latent_shape, patch_sizes(cfg)[mode])


def c_out_dim(cfg: ModelConfig) -> int:
    c_in = cfg.dit.latent_shape[-1]
    return 2 * c_in if cfg.dit.learn_sigma else c_in


# ---------------------------------------------------------------------------
# Schema


def _lora_pair(d_in: int, d_out: int, n_new: int, r: int) -> Params:
    return {"a": ParamSpec((n_new, d_in, r), (None, "embed", None), scale=0.02),
            "b": ParamSpec((n_new, r, d_out), (None, None, "embed"), init="zeros")}


def dit_block_schema(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    dc = cfg.dit.text_dim or d
    n_new = len(cfg.dit.flex_patch_sizes)
    r = cfg.dit.lora_rank
    s: Params = {
        "ada": {"w": ParamSpec((d, 6 * d), ("embed", "mlp"), init="zeros"),
                "b": ParamSpec((6 * d,), ("mlp",), init="zeros")},
        "attn": {"wq": ParamSpec((d, d), ("embed", "heads")),
                 "wk": ParamSpec((d, d), ("embed", "heads")),
                 "wv": ParamSpec((d, d), ("embed", "heads")),
                 "wo": ParamSpec((d, d), ("heads", "embed"))},
        "mlp": {"w_in": ParamSpec((d, cfg.d_ff), ("embed", "mlp")),
                "b_in": ParamSpec((cfg.d_ff,), ("mlp",), init="zeros"),
                "w_out": ParamSpec((cfg.d_ff, d), ("mlp", "embed")),
                "b_out": ParamSpec((d,), ("embed",), init="zeros")},
    }
    if cfg.dit.conditioning == "text":
        s["xattn"] = {"wq": ParamSpec((d, d), ("embed", "heads")),
                      "wk": ParamSpec((dc, d), ("embed", "heads")),
                      "wv": ParamSpec((dc, d), ("embed", "heads")),
                      "wo": ParamSpec((d, d), ("heads", "embed"), init="zeros")}
    if r > 0 and n_new > 0:
        s["lora"] = {
            "attn": {k: _lora_pair(d, d, n_new, r) for k in ("wq", "wk", "wv", "wo")},
            "mlp": {"w_in": _lora_pair(d, cfg.d_ff, n_new, r),
                    "w_out": _lora_pair(cfg.d_ff, d, n_new, r)},
        }
    return s


def dit_schema(cfg: ModelConfig) -> Params:
    d = cfg.d_model
    dit = cfg.dit
    pp = dit.underlying_patch_size
    c_in = dit.latent_shape[-1]
    n_modes = 1 + len(dit.flex_patch_sizes)
    s: Params = {
        "embed": {"w_flex": ParamSpec((int(np.prod(pp)), c_in, d),
                                      (None, None, "embed")),
                  "b": ParamSpec((d,), ("embed",), init="zeros")},
        "deembed": {"w_flex": ParamSpec((d, c_out_dim(cfg), int(np.prod(pp))),
                                        ("embed", None, None), init="zeros"),
                    "b_flex": ParamSpec((c_out_dim(cfg), int(np.prod(pp))),
                                        (None, None), init="zeros")},
        "t_embed": {"w1": ParamSpec((T_EMB_DIM, d), (None, "embed")),
                    "b1": ParamSpec((d,), ("embed",), init="zeros"),
                    "w2": ParamSpec((d, d), ("embed", "mlp")),
                    "b2": ParamSpec((d,), ("embed",), init="zeros")},
        "final": {"ada": {"w": ParamSpec((d, 2 * d), ("embed", "mlp"), init="zeros"),
                          "b": ParamSpec((2 * d,), ("mlp",), init="zeros")}},
        "blocks": stack_schema(dit_block_schema(cfg), cfg.num_layers),
    }
    if n_modes > 1:
        s["ps_embed"] = ParamSpec((n_modes - 1, d), (None, "embed"), init="zeros")
        s["ps_ln"] = {"scale": ParamSpec((n_modes - 1, d), (None, "embed"), init="zeros"),
                      "bias": ParamSpec((n_modes - 1, d), (None, "embed"), init="zeros")}
    if dit.lora_rank > 0 and n_modes > 1:
        # LoRA recipe (§3.2): brand-new (de-)embedding layers per new patch
        # size — the shared flex weights stay frozen so the pre-trained
        # forward pass is bit-exact at mode 0.
        s["embed_new"] = {}
        s["deembed_new"] = {}
        for m, p in enumerate(dit.flex_patch_sizes, start=1):
            npix = int(np.prod(p))
            s["embed_new"][f"m{m}"] = {
                "w": ParamSpec((npix, c_in, d), (None, None, "embed")),
                "b": ParamSpec((d,), ("embed",), init="zeros")}
            s["deembed_new"][f"m{m}"] = {
                "w": ParamSpec((d, c_out_dim(cfg), npix), ("embed", None, None),
                               init="zeros"),
                "b": ParamSpec((c_out_dim(cfg), npix), (None, None), init="zeros")}
    if dit.conditioning == "class":
        s["class_embed"] = ParamSpec((dit.num_classes + 1, d), (None, "embed"),
                                     init="embed")
    elif dit.conditioning == "text":
        dc = dit.text_dim or d
        s["text_proj"] = ParamSpec((dc, dc), (None, "embed"))
    return s


def init_dit(cfg: ModelConfig, key: jax.Array) -> Params:
    return init_tree(dit_schema(cfg), key, dtype_of(cfg.param_dtype))


def dit_partition_specs(cfg: ModelConfig, rules: Dict[str, Any]) -> Params:
    return spec_tree(dit_schema(cfg), rules)


# ---------------------------------------------------------------------------
# Forward


def _linear(x: jax.Array, w: jax.Array, b: Optional[jax.Array] = None,
            lora: Optional[Params] = None, mode: int = 0,
            lora_scale: float = 2.0) -> jax.Array:
    y = jnp.einsum("...d,de->...e", x, w.astype(x.dtype),
                   preferred_element_type=jnp.float32)
    if lora is not None and mode > 0:
        a = lora["a"][mode - 1].astype(x.dtype)
        bb = lora["b"][mode - 1].astype(x.dtype)
        r = a.shape[-1]
        y = y + jnp.einsum("...r,re->...e", jnp.einsum("...d,dr->...r", x, a), bb,
                           preferred_element_type=jnp.float32) * (lora_scale / r)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)


def _modulate(x: jax.Array, shift: jax.Array, scale: jax.Array) -> jax.Array:
    return x * (1.0 + scale[:, None]) + shift[:, None]


def _mha(p: Params, x: jax.Array, num_heads: int, *,
         lora: Optional[Params] = None, mode: int = 0,
         segment_ids: Optional[jax.Array] = None,
         unroll: bool = False, parallel: Optional[Any] = None,
         attn_backend: str = "auto") -> jax.Array:
    B, N, d = x.shape
    hd = d // num_heads
    la = (lora or {})
    q = _linear(x, p["wq"], lora=la.get("wq"), mode=mode).reshape(B, N, num_heads, hd)
    k = _linear(x, p["wk"], lora=la.get("wk"), mode=mode).reshape(B, N, num_heads, hd)
    v = _linear(x, p["wv"], lora=la.get("wv"), mode=mode).reshape(B, N, num_heads, hd)
    if parallel is not None and parallel.sp > 1:
        # sequence-parallel engine: Ulysses all-to-all / ring attention over
        # the mesh's sequence axis (repro.distributed, DESIGN.md
        # §distributed); padding tokens carry segment id -1. The backend
        # selects the post-all-to-all inner attend (Ulysses).
        o = parallel.attend(q, k, v, segment_ids=segment_ids)
        return _linear(o.reshape(B, N, d), p["wo"], lora=la.get("wo"),
                       mode=mode)
    from repro.models import attention as attn_mod
    resolved = attn_mod.resolve_backend(attn_backend, n_tokens=N,
                                        segmented=segment_ids is not None)
    if resolved == "pallas":
        # segment-aware flash kernel with block-sparse cross-segment
        # skipping: packed rows never issue fully-masked score tiles
        from repro.kernels.attention import ops as attn_ops
        o = attn_ops.flash_attention(q, k, v, causal=False,
                                     segment_ids=segment_ids)
        return _linear(o.reshape(B, N, d), p["wo"], lora=la.get("wo"),
                       mode=mode)
    if resolved == "xla-blocked":
        # long (possibly packed) video sequences: flash-style blocked path
        # with q blocks sharded over the model axis; segment ids thread
        # through so packed CFG never materializes [B,H,N,N] scores
        from repro.configs.base import AttnConfig
        acfg = AttnConfig(num_heads=num_heads, num_kv_heads=num_heads,
                          head_dim=hd, use_rope=False)
        pos = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32), (B, N))
        o = attn_mod.blocked_gqa_attend(q, k, v, positions=pos, causal=False,
                                        window=0, cfg=acfg, unroll=unroll,
                                        segment_ids=segment_ids)
        return _linear(o.reshape(B, N, d), p["wo"], lora=la.get("wo"),
                       mode=mode)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) / np.sqrt(hd)
    if segment_ids is not None:
        from repro.kernels.attention import mask as mask_mod
        mask = mask_mod.segment_allowed(segment_ids, segment_ids)
        scores = scores + jnp.where(mask, 0.0, -1e30)[:, None]
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return _linear(o.reshape(B, N, d), p["wo"], lora=la.get("wo"), mode=mode)


def _cross_mha(p: Params, x: jax.Array, kv: jax.Array, num_heads: int,
               kv_mask: Optional[jax.Array] = None) -> jax.Array:
    B, N, d = x.shape
    hd = d // num_heads
    q = _linear(x, p["wq"]).reshape(B, N, num_heads, hd)
    k = _linear(kv, p["wk"]).reshape(B, kv.shape[1], num_heads, hd)
    v = _linear(kv, p["wv"]).reshape(B, kv.shape[1], num_heads, hd)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) / np.sqrt(hd)
    if kv_mask is not None:
        scores = scores + jnp.where(kv_mask[:, None, None], 0.0, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", probs.astype(v.dtype), v,
                   preferred_element_type=jnp.float32).astype(x.dtype)
    return _linear(o.reshape(B, N, d), p["wo"])


def _ln(x: jax.Array) -> jax.Array:
    """LayerNorm without learned affine (DiT blocks use adaLN modulation)."""
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)


def dit_block_apply(p: Params, x: jax.Array, c: jax.Array, cfg: ModelConfig, *,
                    mode: int = 0, text: Optional[jax.Array] = None,
                    text_mask: Optional[jax.Array] = None,
                    segment_ids: Optional[jax.Array] = None,
                    parallel: Optional[Any] = None,
                    attn_backend: str = "auto") -> jax.Array:
    H = cfg.attn.num_heads
    ada = _linear(jax.nn.silu(c.astype(jnp.float32)).astype(x.dtype),
                  p["ada"]["w"], p["ada"]["b"])
    sh1, sc1, g1, sh2, sc2, g2 = jnp.split(ada, 6, axis=-1)
    lora = p.get("lora", {})
    h = _modulate(_ln(x), sh1, sc1)
    x = x + g1[:, None] * _mha(p["attn"], h, H, lora=lora.get("attn"),
                               mode=mode, segment_ids=segment_ids,
                               unroll=cfg.unroll, parallel=parallel,
                               attn_backend=attn_backend)
    if "xattn" in p and text is not None:
        x = x + _cross_mha(p["xattn"], _ln(x), text, H, kv_mask=text_mask)
    h2 = _modulate(_ln(x), sh2, sc2)
    mlp_lora = lora.get("mlp", {})
    h2 = _linear(h2, p["mlp"]["w_in"], p["mlp"]["b_in"],
                 lora=mlp_lora.get("w_in"), mode=mode)
    h2 = jax.nn.gelu(h2.astype(jnp.float32), approximate=True).astype(x.dtype)
    h2 = _linear(h2, p["mlp"]["w_out"], p["mlp"]["b_out"],
                 lora=mlp_lora.get("w_out"), mode=mode)
    return x + g2[:, None] * h2


@functools.lru_cache(maxsize=64)
def _pos_embed_np(latent_shape: Tuple[int, int, int, int], p: Patch,
                  d: int) -> np.ndarray:
    coords = patch_mod.patch_centers(latent_shape, p)
    return patch_mod.sincos_pos_embed(d, coords)


def condition_vector(params: Params, t: jax.Array, cond: Any,
                     cfg: ModelConfig, dtype: jnp.dtype) -> jax.Array:
    """c = t_emb (+ class emb). t: [B] float; cond: labels [B] or None."""
    te = timestep_embedding(t, T_EMB_DIM).astype(dtype)
    te = _linear(te, params["t_embed"]["w1"], params["t_embed"]["b1"])
    te = jax.nn.silu(te.astype(jnp.float32)).astype(dtype)
    te = _linear(te, params["t_embed"]["w2"], params["t_embed"]["b2"])
    if cfg.dit.conditioning == "class" and cond is not None:
        te = te + jnp.take(params["class_embed"], cond, axis=0).astype(dtype)
    return te


def embed_mode_tokens(params: Params, x_t: jax.Array, cfg: ModelConfig,
                      mode: int,
                      latent_shape: Optional[Tuple[int, int, int, int]] = None
                      ) -> jax.Array:
    """Tokenize [B,F,H,W,C] latents at ``mode``'s patch size: per-mode (or
    flex) patch embedding + positional embedding + per-mode LN. Shared by
    the plain forward and the packed (NaViT-style) paths so packed
    segments see bit-identical token streams."""
    dit = cfg.dit
    ls = latent_shape or dit.latent_shape
    p = patch_sizes(cfg)[mode]
    pp = dit.underlying_patch_size
    dtype = dtype_of(cfg.compute_dtype)
    x_t = x_t.astype(dtype)
    if mode > 0 and "embed_new" in params:
        pn = params["embed_new"][f"m{mode}"]
        patches = patch_mod.patchify(x_t, p)
        tok = jnp.einsum("bnqc,qcd->bnd", patches, pn["w"].astype(dtype),
                         preferred_element_type=jnp.float32).astype(dtype)
        tok = tok + pn["b"].astype(dtype)
    else:
        tok = patch_mod.embed_tokens_flex(params["embed"]["w_flex"],
                                          params["embed"]["b"], x_t, p, pp)
    pos = jnp.asarray(_pos_embed_np(ls, p, cfg.d_model), dtype)
    tok = tok + pos[None]
    if mode > 0:
        tok = tok + params["ps_embed"][mode - 1].astype(dtype)[None, None]
        tok = layer_norm(tok, 1.0 + params["ps_ln"]["scale"][mode - 1],
                         params["ps_ln"]["bias"][mode - 1])
    return tok


def deembed_mode_tokens(params: Params, tok: jax.Array, cfg: ModelConfig,
                        mode: int,
                        latent_shape: Optional[Tuple[int, int, int, int]] = None
                        ) -> jax.Array:
    """Project [B, N_mode, d] tokens back to [B,F,H,W,c_out] latents
    (inverse of :func:`embed_mode_tokens`, minus the final adaLN which the
    caller applies)."""
    dit = cfg.dit
    ls = latent_shape or dit.latent_shape
    p = patch_sizes(cfg)[mode]
    pp = dit.underlying_patch_size
    dtype = tok.dtype
    if mode > 0 and "deembed_new" in params:
        pn = params["deembed_new"][f"m{mode}"]
        patches = jnp.einsum("bnd,dcq->bnqc", tok, pn["w"].astype(dtype),
                             preferred_element_type=jnp.float32)
        patches = (patches
                   + pn["b"].T.astype(jnp.float32)[None, None]).astype(dtype)
        return patch_mod.unpatchify(patches, ls, p)
    return patch_mod.deembed_tokens_flex(params["deembed"]["w_flex"],
                                         params["deembed"]["b_flex"], tok,
                                         ls, p, pp, c_out_dim(cfg))


def split_blocks(blocks: Params, split: int) -> Tuple[Params, Params]:
    """Slice a stacked block tree into (shallow [0, split), deep
    [split, L)) for the cached forward path."""
    return (jax.tree.map(lambda a: a[:split], blocks),
            jax.tree.map(lambda a: a[split:], blocks))


def dit_forward(params: Params, x_t: jax.Array, t: jax.Array, cond: Any,  # repro: traced
                cfg: ModelConfig, *, mode: int = 0,
                text_mask: Optional[jax.Array] = None,
                latent_shape: Optional[Tuple[int, int, int, int]] = None,
                parallel: Optional[Any] = None,
                block_cache: Optional[BlockCache] = None,
                attn_backend: str = "auto") -> Any:
    """Denoiser NFE.  x_t: [B,F,H,W,C]; t: [B]; cond: labels [B] int32 (class)
    or text embeddings [B,T,dc] (text). Returns [B,F,H,W,c_out].

    ``parallel``: optional ``distributed.engine.SeqParallel`` — tokens are
    padded to the sequence-axis size, scattered across the mesh, and each
    block's attention runs the Ulysses/ring collective; the per-mode token
    count (and hence the sharding) changes at FlexiSchedule phase
    boundaries, which is handled here by re-padding per call.

    ``block_cache``: optional cross-step activation cache (DESIGN.md
    §cache). When given, the return value is ``(out, new_delta)``: on
    refresh steps the deep blocks run and the fresh residual
    ``h_deep - h_shallow`` is returned for the caller to carry; on skip
    steps only the shallow blocks run and the cached delta is replayed.
    A refresh step computes the exact uncached forward (the output IS
    the deep stack's result, not ``shallow + delta`` re-added), which is
    what makes refresh-every-step bit-identical to no cache at all."""
    dit = cfg.dit
    ls = latent_shape or dit.latent_shape
    dtype = dtype_of(cfg.compute_dtype)
    tok = embed_mode_tokens(params, x_t, cfg, mode, ls)

    n_real = tok.shape[1]
    seg_ids = None
    if parallel is not None and parallel.sp > 1:
        if block_cache is not None:
            raise ValueError("the activation cache does not compose with "
                             "sequence-parallel execution yet (ROADMAP)")
        tok, seg_ids = parallel.pad_and_shard(tok)

    text = None
    if dit.conditioning == "text":
        text = _linear(cond.astype(dtype), params["text_proj"])
        c = condition_vector(params, t, None, cfg, dtype)
    else:
        c = condition_vector(params, t, cond, cfg, dtype)

    def body(h, bp):
        h = dit_block_apply(bp, h, c, cfg, mode=mode, text=text,
                            text_mask=text_mask, segment_ids=seg_ids,
                            parallel=parallel, attn_backend=attn_backend)
        return h, None

    if cfg.remat == "block":
        body = jax.checkpoint(body, prevent_cse=False)
    from repro.models.common import scan_or_unroll
    new_delta = None
    if block_cache is None:
        tok, _ = scan_or_unroll(body, tok, params["blocks"], cfg.unroll)
    else:
        shallow, deep = split_blocks(params["blocks"],
                                     block_cache.split)
        tok, _ = scan_or_unroll(body, tok, shallow, cfg.unroll)

        def _refresh(args):
            h_s, _delta = args
            h_d, _ = scan_or_unroll(body, h_s, deep, cfg.unroll)
            return h_d, h_d - h_s

        def _replay(args):
            h_s, delta = args
            return h_s + delta, delta

        tok, new_delta = jax.lax.cond(block_cache.refresh, _refresh,
                                      _replay, (tok, block_cache.delta))
    if parallel is not None and tok.shape[1] != n_real:
        tok = parallel.unshard(tok, n_real)

    ada = _linear(jax.nn.silu(c.astype(jnp.float32)).astype(dtype),
                  params["final"]["ada"]["w"], params["final"]["ada"]["b"])
    sh, sc = jnp.split(ada, 2, axis=-1)
    tok = _modulate(_ln(tok), sh, sc)
    out = deembed_mode_tokens(params, tok, cfg, mode, ls)
    return out if block_cache is None else (out, new_delta)


def eps_prediction(out: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Extract the ε-prediction (first c_in channels when learning Σ)."""
    c_in = cfg.dit.latent_shape[-1]
    return out[..., :c_in] if cfg.dit.learn_sigma else out
