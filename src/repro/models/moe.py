"""Mixture-of-Experts layer.

Two implementations:

* ``moe_apply_sorted`` — production path. Sort-based dispatch (Megablocks
  style): flatten (token, expert) assignments, argsort by expert, place into
  a static ``[E, capacity, d]`` buffer, run a single batched expert matmul,
  scatter-add back weighted by the router gate. FLOPs stay at the
  *active-parameter* level (one-hot capacity einsums would cost
  O(B·S·E·C·d) — 40× the expert FFN for grok-1 at 32k tokens; see DESIGN.md).
* ``moe_apply_dense`` — O(E) oracle computing every expert for every token,
  used by unit tests to validate the sorted path.

Shared experts (deepseek-moe) are a dense FFN always applied.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import MoEConfig
from repro.models.common import ParamSpec, mlp_act
from repro.models.mlp import mlp_apply, mlp_schema

Params = Dict[str, Any]


def moe_schema(d_model: int, cfg: MoEConfig, d_ff_dense: int,
               activation: str = "swiglu") -> Params:
    e_ff = cfg.expert_d_ff or d_ff_dense
    E = cfg.num_experts
    gated = activation in ("swiglu", "geglu")
    s: Params = {
        "router": ParamSpec((d_model, E), ("embed", None), scale=0.02),
        "w_in": ParamSpec((E, d_model, e_ff), ("expert", "embed", "mlp")),
        "w_out": ParamSpec((E, e_ff, d_model), ("expert", "mlp", "embed")),
    }
    if gated:
        s["w_gate"] = ParamSpec((E, d_model, e_ff), ("expert", "embed", "mlp"))
    if cfg.num_shared_experts:
        s["shared"] = mlp_schema(d_model, cfg.num_shared_experts * e_ff, activation)
    return s


def _router(params: Params, x2d: jax.Array, cfg: MoEConfig
            ) -> Tuple[jax.Array, jax.Array, jax.Array, Dict[str, jax.Array]]:
    """x2d: [T,d] → (gates [T,k], idx [T,k] int32, probs [T,E], aux losses)."""
    logits = jnp.einsum("td,de->te", x2d, params["router"].astype(x2d.dtype),
                        preferred_element_type=jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    gates = gates / jnp.maximum(jnp.sum(gates, -1, keepdims=True), 1e-9)
    # Aux losses (Switch-style load balance + router z-loss).
    E = cfg.num_experts
    me = jnp.mean(probs, axis=0)                                  # [E]
    one_hot_top1 = jax.nn.one_hot(idx[:, 0], E, dtype=jnp.float32)
    ce = jnp.mean(one_hot_top1, axis=0)
    lb = E * jnp.sum(me * ce)
    z = jnp.mean(jnp.square(jax.nn.logsumexp(logits, axis=-1)))
    aux = {"load_balance": lb * cfg.load_balance_loss,
           "router_z": z * cfg.router_z_loss}
    return gates.astype(jnp.float32), idx.astype(jnp.int32), probs, aux


def _expert_ffn(params: Params, xb: jax.Array, activation: str) -> jax.Array:
    """xb: [E, C, d] → [E, C, d] batched expert matmuls."""
    dt = xb.dtype
    up = jnp.einsum("ecd,edf->ecf", xb, params["w_in"].astype(dt))
    if "w_gate" in params:
        gate = jnp.einsum("ecd,edf->ecf", xb, params["w_gate"].astype(dt))
        h = mlp_act(gate, up, activation)
    else:
        h = mlp_act(up, None, activation)
    return jnp.einsum("ecf,efd->ecd", h.astype(dt),
                      params["w_out"].astype(dt)).astype(dt)


def capacity(num_tokens: int, cfg: MoEConfig) -> int:
    c = int(num_tokens * cfg.num_experts_per_tok * cfg.capacity_factor
            / cfg.num_experts)
    return max(8, -(-c // 8) * 8)


def moe_apply_sorted(params: Params, x: jax.Array, cfg: MoEConfig,
                     activation: str = "swiglu"
                     ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Per-row sort-based dispatch: each batch row sorts and dispatches its
    own tokens (axis=-1 sort → NO cross-data-shard collectives under GSPMD;
    the global-sort variant cost grok-1 ~8 TB/device of all-reduce in the
    dry-run — see EXPERIMENTS.md §Perf iteration 2). Capacity is per
    (row, expert); over-capacity tokens are dropped (residual keeps them).
    """
    B, S, d = x.shape
    k = cfg.num_experts_per_tok
    E = cfg.num_experts
    T = S * k
    x2d = x.reshape(B * S, d)
    gates, idx, _, aux = _router(params, x2d, cfg)
    gates = gates.reshape(B, T)
    e_flat = idx.reshape(B, T)
    tok_flat = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)[None]
    tok_flat = jnp.broadcast_to(tok_flat, (B, T))

    C = capacity(S, cfg)
    order = jnp.argsort(e_flat, axis=-1, stable=True)
    e_sorted = jnp.take_along_axis(e_flat, order, -1)
    tok_sorted = jnp.take_along_axis(tok_flat, order, -1)

    counts = jnp.sum(jax.nn.one_hot(e_flat, E, dtype=jnp.int32), axis=1)
    starts = jnp.cumsum(counts, axis=-1) - counts               # [B,E]
    pos = jnp.arange(T, dtype=jnp.int32)[None] \
        - jnp.take_along_axis(starts, e_sorted, -1)
    keep = pos < C
    buf_idx = jnp.where(keep, e_sorted * C + pos, E * C)        # [B,T]
    bi = jnp.arange(B)[:, None]

    # GATHER-ONLY dispatch: the only scatter is of int32 token indices —
    # scattering [B,T,d] activations lowered to a u32[B,T,d] all-gather
    # under GSPMD (≈50 GB/layer on grok-1; EXPERIMENTS.md §Perf iter 3).
    idx_buf = jnp.full((B, E * C + 1), S, jnp.int32)
    idx_buf = idx_buf.at[bi, buf_idx].set(tok_sorted)[:, :E * C]
    valid = (idx_buf < S)[..., None].astype(x.dtype)
    x_buf = jnp.take_along_axis(x, jnp.minimum(idx_buf, S - 1)[..., None], 1)
    x_buf = x_buf * valid
    # keep expert buffers sharded like the batch (stop GSPMD gathering them)
    from jax.sharding import PartitionSpec as _P
    from repro.runtime.sharding import constrain as _constrain
    x_buf = _constrain(x_buf, _P(("pod", "data"), None, None))
    y_buf = _expert_ffn_batched(params, x_buf.reshape(B, E, C, d), activation)
    y_buf = _constrain(y_buf.reshape(B, E * C, d), _P(("pod", "data"), None, None))

    # back to token-major via the inverse permutation (pure gathers)
    inv = jnp.argsort(order, axis=-1)
    buf_pos = jnp.take_along_axis(buf_idx, inv, -1)             # [B,T]
    keep_tok = jnp.take_along_axis(keep, inv, -1)
    y_slots = jnp.take_along_axis(y_buf,
                                  jnp.minimum(buf_pos, E * C - 1)[..., None], 1)
    w_tok = (gates * keep_tok.astype(jnp.float32))[..., None].astype(x.dtype)
    y_tok = (y_slots * w_tok).reshape(B, S, k, d).sum(axis=2)

    out = y_tok
    if "shared" in params:
        out = out + mlp_apply(params["shared"], x, activation)
    aux["dropped_fraction"] = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return out, aux


def _expert_ffn_batched(params: Params, xb: jax.Array, activation: str
                        ) -> jax.Array:
    """xb: [B, E, C, d] → [B, E, C, d]"""
    dt = xb.dtype
    up = jnp.einsum("becd,edf->becf", xb, params["w_in"].astype(dt))
    if "w_gate" in params:
        gate = jnp.einsum("becd,edf->becf", xb, params["w_gate"].astype(dt))
        h = mlp_act(gate, up, activation)
    else:
        h = mlp_act(up, None, activation)
    return jnp.einsum("becf,efd->becd", h.astype(dt),
                      params["w_out"].astype(dt)).astype(dt)


def moe_apply_sorted_global(params: Params, x: jax.Array, cfg: MoEConfig,
                            activation: str = "swiglu"
                            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Global-sort variant (reference; collective-heavy under GSPMD)."""
    B, S, d = x.shape
    T = B * S
    k = cfg.num_experts_per_tok
    E = cfg.num_experts
    x2d = x.reshape(T, d)
    gates, idx, _, aux = _router(params, x2d, cfg)

    C = capacity(T, cfg)
    e_flat = idx.reshape(T * k)                                   # expert ids
    tok_flat = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)      # token ids
    g_flat = gates.reshape(T * k)

    order = jnp.argsort(e_flat, stable=True)
    e_sorted = e_flat[order]
    tok_sorted = tok_flat[order]
    g_sorted = g_flat[order]

    # Position of each slot within its expert group.
    counts = jnp.bincount(e_flat, length=E)                       # [E]
    starts = jnp.cumsum(counts) - counts                          # exclusive
    pos_in_e = jnp.arange(T * k, dtype=jnp.int32) - starts[e_sorted]
    keep = pos_in_e < C

    buf_idx = jnp.where(keep, e_sorted * C + pos_in_e, E * C)     # overflow row
    x_gathered = x2d[tok_sorted] * keep[:, None].astype(x2d.dtype)
    x_buf = jnp.zeros((E * C + 1, d), x2d.dtype).at[buf_idx].set(x_gathered)
    y_buf = _expert_ffn(params, x_buf[:E * C].reshape(E, C, d), activation)

    y_slots = y_buf.reshape(E * C, d)[jnp.minimum(buf_idx, E * C - 1)]
    y_slots = y_slots * (g_sorted * keep.astype(jnp.float32))[:, None].astype(x2d.dtype)
    out = jnp.zeros((T, d), x2d.dtype).at[tok_sorted].add(y_slots)

    if "shared" in params:
        out = out + mlp_apply(params["shared"], x2d, activation)
    aux["dropped_fraction"] = 1.0 - jnp.mean(keep.astype(jnp.float32))
    return out.reshape(B, S, d), aux


def moe_apply_dense(params: Params, x: jax.Array, cfg: MoEConfig,
                    activation: str = "swiglu"
                    ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Oracle: every expert on every token, gated combine. Test-only."""
    B, S, d = x.shape
    T = B * S
    x2d = x.reshape(T, d)
    gates, idx, probs, aux = _router(params, x2d, cfg)
    E = cfg.num_experts
    combine = jnp.zeros((T, E), jnp.float32)
    for j in range(cfg.num_experts_per_tok):
        combine = combine + jax.nn.one_hot(idx[:, j], E) * gates[:, j:j + 1]
    y_all = _expert_ffn(params, jnp.broadcast_to(x2d, (E, T, d)).transpose(0, 1, 2),
                        activation)                               # [E,T,d]
    out = jnp.einsum("te,etd->td", combine.astype(x2d.dtype), y_all)
    if "shared" in params:
        out = out + mlp_apply(params["shared"], x2d, activation)
    aux["dropped_fraction"] = jnp.zeros(())
    return out.reshape(B, S, d), aux
