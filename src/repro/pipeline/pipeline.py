"""FlexiPipeline — the single FlexiDiT inference entry point (DESIGN.md
§pipeline).

The pipeline owns ``(params, cfg, diffusion schedule)`` and a cache of
compiled executables so that repeated ``sample`` calls — including budget
or mode switches between calls — never retrace or recompile:

* **static plans** compile one *phase runner* per plan signature
  ``(solver, resolved schedule, timestep ladder, guidance signature,
  LoRA variant, eps_transform)``; batch shape and conditioning are traced
  arguments, so jax's jit cache keys them per runner;
* **adaptive plans** compile one guided NFE per ``(mode, scale, LoRA
  variant)`` — the same two executables the static scheduler uses — and
  drive the probe loop in ``core.adaptive``.

``cache_stats()`` exposes our own hit/miss counters plus the true number
of XLA compilations (summed jit cache sizes), which tests assert stays
flat across repeated calls.

With a device mesh attached (``FlexiPipeline(..., mesh=...)``) plans may
carry a ``parallel=ParallelSpec(...)`` to run sequence-parallel through
``repro.distributed`` (DESIGN.md §distributed); the mesh fingerprint
joins the runner key so budget switches on a fixed mesh stay
compile-free while mesh swaps compile fresh runners.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.core import adaptive as adaptive_mod
from repro.core.flexify import merge_lora
from repro.core.guidance import GuidanceConfig, make_eps_fn
from repro.core.scheduler import FlexiSchedule
from repro.diffusion import flow, sampler
from repro.diffusion import schedule as sch
from repro.distributed.engine import SeqParallel, mesh_fingerprint
from repro.pipeline.packed import PackLayout, make_packed_step_fn
from repro.pipeline.plan import FLOW_SOLVERS, SamplingPlan
from repro.runtime import sharding as sharding_mod

Params = Dict[str, Any]
# eps_transform(eps, x, t) -> eps — e.g. spectral filtering probes (Fig. 2)
EpsTransform = Callable[[jax.Array, jax.Array, jax.Array], jax.Array]


@dataclasses.dataclass
class SampleResult:
    x0: jax.Array
    flops: float                  # actual FLOPs spent for the whole batch
    relative_compute: float       # vs the all-powerful baseline, same T
    trace: Dict[str, Any]         # schedule / switch point / probe gaps / ...


class FlexiPipeline:
    """Compile-once sampling for a flexified DiT.

    >>> pipe = FlexiPipeline(params, cfg, sched)
    >>> plan = SamplingPlan(T=20, budget=0.6)
    >>> res = pipe.sample(plan, n=16, key=jax.random.PRNGKey(0))
    """

    def __init__(self, params: Params, cfg: ModelConfig,
                 sched: sch.DiffusionSchedule,
                 mesh: Optional[Mesh] = None):
        assert cfg.family == "dit" and cfg.dit is not None, cfg.name
        self.params = params
        self.cfg = cfg
        self.sched = sched
        self.mesh = mesh
        self._runners: Dict[Tuple, Callable] = {}
        self._nfes: Dict[Tuple, Callable] = {}
        self._merged: Dict[int, Params] = {}
        self._hits = 0
        self._misses = 0
        # serializes cache miss/insert so a background warm thread
        # (fleet.warmup) racing the serving thread on the same key can't
        # both build: the loser would keep a runner the cache forgot and
        # the next lookup would compile a twin (a phantom recompile)
        self._cache_lock = threading.Lock()
        # (runner key) -> (arg ShapeDtypeStruct tree, analytic FLOPs per
        # call) for sample()-path runners, recorded only when
        # enable_cost_profiling() was called (DESIGN.md §profiling)
        self.profile_specs: Optional[Dict[Tuple, Tuple[Any, float]]] = None

    def set_mesh(self, mesh: Optional[Mesh]) -> None:
        """Attach / swap the device mesh. Compiled runners are keyed by the
        mesh fingerprint, so switching meshes compiles new runners while a
        fixed mesh (any number of budget switches) never recompiles."""
        self.mesh = mesh

    # ------------------------------------------------------------------
    # Cache plumbing

    def cache_stats(self) -> Dict[str, int]:
        with self._cache_lock:
            compiled = sum(f._cache_size() for f in self._runners.values())
            compiled += sum(f._cache_size() for f in self._nfes.values())
            return {"runners": len(self._runners),
                    "nfe_fns": len(self._nfes),
                    "hits": self._hits, "misses": self._misses,
                    "compiled": compiled}

    def update_params(self, params: Params) -> None:
        """Swap weights without dropping compiled executables (params are
        traced arguments, not baked-in constants)."""
        self.params = params
        self._merged.clear()

    def _lora_variant(self, plan: SamplingPlan) -> str:
        return "none" if self.cfg.dit.lora_rank <= 0 else plan.lora

    def _params_for_mode(self, mode: int, variant: str) -> Params:
        if variant != "merged" or mode == 0:
            return self.params
        if mode not in self._merged:
            self._merged[mode] = merge_lora(self.params, self.cfg, mode)
        return self._merged[mode]

    def _lookup(self, cache: Dict, key: Tuple, build: Callable) -> Callable:
        # build() under the lock is cheap (jit wrapping, no compile —
        # XLA compilation happens at first call and jax serializes that
        # internally); what must be atomic is miss-check + insert
        with self._cache_lock:
            if key in cache:
                self._hits += 1
            else:
                self._misses += 1
                cache[key] = build()
            return cache[key]

    def runners(self) -> Dict[Tuple, Callable]:
        """Read-only view of the compiled-runner cache. The compiled-cost
        registry (telemetry/profile.py) harvests AOT cost/memory analysis
        from these; the keys are the zero-recompile cache keys."""
        return dict(self._runners)

    def enable_cost_profiling(self) -> None:
        """Start recording ``(arg spec, analytic FLOPs)`` for
        sample()-path runners so ``CompiledCostRegistry.harvest`` can
        AOT-lower them. Packed runners need no recording — their specs
        derive from the cache key alone. Idempotent; recording is a
        host-side dict insert per ``sample`` call (no device work, no
        effect on jaxprs or latents)."""
        if self.profile_specs is None:
            self.profile_specs = {}

    def _record_spec(self, runner_key: Tuple, args: Tuple,
                     analytic_flops: float) -> None:
        if self.profile_specs is None:
            return
        specs = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(jnp.shape(a),
                                           jnp.asarray(a).dtype), args)
        self.profile_specs[runner_key] = (specs, float(analytic_flops))

    # ------------------------------------------------------------------
    # Conditioning

    def _default_cond(self, n: int, cond: Any) -> Tuple[Any, Any]:
        dit = self.cfg.dit
        if dit.conditioning == "class":
            y = (jnp.arange(n) % dit.num_classes if cond is None
                 else jnp.asarray(cond))
            return y, jnp.full((n,), dit.num_classes)
        if dit.conditioning == "text":
            if cond is None:
                raise ValueError("text-conditioned models need cond "
                                 "embeddings [n, text_len, text_dim]")
            y = jnp.asarray(cond)
            return y, jnp.zeros_like(y)
        return None, None

    # ------------------------------------------------------------------
    # Compiled runners

    def _phase_guidance(self, plan: SamplingPlan, mode: int) -> GuidanceConfig:
        if plan.guidance_active and plan.guidance_kind == "weak_cond" \
                and mode == 0:
            # §3.4: the weak model's *conditional* prediction guides the
            # powerful phase
            return GuidanceConfig(scale=plan.guidance_scale, mode_cond=0,
                                  mode_uncond=plan.weak_mode, kind="weak_cond")
        return GuidanceConfig(scale=plan.guidance_scale, mode_cond=mode,
                              mode_uncond=mode)

    def _param_set_modes(self, plan: SamplingPlan,
                         schedule: FlexiSchedule) -> Tuple[int, ...]:
        """Modes needing their own param tree: with merged LoRA each weak
        mode gets its own merge — including the weak mode serving only as
        the §3.4 guidance NFE — otherwise everything shares the base."""
        if self._lora_variant(plan) != "merged":
            return (0,)
        modes = {m for m, n in schedule.phases if n}
        if plan.guidance_active and plan.guidance_kind == "weak_cond":
            modes.add(plan.weak_mode)
        return tuple(sorted(modes))

    def _static_runner(self, plan: SamplingPlan, schedule: FlexiSchedule,
                       ts: np.ndarray, transform: Optional[EpsTransform],
                       engine: Optional[SeqParallel] = None) -> Callable:
        splits = schedule.split_timesteps(ts)
        set_idx = {m: i for i, m in
                   enumerate(self._param_set_modes(plan, schedule))}
        cfg = self.cfg

        def run(param_sets, x_T, cond, null_cond, key, text_mask,
                null_text_mask):
            phases = []
            for mode, tsub in splits:
                p = param_sets[set_idx.get(mode, 0)]
                g = self._phase_guidance(plan, mode)
                # §3.4 guidance NFE runs at the weak mode: under merged
                # LoRA it must see that mode's merged weights, not the base
                gp = (param_sets[set_idx[g.mode_uncond]]
                      if g.kind == "weak_cond" and g.mode_uncond in set_idx
                      else None)
                base_fn = make_eps_fn(p, cfg, cond, null_cond, g,
                                      text_mask, null_text_mask,
                                      guidance_params=gp, parallel=engine,
                                      attn_backend=plan.attn_backend)
                if transform is None:
                    fn = base_fn
                else:
                    def fn(x, t, _f=base_fn):
                        eps, lv = _f(x, t)
                        return transform(eps, x, t), lv
                phases.append((fn, tsub))
            return sampler.sample_phased(phases, self.sched, x_T, key,
                                         solver=plan.solver,
                                         clip_x0=plan.clip_x0)

        return jax.jit(run)

    def _flow_runner(self, plan: SamplingPlan, schedule: FlexiSchedule,
                     engine: Optional[SeqParallel] = None) -> Callable:
        taus = flow.tau_ladder(plan.T)
        splits = flow.split_tau_ladder(taus, schedule.phases)
        set_idx = {m: i for i, m in
                   enumerate(self._param_set_modes(plan, schedule))}
        solver = "euler" if plan.solver == "flow_euler" else "heun"
        cfg = self.cfg

        def run(param_sets, x_T, cond):
            phases = []
            for mode, tsub in splits:
                p = param_sets[set_idx.get(mode, 0)]
                phases.append((flow.make_flow_v_fn(
                    p, cfg, cond, mode=mode, parallel=engine,
                    attn_backend=plan.attn_backend), tsub))
            return flow.sample_flow_phased(phases, x_T, solver=solver)

        return jax.jit(run)

    def _cached_runner(self, plan: SamplingPlan, schedule: FlexiSchedule,
                       ts: np.ndarray, taps: bool = False) -> Callable:
        """Static runner with the cross-step activation cache (DESIGN.md
        §cache): per-phase refresh masks arrive as TRACED inputs, so one
        compiled runner serves every refresh policy at this (schedule,
        split) signature. ``taps`` (§telemetry) appends per-step
        eps-norm / replay-drift data outputs; latents are bit-identical
        either way and the flag joins the runner key."""
        from repro.cache import apply as cache_apply
        from repro.models import dit as dit_mod
        from repro.models.common import dtype_of
        splits = schedule.split_timesteps(ts)
        set_idx = {m: i for i, m in
                   enumerate(self._param_set_modes(plan, schedule))}
        cfg = self.cfg
        split = plan.cache.resolve_split(cfg.num_layers)

        def run(param_sets, x_T, cond, null_cond, key, text_mask,
                null_text_mask, masks):
            B = x_T.shape[0]
            dtype = dtype_of(cfg.compute_dtype)
            phases = []
            for i, (mode, tsub) in enumerate(splits):
                p = param_sets[set_idx.get(mode, 0)]
                g = self._phase_guidance(plan, mode)
                fn = cache_apply.make_cached_eps_fn(
                    p, cfg, cond, null_cond, g, text_mask,
                    null_text_mask, split,
                    attn_backend=plan.attn_backend)
                guided = g.scale != 0.0 and cond is not None
                delta0 = jnp.zeros(
                    cache_apply.delta_shape(cfg, mode, B, guided), dtype)
                phases.append((fn, tsub, masks[i], delta0))
            return cache_apply.sample_phased_cached(
                phases, self.sched, x_T, key, solver=plan.solver,
                clip_x0=plan.clip_x0, taps=taps)

        return jax.jit(run)

    def packed_step(self, layout: PackLayout, *, solver: str = "ddim",
                    guidance_scale: float = 1.5, clip_x0: float = 0.0,
                    k_steps: int = 1,
                    cache_split: Optional[int] = None,
                    attn_backend: str = "auto",
                    taps: bool = False) -> Callable:
        """Step-granular entry point (DESIGN.md §serving): the compiled
        executable advancing ONE packed engine step (``k_steps``
        micro-steps under lax.scan) at ``layout``. Latents, timesteps,
        conditioning, params, and solver keys are traced, so the serving
        engine replays a layout across arbitrary requests and denoise
        steps without recompiling; runners share this pipeline's cache,
        so ``cache_stats()`` tracks bucket warmup. ``cache_split``
        selects the activation-cached step family (per-request deltas +
        refresh flags are traced too — refresh policies never join the
        key). ``taps`` selects the telemetry step family (DESIGN.md
        §telemetry): same latents bit-for-bit plus on-device tap
        outputs; it is a build-time flag, so it joins the key."""
        key = ("packed", layout, solver, guidance_scale, clip_x0, k_steps,
               cache_split, attn_backend, taps)
        return self._lookup(
            self._runners, key,
            lambda: jax.jit(make_packed_step_fn(
                self.cfg, self.sched, layout, solver=solver,
                guidance_scale=guidance_scale, clip_x0=clip_x0,
                k_steps=k_steps, cache_split=cache_split,
                attn_backend=attn_backend, taps=taps)))

    def packed_step_is_warm(self, layout: PackLayout, *, solver: str = "ddim",
                            guidance_scale: float = 1.5,
                            clip_x0: float = 0.0,
                            k_steps: int = 1,
                            cache_split: Optional[int] = None,
                            attn_backend: str = "auto",
                            taps: bool = False) -> bool:
        """Whether :meth:`packed_step` would be a cache hit — the serving
        planner prefers warm executables so steady-state traffic never
        stalls on a compile."""
        return ("packed", layout, solver, guidance_scale, clip_x0,
                k_steps, cache_split, attn_backend, taps) in self._runners

    def warm_packed_layouts(self, *, solver: str = "ddim",
                            guidance_scale: float = 1.5,
                            clip_x0: float = 0.0,
                            cache_split: Optional[int] = None,
                            attn_backend: str = "auto",
                            taps: bool = False
                            ) -> Dict[int, List[PackLayout]]:
        """Compiled packed-step layouts grouped by micro-step depth k, for
        the given step family. A frozen serving engine
        (``allow_cold=False``) restricts its planner to these."""
        out: Dict[int, List[PackLayout]] = {}
        for key in self._runners:
            if key[0] == "packed" and key[2:5] == (solver, guidance_scale,
                                                   clip_x0) \
                    and key[6:9] == (cache_split, attn_backend, taps):
                out.setdefault(key[5], []).append(key[1])
        return out

    def _nfe_fn(self, mode: int, scale: float,
                attn_backend: str = "auto") -> Callable:
        cfg = self.cfg
        g = GuidanceConfig(scale=scale, mode_cond=mode, mode_uncond=mode)

        def nfe(params, x, t, cond, null_cond, text_mask, null_text_mask):
            return make_eps_fn(params, cfg, cond, null_cond, g,
                               text_mask, null_text_mask,
                               attn_backend=attn_backend)(x, t)

        return jax.jit(nfe)

    # ------------------------------------------------------------------
    # Sampling

    def sample(self, plan: SamplingPlan, n: int, key: jax.Array, *,
               cond: Any = None, x_T: Optional[jax.Array] = None,
               text_mask: Optional[jax.Array] = None,
               null_text_mask: Optional[jax.Array] = None,
               eps_transform: Optional[EpsTransform] = None,
               taps: bool = False) -> SampleResult:
        """Sample ``n`` latents under ``plan``. ``key`` seeds both the prior
        draw and the solver noise (``x_T`` overrides the prior draw).

        ``eps_transform`` is keyed by function *identity*: reuse the same
        callable across calls to reuse its compiled runner — a fresh
        closure per call compiles (and retains) a new runner each time.

        ``taps`` (cached plans only; DESIGN.md §telemetry) returns
        per-step eps-norm and cache replay-drift data outputs in
        ``result.trace["taps"]`` — same ``x0`` bit-for-bit.
        """
        plan.validate(self.cfg)
        if x_T is None:
            x_T = jax.random.normal(key, (n,) + self.cfg.dit.latent_shape)
        run_key = jax.random.fold_in(key, 1)
        y, null = self._default_cond(n, cond)
        variant = self._lora_variant(plan)

        if eps_transform is not None and (plan.is_adaptive
                                          or plan.solver in FLOW_SOLVERS):
            raise ValueError("eps_transform only applies to static "
                             "diffusion plans")
        if eps_transform is not None and plan.cache is not None:
            raise ValueError("eps_transform does not compose with the "
                             "activation cache")
        if taps and plan.cache is None:
            raise ValueError("taps instrument the cached runner (and the "
                             "serving engine's packed steps); this plan "
                             "has no cache")
        if plan.is_adaptive:
            return self._sample_adaptive(plan, x_T, run_key, y, null,
                                         text_mask, null_text_mask)

        ts = sch.respaced_timesteps(self.sched.num_steps, plan.T)
        schedule = plan.resolve_schedule(self.cfg)
        param_sets = tuple(self._params_for_mode(m, variant)
                           for m in self._param_set_modes(plan, schedule))
        engine = (SeqParallel.create(self.mesh, plan.parallel, self.cfg,
                                     attn_backend=plan.attn_backend)
                  if plan.parallel is not None else None)
        if self.mesh is not None:
            # committed single-device params can't mix with mesh-sharded
            # activations: replicate weights, shard the batch over the data
            # axes (no-ops once placed — jax.device_put short-circuits).
            # Sequence-parallel runners take REPLICATED inputs: the shard_map
            # in_specs re-introduce the (data, seq) split inside the
            # collective, and jax 0.4.x GSPMD miscompiles the mixed
            # batch-sharded + shard_map graph (see distributed.engine).
            repl = NamedSharding(self.mesh, P())
            bspec = (repl if engine is not None else
                     NamedSharding(self.mesh,
                                   sharding_mod.batch_spec(n, self.mesh)))
            param_sets = jax.device_put(param_sets, repl)
            x_T = jax.device_put(x_T, bspec)
            if y is not None:
                y = jax.device_put(y, bspec)
            if null is not None:
                null = jax.device_put(null, bspec)
        # mesh fingerprint joins the key: budget switches on a fixed mesh
        # reuse runners; swapping meshes compiles fresh ones
        sig = (plan.solver, plan.clip_x0, plan.guidance_scale,
               plan.guidance_kind, plan.weak_mode, variant,
               schedule.phases, tuple(int(t) for t in ts), eps_transform,
               plan.parallel, mesh_fingerprint(self.mesh),
               plan.attn_backend)
        if plan.solver in FLOW_SOLVERS:
            runner = self._lookup(
                self._runners, ("flow",) + sig,
                lambda: self._flow_runner(plan, schedule, engine))
            x0 = runner(param_sets, x_T, y)
            self._record_spec(("flow",) + sig, (param_sets, x_T, y),
                              plan.flops(self.cfg, batch=n))
        elif plan.cache is not None:
            from repro.cache import ledger as cache_ledger
            from repro.cache import policy as cache_policy
            # masks are runner INPUTS: interval/band/threshold switches
            # replay the same executable with different flag arrays
            masks = tuple(
                jnp.asarray(cache_policy.refresh_mask(plan.cache, tsub))
                for _m, tsub in schedule.split_timesteps(ts))
            runner = self._lookup(
                self._runners,
                ("cached",) + sig
                + (plan.cache.resolve_split(self.cfg.num_layers), taps),
                lambda: self._cached_runner(plan, schedule, ts, taps=taps))
            out = runner(param_sets, x_T, y, null, run_key, text_mask,
                         null_text_mask, masks)
            x0, tap_phases = out if taps else (out, None)
            fl, n_refresh, n_steps = cache_ledger.schedule_cached_flops(
                self.cfg, schedule, ts, plan.cache,
                cfg_scale_active=plan.guidance_active,
                lora_unmerged=(variant == "unmerged"))
            self._record_spec(
                ("cached",) + sig
                + (plan.cache.resolve_split(self.cfg.num_layers), taps),
                (param_sets, x_T, y, null, run_key, text_mask,
                 null_text_mask, masks), n * fl)
            trace = {"schedule": schedule, "timesteps": ts,
                     "refresh_masks": tuple(np.asarray(m) for m in masks),
                     "cache_refreshes": n_refresh,
                     "cache_steps": n_steps}
            if taps:
                trace["taps"] = tap_phases
            return SampleResult(
                x0=x0, flops=n * fl,
                relative_compute=plan.relative_compute(self.cfg),
                trace=trace)
        else:
            runner = self._lookup(
                self._runners, ("static",) + sig,
                lambda: self._static_runner(plan, schedule, ts, eps_transform,
                                            engine))
            x0 = runner(param_sets, x_T, y, null, run_key, text_mask,
                        null_text_mask)
            self._record_spec(("static",) + sig,
                              (param_sets, x_T, y, null, run_key,
                               text_mask, null_text_mask),
                              plan.flops(self.cfg, batch=n))
        return SampleResult(
            x0=x0, flops=plan.flops(self.cfg, batch=n),
            relative_compute=plan.relative_compute(self.cfg),
            trace={"schedule": schedule, "timesteps": ts})

    def _sample_adaptive(self, plan: SamplingPlan, x_T: jax.Array,
                         run_key: jax.Array, y: Any, null: Any,
                         text_mask, null_text_mask) -> SampleResult:
        ts = sch.respaced_timesteps(self.sched.num_steps, plan.T)
        variant = self._lora_variant(plan)
        n_modes = 1 + len(self.cfg.dit.flex_patch_sizes)
        fns: List[Callable] = []
        for mode in range(n_modes):
            jf = self._lookup(
                self._nfes, ("nfe", mode, plan.guidance_scale, variant,
                             plan.attn_backend),
                lambda m=mode: self._nfe_fn(m, plan.guidance_scale,
                                            plan.attn_backend))
            p = self._params_for_mode(mode, variant)
            fns.append(lambda x, t, _f=jf, _p=p:
                       _f(_p, x, t, y, null, text_mask, null_text_mask))
        res = adaptive_mod.adaptive_sample(
            fns, self.sched, x_T, ts, run_key, self.cfg,
            threshold=plan.budget.threshold,
            probe_every=plan.budget.probe_every,
            weak_mode=plan.weak_mode, solver=plan.solver,
            guided=plan.guidance_active,
            lora_unmerged=(variant == "unmerged"))
        return SampleResult(
            x0=res.x0, flops=res.flops,
            relative_compute=res.flops / res.flops_static_powerful,
            trace={"switch_step": res.switch_step, "gaps": res.gaps,
                   "timesteps": ts,
                   "flops_static_powerful": res.flops_static_powerful})
