"""Step-granular packed runners (DESIGN.md §serving).

A :class:`PackLayout` is the static shape of ONE engine step: how many
requests of each patch mode advance together, whether CFG doubles each
request into a (conditional, unconditional) segment pair, and the token
capacity of each packed row. :func:`make_packed_step_fn` builds the
executable for a layout — embed every segment at its own mode, pack rows
with block-diagonal attention (``core.packing.packed_mixed_forward``),
combine guidance, and apply one solver update per request at that
request's own ``(t, t_prev)``. Timesteps, conditioning, latents, params,
and solver keys are all traced, so a layout compiles exactly once no
matter which requests, denoise steps, or budgets flow through it —
``FlexiPipeline.packed_step`` caches these next to the phase runners so
``cache_stats()`` covers bucket warmup too.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import packing
from repro.core.guidance import split_model_out
from repro.diffusion import schedule as sch
from repro.models import dit as dit_mod
from repro.telemetry import taps as taps_mod

PACKED_SOLVERS = ("ddim", "ddpm")


@dataclasses.dataclass(frozen=True)
class PackLayout:
    """Static shape of one packed engine step.

    ``groups``: ``((mode, n_requests), ...)`` sorted by mode, all counts
    positive. ``guided``: CFG doubles every request into two segments.
    ``row_capacity``: tokens per packed row; 0 resolves to the mode-0
    sequence length at build time.
    """
    groups: Tuple[Tuple[int, int], ...]
    guided: bool = True
    row_capacity: int = 0

    def __post_init__(self):
        if not self.groups:
            raise ValueError("layout needs at least one (mode, n) group")
        modes = [m for m, _ in self.groups]
        if sorted(modes) != modes or len(set(modes)) != len(modes):
            raise ValueError(f"groups must be mode-sorted and unique, "
                             f"got {self.groups}")
        if any(n < 1 for _, n in self.groups) or any(m < 0 for m in modes):
            raise ValueError(f"modes must be >= 0 and counts >= 1, "
                             f"got {self.groups}")

    @property
    def n_requests(self) -> int:
        return sum(n for _, n in self.groups)

    def capacity_for(self, m: int) -> int:
        """Request slots this layout offers at mode ``m``."""
        return dict(self.groups).get(m, 0)

    def resolve_capacity(self, cfg: ModelConfig) -> int:
        if self.row_capacity:
            return self.row_capacity
        return max([dit_mod.tokens_for_mode(cfg, 0)]
                   + [dit_mod.tokens_for_mode(cfg, m) for m, _ in self.groups])

    def segment_modes(self) -> Tuple[int, ...]:
        """Flat per-segment mode list (CFG doubling applied)."""
        mult = 2 if self.guided else 1
        out = []
        for m, n in self.groups:
            out.extend([m] * (mult * n))
        return tuple(out)

    def cost(self, cfg: ModelConfig,
             attn_backend: str = "dense") -> packing.MixedPackCost:
        """Rows / FLOPs / token ledger of one step at this layout."""
        return packing.mixed_pack_cost(cfg, self.segment_modes(),
                                       self.resolve_capacity(cfg),
                                       attn_backend=attn_backend)

    def attention_block_stats(self, cfg: ModelConfig) -> Tuple[int, int]:
        """(active, total) attention block-tile visits of one step at
        this layout under the segment-aware Pallas kernel."""
        return packing.pack_attention_block_stats(
            cfg, self.segment_modes(), self.resolve_capacity(cfg))

    @staticmethod
    def for_counts(counts: Dict[int, int], guided: bool = True,
                   row_capacity: int = 0) -> "PackLayout":
        groups = tuple(sorted((m, n) for m, n in counts.items() if n > 0))
        return PackLayout(groups=groups, guided=guided,
                          row_capacity=row_capacity)


def make_packed_step_fn(cfg: ModelConfig, sched: sch.DiffusionSchedule,
                        layout: PackLayout, *, solver: str = "ddim",
                        guidance_scale: float = 1.5,
                        clip_x0: float = 0.0,
                        k_steps: int = 1,
                        cache_split: Optional[int] = None,
                        attn_backend: str = "auto",
                        taps: bool = False) -> Callable:
    """Build ``step(params, xs, metas, keys)`` for a layout.

    Per group ``g`` (one per mode): ``xs[g]`` [n_g, F, H, W, C] latents;
    ``metas[g]`` [k, 3, n_g] int32 with rows ``(t, t_prev, cond)`` per
    micro-step — each request at its OWN denoise step (``t_prev=-1``
    means the final x0 step), one host→device transfer per group;
    ``keys[g]`` [k, n_g, 2] uint32 per-request solver keys (DDPM
    ancestral noise; ignored by DDIM). Returns one ``x`` array per group
    after ``k_steps`` solver updates.

    ``k_steps > 1`` runs the packed step body under ``lax.scan`` — the
    engine dispatches K consecutive same-mode denoise steps in one call,
    recovering the whole-trajectory sampler's scan fusion while keeping
    join/leave at K-step granularity. Matches per-request
    ``FlexiPipeline.sample`` bit-for-bit in expectation: same embedding
    path, same guidance combine, same solver arithmetic, and DDPM noise
    drawn per request from the same key derivation.

    ``cache_split`` enables the cross-step activation cache (DESIGN.md
    §cache): the step becomes ``step(params, xs, metas, keys, deltas,
    refreshes) → (xs', deltas')`` where ``deltas[g]`` is
    [n_g, mult, N_mode, d] per-request deep-block residuals (mult = 2
    under CFG) and ``refreshes[g]`` is [k, n_g] bool — each request's
    own staleness clock, threaded through the ``lax.scan`` carry so a
    K-deep dispatch refreshes exactly where the request's policy says.
    Refresh flags are traced data: one compiled layout serves every
    policy.

    ``taps`` appends on-device telemetry outputs (DESIGN.md §telemetry)
    as pure extra DATA: the step becomes ``... → (xs'[, deltas'], tap)``
    where ``tap = {"eps_norm": ([k, n_g], ...), "attn_blocks": [2]}``
    plus ``"drift": ([k, n_g], ...)`` on the cached family —
    per-request RMS of the post-guidance eps, the kernel ledger's
    (active, total) block tiles, and the realized replay drift
    ``‖h_fresh − h_replay‖`` computed from residuals the step already
    materializes. Latents and deltas are bit-identical to ``taps=False``
    (DCE of the tap outputs recovers the untapped jaxpr — asserted in
    ``analysis/jaxpr_audit.py``), and taps join the runner cache key, so
    flipping telemetry never retraces a serving executable.
    """
    if solver not in PACKED_SOLVERS:
        raise ValueError(f"packed steps support solvers {PACKED_SOLVERS}, "
                         f"got {solver!r}")
    if cfg.dit.conditioning != "class":
        raise ValueError("packed steps currently serve class-conditioned "
                         "DiTs (text conditioning needs per-segment "
                         "cross-attention plumbing)")
    if k_steps < 1:
        raise ValueError(f"k_steps must be >= 1, got {k_steps}")
    if cache_split is not None and not 1 <= cache_split < cfg.num_layers:
        raise ValueError(f"cache_split {cache_split} must leave at least "
                         f"one deep block (model has {cfg.num_layers} "
                         f"layers)")
    guided = layout.guided
    if guided and guidance_scale == 0.0:
        raise ValueError("guided layout with guidance_scale=0; build an "
                         "unguided layout instead")
    null_label = cfg.dit.num_classes
    groups = layout.groups
    cap = layout.resolve_capacity(cfg)
    seg_groups = tuple((m, (2 if guided else 1) * n) for m, n in groups)

    cached = cache_split is not None
    # kernel-ledger block counts are layout-static: resolved on the host
    # once at build time, emitted as a tap constant (data, not structure)
    blk_stats = layout.attention_block_stats(cfg) if taps else None

    def one_step(params, xs, metas, keys, deltas=None, refreshes=None):
        seg_xs, seg_ts, seg_conds = [], [], []
        seg_deltas, seg_refresh = [], []
        for g, (mode, n) in enumerate(groups):
            t_g, cond_g = metas[g][0], metas[g][2]
            if guided:
                seg_xs.append(jnp.concatenate([xs[g], xs[g]], axis=0))
                seg_ts.append(jnp.concatenate([t_g, t_g], axis=0))
                null = jnp.full((n,), null_label, jnp.int32)
                seg_conds.append(jnp.concatenate([cond_g, null], axis=0))
            else:
                seg_xs.append(xs[g])
                seg_ts.append(t_g)
                seg_conds.append(cond_g)
            if cached:
                # [n, mult, N, d] → segment order (all cond, then all
                # uncond) matching seg_xs; both branches share the clock
                d_g = deltas[g]
                seg_deltas.append(jnp.concatenate(
                    [d_g[:, b] for b in range(d_g.shape[1])], axis=0))
                rf = refreshes[g]
                seg_refresh.append(jnp.concatenate([rf, rf], axis=0)
                                   if guided else rf)
        if cached:
            outs, new_seg_deltas = packing.packed_mixed_forward(
                params, cfg, seg_groups, seg_xs, seg_ts, seg_conds,
                row_capacity=cap, cache_deltas=seg_deltas,
                cache_refresh=seg_refresh, cache_split=cache_split,
                attn_backend=attn_backend)
            new_deltas = []
            for g, (mode, n) in enumerate(groups):
                mult = deltas[g].shape[1]
                new_deltas.append(jnp.stack(
                    jnp.split(new_seg_deltas[g], mult, axis=0), axis=1))
        else:
            outs = packing.packed_mixed_forward(params, cfg, seg_groups,
                                                seg_xs, seg_ts, seg_conds,
                                                row_capacity=cap,
                                                attn_backend=attn_backend)
        x_prevs, eps_taps = [], []
        for g, (mode, n) in enumerate(groups):
            t_g, tp_g = metas[g][0], metas[g][1]
            eps, logvar = split_model_out(outs[g], cfg)
            if guided:
                e_c, e_u = jnp.split(eps, 2, axis=0)
                eps_g = e_u + guidance_scale * (e_c - e_u)
                lv = None if logvar is None else jnp.split(logvar, 2,
                                                           axis=0)[0]
            else:
                eps_g, lv = eps, logvar
            if taps:
                eps_taps.append(taps_mod.eps_norm_tap(eps_g))
            if solver == "ddim":
                x_prev = sch.ddim_step(sched, xs[g], eps_g, t_g,
                                       tp_g, 0.0, None)
            else:
                # per-request ancestral noise: vmap draws each request's
                # noise from its own key, exactly as an n=1 pipeline batch
                if lv is None:
                    x_prev = jax.vmap(
                        lambda x1, e1, t1, k1: sch.ddpm_step(
                            sched, x1, e1, t1, k1, None, clip_x0)
                    )(xs[g], eps_g, t_g, keys[g])
                else:
                    x_prev = jax.vmap(
                        lambda x1, e1, t1, k1, lv1: sch.ddpm_step(
                            sched, x1, e1, t1, k1, lv1, clip_x0)
                    )(xs[g], eps_g, t_g, keys[g], lv)
            x_prevs.append(x_prev)
        if taps:
            tap = {"eps_norm": tuple(eps_taps),
                   # per-request-slot all-finite flag of the step OUTPUT —
                   # pure DATA riding the tap channel, so quarantine can
                   # read it at an existing sync point without adding one
                   "finite": tuple(taps_mod.finite_tap(xp)
                                   for xp in x_prevs)}
            if cached:
                # ‖h_fresh − h_replay‖: the cached forward writes
                # new_delta = where(refresh, h_deep − h_shallow, old), so
                # the realized replay error is one subtraction of arrays
                # the step already materialized — free at refresh steps,
                # exactly 0 at skip steps
                tap["drift"] = tuple(
                    taps_mod.drift_tap(nd, deltas[g])
                    for g, nd in enumerate(new_deltas))
                return tuple(x_prevs), tuple(new_deltas), tap
            return tuple(x_prevs), tap
        if cached:
            return tuple(x_prevs), tuple(new_deltas)
        return tuple(x_prevs)

    def _tap_out(tap):
        """Attach the layout-static kernel-ledger constant; tap arrays
        keep a leading k axis either way (scan stacks, k=1 expands)."""
        tap["attn_blocks"] = jnp.asarray(blk_stats, jnp.int32)
        return tap

    if k_steps == 1:
        if cached:
            if taps:
                def step(params, xs, metas, keys, deltas, refreshes):
                    m1 = tuple(m[0] for m in metas)
                    k1 = tuple(k[0] for k in keys)
                    r1 = tuple(r[0] for r in refreshes)
                    out, dout, tap = one_step(params, xs, m1, k1,
                                              tuple(deltas), r1)
                    tap = jax.tree_util.tree_map(lambda a: a[None], tap)
                    return out, dout, _tap_out(tap)
                return step

            def step(params, xs, metas, keys, deltas, refreshes):
                m1 = tuple(m[0] for m in metas)
                k1 = tuple(k[0] for k in keys)
                r1 = tuple(r[0] for r in refreshes)
                return one_step(params, xs, m1, k1, tuple(deltas), r1)
            return step

        if taps:
            def step(params, xs, metas, keys):
                m1 = tuple(m[0] for m in metas)
                k1 = tuple(k[0] for k in keys)
                out, tap = one_step(params, xs, m1, k1)
                tap = jax.tree_util.tree_map(lambda a: a[None], tap)
                return out, _tap_out(tap)
            return step

        def step(params, xs, metas, keys):
            m1 = tuple(m[0] for m in metas)
            k1 = tuple(k[0] for k in keys)
            return one_step(params, xs, m1, k1)
        return step

    if cached:
        if taps:
            def step(params, xs, metas, keys, deltas, refreshes):
                def body(carry, per_step):
                    cxs, cdeltas = carry
                    m, k, r = per_step
                    nxs, nds, tap = one_step(params, cxs, m, k, cdeltas, r)
                    return (nxs, nds), tap
                (out, dout), tap = jax.lax.scan(
                    body, (tuple(xs), tuple(deltas)),
                    (tuple(metas), tuple(keys), tuple(refreshes)))
                return out, dout, _tap_out(tap)
            return step

        def step(params, xs, metas, keys, deltas, refreshes):
            def body(carry, per_step):
                cxs, cdeltas = carry
                m, k, r = per_step
                nxs, nds = one_step(params, cxs, m, k, cdeltas, r)
                return (nxs, nds), None
            (out, dout), _ = jax.lax.scan(
                body, (tuple(xs), tuple(deltas)),
                (tuple(metas), tuple(keys), tuple(refreshes)))
            return out, dout
        return step

    if taps:
        def step(params, xs, metas, keys):
            def body(carry, per_step):
                m, k = per_step
                nxs, tap = one_step(params, carry, m, k)
                return nxs, tap
            out, tap = jax.lax.scan(body, tuple(xs),
                                    (tuple(metas), tuple(keys)))
            return out, _tap_out(tap)
        return step

    def step(params, xs, metas, keys):
        def body(carry, per_step):
            m, k = per_step
            return one_step(params, carry, m, k), None
        out, _ = jax.lax.scan(body, tuple(xs), (tuple(metas), tuple(keys)))
        return out

    return step
