"""Declarative sampling plans (DESIGN.md §pipeline).

A ``SamplingPlan`` is a frozen, hashable description of one FlexiDiT
inference run: solver, step count, compute budget, guidance, and LoRA
handling. Budgets come in three shapes:

* an explicit :class:`~repro.core.scheduler.FlexiSchedule` (phases);
* a float target *relative-compute fraction* in (0, 1], solved to the
  weak-first schedule with the fewest weak steps meeting the target
  (fewest weak steps ⇒ least quality loss within the budget);
* :class:`AdaptiveBudget` — the per-sample probe loop (paper App. A).

The plan performs all validation up front and exposes analytic FLOPs via
``.flops(cfg)`` / ``.relative_compute(cfg)``, delegating to
``core.scheduler.schedule_flops`` so budgets line up with the paper's
reporting convention everywhere (benchmarks, serving, tests).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

from repro.cache.policy import CacheSpec
from repro.configs.base import ModelConfig
from repro.core.scheduler import (FlexiSchedule, dit_nfe_flops,
                                  lora_nfe_overhead, schedule_flops)
from repro.distributed.partition import ParallelSpec

CACHED_SOLVERS = ("ddim", "ddpm")    # the packed-step solver family

STATIC_SOLVERS = ("ddpm", "ddim", "dpm2")
FLOW_SOLVERS = ("flow_euler", "flow_heun")
ADAPTIVE_SOLVERS = ("ddim", "ddpm")     # single-eps solvers (probe reuse)


@dataclasses.dataclass(frozen=True)
class AdaptiveBudget:
    """Per-sample adaptive budget: probe both modes every ``probe_every``
    steps and switch weak→powerful once the relative prediction gap
    exceeds ``threshold`` (core.adaptive)."""
    threshold: float = 0.35
    probe_every: int = 2

    def __post_init__(self):
        if self.threshold < 0:
            raise ValueError(f"threshold must be >= 0, got {self.threshold}")
        if self.probe_every < 1:
            raise ValueError(f"probe_every must be >= 1, got {self.probe_every}")


Budget = Union[FlexiSchedule, float, AdaptiveBudget]


@dataclasses.dataclass(frozen=True)
class SamplingPlan:
    """One inference run, declaratively. See module docstring for budgets."""
    T: int                               # denoising steps (ladder length)
    budget: Budget = 1.0
    solver: str = "ddim"
    guidance_scale: float = 1.5          # 0 disables guidance entirely
    guidance_kind: str = "uncond"        # 'uncond' (CFG) | 'weak_cond' (§3.4)
    weak_mode: int = 1                   # patch mode used for weak phases
    lora: str = "merged"                 # 'merged' | 'unmerged' (§3.2, Fig. 5)
    weak_last: bool = False              # App. B.4 ablation (fraction budgets)
    clip_x0: float = 0.0                 # DDPM-only x0 clipping
    # sequence-parallel execution over a device mesh (repro.distributed);
    # the mesh itself is owned by the pipeline, keeping plans declarative
    parallel: Optional[ParallelSpec] = None
    # cross-step activation cache (repro.cache, DESIGN.md §cache): deep
    # blocks replay a cached residual on refresh-skip steps. The spec's
    # SPLIT joins the runner key (structure); its policy/threshold only
    # shape the refresh mask (data) — policy switches never recompile.
    cache: Optional[CacheSpec] = None
    # attention backend (DESIGN.md §attention-backend): 'auto' resolves
    # to the segment-aware Pallas flash kernel on packed/long token
    # streams and the dense XLA path otherwise; joins the pipeline's
    # runner-cache key, so switching backends compiles fresh runners
    # while budget switches under a fixed backend stay compile-free.
    attn_backend: str = "auto"

    def __post_init__(self):
        if isinstance(self.budget, int):        # budget=1 → fraction 1.0
            object.__setattr__(self, "budget", float(self.budget))
        if self.T < 1:
            raise ValueError(f"T must be >= 1, got {self.T}")
        if self.solver not in STATIC_SOLVERS + FLOW_SOLVERS:
            raise ValueError(f"unknown solver {self.solver!r}; "
                             f"known: {STATIC_SOLVERS + FLOW_SOLVERS}")
        if self.guidance_kind not in ("uncond", "weak_cond"):
            raise ValueError(f"unknown guidance_kind {self.guidance_kind!r}")
        from repro.models.attention import ATTN_BACKENDS
        if self.attn_backend not in ATTN_BACKENDS:
            raise ValueError(f"unknown attn_backend {self.attn_backend!r}; "
                             f"known: {ATTN_BACKENDS}")
        if self.lora not in ("merged", "unmerged"):
            raise ValueError(f"lora must be 'merged'|'unmerged', got {self.lora!r}")
        if self.weak_mode < 1:
            raise ValueError(f"weak_mode must be >= 1, got {self.weak_mode}")
        if isinstance(self.budget, float) and not 0.0 < self.budget <= 1.0:
            raise ValueError(f"fraction budget must be in (0, 1], got {self.budget}")
        if isinstance(self.budget, FlexiSchedule) \
                and self.budget.total_steps != self.T:
            raise ValueError(f"schedule covers {self.budget.total_steps} steps "
                             f"but plan.T={self.T}")
        if self.is_adaptive and self.solver not in ADAPTIVE_SOLVERS:
            raise ValueError(f"adaptive budgets support solvers "
                             f"{ADAPTIVE_SOLVERS}, got {self.solver!r}")
        if self.is_adaptive and self.weak_last:
            raise ValueError("weak_last only applies to static budgets")
        if self.solver in FLOW_SOLVERS and self.guidance_scale != 0.0:
            raise ValueError("flow solvers are unguided; set guidance_scale=0")
        if self.parallel is not None:
            if not isinstance(self.parallel, ParallelSpec):
                raise ValueError(f"parallel must be a ParallelSpec, got "
                                 f"{type(self.parallel).__name__}")
            if self.is_adaptive:
                raise ValueError("sequence-parallel adaptive plans are not "
                                 "supported yet (the probe loop runs on the "
                                 "host); use a static or fraction budget")
        if self.cache is not None:
            if not isinstance(self.cache, CacheSpec):
                raise ValueError(f"cache must be a CacheSpec, got "
                                 f"{type(self.cache).__name__}")
            if self.solver not in CACHED_SOLVERS:
                raise ValueError(f"the activation cache supports solvers "
                                 f"{CACHED_SOLVERS}, got {self.solver!r}")
            if self.is_adaptive:
                raise ValueError("adaptive plans decide modes per sample; "
                                 "the activation cache needs a static "
                                 "schedule")
            if self.guidance_active and self.guidance_kind != "uncond":
                raise ValueError("the activation cache supports vanilla "
                                 "CFG only (weak_cond mixes patch modes "
                                 "inside one step)")
            if self.parallel is not None:
                raise ValueError("the activation cache does not compose "
                                 "with sequence-parallel plans yet")

    # ------------------------------------------------------------------
    @property
    def is_adaptive(self) -> bool:
        return isinstance(self.budget, AdaptiveBudget)

    @property
    def guidance_active(self) -> bool:
        return self.guidance_scale != 0.0 and self.solver not in FLOW_SOLVERS

    def validate(self, cfg: ModelConfig) -> None:
        """cfg-dependent checks (mode indices, LoRA availability, budgets)."""
        n_modes = 1 + len(cfg.dit.flex_patch_sizes)
        if self.weak_mode >= n_modes:
            raise ValueError(f"weak_mode={self.weak_mode} but the model has "
                             f"{n_modes} patch modes")
        if isinstance(self.budget, FlexiSchedule):
            for mode, _ in self.budget.phases:
                if not 0 <= mode < n_modes:
                    raise ValueError(f"schedule uses mode {mode}; model has "
                                     f"{n_modes} modes")
        if isinstance(self.budget, float):
            floor = self._relative(cfg, self._weak_first(self.T))
            if self.budget < floor:
                raise ValueError(
                    f"fraction budget {self.budget:.3f} below the model's "
                    f"all-weak floor {floor:.3f} at T={self.T}")
        if self.lora == "unmerged" and cfg.dit.lora_rank <= 0 \
                and not self.is_adaptive:
            # harmless no-op, but likely a caller mistake — surface it
            raise ValueError("lora='unmerged' on a model without LoRA adapters")
        if self.cache is not None:
            self.cache.resolve_split(cfg.num_layers)   # raises when invalid

    # ------------------------------------------------------------------
    # Budget resolution

    def _weak_first(self, t_weak: int) -> FlexiSchedule:
        mk = (FlexiSchedule.powerful_first if self.weak_last
              else FlexiSchedule.weak_first)
        return mk(self.T, t_weak, self.weak_mode)

    def _flop_kwargs(self, cfg: ModelConfig, schedule: FlexiSchedule) -> dict:
        kw: dict = {
            "cfg_scale_active": self.guidance_active,
            "lora_unmerged": (self.lora == "unmerged"
                              and cfg.dit.lora_rank > 0),
        }
        if self.guidance_active and self.guidance_kind == "weak_cond":
            # §3.4: powerful phases take their guidance NFE from the weak mode
            kw["guidance_modes"] = tuple(
                (m, self.weak_mode if m == 0 else m)
                for m, _ in schedule.phases)
        return kw

    def _relative(self, cfg: ModelConfig, schedule: FlexiSchedule) -> float:
        # denominator: the vanilla all-powerful run (plain CFG, no LoRA
        # overhead — mode 0 never pays it), NOT the plan's guidance variant
        base = FlexiSchedule(((0, self.T),))
        base_fl = schedule_flops(cfg, base,
                                 cfg_scale_active=self.guidance_active)
        return (schedule_flops(cfg, schedule, **self._flop_kwargs(cfg, schedule))
                / base_fl)

    def resolve_schedule(self, cfg: ModelConfig) -> FlexiSchedule:
        """Static budgets only: the concrete FlexiSchedule this plan runs."""
        if self.is_adaptive:
            raise ValueError("adaptive plans have no static schedule; the "
                             "switch point is decided per sample")
        if isinstance(self.budget, FlexiSchedule):
            return self.budget
        # fraction: the FEWEST weak steps whose relative compute meets the
        # target (relative compute is strictly decreasing in T_weak)
        for t_weak in range(self.T + 1):
            s = self._weak_first(t_weak)
            if self._relative(cfg, s) <= self.budget + 1e-12:
                return s
        raise ValueError(f"no weak-first schedule at T={self.T} meets "
                         f"budget {self.budget}")   # unreachable post-validate

    # ------------------------------------------------------------------
    # Analytic FLOPs

    def flops(self, cfg: ModelConfig, batch: int = 1,
              attn_backend: str = "dense") -> float:
        """Denoising FLOPs for a ``batch``-sample run.

        Static plans delegate to ``core.scheduler.schedule_flops``. Adaptive
        plans return the worst case (never switching + all probes); the
        actual spend is reported per run in ``SampleResult.flops``.

        ``attn_backend`` defaults to the paper's dense-N² reporting
        convention; the serving controller passes the plan's real backend
        so capacity math charges what the kernel issues (DESIGN.md
        §attention-backend). Budget RESOLUTION always stays on the dense
        convention — backends change pricing, never schedules.
        """
        if self.is_adaptive:
            mult = 2.0 if self.guidance_active else 1.0
            f_w = mult * dit_nfe_flops(cfg, self.weak_mode,
                                       attn_backend=attn_backend)
            if self.lora == "unmerged" and cfg.dit.lora_rank > 0:
                f_w += mult * lora_nfe_overhead(cfg, self.weak_mode)
            f_p = mult * dit_nfe_flops(cfg, 0, attn_backend=attn_backend)
            n_probes = len(range(0, self.T, self.budget.probe_every))
            return batch * (self.T * f_w + n_probes * f_p)
        schedule = self.resolve_schedule(cfg)
        total = schedule_flops(cfg, schedule, attn_backend=attn_backend,
                               **self._flop_kwargs(cfg, schedule))
        if self.solver in ("flow_heun", "dpm2"):
            total *= 2.0                 # 2nd-order solvers: 2 NFEs per step
        return batch * total

    def cached_flops(self, cfg: ModelConfig, batch: int = 1,
                     num_train_steps: int = 1000,
                     attn_backend: str = "dense") -> float:
        """Denoising FLOPs with the activation cache applied: skip steps
        pay shallow blocks only (``repro.cache.ledger``). Falls back to
        :meth:`flops` when the plan carries no cache.

        ``num_train_steps`` is the diffusion-schedule length the ladder
        respaces over — banded/proxy masks depend on the actual ``t``
        values, so callers that know the pipeline's schedule (the
        serving controller does) should pass it; the default is the
        paper's 1000-step convention."""
        if self.cache is None:
            return self.flops(cfg, batch, attn_backend=attn_backend)
        from repro.cache.ledger import schedule_cached_flops
        from repro.diffusion.schedule import respaced_timesteps
        schedule = self.resolve_schedule(cfg)
        ts = respaced_timesteps(num_train_steps, self.T)
        total, _, _ = schedule_cached_flops(
            cfg, schedule, ts, self.cache,
            cfg_scale_active=self.guidance_active,
            lora_unmerged=(self.lora == "unmerged"
                           and cfg.dit.lora_rank > 0),
            attn_backend=attn_backend)
        return batch * total

    def relative_compute(self, cfg: ModelConfig) -> float:
        """Compute fraction vs the all-powerful baseline with the same T."""
        if self.is_adaptive:
            base = dataclasses.replace(self, budget=1.0)
            return self.flops(cfg) / base.flops(cfg)
        return self._relative(cfg, self.resolve_schedule(cfg))


def solve_t_weak(cfg: ModelConfig, T: int, target: float, *,
                 weak_mode: int = 1, guidance: bool = True) -> int:
    """Smallest ``T_weak`` whose weak-first schedule meets ``target``
    relative compute (convenience wrapper used by serving and tests)."""
    plan = SamplingPlan(T=T, budget=float(target), weak_mode=weak_mode,
                        guidance_scale=1.5 if guidance else 0.0)
    plan.validate(cfg)
    return plan.resolve_schedule(cfg).phases[0][1]
