"""Unified FlexiDiT inference API (DESIGN.md §pipeline).

``SamplingPlan`` declares *what* to run (solver, steps, compute budget,
guidance, LoRA handling, optional sequence-parallel execution);
``FlexiPipeline`` owns the weights, the device mesh, and compiled
executables and runs plans without ever recompiling for repeated calls.
"""
from repro.cache.policy import CacheSpec  # noqa: F401
from repro.distributed.partition import ParallelSpec  # noqa: F401
from repro.pipeline.packed import PackLayout, make_packed_step_fn  # noqa: F401
from repro.pipeline.pipeline import FlexiPipeline, SampleResult  # noqa: F401
from repro.pipeline.plan import (AdaptiveBudget, SamplingPlan,  # noqa: F401
                                 solve_t_weak)
