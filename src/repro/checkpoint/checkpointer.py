"""Fault-tolerant checkpointing: atomic directory commits, async saves,
retention, and **elastic restore** (reshard onto a different mesh/topology
than the one that wrote the checkpoint).

Layout:  <root>/step_<N>/{manifest.json, <flat__key__path>.npy, COMMITTED}
A checkpoint directory without the COMMITTED marker is ignored (a crash
mid-save never corrupts restore).
"""
from __future__ import annotations

import concurrent.futures
import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

SEP = "__"


def _flatten(tree: Any, prefix: Tuple[str, ...] = ()) -> Dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], prefix + (str(k),)))
    else:
        out[SEP.join(prefix)] = tree
    return out


def _unflatten(flat: Dict[str, Any]) -> Any:
    root: Dict[str, Any] = {}
    for key, val in flat.items():
        parts = key.split(SEP)
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return root


class Checkpointer:
    def __init__(self, root: str | Path, keep: int = 3,
                 async_save: bool = True):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._pending: Optional[concurrent.futures.Future] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        host = jax.tree.map(lambda x: np.asarray(x), tree)
        if self.async_save:
            self.wait()
            self._pending = self._pool.submit(self._write, step, host, extra)
        else:
            self._write(step, host, extra)

    def wait(self):
        if self._pending is not None:
            self._pending.result()
            self._pending = None

    def _write(self, step: int, host_tree: Any, extra: Optional[Dict]):
        final = self.root / f"step_{step:08d}"
        tmp = self.root / f".tmp_step_{step:08d}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)
        flat = _flatten(host_tree)
        manifest = {"step": step, "extra": extra or {},
                    "leaves": {k: {"shape": list(v.shape),
                                   "dtype": str(v.dtype)}
                               for k, v in flat.items()}}
        for k, v in flat.items():
            np.save(tmp / f"{k}.npy", v)
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        (tmp / "COMMITTED").write_text(str(time.time()))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep > 0 else []:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in self.root.glob("step_*"):
            if (d / "COMMITTED").exists():
                out.append(int(d.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None,
                shardings: Any = None) -> Tuple[Any, Dict]:
        """Load a checkpoint. ``shardings``: optional pytree of
        ``NamedSharding`` (same structure) — enables **elastic restore**:
        arrays are placed directly onto the (possibly different) new mesh.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no committed checkpoints in {self.root}")
        d = self.root / f"step_{step:08d}"
        manifest = json.loads((d / "manifest.json").read_text())
        flat = {k: np.load(d / f"{k}.npy")
                for k in manifest["leaves"]}
        tree = _unflatten(flat)
        if shardings is not None:
            flat_s = _flatten(shardings)
            placed = {k: jax.device_put(v, flat_s[k]) if k in flat_s else v
                      for k, v in flat.items()}
            tree = _unflatten(placed)
        return tree, manifest["extra"]
