"""End-to-end inference telemetry (DESIGN.md §telemetry).

Three layers, one rule — **observability must be data, not structure**:

* :mod:`repro.telemetry.trace` — host-side span/event recorder (bounded
  ring buffer, simulated- or wall-clock) with Chrome-trace/Perfetto
  export; instruments the request lifecycle queue admit → pack decision
  → dispatch → device step(s) → materialization → finish plus compile
  events.
* :mod:`repro.telemetry.taps` — on-device scalar taps threaded as extra
  **data** outputs through ``make_packed_step_fn`` (per-request eps
  norm, realized cache replay drift ``‖h_fresh − h_replay‖``, the
  kernel ledger's attention block counts). No host callbacks, no
  ``debug.print``, no recompiles: DCE of the tap outputs recovers the
  untapped jaxpr bit-for-bit (asserted in ``analysis/jaxpr_audit.py``).
* :mod:`repro.telemetry.export` — Prometheus text-format + JSON
  snapshot exporters over ``ServingMetrics`` summaries and tap
  aggregates (duck-typed: this module never imports the engine).

``Telemetry`` bundles a recorder + tap aggregator for the serving
engine; device values cross to the host only inside
``TapAggregator.aggregate()`` / trace export — never on the dispatch
path.
"""
from repro.telemetry.taps import TapAggregator, TapSample  # noqa: F401
from repro.telemetry.trace import SpanRecorder, TraceEvent  # noqa: F401


class Telemetry:
    """One serving session's telemetry bundle.

    ``taps=False`` keeps the engine on the untapped step family (spans
    only); ``taps=True`` routes dispatches through the tapped runners —
    same latents bit-for-bit, plus per-dispatch tap samples.
    """

    def __init__(self, clock=None, taps: bool = False,
                 max_events: int = 65536, max_samples: int = 4096):
        self.recorder = SpanRecorder(clock=clock, max_events=max_events)
        self.taps = TapAggregator(max_samples=max_samples)
        self.taps_enabled = bool(taps)

    def bind_clock(self, clock) -> None:
        """Adopt the engine's clock (simulated or wall) if the recorder
        was built before the engine existed."""
        self.recorder.clock = clock

    def snapshot(self) -> dict:
        """JSON-friendly view: tap aggregates + recorder counters."""
        return {"taps_enabled": self.taps_enabled,
                "tap_aggregates": self.taps.aggregate(),
                "events_recorded": self.recorder.events_recorded,
                "events_dropped": self.recorder.events_dropped}
